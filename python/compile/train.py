"""Build-time training of the tiny GQA transformer on the synthetic
corpus.  Runs once inside `make artifacts`; never on the request path.

Plain Adam in jnp -- the model is ~1M parameters, a few hundred steps on
CPU take a couple of minutes.  The loss curve is logged to
artifacts/train_log.tsv and summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.float32(0.0)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1.0
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps)
           for k in params}
    return new, {"m": m, "v": v, "t": t}


def train(cfg: model.Config, steps=400, batch=16, seqlen=128, lr=1e-3,
          seed=0, log_every=20, corpus_names=("wiki_syn", "c4_syn"),
          verbose=True):
    """Returns (params, log) where log is a list of (step, loss).

    Trains on a mixture of corpora (like the paper's models, which are
    competent on both Wikitext-2 and C4) so that both evaluation
    corpora are in-domain; pile_syn stays calibration-only.
    """
    params = model.init_params(cfg, seed)
    state = adam_init(params)

    @jax.jit
    def update(params, state, block):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, block, cfg)
        new, state = adam_update(params, grads, state, lr)
        return new, state, loss

    tokens = np.concatenate(
        [corpus.make_splits(n)[0] for n in corpus_names])
    rng = np.random.default_rng(seed + 1)
    log = []
    t0 = time.time()
    step = 0
    while step < steps:
        for block in corpus.batches(tokens, batch, seqlen, rng):
            params, state, loss = update(params, state, jnp.asarray(block))
            step += 1
            if step % log_every == 0 or step == 1:
                log.append((step, float(loss)))
                if verbose:
                    print(f"  step {step:4d}  loss {float(loss):.4f}  "
                          f"({time.time() - t0:.1f}s)", flush=True)
            if step >= steps:
                break
    return params, log


def save_weights(params: Dict[str, jnp.ndarray], bin_path, tsv_path=None):
    """Flat f32 little-endian in sorted-name order + TSV manifest."""
    names = sorted(params)
    with open(bin_path, "wb") as f:
        offset = 0
        rows = []
        for n in names:
            a = np.asarray(params[n], np.float32)
            f.write(a.tobytes())
            rows.append((n, "x".join(map(str, a.shape)), offset, a.size))
            offset += a.size
    if tsv_path:
        with open(tsv_path, "w") as f:
            f.write("name\tshape\toffset_f32\tcount\n")
            for n, shp, off, cnt in rows:
                f.write(f"{n}\t{shp}\t{off}\t{cnt}\n")


def load_weights(bin_path, cfg: model.Config):
    shapes = model.param_shapes(cfg)
    flat = np.fromfile(bin_path, dtype="<f4")
    params, off = {}, 0
    for n in sorted(shapes):
        cnt = int(np.prod(shapes[n]))
        params[n] = jnp.asarray(flat[off:off + cnt].reshape(shapes[n]))
        off += cnt
    assert off == flat.size, (off, flat.size)
    return params
