"""Baseline quantization algorithms (paper Section VI-A).

Simplified-but-faithful re-implementations of the algorithms P3-LLM is
compared against.  Each follows the published method's *mechanism*:

  * Oaken  [42]: offline calibration picks per-channel KV outlier
    channels; those stay at INT8, the rest go INT4 (effective ~4.8 bit).
  * QuaRot [2]:  Hadamard rotation folded into weights offline, applied
    to activations online; then plain INT W4A8KV4.
  * QoQ/QServe [53]: SmoothQuant-style calibrated channel smoothing for
    activations *and* key cache, then INT W4A8KV4.
  * SmoothQuant [88]: calibrated smoothing, W8A8.
  * AWQ [52]: activation-aware per-channel weight scaling, W4 group-128,
    A16.

All calibration statistics come from a *calibration corpus* -- the
overfitting this induces when evaluating on a different corpus is one of
the paper's central claims (Table IV, Fig. 8), so the corpus used for
calibration is an explicit argument everywhere.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import model, quant


# ----------------------------------------------------------------------
# Calibration: per-channel activation / KV statistics
# ----------------------------------------------------------------------


def calibrate(params, blocks, cfg: model.Config):
    """Run the fp model over calibration blocks and collect per-channel
    absolute maxima at every quantization site.

    Returns dict of numpy arrays:
      asm_attn/asm_o/asm_mlp/asm_down : [L, site_dim] linear-input maxima
      k_absmax_pre / k_absmax_post    : [L, kvdim]    key-cache maxima
      v_absmax                        : [L, kvdim]    value-cache maxima
    """
    L = cfg.n_layers

    @jax.jit
    def stats_one(block):
        tokens = block[:, :-1]
        B, T = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x = params["tok_emb"][tokens]
        causal = jnp.tril(jnp.ones((T, T), jnp.float32))
        out = {k: [] for k in ("asm_attn", "asm_o", "asm_mlp", "asm_down",
                               "k_absmax_pre", "k_absmax_post", "v_absmax")}
        for i in range(L):
            p = f"layer{i}."
            xa = model._rmsnorm(x, params[p + "norm_attn"], cfg.norm_eps)
            out["asm_attn"].append(jnp.max(jnp.abs(xa), axis=(0, 1)))
            q = xa @ params[p + "wq"]
            k = xa @ params[p + "wk"]
            v = xa @ params[p + "wv"]
            out["k_absmax_pre"].append(jnp.max(jnp.abs(k), axis=(0, 1)))
            out["v_absmax"].append(jnp.max(jnp.abs(v), axis=(0, 1)))
            qh = model._rope(
                q.reshape(B, T, cfg.n_heads, cfg.d_head), pos, cfg)
            kh = model._rope(
                k.reshape(B, T, cfg.n_kv, cfg.d_head), pos, cfg)
            kpost = kh.reshape(B, T, cfg.n_kv * cfg.d_head)
            out["k_absmax_post"].append(jnp.max(jnp.abs(kpost), axis=(0, 1)))
            g = cfg.gqa_group
            att = jnp.einsum("bqhd,bkhd->bhqk", qh, jnp.repeat(kh, g, 2))
            att = att / np.sqrt(cfg.d_head)
            att = jnp.where(causal[None, None] > 0, att, -1e30)
            pr = jax.nn.softmax(att, axis=-1)
            vh = v.reshape(B, T, cfg.n_kv, cfg.d_head)
            o = jnp.einsum("bhqk,bkhd->bqhd", pr, jnp.repeat(vh, g, 2))
            o = o.reshape(B, T, cfg.n_heads * cfg.d_head)
            out["asm_o"].append(jnp.max(jnp.abs(o), axis=(0, 1)))
            x2 = x + o @ params[p + "wo"]
            xm = model._rmsnorm(x2, params[p + "norm_mlp"], cfg.norm_eps)
            out["asm_mlp"].append(jnp.max(jnp.abs(xm), axis=(0, 1)))
            act = jax.nn.silu(xm @ params[p + "wgate"]) * (
                xm @ params[p + "wup"])
            out["asm_down"].append(jnp.max(jnp.abs(act), axis=(0, 1)))
            x = x2 + act @ params[p + "wdown"]
        return {k: jnp.stack(v) for k, v in out.items()}

    acc = None
    for block in blocks:
        st = stats_one(jnp.asarray(block))
        st = {k: np.asarray(v) for k, v in st.items()}
        if acc is None:
            acc = st
        else:
            acc = {k: np.maximum(acc[k], st[k]) for k in acc}
    return acc


# ----------------------------------------------------------------------
# Weight transformations (host-side; mirrored bit-exactly in Rust where
# the serving path needs them)
# ----------------------------------------------------------------------

_LINEAR_SUFFIXES = tuple(model.LINEAR_NAMES) + ("lm_head",)


def _is_linear(name):
    return name.endswith(_LINEAR_SUFFIXES)


def _map_linear(params, fn):
    return {k: (fn(k, v) if _is_linear(k) else v) for k, v in params.items()}


def weights_int4(params, group=128):
    """Plain INT4 asymmetric per-group (along input dim) fake-quant."""
    def q(_, w):
        return np.asarray(
            quant.quant_int_asym_grouped(jnp.asarray(w).T, 4.0, group).T)
    return _map_linear(params, q)


def weights_bitmod(params, group=128):
    """BitMoD 4-bit fake-quant (paper Section IV-C)."""
    def q(_, w):
        return np.asarray(quant.quant_bitmod(jnp.asarray(w).T, group).T)
    return _map_linear(params, q)


def weights_quarot(params, cfg, group=128, quant_bits=4):
    """QuaRot: fold Hadamard into the input dim of the residual-stream
    linears (wq/wk/wv/wgate/wup), then INT4 per-group quantization of all
    linears.  The matching online rotation is scheme flag hadamard=True.
    """
    h = np.asarray(quant.hadamard_matrix(cfg.d_model))
    rotated = {}
    for k, v in params.items():
        if k.endswith(("wq", "wk", "wv", "wgate", "wup")):
            rotated[k] = h.T @ np.asarray(v)
        else:
            rotated[k] = np.asarray(v)
    return weights_int4(rotated, group) if quant_bits == 4 else rotated


def smooth_sites(stats, params, cfg, alpha=0.5):
    """SmoothQuant/QoQ activation-smoothing factors per linear-input site
    plus the matching input-channel-scaled weights.

    Returns (aux_vectors dict, scaled params dict).  Activations get
    divided by s, weight input channels multiplied by s.
    """
    L = cfg.n_layers
    out_aux = {
        "asm_attn": np.ones((L, cfg.d_model), np.float32),
        "asm_o": np.ones((L, cfg.n_heads * cfg.d_head), np.float32),
        "asm_mlp": np.ones((L, cfg.d_model), np.float32),
        "asm_down": np.ones((L, cfg.d_ff), np.float32),
    }
    scaled = {k: np.asarray(v).copy() for k, v in params.items()}
    site_weights = {
        "asm_attn": ("wq", "wk", "wv"),
        "asm_o": ("wo",),
        "asm_mlp": ("wgate", "wup"),
        "asm_down": ("wdown",),
    }
    for i in range(L):
        for site, wnames in site_weights.items():
            amax = stats[site][i]
            wmax = np.max(
                [np.abs(scaled[f"layer{i}.{w}"]).max(axis=1)
                 for w in wnames],
                axis=0,
            )
            s = np.asarray(quant.smoothquant_factors(
                jnp.asarray(amax), jnp.asarray(wmax), alpha))
            out_aux[site][i] = s
            for w in wnames:
                scaled[f"layer{i}.{w}"] *= s[:, None]
    return out_aux, scaled


def build_qoq(params, stats, cfg, alpha=0.5, group=128):
    """QoQ: calibrated activation smoothing + calibrated key smoothing +
    INT4 per-group weights.  Returns (aux updates, weights)."""
    aux_vecs, scaled = smooth_sites(stats, params, cfg, alpha)
    aux_vecs["qoq_ksm"] = np.maximum(stats["k_absmax_post"], 1e-6)
    return aux_vecs, weights_int4(scaled, group)


def build_smoothquant(params, stats, cfg, alpha=0.5):
    """SmoothQuant: calibrated smoothing + INT8 per-group weights."""
    aux_vecs, scaled = smooth_sites(stats, params, cfg, alpha)

    def q8(_, w):
        return np.asarray(
            quant.quant_int_asym_grouped(jnp.asarray(w).T, 8.0, 128).T)
    return aux_vecs, _map_linear(scaled, q8)


def build_oaken_masks(stats, cfg, frac=0.1):
    """Oaken: flag the top-`frac` key/value channels (by calibrated
    absmax) per layer as INT8-resident outlier channels."""
    def mask_of(absmax):
        L, C = absmax.shape
        n8 = max(1, int(round(frac * C)))
        m = np.zeros((L, C), np.float32)
        for i in range(L):
            idx = np.argsort(absmax[i])[-n8:]
            m[i, idx] = 1.0
        return m
    return {
        "oaken_mask_k": mask_of(stats["k_absmax_post"]),
        "oaken_mask_v": mask_of(stats["v_absmax"]),
    }


def weights_awq(params, stats, cfg, alpha=0.25, group=128):
    """AWQ: activation-aware weight scaling s = amax_act^alpha applied to
    weight input channels before INT4 group quant, inverted after --
    weight-only, activations stay fp."""
    site_of = {
        "wq": "asm_attn", "wk": "asm_attn", "wv": "asm_attn",
        "wo": "asm_o", "wgate": "asm_mlp", "wup": "asm_mlp",
        "wdown": "asm_down",
    }
    out = {}
    for k, v in params.items():
        suffix = k.split(".")[-1]
        if suffix in site_of:
            layer = int(k.split(".")[0].removeprefix("layer"))
            amax = stats[site_of[suffix]][layer]
            s = np.maximum(amax, 1e-6) ** alpha
            w = np.asarray(v) * s[:, None]
            wq = np.asarray(
                quant.quant_int_asym_grouped(jnp.asarray(w).T, 4.0, group).T)
            out[k] = wq / s[:, None]
        elif k == "lm_head":
            out[k] = np.asarray(
                quant.quant_int_asym_grouped(
                    jnp.asarray(v).T, 4.0, group).T)
        else:
            out[k] = np.asarray(v)
    return out
