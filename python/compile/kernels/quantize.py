"""L1 Pallas kernels: standalone quantizers.

These run on the NPU side of Fig. 6 (activations / new KV entries are
quantized before being shipped to the PCU input registers).  They are
lowered both standalone (kernel microbench artifacts) and fused into
the decode graphs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _e4m3_kernel(x_ref, o_ref):
    x = x_ref[...]
    ax = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.maximum(ax, 1e-38)))
    e = jnp.clip(e, -6.0, 8.0)
    ulp = jnp.exp2(e - 3.0)
    q = jnp.asarray(jnp.rint(ax / ulp), x.dtype) * ulp
    o_ref[...] = jnp.sign(x) * jnp.minimum(q, 448.0)


def fp8_e4m3(x, row_blk=None):
    """Row-blocked FP8-E4M3 cast of a 2-D tensor."""
    r, c = x.shape
    rb = r if row_blk is None else min(row_blk, r)
    assert r % rb == 0
    return pl.pallas_call(
        _e4m3_kernel,
        grid=(r // rb,),
        in_specs=[pl.BlockSpec((rb, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(x)


def _int4_asym_kernel(x_ref, o_ref):
    x = x_ref[...]  # [rows, group]
    levels = 15.0
    xmin = jnp.min(x, axis=-1, keepdims=True)
    xmax = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum(xmax - xmin, 1e-8) / levels
    q = jnp.clip(jnp.round((x - xmin) / scale), 0.0, levels)
    o_ref[...] = q * scale + xmin


def int4_asym_per_head(x, head_dim, row_blk=64):
    """INT4-Asym per-head fake-quant of [T, kvdim] new KV entries; each
    contiguous `head_dim` span of one token shares (scale, zero)."""
    t, kvdim = x.shape
    assert kvdim % head_dim == 0
    rows = t * (kvdim // head_dim)
    xg = x.reshape(rows, head_dim)
    rb = min(row_blk, rows)
    assert rows % rb == 0
    out = pl.pallas_call(
        _int4_asym_kernel,
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, head_dim), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, head_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, head_dim), jnp.float32),
        interpret=True,
    )(xg)
    return out.reshape(t, kvdim)
