"""Pure-jnp oracles for every L1 Pallas kernel.

pytest (python/tests/test_kernels.py) asserts kernel == ref to within
float tolerance across hypothesis-driven shape/value sweeps; this is the
core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import quant


def w4a8_matmul_ref(x, codes, scales, specials, group=128):
    """Reference fused dequant matmul: decode BitMoD codes eagerly with
    the same table math, then a plain jnp matmul."""
    tables = np.tile(quant.FP4_BASE[None, :], (4, 1))
    tables = np.concatenate(
        [tables, np.asarray(quant.BITMOD_SPECIALS)[:, None]], axis=1
    )
    table = jnp.asarray(tables.reshape(-1), jnp.float32)
    sel = jnp.repeat(specials.astype(jnp.int32), group, axis=0)
    sc = jnp.repeat(scales, group, axis=0)
    w = jnp.take(table, sel * 16 + codes.astype(jnp.int32)) * sc
    return x @ w


def decode_attention_ref(q, k_cache, v_cache, attend, quantized=True):
    """Reference GQA decode attention with S0E4M4 score rounding."""
    b, nh, dh = q.shape
    _, ctx, nkv, _ = k_cache.shape
    g = nh // nkv
    kg = jnp.repeat(k_cache, g, axis=2)  # [B, ctx, nh, dh]
    vg = jnp.repeat(v_cache, g, axis=2)
    att = jnp.einsum("bhd,bkhd->bhk", q, kg) / np.sqrt(dh)
    att = jnp.where(attend[:, None, :], att, -1e30)
    att = att - jnp.max(att, axis=-1, keepdims=True)
    ex = jnp.exp(att)
    p = ex / jnp.sum(ex, axis=-1, keepdims=True)
    if quantized:
        p = quant.quant_fp8_s0e4m4(p)
    return jnp.einsum("bhk,bkhd->bhd", p, vg)


def fp8_e4m3_ref(x):
    return quant.quant_fp8_e4m3(x)


def int4_asym_per_head_ref(x, head_dim):
    return quant.quant_kv_asym_per_head(x, 4.0, head_dim)
