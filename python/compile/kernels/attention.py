"""L1 Pallas kernel: quantized GQA decode attention (paper Fig. 6b/6c).

One grid step handles one (batch, kv-head) pair: the G = n_heads/n_kv
query heads that share a kv head compute Q.K^T over the whole cache,
softmax, FP8-S0E4M4 score rounding, and P.V -- the full self-attention
offload that the low-precision PCU enables (Section IV-B: without 8-bit
scores the P.V GEMV would have to fall back to the NPU).

The kv cache arrives as fp values already snapped to the INT4-Asym grid
(dequantized by the KV manager / PCU decoder); score quantization is
done in-kernel, after softmax, exactly where Fig. 6(c) fuses it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _s0e4m4(p):
    """Unsigned FP8-S0E4M4 rounding (4-bit exp bias 15, 4-bit mantissa),
    p in [0, 1].  Mirrors quant.quant_fp8_s0e4m4 with in-kernel ops."""
    p = jnp.clip(p, 0.0, 1.0)
    e = jnp.floor(jnp.log2(jnp.maximum(p, 1e-38)))
    e = jnp.clip(e, -14.0, 0.0)
    ulp = jnp.exp2(e - 4.0)
    q = jnp.asarray(jnp.rint(p / ulp), p.dtype) * ulp
    return jnp.minimum(q, 1.0)


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale, quantized):
    q = q_ref[...][0]  # [G, dh]
    k = k_ref[...][0, :, 0]  # [ctx, dh]
    v = v_ref[...][0, :, 0]  # [ctx, dh]
    m = mask_ref[...][0]  # [ctx]
    att = (q @ k.T) * scale  # [G, ctx]
    att = jnp.where(m[None, :] > 0, att, -1e30)
    att = att - jnp.max(att, axis=-1, keepdims=True)
    ex = jnp.exp(att)
    p = ex / jnp.sum(ex, axis=-1, keepdims=True)
    if quantized:
        p = _s0e4m4(p)
    o_ref[...] = (p @ v)[None]


def decode_attention(q, k_cache, v_cache, attend, *, quantized=True):
    """q: [B, nh, dh]; k_cache/v_cache: [B, ctx, n_kv, dh];
    attend: [B, ctx] bool/int mask.  Returns [B, nh, dh]."""
    b, nh, dh = q.shape
    _, ctx, nkv, _ = k_cache.shape
    g = nh // nkv
    scale = 1.0 / float(dh) ** 0.5
    mask = attend.astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, quantized=quantized),
        grid=(b, nkv),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ctx, 1, dh), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, ctx, 1, dh), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, ctx), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, dh), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, mask)


def vmem_bytes(b, nh, dh, ctx, nkv):
    """Estimated VMEM working set of one grid step (for §Perf)."""
    g = nh // nkv
    return 4 * (g * dh + 2 * ctx * dh + ctx + g * ctx + g * dh)
