"""L1 Pallas kernel: fused BitMoD-W4 x FP8-A8 GEMV/GEMM (paper Fig. 6c).

This is the PCU's dataflow expressed as a Pallas kernel: 4-bit weight
*codes* travel to the compute unit untouched (as they would over the
256-bit DRAM column bus) and are dequantized *inside* the kernel right
before the multiply -- operator fusion eliminates any materialized fp16
weight tensor, which is the paper's "minimize runtime dequantization
overhead" co-design point.

Tiling (§Hardware-Adaptation of DESIGN.md): the PCU computes a 1x4x16
GEMV tile (4 8-bit inputs x 64 4-bit weights -> 16 accumulators).  On
TPU we scale the same schedule up to VMEM/MXU granularity: the grid
walks output-column blocks of N_BLK (the "16 PEs" axis, x4 PCUs per
group) while the full K axis (the "4-way dot product" axis, unrolled
over commands) stays resident in VMEM -- K is at most a few hundred for
the edge models this targets, exactly like a DRAM row worth of codes.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerical behaviour is identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..quant import BITMOD_SPECIALS, FP4_BASE

N_BLK = 64  # output columns per grid step = one PCU command's 64 weights
GROUP = 128  # BitMoD quantization group along K


def _dequant_table():
    """Flat [4*16] BitMoD dequant LUT: entry 16*s + c decodes code c under
    special-select s.  Code 15 is the special-value slot."""
    t = np.tile(FP4_BASE[None, :], (4, 1))  # [4, 15]
    t = np.concatenate([t, np.asarray(BITMOD_SPECIALS)[:, None]], axis=1)
    return jnp.asarray(t.reshape(-1), jnp.float32)  # [64]


def _kernel(table_ref, x_ref, codes_ref, scales_ref, specials_ref, o_ref,
            *, group):
    table = table_ref[...]  # [64] BitMoD dequant LUT
    x = x_ref[...]  # [B, K] fp8-e4m3-grid values
    codes = codes_ref[...].astype(jnp.int32)  # [K, Nb] in 0..15
    scales = scales_ref[...]  # [K//group, Nb]
    specials = specials_ref[...].astype(jnp.int32)  # [K//group, Nb]
    # expand per-group metadata along K
    sel = jnp.repeat(specials, group, axis=0)  # [K, Nb]
    sc = jnp.repeat(scales, group, axis=0)  # [K, Nb]
    w = jnp.take(table, sel * 16 + codes) * sc  # fused dequant
    o_ref[...] = x @ w


def w4a8_matmul(x, codes, scales, specials, *, group=GROUP, n_blk=N_BLK):
    """x: [B, K] f32 (values on the FP8-E4M3 grid -- the caller quantizes
    activations, mirroring the NPU->PCU input registers), codes: [K, N]
    uint8 BitMoD codes, scales: [K//group, N] f32, specials: [K//group, N]
    uint8.  Returns [B, N] f32 with 32-bit accumulation (f32 here)."""
    b, k = x.shape
    kc, n = codes.shape
    assert kc == k and k % group == 0, (x.shape, codes.shape, group)
    nb = min(n_blk, n)
    assert n % nb == 0, (n, nb)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=(n // nb,),
        in_specs=[
            pl.BlockSpec((64,), lambda j: (0,)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((k, nb), lambda j: (0, j)),
            pl.BlockSpec((k // group, nb), lambda j: (0, j)),
            pl.BlockSpec((k // group, nb), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, nb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(_dequant_table(), x, codes, scales, specials)


def vmem_bytes(b, k, n, *, group=GROUP, n_blk=N_BLK):
    """Estimated VMEM working set of one grid step (for §Perf)."""
    nb = min(n_blk, n)
    return (
        b * k * 4  # x block (f32)
        + k * nb * 1  # codes (u8)
        + 2 * (k // group) * nb * 4  # scales + specials blocks
        + b * nb * 4  # output accumulators
    )
