"""Hybrid numerical formats for P3-LLM (Section IV of the paper).

Every function here is a *fake-quant* transformation: it maps an fp32
tensor to the exact value grid of the target format and back, so the
returned tensor is fp32 but numerically identical to what the PCU
computes after dequantization.  All functions are written in jnp and are
jax-traceable, so they can be used both (a) eagerly for host-side weight
quantization / golden-vector generation, and (b) inside the L2 eval/decode
graphs that get AOT-lowered to HLO.

Formats implemented:
  * INT-b asymmetric / symmetric per-group quantization (b may be a traced
    scalar -> the "operand bit-width sweep" of Fig. 3b lowers to ONE graph)
  * FP8-E4M3   (standard OCP format, bias 7, max 448)       -- activations
  * FP8-S0E4M4 (paper's unsigned sign-free format, bias 15)  -- attn scores
  * BitMoD FP4 (FP4 grid + per-group remapped special value) -- weights
  * Dynamic per-channel key-cache smoothing (Eq. 2)

The Rust crate re-implements the packed/encoded versions bit-exactly
(`rust/src/quant/`); `aot.py` emits golden vectors produced by this module
that the Rust tests must match exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Integer quantization (Eq. 1 of the paper)
# --------------------------------------------------------------------------


def quant_int_asym(x, bits, axis=-1):
    """Asymmetric integer fake-quant along `axis`.

    `bits` may be a python int or a traced fp scalar (>= 16 disables
    quantization, used by the Fig. 3b sweep graph).
    """
    bits = jnp.asarray(bits, jnp.float32)
    levels = jnp.exp2(bits) - 1.0
    xmin = jnp.min(x, axis=axis, keepdims=True)
    xmax = jnp.max(x, axis=axis, keepdims=True)
    scale = jnp.maximum(xmax - xmin, 1e-8) / levels
    q = jnp.round((x - xmin) / scale)
    q = jnp.clip(q, 0.0, levels)
    xq = q * scale + xmin
    return jnp.where(bits >= 16.0, x, xq)


def quant_int_sym(x, bits, axis=-1):
    """Symmetric integer fake-quant along `axis` (zero-point = 0)."""
    bits = jnp.asarray(bits, jnp.float32)
    qmax = jnp.exp2(bits - 1.0) - 1.0
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return jnp.where(bits >= 16.0, x, q * scale)


def quant_int_asym_grouped(x, bits, group):
    """Asymmetric int fake-quant over contiguous groups of the last axis."""
    shp = x.shape
    assert shp[-1] % group == 0, (shp, group)
    xg = x.reshape(shp[:-1] + (shp[-1] // group, group))
    return quant_int_asym(xg, bits, axis=-1).reshape(shp)


# --------------------------------------------------------------------------
# FP8 formats
# --------------------------------------------------------------------------


def _round_fp(x, n_mantissa, e_min, e_max, max_val):
    """Round |x| to a float grid with `n_mantissa` mantissa bits and
    exponent range [e_min, e_max] (normal numbers), with subnormals at
    e_min.  Round-half-to-even on the mantissa ULP.  Sign preserved.
    """
    ax = jnp.abs(x)
    sign = jnp.sign(x)
    # exponent of the representable value
    e = jnp.floor(jnp.log2(jnp.maximum(ax, 1e-38)))
    e = jnp.clip(e, e_min, e_max)
    ulp = jnp.exp2(e - n_mantissa)
    # round-half-even via rint (numpy/jax rint is half-to-even)
    q = jnp.asarray(jnp.rint(ax / ulp), x.dtype) * ulp
    # rounding may bump the exponent (e.g. 1.96 -> 2.0); that is still on
    # the grid, but may exceed max_val -> saturate.
    q = jnp.minimum(q, max_val)
    return sign * q


def quant_fp8_e4m3(x):
    """OCP FP8-E4M3: 4-bit exponent (bias 7), 3-bit mantissa, max 448."""
    return _round_fp(x, n_mantissa=3, e_min=-6.0, e_max=8.0, max_val=448.0)


def quant_fp8_e5m2(x):
    """OCP FP8-E5M2: 5-bit exponent (bias 15), 2-bit mantissa, max 57344."""
    return _round_fp(x, n_mantissa=2, e_min=-14.0, e_max=15.0, max_val=57344.0)


def quant_fp8_s0e4m4(x):
    """Paper's unsigned FP8-S0E4M4 for attention scores (Section IV-B).

    No sign bit, 4-bit exponent with bias 15 (stored 1..15 -> e in
    [-14, 0]), 4-bit mantissa; stored-exponent 0 holds subnormals with
    e = -14.  The grid covers [0, 1]: softmax outputs need nothing more
    (exactly-1.0 is representable as stored_e=15, m=0).
    """
    x = jnp.clip(x, 0.0, 1.0)
    return _round_fp(x, n_mantissa=4, e_min=-14.0, e_max=0.0, max_val=1.0)


def quant_int8_unsigned(x):
    """Unsigned INT8 for attention scores in [0, 1] (SageAttention-style),
    scale fixed at 1/255 -- the Table II 'INT8' row."""
    return jnp.clip(jnp.round(x * 255.0), 0.0, 255.0) / 255.0


# --------------------------------------------------------------------------
# BitMoD weight format (Section IV-C)
# --------------------------------------------------------------------------

# FP4 basic grid: +-{0, 0.5, 1, 1.5, 2, 3, 4, 6}; negative zero is redundant
# and is remapped to one of the special values {-8, -5, +5, +8} per group.
FP4_BASE = np.array(
    [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0],
    dtype=np.float32,
)  # 15 values; the 16th slot is the per-group special value
BITMOD_SPECIALS = np.array([-8.0, -5.0, 5.0, 8.0], dtype=np.float32)


def _bitmod_tables():
    """All four candidate 16-entry dequant tables, shape [4, 16]."""
    tables = np.tile(FP4_BASE[None, :], (4, 1))
    tables = np.concatenate([tables, BITMOD_SPECIALS[:, None]], axis=1)
    return jnp.asarray(tables)  # [4, 16]


def quant_bitmod(w, group=128):
    """BitMoD fake-quant of a weight matrix along the last axis.

    For every contiguous group of `group` elements, tries each of the four
    special-value tables; for each candidate the scale is
    max|w| / max|table|, values snap to the nearest table entry, and the
    table with the smallest squared error wins.  Returns the dequantized
    tensor (same shape as w).
    """
    shp = w.shape
    assert shp[-1] % group == 0, (shp, group)
    wg = w.reshape(-1, group)  # [G, group]
    tables = _bitmod_tables()  # [4, 16]
    amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)  # [G, 1]
    tmax = jnp.max(jnp.abs(tables), axis=-1)  # [4]
    # scale per (group, table): [G, 4]
    scales = jnp.maximum(amax, 1e-8) / tmax[None, :]

    def snap(table, scale):
        # wg: [G, group], table: [16], scale: [G, 1]
        grid = table[None, None, :] * scale[:, :, None]  # [G, 1, 16]
        d = jnp.abs(wg[:, :, None] - grid)  # [G, group, 16]
        idx = jnp.argmin(d, axis=-1)  # [G, group]
        return jnp.take(table, idx) * scale  # [G, group]

    cands = [snap(tables[t], scales[:, t : t + 1]) for t in range(4)]
    cands = jnp.stack(cands, axis=0)  # [4, G, group]
    errs = jnp.sum((cands - wg[None]) ** 2, axis=-1)  # [4, G]
    best = jnp.argmin(errs, axis=0)  # [G]
    out = jnp.take_along_axis(cands, best[None, :, None], axis=0)[0]
    return out.reshape(shp)


def quant_bitmod_encode(w, group=128):
    """Eager (numpy) BitMoD *encoder*: returns (codes u8 in 0..15,
    scales f32 [G], specials u8 in 0..3) for the packed artifact path and
    for golden vectors.  Code 15 is the special value slot.
    """
    w = np.asarray(w, np.float32)
    shp = w.shape
    wg = w.reshape(-1, group)
    tables = np.asarray(_bitmod_tables())  # [4, 16]
    amax = np.maximum(np.abs(wg).max(axis=-1, keepdims=True), 1e-8)
    tmax = np.abs(tables).max(axis=-1)
    scales = amax / tmax[None, :]  # [G, 4]
    best_err = np.full(wg.shape[0], np.inf, np.float32)
    best_codes = np.zeros(wg.shape, np.uint8)
    best_sel = np.zeros(wg.shape[0], np.uint8)
    best_scale = np.zeros(wg.shape[0], np.float32)
    for t in range(4):
        grid = tables[t][None, :] * scales[:, t : t + 1]  # [G, 16]
        idx = np.abs(wg[:, :, None] - grid[:, None, :]).argmin(axis=-1)
        deq = np.take_along_axis(grid, idx, axis=1)
        err = ((deq - wg) ** 2).sum(axis=-1)
        upd = err < best_err
        best_err = np.where(upd, err, best_err)
        best_codes[upd] = idx[upd]
        best_sel[upd] = t
        best_scale[upd] = scales[upd, t]
    return (
        best_codes.reshape(shp),
        best_scale.astype(np.float32),
        best_sel,
    )


def bitmod_decode(codes, scales, specials, group=128):
    """Eager decoder matching `quant_bitmod_encode` (numpy)."""
    tables = np.asarray(_bitmod_tables())
    codes = np.asarray(codes, np.int64)
    shp = codes.shape
    cg = codes.reshape(-1, group)
    vals = tables[np.asarray(specials, np.int64)[:, None], cg]
    return (vals * np.asarray(scales)[:, None]).reshape(shp).astype(np.float32)


# --------------------------------------------------------------------------
# KV-cache quantization with dynamic smoothing (Section IV-A)
# --------------------------------------------------------------------------


def smoothing_factors(k, eps=1e-6):
    """Per-channel absolute maxima of the key cache (Eq. 2).

    k: [..., T, C] -> factors [..., 1, C], computed over the token axis.
    During serving these are computed once at prefill and reused in decode;
    in the teacher-forced eval graphs the full sequence plays the role of
    the prefill context.
    """
    return jnp.maximum(jnp.max(jnp.abs(k), axis=-2, keepdims=True), eps)


def quant_kv_asym_per_head(x, bits, head_dim):
    """INT-b asymmetric per-head KV quantization: each group of `head_dim`
    contiguous channels of one token shares (scale, zero-point)."""
    return quant_int_asym_grouped(x, bits, head_dim)


def quant_key_smoothed(k, bits, head_dim, factors=None):
    """Dynamic input-aware smoothed key quantization.

    k: [..., T, C]; divide channels by the smoothing factors, INT-b
    per-head fake-quant, multiply back.  (Serving fuses the multiply into
    the query -- numerically identical.)
    """
    f = smoothing_factors(k) if factors is None else factors
    return quant_kv_asym_per_head(k / f, bits, head_dim) * f


def quant_kv_oaken(x, mask8, head_dim):
    """Oaken-style mixed-precision KV quant: channels flagged by `mask8`
    (an offline-calibrated outlier mask, broadcastable over tokens) are
    kept at INT8, the rest at INT4.  Effective precision ~4 + 4*frac bits.
    """
    q4 = quant_kv_asym_per_head(x, 4.0, head_dim)
    q8 = quant_kv_asym_per_head(x, 8.0, head_dim)
    return jnp.where(mask8 > 0.5, q8, q4)


# --------------------------------------------------------------------------
# Hadamard rotation (QuaRot baseline, Section VI-A)
# --------------------------------------------------------------------------


def hadamard_matrix(n):
    """Normalized Hadamard matrix of size n (n must be a power of two)."""
    assert n & (n - 1) == 0, n
    h = np.array([[1.0]], np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return jnp.asarray(h / np.sqrt(n))


def hadamard_rotate(x, h):
    """Rotate the channel (last) axis: x @ H."""
    return x @ h


# --------------------------------------------------------------------------
# SmoothQuant-style calibrated smoothing (QoQ / SmoothQuant baselines)
# --------------------------------------------------------------------------


def smoothquant_factors(act_absmax, w_absmax, alpha=0.5, eps=1e-6):
    """Per-channel migration factors s = amax_a^alpha / amax_w^(1-alpha).

    Activations are divided by s, weight *input* channels multiplied by s.
    `act_absmax` comes from an offline calibration corpus -- this is the
    overfitting-prone step the paper criticizes (Fig. 8 / Table IV).
    """
    a = jnp.maximum(act_absmax, eps)
    w = jnp.maximum(w_absmax, eps)
    s = a**alpha / w ** (1.0 - alpha)
    return jnp.maximum(s, eps)
