"""Synthetic corpora standing in for Wikitext-2 / C4 / Pile.

The paper's accuracy experiments need (a) text a small model can learn,
and (b) *distribution shift between corpora* so that calibration-based
baselines (Oaken, QoQ) visibly overfit (Table IV, Fig. 8).  We generate
three byte-level corpora from three different probabilistic grammars:

  * ``wiki_syn`` -- encyclopedia-style sentences: entity + relation +
    attribute templates with a closed world of facts (so repeated entities
    create learnable long-range structure).
  * ``c4_syn``   -- webby mixture: product reviews, how-to fragments and
    number-heavy lines; different lexicon and punctuation statistics.
  * ``pile_syn`` -- code-ish / log-ish lines; used only as the calibration
    corpus for the QoQ baseline (mirroring the paper, which calibrates QoQ
    on Pile).

Tokenization is byte-level (vocab 256); token 0 is reserved as BOS/newline
separator.  Everything is deterministic given the seed.
"""

from __future__ import annotations

import numpy as np

VOCAB = 256
BOS = 0


def _rng(seed):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------------
# wiki_syn grammar
# ----------------------------------------------------------------------

_ENTITIES = [
    "aldora", "brevik", "celund", "dravos", "eltheria", "fenwick",
    "gorlim", "halvard", "ithilan", "jorveth", "kelmora", "lunden",
    "morvane", "nerith", "oskaria", "pellago", "quenlan", "rothgar",
    "sylvane", "torvald",
]
_RELATIONS = [
    "is the capital of", "lies north of", "was founded by",
    "exports grain to", "borders", "is governed by", "trades with",
    "was rebuilt after", "is twinned with", "pays tribute to",
]
_ATTRS = [
    "a walled city", "a river port", "a mountain hold", "a fishing town",
    "an old republic", "a mining colony", "a free harbor", "a salt market",
]


def _wiki_sentence(r):
    a = _ENTITIES[r.integers(len(_ENTITIES))]
    b = _ENTITIES[r.integers(len(_ENTITIES))]
    rel = _RELATIONS[r.integers(len(_RELATIONS))]
    if r.random() < 0.4:
        attr = _ATTRS[r.integers(len(_ATTRS))]
        return f"{a} {rel} {b} , and {a} is {attr} ."
    year = 800 + int(r.integers(400))
    return f"in {year} , {a} {rel} {b} ."


# ----------------------------------------------------------------------
# c4_syn grammar
# ----------------------------------------------------------------------

_PRODUCTS = [
    "kettle", "lantern", "backpack", "router", "blender", "drone",
    "keyboard", "tripod", "heater", "speaker",
]
_OPINIONS = [
    "works great", "stopped working", "exceeded my expectations",
    "arrived late", "is worth every penny", "feels cheap",
    "does the job", "broke after a week",
]
_STEPS = [
    "unplug the unit", "press and hold the reset button",
    "check the firmware version", "clean the filter",
    "charge it overnight", "update the app",
]


def _c4_sentence(r):
    p = _PRODUCTS[r.integers(len(_PRODUCTS))]
    if r.random() < 0.5:
        op = _OPINIONS[r.integers(len(_OPINIONS))]
        stars = 1 + int(r.integers(5))
        return f"the {p} {op} ! rating : {stars} / 5 ."
    s1 = _STEPS[r.integers(len(_STEPS))]
    s2 = _STEPS[r.integers(len(_STEPS))]
    return f"to fix your {p} , first {s1} , then {s2} ."


# ----------------------------------------------------------------------
# pile_syn grammar (calibration only)
# ----------------------------------------------------------------------

_FUNCS = ["init", "read", "write", "flush", "close", "sync", "poll", "map"]
_OBJS = ["buf", "ctx", "dev", "node", "page", "sock", "ring", "slot"]


def _pile_sentence(r):
    f = _FUNCS[r.integers(len(_FUNCS))]
    o = _OBJS[r.integers(len(_OBJS))]
    if r.random() < 0.5:
        code = int(r.integers(256))
        return f"[{code:02x}] {f}_{o} returned {int(r.integers(64))} ;"
    return f"if ( {f}_{o} ( {o} ) < 0 ) goto err_{o} ;"


_GRAMMARS = {
    "wiki_syn": (_wiki_sentence, 1234),
    "c4_syn": (_c4_sentence, 5678),
    "pile_syn": (_pile_sentence, 9012),
}


def generate_text(name, n_sentences, seed_offset=0):
    fn, seed = _GRAMMARS[name]
    r = _rng(seed + seed_offset)
    return "\n".join(fn(r) for _ in range(n_sentences))


def tokenize(text):
    """Byte-level tokens; newlines become BOS separators."""
    raw = text.encode("utf-8", errors="replace")
    toks = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
    toks = np.where(toks == ord("\n"), BOS, toks)
    return toks


def detokenize(tokens):
    b = bytes(int(t) if t != BOS else ord("\n") for t in np.asarray(tokens))
    return b.decode("utf-8", errors="replace")


def corpus_tokens(name, n_sentences, seed_offset=0):
    return tokenize(generate_text(name, n_sentences, seed_offset))


def make_splits(name, n_train_sent=20000, n_eval_sent=2000):
    """(train_tokens, eval_tokens) with disjoint sentence streams."""
    train = corpus_tokens(name, n_train_sent, seed_offset=0)
    evals = corpus_tokens(name, n_eval_sent, seed_offset=1_000_003)
    return train, evals


def batches(tokens, batch, seqlen, rng=None, n_batches=None):
    """Yield [batch, seqlen+1] teacher-forcing blocks (inputs+targets)."""
    span = seqlen + 1
    n = (len(tokens) - 1) // span
    starts = np.arange(n) * span
    if rng is not None:
        rng.shuffle(starts)
    if n_batches is not None:
        starts = starts[: n_batches * batch]
    for i in range(0, len(starts) - batch + 1, batch):
        idx = starts[i : i + batch]
        yield np.stack([tokens[s : s + span] for s in idx]).astype(np.int32)
