"""Analysis graphs for Fig. 5 (KV-cache distribution) and Fig. 8
(layer-wise key-cache quantization error).

These are lowered to HLO like every other graph; the Rust bench harness
streams evaluation corpora through them and aggregates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import model, quant


def k_caches(params, tokens, cfg: model.Config):
    """Per-layer pre-RoPE and post-RoPE key caches for a token block.

    tokens: [B, T] -> (k_pre [L, B, T, kvdim], k_post [L, B, T, kvdim]).
    """
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = params["tok_emb"][tokens]
    causal = jnp.tril(jnp.ones((T, T), jnp.float32))
    pres, posts = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xa = model._rmsnorm(x, params[p + "norm_attn"], cfg.norm_eps)
        q = xa @ params[p + "wq"]
        k = xa @ params[p + "wk"]
        v = xa @ params[p + "wv"]
        pres.append(k)
        qh = model._rope(q.reshape(B, T, cfg.n_heads, cfg.d_head), pos, cfg)
        kh = model._rope(k.reshape(B, T, cfg.n_kv, cfg.d_head), pos, cfg)
        posts.append(kh.reshape(B, T, cfg.n_kv * cfg.d_head))
        g = cfg.gqa_group
        att = jnp.einsum("bqhd,bkhd->bhqk", qh, jnp.repeat(kh, g, 2))
        att = att / np.sqrt(cfg.d_head)
        att = jnp.where(causal[None, None] > 0, att, -1e30)
        pr = jax.nn.softmax(att, axis=-1)
        vh = v.reshape(B, T, cfg.n_kv, cfg.d_head)
        o = jnp.einsum("bhqk,bkhd->bqhd", pr, jnp.repeat(vh, g, 2))
        x = x + o.reshape(B, T, -1) @ params[p + "wo"]
        xm = model._rmsnorm(x, params[p + "norm_mlp"], cfg.norm_eps)
        act = jax.nn.silu(xm @ params[p + "wgate"]) * (xm @ params[p + "wup"])
        x = x + act @ params[p + "wdown"]
    return jnp.stack(pres), jnp.stack(posts)


def kdist_report(params, block, cfg: model.Config):
    """Fig. 5 statistics: per-channel absmax of the key cache pre-RoPE,
    post-RoPE, and post-smoothing, plus per-channel mean |K|.

    block: [B, T+1] -> dict of [L, kvdim] arrays.
    """
    k_pre, k_post = k_caches(params, block[:, :-1], cfg)
    f = jnp.maximum(jnp.max(jnp.abs(k_post), axis=(1, 2)), 1e-6)  # [L, C]
    k_sm = k_post / f[:, None, None, :]
    return (
        jnp.max(jnp.abs(k_pre), axis=(1, 2)),
        jnp.max(jnp.abs(k_post), axis=(1, 2)),
        jnp.max(jnp.abs(k_sm), axis=(1, 2)),
        jnp.mean(jnp.abs(k_post), axis=(1, 2)),
    )


def kv_error_report(params, block, aux, cfg: model.Config):
    """Fig. 8: per-layer key-cache quantization error of three methods,
    normalized by the mean |K| of the layer.

    Methods (all INT4, post-RoPE):
      0  P3-LLM  -- dynamic per-channel smoothing from the live block
      1  Oaken   -- calibrated outlier mask (aux[oaken_mask_k])
      2  QoQ     -- calibrated smoothing factors (aux[qoq_ksm])

    Returns [3, L] normalized mean-squared errors.
    """
    _, k_post = k_caches(params, block[:, :-1], cfg)
    dh = cfg.d_head

    def err(kq):
        return jnp.mean((kq - k_post) ** 2, axis=(1, 2, 3))

    f_dyn = jnp.maximum(jnp.max(jnp.abs(k_post), axis=(1, 2)), 1e-6)
    p3 = quant.quant_kv_asym_per_head(
        k_post / f_dyn[:, None, None, :], 4.0, dh) * f_dyn[:, None, None, :]
    oaken = quant.quant_kv_oaken(
        k_post, aux["oaken_mask_k"][:, None, None, :], dh)
    f_cal = aux["qoq_ksm"][:, None, None, :]
    qoq = quant.quant_kv_asym_per_head(k_post / f_cal, 4.0, dh) * f_cal
    norm = jnp.mean(k_post**2, axis=(1, 2, 3)) + 1e-12
    return jnp.stack([err(p3) / norm, err(oaken) / norm, err(qoq) / norm])
