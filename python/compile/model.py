"""L2: the GQA decoder transformer (paper Fig. 1) in JAX.

One generic ``forward`` implements both the FP baseline and every
quantized variant; the quantization *scheme* is static (python-side
branching at trace time) while calibration data / bit-widths that the
experiments sweep are traced inputs, so a single HLO artifact covers a
whole sweep (e.g. Fig. 3b's per-operand bit-width sweep).

The model mirrors Llama-style architecture at tiny scale: RMSNorm,
rotary position embeddings, grouped-query attention (G = n_heads/n_kv),
SwiGLU MLP.  Defaults give ~1M parameters so that build-time training on
the synthetic corpus takes minutes on CPU while still producing
meaningful perplexity orderings between numerical formats.

Weights are always *runtime inputs* of the lowered graphs (fed by the
Rust runtime from ``weights.bin``); weight quantization (BitMoD / INT4 /
AWQ / rotation folding) is applied host-side -- in python for tests and
golden vectors, in Rust (bit-exactly) on the serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import quant


@dataclasses.dataclass(frozen=True)
class Config:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 8
    n_kv: int = 2
    d_head: int = 16
    d_ff: int = 256
    max_ctx: int = 160
    rope_base: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def gqa_group(self):
        return self.n_heads // self.n_kv


TINY = Config()

# Linear-layer names, in forward order, per layer.
LINEAR_NAMES = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")


def param_shapes(cfg: Config) -> Dict[str, tuple]:
    """Name -> shape for every parameter.  Iteration order (sorted name)
    defines the flat input ordering of all lowered graphs and of
    weights.bin -- the Rust loader follows the same order via the TSV
    manifest."""
    shapes = {
        "tok_emb": (cfg.vocab, cfg.d_model),
        "final_norm": (cfg.d_model,),
        "lm_head": (cfg.d_model, cfg.vocab),
    }
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes[p + "norm_attn"] = (cfg.d_model,)
        shapes[p + "norm_mlp"] = (cfg.d_model,)
        shapes[p + "wq"] = (cfg.d_model, cfg.n_heads * cfg.d_head)
        shapes[p + "wk"] = (cfg.d_model, cfg.n_kv * cfg.d_head)
        shapes[p + "wv"] = (cfg.d_model, cfg.n_kv * cfg.d_head)
        shapes[p + "wo"] = (cfg.n_heads * cfg.d_head, cfg.d_model)
        shapes[p + "wgate"] = (cfg.d_model, cfg.d_ff)
        shapes[p + "wup"] = (cfg.d_model, cfg.d_ff)
        shapes[p + "wdown"] = (cfg.d_ff, cfg.d_model)
    return dict(sorted(shapes.items()))


OUTLIER_EMB_CHANNELS = (11, 47, 83, 120)
OUTLIER_KEY_CHANNELS = (3, 9)  # per kv head


def init_params(cfg: Config, seed=0, outliers=True) -> Dict[str, jnp.ndarray]:
    """Initialize parameters.

    `outliers=True` injects per-channel scale diversity: a few residual
    channels (tok_emb columns) and key-projection output channels start
    ~6x larger.  Billion-parameter LLMs *develop* exactly this fixed
    outlier-channel structure in activations and key caches (paper
    Fig. 5; also [12], [88]); at 1M-parameter/600-step scale it does not
    emerge on its own, so we seed it at init -- training preserves the
    relative channel scales.  This substitution (documented in
    DESIGN.md) is what makes the outlier-driven format comparisons
    (smoothing, FP8-vs-INT8 activations) meaningful on the tiny model.
    """
    r = np.random.default_rng(seed)
    params = {}
    for name, shp in param_shapes(cfg).items():
        if name.endswith(("norm_attn", "norm_mlp", "final_norm")):
            params[name] = jnp.ones(shp, jnp.float32)
        else:
            fan_in = shp[0]
            std = 1.0 / np.sqrt(fan_in)
            w = r.normal(0.0, std, size=shp).astype(np.float32)
            if outliers and name == "tok_emb":
                for c in OUTLIER_EMB_CHANNELS:
                    w[:, c] *= 16.0
            if outliers and name.endswith(".wk"):
                for h in range(cfg.n_kv):
                    for c in OUTLIER_KEY_CHANNELS:
                        w[:, h * cfg.d_head + c] *= 6.0
            params[name] = jnp.asarray(w)
    return params


# ----------------------------------------------------------------------
# Quantization scheme plumbing
# ----------------------------------------------------------------------

FP_SCHEME: Dict[str, Any] = dict(
    a_fmt="fp",        # "fp" | "int" (bits from aux) | "e4m3"
    a_smooth=False,    # divide activations by calibrated factors (aux)
    kv_mode="fp",      # "fp" | "int" (bits from aux) | "smooth" | "oaken"
    k_stage="post",    # quantize key "pre" or "post" RoPE
    p_fmt="fp",        # "fp" | "int8u" | "e4m3" | "s0e4m4" | "int" (aux)
    q_fmt="fp",        # "fp" | "e4m3"
    hadamard=False,    # QuaRot-style online rotation of linear inputs
)


def scheme(**kw) -> Dict[str, Any]:
    s = dict(FP_SCHEME)
    for k, v in kw.items():
        assert k in s, k
        s[k] = v
    return s


# Traced auxiliary inputs; every eval graph takes all of them so the I/O
# signature is scheme-independent.
def default_aux(cfg: Config):
    """Neutral aux values (everything disabled / identity)."""
    L, d, ff = cfg.n_layers, cfg.d_model, cfg.d_ff
    kvdim = cfg.n_kv * cfg.d_head
    return dict(
        a_bits=jnp.float32(16.0),
        kv_bits=jnp.float32(16.0),
        p_bits=jnp.float32(16.0),
        # SmoothQuant/QoQ calibrated per-channel activation factors, one
        # per linear-input site (ones = disabled).
        asm_attn=jnp.ones((L, d), jnp.float32),
        asm_o=jnp.ones((L, cfg.n_heads * cfg.d_head), jnp.float32),
        asm_mlp=jnp.ones((L, d), jnp.float32),
        asm_down=jnp.ones((L, ff), jnp.float32),
        # Oaken offline outlier mask over key/value channels (per layer).
        oaken_mask_k=jnp.zeros((L, kvdim), jnp.float32),
        oaken_mask_v=jnp.zeros((L, kvdim), jnp.float32),
        # QoQ-style *calibrated* per-channel key smoothing factors
        # (kv_mode="smooth_calib"); contrast with the dynamic factors of
        # kv_mode="smooth" that P3-LLM computes from the live prefill.
        qoq_ksm=jnp.ones((L, kvdim), jnp.float32),
    )


AUX_ORDER = (
    "a_bits", "kv_bits", "p_bits",
    "asm_attn", "asm_o", "asm_mlp", "asm_down",
    "oaken_mask_k", "oaken_mask_v", "qoq_ksm",
)


def _quant_act(x, sm_vec, s, aux):
    """Quantize a linear-layer input activation per the scheme."""
    if s["a_smooth"]:
        x = x / sm_vec
    if s["a_fmt"] == "int":
        x = quant.quant_int_asym(x, aux["a_bits"], axis=-1)  # per token
    elif s["a_fmt"] == "e4m3":
        x = quant.quant_fp8_e4m3(x)
    return x


def _linear(x, w, sm_vec, s, aux, h=None):
    """Quantized linear: activation-quant then matmul.  `h` is the
    Hadamard matrix when QuaRot rotation is enabled for this site (the
    matching inverse rotation is folded into `w` host-side)."""
    if s["hadamard"] and h is not None:
        x = quant.hadamard_rotate(x, h)
    x = _quant_act(x, sm_vec, s, aux)
    return x @ w


def _rope(x, pos, cfg: Config):
    """Rotary embedding.  x: [..., T, n, d_head]; pos: [..., T]."""
    dh = cfg.d_head
    half = dh // 2
    freqs = cfg.rope_base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _rmsnorm(x, g, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _quant_kv(x, s, aux, cfg, mask8=None, smooth=False, token_mask=None,
              calib_f=None):
    """Quantize a KV tensor [..., T, kvdim] per the scheme."""
    dh = cfg.d_head
    if s["kv_mode"] == "fp":
        return x
    if s["kv_mode"] == "oaken":
        return quant.quant_kv_oaken(x, mask8, dh)
    if s["kv_mode"] == "smooth_calib" and smooth:
        f = calib_f  # offline-calibrated factors: the overfitting path
        return quant.quant_kv_asym_per_head(x / f, aux["kv_bits"], dh) * f
    if s["kv_mode"] == "smooth" and smooth:
        if token_mask is not None:
            masked = jnp.where(token_mask[..., :, None] > 0, jnp.abs(x), 0.0)
            f = jnp.maximum(jnp.max(masked, axis=-2, keepdims=True), 1e-6)
        else:
            f = quant.smoothing_factors(x)
        return quant.quant_kv_asym_per_head(x / f, aux["kv_bits"], dh) * f
    # "int" and the value-cache path of "smooth"/"oaken" fall through to
    # plain per-head asymmetric quantization.
    return quant.quant_kv_asym_per_head(x, aux["kv_bits"], dh)


def _quant_scores(p, s, aux):
    if s["p_fmt"] == "fp":
        return p
    if s["p_fmt"] == "int8u":
        return quant.quant_int8_unsigned(p)
    if s["p_fmt"] == "e4m3":
        return quant.quant_fp8_e4m3(p)
    if s["p_fmt"] == "s0e4m4":
        return quant.quant_fp8_s0e4m4(p)
    if s["p_fmt"] == "int":
        # unsigned int-b with fixed scale over [0, 1]
        levels = jnp.exp2(aux["p_bits"]) - 1.0
        q = jnp.clip(jnp.round(p * levels), 0.0, levels) / levels
        return jnp.where(aux["p_bits"] >= 16.0, p, q)
    raise ValueError(s["p_fmt"])


# ----------------------------------------------------------------------
# Teacher-forced forward (prefill-shaped): the accuracy workhorse
# ----------------------------------------------------------------------


def forward(params, tokens, cfg: Config, s=FP_SCHEME, aux=None, h=None):
    """tokens: [B, T] int32 -> logits [B, T, vocab].

    Causal full-sequence forward.  The full sequence plays the role of
    the prefill context: smoothing factors are per-channel abs-maxima
    over the sequence, exactly as the serving path computes them at
    prefill time (Section IV-A).
    """
    if aux is None:
        aux = default_aux(cfg)
    if s["hadamard"] and h is None:
        h = quant.hadamard_matrix(cfg.d_model)
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = params["tok_emb"][tokens]  # [B, T, d]
    causal = jnp.tril(jnp.ones((T, T), jnp.float32))

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xa = _rmsnorm(x, params[p + "norm_attn"], cfg.norm_eps)
        q = _linear(xa, params[p + "wq"], aux["asm_attn"][i], s, aux, h)
        k = _linear(xa, params[p + "wk"], aux["asm_attn"][i], s, aux, h)
        v = _linear(xa, params[p + "wv"], aux["asm_attn"][i], s, aux, h)

        if s["kv_mode"] != "fp" and s["k_stage"] == "pre":
            k = _quant_kv(k, s, aux, cfg, aux["oaken_mask_k"][i],
                          smooth=True, calib_f=aux["qoq_ksm"][i])
        qh = q.reshape(B, T, cfg.n_heads, cfg.d_head)
        kh = k.reshape(B, T, cfg.n_kv, cfg.d_head)
        qh = _rope(qh, pos, cfg)
        kh = _rope(kh, pos, cfg)
        if s["kv_mode"] != "fp" and s["k_stage"] == "post":
            kflat = kh.reshape(B, T, cfg.n_kv * cfg.d_head)
            kflat = _quant_kv(kflat, s, aux, cfg, aux["oaken_mask_k"][i],
                              smooth=True, calib_f=aux["qoq_ksm"][i])
            kh = kflat.reshape(B, T, cfg.n_kv, cfg.d_head)
        if s["kv_mode"] != "fp":
            v = _quant_kv(v, s, aux, cfg, aux["oaken_mask_v"][i])
        vh = v.reshape(B, T, cfg.n_kv, cfg.d_head)

        if s["q_fmt"] == "e4m3":
            qh = quant.quant_fp8_e4m3(qh)

        # GQA: repeat kv heads G times.
        g = cfg.gqa_group
        kg = jnp.repeat(kh, g, axis=2)  # [B, T, nh, dh]
        vg = jnp.repeat(vh, g, axis=2)
        att = jnp.einsum("bqhd,bkhd->bhqk", qh, kg) / np.sqrt(cfg.d_head)
        att = jnp.where(causal[None, None] > 0, att, -1e30)
        pr = jax.nn.softmax(att, axis=-1)
        pr = _quant_scores(pr, s, aux)
        out = jnp.einsum("bhqk,bkhd->bqhd", pr, vg)
        out = out.reshape(B, T, cfg.n_heads * cfg.d_head)
        x = x + _linear(out, params[p + "wo"], aux["asm_o"][i], s, aux, None)

        xm = _rmsnorm(x, params[p + "norm_mlp"], cfg.norm_eps)
        gate = _linear(xm, params[p + "wgate"], aux["asm_mlp"][i], s, aux, h)
        up = _linear(xm, params[p + "wup"], aux["asm_mlp"][i], s, aux, h)
        act = jax.nn.silu(gate) * up
        x = x + _linear(act, params[p + "wdown"], aux["asm_down"][i], s, aux,
                        None)

    x = _rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"]


def nll(params, block, cfg: Config, s=FP_SCHEME, aux=None):
    """block: [B, T+1] -> (sum NLL, token count, top-1 correct count).

    The correct count feeds the Table V task-accuracy substitute
    (held-out next-token accuracy; see DESIGN.md substitutions).
    """
    inputs, targets = block[:, :-1], block[:, 1:]
    logits = forward(params, inputs, cfg, s, aux)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return -jnp.sum(picked), jnp.float32(targets.size), correct


def loss_fn(params, block, cfg: Config):
    total, count, _ = nll(params, block, cfg)
    return total / count


# ----------------------------------------------------------------------
# Serving graphs: prefill + single-token decode with external KV cache
# ----------------------------------------------------------------------


def prefill(params, tokens, true_len, cfg: Config, quantized=False):
    """tokens: [1, T] padded prompt, true_len: [] int32.

    Returns (logits_last [1, vocab], k_cache [L, 1, T, kvdim],
    v_cache [L, 1, T, kvdim], smooth_f [L, kvdim]).

    The caches hold fp values already snapped to the INT4 grid when
    `quantized`; the Rust KV-cache manager packs them bit-exactly
    (mirroring Fig. 6's split where quantization of KV entries happens
    outside the PIM banks).  Smoothing factors are per-channel
    abs-maxima over the valid prompt region (Eq. 2), returned for reuse
    during decode.
    """
    s = FP_SCHEME
    aux = default_aux(cfg)
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    valid = (jnp.arange(T, dtype=jnp.int32) < true_len)[None]  # [1, T]
    x = params["tok_emb"][tokens]
    causal = jnp.tril(jnp.ones((T, T), jnp.float32)) * valid[0][None, :]
    ks, vs, sfs = [], [], []

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xa = _rmsnorm(x, params[p + "norm_attn"], cfg.norm_eps)
        q = _linear(xa, params[p + "wq"], aux["asm_attn"][i], s, aux)
        k = _linear(xa, params[p + "wk"], aux["asm_attn"][i], s, aux)
        v = _linear(xa, params[p + "wv"], aux["asm_attn"][i], s, aux)
        qh = _rope(q.reshape(B, T, cfg.n_heads, cfg.d_head), pos, cfg)
        kh = _rope(k.reshape(B, T, cfg.n_kv, cfg.d_head), pos, cfg)
        kflat = kh.reshape(B, T, cfg.n_kv * cfg.d_head)
        # smoothing factors over the valid prompt region
        kabs = jnp.where(valid[..., None] > 0, jnp.abs(kflat), 0.0)
        sf = jnp.maximum(jnp.max(kabs, axis=(0, 1)), 1e-6)  # [kvdim]
        sfs.append(sf)
        if quantized:
            kq = quant.quant_kv_asym_per_head(
                kflat / sf, 4.0, cfg.d_head) * sf
            vq = quant.quant_kv_asym_per_head(v, 4.0, cfg.d_head)
        else:
            kq, vq = kflat, v
        ks.append(kq)
        vs.append(vq)
        kh2 = kq.reshape(B, T, cfg.n_kv, cfg.d_head)
        vh = vq.reshape(B, T, cfg.n_kv, cfg.d_head)
        g = cfg.gqa_group
        att = jnp.einsum("bqhd,bkhd->bhqk", qh, jnp.repeat(kh2, g, 2))
        att = att / np.sqrt(cfg.d_head)
        att = jnp.where(causal[None, None] > 0, att, -1e30)
        pr = jax.nn.softmax(att, axis=-1)
        if quantized:
            pr = quant.quant_fp8_s0e4m4(pr)
        out = jnp.einsum("bhqk,bkhd->bqhd", pr, jnp.repeat(vh, g, 2))
        out = out.reshape(B, T, cfg.n_heads * cfg.d_head)
        x = x + _linear(out, params[p + "wo"], aux["asm_o"][i], s, aux)
        xm = _rmsnorm(x, params[p + "norm_mlp"], cfg.norm_eps)
        gate = _linear(xm, params[p + "wgate"], aux["asm_mlp"][i], s, aux)
        up = _linear(xm, params[p + "wup"], aux["asm_mlp"][i], s, aux)
        act = jax.nn.silu(gate) * up
        x = x + _linear(act, params[p + "wdown"], aux["asm_down"][i], s, aux)

    x = _rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, (true_len - 1).reshape(1, 1, 1).astype(jnp.int32), axis=1
    )  # [1, 1, d]
    logits = (last @ params["lm_head"])[:, 0]
    return (
        logits,
        jnp.stack(ks),   # [L, 1, T, kvdim]
        jnp.stack(vs),
        jnp.stack(sfs),  # [L, kvdim]
    )


def decode_step(params, tokens, pos, k_cache, v_cache, smooth_f,
                cfg: Config, quantized=False, kernels=None):
    """One decode iteration over a batch.

    tokens: [B] int32, pos: [B] int32 (index where the new token goes),
    k_cache/v_cache: [L, B, ctx, kvdim] fp (dequantized by the Rust KV
    manager), smooth_f: [L, B, kvdim].

    Returns (logits [B, vocab], new_k [L, B, kvdim], new_v [L, B, kvdim]).
    new_k/new_v are already snapped to the INT4 grid when `quantized`, so
    the Rust manager's packing round-trips bit-exactly.

    When `kernels` is set (a module exposing w4a8_matmul /
    decode_attention), linear layers and attention run through the L1
    Pallas kernels with packed BitMoD weights -- that variant expects
    `params[name]` for linear weights to be (codes, scales, specials)
    tuples and is lowered separately by aot.py.
    """
    B = tokens.shape[0]
    L, _, ctx, kvdim = k_cache.shape
    x = params["tok_emb"][tokens]  # [B, d]
    slot = jax.nn.one_hot(pos, ctx, dtype=jnp.float32)  # [B, ctx]
    # cache slot j is attendable iff j < pos (history) or j == pos (self)
    attend = jnp.arange(ctx, dtype=jnp.int32)[None] <= pos[:, None]

    def linear(h, name, i):
        wname = f"layer{i}.{name}" if i >= 0 else name
        hq = quant.quant_fp8_e4m3(h) if quantized else h
        if kernels is not None and (name in LINEAR_NAMES or
                                    name == "lm_head"):
            codes, scales, specials = params[wname]
            return kernels.w4a8_matmul(hq, codes, scales, specials)
        return hq @ params[wname]

    new_ks, new_vs = [], []
    for i in range(L):
        p = f"layer{i}."
        xa = _rmsnorm(x, params[p + "norm_attn"], cfg.norm_eps)
        q = linear(xa, "wq", i)
        k = linear(xa, "wk", i)
        v = linear(xa, "wv", i)
        qh = _rope(q.reshape(B, 1, cfg.n_heads, cfg.d_head),
                   pos[:, None], cfg)[:, 0]  # [B, nh, dh]
        kh = _rope(k.reshape(B, 1, cfg.n_kv, cfg.d_head),
                   pos[:, None], cfg)[:, 0]
        kflat = kh.reshape(B, kvdim)
        if quantized:
            sf = smooth_f[i]
            kflat = quant.quant_kv_asym_per_head(
                kflat / sf, 4.0, cfg.d_head) * sf
            v = quant.quant_kv_asym_per_head(v, 4.0, cfg.d_head)
        new_ks.append(kflat)
        new_vs.append(v)
        # insert into the (fp view of the) cache at `pos`
        kc = k_cache[i] + slot[:, :, None] * kflat[:, None, :]
        vc = v_cache[i] + slot[:, :, None] * v[:, None, :]
        khc = kc.reshape(B, ctx, cfg.n_kv, cfg.d_head)
        vhc = vc.reshape(B, ctx, cfg.n_kv, cfg.d_head)
        if quantized:
            qh = quant.quant_fp8_e4m3(qh)
        if kernels is not None:
            out = kernels.decode_attention(
                qh, khc, vhc, attend, quantized=quantized)
        else:
            g = cfg.gqa_group
            kg = jnp.repeat(khc, g, axis=2)
            vg = jnp.repeat(vhc, g, axis=2)
            att = jnp.einsum("bhd,bkhd->bhk", qh, kg) / np.sqrt(cfg.d_head)
            att = jnp.where(attend[:, None, :], att, -1e30)
            pr = jax.nn.softmax(att, axis=-1)
            if quantized:
                pr = quant.quant_fp8_s0e4m4(pr)
            out = jnp.einsum("bhk,bkhd->bhd", pr, vg)
        out = out.reshape(B, cfg.n_heads * cfg.d_head)
        x = x + linear(out, "wo", i)
        xm = _rmsnorm(x, params[p + "norm_mlp"], cfg.norm_eps)
        act = jax.nn.silu(linear(xm, "wgate", i)) * linear(xm, "wup", i)
        x = x + linear(act, "wdown", i)

    x = _rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(x, "lm_head", -1)
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)
