"""AOT pipeline: train the tiny model, build every HLO artifact, data
file, calibration blob and golden vector the Rust side consumes.

Run as ``python -m compile.aot --out ../artifacts``.  Python's job ends
here -- the `p3llm` Rust binary is self-contained afterwards.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the HLO text
parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import analysis, baselines, corpus, model, quant, train
from .kernels import attention as k_attn
from .kernels import quantize as k_quant
from .kernels import w4a8_gemv as k_gemv

DECODE_BATCHES = (1, 2, 4, 8)
KERNEL_DECODE_BATCHES = (1, 4)
PREFILL_T = 64
EVAL_B, EVAL_T = 8, 128
BITMOD_GROUP = 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # "{...}", which silently corrupts any graph with an embedded
    # constant (e.g. the QuaRot Hadamard matrix) when the text is
    # re-parsed by the Rust loader.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the xla_extension 0.5.1 parser predates jax's source_end_line
    # metadata fields -- strip metadata entirely
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def _dtype_tag(x):
    return {np.dtype("float32"): "f32", np.dtype("int32"): "i32",
            np.dtype("uint8"): "u8"}[np.dtype(x)]


class Registry:
    """Collects artifacts + a TSV manifest the Rust loader parses."""

    def __init__(self, out_dir):
        self.out = out_dir
        self.rows = []
        os.makedirs(out_dir, exist_ok=True)

    def graph(self, name, fn, arg_specs):
        """arg_specs: list of (arg_name, ShapeDtypeStruct)."""
        t0 = time.time()
        specs = [s for _, s in arg_specs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out, path), "w") as f:
            f.write(text)
        sig = ";".join(
            f"{n}:{'x'.join(map(str, s.shape))}:{_dtype_tag(s.dtype)}"
            for n, s in arg_specs
        )
        self.rows.append(("graph", name, path, sig))
        print(f"  graph {name:28s} {len(text)/1e6:6.2f} MB  "
              f"{time.time()-t0:5.1f}s", flush=True)

    def data(self, name, path, note=""):
        self.rows.append(("data", name, path, note))

    def write(self):
        with open(os.path.join(self.out, "manifest.tsv"), "w") as f:
            f.write("kind\tname\tfile\tinfo\n")
            for r in self.rows:
                f.write("\t".join(r) + "\n")


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _keep(*tensors):
    """Zero-valued keep-alive term over float tensors.

    XLA's compiler prunes parameters a graph does not use, which would
    desynchronize the Rust caller (it feeds every manifest arg).  Adding
    0 * sum(args) to one output keeps the parameter list stable across
    every scheme variant without changing numerics.
    """
    acc = jnp.float32(0.0)
    for t in tensors:
        if jnp.issubdtype(jnp.asarray(t).dtype, jnp.floating):
            acc = acc + jnp.sum(t)
    return acc * 0.0


def param_specs(cfg):
    return [(n, spec(s)) for n, s in sorted(model.param_shapes(cfg).items())]


def aux_specs(cfg):
    ref = model.default_aux(cfg)
    return [(f"aux.{n}", spec(np.asarray(ref[n]).shape)) for n in
            model.AUX_ORDER]


def reassemble(cfg, args):
    """Inverse of the flat (params..., block, aux...) calling convention."""
    names = sorted(model.param_shapes(cfg))
    params = dict(zip(names, args[: len(names)]))
    rest = args[len(names):]
    block = rest[0]
    aux = dict(zip(model.AUX_ORDER, rest[1:]))
    return params, block, aux


# ----------------------------------------------------------------------
# Eval graphs (accuracy experiments)
# ----------------------------------------------------------------------

EVAL_SCHEMES = {
    # name -> scheme kwargs (static; bit-widths et al. come from aux)
    "fp": dict(),
    "int": dict(a_fmt="int", kv_mode="int", p_fmt="int"),
    "int_pre": dict(a_fmt="int", kv_mode="int", p_fmt="int", k_stage="pre"),
    "int_had": dict(a_fmt="int", kv_mode="int", p_fmt="int", hadamard=True),
    "int_sq": dict(a_fmt="int", a_smooth=True, kv_mode="smooth_calib",
                   p_fmt="int"),
    "smooth": dict(kv_mode="smooth"),
    "smooth_pre": dict(kv_mode="smooth", k_stage="pre"),
    "oaken": dict(kv_mode="oaken"),
    "p3_pe4m3": dict(kv_mode="smooth", p_fmt="e4m3"),
    "p3_ps0e4m4": dict(kv_mode="smooth", p_fmt="s0e4m4"),
    "p_int8u": dict(kv_mode="smooth", p_fmt="int8u"),
    "p3_ainte": dict(a_fmt="int", kv_mode="smooth", p_fmt="s0e4m4"),
    "p3_full": dict(a_fmt="e4m3", kv_mode="smooth", p_fmt="s0e4m4"),
    "p3_full_q8": dict(a_fmt="e4m3", kv_mode="smooth", p_fmt="s0e4m4",
                       q_fmt="e4m3"),
    "p3_full_pre": dict(a_fmt="e4m3", kv_mode="smooth", p_fmt="s0e4m4",
                        k_stage="pre"),
    "a_e4m3": dict(a_fmt="e4m3"),
    "a_int": dict(a_fmt="int"),
    "a_int_sq": dict(a_fmt="int", a_smooth=True),
}


def build_eval_graphs(reg, cfg):
    pspecs = param_specs(cfg)
    bspec = [("block", spec((EVAL_B, EVAL_T + 1), jnp.int32))]
    aspecs = aux_specs(cfg)
    for tag, kw in EVAL_SCHEMES.items():
        s = model.scheme(**kw)

        def fn(*args, _s=s):
            params, block, aux = reassemble(cfg, args)
            total, count, correct = model.nll(params, block, cfg, _s, aux)
            return total + _keep(*args), count, correct

        reg.graph(f"eval_{tag}", fn, pspecs + bspec + aspecs)


# ----------------------------------------------------------------------
# Serving graphs
# ----------------------------------------------------------------------


def build_serving_graphs(reg, cfg):
    pspecs = param_specs(cfg)
    L, kvdim, ctx = cfg.n_layers, cfg.n_kv * cfg.d_head, cfg.max_ctx

    for quantized, tag in ((False, "fp"), (True, "q")):
        def pf(*args, _q=quantized):
            params = dict(zip(sorted(model.param_shapes(cfg)), args[:-2]))
            tokens, true_len = args[-2], args[-1]
            lg, kc, vc, sf = model.prefill(params, tokens, true_len, cfg,
                                           quantized=_q)
            return lg + _keep(*args), kc, vc, sf

        reg.graph(
            f"prefill_{tag}", pf,
            pspecs + [("tokens", spec((1, PREFILL_T), jnp.int32)),
                      ("true_len", spec((), jnp.int32))],
        )

    for quantized, tag in ((False, "fp"), (True, "q")):
        for b in DECODE_BATCHES:
            def df(*args, _q=quantized, _b=b):
                names = sorted(model.param_shapes(cfg))
                params = dict(zip(names, args[: len(names)]))
                tokens, pos, kc, vc, sf = args[len(names):]
                lg, nk, nv = model.decode_step(params, tokens, pos, kc, vc,
                                               sf, cfg, quantized=_q)
                return lg + _keep(*args), nk, nv

            reg.graph(
                f"decode_{tag}_b{b}", df,
                pspecs + [
                    ("tokens", spec((b,), jnp.int32)),
                    ("pos", spec((b,), jnp.int32)),
                    ("k_cache", spec((L, b, ctx, kvdim))),
                    ("v_cache", spec((L, b, ctx, kvdim))),
                    ("smooth_f", spec((L, b, kvdim))),
                ],
            )


class _KernelShim:
    """Adapter giving model.decode_step the L1 Pallas kernels."""

    @staticmethod
    def w4a8_matmul(x, codes, scales, specials):
        return k_gemv.w4a8_matmul(x, codes, scales, specials,
                                  group=BITMOD_GROUP)

    @staticmethod
    def decode_attention(q, khc, vhc, attend, quantized=True):
        return k_attn.decode_attention(q, khc, vhc, attend,
                                       quantized=quantized)


def kernel_param_specs(cfg):
    """Linear weights expand to (codes, scales, specials) triples."""
    out = []
    for n, shp in sorted(model.param_shapes(cfg).items()):
        if n.endswith(model.LINEAR_NAMES) or n == "lm_head":
            k, nn = shp
            g = k // BITMOD_GROUP
            out.append((f"{n}.codes", spec((k, nn), jnp.uint8)))
            out.append((f"{n}.scales", spec((g, nn))))
            out.append((f"{n}.specials", spec((g, nn), jnp.uint8)))
        else:
            out.append((n, spec(shp)))
    return out


def build_kernel_decode_graphs(reg, cfg):
    L, kvdim, ctx = cfg.n_layers, cfg.n_kv * cfg.d_head, cfg.max_ctx
    kspecs = kernel_param_specs(cfg)

    for b in KERNEL_DECODE_BATCHES:
        def df(*args, _b=b):
            params = {}
            i = 0
            for n, shp in sorted(model.param_shapes(cfg).items()):
                if n.endswith(model.LINEAR_NAMES) or n == "lm_head":
                    params[n] = (args[i], args[i + 1], args[i + 2])
                    i += 3
                else:
                    params[n] = args[i]
                    i += 1
            tokens, pos, kc, vc, sf = args[i:]
            lg, nk, nv = model.decode_step(params, tokens, pos, kc, vc, sf,
                                           cfg, quantized=True,
                                           kernels=_KernelShim)
            return lg + _keep(*args), nk, nv

        reg.graph(
            f"decode_qk_b{b}", df,
            kspecs + [
                ("tokens", spec((b,), jnp.int32)),
                ("pos", spec((b,), jnp.int32)),
                ("k_cache", spec((L, b, ctx, kvdim))),
                ("v_cache", spec((L, b, ctx, kvdim))),
                ("smooth_f", spec((L, b, kvdim))),
            ],
        )


def build_kernel_microbench_graphs(reg, cfg):
    """Standalone kernel artifacts for Rust microbenches + cross-checks."""
    b, k, n = 8, 128, 256
    g = k // BITMOD_GROUP
    reg.graph(
        "kernel_w4a8_gemv",
        lambda x, c, s, sp: k_gemv.w4a8_matmul(x, c, s, sp,
                                               group=BITMOD_GROUP),
        [("x", spec((b, k))), ("codes", spec((k, n), jnp.uint8)),
         ("scales", spec((g, n))), ("specials", spec((g, n), jnp.uint8))],
    )
    ctx = cfg.max_ctx
    reg.graph(
        "kernel_attn",
        lambda q, kc, vc, m: k_attn.decode_attention(
            q, kc, vc, m > 0, quantized=True),
        [("q", spec((4, cfg.n_heads, cfg.d_head))),
         ("k_cache", spec((4, ctx, cfg.n_kv, cfg.d_head))),
         ("v_cache", spec((4, ctx, cfg.n_kv, cfg.d_head))),
         ("mask", spec((4, ctx), jnp.int32))],
    )
    reg.graph(
        "kernel_e4m3",
        lambda x: k_quant.fp8_e4m3(x),
        [("x", spec((64, 128)))],
    )


def build_analysis_graphs(reg, cfg):
    pspecs = param_specs(cfg)
    bspec = [("block", spec((EVAL_B, EVAL_T + 1), jnp.int32))]
    aspecs = aux_specs(cfg)

    def kdist(*args):
        names = sorted(model.param_shapes(cfg))
        params = dict(zip(names, args[:-1]))
        pre, post, sm, mean = analysis.kdist_report(params, args[-1], cfg)
        return pre + _keep(*args), post, sm, mean

    reg.graph("kdist", kdist, pspecs + bspec)

    def kverr(*args):
        params, block, aux = reassemble(cfg, args)
        return (analysis.kv_error_report(params, block, aux, cfg)
                + _keep(*args),)

    reg.graph("kverr", kverr, pspecs + bspec + aspecs)


# ----------------------------------------------------------------------
# Data artifacts
# ----------------------------------------------------------------------


def write_corpora(reg, out):
    for name in ("wiki_syn", "c4_syn", "pile_syn"):
        tr, ev = corpus.make_splits(name)
        short = name.removesuffix("_syn")
        for split, toks in (("train", tr), ("eval", ev)):
            path = f"tokens_{short}_{split}.bin"
            toks.astype(np.uint8).tofile(os.path.join(out, path))
            reg.data(f"tokens_{short}_{split}", path,
                     f"u8 tokens n={toks.size}")


def aux_to_blob(cfg, aux_overrides):
    """Flatten an aux dict (AUX_ORDER) to one little-endian f32 blob."""
    ref = model.default_aux(cfg)
    parts = []
    for n in model.AUX_ORDER:
        v = np.asarray(aux_overrides.get(n, ref[n]), np.float32)
        assert v.shape == np.asarray(ref[n]).shape, (n, v.shape)
        parts.append(np.atleast_1d(v).reshape(-1))
    return np.concatenate(parts)


def write_aux_manifest(out, cfg):
    ref = model.default_aux(cfg)
    off = 0
    with open(os.path.join(out, "aux_layout.tsv"), "w") as f:
        f.write("name\tshape\toffset_f32\tcount\n")
        for n in model.AUX_ORDER:
            a = np.atleast_1d(np.asarray(ref[n]))
            f.write(f"{n}\t{'x'.join(map(str, a.shape))}\t{off}\t{a.size}\n")
            off += a.size


def build_weight_variants(reg, out, cfg, params, stats_pile, stats_wiki):
    """Write every weight file + aux blob + the evalcfg registry."""
    variants = {}  # name -> (params, aux_overrides)
    variants["fp"] = (params, {})
    variants["w4"] = (baselines.weights_int4(params), {})
    variants["bitmod"] = (baselines.weights_bitmod(params), {})
    qoq_aux, qoq_w = baselines.build_qoq(params, stats_pile, cfg)
    variants["qoq_pile"] = (qoq_w, qoq_aux)
    sq_aux, sq_w = baselines.build_smoothquant(params, stats_wiki, cfg)
    variants["sq_wiki"] = (sq_w, sq_aux)
    # SmoothQuant smoothing with fp weights (Table III W16 row)
    sm_aux, sm_w = baselines.smooth_sites(stats_wiki, params, cfg)
    variants["smoothed_fp"] = (sm_w, sm_aux)
    sm_bm = baselines.weights_bitmod(sm_w)
    variants["smoothed_bitmod"] = (sm_bm, sm_aux)
    variants["quarot"] = (baselines.weights_quarot(params, cfg), {})
    variants["awq_wiki"] = (baselines.weights_awq(params, stats_wiki, cfg),
                            {})
    oaken_wiki = baselines.build_oaken_masks(stats_pile, cfg)
    variants["oaken_pile"] = (params, oaken_wiki)

    # layout manifest (identical for every variant)
    train.save_weights(params, os.path.join(out, "weights_fp.bin"),
                       os.path.join(out, "weights.tsv"))
    reg.data("weights_layout", "weights.tsv", "name/shape/offset/count")

    for name, (p, auxo) in variants.items():
        wpath = f"weights_{name}.bin"
        train.save_weights(p, os.path.join(out, wpath))
        reg.data(f"weights_{name}", wpath, "f32 flat, sorted-name order")
        apath = f"aux_{name}.bin"
        aux_to_blob(cfg, auxo).tofile(os.path.join(out, apath))
        reg.data(f"aux_{name}", apath, "f32 flat, aux_layout.tsv order")

    # packed BitMoD weights for the kernel decode graphs
    packed = {}
    for n, w in params.items():
        if n.endswith(model.LINEAR_NAMES) or n == "lm_head":
            codes, scales, specials = quant.quant_bitmod_encode(
                np.asarray(w).T, BITMOD_GROUP)
            k, nn = np.asarray(w).shape
            g = k // BITMOD_GROUP
            packed[f"{n}.codes"] = codes.T.astype(np.uint8)  # [K, N]
            packed[f"{n}.scales"] = (
                scales.reshape(nn, g).T.astype(np.float32))
            packed[f"{n}.specials"] = (
                specials.reshape(nn, g).T.astype(np.uint8))
    ppath = os.path.join(out, "weights_packed.bin")
    with open(ppath, "wb") as f, open(
            os.path.join(out, "weights_packed.tsv"), "w") as m:
        m.write("name\tshape\tdtype\toffset_bytes\tnbytes\n")
        off = 0
        for n in sorted(packed):
            a = packed[n]
            f.write(a.tobytes())
            m.write(f"{n}\t{'x'.join(map(str, a.shape))}\t"
                    f"{_dtype_tag(a.dtype)}\t{off}\t{a.nbytes}\n")
            off += a.nbytes
    reg.data("weights_packed", "weights_packed.bin",
             "BitMoD codes/scales/specials, see weights_packed.tsv")


# ----------------------------------------------------------------------
# Golden vectors for Rust <-> Python bit-exactness
# ----------------------------------------------------------------------


def write_goldens(out, seed=7):
    r = np.random.default_rng(seed)
    lines = []

    def csv(a):
        return ",".join(f"{float(v):.9g}" for v in np.asarray(a).reshape(-1))

    x = r.normal(0, 2.0, size=64).astype(np.float32)
    lines.append(("e4m3", csv(x), csv(quant.quant_fp8_e4m3(jnp.asarray(x)))))
    p = r.random(64).astype(np.float32)
    lines.append(("s0e4m4", csv(p),
                  csv(quant.quant_fp8_s0e4m4(jnp.asarray(p)))))
    for i in range(4):
        g = r.normal(0, 1 + i, size=16).astype(np.float32)
        lines.append((f"int4asym", csv(g),
                      csv(quant.quant_int_asym(jnp.asarray(g), 4.0))))
    for i in range(3):
        w = r.normal(0, 0.5, size=128).astype(np.float32)
        codes, scales, specials = quant.quant_bitmod_encode(w, 128)
        deq = quant.bitmod_decode(codes, scales, specials, 128)
        lines.append(("bitmod", csv(w),
                      csv(codes.astype(np.float32)) + "|" +
                      f"{float(scales[0]):.9g}|{int(specials[0])}|" +
                      csv(deq)))
    k = r.normal(0, 1, size=(8, 32)).astype(np.float32)
    k[:, 3] *= 8.0  # an outlier channel
    f = np.asarray(quant.smoothing_factors(jnp.asarray(k)))
    lines.append(("smooth", csv(k), csv(f)))
    with open(os.path.join(out, "golden_quant.tsv"), "w") as fh:
        fh.write("kind\tinput\toutput\n")
        for kind, i, o in lines:
            fh.write(f"{kind}\t{i}\t{o}\n")


def write_evalcfg(out):
    """Experiment-variant registry: which graph runs with which weight
    file / aux blob.  The Rust bench harness reads this."""
    rows = [
        # name, graph, weights, aux, scalar overrides, note
        ("fp16", "eval_fp", "fp", "fp", "", "FP16 baseline"),
        ("p3_kv4", "eval_smooth", "fp", "fp", "kv_bits=4",
         "P3 KV4-only (Table IV)"),
        ("oaken_kv4", "eval_oaken", "fp", "oaken_pile", "",
         "Oaken KV4-only, calibrated on pile_syn"),
        ("naive_int", "eval_int", "w4", "fp",
         "kv_bits=4,a_bits=8,p_bits=8",
         "naive INT W4A8KV4P8 (Fig 3b highlight)"),
        ("quarot", "eval_int_had", "quarot", "fp", "kv_bits=4,a_bits=8",
         "QuaRot W4A8KV4 (rotated weights)"),
        ("qoq", "eval_int_sq", "qoq_pile", "qoq_pile",
         "kv_bits=4,a_bits=8", "QoQ W4A8KV4, calibrated on pile_syn"),
        ("p3_full", "eval_p3_full", "bitmod", "fp", "kv_bits=4",
         "P3-LLM W4A8KV4P8 (BitMoD weights)"),
        ("p3_full_q8", "eval_p3_full_q8", "bitmod", "fp", "kv_bits=4",
         "P3-LLM with FP8 query (Llama-3/Mistral mode)"),
        # Table VI ablation chain
        ("abl_int4kv_pre", "eval_int_pre", "fp", "fp", "kv_bits=4",
         "+preRoPE INT4 KV"),
        ("abl_int4kv_post", "eval_int", "fp", "fp", "kv_bits=4",
         "+postRoPE INT4 KV"),
        ("abl_smooth", "eval_smooth", "fp", "fp", "kv_bits=4",
         "+dynamic smoothing"),
        ("abl_w4", "eval_smooth", "w4", "fp", "kv_bits=4", "+INT4 weights"),
        ("abl_bitmod", "eval_smooth", "bitmod", "fp", "kv_bits=4",
         "+BitMoD weights"),
        ("abl_p_e4m3", "eval_p3_pe4m3", "bitmod", "fp", "kv_bits=4",
         "+E4M3 scores"),
        ("abl_p_s0e4m4", "eval_p3_ps0e4m4", "bitmod", "fp", "kv_bits=4",
         "+S0E4M4 scores"),
        ("abl_a_int8", "eval_p3_ainte", "bitmod", "fp",
         "kv_bits=4,a_bits=8", "+INT8 act"),
        ("abl_a_e4m3", "eval_p3_full", "bitmod", "fp", "kv_bits=4",
         "+E4M3 act"),
        # Table II (attention-score formats, KV4 smoothed)
        ("score_fp16", "eval_smooth", "fp", "fp", "kv_bits=4",
         "KV4 + FP16 scores"),
        ("score_int8", "eval_p_int8u", "fp", "fp", "kv_bits=4",
         "KV4 + unsigned INT8 scores"),
        ("score_e4m3", "eval_p3_pe4m3", "fp", "fp", "kv_bits=4",
         "KV4 + E4M3 scores"),
        ("score_s0e4m4", "eval_p3_ps0e4m4", "fp", "fp", "kv_bits=4",
         "KV4 + S0E4M4 scores"),
        # Table III (activation formats x weight precision)
        ("act_sq8_w16", "eval_a_int_sq", "smoothed_fp", "smoothed_fp",
         "a_bits=8", "INT8-SQ act, FP16 weights"),
        ("act_e4m3_w16", "eval_a_e4m3", "fp", "fp", "",
         "FP8-E4M3 act, FP16 weights"),
        ("act_sq8_w4", "eval_a_int_sq", "smoothed_bitmod", "smoothed_fp",
         "a_bits=8", "INT8-SQ act, 4-bit weights"),
        ("act_e4m3_w4", "eval_a_e4m3", "bitmod", "fp", "",
         "FP8-E4M3 act, 4-bit weights"),
        # SW-quant perf baselines also have accuracy counterparts
        ("smoothquant", "eval_a_int_sq", "sq_wiki", "sq_wiki", "a_bits=8",
         "SmoothQuant W8A8"),
        ("awq", "eval_fp", "awq_wiki", "fp", "", "AWQ W4A16"),
    ]
    with open(os.path.join(out, "evalcfg.tsv"), "w") as f:
        f.write("name\tgraph\tweights\taux\tscalars\tnote\n")
        for r in rows:
            f.write("\t".join(r) + "\n")


# ----------------------------------------------------------------------
# main
# ----------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--skip-graphs", action="store_true",
                    help="only data/weights/goldens (debug)")
    args = ap.parse_args()
    cfg = model.TINY
    out = args.out
    reg = Registry(out)

    print("[aot] corpora", flush=True)
    write_corpora(reg, out)

    wpath = os.path.join(out, "weights_fp.bin")
    if os.path.exists(wpath):
        print("[aot] reusing trained weights", flush=True)
        params = {k: np.asarray(v) for k, v in
                  train.load_weights(wpath, cfg).items()}
    else:
        print(f"[aot] training tiny model ({args.steps} steps)", flush=True)
        params, log = train.train(cfg, steps=args.steps)
        params = {k: np.asarray(v) for k, v in params.items()}
        with open(os.path.join(out, "train_log.tsv"), "w") as f:
            f.write("step\tloss\n")
            for s, l in log:
                f.write(f"{s}\t{l:.6f}\n")

    print("[aot] calibration", flush=True)
    calib_blocks = {}
    for name in ("pile_syn", "wiki_syn"):
        tr, _ = corpus.make_splits(name, n_train_sent=2000)
        calib_blocks[name] = list(
            corpus.batches(tr, EVAL_B, EVAL_T, n_batches=4))
    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    stats_pile = baselines.calibrate(jparams, calib_blocks["pile_syn"], cfg)
    stats_wiki = baselines.calibrate(jparams, calib_blocks["wiki_syn"], cfg)

    print("[aot] weight variants + aux blobs", flush=True)
    build_weight_variants(reg, out, cfg, params, stats_pile, stats_wiki)
    write_aux_manifest(out, cfg)
    write_evalcfg(out)

    print("[aot] golden vectors", flush=True)
    write_goldens(out)

    if not args.skip_graphs:
        print("[aot] eval graphs", flush=True)
        build_eval_graphs(reg, cfg)
        print("[aot] serving graphs", flush=True)
        build_serving_graphs(reg, cfg)
        print("[aot] kernel decode graphs", flush=True)
        build_kernel_decode_graphs(reg, cfg)
        print("[aot] kernel microbench graphs", flush=True)
        build_kernel_microbench_graphs(reg, cfg)
        print("[aot] analysis graphs", flush=True)
        build_analysis_graphs(reg, cfg)

    reg.write()
    with open(os.path.join(out, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print("[aot] done", flush=True)


if __name__ == "__main__":
    main()
