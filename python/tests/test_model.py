"""L2 model tests: shapes, serving-path consistency, scheme behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model, quant

CFG = model.Config(n_layers=2)  # shallow for speed


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def block():
    toks = corpus.corpus_tokens("wiki_syn", 80)
    return jnp.asarray(toks[: 4 * 33].reshape(4, 33))


def test_param_shapes_sorted_and_complete():
    shapes = model.param_shapes(CFG)
    assert list(shapes) == sorted(shapes)
    n = sum(int(np.prod(s)) for s in shapes.values())
    assert n > 100_000


def test_forward_shapes(params, block):
    logits = model.forward(params, block[:, :-1], CFG)
    assert logits.shape == (4, 32, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_nll_positive(params, block):
    total, count, correct = model.nll(params, block, CFG)
    assert float(total) > 0 and int(count) == 4 * 32


def test_quant_scheme_changes_but_stays_close(params, block):
    base, _, _ = model.nll(params, block, CFG)
    s = model.scheme(a_fmt="e4m3", kv_mode="smooth", p_fmt="s0e4m4")
    aux = model.default_aux(CFG)
    aux["kv_bits"] = jnp.float32(4.0)
    q, _, _ = model.nll(params, block, CFG, s, aux)
    assert float(q) != float(base)
    assert abs(float(q) - float(base)) / float(base) < 0.2


def test_bits16_aux_is_noop(params, block):
    base, _, _ = model.nll(params, block, CFG)
    s = model.scheme(a_fmt="int", kv_mode="int", p_fmt="int")
    same, _, _ = model.nll(params, block, CFG, s, model.default_aux(CFG))
    np.testing.assert_allclose(float(base), float(same), rtol=1e-6)


def test_quarot_rotation_identity_at_fp(params, block):
    """Rotating weights + activations with no quantization must be a
    numerical no-op (H is orthonormal)."""
    h = np.asarray(quant.hadamard_matrix(CFG.d_model))
    p2 = {}
    for k, v in params.items():
        if k.endswith(("wq", "wk", "wv", "wgate", "wup")):
            p2[k] = jnp.asarray(h.T @ np.asarray(v))
        else:
            p2[k] = v
    base, _, _ = model.nll(params, block, CFG)
    s = model.scheme(hadamard=True)
    rot, _, _ = model.nll(p2, block, CFG, s, model.default_aux(CFG))
    np.testing.assert_allclose(float(base), float(rot), rtol=1e-3)


def test_prefill_matches_forward(params, block):
    pt = block[:1, :17]
    lg, kc, vc, sf = model.prefill(params, pt[:, :16], jnp.int32(16), CFG)
    logits_f = model.forward(params, pt[:, :16], CFG)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_f[:, 15]), atol=1e-4)
    assert kc.shape == (CFG.n_layers, 1, 16, CFG.n_kv * CFG.d_head)
    assert (np.asarray(sf) > 0).all()


def test_prefill_respects_true_len(params, block):
    """Padding beyond true_len must not change outputs."""
    pt = np.asarray(block[:1, :16])
    padded = pt.copy()
    padded[:, 10:] = 7  # garbage pad
    lg1, *_ = model.prefill(params, jnp.asarray(pt), jnp.int32(10), CFG)
    lg2, *_ = model.prefill(params, jnp.asarray(padded), jnp.int32(10), CFG)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-5)


def _decode_setup(params, block, ctx=32, quantized=False):
    pt = block[:1, :17]
    lg, kc, vc, sf = model.prefill(params, pt[:, :16], jnp.int32(16), CFG,
                                   quantized=quantized)
    L = CFG.n_layers
    kvdim = CFG.n_kv * CFG.d_head
    kcache = np.zeros((L, 1, ctx, kvdim), np.float32)
    vcache = np.zeros((L, 1, ctx, kvdim), np.float32)
    kcache[:, :, :16] = np.asarray(kc)
    vcache[:, :, :16] = np.asarray(vc)
    sfb = jnp.asarray(np.asarray(sf)[:, None, :])
    return pt, jnp.asarray(kcache), jnp.asarray(vcache), sfb


def test_decode_matches_forward(params, block):
    pt, kc, vc, sf = _decode_setup(params, block)
    lg, nk, nv = model.decode_step(
        params, pt[:, 16], jnp.asarray([16], jnp.int32), kc, vc, sf, CFG)
    want = model.forward(params, pt, CFG)[:, 16]
    np.testing.assert_allclose(np.asarray(lg), np.asarray(want), atol=1e-4)
    assert nk.shape == (CFG.n_layers, 1, CFG.n_kv * CFG.d_head)


def test_decode_quantized_runs_and_snaps_kv(params, block):
    pt, kc, vc, sf = _decode_setup(params, block, quantized=True)
    lg, nk, nv = model.decode_step(
        params, pt[:, 16], jnp.asarray([16], jnp.int32), kc, vc, sf, CFG,
        quantized=True)
    assert np.isfinite(np.asarray(lg)).all()
    # new_v must already be on the INT4 grid (idempotent requant)
    again = quant.quant_kv_asym_per_head(nv, 4.0, CFG.d_head)
    np.testing.assert_allclose(np.asarray(again), np.asarray(nv),
                               rtol=1e-5, atol=1e-6)


def test_decode_batch_positions_independent(params, block):
    """Each batch lane attends only to its own prefix length."""
    ctx = 32
    L, kvdim = CFG.n_layers, CFG.n_kv * CFG.d_head
    r = np.random.default_rng(0)
    kc = r.normal(size=(L, 2, ctx, kvdim)).astype(np.float32)
    vc = r.normal(size=(L, 2, ctx, kvdim)).astype(np.float32)
    sf = jnp.ones((L, 2, kvdim), jnp.float32)
    toks = jnp.asarray([5, 9], jnp.int32)
    pos = jnp.asarray([4, 20], jnp.int32)
    lg1, *_ = model.decode_step(params, toks, pos, jnp.asarray(kc),
                                jnp.asarray(vc), sf, CFG)
    # garbage beyond each lane's position must not matter
    kc2, vc2 = kc.copy(), vc.copy()
    kc2[:, 0, 5:] = 42.0
    vc2[:, 1, 21:] = -42.0
    lg2, *_ = model.decode_step(params, toks, pos, jnp.asarray(kc2),
                                jnp.asarray(vc2), sf, CFG)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-5)


def test_smooth_calib_mode(params, block):
    s = model.scheme(kv_mode="smooth_calib")
    aux = model.default_aux(CFG)
    aux["kv_bits"] = jnp.float32(4.0)
    q, _, _ = model.nll(params, block, CFG, s, aux)
    assert np.isfinite(float(q))
