"""Synthetic corpora tests: determinism, split disjointness, shift."""

import numpy as np

from compile import corpus


def test_deterministic():
    a = corpus.corpus_tokens("wiki_syn", 100)
    b = corpus.corpus_tokens("wiki_syn", 100)
    np.testing.assert_array_equal(a, b)


def test_roundtrip():
    text = corpus.generate_text("c4_syn", 20)
    toks = corpus.tokenize(text)
    assert corpus.detokenize(toks) == text


def test_vocab_range():
    for name in ("wiki_syn", "c4_syn", "pile_syn"):
        toks = corpus.corpus_tokens(name, 200)
        assert toks.min() >= 0 and toks.max() < corpus.VOCAB


def test_splits_disjoint_streams():
    tr, ev = corpus.make_splits("wiki_syn", 200, 50)
    assert not np.array_equal(tr[: ev.size], ev)


def test_corpora_differ():
    """Distribution shift between corpora (the Table IV mechanism):
    unigram distributions must differ substantially."""
    def unigram(name):
        t = corpus.corpus_tokens(name, 500)
        h = np.bincount(t, minlength=256).astype(np.float64)
        return h / h.sum()
    pw = unigram("wiki_syn")
    pc = unigram("c4_syn")
    pp = unigram("pile_syn")
    def tv(a, b):
        return 0.5 * np.abs(a - b).sum()
    assert tv(pw, pc) > 0.1
    assert tv(pw, pp) > 0.1


def test_batches_shape_and_coverage():
    toks = corpus.corpus_tokens("wiki_syn", 500)
    blocks = list(corpus.batches(toks, 4, 32))
    assert all(b.shape == (4, 33) for b in blocks)
    assert len(blocks) >= 2
