"""Unit + property tests for the hybrid numerical formats (Section IV)."""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant

finite_f32 = st.floats(
    min_value=-1e4, max_value=1e4, allow_nan=False, width=32)


def arr(shape, min_value=-1e4, max_value=1e4):
    return hnp.arrays(
        np.float32, shape,
        elements=st.floats(min_value=min_value, max_value=max_value,
                           allow_nan=False, width=32),
    )


# ---------------------------------------------------------------- INT


@hypothesis.given(arr((4, 32)))
def test_int_asym_error_bound(x):
    """|x - q(x)| <= scale/2 with scale = range/(2^b - 1)."""
    q = np.asarray(quant.quant_int_asym(jnp.asarray(x), 4))
    rng = x.max(-1) - x.min(-1)
    bound = np.maximum(rng / 15.0, 1e-8) / 2 + 1e-5 * (1 + np.abs(x).max())
    assert (np.abs(q - x) <= bound[:, None] + 1e-6).all()


@hypothesis.given(arr((2, 16)))
def test_int_asym_idempotent(x):
    q1 = np.asarray(quant.quant_int_asym(jnp.asarray(x), 4))
    q2 = np.asarray(quant.quant_int_asym(jnp.asarray(q1), 4))
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-6)


def test_int_bits16_is_identity():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quant.quant_int_asym(x, 16.0)), np.asarray(x))


def test_int_sym_preserves_sign_and_zero():
    x = jnp.asarray([[-3.0, 0.0, 5.0, -0.1]])
    q = np.asarray(quant.quant_int_sym(x, 4))
    assert q[0, 1] == 0.0
    assert q[0, 0] <= 0.0 and q[0, 2] > 0.0


def test_int_grouped_matches_manual():
    x = np.random.default_rng(1).normal(size=(2, 8)).astype(np.float32)
    g = np.asarray(quant.quant_int_asym_grouped(jnp.asarray(x), 4, 4))
    m = np.asarray(
        quant.quant_int_asym(jnp.asarray(x.reshape(2, 2, 4)), 4)
    ).reshape(2, 8)
    np.testing.assert_allclose(g, m)


# ---------------------------------------------------------------- FP8


def test_e4m3_exact_values():
    # values exactly representable in E4M3 must round-trip
    exact = np.array([0.0, 0.5, 1.0, 1.5, -2.0, 448.0, 0.001953125],
                     np.float32)
    q = np.asarray(quant.quant_fp8_e4m3(jnp.asarray(exact)))
    np.testing.assert_array_equal(q, exact)


def test_e4m3_saturates():
    q = np.asarray(quant.quant_fp8_e4m3(jnp.asarray([1e6, -1e6],
                                                    jnp.float32)))
    np.testing.assert_array_equal(q, [448.0, -448.0])


@hypothesis.given(arr((64,), min_value=-448, max_value=448))
def test_e4m3_relative_error(x):
    """Normals: relative error <= 2^-4 (half ULP of 3-bit mantissa)."""
    q = np.asarray(quant.quant_fp8_e4m3(jnp.asarray(x)))
    normal = np.abs(x) >= 2.0**-6
    rel = np.abs(q - x) / np.maximum(np.abs(x), 1e-30)
    assert (rel[normal] <= 2.0**-4 + 1e-6).all()


def test_s0e4m4_range_and_fidelity():
    p = np.linspace(0, 1, 1001).astype(np.float32)
    q = np.asarray(quant.quant_fp8_s0e4m4(jnp.asarray(p)))
    assert (q >= 0).all() and (q <= 1).all()
    assert q[-1] == 1.0 and q[0] == 0.0
    # 4-bit mantissa: rel error of normals <= 2^-5
    normal = p >= 2.0**-14
    rel = np.abs(q - p)[normal] / p[normal]
    assert (rel <= 2.0**-5 + 1e-6).all()


def test_s0e4m4_beats_e4m3_and_int8_on_softmax_tensors():
    """Table II's mechanism: S0E4M4 has the best numerical fidelity on
    softmax-distributed scores.  Relative error is the relevant metric
    (perplexity perturbations track relative error of attention
    weights); int8 zeroes every score below 1/510 and e4m3 only keeps 3
    mantissa bits, while s0e4m4's 4-bit mantissa covers [0,1] exactly."""
    r = np.random.default_rng(0)
    logits = r.normal(0, 3, size=(256, 64)).astype(np.float32)
    p = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    pj = jnp.asarray(p)
    def relerr(q):
        return float(jnp.mean(jnp.abs(q - pj) / (pj + 1e-12)))
    e_s0 = relerr(quant.quant_fp8_s0e4m4(pj))
    e_e4 = relerr(quant.quant_fp8_e4m3(pj))
    e_i8 = relerr(quant.quant_int8_unsigned(pj))
    assert e_s0 < e_e4 < e_i8
    # and on the attention output (P @ V) at long context, MSE too
    ctx = 256
    logits = r.normal(0, 3, size=(64, ctx)).astype(np.float32)
    p = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    v = r.normal(size=(ctx, 16)).astype(np.float32)
    pj, vj = jnp.asarray(p), jnp.asarray(v)
    out = pj @ vj
    def pv_mse(q):
        return float(jnp.mean((q @ vj - out) ** 2))
    assert pv_mse(quant.quant_fp8_s0e4m4(pj)) < pv_mse(
        quant.quant_int8_unsigned(pj))
    assert pv_mse(quant.quant_fp8_s0e4m4(pj)) < pv_mse(
        quant.quant_fp8_e4m3(pj))


@hypothesis.given(arr((32,), min_value=0.0, max_value=1.0))
def test_s0e4m4_idempotent(p):
    q1 = np.asarray(quant.quant_fp8_s0e4m4(jnp.asarray(p)))
    q2 = np.asarray(quant.quant_fp8_s0e4m4(jnp.asarray(q1)))
    np.testing.assert_array_equal(q1, q2)


# ------------------------------------------------------------- BitMoD


def test_bitmod_encode_decode_roundtrip():
    r = np.random.default_rng(2)
    w = r.normal(0, 0.3, size=(4, 128)).astype(np.float32)
    codes, scales, specials = quant.quant_bitmod_encode(w, 128)
    deq = quant.bitmod_decode(codes, scales, specials, 128)
    fq = np.asarray(quant.quant_bitmod(jnp.asarray(w), 128))
    np.testing.assert_allclose(deq, fq, atol=1e-6)
    assert codes.max() <= 15 and specials.max() <= 3


def test_bitmod_beats_int4_on_gaussian_weights():
    """BitMoD's claim: lower error than asymmetric INT4 on
    normally-distributed weight groups."""
    r = np.random.default_rng(3)
    w = r.normal(0, 0.1, size=(64, 128)).astype(np.float32)
    wj = jnp.asarray(w)
    e_bm = float(jnp.mean((quant.quant_bitmod(wj, 128) - wj) ** 2))
    e_i4 = float(jnp.mean(
        (quant.quant_int_asym_grouped(wj, 4, 128) - wj) ** 2))
    assert e_bm < e_i4


def test_bitmod_uses_special_values():
    """A group with one large-magnitude outlier should pick a +-8/5
    special value for it."""
    w = np.full(128, 0.1, np.float32)
    w[7] = -0.8  # 8x the rest -> special -8 fits
    codes, scales, specials = quant.quant_bitmod_encode(w, 128)
    assert codes.reshape(-1)[7] == 15  # special slot
    assert specials[0] in (0, 1)  # -8 or -5


# ---------------------------------------------------------- smoothing


def test_smoothing_suppresses_outlier_channels():
    r = np.random.default_rng(4)
    k = r.normal(size=(64, 32)).astype(np.float32)
    k[:, 5] *= 20.0
    kj = jnp.asarray(k)
    f = quant.smoothing_factors(kj)
    ks = np.asarray(kj / f)
    assert np.abs(ks).max() <= 1.0 + 1e-6
    # quantization error (relative) improves vs direct per-head INT4
    e_direct = float(jnp.mean(
        (quant.quant_kv_asym_per_head(kj, 4.0, 16) - kj) ** 2))
    e_smooth = float(jnp.mean(
        (quant.quant_key_smoothed(kj, 4.0, 16) - kj) ** 2))
    assert e_smooth < e_direct


def test_oaken_mixed_precision():
    r = np.random.default_rng(5)
    x = r.normal(size=(8, 32)).astype(np.float32)
    mask = np.zeros(32, np.float32)
    mask[3] = 1.0
    q = np.asarray(quant.quant_kv_oaken(jnp.asarray(x),
                                        jnp.asarray(mask), 16))
    q8 = np.asarray(quant.quant_kv_asym_per_head(jnp.asarray(x), 8.0, 16))
    np.testing.assert_allclose(q[:, 3], q8[:, 3], atol=1e-6)


def test_hadamard_orthonormal():
    h = np.asarray(quant.hadamard_matrix(64))
    np.testing.assert_allclose(h @ h.T, np.eye(64), atol=1e-5)


def test_smoothquant_factors_migrate():
    a = jnp.asarray([10.0, 0.1])
    w = jnp.asarray([0.1, 0.1])
    s = np.asarray(quant.smoothquant_factors(a, w, 0.5))
    assert s[0] > s[1]  # big-activation channel gets shrunk more
