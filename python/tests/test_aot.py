"""AOT pipeline tests: manifests, blobs, goldens, graph emission."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, train


CFG = model.TINY


def test_aux_blob_layout_roundtrip(tmp_path):
    aot.write_aux_manifest(str(tmp_path), CFG)
    blob = aot.aux_to_blob(CFG, {"kv_bits": np.float32(4.0)})
    layout = {}
    with open(tmp_path / "aux_layout.tsv") as f:
        next(f)
        for line in f:
            name, shape, off, cnt = line.strip().split("\t")
            layout[name] = (int(off), int(cnt))
    # scalar override landed at its offset
    off, cnt = layout["kv_bits"]
    assert cnt == 1 and blob[off] == 4.0
    # total size matches
    assert blob.size == sum(c for _, c in layout.values())


def test_weights_roundtrip(tmp_path):
    params = model.init_params(model.Config(n_layers=1), seed=3)
    cfg1 = model.Config(n_layers=1)
    train.save_weights(params, tmp_path / "w.bin", tmp_path / "w.tsv")
    loaded = train.load_weights(tmp_path / "w.bin", cfg1)
    for k in params:
        np.testing.assert_array_equal(
            np.asarray(params[k]), np.asarray(loaded[k]))


def test_graph_emission_hlo_text(tmp_path):
    reg = aot.Registry(str(tmp_path))
    reg.graph("add", lambda a, b: (a + b,),
              [("a", aot.spec((2, 2))), ("b", aot.spec((2, 2)))])
    reg.write()
    text = (tmp_path / "add.hlo.txt").read_text()
    assert "HloModule" in text
    manifest = (tmp_path / "manifest.tsv").read_text()
    assert "add\tadd.hlo.txt\ta:2x2:f32;b:2x2:f32" in manifest


def test_reassemble_inverse():
    names = sorted(model.param_shapes(CFG))
    args = list(range(len(names))) + ["block"] + [
        f"aux{i}" for i in range(len(model.AUX_ORDER))]
    params, block, aux = aot.reassemble(CFG, args)
    assert block == "block"
    assert len(params) == len(names)
    assert list(aux) == list(model.AUX_ORDER)


def test_eval_scheme_table_complete():
    """Every graph referenced by evalcfg must exist in EVAL_SCHEMES."""
    import io
    from unittest import mock
    buf = {}
    def fake_open(path, mode="r"):
        buf[path] = io.StringIO()
        buf[path].close = lambda: None
        return buf[path]
    with mock.patch("builtins.open", fake_open):
        aot.write_evalcfg("/x")
    content = next(iter(buf.values())).getvalue()
    for line in content.splitlines()[1:]:
        graph = line.split("\t")[1]
        tag = graph.removeprefix("eval_")
        assert tag in aot.EVAL_SCHEMES, graph
