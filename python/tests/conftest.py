import hypothesis

# jax tracing/compilation inside property bodies blows the default 200 ms
# deadline; wall-clock flakiness is not what these tests measure.
hypothesis.settings.register_profile(
    "jax", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("jax")
