"""L1 Pallas kernels vs pure-jnp oracles -- the core correctness signal.

hypothesis sweeps shapes and values; every kernel must match its ref to
float tolerance (elementwise quantizers bit-exactly; matmuls to
accumulation-order tolerance).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant
from compile.kernels import attention, quantize, ref, w4a8_gemv

hsettings = hypothesis.settings(max_examples=12, deadline=None)


def _bitmod_pack(w):
    """w: [K, N] -> kernel operands."""
    k, n = w.shape
    codes, scales, specials = quant.quant_bitmod_encode(w.T, 128)
    g = k // 128
    return (
        jnp.asarray(codes.T.astype(np.uint8)),
        jnp.asarray(scales.reshape(n, g).T.astype(np.float32)),
        jnp.asarray(specials.reshape(n, g).T.astype(np.uint8)),
    )


@hsettings
@hypothesis.given(
    b=st.sampled_from([1, 2, 8]),
    k=st.sampled_from([128, 256]),
    n=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_w4a8_gemv_matches_ref(b, k, n, seed):
    r = np.random.default_rng(seed)
    x = quant.quant_fp8_e4m3(
        jnp.asarray(r.normal(size=(b, k)).astype(np.float32)))
    w = r.normal(0, 0.2, size=(k, n)).astype(np.float32)
    codes, scales, specials = _bitmod_pack(w)
    y_k = w4a8_gemv.w4a8_matmul(x, codes, scales, specials)
    y_r = ref.w4a8_matmul_ref(x, codes, scales, specials)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


def test_w4a8_gemv_equals_dense_matmul_on_dequant():
    """Fused kernel == dequantize-then-matmul (the paper's fusion claim:
    same numerics, no materialized fp weights)."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(4, 128)).astype(np.float32))
    w = r.normal(0, 0.2, size=(128, 128)).astype(np.float32)
    codes, scales, specials = _bitmod_pack(w)
    wd = np.asarray(quant.quant_bitmod(jnp.asarray(w.T), 128)).T
    y_k = np.asarray(w4a8_gemv.w4a8_matmul(x, codes, scales, specials))
    np.testing.assert_allclose(y_k, np.asarray(x) @ wd, rtol=1e-5,
                               atol=1e-5)


@hsettings
@hypothesis.given(
    b=st.sampled_from([1, 3, 8]),
    ctx=st.sampled_from([16, 64, 160]),
    quantized=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, ctx, quantized, seed):
    r = np.random.default_rng(seed)
    nh, nkv, dh = 8, 2, 16
    q = jnp.asarray(r.normal(size=(b, nh, dh)).astype(np.float32))
    kc = jnp.asarray(r.normal(size=(b, ctx, nkv, dh)).astype(np.float32))
    vc = jnp.asarray(r.normal(size=(b, ctx, nkv, dh)).astype(np.float32))
    lens = r.integers(1, ctx + 1, size=b)
    att = jnp.asarray(np.arange(ctx)[None, :] < lens[:, None])
    o_k = attention.decode_attention(q, kc, vc, att, quantized=quantized)
    o_r = ref.decode_attention_ref(q, kc, vc, att, quantized=quantized)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_masks_out_future():
    """Scores on masked slots must not leak: vary masked-slot contents."""
    r = np.random.default_rng(1)
    q = jnp.asarray(r.normal(size=(1, 8, 16)).astype(np.float32))
    kc = r.normal(size=(1, 32, 2, 16)).astype(np.float32)
    vc = r.normal(size=(1, 32, 2, 16)).astype(np.float32)
    att = jnp.asarray(np.arange(32)[None, :] < 10)
    o1 = attention.decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), att)
    kc[:, 10:] = 99.0
    vc[:, 10:] = -99.0
    o2 = attention.decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), att)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@hsettings
@hypothesis.given(
    rows=st.sampled_from([8, 64]),
    cols=st.sampled_from([16, 128]),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fp8_e4m3_kernel_matches_ref(rows, cols, scale, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray((r.normal(size=(rows, cols)) * scale)
                    .astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(quantize.fp8_e4m3(x)),
        np.asarray(ref.fp8_e4m3_ref(x)))


@hsettings
@hypothesis.given(
    t=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_int4_kernel_matches_ref(t, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(t, 32)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(quantize.int4_asym_per_head(x, 16)),
        np.asarray(ref.int4_asym_per_head_ref(x, 16)),
        rtol=1e-6, atol=1e-6)


def test_s0e4m4_in_kernel_matches_quant_lib():
    """attention._s0e4m4 must be the same grid as quant.quant_fp8_s0e4m4."""
    p = jnp.asarray(np.linspace(0, 1, 257).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(attention._s0e4m4(p)),
        np.asarray(quant.quant_fp8_s0e4m4(p)))


def test_vmem_estimates_positive():
    assert w4a8_gemv.vmem_bytes(8, 128, 256) > 0
    assert attention.vmem_bytes(4, 8, 16, 160, 2) > 0
