//! Regenerate every simulator-backed paper table/figure in one go and
//! write the TSVs under reports/.  (Accuracy tables need the PJRT
//! artifacts and live in `cargo bench` targets tab02..tab06, fig03b,
//! fig05, fig08.)
//!
//! ```sh
//! cargo run --release --example paper_tables
//! ```

use p3llm::accel::{fig9_systems, Accel};
use p3llm::area::{pcu_area_table, pe_table};
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, f3, Table};
use p3llm::workload::memory_breakdown;

fn main() {
    let dir = p3llm::benchkit::reports_dir();

    // Fig 9 + summary
    let systems = fig9_systems();
    let mut fig9 = Table::new(
        "Fig 9: speedup over NPU",
        &["model", "bs", "NPU", "HBM-PIM", "Ecco", "P3-LLM"],
    );
    let mut sums = vec![0.0; systems.len()];
    let mut n = 0;
    for m in eval_models() {
        for bs in [1usize, 2, 4, 8] {
            let ns: Vec<f64> = systems
                .iter()
                .map(|a| a.decode_step(&m, bs, 4096).total_ns())
                .collect();
            fig9.row(
                std::iter::once(m.name.to_string())
                    .chain(std::iter::once(bs.to_string()))
                    .chain(ns.iter().map(|&x| f2(ns[0] / x)))
                    .collect(),
            );
            for (i, &x) in ns.iter().enumerate() {
                sums[i] += x / ns[3];
            }
            n += 1;
        }
    }
    fig9.print();
    println!(
        "P3 avg speedups -- NPU {:.2}x, HBM-PIM {:.2}x, Ecco {:.2}x (paper 7.8/4.9/2.0)\n",
        sums[0] / n as f64,
        sums[1] / n as f64,
        sums[2] / n as f64
    );
    fig9.save(&dir, "paper_fig09").unwrap();

    // Table VII/VIII
    let mut t7 = Table::new("Table VII", &["design", "compute", "buffer", "overhead %"]);
    for r in pcu_area_table() {
        t7.row(vec![r.name.into(), f2(r.compute_mm2), f2(r.buffer_mm2),
                    f2(r.hbm_overhead_pct)]);
    }
    t7.print();
    t7.save(&dir, "paper_tab07").unwrap();

    let mut t8 = Table::new("Table VIII", &["PE", "area um2", "pJ/MAC"]);
    for r in pe_table() {
        t8.row(vec![r.name.into(), f2(r.area_um2_28nm), f3(r.energy_pj_per_mac)]);
    }
    t8.print();
    t8.save(&dir, "paper_tab08").unwrap();

    // Fig 14
    let mut f14 = Table::new(
        "Fig 14: weights+KV GB (bs=8, ctx=4K)",
        &["model", "FP16", "P3-LLM", "reduction"],
    );
    for m in eval_models() {
        let fp = memory_breakdown(&m, 8, 4096, 16.0, 16.0, 16.0, 16.0);
        let p3s = p3llm::config::scheme::QuantScheme::p3llm();
        let p3 = memory_breakdown(&m, 8, 4096, p3s.bits.weights, 16.0,
                                  p3s.bits.kv, 16.0);
        let a = (fp.weights + fp.kv) / 1e9;
        let b = (p3.weights + p3.kv) / 1e9;
        f14.row(vec![m.name.into(), f2(a), f2(b), f2(a / b)]);
    }
    f14.print();
    f14.save(&dir, "paper_fig14").unwrap();

    // Fig 15 chain summary
    let chain = [
        Accel::hbm_pim(),
        Accel::pim_w4a8kv4(),
        Accel::pim_w4a8kv4_tep(),
        Accel::p3llm(),
    ];
    let mut c = vec![0.0; chain.len()];
    let mut n2 = 0;
    for m in eval_models() {
        for bs in [2usize, 4] {
            let ns: Vec<f64> = chain
                .iter()
                .map(|a| a.decode_step(&m, bs, 4096).total_ns())
                .collect();
            for i in 0..chain.len() {
                c[i] += ns[0] / ns[i];
            }
            n2 += 1;
        }
    }
    println!(
        "Fig 15 chain: +W4A8KV4 {:.2}x, +TEP x{:.2}, +P8 x{:.2} (paper 3.3/1.6/1.2)",
        c[1] / n2 as f64,
        c[2] / c[1],
        c[3] / c[2]
    );

    println!("\nreports written to {}", dir.display());
}
