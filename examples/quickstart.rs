//! Quickstart: load the AOT artifacts, run one prefill + a few decode
//! steps through the PJRT runtime, and print the generated text.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! No artifacts handy?  Swap `EngineBuilder::pjrt(..)` for
//! `EngineBuilder::sim()` and the same lifecycle runs on the NPU-PIM
//! cost model (tokens become synthetic, timing becomes simulated).

use p3llm::EngineBuilder;

fn main() -> p3llm::Result<()> {
    let dir = p3llm::benchkit::artifacts_dir();
    let mut engine = EngineBuilder::pjrt(&dir)
        .scheme("p3llm")
        .max_batch(1)
        .build()?;
    let prompt = "celund is the capital of";
    println!("model: {} (W4A8KV4P8, BitMoD weights)", engine.model().name);
    println!("prompt: {prompt:?}");
    let toks: Vec<i32> = prompt.bytes().map(|b| b as i32).collect();
    let id = engine.submit(toks, 32)?;
    let metrics = engine.run_to_completion()?;
    let req = engine.request(id).unwrap();
    let text: String = req
        .generated
        .iter()
        .map(|&t| if t == 0 { '\n' } else { t as u8 as char })
        .collect();
    println!("generated: {text:?}");
    println!(
        "{} tokens in {:.0} ms ({:.1} tok/s), ttft {:.1} ms (p99 {:.1}), \
         kv pool {} B packed",
        metrics.tokens_out,
        metrics.wall_ms,
        metrics.tokens_per_sec(),
        metrics.mean_ttft_ms(),
        metrics.ttft_ms.p99,
        engine.pool_used_bytes(),
    );
    Ok(())
}
