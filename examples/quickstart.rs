//! Quickstart: load the AOT artifacts, run one prefill + a few decode
//! steps through the PJRT runtime, and print the generated text.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use p3llm::coordinator::{Engine, EngineConfig};

fn main() -> anyhow::Result<()> {
    let dir = p3llm::benchkit::artifacts_dir();
    let mut engine = Engine::new(
        &dir,
        EngineConfig { quantized: true, max_batch: 1, ..Default::default() },
    )?;
    let prompt = "celund is the capital of";
    println!("model: {} (W4A8KV4P8, BitMoD weights)", engine.model.name);
    println!("prompt: {prompt:?}");
    let toks: Vec<i32> = prompt.bytes().map(|b| b as i32).collect();
    let id = engine.submit(toks, 32);
    let stats = engine.run_to_completion()?;
    let req = engine.request(id).unwrap();
    let text: String = req
        .generated
        .iter()
        .map(|&t| if t == 0 { '\n' } else { t as u8 as char })
        .collect();
    println!("generated: {text:?}");
    println!(
        "{} tokens in {:.0} ms ({:.1} tok/s), ttft {:.1} ms, kv pool {} B packed",
        stats.tokens_out,
        stats.wall_ms,
        stats.tokens_per_sec(),
        stats.mean_ttft_ms(),
        engine.pool_used_bytes(),
    );
    Ok(())
}
