//! Operator-mapping + PIM command-trace explorer (paper Fig. 6b/7):
//! prints where every decode operator of a model runs (NPU vs PIM),
//! the per-op latency, and the command timing of the first columns.
//!
//! ```sh
//! cargo run --release --example pim_trace -- --model Llama-3.1-8B --batch 2
//! ```

use p3llm::accel::Accel;
use p3llm::cli::Args;
use p3llm::config::accel::{HbmTiming, PcuConfig, PimConfig};
use p3llm::config::llm;
use p3llm::coordinator::mapper::{command_timing, map_decode_step, Engine};
use p3llm::report::{f2, Table};
use p3llm::sim::pim::PimGemm;

fn main() -> p3llm::Result<()> {
    let args = Args::from_env();
    let model = llm::by_name(args.get_or("model", "Llama-3.1-8B"))
        .expect("unknown model");
    let bs = args.get_usize("batch", 2)?;
    let ctx = args.get_usize("ctx", 4096)?;
    let accel = Accel::p3llm();

    let mut t = Table::new(
        format!("{} decode step mapping (bs={bs}, ctx={ctx})", model.name),
        &["op", "engine", "us", "PIM commands"],
    );
    let mut pim_us = 0.0;
    let mut npu_us = 0.0;
    for a in map_decode_step(&accel, &model, bs, ctx) {
        t.row(vec![
            a.op.into(),
            format!("{:?}", a.engine),
            f2(a.ns / 1e3),
            a.commands.to_string(),
        ]);
        match a.engine {
            Engine::Pim => pim_us += a.ns / 1e3,
            Engine::Npu => npu_us += a.ns / 1e3,
        }
    }
    t.print();
    println!("PIM {:.1} us, NPU {:.1} us per step\n", pim_us, npu_us);

    let mut tt = Table::new(
        "Fig 7 command timing (first 3 columns of a GEMV pass)",
        &["pcu", "col", "event", "t ns"],
    );
    for pcu in [PcuConfig::hbm_pim(), PcuConfig::p3llm()] {
        let bits = pcu.weight_bits.min(16.0);
        let pim = PimConfig { hbm: HbmTiming::default(), pcu: pcu.clone() };
        let g = PimGemm { m: 2, k: model.hidden, n: 128, count: 1, stored_bits: bits };
        for (c, t_ns, ev) in command_timing(&pim, g, 3) {
            tt.row(vec![
                pcu.name.into(),
                c.to_string(),
                ev.into(),
                format!("{t_ns:.1}"),
            ]);
        }
    }
    tt.print();
    Ok(())
}
