//! Programmatic tour of the traffic subsystem: run every named
//! scenario on P3-LLM and one custom trace-replay workload, printing
//! goodput / SLO attainment per scenario.  Equivalent CLI:
//! `cargo run --release -- loadtest --system P3-LLM --seed 7`.

use p3llm::report::{f2, Table};
use p3llm::traffic::{
    all_scenarios, parse_trace_tsv, LoadRunner, RequestMix, SloSpec,
};

fn main() -> p3llm::Result<()> {
    let seed = 7u64;
    let mut t = Table::new(
        "traffic scenarios on P3-LLM",
        &["scenario", "done", "SLO %", "goodput tok/s", "p95 TTFT ms", "p95 queue ms"],
    );
    for sc in all_scenarios() {
        let mut eng = sc.engine("P3-LLM", None)?;
        let out = sc.runner(seed).run(&mut eng)?;
        let r = out.report;
        t.row(vec![
            sc.name.into(),
            format!("{}/{}", r.completed, r.offered),
            f2(r.slo_attainment * 100.0),
            f2(r.goodput_tok_s),
            f2(r.ttft_ms.p95),
            f2(r.queue_delay_ms.p95),
        ]);
    }

    // trace replay: a hand-written arrival trace (ms offsets) through
    // the smoke engine shape -- the `loadtest --trace FILE` path
    let trace = parse_trace_tsv("# ms\n0\n5\n6\n7\n120\n125\n300\n")?;
    let sc = p3llm::traffic::scenario_by_name("smoke").unwrap();
    let mut eng = sc.engine("P3-LLM", None)?;
    let runner = LoadRunner::new(
        &trace,
        &RequestMix::tiny(),
        SloSpec::chatbot(),
        7,
        seed,
    );
    let out = runner.run(&mut eng)?;
    t.row(vec![
        "trace-replay".into(),
        format!("{}/{}", out.report.completed, out.report.offered),
        f2(out.report.slo_attainment * 100.0),
        f2(out.report.goodput_tok_s),
        f2(out.report.ttft_ms.p95),
        f2(out.report.queue_delay_ms.p95),
    ]);
    t.print();
    Ok(())
}
