//! Numerical-format explorer: quantize a tensor with every format in
//! the library and compare errors -- a tool for reproducing the
//! paper's Section IV design choices interactively.
//!
//! ```sh
//! cargo run --release --example quant_explore -- --dist softmax
//! cargo run --release --example quant_explore -- --dist gaussian --outlier 20
//! ```

use p3llm::cli::Args;
use p3llm::quant::{
    bitmod_encode_group, bitmod_decode_group, fp8_e4m3, fp8_s0e4m4,
    int8_unsigned, smoothing_factors,
};
use p3llm::quant::int::fake_quant_group_int;
use p3llm::report::{Table, f3};
use p3llm::testutil::Rng;

fn mse(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((x - y) * (x - y)) as f64).sum::<f64>()
        / a.len() as f64
}

fn rel(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y).abs() / (x.abs() + 1e-9)) as f64)
        .sum::<f64>()
        / a.len() as f64
}

fn main() -> p3llm::Result<()> {
    let args = Args::from_env();
    let dist = args.get_or("dist", "softmax");
    let outlier = args.get_f64("outlier", 1.0)? as f32;
    let n = args.get_usize("n", 4096)?;
    let mut rng = Rng::new(args.get_usize("seed", 3)? as u64);

    let x: Vec<f32> = match dist {
        "softmax" => {
            // scores from a realistic logit spread
            let logits: Vec<f32> = (0..n).map(|_| rng.normal() * 3.0).collect();
            let rows = n / 64;
            let mut out = vec![0.0f32; n];
            for r in 0..rows {
                let row = &logits[r * 64..(r + 1) * 64];
                let m = row.iter().cloned().fold(f32::MIN, f32::max);
                let ex: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
                let s: f32 = ex.iter().sum();
                for (i, e) in ex.iter().enumerate() {
                    out[r * 64 + i] = e / s;
                }
            }
            out
        }
        "gaussian" => (0..n)
            .map(|i| rng.normal() * if i % 128 == 7 { outlier } else { 1.0 })
            .collect(),
        _ => panic!("--dist softmax|gaussian"),
    };

    let mut t = Table::new(
        format!("format comparison on {dist} tensor (n={n}, outlier x{outlier})"),
        &["format", "MSE", "mean rel err"],
    );
    let apply = |f: &dyn Fn(f32) -> f32| -> Vec<f32> {
        x.iter().map(|&v| f(v)).collect()
    };
    t.row(vec!["FP8-E4M3".into(), f3(mse(&x, &apply(&fp8_e4m3))),
               f3(rel(&x, &apply(&fp8_e4m3)))]);
    if dist == "softmax" {
        t.row(vec!["FP8-S0E4M4".into(), f3(mse(&x, &apply(&fp8_s0e4m4))),
                   f3(rel(&x, &apply(&fp8_s0e4m4)))]);
        t.row(vec!["INT8-unsigned".into(), f3(mse(&x, &apply(&int8_unsigned))),
                   f3(rel(&x, &apply(&int8_unsigned)))]);
    }
    // group formats
    for (name, bits) in [("INT4-Asym/128", 4u32), ("INT8-Asym/128", 8)] {
        let mut q = x.clone();
        for g in q.chunks_mut(128) {
            fake_quant_group_int(g, bits);
        }
        t.row(vec![name.into(), f3(mse(&x, &q)), f3(rel(&x, &q))]);
    }
    {
        let mut q = vec![0.0f32; x.len()];
        for (xi, qi) in x.chunks(128).zip(q.chunks_mut(128)) {
            let enc = bitmod_encode_group(xi);
            bitmod_decode_group(&enc, qi);
        }
        t.row(vec!["BitMoD-FP4/128".into(), f3(mse(&x, &q)), f3(rel(&x, &q))]);
    }
    if dist == "gaussian" {
        // smoothed INT4 (the P3 key-cache path), channels = 128
        let f = smoothing_factors(&x, 128);
        let mut q = x.clone();
        for row in q.chunks_mut(128) {
            for (v, fc) in row.iter_mut().zip(&f) {
                *v /= fc;
            }
            fake_quant_group_int(row, 4);
            for (v, fc) in row.iter_mut().zip(&f) {
                *v *= fc;
            }
        }
        t.row(vec!["INT4 + smoothing".into(), f3(mse(&x, &q)),
                   f3(rel(&x, &q))]);
    }
    t.print();
    Ok(())
}
