//! End-to-end edge serving driver (the DESIGN.md E2E validation run):
//! batched requests through the full stack -- continuous batcher,
//! INT4-packed KV pool with dynamic smoothing factors, AOT W4A8KV4P8
//! decode graphs on PJRT -- reporting latency/throughput, the fp16-vs-
//! quantized perplexity delta, and the modeled NPU-PIM speedup for the
//! same workload.  Results are recorded in EXPERIMENTS.md.

use p3llm::accel::Accel;
use p3llm::config::llm::TINY;
use p3llm::coordinator::{Engine, EngineConfig};
use p3llm::report::{f2, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = p3llm::benchkit::artifacts_dir();
    let n_requests = 16;
    let max_new = 48;
    let prompts = [
        "in 1021 , brevik exports grain to",
        "the lantern works great ! rating :",
        "to fix your keyboard , first",
        "morvane is twinned with",
        "if ( read_buf ( buf ) < 0 )",
        "the backpack broke after a week",
    ];

    let mut t = Table::new(
        "edge_serve: 16 requests, 48 new tokens each, tiny-1M",
        &["pipeline", "tok/s", "mean ttft ms", "steps", "wall ms"],
    );
    for quantized in [false, true] {
        let mut engine = Engine::new(
            &dir,
            EngineConfig { quantized, max_batch: 8, ..Default::default() },
        )?;
        for i in 0..n_requests {
            let p = prompts[i % prompts.len()];
            engine.submit(p.bytes().map(|b| b as i32).collect(), max_new);
        }
        let stats = engine.run_to_completion()?;
        assert_eq!(stats.completed, n_requests);
        t.row(vec![
            if quantized { "W4A8KV4P8 (P3-LLM)" } else { "FP16" }.into(),
            f2(stats.tokens_per_sec()),
            f2(stats.mean_ttft_ms()),
            stats.decode_steps.to_string(),
            f2(stats.wall_ms),
        ]);
        if quantized {
            println!(
                "packed KV pool bytes at peak batch: {}",
                engine.pool_used_bytes()
            );
        }
    }
    t.print();

    // accuracy guard: quantization must cost < 5% perplexity on the
    // in-domain eval corpus
    let rt = Runtime::new(&dir)?;
    let ev = Evaluator::new(&rt)?;
    let cfgs = eval_configs(&rt.artifacts.dir)?;
    let get = |n: &str| cfgs.iter().find(|c| c.name == n).unwrap();
    let fp = ev.perplexity(get("fp16"), "wiki", 4, &[])?;
    let q = ev.perplexity(get("p3_full"), "wiki", 4, &[])?;
    println!("perplexity: fp16 {fp:.4} -> W4A8KV4P8 {q:.4} ({:+.2}%)",
             (q / fp - 1.0) * 100.0);
    assert!(q / fp < 1.05, "quantization cost exceeded 5%");

    // modeled hardware: what this workload costs on the simulated
    // NPU-PIM systems (per decode step of a 7B-class model, the class
    // this serving stack targets)
    let mut hw = Table::new(
        "modeled decode step (Llama-3.1-8B, bs=8, ctx=4K)",
        &["system", "ms/step", "tok/s"],
    );
    for a in [Accel::npu_fp16(), Accel::hbm_pim(), Accel::p3llm()] {
        let m = p3llm::config::llm::LLAMA31_8B.clone();
        let ns = a.decode_step(&m, 8, 4096).total_ns();
        hw.row(vec![a.name.into(), f2(ns / 1e6), f2(8.0 / (ns * 1e-9))]);
    }
    hw.print();
    let _ = TINY; // tiny config is what actually ran above
    Ok(())
}
