//! End-to-end edge serving driver (the DESIGN.md E2E validation run):
//! batched requests through the full stack -- continuous batcher,
//! INT4-packed KV pool with dynamic smoothing factors, AOT W4A8KV4P8
//! decode graphs on PJRT -- reporting latency/throughput, the fp16-vs-
//! quantized perplexity delta, and the *same serving loop* replayed on
//! the modeled NPU-PIM hardware via the sim backend.  Results are
//! recorded in EXPERIMENTS.md.

use p3llm::report::{f2, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};
use p3llm::EngineBuilder;

fn main() -> p3llm::Result<()> {
    let dir = p3llm::benchkit::artifacts_dir();
    let n_requests = 16;
    let max_new = 48;
    let prompts = [
        "in 1021 , brevik exports grain to",
        "the lantern works great ! rating :",
        "to fix your keyboard , first",
        "morvane is twinned with",
        "if ( read_buf ( buf ) < 0 )",
        "the backpack broke after a week",
    ];

    let mut t = Table::new(
        "edge_serve: 16 requests, 48 new tokens each, tiny-1M",
        &["pipeline", "tok/s", "p50 ttft ms", "p95 ttft ms", "steps", "wall ms"],
    );
    for scheme in ["fp16", "p3llm"] {
        let mut engine = EngineBuilder::pjrt(&dir)
            .scheme(scheme)
            .max_batch(8)
            .build()?;
        for i in 0..n_requests {
            let p = prompts[i % prompts.len()];
            engine.submit(p.bytes().map(|b| b as i32).collect(), max_new)?;
        }
        let m = engine.run_to_completion()?;
        assert_eq!(m.completed, n_requests);
        t.row(vec![
            if scheme == "p3llm" { "W4A8KV4P8 (P3-LLM)" } else { "FP16" }
                .into(),
            f2(m.tokens_per_sec()),
            f2(m.ttft_ms.p50),
            f2(m.ttft_ms.p95),
            m.decode_steps.to_string(),
            f2(m.wall_ms),
        ]);
        if scheme == "p3llm" {
            println!(
                "packed KV pool bytes at peak batch: {}",
                engine.pool_used_bytes()
            );
        }
    }
    t.print();

    // accuracy guard: quantization must cost < 5% perplexity on the
    // in-domain eval corpus
    let rt = Runtime::new(&dir)?;
    let ev = Evaluator::new(&rt)?;
    let cfgs = eval_configs(&rt.artifacts.dir)?;
    let get = |n: &str| cfgs.iter().find(|c| c.name == n).unwrap();
    let fp = ev.perplexity(get("fp16"), "wiki", 4, &[])?;
    let q = ev.perplexity(get("p3_full"), "wiki", 4, &[])?;
    println!("perplexity: fp16 {fp:.4} -> W4A8KV4P8 {q:.4} ({:+.2}%)",
             (q / fp - 1.0) * 100.0);
    assert!(q / fp < 1.05, "quantization cost exceeded 5%");

    // modeled hardware: the same 7B-class workload through the same
    // engine/batcher/pool, with the sim backend advancing modeled time
    let mut hw = Table::new(
        "modeled serving loop (Llama-3.1-8B, bs=8, 16-tok prompts, 48 new)",
        &["system", "sim ms", "p95 ttft ms", "tok/s (modeled)"],
    );
    for system in ["NPU", "HBM-PIM", "P3-LLM"] {
        let mut engine = EngineBuilder::sim()
            .model("Llama-3.1-8B")
            .system(system)
            .max_batch(8)
            .ctx_limit(512)
            .kv_capacity(1 << 30)
            .build()?;
        for i in 0..n_requests {
            let toks: Vec<i32> =
                (0..16).map(|t| ((i * 13 + t) % 250) as i32).collect();
            engine.submit(toks, max_new)?;
        }
        let m = engine.run_to_completion()?;
        hw.row(vec![
            system.into(),
            f2(m.wall_ms),
            f2(m.ttft_ms.p95),
            f2(m.tokens_per_sec()),
        ]);
    }
    hw.print();
    Ok(())
}
