#!/usr/bin/env bash
# One gate for every PR: tier-1 verify (hard) + fmt/clippy hygiene.
#
#   ./ci.sh            # build + test are fatal; fmt/clippy report only
#   ./ci.sh --strict   # fmt/clippy failures are fatal too
#
# Keep this green.  The hygiene checks are advisory by default so the
# gate stays usable on toolchains without rustfmt/clippy components.
set -euo pipefail
cd "$(dirname "$0")"

STRICT=0
[ "${1:-}" = "--strict" ] && STRICT=1

hygiene() {
    local name="$1"; shift
    if ! command -v cargo >/dev/null; then
        echo "ci: cargo not found" >&2; exit 1
    fi
    if "$@"; then
        echo "ci: $name OK"
    else
        if [ "$STRICT" = 1 ]; then
            echo "ci: $name FAILED (strict)" >&2; exit 1
        fi
        echo "ci: $name failed (advisory; run with --strict to enforce)" >&2
    fi
}

hygiene "cargo fmt" cargo fmt --all -- --check
hygiene "cargo clippy" cargo clippy --workspace --all-targets -- -D warnings

echo "ci: tier-1 build"
cargo build --release
echo "ci: tier-1 tests"
cargo test -q

# Rustdoc gate: crate docs (incl. the compiling doc-examples in
# lib.rs / serve.rs / traffic / cluster) must build warning-free --
# broken intra-doc links and malformed doc markup fail the build.
echo "ci: rustdoc"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "ci: rustdoc OK"

# Fast closed-loop serving gate: the tiny Poisson scenario AND the
# tiny shared-prefix scenario run through the real engine under
# --smoke.  The binary enforces nonzero goodput, a nonzero prefix-
# cache hit rate, and a strictly lower mean TTFT than the identical
# cache-disabled run; the diff below enforces bit-identical output
# across runs under a fixed seed (hit/saved columns included).
echo "ci: loadtest smoke"
S1=$(cargo run --release --quiet -- loadtest --smoke --seed 7)
S2=$(cargo run --release --quiet -- loadtest --smoke --seed 7)
if [ "$S1" != "$S2" ]; then
    echo "ci: loadtest smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$S1" | grep -q "goodput"; then
    echo "ci: loadtest smoke output missing goodput columns" >&2
    exit 1
fi
echo "ci: loadtest smoke OK"

# Multi-replica cluster gate: 2 replicas of the tiny model behind JSQ
# routing must report nonzero fleet goodput (the binary enforces that
# under --smoke) and be bit-identical across runs under a fixed seed.
echo "ci: cluster smoke"
C1=$(cargo run --release --quiet -- cluster --smoke --seed 7)
C2=$(cargo run --release --quiet -- cluster --smoke --seed 7)
if [ "$C1" != "$C2" ]; then
    echo "ci: cluster smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$C1" | grep -q "goodput"; then
    echo "ci: cluster smoke output missing goodput columns" >&2
    exit 1
fi
echo "ci: cluster smoke OK"

# Tiered overload gate: the smoke-overload scenario pinned to 2x the
# modeled saturation throughput.  The binary enforces that both victim
# policies lose zero requests, actually preempt, and hold interactive
# attainment >= 0.9 against a calibrated TTFT budget that the FIFO
# baseline (same tiers, no preemption) strictly misses; the diff below
# enforces bit-identical output across runs under a fixed seed
# (per-tier rows and preemption counters included).
echo "ci: overload smoke"
O1=$(cargo run --release --quiet -- overload --smoke --seed 7 --victim recompute,swap)
O2=$(cargo run --release --quiet -- overload --smoke --seed 7 --victim recompute,swap)
if [ "$O1" != "$O2" ]; then
    echo "ci: overload smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$O1" | grep -q "interactive"; then
    echo "ci: overload smoke output missing per-tier rows" >&2
    exit 1
fi
echo "ci: overload smoke OK"

# Telemetry gate: the smoke-overload scenario traced end-to-end.  The
# binary enforces in-process determinism (two runs must export
# byte-identical Chrome-trace JSON), nonzero NPU/PIM/bus busy time,
# a complete enqueue->retire chain, a firing flight recorder under an
# injected zero TTFT budget, and that a telemetry-off run produces an
# identical report while recording zero events (the zero-overhead
# guarantee); the diff below additionally enforces bit-identical
# stdout across two processes.
echo "ci: trace smoke"
T1=$(cargo run --release --quiet -- trace --smoke --seed 7)
T2=$(cargo run --release --quiet -- trace --smoke --seed 7)
if [ "$T1" != "$T2" ]; then
    echo "ci: trace smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$T1" | grep -q "overlap factor"; then
    echo "ci: trace smoke output missing the NPU/PIM overlap summary" >&2
    exit 1
fi
if ! printf '%s\n' "$T1" | grep -q "flight recorder: replica"; then
    echo "ci: trace smoke flight recorder never fired" >&2
    exit 1
fi
if ! printf '%s\n' "$T1" | grep -q "telemetry off: report identical, 0 events recorded"; then
    echo "ci: trace smoke did not prove the disabled-telemetry zero-event path" >&2
    exit 1
fi
echo "ci: trace smoke OK"

# Tiered-KV gate: the long-document scenario whose working set
# overflows the HBM hot tier (hot fraction 0.3) must complete every
# request with a nonzero prefetch hit rate and a strictly lower mean
# TPOT than the identical demand-paging run, and a 32k-context
# Mistral-7B pair must prove the same on a real model footprint; the
# binary enforces all of that under --smoke (plus an in-process
# double-run report equality check), and the diff below enforces
# bit-identical stdout across two processes under a fixed seed.
echo "ci: memtier smoke"
M1=$(cargo run --release --quiet -- memtier --smoke --seed 7)
M2=$(cargo run --release --quiet -- memtier --smoke --seed 7)
if [ "$M1" != "$M2" ]; then
    echo "ci: memtier smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$M1" | grep -q "prefetch hit rate"; then
    echo "ci: memtier smoke output missing the prefetch hit rate proof" >&2
    exit 1
fi
if ! printf '%s\n' "$M1" | grep -q "32k long-doc on Mistral-7B"; then
    echo "ci: memtier smoke skipped the 32k long-context proof" >&2
    exit 1
fi
if ! printf '%s\n' "$M1" | grep -q "< demand"; then
    echo "ci: memtier smoke did not prove prefetch beats demand paging" >&2
    exit 1
fi
echo "ci: memtier smoke OK"

# Interleaving gate: the decode-heavy smoke-interleave scenario A/Bd
# serial vs NPU||PIM sub-batch interleaved on identical seeds.  The
# binary enforces (in-process, both modes double-run for report
# equality) that the serial schedule charges zero interleaving, no
# requests are lost, and at batch 8 the interleaved run overlaps real
# steps with an overlap factor above 0.3, strictly higher goodput,
# and a strictly shorter makespan; the diff below enforces
# bit-identical stdout across two processes under a fixed seed.
echo "ci: interleave smoke"
I1=$(cargo run --release --quiet -- interleave --smoke --seed 7)
I2=$(cargo run --release --quiet -- interleave --smoke --seed 7)
if [ "$I1" != "$I2" ]; then
    echo "ci: interleave smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$I1" | grep -q "overlap factor"; then
    echo "ci: interleave smoke output missing the overlap factor proof" >&2
    exit 1
fi
if ! printf '%s\n' "$I1" | grep -q "> serial"; then
    echo "ci: interleave smoke did not prove interleaved goodput beats serial" >&2
    exit 1
fi
echo "ci: interleave smoke OK"

# Observability gate: the injected flash crowd run with the burn-rate
# alert engine scraping the live engine.  The binary enforces
# (in-process) that the interactive burn-rate alert fires strictly
# before the end-of-run report reflects the attainment dip and
# resolves after the crowd subsides, that a metrics-off run produces a
# byte-identical LoadReport while recording zero series points (the
# zero-cost guarantee), and that two instrumented runs export
# byte-identical Prometheus text and series JSON; the diff below
# additionally enforces bit-identical stdout across two processes.
echo "ci: monitor smoke"
N1=$(cargo run --release --quiet -- monitor --smoke --seed 7)
N2=$(cargo run --release --quiet -- monitor --smoke --seed 7)
if [ "$N1" != "$N2" ]; then
    echo "ci: monitor smoke is not deterministic under --seed 7" >&2
    exit 1
fi
if ! printf '%s\n' "$N1" | grep -q "interactive burn-rate alert fired"; then
    echo "ci: monitor smoke did not prove the alert led the report" >&2
    exit 1
fi
if ! printf '%s\n' "$N1" | grep -q "metrics off: report identical"; then
    echo "ci: monitor smoke did not prove the disabled-metrics zero-cost path" >&2
    exit 1
fi
echo "ci: monitor smoke OK"

# Every smoke gate above writes a BENCH_*.json sidecar through
# benchkit::save_bench_json so downstream tooling can diff runs
# without scraping tables; their absence means a smoke path silently
# stopped emitting.
echo "ci: bench sidecars"
REPORTS="${P3LLM_REPORTS:-reports}"
for b in loadtest_smoke cluster_smoke overload_smoke trace_smoke memtier_smoke interleave monitor; do
    if [ ! -f "$REPORTS/BENCH_$b.json" ]; then
        echo "ci: missing bench sidecar $REPORTS/BENCH_$b.json" >&2
        exit 1
    fi
done
echo "ci: bench sidecars OK"

# Trend gate: the sidecars the smokes just wrote must sit inside the
# tolerance bands committed in rust/benches/baselines.json (absolute
# floors for the interleave gates, presence-only for the simulated-
# clock metrics until wall-clock benches land).
echo "ci: trend"
TR=$(cargo run --release --quiet -- trend)
printf '%s\n' "$TR"
if ! printf '%s\n' "$TR" | grep -q "bands within tolerance"; then
    echo "ci: trend gate did not confirm the tolerance bands" >&2
    exit 1
fi
echo "ci: trend OK"
echo "ci: PASS"
