//! Integration tests for the multi-replica cluster layer: request
//! conservation under every routing policy (including KV-admission
//! bounce), whole-run seed determinism, weak-scaling goodput growth,
//! and the prefill/decode-disaggregated handoff's client-visible
//! accounting.

use p3llm::cluster::{all_policy_names, Cluster};
use p3llm::testutil::Runner;
use p3llm::traffic::{scenario_by_name, ArrivalProcess, RequestMix, Scenario, SloSpec};

/// A small bursty tiny-model scenario whose KV pool overcommits, so
/// routing interacts with admission control (bounce + requeue).
fn bursty_tiny(n_requests: usize, kv_slots: usize) -> Scenario {
    Scenario {
        name: "cluster-test",
        desc: "bursty tiny scenario for cluster property tests",
        model: "tiny-1M",
        arrival: ArrivalProcess::OnOff {
            burst_n: 6,
            burst_gap_ms: 0.1,
            idle_ms: 30.0,
        },
        mix: RequestMix::tiny(),
        slo: SloSpec::relaxed(),
        n_requests,
        max_batch: 4,
        ctx_limit: 128,
        kv_slots,
        prefix_cache: true,
        tiers: None,
        victim: None,
        interleave: false,
    }
}

/// Satellite: request conservation.  For every policy, arrivals ==
/// completed + still-queued (zero after a full run) across all
/// replicas -- no request lost or duplicated by routing, including
/// when bursts overcommit the KV pool and requests bounce.
#[test]
fn every_policy_conserves_requests_under_bounce() {
    for policy in all_policy_names() {
        Runner::new(6).run(|r| {
            let replicas = r.usize(1, 5); // 1..=4
            let n = r.usize(8, 25); // 8..=24 requests
            // 2..=3 KV slots vs batch 4: bursts bounce
            let sc = bursty_tiny(n, r.usize(2, 4));
            let mut fleet =
                Cluster::from_scenario(&sc, "P3-LLM", None, replicas, policy)
                    .unwrap();
            let plan = sc.runner(r.next_u64());
            let out = fleet.run(&plan, None).unwrap();
            // fleet view: every arrival accounted for exactly once
            assert_eq!(out.run.records.len(), n, "{policy}");
            assert_eq!(out.run.report.offered, n, "{policy}");
            assert_eq!(
                out.run.report.completed, n,
                "{policy} x{replicas} lost requests"
            );
            assert!(
                out.run.records.iter().all(|rec| rec.finished()),
                "{policy}"
            );
            // per-replica partition sums back to the offered count
            let per: usize = out
                .report
                .per_replica
                .iter()
                .map(|p| p.report.completed)
                .sum();
            assert_eq!(per, n, "{policy} partition double-counts");
            // every reservation released everywhere
            for i in 0..fleet.replicas() {
                assert_eq!(fleet.replica(i).kv_entries(), 0, "{policy}");
                assert_eq!(fleet.replica(i).pool_used_bytes(), 0, "{policy}");
            }
        });
    }
}

/// Whole cluster runs are bit-identical under a seed, and the seed
/// steers the timeline.
#[test]
fn cluster_runs_are_bit_identical_under_a_seed() {
    let sc = scenario_by_name("smoke").unwrap();
    let run = |seed: u64| {
        let mut fleet =
            Cluster::from_scenario(&sc, "P3-LLM", None, 3, "jsq").unwrap();
        let plan = sc.clone().for_fleet(3).unwrap().runner(seed);
        fleet.run(&plan, sc.saturation_tok_s("P3-LLM")).unwrap()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a.run.records, b.run.records);
    assert_eq!(a.run.report, b.run.report);
    assert_eq!(a.report.fleet, b.report.fleet);
    assert_eq!(a.report.util_skew, b.report.util_skew);
    let c = run(8);
    assert_ne!(a.run.records, c.run.records, "seed must steer routing");
}

/// Weak scaling: 4 JSQ replicas offered 4x the load deliver well over
/// 2.5x the 1-replica goodput (the bench asserts the same floor on
/// the full chat-poisson scenario in release mode).
#[test]
fn jsq_goodput_scales_with_replicas() {
    let mut sc = scenario_by_name("smoke").unwrap();
    sc.n_requests = 24;
    let run = |n: usize| {
        let mut fleet =
            Cluster::from_scenario(&sc, "P3-LLM", None, n, "jsq").unwrap();
        let plan = sc.clone().for_fleet(n).unwrap().runner(7);
        fleet
            .run(&plan, sc.saturation_tok_s("P3-LLM"))
            .unwrap()
            .report
    };
    let r1 = run(1);
    let r4 = run(4);
    let (g1, g4) = (r1.fleet.goodput_tok_s, r4.fleet.goodput_tok_s);
    assert!(g1 > 0.0);
    assert!(
        g4 >= 2.5 * g1,
        "fleet goodput flat: {g1} tok/s at 1 replica, {g4} at 4"
    );
    // adaptive routing keeps the fleet reasonably balanced
    let skew = r4.util_skew;
    assert!(skew < 3.0, "skew {skew}");
    let eff = r4.with_baseline(g1).scaling_efficiency.unwrap();
    assert!(eff > 0.6 && eff <= 1.5, "efficiency {eff}");
}

/// Disaggregated routing: prompts prefill on the prefill pool, decode
/// continuations land on the decode pool, and the client-visible
/// token/latency accounting stays exact across the handoff.
#[test]
fn prefill_decode_handoff_accounts_exactly() {
    let sc = scenario_by_name("smoke").unwrap();
    let mut fleet =
        Cluster::from_scenario(&sc, "P3-LLM", None, 4, "pd").unwrap();
    let plan = sc.clone().for_fleet(4).unwrap().runner(11);
    let out = fleet.run(&plan, None).unwrap();
    assert_eq!(out.run.report.completed, out.run.report.offered);
    for rec in &out.run.records {
        assert!(rec.finished());
        // first token from the prefill side never after completion
        let first = rec.first_token_ms.unwrap();
        let fin = rec.finished_ms.unwrap();
        assert!(first <= fin + 1e-9, "{rec:?}");
        assert!(rec.ttft_ms().unwrap() >= 0.0);
        assert!(rec.tokens_generated >= 1);
    }
    // smoke's tiny mix draws >= 2 output tokens, so every request
    // splits: the prefill replica (index 0 of a 4-fleet) completes one
    // stub per request, and every continuation finishes on the decode
    // pool (replicas 1..4)
    let offered = out.run.report.offered;
    let pre = fleet.replica_metrics(0);
    assert_eq!(pre.completed, offered, "prefill stubs");
    let decode_completed: usize =
        (1..4).map(|i| fleet.replica_metrics(i).completed).sum();
    assert_eq!(decode_completed, offered, "handoffs lost");
    // prefill replica did real prefill work
    assert!(pre.prefill_ms > 0.0);
}

/// Prefix-affinity routing keeps shared-prefix caches replica-local:
/// on a prefix-bearing scenario every popular system prompt cold-
/// misses once per *fleet* under `pa`, but once per *replica* under
/// round-robin, so `pa` ends with a strictly higher fleet hit rate.
#[test]
fn prefix_affinity_keeps_caches_replica_local() {
    let mut sc = scenario_by_name("smoke-prefix").unwrap();
    sc.n_requests = 24;
    let run = |policy: &str| {
        let mut fleet =
            Cluster::from_scenario(&sc, "P3-LLM", None, 4, policy).unwrap();
        let plan = sc.clone().for_fleet(4).unwrap().runner(7);
        fleet.run(&plan, None).unwrap().report.fleet.clone()
    };
    let pa = run("pa");
    let rr = run("rr");
    assert_eq!(pa.completed, pa.offered);
    assert!(pa.prefix_hit_rate > 0.0, "{:?}", pa.prefix_hits);
    assert!(
        pa.prefix_hit_rate > rr.prefix_hit_rate,
        "pa hit rate {:.3} !> rr hit rate {:.3}",
        pa.prefix_hit_rate,
        rr.prefix_hit_rate
    );
    assert!(pa.prefill_tokens_saved > rr.prefill_tokens_saved);
}

/// The fleet-merged report stays consistent with the exact
/// record-level fleet report (counts identical, rates close).
#[test]
fn merged_report_matches_exact_fleet_view() {
    let sc = scenario_by_name("smoke").unwrap();
    let mut fleet =
        Cluster::from_scenario(&sc, "P3-LLM", None, 2, "rr").unwrap();
    let plan = sc.clone().for_fleet(2).unwrap().runner(5);
    let out = fleet.run(&plan, sc.saturation_tok_s("P3-LLM")).unwrap();
    let exact = &out.run.report;
    let merged = &out.report.fleet;
    assert_eq!(exact.offered, merged.offered);
    assert_eq!(exact.completed, merged.completed);
    assert_eq!(exact.slo_met, merged.slo_met);
    // same token mass over (possibly) slightly different spans
    let exact_tokens = exact.throughput_tok_s * exact.makespan_ms;
    let merged_tokens = merged.throughput_tok_s * merged.makespan_ms;
    assert!(
        (exact_tokens - merged_tokens).abs() <= 1e-6 * exact_tokens.max(1.0),
        "{exact_tokens} vs {merged_tokens}"
    );
    // the fleet views agree on the aggregate decode-busy rate
    assert!((exact.busy_tok_s - merged.busy_tok_s).abs() < 1e-9);
    // a cluster is single-use: a second run is a typed error, not a
    // silently corrupt report
    assert!(fleet.run(&plan, None).is_err());
}
