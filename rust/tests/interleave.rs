//! Property + integration suite for NPU||PIM sub-batch interleaving:
//! token/work conservation across seeds, scenarios, and victim
//! policies; interleaved makespan never exceeds the serial schedule's;
//! two-run byte determinism in both modes; the serial mode's golden
//! guarantee (an `interleave(false)` engine is bit-identical to a
//! default-built one -- the pre-interleave code path); per-sub-batch
//! demand-stall isolation under the tiered KV hierarchy; the PJRT
//! builder rejection; and the traced end-to-end overlap factor the
//! telemetry summary derives from the device timelines.

use p3llm::coordinator::{EngineBuilder, Metrics};
use p3llm::telemetry::{summary, Trace, TraceLane};
use p3llm::testutil::{Rng, Runner};
use p3llm::traffic::{scenario_by_name, LoadReport, Scenario};

const SYSTEM: &str = "P3-LLM";

/// Run one scenario mode and return its report plus the per-request
/// `(prompt_len, tokens_generated, cached_prefix_tokens)` ledger in
/// arrival order -- the conservation observables whose values must not
/// depend on how a step's lanes were grouped into sub-batches.
fn run_mode(
    sc: &Scenario,
    interleave: bool,
    seed: u64,
) -> (LoadReport, Vec<(usize, usize, usize)>) {
    let mut sc = sc.clone();
    sc.interleave = interleave;
    let mut eng = sc.engine(SYSTEM, None).expect("engine build");
    assert_eq!(eng.interleave_enabled(), interleave);
    let out = sc
        .runner(seed)
        .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
        .expect("closed-loop run");
    let ledger = out
        .records
        .iter()
        .map(|r| (r.prompt_len, r.tokens_generated, r.cached_prefix_tokens))
        .collect();
    (out.report, ledger)
}

/// Satellite: conservation for any seed x scenario x victim policy.
/// Both modes retire every offered request and generate the same
/// per-request token counts, and the interleaved makespan never
/// exceeds the serial one -- the fused fallback caps every step at
/// its serial charge.  Preemption decisions are clock-driven, so once
/// a run actually preempts, the two modes may evict different victims
/// (recompute then re-prefills different pages); the per-request
/// output ledger must survive that, but the prefix-hit accounting and
/// the makespan bound are only comparable while both schedules stayed
/// preemption-free.
#[test]
fn interleaving_conserves_work_for_any_seed_scenario_and_victim() {
    for name in
        ["smoke-interleave", "smoke", "smoke-prefix", "smoke-overload"]
    {
        for victim in [None, Some("recompute"), Some("swap")] {
            Runner::new(3).run(|r: &mut Rng| {
                let seed = r.next_u64() % 10_000;
                let mut sc =
                    scenario_by_name(name).expect("registry scenario");
                sc.victim = victim;
                let (serial, led_s) = run_mode(&sc, false, seed);
                let (ilv, led_i) = run_mode(&sc, true, seed);
                for (tag, rep) in
                    [("serial", &serial), ("interleaved", &ilv)]
                {
                    assert_eq!(
                        rep.completed, rep.offered,
                        "{name}/{victim:?}/{seed}: {tag} lost requests"
                    );
                }
                // grouping lanes into sub-batches must not change what
                // any request computed -- only when it computed it
                let outputs = |l: &[(usize, usize, usize)]| {
                    l.iter().map(|&(p, t, _)| (p, t)).collect::<Vec<_>>()
                };
                assert_eq!(
                    outputs(&led_s),
                    outputs(&led_i),
                    "{name}/{victim:?}/{seed}: per-request output \
                     ledger diverged between modes"
                );
                if serial.preemptions == 0 && ilv.preemptions == 0 {
                    assert_eq!(
                        led_s, led_i,
                        "{name}/{victim:?}/{seed}: prefix-hit \
                         accounting diverged between modes"
                    );
                    assert!(
                        ilv.makespan_ms <= serial.makespan_ms + 1e-9,
                        "{name}/{victim:?}/{seed}: interleaved \
                         makespan {:.6} ms exceeds serial {:.6} ms",
                        ilv.makespan_ms,
                        serial.makespan_ms
                    );
                }
                // the serial schedule never charges interleaving
                assert_eq!(serial.interleaved_steps, 0);
                assert_eq!(serial.fused_steps, 0);
                assert_eq!(serial.overlap_ms, 0.0);
                assert_eq!(serial.serial_saved_ms, 0.0);
            });
        }
    }
}

/// Satellite: two-run byte determinism in both modes -- the whole
/// report (Debug-rendered, every float bit included) must agree.
#[test]
fn both_modes_are_byte_deterministic_across_runs() {
    let sc = scenario_by_name("smoke-interleave").expect("scenario");
    for interleave in [false, true] {
        let (a, la) = run_mode(&sc, interleave, 7);
        let (b, lb) = run_mode(&sc, interleave, 7);
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "interleave={interleave}: two identical runs disagreed"
        );
        assert_eq!(la, lb);
    }
}

/// Drive an engine through a fixed 8-lane decode-heavy workload and
/// return its metrics plus every request's generated token stream.
fn drive(mut eng: p3llm::coordinator::Engine) -> (Metrics, Vec<Vec<i32>>) {
    let mut ids = vec![];
    for i in 0..8 {
        let mut rng = Rng::new(0x1eaf ^ i as u64);
        let toks: Vec<i32> =
            (0..100).map(|_| rng.usize(0, 251) as i32).collect();
        ids.push(eng.submit(toks, 24).expect("submit"));
    }
    let m = eng.run_to_completion().expect("run");
    let streams = ids
        .into_iter()
        .map(|id| eng.take_tokens(id).expect("tokens"))
        .collect();
    (m, streams)
}

fn sim_engine() -> EngineBuilder {
    EngineBuilder::sim()
        .model("tiny-1M")
        .system(SYSTEM)
        .max_batch(8)
        .ctx_limit(128)
}

/// Golden diff: `interleave(false)` is the pre-interleave code path.
/// An engine with the knob spelled out must match a default-built one
/// bit for bit -- metrics (every timing float included) and token
/// streams -- and the interleaved engine must produce the same tokens
/// while finishing strictly earlier on this decode-heavy workload.
#[test]
fn interleave_off_is_bit_identical_and_on_conserves_tokens() {
    let (m_default, t_default) = drive(sim_engine().build().unwrap());
    let (m_off, t_off) =
        drive(sim_engine().interleave(false).build().unwrap());
    assert_eq!(format!("{m_default:?}"), format!("{m_off:?}"));
    assert_eq!(t_default, t_off);

    let (m_on, t_on) =
        drive(sim_engine().interleave(true).build().unwrap());
    // same tokens, earlier clock: the split changes scheduling only
    assert_eq!(t_default, t_on);
    assert_eq!(m_on.completed, m_default.completed);
    assert_eq!(m_on.tokens_out, m_default.tokens_out);
    assert!(
        m_on.wall_ms < m_default.wall_ms,
        "interleaved wall {:.6} ms not below serial {:.6} ms",
        m_on.wall_ms,
        m_default.wall_ms
    );
    assert!(m_on.interleaved_steps > 0);
    assert!(
        m_on.overlap_factor() > 0.3,
        "overlap factor {:.3} <= 0.3",
        m_on.overlap_factor()
    );
    assert!(m_on.serial_saved_ms > 0.0);
    // serial engines report zeroed interleave counters
    assert_eq!(m_default.interleaved_steps, 0);
    assert_eq!(m_default.fused_steps, 0);
    assert_eq!(m_default.overlap_factor(), 0.0);
}

/// Satellite regression: under the tiered KV hierarchy, a demand-miss
/// stall charged to one sub-batch must not push the whole step to the
/// serial stall total -- the tiered interleaved run still completes
/// everything and never finishes later than tiered serial.
#[test]
fn tiered_demand_stalls_do_not_regress_the_interleaved_run() {
    let sc = scenario_by_name("smoke-longdoc").expect("scenario");
    let run_tiered = |interleave: bool| -> LoadReport {
        let mut sc = sc.clone();
        sc.interleave = interleave;
        // depth 0 = pure demand paging: every cold page stalls
        let mut eng =
            sc.engine_tiered(SYSTEM, None, 0.3, 0).expect("engine");
        sc.runner(7)
            .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
            .expect("run")
            .report
    };
    let serial = run_tiered(false);
    let ilv = run_tiered(true);
    assert!(
        serial.pages_demand > 0,
        "hot tier never overflowed; the stall path was not exercised"
    );
    for (tag, r) in [("serial", &serial), ("interleaved", &ilv)] {
        assert_eq!(
            r.completed, r.offered,
            "tiered {tag} run lost requests"
        );
    }
    assert!(
        ilv.makespan_ms <= serial.makespan_ms + 1e-9,
        "per-sub-batch stalls regressed the step: interleaved \
         {:.6} ms vs serial {:.6} ms",
        ilv.makespan_ms,
        serial.makespan_ms
    );
}

/// The PJRT backend has one wall clock, not two device timelines: the
/// builder must reject the knob instead of silently ignoring it.
#[test]
fn pjrt_builder_rejects_interleaving() {
    let err = EngineBuilder::pjrt("artifacts")
        .interleave(true)
        .build()
        .unwrap_err();
    assert!(
        format!("{err}").contains("sim-backend"),
        "unexpected error: {err}"
    );
}

/// Satellite e2e: the traced device timelines agree with the metrics.
/// A traced interleaved run's NPU||PIM overlap factor (derived by
/// `telemetry::summary` from the actual span intervals) clears the
/// 0.3 gate, while the serial schedule's stays ~0.
#[test]
fn traced_overlap_factor_clears_the_gate_only_when_interleaved() {
    let sc = scenario_by_name("smoke-interleave").expect("scenario");
    let traced_factor = |interleave: bool| -> f64 {
        let mut sc = sc.clone();
        sc.interleave = interleave;
        let mut eng = sc.engine(SYSTEM, None).expect("engine");
        let trace = Trace::ring(1 << 18);
        eng.set_trace(trace.clone());
        sc.runner(7)
            .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
            .expect("run");
        assert_eq!(trace.dropped(), 0, "ring too small");
        let util = summary::utilization(&trace.snapshot());
        assert!(
            util.busy_ms(0, TraceLane::Npu) > 0.0
                && util.busy_ms(0, TraceLane::Pim) > 0.0,
            "trace missing device busy time"
        );
        util.overlap[0].factor
    };
    let serial = traced_factor(false);
    let ilv = traced_factor(true);
    assert!(
        serial < 0.05,
        "serial schedule shows overlap factor {serial:.3}"
    );
    assert!(ilv > 0.3, "interleaved overlap factor {ilv:.3} <= 0.3");
}
