//! Public-API integration tests for the unified serving engine: the
//! `EngineBuilder` -> `ExecBackend` -> `Engine` path on the sim
//! backend (no artifacts required), typed-error behavior, KV-pool
//! admission control under capacity pressure, and request
//! streaming/polling.  PJRT-specific behavior is covered by
//! tests/integration.rs (which needs `make artifacts`).

use p3llm::coordinator::State;
use p3llm::{EngineBuilder, P3Error};

/// Acceptance path: batch 64 through the full request lifecycle
/// (submit -> prefill -> decode -> retire) on the same engine +
/// batcher + pool code as the PJRT path, no artifacts involved.
#[test]
fn sim_serves_batch_64_full_lifecycle() {
    let mut eng = EngineBuilder::sim()
        .model("tiny-1M")
        .scheme("p3llm")
        .max_batch(64)
        .ctx_limit(128)
        .build()
        .unwrap();
    let n = 80usize;
    let max_new = 12usize;
    let mut ids = vec![];
    for i in 0..n {
        let prompt: Vec<i32> = (0..8).map(|t| ((i * 7 + t) % 256) as i32).collect();
        ids.push(eng.submit(prompt, max_new).unwrap());
    }
    let m = eng.run_to_completion().unwrap();
    assert_eq!(m.backend, "sim");
    assert_eq!(m.completed, n);
    assert_eq!(m.tokens_out, n * (max_new - 1));
    assert_eq!(m.ttft_ms.count, n);
    assert!(m.ttft_ms.p50 > 0.0);
    assert!(m.ttft_ms.p50 <= m.ttft_ms.p95 && m.ttft_ms.p95 <= m.ttft_ms.p99);
    assert!(m.per_token_ms.count == n && m.per_token_ms.p99 > 0.0);
    // simulated time advanced; decode accounted under decode_ms
    assert!(m.wall_ms > 0.0 && m.decode_ms > 0.0 && m.prefill_ms > 0.0);
    for id in ids {
        let st = eng.poll(id).unwrap();
        assert_eq!(st.state, State::Finished);
        assert_eq!(st.tokens_generated, max_new);
        assert!(st.ttft_ms.unwrap() > 0.0);
    }
    // every KV reservation released at retire
    assert_eq!(eng.kv_entries(), 0);
    assert_eq!(eng.pool_used_bytes(), 0);
    // the sim backend exposes the online operator-mapping view
    let map = eng.mapping_summary().unwrap();
    assert!(map.npu_ops > 0);
}

/// Long-context / large-model serving-loop experiment: a 3B-class GQA
/// model at a 4k context cap -- far outside what PJRT-on-CPU reaches.
#[test]
fn sim_serves_large_model_long_ctx() {
    let mut eng = EngineBuilder::sim()
        .model("Llama-3.2-3B")
        .system("P3-LLM")
        .max_batch(4)
        .ctx_limit(4096)
        .kv_capacity(1 << 30)
        .build()
        .unwrap();
    for i in 0..4 {
        let prompt: Vec<i32> = (0..64).map(|t| (i * 97 + t) as i32).collect();
        eng.submit(prompt, 8).unwrap();
    }
    let m = eng.run_to_completion().unwrap();
    assert_eq!(m.completed, 4);
    assert!(m.wall_ms > 0.0);
    let map = eng.mapping_summary().unwrap();
    // P3 offloads work to the PIM at small batch
    assert!(map.pim_ops > 0 && map.pim_commands > 0);
}

/// Same config -> bit-identical tokens and identical simulated time.
#[test]
fn sim_runs_are_deterministic() {
    let run = || {
        let mut eng = EngineBuilder::sim()
            .max_batch(8)
            .ctx_limit(64)
            .build()
            .unwrap();
        let mut ids = vec![];
        for i in 0..10 {
            ids.push(eng.submit(vec![1 + i, 2, 3], 6).unwrap());
        }
        let m = eng.run_to_completion().unwrap();
        let toks: Vec<Vec<i32>> = ids
            .iter()
            .map(|&id| eng.request(id).unwrap().generated.clone())
            .collect();
        (m.wall_ms, toks)
    };
    let (w1, t1) = run();
    let (w2, t2) = run();
    assert_eq!(w1, w2);
    assert_eq!(t1, t2);
}

/// KV pages for only 2 of 5 requests: the engine bounces the rest
/// back to the queue head (admission control) instead of erroring, and
/// still completes everything as pages free.  Admission is
/// page-granular: each request here reserves ceil((3 prompt + 4 new) /
/// 16) = 1 page, so a 2-page pool holds exactly 2 concurrent requests
/// -- the old whole-request accounting would have reserved the full
/// 32-token context (2 pages) each and halved the batch depth.
#[test]
fn kv_exhaustion_mid_stream_is_admission_controlled() {
    let ctx = 32usize;
    // one page: 2 sides * 4 layers * 16 tokens * (32 kv_dim / 2) bytes
    let page_bytes = 2 * 4 * 16 * (32 / 2);
    let mut eng = EngineBuilder::sim()
        .model("tiny-1M")
        .max_batch(4)
        .ctx_limit(ctx)
        .kv_capacity(2 * page_bytes)
        .build()
        .unwrap();
    let mut ids = vec![];
    for i in 0..5 {
        ids.push(eng.submit(vec![5 + i, 6, 7], 4).unwrap());
    }
    let mut max_live = 0usize;
    let mut guard = 0;
    loop {
        let emitted = eng.step().unwrap();
        max_live = max_live.max(eng.kv_entries());
        assert!(eng.kv_entries() <= 2, "pool over-admitted");
        guard += 1;
        assert!(guard < 1000, "did not converge");
        if emitted == 0 && eng.kv_entries() == 0 {
            break;
        }
    }
    assert_eq!(max_live, 2);
    let m = eng.metrics();
    assert_eq!(m.completed, 5);
    // FIFO order preserved across bounces: earlier submissions never
    // finish after later ones (uniform-length requests)
    let finish: Vec<f64> = ids
        .iter()
        .map(|&id| eng.request(id).unwrap().finished_ms.unwrap())
        .collect();
    for w in finish.windows(2) {
        assert!(w[0] <= w[1], "out-of-order completion: {finish:?}");
    }
}

/// Capacity below a single request is a hard, typed, immediate error.
#[test]
fn kv_capacity_below_one_request_rejected_at_build() {
    let err = EngineBuilder::sim()
        .ctx_limit(64)
        .kv_capacity(64)
        .build()
        .unwrap_err();
    assert!(matches!(err, P3Error::InvalidConfig(_)), "{err}");
}

/// Token streaming + lifecycle polling while stepping manually.
#[test]
fn poll_and_streaming_drain() {
    let mut eng = EngineBuilder::sim()
        .max_batch(1)
        .ctx_limit(64)
        .build()
        .unwrap();
    let id = eng.submit(vec![9, 8, 7], 6).unwrap();
    assert_eq!(eng.poll(id).unwrap().state, State::Queued);
    assert!(eng.take_tokens(id).unwrap().is_empty());

    let mut streamed = vec![];
    while !eng.poll(id).unwrap().finished {
        eng.step().unwrap();
        let chunk = eng.take_tokens(id).unwrap();
        // continuous decode emits at least one token per step here
        streamed.extend(chunk);
    }
    assert_eq!(streamed.len(), 6);
    assert_eq!(streamed, eng.request(id).unwrap().generated);
    // drained: nothing left
    assert!(eng.take_tokens(id).unwrap().is_empty());
    // unknown ids are typed errors
    let ghost = p3llm::RequestId(999);
    assert!(matches!(eng.poll(ghost), Err(P3Error::UnknownRequest(999))));
    assert!(matches!(
        eng.take_tokens(ghost),
        Err(P3Error::UnknownRequest(999))
    ));
}

/// Prompt validation is engine-level and typed on every backend.
#[test]
fn prompt_validation_typed_errors() {
    let mut eng = EngineBuilder::sim().ctx_limit(32).build().unwrap();
    assert!(matches!(eng.submit(vec![], 4), Err(P3Error::EmptyPrompt)));
    assert!(matches!(
        eng.submit(vec![0; 200], 4),
        Err(P3Error::PromptTooLong { len: 200, max: 31 })
    ));
    // rejected submissions leave the engine serviceable
    let id = eng.submit(vec![1, 2], 3).unwrap();
    eng.run_to_completion().unwrap();
    assert!(eng.poll(id).unwrap().finished);
}
