//! Rust <-> Python bit-exactness: every format in `p3llm::quant` must
//! reproduce the golden vectors emitted by `python -m compile.aot`
//! (artifacts/golden_quant.tsv) EXACTLY -- the two sides share the
//! serving path (python builds the graphs, Rust packs/unpacks KV and
//! weights), so any drift is a correctness bug.

use p3llm::quant::{
    bitmod_decode_group, bitmod_encode_group, fp8_e4m3, fp8_s0e4m4,
    quant_group_int4, smoothing_factors,
};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var("P3LLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if dir.join("golden_quant.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping golden tests: run `make artifacts` first");
        None
    }
}

fn parse_csv(s: &str) -> Vec<f32> {
    s.split(',').map(|v| v.parse().unwrap()).collect()
}

fn rows(kind: &str) -> Vec<(Vec<f32>, String)> {
    let Some(dir) = artifacts() else { return vec![] };
    let text = std::fs::read_to_string(dir.join("golden_quant.tsv")).unwrap();
    text.lines()
        .skip(1)
        .filter_map(|l| {
            let c: Vec<&str> = l.split('\t').collect();
            (c[0] == kind).then(|| (parse_csv(c[1]), c[2].to_string()))
        })
        .collect()
}

#[test]
fn golden_e4m3_exact() {
    for (input, out) in rows("e4m3") {
        let want = parse_csv(&out);
        for (x, w) in input.iter().zip(&want) {
            assert_eq!(fp8_e4m3(*x), *w, "e4m3({x})");
        }
    }
}

#[test]
fn golden_s0e4m4_exact() {
    for (input, out) in rows("s0e4m4") {
        let want = parse_csv(&out);
        for (x, w) in input.iter().zip(&want) {
            assert_eq!(fp8_s0e4m4(*x), *w, "s0e4m4({x})");
        }
    }
}

#[test]
fn golden_int4_asym_exact() {
    for (input, out) in rows("int4asym") {
        let want = parse_csv(&out);
        let g = quant_group_int4(&input);
        let mut got = vec![0.0f32; input.len()];
        p3llm::quant::dequant_group_int4(&g, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
                "int4: {a} vs {b}"
            );
        }
    }
}

#[test]
fn golden_bitmod_exact() {
    let cases = rows("bitmod");
    assert!(!cases.is_empty() || artifacts().is_none());
    for (input, out) in cases {
        let parts: Vec<&str> = out.split('|').collect();
        let want_codes: Vec<f32> = parse_csv(parts[0]);
        let want_scale: f32 = parts[1].parse().unwrap();
        let want_special: u8 = parts[2].parse().unwrap();
        let want_deq = parse_csv(parts[3]);
        let g = bitmod_encode_group(&input);
        assert_eq!(g.special, want_special);
        assert!((g.scale - want_scale).abs() <= 1e-6 * want_scale.abs());
        for (i, c) in g.codes.iter().enumerate() {
            assert_eq!(*c as f32, want_codes[i], "code {i}");
        }
        let mut deq = vec![0.0f32; input.len()];
        bitmod_decode_group(&g, &mut deq);
        for (a, b) in deq.iter().zip(&want_deq) {
            assert!((a - b).abs() <= 1e-6 * (1.0 + b.abs()));
        }
    }
}

#[test]
fn golden_smoothing_exact() {
    for (input, out) in rows("smooth") {
        let want = parse_csv(&out);
        let channels = want.len();
        let got = smoothing_factors(&input, channels);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b, "smoothing factor");
        }
    }
}
