//! Integration and property tests for the telemetry layer through the
//! public API: well-formed per-request event histories across every
//! victim policy under bursty 2x-saturation load, byte-identical
//! Chrome-trace exports under a seed, per-replica tagging on a shared
//! cluster sink, ring truncation behavior, and the zero-event
//! guarantee with telemetry disabled.

use std::collections::{BTreeMap, BTreeSet};

use p3llm::cluster::Cluster;
use p3llm::telemetry::{export, EventKind, Trace, TraceEvent};
use p3llm::traffic::{scenario_by_name, Scenario};

const SYSTEM: &str = "P3-LLM";
const EPS: f64 = 1e-9;

/// The CI overload scenario pinned to 2x modeled saturation with the
/// victim policy overridden (None = FIFO baseline, no preemption) --
/// the same shape the sched tests and the overload bench use, so the
/// trace covers enqueue/bounce/admit/preempt/restore/retire churn.
fn overloaded(victim: Option<&'static str>, seed: u64) -> Scenario {
    let mut sc = scenario_by_name("smoke-overload")
        .unwrap()
        .with_load_factor(SYSTEM, 2.0, seed)
        .unwrap();
    sc.victim = victim;
    sc
}

/// Run a scenario on a single traced engine and return the recorded
/// events (asserting the ring never overflowed, so the history is
/// complete).
fn traced_run(sc: &Scenario, seed: u64, trace: &Trace) -> Vec<TraceEvent> {
    let mut eng = sc.engine(SYSTEM, None).unwrap();
    eng.set_trace(trace.clone());
    sc.runner(seed)
        .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
        .unwrap();
    assert_eq!(trace.dropped(), 0, "ring too small for a complete history");
    trace.snapshot()
}

/// The well-formedness property over every request history in an
/// event stream; returns the total preemption count so callers can
/// assert the pairing check was not vacuous.
///
/// Per `(replica, rid)`:
/// * the first event (by emission order) is `enqueue`, and no event
///   predates it on the engine clock;
/// * there is exactly one terminal (`retire` or `error`), it is the
///   last event, and nothing (spans included) extends past it;
/// * every `prefill_tile` span nests inside a covering
///   prefill-family span;
/// * every preemption instant is paired with a recovery prefill
///   (`restore` for swap victims, `recompute` for recompute victims).
fn check_request_histories(events: &[TraceEvent]) -> usize {
    let mut by_req: BTreeMap<(u32, u64), Vec<&TraceEvent>> = BTreeMap::new();
    for e in events {
        if let Some(rid) = e.rid {
            by_req.entry((e.replica, rid)).or_default().push(e);
        }
    }
    assert!(!by_req.is_empty(), "run recorded no request events");
    let mut total_preempts = 0usize;
    for ((rep, rid), evs) in &by_req {
        let mut evs = evs.clone();
        evs.sort_by_key(|e| e.seq);
        let first = evs.first().unwrap();
        assert_eq!(
            first.name, "enqueue",
            "({rep},{rid}): history starts with {}",
            first.name
        );
        let terminals = evs
            .iter()
            .filter(|e| e.name == "retire" || e.name == "error")
            .count();
        assert_eq!(terminals, 1, "({rep},{rid}): {terminals} terminals");
        let last = evs.last().unwrap();
        assert!(
            last.name == "retire" || last.name == "error",
            "({rep},{rid}): history continues after terminal ({})",
            last.name
        );
        let (t_start, t_end) = (first.ts_ms, last.ts_ms);
        for e in &evs {
            assert!(
                e.ts_ms >= t_start - EPS && e.ts_ms <= t_end + EPS,
                "({rep},{rid}): {} at {} outside [{t_start}, {t_end}]",
                e.name,
                e.ts_ms
            );
            if e.kind == EventKind::Span {
                assert!(e.dur_ms >= 0.0, "({rep},{rid}): negative span");
                assert!(
                    e.ts_ms + e.dur_ms <= t_end + EPS,
                    "({rep},{rid}): {} span ends after terminal",
                    e.name
                );
            }
        }
        let covers: Vec<(f64, f64)> = evs
            .iter()
            .filter(|e| {
                matches!(
                    e.name,
                    "prefill" | "recompute" | "restore" | "kv_install"
                )
            })
            .map(|e| (e.ts_ms, e.ts_ms + e.dur_ms))
            .collect();
        for tile in evs.iter().filter(|e| e.name == "prefill_tile") {
            assert!(
                covers.iter().any(|&(a, b)| {
                    tile.ts_ms >= a - EPS && tile.ts_ms + tile.dur_ms <= b + EPS
                }),
                "({rep},{rid}): prefill_tile at {} nests in no prefill span",
                tile.ts_ms
            );
        }
        let preempts = evs
            .iter()
            .filter(|e| e.name.starts_with("preempt:"))
            .count();
        let recoveries = evs
            .iter()
            .filter(|e| e.name == "recompute" || e.name == "restore")
            .count();
        assert_eq!(
            preempts, recoveries,
            "({rep},{rid}): {preempts} preemptions vs {recoveries} \
             recovery prefills"
        );
        total_preempts += preempts;
    }
    total_preempts
}

/// Property test: every victim policy (and the FIFO baseline), several
/// seeds, bursty 2x-saturation load -- all request histories stay
/// well-formed, and the preempt/recovery pairing check is exercised
/// for real on the pinned CI seed.
#[test]
fn event_histories_are_well_formed_across_victim_policies() {
    for victim in [None, Some("recompute"), Some("swap")] {
        for seed in [7u64, 11, 23] {
            let sc = overloaded(victim, seed);
            let trace = Trace::ring(1 << 20);
            let events = traced_run(&sc, seed, &trace);
            let preempts = check_request_histories(&events);
            if victim.is_none() {
                assert_eq!(preempts, 0, "FIFO baseline preempted");
            } else if seed == 7 {
                // the sched tests pin this seed as guaranteed to
                // preempt at 2x; without it the pairing check above
                // would be vacuous
                assert!(
                    preempts > 0,
                    "{victim:?}/seed {seed}: 2x overload never preempted"
                );
            }
        }
    }
}

/// Two identical seeded runs export byte-identical Chrome traces --
/// the determinism the `trace --smoke` CI gate relies on.
#[test]
fn exported_traces_are_byte_identical_under_a_seed() {
    let sc = overloaded(Some("swap"), 7);
    let export_once = || {
        let trace = Trace::ring(1 << 20);
        let events = traced_run(&sc, 7, &trace);
        let sampled = export::sample_requests(&events, 4);
        export::chrome_trace_json(&events, &sampled)
    };
    let a = export_once();
    let b = export_once();
    assert_eq!(a, b, "same-seed exports differ byte-wise");
    assert!(a.contains("\"traceEvents\""));
    assert!(a.contains("\"prefill\""));
}

/// A 2-replica cluster sharing one sink tags every event with its
/// replica, both replicas land events, and the merged stream still
/// passes the per-request well-formedness property (request ids are
/// per-replica counters; `(replica, rid)` is the cross-replica key).
#[test]
fn cluster_sink_tags_replicas_and_stays_well_formed() {
    let sc = scenario_by_name("smoke").unwrap();
    let trace = Trace::ring(1 << 20);
    let mut fleet =
        Cluster::from_scenario_traced(&sc, SYSTEM, None, 2, "jsq", &trace)
            .unwrap();
    let plan = sc.clone().for_fleet(2).unwrap().runner(7);
    fleet.run(&plan, sc.saturation_tok_s(SYSTEM)).unwrap();
    assert_eq!(trace.dropped(), 0);
    let events = trace.snapshot();
    let replicas: BTreeSet<u32> = events.iter().map(|e| e.replica).collect();
    assert_eq!(
        replicas.into_iter().collect::<Vec<_>>(),
        vec![0, 1],
        "JSQ over 2 replicas must land events on both"
    );
    check_request_histories(&events);
}

/// A deliberately tiny ring drops the oldest events but keeps an
/// unbroken, in-order tail ending at the last emission -- exactly the
/// retention the flight recorder needs on long runs.
#[test]
fn bounded_ring_keeps_only_the_newest_tail() {
    let sc = overloaded(None, 7);
    let trace = Trace::ring(64);
    let mut eng = sc.engine(SYSTEM, None).unwrap();
    eng.set_trace(trace.clone());
    sc.runner(7)
        .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
        .unwrap();
    let events = trace.snapshot();
    assert_eq!(events.len(), 64);
    assert!(trace.dropped() > 0, "overload run fit in 64 events?");
    assert!(events.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
    let last = events.last().unwrap();
    assert_eq!(
        last.seq as usize,
        64 + trace.dropped() - 1,
        "tail must end at the newest event"
    );
}

/// With telemetry disabled nothing is recorded and nothing is
/// allocated per event -- the zero-overhead default path.
#[test]
fn disabled_trace_records_nothing() {
    let sc = overloaded(Some("recompute"), 7);
    let trace = Trace::off();
    let mut eng = sc.engine(SYSTEM, None).unwrap();
    eng.set_trace(trace.clone());
    let out = sc
        .runner(7)
        .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
        .unwrap();
    assert!(out.report.completed > 0);
    assert!(!trace.enabled());
    assert!(trace.snapshot().is_empty());
    assert_eq!(trace.dropped(), 0);
}
