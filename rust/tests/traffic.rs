//! Integration tests for the traffic layer: closed-loop load
//! generation over the public engine API -- scenario registry,
//! seed-determinism of whole runs, KV admission under bursty
//! overcommit (FIFO preserved through requeue), and the P3-vs-NPU
//! serving comparison the old open-loop scheduler used to assert.

use p3llm::coordinator::{EngineBuilder, KvLayout};
use p3llm::testutil::Runner;
use p3llm::traffic::{
    all_scenarios, scenario_by_name, ArrivalProcess, LoadRunner,
    RequestMix, SloSpec,
};

#[test]
fn registry_exposes_at_least_four_named_scenarios() {
    let named: Vec<_> = all_scenarios()
        .into_iter()
        .filter(|s| s.name != "smoke")
        .collect();
    assert!(named.len() >= 4, "only {} scenarios", named.len());
    for want in
        ["chat-poisson", "chat-burst", "summarize-steady", "code-complete"]
    {
        assert!(
            scenario_by_name(want).is_some(),
            "missing scenario {want}"
        );
    }
}

/// Whole-run determinism through the public path `loadtest` uses:
/// same scenario + system + seed => identical reports and records.
#[test]
fn scenario_runs_are_bit_identical_under_a_seed() {
    let sc = scenario_by_name("smoke").unwrap();
    let run = |seed| {
        let mut eng = sc.engine("P3-LLM", None).unwrap();
        sc.runner(seed).run(&mut eng).unwrap()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a.report, b.report);
    assert_eq!(a.records, b.records);
    let c = run(8);
    assert_ne!(a.records, c.records, "seed must steer the timeline");
}

/// Satellite: a burst that overcommits the KV pool must preserve FIFO
/// order through the requeue path and eventually complete everything.
#[test]
fn bursty_overcommit_preserves_fifo_and_completes() {
    // per-request packed reservation for tiny-1M at ctx 32
    let ctx = 32usize;
    let per_request = KvLayout {
        layers: 4,
        kv_dim: 32,
        head_dim: 16,
        max_ctx: ctx,
    }
    .bytes_per_request();
    Runner::new(12).run(|r| {
        let pool_slots = r.usize(1, 4); // 1..=3 concurrent KV entries
        let n = r.usize(6, 14); // burst always overcommits the pool
        let max_batch = r.usize(2, 7);
        let mut eng = EngineBuilder::sim()
            .model("tiny-1M")
            .max_batch(max_batch)
            .ctx_limit(ctx)
            .kv_capacity(pool_slots * per_request)
            .build()
            .unwrap();
        let arrival = ArrivalProcess::OnOff {
            burst_n: n, // one solid burst at t=0
            burst_gap_ms: 0.0,
            idle_ms: 0.0,
        };
        let plan = LoadRunner::new(
            &arrival,
            &RequestMix::tiny(),
            SloSpec::relaxed(),
            n,
            r.next_u64(),
        );
        let out = plan.run(&mut eng).unwrap();
        assert_eq!(out.report.completed, n, "burst must fully drain");
        // FIFO through requeue: prefill (= admission) order matches
        // submission order even when requests bounce on a full pool
        let starts: Vec<f64> = out
            .records
            .iter()
            .map(|rec| rec.prefill_start_ms.expect("all prefilled"))
            .collect();
        for w in starts.windows(2) {
            assert!(
                w[0] <= w[1],
                "admission reordered under overcommit: {starts:?}"
            );
        }
        // all reservations released
        assert_eq!(eng.kv_entries(), 0);
        assert_eq!(eng.pool_used_bytes(), 0);
    });
}

/// The comparison the deleted open-loop scheduler asserted, now
/// through the real engine: P3-LLM out-serves the FP16 NPU baseline
/// under saturating load on a 3B-class model.
#[test]
fn p3_beats_npu_on_closed_loop_throughput() {
    let mut sc = scenario_by_name("chat-poisson").unwrap();
    sc.n_requests = 8;
    sc.max_batch = 4;
    sc.ctx_limit = 256;
    // saturating burst of decode-heavy requests: all arrive at t=0,
    // short prompts, 48-token outputs (decode dominates the makespan,
    // where the PIM offload pays off)
    let plan = LoadRunner::from_plan(
        vec![0.0; sc.n_requests],
        vec![(16, 48); sc.n_requests],
        sc.slo,
        5,
    );
    let run = |sys: &str| {
        let mut eng = sc.engine(sys, None).unwrap();
        plan.run(&mut eng).unwrap().report
    };
    let npu = run("NPU");
    let p3 = run("P3-LLM");
    assert!(
        p3.throughput_tok_s > npu.throughput_tok_s,
        "P3 {} vs NPU {}",
        p3.throughput_tok_s,
        npu.throughput_tok_s
    );
    assert!(p3.makespan_ms < npu.makespan_ms);
}

/// Trace replay hits the engine at exactly the recorded offsets when
/// the system is unloaded (the clock fast-forwards between arrivals).
#[test]
fn trace_replay_submits_on_the_recorded_clock() {
    let arrivals = vec![0.0, 500.0, 1500.0];
    let plan = LoadRunner::from_plan(
        arrivals.clone(),
        vec![(6, 2); 3],
        SloSpec::chatbot(),
        1,
    );
    let mut eng = EngineBuilder::sim()
        .model("tiny-1M")
        .max_batch(2)
        .ctx_limit(64)
        .build()
        .unwrap();
    let out = plan.run(&mut eng).unwrap();
    // gaps are huge vs tiny-1M service times: each request finds an
    // idle engine, so submit lands exactly on its arrival
    for (rec, want) in out.records.iter().zip(&arrivals) {
        assert!(
            (rec.submitted_ms - want).abs() < 1e-6,
            "submitted {} vs arrival {want}",
            rec.submitted_ms
        );
        assert!(rec.finished());
    }
    assert_eq!(out.report.completed, 3);
}
