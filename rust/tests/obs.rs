//! Integration and property tests for the obs layer through the
//! public API: histogram quantile estimates bounded by the exact
//! sample percentiles, byte-identical scrape exports across seeds and
//! victim policies, the report-identical guarantee with metrics
//! disabled, the alert state machine end-to-end without flapping, and
//! per-replica tagging on a shared cluster hub.

use p3llm::cluster::Cluster;
use p3llm::coordinator::Percentiles;
use p3llm::obs::{AlertKind, Histogram, Obs, ObsConfig};
use p3llm::sched::SloClass;
use p3llm::telemetry::Trace;
use p3llm::testutil::{Rng, Runner};
use p3llm::traffic::{scenario_by_name, LoadReport, Scenario, SloSpec};

const SYSTEM: &str = "P3-LLM";
const EPS: f64 = 1e-9;

/// The CI overload scenario pinned to 2x modeled saturation with the
/// victim policy overridden -- the same shape the telemetry tests use,
/// so the scraped counters cover admission, preemption, and retire
/// churn.
fn overloaded(victim: Option<&'static str>, seed: u64) -> Scenario {
    let mut sc = scenario_by_name("smoke-overload")
        .unwrap()
        .with_load_factor(SYSTEM, 2.0, seed)
        .unwrap();
    sc.victim = victim;
    sc
}

/// Run a scenario on a single observed engine and return the report.
fn observed_run(sc: &Scenario, seed: u64, obs: &Obs) -> LoadReport {
    let mut eng = sc.engine(SYSTEM, None).unwrap();
    eng.set_obs(obs.clone());
    sc.runner(seed)
        .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
        .unwrap()
        .report
}

/// Property test: the log2-bucket histogram's nearest-rank quantile
/// estimate never undershoots the exact sample percentile and stays
/// within the bucket's factor-of-two bound above it.  Sample counts
/// avoid multiples of 20 and 100 so the float `ceil(n * q)` rank and
/// the exact integer `ceil(n * pct / 100)` rank agree.
#[test]
fn histogram_quantiles_track_exact_percentiles_within_2x() {
    Runner::new(64).run(|rng: &mut Rng| {
        let n = loop {
            let n = rng.usize(5, 300);
            if n % 20 != 0 && n % 100 != 0 {
                break n;
            }
        };
        let samples: Vec<f64> =
            (0..n).map(|_| rng.lognormal(2.0, 1.5)).collect();
        let mut h = Histogram::default();
        for &s in &samples {
            h.observe(s);
        }
        assert_eq!(h.count(), n as u64);
        let exact = Percentiles::from_samples(&samples);
        for (q, want) in
            [(0.5, exact.p50), (0.95, exact.p95), (0.99, exact.p99)]
        {
            let est = h.quantile(q);
            assert!(
                est + EPS >= want,
                "n={n} q={q}: estimate {est} undershoots exact {want}"
            );
            assert!(
                est <= 2.0 * want + EPS,
                "n={n} q={q}: estimate {est} above 2x exact {want}"
            );
            assert!(est <= exact.max + EPS);
        }
    });
}

/// Two identical runs export byte-identical Prometheus text and series
/// JSON, for every victim policy and several seeds -- the determinism
/// the `monitor --smoke` CI gate relies on.
#[test]
fn scrape_exports_are_byte_identical_across_seeds_and_victims() {
    for victim in [Some("recompute"), Some("swap")] {
        for seed in [7u64, 11] {
            let sc = overloaded(victim, seed);
            let export_once = || {
                let obs = Obs::new(ObsConfig::standard(sc.slo));
                let report = observed_run(&sc, seed, &obs);
                assert!(report.completed > 0);
                assert!(obs.scrapes() > 0, "engine never scraped");
                (obs.prometheus(), obs.series_json())
            };
            let (p1, j1) = export_once();
            let (p2, j2) = export_once();
            assert_eq!(p1, p2, "{victim:?}/seed {seed}: prometheus text");
            assert_eq!(j1, j2, "{victim:?}/seed {seed}: series JSON");
            assert!(p1.contains("p3llm_slo_total"));
            assert!(p1.contains("# TYPE p3llm_queue_depth gauge"));
            assert!(j1.contains("\"name\":\"slo_total\""));
        }
    }
}

/// Instrumentation must never perturb the run: a metrics-off engine
/// produces a LoadReport identical to the observed one, and the
/// disabled handle records nothing.
#[test]
fn metrics_off_run_is_report_identical() {
    let sc = overloaded(Some("swap"), 7);
    let obs = Obs::new(ObsConfig::standard(sc.slo));
    let on = observed_run(&sc, 7, &obs);
    let off = Obs::off();
    let plain = observed_run(&sc, 7, &off);
    assert_eq!(plain, on, "metrics changed the schedule");
    assert!(obs.total_points() > 0);
    assert_eq!(off.total_points(), 0);
    assert_eq!(off.scrapes(), 0);
    assert!(off.prometheus().is_empty());
}

/// The burn-rate state machine end-to-end through the public handle:
/// an outage walks interactive through pending -> firing, a sustained
/// recovery resolves it exactly once, and an isolated boundary miss
/// afterwards cannot re-fire (the slow window refuses to confirm).
#[test]
fn alert_state_machine_fires_and_resolves_without_flapping() {
    let slo = SloSpec { ttft_ms: 10.0, tpot_ms: f64::INFINITY };
    let o = Obs::new(ObsConfig::with_windows(slo, 10.0, 50.0, 100.0));
    let mut t = 0.0;
    let mut tick = |o: &Obs, ttft: f64, n: usize, t: &mut f64| {
        for _ in 0..n {
            o.request_finished(SloClass::Interactive, ttft, None);
            o.maybe_scrape(*t);
            *t += 10.0;
        }
    };
    tick(&o, 1.0, 10, &mut t); // healthy
    tick(&o, 99.0, 15, &mut t); // outage
    tick(&o, 1.0, 35, &mut t); // sustained recovery
    // boundary noise: one isolated miss in a sea of meets
    o.request_finished(SloClass::Interactive, 99.0, None);
    tick(&o, 1.0, 20, &mut t);
    let evs = o.events();
    let of = |k: AlertKind| {
        evs.iter()
            .filter(|e| e.class == SloClass::Interactive && e.kind == k)
            .count()
    };
    assert_eq!(of(AlertKind::Firing), 1, "{evs:?}");
    assert_eq!(of(AlertKind::Resolved), 1, "{evs:?}");
    let firing = evs
        .iter()
        .find(|e| e.kind == AlertKind::Firing)
        .unwrap()
        .ts_ms;
    let pending = evs
        .iter()
        .find(|e| e.kind == AlertKind::Pending)
        .unwrap()
        .ts_ms;
    let resolved = evs
        .iter()
        .find(|e| e.kind == AlertKind::Resolved)
        .unwrap()
        .ts_ms;
    assert!(pending < firing && firing < resolved);
}

/// A 2-replica cluster sharing one hub tags every replica's samples,
/// merges fleet series at scrape timestamps, and mirrors alert
/// transitions into the shared trace sink when one is attached.
#[test]
fn cluster_hub_tags_replicas_and_merges_series() {
    let sc = scenario_by_name("smoke").unwrap();
    let obs = Obs::new(ObsConfig::standard(sc.slo));
    let trace = Trace::ring(1 << 18);
    obs.set_trace(trace.clone());
    let mut fleet = Cluster::from_scenario_observed(
        &sc, SYSTEM, None, 2, "jsq", &trace, &obs,
    )
    .unwrap();
    let plan = sc.clone().for_fleet(2).unwrap().runner(7);
    fleet.run(&plan, sc.saturation_tok_s(SYSTEM)).unwrap();
    let prom = obs.prometheus();
    assert!(prom.contains("replica=\"0\""), "{prom}");
    assert!(prom.contains("replica=\"1\""), "{prom}");
    // scrapes mirror the headline gauges into the trace as obs:
    // counters (the Perfetto metrics track)
    assert!(trace
        .snapshot()
        .iter()
        .any(|e| e.name.starts_with("obs:")));
    let h = obs.health(1e12, None, sc.saturation_tok_s(SYSTEM));
    assert!(h.replica_skew >= 0.0);
    assert!(!h.tiers.is_empty(), "no tier was ever judged");
}
