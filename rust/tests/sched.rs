//! Integration tests for the preemptive sched layer through the
//! public API: KV-page conservation across preempt/restore churn for
//! both victim policies, bit-identical overload runs under a seed,
//! the interactive tail-latency win over FIFO past saturation, and
//! the aging floor that stops best-effort starvation.

use p3llm::config::llm;
use p3llm::coordinator::{Engine, EngineBuilder, KvLayout};
use p3llm::sched::SloClass;
use p3llm::traffic::{scenario_by_name, LoadReport, Scenario};

const SYSTEM: &str = "P3-LLM";
const SEED: u64 = 7;

/// The CI overload scenario pinned to 2x the modeled saturation
/// throughput (`Scenario::with_load_factor`), with the victim policy
/// overridden (None = FIFO baseline, no preemption).
fn overloaded(victim: Option<&'static str>) -> Scenario {
    let mut sc = scenario_by_name("smoke-overload")
        .unwrap()
        .with_load_factor(SYSTEM, 2.0, SEED)
        .unwrap();
    sc.victim = victim;
    sc
}

fn run(sc: &Scenario) -> (LoadReport, Engine) {
    let mut eng = sc.engine(SYSTEM, None).unwrap();
    let out = sc
        .runner(SEED)
        .run_with_saturation(&mut eng, sc.saturation_tok_s(SYSTEM))
        .unwrap();
    (out.report, eng)
}

fn interactive(r: &LoadReport) -> &LoadReport {
    r.per_class
        .iter()
        .find(|(c, _)| *c == SloClass::Interactive)
        .map(|(_, cr)| cr)
        .expect("tiered run carries an interactive tier")
}

/// Tentpole invariant: a 2x-saturation run that preempts, restores,
/// and re-prefills must end with every request served and every KV
/// page back on the free list -- for both victim policies.
#[test]
fn overload_churn_conserves_kv_pages_for_both_victims() {
    for victim in ["recompute", "swap"] {
        let sc = overloaded(Some(victim));
        let (r, eng) = run(&sc);
        assert_eq!(
            r.completed, r.offered,
            "{victim}: requests lost under overload"
        );
        assert!(
            r.preemptions > 0,
            "{victim}: 2x overload never preempted"
        );
        match victim {
            "recompute" => {
                assert!(r.pages_recomputed > 0, "recompute counted no pages");
                assert_eq!(r.pages_swapped, 0, "recompute must not swap");
            }
            _ => {
                assert!(r.pages_swapped > 0, "swap counted no pages");
                assert_eq!(r.pages_recomputed, 0, "swap must not recompute");
            }
        }
        // conservation: no live sequences and no pinned bytes remain
        // (cache-only prefix pages are reclaimable and excluded from
        // used_bytes by contract)
        assert_eq!(eng.kv_entries(), 0, "{victim}: live KV entries leaked");
        assert_eq!(eng.pool_used_bytes(), 0, "{victim}: pool bytes leaked");
    }
}

/// Preempt/swap/restore decisions ride the same virtual clock as
/// everything else: identical seeds must give identical reports,
/// including the per-tier breakdown and preemption counters.
#[test]
fn overload_runs_are_bit_identical_under_a_seed() {
    let sc = overloaded(Some("swap"));
    let (a, _) = run(&sc);
    let (b, _) = run(&sc);
    assert_eq!(a, b, "overload run is nondeterministic");
    assert!(!a.per_class.is_empty(), "tiered run lost its breakdown");
    assert!(a.preemptions > 0 && a.pages_swapped > 0);
}

/// The point of the subsystem: past saturation, preemption keeps the
/// interactive tier's tail TTFT strictly below the FIFO baseline's
/// (same arrivals, same tiers, no eviction).
#[test]
fn preemption_beats_fifo_on_interactive_tail_latency_past_saturation() {
    let (pre, _) = run(&overloaded(Some("recompute")));
    let (fifo, _) = run(&overloaded(None));
    assert_eq!(fifo.preemptions, 0, "FIFO baseline must not preempt");
    assert_eq!(fifo.completed, fifo.offered);
    let (ipre, ififo) = (interactive(&pre), interactive(&fifo));
    assert!(
        ipre.ttft_ms.p95 < ififo.ttft_ms.p95,
        "preemptive interactive p95 TTFT {:.4} ms not below FIFO's \
         {:.4} ms at 2x saturation",
        ipre.ttft_ms.p95,
        ififo.ttft_ms.p95
    );
}

/// Starvation regression: the aging floor promotes long-waiting
/// requests to interactive rank.  With an instant floor every request
/// ages immediately, so nothing outranks anything -- preemption must
/// go completely quiet (aged best-effort decodes are unpreemptible)
/// while the run still drains; with the floor pushed past the run's
/// timescale the preemptive schedule re-emerges.
#[test]
fn aging_floor_quiesces_preemption_and_prevents_starvation() {
    let sc = overloaded(Some("recompute"));
    let model = llm::by_name(sc.model).unwrap();
    let per_req = KvLayout {
        layers: model.layers,
        kv_dim: model.kv_dim(),
        head_dim: model.head_dim,
        max_ctx: sc.ctx_limit.min(model.max_ctx),
    }
    .bytes_per_request();
    let drive = |aging_ms: f64| {
        let mut eng = EngineBuilder::sim()
            .model(sc.model)
            .system(SYSTEM)
            .max_batch(sc.max_batch)
            .ctx_limit(sc.ctx_limit.min(model.max_ctx))
            .kv_capacity(per_req.saturating_mul(sc.kv_slots.max(1)))
            .prefix_cache(sc.prefix_cache)
            .preempt("recompute")
            .aging_ms(aging_ms)
            .build()
            .unwrap();
        let r = sc.runner(SEED).run(&mut eng).unwrap().report;
        assert_eq!(r.completed, r.offered, "aging run lost requests");
        r
    };
    let aged = drive(1e-9);
    assert_eq!(
        aged.preemptions, 0,
        "aged requests must be unpreemptible (starvation floor)"
    );
    let unaged = drive(1e12);
    assert!(
        unaged.preemptions > 0,
        "inactive aging floor must preempt under 2x overload"
    );
}
