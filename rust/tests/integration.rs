//! Integration tests over the PJRT runtime + coordinator: the AOT
//! bridge (HLO text -> compile -> execute), the serving engine, and
//! eval-driver consistency.  Skipped gracefully when artifacts are
//! missing (run `make artifacts`).

use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};
use p3llm::EngineBuilder;

fn artifacts() -> Option<String> {
    let dir =
        std::env::var("P3LLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping integration tests: run `make artifacts`");
        None
    }
}

#[test]
fn kernel_gemv_artifact_matches_rust_reference() {
    // the L1 Pallas kernel (lowered to HLO) must agree with the Rust
    // BitMoD decode + matmul on the same packed operands
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("kernel_w4a8_gemv").unwrap();
    let (b, k, n) = (8usize, 128usize, 256usize);
    let mut rng = p3llm::testutil::Rng::new(9);
    let x: Vec<f32> = (0..b * k)
        .map(|_| p3llm::quant::fp8_e4m3(rng.normal()))
        .collect();
    // encode weights column-wise with the Rust encoder
    let mut codes = vec![0u8; k * n];
    let mut scales = vec![0.0f32; n];
    let mut specials = vec![0u8; n];
    let mut wdeq = vec![0.0f32; k * n];
    for j in 0..n {
        let col: Vec<f32> = (0..k).map(|_| rng.normal() * 0.2).collect();
        let g = p3llm::quant::bitmod_encode_group(&col);
        let mut deq = vec![0.0f32; k];
        p3llm::quant::bitmod_decode_group(&g, &mut deq);
        for i in 0..k {
            codes[i * n + j] = g.codes[i];
            wdeq[i * n + j] = deq[i];
        }
        scales[j] = g.scale;
        specials[j] = g.special;
    }
    let args = vec![
        p3llm::runtime::artifacts::lit_f32(&[b, k], &x).unwrap(),
        p3llm::runtime::artifacts::lit_u8(&[k, n], &codes).unwrap(),
        p3llm::runtime::artifacts::lit_f32(&[1, n], &scales).unwrap(),
        p3llm::runtime::artifacts::lit_u8(&[1, n], &specials).unwrap(),
    ];
    let out = exe.run(&args).unwrap();
    let y = p3llm::runtime::artifacts::vec_f32(&out[0]).unwrap();
    // rust reference: x @ wdeq
    for bi in 0..b {
        for j in 0..n {
            let mut acc = 0.0f64;
            for i in 0..k {
                acc += (x[bi * k + i] * wdeq[i * n + j]) as f64;
            }
            let got = y[bi * n + j] as f64;
            assert!(
                (got - acc).abs() <= 1e-3 * (1.0 + acc.abs()),
                "[{bi},{j}] {got} vs {acc}"
            );
        }
    }
}

#[test]
fn serve_fp16_and_quantized_complete() {
    let Some(dir) = artifacts() else { return };
    for scheme in ["fp16", "p3llm"] {
        let mut eng = EngineBuilder::pjrt(&dir)
            .scheme(scheme)
            .max_batch(4)
            .build()
            .unwrap();
        for i in 0..5 {
            eng.submit(vec![104, 101, 108 + i], 6).unwrap();
        }
        let m = eng.run_to_completion().unwrap();
        assert_eq!(m.completed, 5);
        // the first token of each request is emitted by prefill; the
        // remaining max_new-1 by decode steps
        assert_eq!(m.tokens_out, 5 * (6 - 1));
        assert_eq!(m.ttft_ms.count, 5);
        assert!(m.ttft_ms.p50 <= m.ttft_ms.p99);
    }
}

#[test]
fn serve_deterministic_and_valid() {
    // greedy serving is deterministic across runs, and outputs are
    // valid byte tokens.  (fp16 vs quantized token agreement is NOT
    // asserted: greedy decoding branch-flips under tiny logit
    // perturbations -- the python reference produces the identical
    // quantized continuation; accuracy is guarded by the <5% ppl delta
    // in examples/edge_serve.rs and the tab04 bench.)
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<i32> = "the kettle works".bytes().map(|b| b as i32).collect();
    for scheme in ["fp16", "p3llm"] {
        let mut outs = vec![];
        for _ in 0..2 {
            let mut eng = EngineBuilder::pjrt(&dir)
                .scheme(scheme)
                .max_batch(1)
                .build()
                .unwrap();
            let id = eng.submit(prompt.clone(), 12).unwrap();
            eng.run_to_completion().unwrap();
            outs.push(eng.request(id).unwrap().generated.clone());
        }
        assert_eq!(outs[0], outs[1], "nondeterministic (scheme={scheme})");
        assert!(outs[0].iter().all(|&t| (0..256).contains(&t)));
    }
}

#[test]
fn device_weights_path_matches_literal_path() {
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<i32> = "aldora".bytes().map(|b| b as i32).collect();
    let mut outs = vec![];
    for device_weights in [false, true] {
        let mut eng = EngineBuilder::pjrt(&dir)
            .scheme("p3llm")
            .max_batch(1)
            .device_weights(device_weights)
            .build()
            .unwrap();
        let id = eng.submit(prompt.clone(), 8).unwrap();
        eng.run_to_completion().unwrap();
        outs.push(eng.request(id).unwrap().generated.clone());
    }
    assert_eq!(outs[0], outs[1]);
}

#[test]
fn eval_bits16_matches_fp_graph() {
    // the eval_int graph with all bit-widths at 16 must reproduce the
    // eval_fp perplexity exactly (the jnp.where(bits>=16) bypass)
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let w = ev.load_weights("fp").unwrap();
    let aux = ev.load_aux("fp").unwrap();
    let a = ev.perplexity_raw("eval_fp", &w, &aux, "wiki", 2).unwrap();
    let b = ev.perplexity_raw("eval_int", &w, &aux, "wiki", 2).unwrap();
    assert!((a - b).abs() < 1e-4 * a, "{a} vs {b}");
}

#[test]
fn evalcfg_all_variants_run() {
    // every configured experiment variant must execute end-to-end
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let cfgs = eval_configs(&rt.artifacts.dir).unwrap();
    assert!(cfgs.len() >= 20);
    for cfg in &cfgs {
        let r = ev.evaluate(cfg, "wiki", 1, &[]).unwrap();
        assert!(
            r.ppl.is_finite() && r.ppl >= 1.0 && r.ppl < 100.0,
            "{}: ppl {}",
            cfg.name,
            r.ppl
        );
        assert!(r.accuracy > 0.3, "{}: acc {}", cfg.name, r.accuracy);
    }
}
