//! Microbenchmarks of the L3 hot paths (feeds the §Perf pass in
//! EXPERIMENTS.md): KV pack/dequant, BitMoD encode, simulator step
//! cost, PJRT kernel + decode-step latency.

use p3llm::accel::Accel;
use p3llm::benchkit::{time, Timing};
use p3llm::config::llm::LLAMA31_8B;
use p3llm::coordinator::{KvLayout, KvPool};
use p3llm::EngineBuilder;
use p3llm::quant::bitmod::bitmod_encode_group;
use p3llm::report::{f2, Table};
use p3llm::testutil::Rng;

fn row(t: &mut Table, name: &str, timing: Timing, unit_note: &str) {
    t.row(vec![
        name.into(),
        f2(timing.mean_us()),
        f2(timing.median_ns / 1e3),
        f2(timing.min_ns / 1e3),
        unit_note.into(),
    ]);
}

fn main() {
    let mut t = Table::new(
        "L3 hot-path microbenchmarks",
        &["path", "mean us", "median us", "min us", "unit"],
    );
    let mut rng = Rng::new(1);

    // KV pack + dequant of one full tiny-model cache (page-pooled)
    let layout = KvLayout { layers: 4, kv_dim: 32, head_dim: 16, max_ctx: 160 };
    let mut pool = KvPool::new(layout.clone(), 64 << 20);
    let smooth = vec![vec![1.0f32; 32]; 4];
    pool.alloc_seq(1, smooth, 160, None).unwrap();
    let k: Vec<f32> = rng.vec_f32(32, -1.0, 1.0);
    let v: Vec<f32> = rng.vec_f32(32, -1.0, 1.0);
    for _ in 0..128 {
        for l in 0..4 {
            pool.push_token(1, l, &k, &v).unwrap();
        }
        pool.commit_token(1).unwrap();
    }
    let tm = time(3, 20, || {
        let mut ko = vec![0.0f32; 160 * 32];
        let mut vo = vec![0.0f32; 160 * 32];
        for l in 0..4 {
            pool.dequant_layer(1, l, &mut ko, &mut vo).unwrap();
            std::hint::black_box((&ko, &vo));
        }
    });
    row(&mut t, "kv dequant (4 layers x 128 tok)", tm, "per request-step");

    let tm = time(3, 20, || {
        let mut p = KvPool::new(layout.clone(), 64 << 20);
        p.alloc_seq(2, vec![vec![1.0f32; 32]; 4], 160, None).unwrap();
        for _ in 0..128 {
            for l in 0..4 {
                p.push_token(2, l, &k, &v).unwrap();
            }
            p.commit_token(2).unwrap();
        }
        std::hint::black_box(p.used_bytes());
    });
    row(&mut t, "kv pack (4 layers x 128 tok)", tm, "per prefill");

    let w: Vec<f32> = rng.vec_f32(128, -0.5, 0.5);
    let tm = time(10, 100, || {
        std::hint::black_box(bitmod_encode_group(&w));
    });
    row(&mut t, "bitmod encode (group 128)", tm, "per group");

    let a = Accel::p3llm();
    let tm = time(3, 50, || {
        std::hint::black_box(a.decode_step(&LLAMA31_8B, 4, 4096));
    });
    row(&mut t, "simulator decode-step cost", tm, "per call");

    // PJRT decode step on the tiny model (the serving hot path)
    if let Some(dir) = p3llm::benchkit::require_artifacts() {
        for device_weights in [false, true] {
            let mut eng = EngineBuilder::pjrt(&dir)
                .scheme("p3llm")
                .max_batch(4)
                .device_weights(device_weights)
                .build()
                .unwrap();
            for i in 0..4 {
                eng.submit(vec![104, 105, 32 + i], 200).unwrap();
            }
            eng.step().unwrap(); // prefill + first decode
            let tm = time(2, 15, || {
                eng.step().unwrap();
            });
            row(
                &mut t,
                if device_weights {
                    "pjrt decode step b4 (device weights)"
                } else {
                    "pjrt decode step b4 (literal upload)"
                },
                tm,
                "per decode step",
            );
        }
    }

    t.print();
    t.save(p3llm::benchkit::reports_dir(), "micro_hotpath").unwrap();
}
