//! Table III: activation formats (INT8-SmoothQuant vs FP8-E4M3) under
//! FP16 and 4-bit (BitMoD) weights.

use p3llm::report::{f3, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let cfgs = eval_configs(&rt.artifacts.dir).unwrap();
    let blocks = p3llm::benchkit::eval_blocks();
    let mut t = Table::new(
        "Table III: weight x activation formats, perplexity",
        &["weights", "activation", "wiki ppl", "c4 ppl"],
    );
    let rows = [
        ("FP16", "FP16", "fp16"),
        ("FP16", "INT8-SQ", "act_sq8_w16"),
        ("FP16", "FP8-E4M3", "act_e4m3_w16"),
        ("4-bit", "INT8-SQ", "act_sq8_w4"),
        ("4-bit", "FP8-E4M3", "act_e4m3_w4"),
    ];
    let mut res = vec![];
    for (wl, al, name) in rows {
        let cfg = cfgs.iter().find(|c| c.name == name).unwrap();
        let w = ev.perplexity(cfg, "wiki", blocks, &[]).unwrap();
        let c = ev.perplexity(cfg, "c4", blocks, &[]).unwrap();
        t.row(vec![wl.into(), al.into(), f3(w), f3(c)]);
        res.push((name, w, c));
    }
    t.print();
    let sq4 = res.iter().find(|r| r.0 == "act_sq8_w4").unwrap();
    let fp4 = res.iter().find(|r| r.0 == "act_e4m3_w4").unwrap();
    println!(
        "expected shape: under 4-bit weights, FP8-E4M3 beats INT8-SQ \
         (SQ migrates difficulty onto already-fragile weights) -- {}",
        if fp4.1 <= sq4.1 && fp4.2 <= sq4.2 { "HOLDS" } else { "CHECK" }
    );
    t.save(p3llm::benchkit::reports_dir(), "tab03_act").unwrap();
}
