//! Table VII: PCU area + HBM area overhead, HBM-PIM vs P3-LLM.

use p3llm::area::pcu_area_table;
use p3llm::report::{f2, Table};

fn main() {
    let mut t = Table::new(
        "Table VII (paper: HBM-PIM 7.7+6.2 = 16.4%; P3 8.4+6.2 = 17.5%)",
        &["design", "compute mm2", "buffer mm2", "HBM overhead %"],
    );
    for r in pcu_area_table() {
        t.row(vec![
            r.name.into(),
            f2(r.compute_mm2),
            f2(r.buffer_mm2),
            f2(r.hbm_overhead_pct),
        ]);
    }
    t.print();
    t.save(p3llm::benchkit::reports_dir(), "tab07_area").unwrap();
}
