//! Fig. 13: decoding throughput of P3-LLM vs software quantization
//! (SmoothQuant W8A8, AWQ W4A16) running on the baseline NPU.

use p3llm::accel::Accel;
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, Table};

fn main() {
    let mut t = Table::new(
        "Fig 13: decode throughput tok/s (ctx=4K); paper: P3 3.9x SmoothQuant, 3.0x AWQ",
        &["model", "bs", "SmoothQuant", "AWQ", "P3-LLM"],
    );
    let (mut r_sq, mut r_awq, mut n) = (0.0, 0.0, 0);
    for m in eval_models() {
        for bs in [1usize, 2, 4, 8] {
            let sq = Accel::smoothquant().decode_tokens_per_sec(&m, bs, 4096);
            let awq = Accel::awq().decode_tokens_per_sec(&m, bs, 4096);
            let p3 = Accel::p3llm().decode_tokens_per_sec(&m, bs, 4096);
            t.row(vec![
                m.name.into(),
                bs.to_string(),
                f2(sq),
                f2(awq),
                f2(p3),
            ]);
            r_sq += p3 / sq;
            r_awq += p3 / awq;
            n += 1;
        }
    }
    t.print();
    println!(
        "avg P3 speedup: {:.2}x over SmoothQuant, {:.2}x over AWQ",
        r_sq / n as f64,
        r_awq / n as f64
    );
    t.save(p3llm::benchkit::reports_dir(), "fig13_swquant").unwrap();
}
