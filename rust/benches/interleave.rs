//! NPU||PIM sub-batch interleaving curves (extension experiment, not a
//! paper figure): sweep the decode batch width on the decode-heavy
//! smoke scenario and A/B the interleaved engine against the serial
//! schedule on identical seeds.
//!
//! The claim under test is the one the `interleave --smoke` CI gate
//! enforces at batch 8: splitting each step's active lanes into two
//! sub-batches lets A's NPU phase run under B's PIM phase (and vice
//! versa), so the step pays the critical path across both timelines
//! instead of the serial sum.  Narrow batches have too little work per
//! sub-batch to cover the split's loss of intra-engine batching, and
//! the engine must fuse those steps back to the serial charge -- never
//! finishing later than the serial schedule, seed for seed.
//!
//! Emits `BENCH_interleave_bench.json` through the shared
//! `p3llm::benchkit::save_bench_json` emitter (the `interleave_bench`
//! name keeps it clear of the `BENCH_interleave.json` sidecar the CI
//! smoke gate writes): a flat `{bench, config, metric, value, seed}`
//! array covering every `batch x mode` point.

use p3llm::benchkit::BenchRecord;
use p3llm::report::{f2, f3, Table};
use p3llm::traffic::{scenario_by_name, LoadReport, Scenario};

const SYSTEM: &str = "P3-LLM";
const SEED: u64 = 7;
const BATCHES: [usize; 3] = [2, 4, 8];

fn at_batch(batch: usize, interleave: bool) -> Scenario {
    let mut sc =
        scenario_by_name("smoke-interleave").expect("registry scenario");
    sc.max_batch = batch;
    sc.kv_slots = batch + 2;
    sc.interleave = interleave;
    sc
}

fn run(sc: &Scenario) -> LoadReport {
    let mut engine = sc.engine(SYSTEM, None).expect("engine build");
    sc.runner(SEED)
        .run_with_saturation(&mut engine, sc.saturation_tok_s(SYSTEM))
        .expect("closed-loop run")
        .report
}

fn main() {
    let mut t = Table::new(
        format!(
            "interleave: batch width x mode on {SYSTEM}, \
             smoke-interleave scenario, seed {SEED}"
        ),
        &[
            "batch",
            "mode",
            "done",
            "goodput tok/s",
            "makespan ms",
            "overlap",
            "steps ilv/fused",
            "saved ms",
        ],
    );
    let mut recs: Vec<BenchRecord> = vec![];
    for &batch in &BATCHES {
        let serial = run(&at_batch(batch, false));
        let ilv = run(&at_batch(batch, true));
        for (mode, r) in [("serial", &serial), ("interleaved", &ilv)] {
            assert_eq!(
                r.completed, r.offered,
                "batch={batch} mode={mode} lost requests"
            );
            t.row(vec![
                batch.to_string(),
                mode.into(),
                format!("{}/{}", r.completed, r.offered),
                f2(r.goodput_tok_s),
                f3(r.makespan_ms),
                f2(r.overlap_factor),
                format!("{}/{}", r.interleaved_steps, r.fused_steps),
                f3(r.serial_saved_ms),
            ]);
            let cfg = format!("batch={batch},mode={mode}");
            for (metric, value) in [
                ("goodput_tok_s", r.goodput_tok_s),
                ("makespan_ms", r.makespan_ms),
                ("overlap_factor", r.overlap_factor),
                ("interleaved_steps", r.interleaved_steps as f64),
                ("fused_steps", r.fused_steps as f64),
            ] {
                recs.push(BenchRecord::new(cfg.as_str(), metric, value));
            }
        }
        // the serial schedule never charges interleaving
        assert_eq!(serial.interleaved_steps + serial.fused_steps, 0);
        assert_eq!(serial.overlap_factor, 0.0);
        // the fused fallback caps every step at its serial charge, so
        // the interleaved run can never finish later
        assert!(
            ilv.makespan_ms <= serial.makespan_ms,
            "batch={batch}: interleaved makespan {:.4} ms exceeds \
             serial {:.4} ms",
            ilv.makespan_ms,
            serial.makespan_ms
        );
        recs.push(BenchRecord::new(
            format!("batch={batch}"),
            "goodput_speedup",
            ilv.goodput_tok_s / serial.goodput_tok_s,
        ));
        if batch >= 8 {
            // wide decode batches are the paying regime: the CI gate's
            // claim, reproduced here across the sweep
            assert!(
                ilv.overlap_factor > 0.3,
                "batch={batch}: overlap factor {:.3} <= 0.3",
                ilv.overlap_factor
            );
            assert!(
                ilv.goodput_tok_s > serial.goodput_tok_s,
                "batch={batch}: interleaved goodput {:.2} tok/s not \
                 strictly above serial {:.2}",
                ilv.goodput_tok_s,
                serial.goodput_tok_s
            );
        }
        println!(
            "check: batch={batch}: speedup x{:.3}, overlap factor \
             {:.3}, {} steps overlapped / {} fused",
            ilv.goodput_tok_s / serial.goodput_tok_s,
            ilv.overlap_factor,
            ilv.interleaved_steps,
            ilv.fused_steps
        );
    }
    t.print();
    println!(
        "expected shape: narrow batches fuse back to the serial charge \
         (speedup pinned at 1.0, overlap 0), and once the split halves \
         still batch enough work per engine the step cost drops to the \
         two-timeline critical path -- overlap factor climbs past 0.3 \
         and goodput rises strictly above the serial schedule"
    );
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "interleave_bench").unwrap();
    let p =
        p3llm::benchkit::save_bench_json("interleave_bench", SEED, &recs)
            .expect("write BENCH_interleave_bench.json");
    println!("saved {}", p.display());
}
