//! Table IV: perplexity of KV-cache-only quantization (Oaken vs P3)
//! and weight-activation quantization (QuaRot, QoQ vs P3) on both
//! evaluation corpora, with mean delta-ppl vs the FP16 baseline.

use p3llm::report::{f3, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let cfgs = eval_configs(&rt.artifacts.dir).unwrap();
    let blocks = p3llm::benchkit::eval_blocks();
    let rows = [
        ("Baseline FP16", "fp16"),
        ("Oaken KV4", "oaken_kv4"),
        ("P3-LLM KV4", "p3_kv4"),
        ("QuaRot W4A8KV4", "quarot"),
        ("QoQ W4A8KV4", "qoq"),
        ("P3-LLM W4A8KV4P8", "p3_full"),
        ("P3-LLM +FP8 query", "p3_full_q8"),
    ];
    let mut t = Table::new(
        "Table IV: perplexity under quantization methods",
        &["method", "wiki ppl", "c4 ppl", "mean d-ppl"],
    );
    let mut base = (0.0, 0.0);
    let mut deltas = vec![];
    for (label, name) in rows {
        let cfg = cfgs.iter().find(|c| c.name == name).unwrap();
        let w = ev.perplexity(cfg, "wiki", blocks, &[]).unwrap();
        let c = ev.perplexity(cfg, "c4", blocks, &[]).unwrap();
        if name == "fp16" {
            base = (w, c);
        }
        let d = ((w - base.0) + (c - base.1)) / 2.0;
        t.row(vec![label.into(), f3(w), f3(c), f3(d)]);
        deltas.push((name, d));
    }
    t.print();
    let d = |n: &str| deltas.iter().find(|x| x.0 == n).unwrap().1;
    println!(
        "expected shape: P3 KV4 <= Oaken KV4 ({}); P3 full < QuaRot ({}) \
         and < QoQ ({})",
        if d("p3_kv4") <= d("oaken_kv4") { "HOLDS" } else { "CHECK" },
        if d("p3_full") < d("quarot") { "HOLDS" } else { "CHECK" },
        if d("p3_full") < d("qoq") { "HOLDS" } else { "CHECK" },
    );
    t.save(p3llm::benchkit::reports_dir(), "tab04_ppl").unwrap();
}
