//! Shared-prefix KV cache effect (extension experiment, not a paper
//! figure): each prefix-bearing scenario runs twice -- cache on vs
//! cache off -- on the same deterministic load plan, so the hit-rate,
//! prefill-tokens-saved and TTFT columns isolate exactly what the
//! paged pool's prefix sharing buys.
//!
//! The harness asserts the acceptance criteria: a nonzero hit rate
//! with the cache on, zero hits with it off, and a strictly lower
//! mean TTFT on the cached run of the deterministic CI scenarios
//! (`smoke-prefix`, `agent-pool`).

use p3llm::benchkit::BenchRecord;
use p3llm::report::{f2, Table};
use p3llm::traffic::{scenario_by_name, LoadReport};

fn run(name: &str, cache_on: bool, seed: u64) -> LoadReport {
    let mut sc = scenario_by_name(name).expect("registry scenario");
    sc.prefix_cache = cache_on;
    let mut eng = sc.engine("P3-LLM", None).expect("engine build");
    sc.runner(seed)
        .run_with_saturation(&mut eng, sc.saturation_tok_s("P3-LLM"))
        .expect("closed-loop run")
        .report
}

fn main() {
    let seed = 7u64;
    let mut t = Table::new(
        format!("prefix cache: hit rate and TTFT effect (seed {seed})"),
        &[
            "scenario",
            "cache",
            "hit %",
            "saved tok",
            "mean TTFT ms",
            "p95 TTFT ms",
            "goodput tok/s",
        ],
    );
    let mut recs: Vec<BenchRecord> = vec![];
    for name in ["smoke-prefix", "agent-pool", "rag-cached"] {
        let on = run(name, true, seed);
        let off = run(name, false, seed);
        for (label, r) in [("on", &on), ("off", &off)] {
            let cfg = format!("scenario={name},cache={label}");
            for (metric, value) in [
                ("prefix_hit_rate", r.prefix_hit_rate),
                ("prefill_tokens_saved", r.prefill_tokens_saved as f64),
                ("ttft_mean_ms", r.ttft_ms.mean),
                ("ttft_p95_ms", r.ttft_ms.p95),
                ("goodput_tok_s", r.goodput_tok_s),
            ] {
                recs.push(BenchRecord::new(cfg.as_str(), metric, value));
            }
            t.row(vec![
                name.into(),
                label.into(),
                f2(r.prefix_hit_rate * 100.0),
                r.prefill_tokens_saved.to_string(),
                f2(r.ttft_ms.mean),
                f2(r.ttft_ms.p95),
                f2(r.goodput_tok_s),
            ]);
        }
        assert_eq!(on.completed, on.offered, "{name}: requests lost");
        assert_eq!(off.completed, off.offered, "{name}: requests lost");
        assert!(
            on.prefix_hit_rate > 0.0 && on.prefill_tokens_saved > 0,
            "{name}: prefix-bearing scenario never hit the cache"
        );
        assert_eq!(
            off.prefix_hits, 0,
            "{name}: disabled cache reported hits"
        );
        // the two CI scenarios must show a strict TTFT win; rag-cached
        // is long-context and queueing-heavy, so allow ties there
        if name == "rag-cached" {
            assert!(
                on.ttft_ms.mean <= off.ttft_ms.mean,
                "{name}: cached mean TTFT {} above cold {}",
                on.ttft_ms.mean,
                off.ttft_ms.mean
            );
        } else {
            assert!(
                on.ttft_ms.mean < off.ttft_ms.mean,
                "{name}: cached mean TTFT {} not below cold {}",
                on.ttft_ms.mean,
                off.ttft_ms.mean
            );
        }
        println!(
            "check: {name}: hit {:.1}%, {} prefill tokens skipped, mean \
             TTFT {:.2} -> {:.2} ms (cold -> cached)",
            on.prefix_hit_rate * 100.0,
            on.prefill_tokens_saved,
            off.ttft_ms.mean,
            on.ttft_ms.mean
        );
    }
    t.print();
    println!(
        "expected shape: hot system prompts (agent-pool) and hot RAG \
         contexts (rag-cached) skip most of their prefill, cutting TTFT \
         without touching decode throughput; the cold column is the \
         same plan with the cache disabled"
    );
    t.save(p3llm::benchkit::reports_dir(), "prefix_cache").unwrap();
    let p = p3llm::benchkit::save_bench_json("prefix_cache", seed, &recs)
        .expect("write BENCH_prefix_cache.json");
    println!("saved {}", p.display());
}
