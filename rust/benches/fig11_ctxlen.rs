//! Fig. 11: single-batch decoding speedup across context lengths
//! 2K-16K.  Llama-2-7B (pre-RoPE key quantization -> Q.K^T on NPU)
//! should show the flattest scaling.

use p3llm::accel::Accel;
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, Table};

fn main() {
    let mut t = Table::new(
        "Fig 11: P3-LLM speedup over HBM-PIM vs context length (bs=1)",
        &["model", "2K", "4K", "8K", "16K"],
    );
    let p3 = Accel::p3llm();
    let base = Accel::hbm_pim();
    for m in eval_models() {
        let mut row = vec![m.name.to_string()];
        for ctx in [2048usize, 4096, 8192, 16384] {
            let s = base.decode_step(&m, 1, ctx).total_ns()
                / p3.decode_step(&m, 1, ctx).total_ns();
            row.push(f2(s));
        }
        t.row(row);
    }
    t.print();
    println!(
        "expected shape: speedup grows with ctx for post-RoPE models; \
         Llama-2 (pre-RoPE, attention QK on NPU) grows least"
    );
    t.save(p3llm::benchkit::reports_dir(), "fig11_ctxlen").unwrap();
}
