//! Fig. 7: PIM command timing of HBM-PIM vs P3-LLM's
//! throughput-enhanced PCU (column read every t_CCD_L; P3 issues two
//! MAC waves per column at t_CCD_S).

use p3llm::config::accel::{HbmTiming, PcuConfig, PimConfig};
use p3llm::coordinator::mapper::command_timing;
use p3llm::report::Table;
use p3llm::sim::pim::PimGemm;

fn main() {
    let g = PimGemm { m: 2, k: 4096, n: 128, count: 1, stored_bits: 4.25 };
    let mut t = Table::new(
        "Fig 7: command start times (ns), first 4 columns",
        &["pcu", "column", "event", "t_ns"],
    );
    for pcu in [PcuConfig::hbm_pim(), PcuConfig::p3llm()] {
        let pim = PimConfig { hbm: HbmTiming::default(), pcu: pcu.clone() };
        let g = if pcu.weight_bits >= 16.0 {
            PimGemm { stored_bits: 16.0, ..g }
        } else {
            g
        };
        for (col, t_ns, ev) in command_timing(&pim, g, 4) {
            t.row(vec![
                pcu.name.into(),
                col.to_string(),
                ev.into(),
                format!("{t_ns:.1}"),
            ]);
        }
    }
    t.print();
    println!(
        "expected shape: HBM-PIM = one MAC wave per t_CCD_L (4 ns); \
         P3-LLM = two MAC waves per column read, t_CCD_S (2 ns) apart \
         -- the same weight slice serves two inputs (Section V-D)"
    );
    t.save(p3llm::benchkit::reports_dir(), "fig07_timing").unwrap();
}
