//! Fig. 15: architecture ablation -- HBM-PIM -> +W4A8KV4 -> +TEP ->
//! +P8 scores (= P3-LLM), batch 2 and 4, ctx 4K.
//! Paper: W4A8KV4 3.3x, +TEP another 1.6x, +P8 another 1.2x.

use p3llm::accel::Accel;
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, Table};

fn main() {
    let steps = [
        Accel::hbm_pim(),
        Accel::pim_w4a8kv4(),
        Accel::pim_w4a8kv4_tep(),
        Accel::p3llm(),
    ];
    let mut t = Table::new(
        "Fig 15: architectural ablation, speedup over HBM-PIM",
        &["model", "bs", "HBM-PIM", "+W4A8KV4", "+TEP", "+P8 (=P3)"],
    );
    let mut sums = vec![0.0f64; steps.len()];
    let mut n = 0;
    for m in eval_models() {
        for bs in [2usize, 4] {
            let ns: Vec<f64> = steps
                .iter()
                .map(|a| a.decode_step(&m, bs, 4096).total_ns())
                .collect();
            t.row(
                std::iter::once(m.name.to_string())
                    .chain(std::iter::once(bs.to_string()))
                    .chain(ns.iter().map(|&x| f2(ns[0] / x)))
                    .collect(),
            );
            for i in 0..steps.len() {
                sums[i] += ns[0] / ns[i];
            }
            n += 1;
        }
    }
    t.print();
    println!(
        "avg chain: quant {:.2}x, +TEP {:.2}x, +P8 {:.2}x",
        sums[1] / n as f64,
        sums[2] / sums[1],
        sums[3] / sums[2]
    );
    t.save(p3llm::benchkit::reports_dir(), "fig15_archablation").unwrap();
}
