//! Fig. 3b: per-operand quantization sensitivity -- sweep one operand's
//! integer bit-width at a time (others fp16) and report perplexity.
//! Weights are swept host-side (Rust INT-asym fake-quant); A/KV/P are
//! traced scalars of the eval_int graph.

use p3llm::report::{f3, Table};
use p3llm::runtime::{Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let blocks = p3llm::benchkit::eval_blocks();
    let aux = ev.load_aux("fp").unwrap();
    let weights = ev.load_weights("fp").unwrap();
    let bits = [8.0f32, 6.0, 4.0, 3.0, 2.0];

    let mut t = Table::new(
        "Fig 3b: wiki perplexity vs per-operand INT bit-width",
        &["bits", "weights", "activations", "kv", "scores"],
    );
    let sweep = |field: &str, b: f32| -> f64 {
        let mut a = aux.clone();
        a.set_scalar(field, b).unwrap();
        ev.perplexity_raw("eval_int", &weights, &a, "wiki", blocks).unwrap()
    };
    for &b in &bits {
        // weights host-side: INT-asym per group of 128 along input dim
        let wq = quantize_weights(&ev, b);
        let w_ppl = ev
            .perplexity_raw("eval_int", &wq, &aux, "wiki", blocks)
            .unwrap();
        t.row(vec![
            format!("{b}"),
            f3(w_ppl),
            f3(sweep("a_bits", b)),
            f3(sweep("kv_bits", b)),
            f3(sweep("p_bits", b)),
        ]);
    }
    t.print();
    println!(
        "expected shape: activations/scores degrade faster than weights \
         and KV at equal bits; W and KV stay usable down to 4 bits"
    );
    t.save(p3llm::benchkit::reports_dir(), "fig03b_sensitivity").unwrap();
}

/// INT-b asym fake-quant of the linear weights (groups of 128 along the
/// input dim), matching python baselines.weights_int4 generalized to b.
fn quantize_weights(
    ev: &Evaluator,
    bits: f32,
) -> p3llm::runtime::Weights {
    let mut w = ev.load_weights("fp").unwrap();
    let linears = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown", "lm_head"];
    for t in w.tensors.iter_mut() {
        let is_linear = linears.iter().any(|s| {
            t.name.ends_with(s) && (t.name == "lm_head" || t.name.contains('.'))
        });
        if !is_linear || t.dims.len() != 2 {
            continue;
        }
        let (k, n) = (t.dims[0], t.dims[1]);
        let group = 128.min(k);
        // per output column, groups along k
        let mut col = vec![0.0f32; group];
        for j in 0..n {
            for g0 in (0..k).step_by(group) {
                for (i, c) in col.iter_mut().enumerate() {
                    *c = t.f32_data[(g0 + i) * n + j];
                }
                p3llm::quant::int::fake_quant_group_int(&mut col, bits as u32);
                for (i, &c) in col.iter().enumerate() {
                    t.f32_data[(g0 + i) * n + j] = c;
                }
            }
        }
    }
    w
}
