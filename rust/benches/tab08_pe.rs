//! Table VIII: PE area + energy/MAC at 1 GHz for HBM-PIM (FP16 MAC),
//! MANT, BitMoD and the P3-LLM PE.

use p3llm::area::pe_table;
use p3llm::report::{f2, f3, Table};

fn main() {
    let rows = pe_table();
    let base = rows[0].clone();
    let mut t = Table::new(
        "Table VIII (paper: MANT 0.70x/0.58x, BitMoD 1.26x/0.88x, P3 1.08x/0.26x)",
        &["PE", "MAC/cycle", "area um2", "area ratio", "pJ/MAC", "energy ratio"],
    );
    for r in &rows {
        t.row(vec![
            r.name.into(),
            format!("{}", r.macs_per_cycle),
            f2(r.area_um2_28nm),
            f2(r.area_um2_28nm / base.area_um2_28nm),
            f3(r.energy_pj_per_mac),
            f2(r.energy_pj_per_mac / base.energy_pj_per_mac),
        ]);
    }
    t.print();
    t.save(p3llm::benchkit::reports_dir(), "tab08_pe").unwrap();
}
