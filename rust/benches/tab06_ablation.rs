//! Table VI: stepwise ablation of the P3-LLM quantization techniques
//! (wiki perplexity), matching the paper's chain:
//! FP16 -> +INT4 KV (pre/post RoPE) -> +dynamic smoothing -> +INT4
//! weights -> +BitMoD -> +E4M3/S0E4M4 scores -> +INT8/E4M3 activations.

use p3llm::report::{f3, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let cfgs = eval_configs(&rt.artifacts.dir).unwrap();
    let blocks = p3llm::benchkit::eval_blocks();
    let chain = [
        ("Baseline FP16", "fp16"),
        ("+ pre-RoPE INT4 KV", "abl_int4kv_pre"),
        ("+ post-RoPE INT4 KV", "abl_int4kv_post"),
        ("-> dynamic key smoothing", "abl_smooth"),
        ("+ INT4 weights", "abl_w4"),
        ("-> BitMoD weights", "abl_bitmod"),
        ("+ FP8-E4M3 scores", "abl_p_e4m3"),
        ("-> FP8-S0E4M4 scores", "abl_p_s0e4m4"),
        ("+ INT8 activations", "abl_a_int8"),
        ("-> FP8-E4M3 activations", "abl_a_e4m3"),
    ];
    let mut t = Table::new(
        "Table VI: quantization ablation (wiki + c4 perplexity)",
        &["step", "wiki ppl", "c4 ppl"],
    );
    let mut res = vec![];
    for (label, name) in chain {
        let cfg = cfgs.iter().find(|c| c.name == name).unwrap();
        let w = ev.perplexity(cfg, "wiki", blocks, &[]).unwrap();
        let c = ev.perplexity(cfg, "c4", blocks, &[]).unwrap();
        t.row(vec![label.into(), f3(w), f3(c)]);
        res.push((name, w, c));
    }
    t.print();
    let g = |n: &str| res.iter().find(|x| x.0 == n).unwrap();
    let checks = [
        ("smoothing improves over raw INT4 KV",
         g("abl_smooth").1 <= g("abl_int4kv_post").1),
        ("BitMoD improves over INT4 weights",
         g("abl_bitmod").1 <= g("abl_w4").1),
        ("S0E4M4 scores <= E4M3 scores",
         g("abl_p_s0e4m4").1 <= g("abl_p_e4m3").1),
        ("E4M3 activations <= INT8 activations",
         g("abl_a_e4m3").1 <= g("abl_a_int8").1),
    ];
    for (msg, ok) in checks {
        println!("{}: {}", msg, if ok { "HOLDS" } else { "CHECK" });
    }
    t.save(p3llm::benchkit::reports_dir(), "tab06_ablation").unwrap();
}
