//! Fig. 12: P3-LLM vs Pimba (original KV8-only and enhanced W8A8KV8)
//! at batch sizes 2 and 4, ctx 4K.

use p3llm::accel::Accel;
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, Table};

fn main() {
    let mut t = Table::new(
        "Fig 12: speedup over Pimba-orig (paper: enhanced ~2.1x, P3 ~3.4x over enhanced)",
        &["model", "bs", "Pimba", "Pimba-W8A8", "P3-LLM"],
    );
    let mut enh_sum = 0.0;
    let mut p3_sum = 0.0;
    let mut n = 0;
    for m in eval_models() {
        for bs in [2usize, 4] {
            let orig = Accel::pimba_orig().decode_step(&m, bs, 4096).total_ns();
            let enh =
                Accel::pimba_enhanced().decode_step(&m, bs, 4096).total_ns();
            let p3 = Accel::p3llm().decode_step(&m, bs, 4096).total_ns();
            t.row(vec![
                m.name.into(),
                bs.to_string(),
                "1.00".into(),
                f2(orig / enh),
                f2(orig / p3),
            ]);
            enh_sum += orig / enh;
            p3_sum += enh / p3;
            n += 1;
        }
    }
    t.print();
    println!(
        "avg: enhanced {:.2}x over orig; P3 {:.2}x over enhanced",
        enh_sum / n as f64,
        p3_sum / n as f64
    );
    t.save(p3llm::benchkit::reports_dir(), "fig12_pimba").unwrap();
}
