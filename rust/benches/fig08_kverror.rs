//! Fig. 8: layer-wise key-cache quantization error of P3 (dynamic
//! smoothing) vs Oaken (calibrated outlier mask) vs QoQ (calibrated
//! smoothing), evaluated on both corpora -- the calibration-overfitting
//! experiment.  Calibration stats come from pile_syn (QoQ) and wiki
//! (Oaken), matching the paper's setup.

use p3llm::report::{Table, f3};
use p3llm::runtime::artifacts::{lit_f32, lit_i32, vec_f32};
use p3llm::runtime::eval::{blocks, EVAL_B, EVAL_T};
use p3llm::runtime::{Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let exe = rt.load("kverr").unwrap();
    let weights = ev.load_weights("fp").unwrap();
    // oaken masks calibrated on pile; qoq factors calibrated on pile
    let aux_oaken = ev.load_aux("oaken_pile").unwrap();
    let aux_qoq = ev.load_aux("qoq_pile").unwrap();
    // merge: masks from oaken blob, qoq_ksm from qoq blob
    let mut aux = aux_oaken.clone();
    if let Some((dims, data)) = aux_qoq.view("qoq_ksm") {
        let total: usize = dims.iter().product();
        let off = aux
            .layout
            .iter()
            .find(|(n, ..)| n == "qoq_ksm")
            .map(|(_, _, off, _)| *off)
            .unwrap();
        aux.data[off..off + total].copy_from_slice(data);
    }

    let mut t = Table::new(
        "Fig 8: normalized key-cache quant MSE per layer (INT4)",
        &["corpus", "layer", "P3 dynamic", "Oaken(pile)", "QoQ(pile)"],
    );
    let mut sums = [[0.0f64; 3]; 2];
    for (ci, corpus) in ["wiki", "c4"].iter().enumerate() {
        let toks = ev.load_corpus(corpus, "eval").unwrap();
        let blk = &blocks(&toks, 1)[0];
        let mut args: Vec<xla::Literal> = weights
            .tensors
            .iter()
            .map(|w| lit_f32(&w.dims, &w.f32_data))
            .collect::<Result<_, _>>()
            .unwrap();
        args.push(lit_i32(&[EVAL_B, EVAL_T + 1], blk).unwrap());
        for (_, dims, off, cnt) in &aux.layout {
            args.push(lit_f32(dims, &aux.data[*off..*off + *cnt]).unwrap());
        }
        let out = exe.run(&args).unwrap();
        let errs = vec_f32(&out[0]).unwrap(); // [3, L]
        let l = errs.len() / 3;
        for layer in 0..l {
            t.row(vec![
                corpus.to_string(),
                layer.to_string(),
                f3(errs[layer] as f64),
                f3(errs[l + layer] as f64),
                f3(errs[2 * l + layer] as f64),
            ]);
            for m in 0..3 {
                sums[ci][m] += errs[m * l + layer] as f64 / l as f64;
            }
        }
    }
    t.print();
    for (ci, corpus) in ["wiki", "c4"].iter().enumerate() {
        let [p3, oaken, qoq] = sums[ci];
        println!(
            "{corpus}: P3 {:.4} vs Oaken {:.4} vs QoQ {:.4} -> P3 lowest: {}",
            p3, oaken, qoq,
            if p3 <= oaken && p3 <= qoq { "HOLDS" } else { "CHECK" }
        );
    }
    t.save(p3llm::benchkit::reports_dir(), "fig08_kverror").unwrap();
}
