//! Table V: task accuracy under quantization.  Substitute task (see
//! DESIGN.md): held-out next-token top-1 accuracy on both corpora --
//! what matters is the method-vs-method ordering.

use p3llm::report::{f2, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let cfgs = eval_configs(&rt.artifacts.dir).unwrap();
    let blocks = p3llm::benchkit::eval_blocks();
    let rows = [
        ("FP16", "fp16"),
        ("Oaken KV4", "oaken_kv4"),
        ("P3-LLM KV4", "p3_kv4"),
        ("QuaRot", "quarot"),
        ("QoQ", "qoq"),
        ("P3-LLM full", "p3_full"),
    ];
    let mut t = Table::new(
        "Table V (substitute): held-out next-token accuracy %",
        &["method", "wiki acc", "c4 acc", "avg"],
    );
    let mut accs = vec![];
    for (label, name) in rows {
        let cfg = cfgs.iter().find(|c| c.name == name).unwrap();
        let w = ev.evaluate(cfg, "wiki", blocks, &[]).unwrap().accuracy;
        let c = ev.evaluate(cfg, "c4", blocks, &[]).unwrap().accuracy;
        t.row(vec![
            label.into(),
            f2(w * 100.0),
            f2(c * 100.0),
            f2((w + c) * 50.0),
        ]);
        accs.push((name, (w + c) / 2.0));
    }
    t.print();
    let a = |n: &str| accs.iter().find(|x| x.0 == n).unwrap().1;
    println!(
        "expected shape: P3 full > QuaRot ({}) and > QoQ ({})",
        if a("p3_full") >= a("quarot") { "HOLDS" } else { "CHECK" },
        if a("p3_full") >= a("qoq") { "HOLDS" } else { "CHECK" },
    );
    t.save(p3llm::benchkit::reports_dir(), "tab05_acc").unwrap();
}
