//! Fig. 4: roofline analysis of NPU, HBM-PIM and P3-LLM with the
//! paper's operator markers (MHA, GQA G in {2,4,8}, linear BS in
//! {4,16,64}).

use p3llm::config::accel::{HbmTiming, NpuConfig, PcuConfig};
use p3llm::report::{f2, si, Table};
use p3llm::sim::roofline::{npu_platform, op_intensity, pim_platform};

fn main() {
    let hbm = HbmTiming::default();
    let plats = [
        npu_platform(&NpuConfig::default(), &hbm),
        pim_platform(&PcuConfig::hbm_pim(), &hbm),
        pim_platform(&PcuConfig::p3llm(), &hbm),
    ];
    let mut t = Table::new(
        "Fig 4: attainable MAC/s per platform and operator",
        &["operator", "intensity MAC/B", "NPU", "HBM-PIM", "P3-LLM"],
    );
    // markers: (name, rows sharing a matrix pass, stored bits)
    let markers: [(&str, usize, f64); 7] = [
        ("MHA (G=1, fp16)", 1, 16.0),
        ("GQA G=2 (fp16)", 2, 16.0),
        ("GQA G=4 (fp16)", 4, 16.0),
        ("GQA G=8 (fp16)", 8, 16.0),
        ("Linear BS=4 (fp16)", 4, 16.0),
        ("Linear BS=16 (fp16)", 16, 16.0),
        ("Linear BS=4 (W4, P3)", 4, 4.25),
    ];
    for (name, rows, bits) in markers {
        let ai = op_intensity(rows, bits);
        let mut row = vec![name.to_string(), f2(ai)];
        for p in &plats {
            row.push(si(p.attainable(ai)));
        }
        t.row(row);
    }
    t.print();
    let mut roofs = Table::new(
        "Fig 4 roofs: peak MAC/s + knee intensity",
        &["platform", "peak MAC/s", "feed BW B/s", "knee MAC/B"],
    );
    for p in &plats {
        roofs.row(vec![p.name.clone(), si(p.peak), si(p.bw), f2(p.knee())]);
    }
    roofs.print();
    println!(
        "expected shape: HBM-PIM advantage over NPU vanishes around G/BS=4; \
         P3 roofline 8x HBM-PIM"
    );
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "fig04_roofline").unwrap();
    roofs.save(&dir, "fig04_roofs").unwrap();
}
