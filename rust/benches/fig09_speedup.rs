//! Fig. 9: normalized decoding speedup vs batch size for NPU, HBM-PIM,
//! Ecco and P3-LLM across the five evaluation models (ctx 4K).

use p3llm::accel::{fig9_systems, Accel};
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, Table};

fn main() {
    let mut t = Table::new(
        "Fig 9: normalized decoding speedup (ctx=4K, NPU=1.0)",
        &["model", "bs", "NPU", "HBM-PIM", "Ecco", "P3-LLM"],
    );
    let systems = fig9_systems();
    let mut p3_over = vec![0.0f64; systems.len()];
    let mut n = 0usize;
    for m in eval_models() {
        for bs in [1usize, 2, 4, 8] {
            let ns: Vec<f64> = systems
                .iter()
                .map(|a| a.decode_step(&m, bs, 4096).total_ns())
                .collect();
            let base = ns[0];
            t.row(
                std::iter::once(m.name.to_string())
                    .chain(std::iter::once(bs.to_string()))
                    .chain(ns.iter().map(|&x| f2(base / x)))
                    .collect(),
            );
            let p3 = *ns.last().unwrap();
            for (i, &x) in ns.iter().enumerate() {
                p3_over[i] += x / p3;
            }
            n += 1;
        }
    }
    t.print();
    let mut avg = Table::new(
        "Fig 9 summary: average P3-LLM speedup (paper: 7.8x NPU, 4.9x HBM-PIM, 2.0x Ecco)",
        &["over", "speedup"],
    );
    for (i, a) in [Accel::npu_fp16(), Accel::hbm_pim(), Accel::ecco()]
        .iter()
        .enumerate()
    {
        avg.row(vec![a.name.into(), f2(p3_over[i] / n as f64)]);
    }
    avg.print();
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "fig09_speedup").unwrap();
    avg.save(&dir, "fig09_summary").unwrap();
}
