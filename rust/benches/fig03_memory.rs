//! Fig. 3a: per-operand memory footprint of the four edge LLMs at
//! FP16 across batch sizes 1-8 (ctx 4K).

use p3llm::config::llm::{LLAMA2_7B, LLAMA31_8B, LLAMA32_3B, MISTRAL_7B};
use p3llm::report::{f2, Table};
use p3llm::workload::memory_breakdown;

fn main() {
    let mut t = Table::new(
        "Fig 3a: FP16 memory footprint GB (ctx=4K)",
        &["model", "bs", "weights", "kv", "activations", "scores", "total"],
    );
    for m in [&LLAMA2_7B, &LLAMA31_8B, &LLAMA32_3B, &MISTRAL_7B] {
        for bs in [1usize, 2, 4, 8] {
            let b = memory_breakdown(m, bs, 4096, 16.0, 16.0, 16.0, 16.0);
            t.row(vec![
                m.name.into(),
                bs.to_string(),
                f2(b.weights / 1e9),
                f2(b.kv / 1e9),
                f2(b.activations / 1e9),
                f2(b.scores / 1e9),
                f2(b.total() / 1e9),
            ]);
        }
    }
    t.print();
    println!(
        "expected shape: weights dominate at bs=1; Llama-2-7B (MHA) KV \
         grows far faster than the GQA models; scores negligible"
    );
    t.save(p3llm::benchkit::reports_dir(), "fig03a_memory").unwrap();
}
