//! Serving-level timeline simulation (extension experiment, not a
//! paper figure): Poisson arrivals + continuous batching under each
//! modeled accelerator -- TTFT/throughput/SLO attainment for the edge
//! chatbot scenario the paper's introduction motivates (250 ms TTFT
//! SLO from DistServe [97], which the paper uses as its
//! smoothing-overhead budget).

use p3llm::accel::Accel;
use p3llm::config::llm::LLAMA32_3B;
use p3llm::coordinator::scheduler::{simulate, ServingParams};
use p3llm::report::{f2, Table};

fn main() {
    let m = &LLAMA32_3B;
    let mut t = Table::new(
        "serving timeline: Llama-3.2-3B, 512-tok prompts, 128-tok outputs",
        &["system", "arrival ms", "mean TTFT ms", "p95 TTFT ms",
          "tok/s", "TTFT<=250ms %"],
    );
    for ia in [400.0, 150.0, 50.0] {
        let p = ServingParams {
            interarrival_ms: ia,
            n_requests: 32,
            ..Default::default()
        };
        for a in [Accel::npu_fp16(), Accel::hbm_pim(), Accel::ecco(),
                  Accel::p3llm()] {
            let r = simulate(&a, m, &p, 42);
            t.row(vec![
                a.name.into(),
                f2(ia),
                f2(r.mean_ttft_ms),
                f2(r.p95_ttft_ms),
                f2(r.throughput_tok_s),
                f2(r.slo_250ms * 100.0),
            ]);
        }
    }
    t.print();
    println!(
        "expected shape: P3 sustains the 250 ms TTFT SLO to higher load \
         than the baselines (faster decode steps drain the batch sooner)"
    );
    t.save(p3llm::benchkit::reports_dir(), "serving_slo").unwrap();
}
