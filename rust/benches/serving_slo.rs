//! Closed-loop serving SLO sweep (extension experiment, not a paper
//! figure): the `chat-poisson` traffic scenario at three load levels
//! under each modeled accelerator, driven through the *real* serving
//! engine by `traffic::LoadRunner` -- TTFT, goodput and attainment of
//! the 250 ms TTFT SLO the paper's introduction motivates (DistServe
//! [97], also the smoothing-overhead budget).

use p3llm::report::{f2, Table};
use p3llm::traffic::{scenario_by_name, LoadRunner};

fn main() {
    let sc = scenario_by_name("chat-poisson").expect("registry scenario");
    let mut t = Table::new(
        format!(
            "closed-loop serving: {} ({}, {} requests, chat mix)",
            sc.name, sc.model, sc.n_requests
        ),
        &[
            "system",
            "load x",
            "SLO %",
            "goodput tok/s",
            "tok/s",
            "mean TTFT ms",
            "p95 TTFT ms",
        ],
    );
    // load multipliers: arrival gaps scaled by 1/load
    for load in [0.33, 1.0, 3.0] {
        let arrival = sc.arrival.scaled(1.0 / load).expect("positive load");
        for sys in ["NPU", "HBM-PIM", "Ecco", "P3-LLM"] {
            let mut eng = sc.engine(sys, None).expect("sim engine");
            let runner = LoadRunner::new(
                &arrival,
                &sc.mix,
                sc.slo,
                sc.n_requests,
                42,
            );
            let out = runner
                .run_with_saturation(&mut eng, sc.saturation_tok_s(sys))
                .expect("closed-loop run");
            let r = out.report;
            t.row(vec![
                sys.into(),
                f2(load),
                f2(r.slo_attainment * 100.0),
                f2(r.goodput_tok_s),
                f2(r.throughput_tok_s),
                f2(r.ttft_ms.mean),
                f2(r.ttft_ms.p95),
            ]);
        }
    }
    t.print();
    println!(
        "expected shape: P3 sustains the 250 ms TTFT SLO (and hence \
         goodput) to higher load than the baselines -- faster decode \
         steps drain the batch sooner, so prefills queue less"
    );
    t.save(p3llm::benchkit::reports_dir(), "serving_slo").unwrap();
}
