//! Burn-rate alert lead time under an injected flash crowd (extension
//! experiment, not a paper figure): sweep the burst size on the
//! 3-phase plan the `monitor --smoke` CI gate runs (calm lead-in ->
//! flash crowd -> calm recovery) and measure how far ahead of the
//! end-of-run attainment report the interactive burn-rate alert fires.
//!
//! The claim under test: a multi-window burn-rate rule over scraped
//! miss counters calls the SLO dip while the crowd is still in the
//! queue -- strictly before the terminal `LoadReport` can show it --
//! and resolves on its own once the load subsides, while a calm run
//! of the same shape never fires at all (pending fizzles are allowed;
//! a firing is not).
//!
//! Emits `BENCH_monitor_bench.json` through the shared
//! `p3llm::benchkit::save_bench_json` emitter (the `monitor_bench`
//! name keeps it clear of the `BENCH_monitor.json` sidecar the CI
//! smoke gate writes).

use p3llm::benchkit::BenchRecord;
use p3llm::coordinator::{Engine, EngineBuilder};
use p3llm::obs::{AlertKind, Obs, ObsConfig};
use p3llm::report::{f2, f3, Table};
use p3llm::sched::SloClass;
use p3llm::traffic::{LoadReport, LoadRunner, SloSpec};

const SEED: u64 = 7;
const BURSTS: [usize; 3] = [0, 16, 32];

fn build(obs: &Obs) -> Engine {
    let mut e = EngineBuilder::sim()
        .model("tiny-1M")
        .max_batch(2)
        .ctx_limit(128)
        .preempt("recompute")
        .build()
        .expect("engine build");
    e.set_obs(obs.clone());
    e
}

/// The smoke gate's plan shape, with the crowd size as the knob: 12
/// calm interactive requests, `burst` simultaneous arrivals at 96
/// calibrated-TTFT units (classes cycling interactive-heavy), then 16
/// recovery requests.
fn mk_plan(burst: usize, t_base: f64, budget: SloSpec) -> LoadRunner {
    let mut arrivals = vec![];
    let mut shapes = vec![];
    let mut classes = vec![];
    for i in 0..12 {
        arrivals.push(i as f64 * 8.0 * t_base);
        shapes.push((16, 8));
        classes.push(SloClass::Interactive);
    }
    for i in 0..burst {
        arrivals.push(96.0 * t_base);
        shapes.push((16, 8));
        classes.push(match i % 4 {
            0 | 1 => SloClass::Interactive,
            2 => SloClass::Batch,
            _ => SloClass::BestEffort,
        });
    }
    for i in 0..16 {
        arrivals.push(220.0 * t_base + i as f64 * 12.0 * t_base);
        shapes.push((16, 8));
        classes.push(SloClass::Interactive);
    }
    LoadRunner::from_plan(arrivals, shapes, budget, SEED)
        .with_classes(classes)
}

/// Post-run cool-down: keep the scrape clock ticking through the quiet
/// tail so the windowed burn decays and firing alerts resolve (same
/// helper the monitor subcommand uses).
fn cool_down(obs: &Obs, from_ms: f64, step_ms: f64, horizon_ms: f64) {
    let from = obs.last_scrape_ms().unwrap_or(from_ms).max(from_ms);
    let step = step_ms.max(1e-3);
    let mut k = 1u64;
    while (k as f64) * step <= horizon_ms + 1e-9 {
        obs.scrape_now(from + k as f64 * step);
        k += 1;
    }
}

fn main() {
    // calibrate the budget off a calm probe, exactly like the CI gate:
    // the tiny model's absolute latencies are meaningless, its p95
    // under no contention is the unit everything else is timed in
    let probe = LoadRunner::from_plan(
        (0..8).map(|i| i as f64 * 200.0).collect(),
        vec![(16, 8); 8],
        SloSpec::chatbot(),
        SEED,
    );
    let mut eng = build(&Obs::off());
    let t_base = probe.run(&mut eng).expect("probe run").report.ttft_ms.p95;
    assert!(t_base > 0.0, "calibration run produced no TTFT");
    let budget = SloSpec { ttft_ms: 6.0 * t_base, tpot_ms: f64::INFINITY };
    let (scrape, fast, slow) =
        (2.0 * t_base, 24.0 * t_base, 60.0 * t_base);

    let mut t = Table::new(
        format!(
            "monitor: alert lead vs flash-crowd size, tiny-1M sim \
             engine, budget 6x calibrated p95 TTFT, seed {SEED}"
        ),
        &[
            "burst",
            "done",
            "makespan ms",
            "att I",
            "transitions",
            "firing ms",
            "lead ms",
            "resolved ms",
        ],
    );
    let mut recs: Vec<BenchRecord> = vec![];
    let mut calm_att = 1.0;
    for &burst in &BURSTS {
        let obs =
            Obs::new(ObsConfig::with_windows(budget, scrape, fast, slow));
        let mut eng = build(&obs);
        let r: LoadReport = mk_plan(burst, t_base, budget)
            .run(&mut eng)
            .expect("closed-loop run")
            .report;
        cool_down(&obs, r.makespan_ms, scrape, slow + 2.0 * fast);
        assert_eq!(
            r.completed, r.offered,
            "burst={burst} lost requests"
        );
        let events = obs.events();
        let firing = events.iter().find(|e| {
            e.class == SloClass::Interactive && e.kind == AlertKind::Firing
        });
        let resolved = firing.and_then(|f| {
            events.iter().find(|e| {
                e.class == SloClass::Interactive
                    && e.kind == AlertKind::Resolved
                    && e.ts_ms > f.ts_ms
            })
        });
        let att = r
            .class_attainment(SloClass::Interactive)
            .unwrap_or(r.slo_attainment);
        let lead = firing.map(|f| r.makespan_ms - f.ts_ms);
        t.row(vec![
            burst.to_string(),
            format!("{}/{}", r.completed, r.offered),
            f3(r.makespan_ms),
            f2(att),
            events.len().to_string(),
            firing.map(|f| f3(f.ts_ms)).unwrap_or_else(|| "-".into()),
            lead.map(f3).unwrap_or_else(|| "-".into()),
            resolved.map(|e| f3(e.ts_ms)).unwrap_or_else(|| "-".into()),
        ]);
        let cfg = format!("burst={burst}");
        for (metric, value) in [
            ("interactive_attainment", att),
            ("alert_transitions", events.len() as f64),
            ("alert_lead_ms", lead.unwrap_or(0.0)),
            ("makespan_ms", r.makespan_ms),
        ] {
            recs.push(BenchRecord::new(cfg.as_str(), metric, value));
        }
        if burst == 0 {
            // a calm run must never page anyone: pending fizzles are
            // fine, a firing is a false alarm
            assert!(
                firing.is_none(),
                "burst=0: burn-rate alert fired on a calm run \
                 ({events:?})"
            );
            calm_att = att;
            println!(
                "check: burst=0: attainment {att:.3}, no firing \
                 ({} transitions)",
                events.len()
            );
        } else if burst == 32 {
            // the flash crowd: the alert must fire strictly before the
            // end of the run, resolve after the crowd subsides, and
            // the terminal report must confirm the dip it called early
            let f = firing.expect(
                "burst=32: interactive burn-rate alert never fired",
            );
            let lead = r.makespan_ms - f.ts_ms;
            assert!(
                lead > 0.0,
                "burst=32: alert fired at {:.1} ms, not before the end \
                 of the run ({:.1} ms)",
                f.ts_ms,
                r.makespan_ms
            );
            resolved.expect(
                "burst=32: firing alert never resolved after the crowd",
            );
            assert!(
                att < 1.0,
                "burst=32: flash crowd left no attainment dip"
            );
            assert!(
                att < calm_att + 1e-9,
                "burst=32: attainment {att:.3} not below the calm \
                 run's {calm_att:.3}"
            );
            println!(
                "check: burst=32: fired at {:.1} ms, lead {:.1} ms \
                 ahead of the report, attainment {att:.3} (calm \
                 {calm_att:.3})",
                f.ts_ms, lead
            );
        }
    }
    t.print();
    println!(
        "expected shape: the calm run never fires; as the crowd grows \
         the interactive attainment drops below the calm baseline and \
         the burn-rate alert calls it while requests are still queued \
         -- a positive lead over the end-of-run report -- then resolves \
         once the recovery phase drains"
    );
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "monitor_bench").unwrap();
    let p = p3llm::benchkit::save_bench_json("monitor_bench", SEED, &recs)
        .expect("write BENCH_monitor_bench.json");
    println!("saved {}", p.display());
}
