//! Fig. 5: key/value cache distribution -- per-channel abs-max pre- and
//! post-RoPE and after dynamic smoothing, from the trained tiny model
//! (via the kdist graph) plus a synthetic LLM-statistics generator
//! reproducing the published outlier-channel structure.

use p3llm::quant::smoothing_factors;
use p3llm::report::{f2, f3, Table};
use p3llm::runtime::artifacts::{lit_i32, vec_f32};
use p3llm::runtime::eval::{blocks, clone_literal, EVAL_B, EVAL_T};
use p3llm::runtime::{Evaluator, Runtime};
use p3llm::testutil::Rng;

fn kurtosis_like(xs: &[f32]) -> f64 {
    // max/mean of per-channel absmax: >~4 indicates distinct outliers
    let mx = xs.iter().cloned().fold(0.0f32, f32::max) as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
    mx / mean.max(1e-12)
}

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let exe = rt.load("kdist").unwrap();
    let weights = ev.load_weights("fp").unwrap();
    let toks = ev.load_corpus("wiki", "eval").unwrap();
    let blk = &blocks(&toks, 1)[0];
    let mut args: Vec<xla::Literal> = weights
        .tensors
        .iter()
        .map(|t| p3llm::runtime::artifacts::lit_f32(&t.dims, &t.f32_data))
        .collect::<Result<_, _>>()
        .unwrap();
    args.push(lit_i32(&[EVAL_B, EVAL_T + 1], blk).unwrap());
    let _ = clone_literal(&args[0]).unwrap(); // exercise helper
    let out = exe.run(&args).unwrap();
    let kpre = vec_f32(&out[0]).unwrap(); // [L, kvdim]
    let kpost = vec_f32(&out[1]).unwrap();
    let ksm = vec_f32(&out[2]).unwrap();

    let kvd = kpre.len() / 4;
    let mut t = Table::new(
        "Fig 5 (tiny model): per-layer channel absmax outlier ratio (max/mean)",
        &["layer", "pre-RoPE", "post-RoPE", "smoothed", "smoothed max"],
    );
    for l in 0..4 {
        let s = l * kvd..(l + 1) * kvd;
        t.row(vec![
            l.to_string(),
            f2(kurtosis_like(&kpre[s.clone()])),
            f2(kurtosis_like(&kpost[s.clone()])),
            f2(kurtosis_like(&ksm[s.clone()])),
            f3(ksm[s].iter().cloned().fold(0.0f32, f32::max) as f64),
        ]);
    }
    t.print();

    // synthetic generator calibrated to published LLM key-cache stats:
    // a few fixed channels carry 10-20x magnitude (Fig. 5b/5f)
    let mut rng = Rng::new(11);
    let (tokens, ch) = (512usize, 128usize);
    let mut k = vec![0.0f32; tokens * ch];
    let outliers = [7usize, 40, 99];
    for ti in 0..tokens {
        for c in 0..ch {
            let scale = if outliers.contains(&c) { 16.0 } else { 1.0 };
            k[ti * ch + c] = rng.normal() * scale;
        }
    }
    let f = smoothing_factors(&k, ch);
    let absmax: Vec<f32> = (0..ch)
        .map(|c| {
            (0..tokens).map(|t| k[t * ch + c].abs()).fold(0.0f32, f32::max)
        })
        .collect();
    let smoothed: Vec<f32> = absmax.iter().zip(&f).map(|(a, b)| a / b).collect();
    let mut t2 = Table::new(
        "Fig 5 (synthetic LLM-calibrated K): outlier suppression",
        &["view", "max/mean", "absmax"],
    );
    t2.row(vec![
        "raw post-RoPE".into(),
        f2(kurtosis_like(&absmax)),
        f2(absmax.iter().cloned().fold(0.0f32, f32::max) as f64),
    ]);
    t2.row(vec![
        "smoothed".into(),
        f2(kurtosis_like(&smoothed)),
        f2(smoothed.iter().cloned().fold(0.0f32, f32::max) as f64),
    ]);
    t2.print();
    println!(
        "expected shape: distinct outlier channels pre-smoothing; \
         smoothed view flat at <= 1.0 (paper Fig. 5d/5h)"
    );
    let rdir = p3llm::benchkit::reports_dir();
    t.save(&rdir, "fig05_kvdist").unwrap();
    t2.save(&rdir, "fig05_synthetic").unwrap();
}
