//! Table II: attention-score format comparison (FP16 / INT8 /
//! FP8-E4M3 / FP8-S0E4M4) with INT4-Asym smoothed KV, on the tiny
//! trained model via the AOT eval graphs.

use p3llm::report::{Table, f3};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

fn main() {
    let Some(dir) = p3llm::benchkit::require_artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ev = Evaluator::new(&rt).unwrap();
    let cfgs = eval_configs(&rt.artifacts.dir).unwrap();
    let blocks = p3llm::benchkit::eval_blocks();
    let mut t = Table::new(
        "Table II: 8-bit attention-score formats, perplexity (KV4 smoothed)",
        &["format", "wiki ppl", "c4 ppl"],
    );
    let rows = [
        ("FP16", "score_fp16"),
        ("INT8", "score_int8"),
        ("FP8-E4M3", "score_e4m3"),
        ("FP8-S0E4M4", "score_s0e4m4"),
    ];
    let mut results = vec![];
    for (label, name) in rows {
        let cfg = cfgs.iter().find(|c| c.name == name).unwrap();
        let w = ev.perplexity(cfg, "wiki", blocks, &[]).unwrap();
        let c = ev.perplexity(cfg, "c4", blocks, &[]).unwrap();
        t.row(vec![label.into(), f3(w), f3(c)]);
        results.push((label, w, c));
    }
    t.print();
    let s0 = results.iter().find(|r| r.0 == "FP8-S0E4M4").unwrap();
    let i8 = results.iter().find(|r| r.0 == "INT8").unwrap();
    println!(
        "expected shape: S0E4M4 <= E4M3 < INT8 perplexity loss -- {}",
        if s0.1 <= i8.1 && s0.2 <= i8.2 { "HOLDS" } else { "CHECK" }
    );
    t.save(p3llm::benchkit::reports_dir(), "tab02_scores").unwrap();
}
