//! Fig. 16: large-batch decoding latency breakdown (attention vs
//! linear) for Ecco and P3-LLM, batch 2-64, Llama-3 models.

use p3llm::accel::Accel;
use p3llm::config::llm::{LLAMA31_8B, LLAMA32_3B};
use p3llm::report::{f2, Table};

fn main() {
    let mut t = Table::new(
        "Fig 16: decode latency ms (attn + linear) vs batch, ctx=4K",
        &["model", "bs", "Ecco attn", "Ecco lin", "Ecco tot", "P3 attn",
          "P3 lin", "P3 tot", "P3 speedup"],
    );
    for m in [&LLAMA31_8B, &LLAMA32_3B] {
        for bs in [2usize, 4, 8, 16, 32, 64] {
            let e = Accel::ecco().decode_step(m, bs, 4096);
            let p = Accel::p3llm().decode_step(m, bs, 4096);
            t.row(vec![
                m.name.into(),
                bs.to_string(),
                f2(e.attn.ns / 1e6),
                f2(e.linear.ns / 1e6),
                f2(e.total_ns() / 1e6),
                f2(p.attn.ns / 1e6),
                f2(p.linear.ns / 1e6),
                f2(p.total_ns() / 1e6),
                f2(e.total_ns() / p.total_ns()),
            ]);
        }
    }
    t.print();
    println!(
        "expected shape: linear latency converges by bs>=8 (P3 offloads \
         linears to NPU); P3 keeps winning on attention (GQA low reuse)"
    );
    t.save(p3llm::benchkit::reports_dir(), "fig16_largebatch").unwrap();
}
