//! Overload degradation curves (extension experiment, not a paper
//! figure): sweep offered load past the modeled saturation point of
//! the tiered overload scenarios and report per-tier goodput, SLO
//! attainment, and tail TTFT for each preemptive victim policy next to
//! a FIFO baseline (same tiers, no preemption).
//!
//! Load factors are offered/saturation ratios
//! (`Scenario::with_load_factor`), so "2x" means the same thing on
//! every system.  The absolute SLO budgets of the scenarios are not
//! meaningful across models, so each scenario is judged against a
//! calibrated budget: 8x the interactive p95 TTFT of a light (0.1x)
//! FIFO run.  The harness asserts that no run loses requests, that the
//! preemptive engines actually preempt on the CI-sized scenario, and
//! that at 2x saturation interactive attainment under preemption is
//! strictly above FIFO's -- graceful degradation instead of collapse.
//!
//! `--save` additionally emits `BENCH_overload_degradation.json`
//! through the shared `p3llm::benchkit::save_bench_json` emitter:
//! a flat `{bench, config, metric, value, seed}` array covering the
//! per-run counters and the per-tier goodput/attainment/p99 at every
//! `scenario x victim x load` point.

use p3llm::benchkit::BenchRecord;
use p3llm::report::{f2, f3, Table};
use p3llm::sched::SloClass;
use p3llm::traffic::{scenario_by_name, LoadReport, Scenario, SloSpec};

const SYSTEM: &str = "P3-LLM";
const SEED: u64 = 7;
const LOADS: [f64; 5] = [0.5, 1.0, 1.5, 2.0, 3.0];

fn run(
    sc: &Scenario,
    victim: Option<&'static str>,
    load: f64,
    slo: Option<SloSpec>,
) -> LoadReport {
    let mut s = sc
        .clone()
        .with_load_factor(SYSTEM, load, SEED)
        .expect("load normalization");
    s.victim = victim;
    let mut engine = s.engine(SYSTEM, None).expect("engine build");
    let mut plan = s.runner(SEED);
    if let Some(slo) = slo {
        plan.slo = slo;
    }
    plan.run_with_saturation(&mut engine, s.saturation_tok_s(SYSTEM))
        .expect("closed-loop run")
        .report
}

fn interactive(r: &LoadReport) -> &LoadReport {
    r.per_class
        .iter()
        .find(|(c, _)| *c == SloClass::Interactive)
        .map(|(_, cr)| cr)
        .expect("tiered run carries an interactive tier")
}

fn main() {
    let save_json = std::env::args().any(|a| a == "--save");
    let mut t = Table::new(
        format!(
            "overload degradation on {SYSTEM}, seed {SEED} \
             (load = offered/saturation, calibrated TTFT budgets)"
        ),
        &[
            "scenario",
            "victim",
            "load",
            "tier",
            "done",
            "attain %",
            "goodput req/s",
            "p99 TTFT ms",
            "preempt",
            "swapped",
            "recomputed",
        ],
    );
    let mut recs: Vec<BenchRecord> = vec![];
    for name in ["smoke-overload", "flash-crowd"] {
        let sc = scenario_by_name(name).expect("registry scenario");
        assert!(sc.tiers.is_some(), "{name} must be a tiered scenario");
        let calib = run(&sc, None, 0.1, None);
        let t_base = interactive(&calib).ttft_ms.p95;
        assert!(t_base > 0.0, "{name}: empty calibration run");
        let budget =
            SloSpec { ttft_ms: 8.0 * t_base, tpot_ms: f64::INFINITY };
        recs.push(BenchRecord::new(
            format!("scenario={name}"),
            "ttft_budget_ms",
            budget.ttft_ms,
        ));
        // (victim label, interactive attainment at 2x saturation)
        let mut att2: Vec<(&str, f64)> = vec![];
        for &load in &LOADS {
            for victim in [Some("recompute"), Some("swap"), None] {
                let label = victim.unwrap_or("fifo");
                let r = run(&sc, victim, load, Some(budget));
                assert_eq!(
                    r.completed, r.offered,
                    "{name}/{label} at {load}x lost requests"
                );
                if name == "smoke-overload"
                    && victim.is_some()
                    && load >= 2.0
                {
                    assert!(
                        r.preemptions > 0,
                        "{name}/{label} at {load}x never preempted"
                    );
                }
                for (class, cr) in &r.per_class {
                    t.row(vec![
                        name.into(),
                        label.into(),
                        format!("{load}x"),
                        class.name().into(),
                        format!("{}/{}", cr.completed, cr.offered),
                        f2(cr.slo_attainment * 100.0),
                        f3(cr.goodput_req_s),
                        f2(cr.ttft_ms.p99),
                        cr.preemptions.to_string(),
                        cr.pages_swapped.to_string(),
                        cr.pages_recomputed.to_string(),
                    ]);
                    let cfg = format!(
                        "scenario={name},victim={label},load={load},\
                         tier={}",
                        class.name()
                    );
                    for (metric, value) in [
                        ("goodput_req_s", cr.goodput_req_s),
                        ("slo_attainment", cr.slo_attainment),
                        ("ttft_p99_ms", cr.ttft_ms.p99),
                    ] {
                        recs.push(BenchRecord::new(
                            cfg.as_str(),
                            metric,
                            value,
                        ));
                    }
                }
                let cfg =
                    format!("scenario={name},victim={label},load={load}");
                for (metric, value) in [
                    ("offered", r.offered as f64),
                    ("completed", r.completed as f64),
                    ("preemptions", r.preemptions as f64),
                    ("pages_swapped", r.pages_swapped as f64),
                    ("pages_recomputed", r.pages_recomputed as f64),
                ] {
                    recs.push(BenchRecord::new(cfg.as_str(), metric, value));
                }
                if (load - 2.0).abs() < 1e-9 {
                    att2.push((label, interactive(&r).slo_attainment));
                }
            }
        }
        let fifo = att2
            .iter()
            .find(|(l, _)| *l == "fifo")
            .map(|(_, a)| *a)
            .expect("FIFO baseline at 2x");
        for &(label, att) in &att2 {
            if label == "fifo" {
                continue;
            }
            println!(
                "check: {name} at 2x: {label} interactive attainment \
                 {att:.3} vs FIFO {fifo:.3} (budget {:.3} ms)",
                budget.ttft_ms
            );
            assert!(
                att > fifo,
                "{name}: {label} interactive attainment {att:.3} not \
                 strictly above FIFO's {fifo:.3} at 2x saturation"
            );
        }
    }
    t.print();
    println!(
        "expected shape: FIFO interactive attainment collapses past 1x \
         while the preemptive engines hold it by evicting best-effort \
         decodes (recompute re-prefills, swap pays the modeled \
         slow-tier transfer); batch/best-effort degrade gracefully \
         instead of everything failing together"
    );
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "overload_degradation").unwrap();
    if save_json {
        let p = p3llm::benchkit::save_bench_json(
            "overload_degradation",
            SEED,
            &recs,
        )
        .expect("write BENCH_overload_degradation.json");
        println!("saved {}", p.display());
    }
}
