//! Tiered-KV hierarchy curves (extension experiment, not a paper
//! figure): sweep the HBM hot-tier fraction against the
//! ahead-of-decode prefetch depth on the long-document scenario and
//! report TTFT/TPOT next to the page-migration counters.
//!
//! The claim under test is the one the `memtier --smoke` CI gate
//! enforces: whenever the working set overflows the hot tier (hot
//! fraction < 1), turning the prefetcher on strictly lowers mean
//! decode TPOT versus pure demand paging on identical seeds, because
//! prefetched pages cross the CXL link overlapped with decode while
//! demand misses stall the engine clock.  At hot fraction 1.0 the
//! hierarchy must be inert: no pages migrate and the timings match the
//! untiered engine exactly.
//!
//! Emits `BENCH_memtier.json` through the shared
//! `p3llm::benchkit::save_bench_json` emitter: a flat
//! `{bench, config, metric, value, seed}` array covering every
//! `hot x depth` point.

use p3llm::benchkit::BenchRecord;
use p3llm::report::{f2, f3, Table};
use p3llm::traffic::{scenario_by_name, LoadReport};

const SYSTEM: &str = "P3-LLM";
const SEED: u64 = 7;
const HOTS: [f64; 3] = [0.25, 0.5, 1.0];
const DEPTHS: [usize; 3] = [0, 4, 8];

fn run_tiered(hot: f64, depth: usize) -> LoadReport {
    let sc = scenario_by_name("smoke-longdoc").expect("registry scenario");
    let mut engine = sc
        .engine_tiered(SYSTEM, None, hot, depth)
        .expect("tiered engine build");
    sc.runner(SEED)
        .run_with_saturation(&mut engine, sc.saturation_tok_s(SYSTEM))
        .expect("closed-loop run")
        .report
}

fn main() {
    let sc = scenario_by_name("smoke-longdoc").expect("registry scenario");
    let mut t = Table::new(
        format!(
            "memtier: hot-tier fraction x prefetch depth on {SYSTEM}, \
             {} scenario, seed {SEED}",
            sc.name
        ),
        &[
            "hot",
            "depth",
            "done",
            "mean TTFT ms",
            "mean TPOT ms",
            "p95 TPOT ms",
            "prefetched",
            "demand",
        ],
    );
    let mut recs: Vec<BenchRecord> = vec![];

    // untiered reference: the hierarchy disabled entirely
    let mut base_eng = sc.engine(SYSTEM, None).expect("engine build");
    let base = sc
        .runner(SEED)
        .run_with_saturation(&mut base_eng, sc.saturation_tok_s(SYSTEM))
        .expect("closed-loop run")
        .report;
    assert_eq!(base.completed, base.offered, "untiered baseline lost requests");

    for &hot in &HOTS {
        // (depth, report) points at this hot fraction
        let mut points: Vec<(usize, LoadReport)> = vec![];
        for &depth in &DEPTHS {
            let r = run_tiered(hot, depth);
            assert_eq!(
                r.completed, r.offered,
                "hot={hot} depth={depth} lost requests"
            );
            t.row(vec![
                format!("{hot}"),
                depth.to_string(),
                format!("{}/{}", r.completed, r.offered),
                f2(r.ttft_ms.mean),
                f3(r.tpot_ms.mean),
                f3(r.tpot_ms.p95),
                r.pages_prefetched.to_string(),
                r.pages_demand.to_string(),
            ]);
            let cfg = format!("hot={hot},depth={depth}");
            for (metric, value) in [
                ("ttft_mean_ms", r.ttft_ms.mean),
                ("tpot_mean_ms", r.tpot_ms.mean),
                ("tpot_p95_ms", r.tpot_ms.p95),
                ("pages_prefetched", r.pages_prefetched as f64),
                ("pages_demand", r.pages_demand as f64),
            ] {
                recs.push(BenchRecord::new(cfg.as_str(), metric, value));
            }
            points.push((depth, r));
        }
        let demand = &points
            .iter()
            .find(|(d, _)| *d == 0)
            .expect("depth-0 point")
            .1;
        if hot >= 1.0 {
            // full hot tier: the hierarchy must be inert at any depth
            for (depth, r) in &points {
                assert_eq!(
                    r.pages_prefetched + r.pages_demand,
                    0,
                    "hot=1.0 depth={depth} migrated pages"
                );
                assert_eq!(
                    r.tpot_ms.mean, base.tpot_ms.mean,
                    "hot=1.0 depth={depth} perturbed decode timing"
                );
            }
        } else {
            // overflowing hot tier: demand paging stalls, prefetch
            // overlaps -- strictly lower mean TPOT at every depth > 0
            assert!(
                demand.pages_demand > 0,
                "hot={hot} never overflowed the hot tier"
            );
            assert_eq!(demand.pages_prefetched, 0);
            for (depth, r) in points.iter().filter(|(d, _)| *d > 0) {
                assert!(
                    r.pages_prefetched > 0,
                    "hot={hot} depth={depth}: prefetcher never fired"
                );
                println!(
                    "check: hot={hot} depth={depth}: prefetch mean TPOT \
                     {:.4} ms vs demand-paging {:.4} ms",
                    r.tpot_ms.mean, demand.tpot_ms.mean
                );
                assert!(
                    r.tpot_ms.mean < demand.tpot_ms.mean,
                    "hot={hot} depth={depth}: prefetch mean TPOT \
                     {:.4} ms not strictly below demand paging's {:.4} ms",
                    r.tpot_ms.mean,
                    demand.tpot_ms.mean
                );
            }
        }
    }
    t.print();
    println!(
        "expected shape: at hot fraction 1.0 the tier is inert (no \
         migrations, untiered timings); below it, demand paging pays a \
         CXL stall per cold page each step while the prefetcher pulls \
         the next attention window overlapped with decode, so TPOT \
         falls monotonically as depth grows until the window is covered"
    );
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "memtier").unwrap();
    let p = p3llm::benchkit::save_bench_json("memtier", SEED, &recs)
        .expect("write BENCH_memtier.json");
    println!("saved {}", p.display());
}
