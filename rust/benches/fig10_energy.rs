//! Fig. 10: normalized energy consumption vs batch size with the
//! attention/linear breakdown (ctx 4K).

use p3llm::accel::fig9_systems;
use p3llm::config::llm::eval_models;
use p3llm::report::{f2, f3, Table};

fn main() {
    let mut t = Table::new(
        "Fig 10: normalized energy (P3-LLM total = 1.0) + attn/linear split",
        &["model", "bs", "system", "attn", "linear", "other", "total"],
    );
    let systems = fig9_systems();
    let mut sums = vec![0.0f64; systems.len()];
    let mut n = 0;
    for m in eval_models() {
        for bs in [1usize, 2, 4, 8] {
            let p3 = systems.last().unwrap().decode_step(&m, bs, 4096).total_pj();
            for (i, a) in systems.iter().enumerate() {
                let c = a.decode_step(&m, bs, 4096);
                t.row(vec![
                    m.name.into(),
                    bs.to_string(),
                    a.name.into(),
                    f3(c.attn.pj / p3),
                    f3(c.linear.pj / p3),
                    f3(c.other.pj / p3),
                    f2(c.total_pj() / p3),
                ]);
                sums[i] += c.total_pj() / p3;
            }
            n += 1;
        }
    }
    t.print();
    let mut avg = Table::new(
        "Fig 10 summary: average energy vs P3 (paper: 6.3x NPU, 3.5x HBM-PIM, 2.1x Ecco)",
        &["system", "energy ratio"],
    );
    for (i, a) in systems.iter().enumerate() {
        avg.row(vec![a.name.into(), f2(sums[i] / n as f64)]);
    }
    avg.print();
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, "fig10_energy").unwrap();
    avg.save(&dir, "fig10_summary").unwrap();
}
