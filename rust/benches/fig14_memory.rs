//! Fig. 14: weights + KV memory during decoding (bs=8, ctx=4K) for
//! FP16, SmoothQuant, AWQ, Ecco and P3-LLM.

use p3llm::config::llm::eval_models;
use p3llm::config::scheme::QuantScheme;
use p3llm::report::{f2, Table};
use p3llm::workload::memory_breakdown;

fn main() {
    let schemes = [
        QuantScheme::fp16(),
        QuantScheme::smoothquant(),
        QuantScheme::awq(),
        QuantScheme::ecco(),
        QuantScheme::p3llm(),
    ];
    let mut t = Table::new(
        "Fig 14: weights+KV GB at bs=8 ctx=4K (paper: Ecco 3.8x, P3 3.7x reduction)",
        &["model", "FP16", "SmoothQuant", "AWQ", "Ecco", "P3-LLM", "P3 reduction"],
    );
    for m in eval_models() {
        let gb: Vec<f64> = schemes
            .iter()
            .map(|s| {
                let mb = memory_breakdown(
                    &m, 8, 4096, s.bits.weights, 16.0, s.bits.kv, 16.0,
                );
                (mb.weights + mb.kv) / 1e9
            })
            .collect();
        let mut row = vec![m.name.to_string()];
        row.extend(gb.iter().map(|&x| f2(x)));
        row.push(f2(gb[0] / gb[4]));
        t.row(row);
    }
    t.print();
    t.save(p3llm::benchkit::reports_dir(), "fig14_memory").unwrap();
}
