//! Fleet-scaling curves (extension experiment, not a paper figure):
//! the `chat-poisson` scenario weak-scaled across 1/2/4/8 NPU-PIM
//! replicas under every routing policy -- fleet goodput, SLO
//! attainment, utilization skew, and scaling efficiency against the
//! 1-replica baseline.
//!
//! Weak scaling (`Scenario::for_fleet`): an n-replica fleet is offered
//! n x the requests at n x the arrival rate, so per-replica load is
//! constant and goodput should grow ~linearly when routing spreads the
//! load.  Sub-linear is expected (queueing + routing granularity);
//! flat is a routing bug -- the harness asserts JSQ reaches at least
//! 2.5x the 1-replica goodput at 4 replicas.

use p3llm::benchkit::BenchRecord;
use p3llm::cluster::{all_policy_names, Cluster};
use p3llm::report::{f2, Table};
use p3llm::traffic::scenario_by_name;

fn main() {
    let sc = scenario_by_name("chat-poisson").expect("registry scenario");
    let system = "P3-LLM";
    let seed = 7u64;
    let mut t = Table::new(
        format!(
            "cluster scaling: {} on {system} (weak-scaled, seed {seed})",
            sc.name
        ),
        &[
            "policy",
            "replicas",
            "done",
            "SLO %",
            "goodput tok/s",
            "tok/s",
            "p95 TTFT ms",
            "skew",
            "scale-eff %",
        ],
    );
    let mut jsq_curve: Vec<(usize, f64)> = vec![];
    let mut recs: Vec<BenchRecord> = vec![];
    for policy in all_policy_names() {
        let mut base_goodput = 0.0f64;
        for n in [1usize, 2, 4, 8] {
            let fleet_sc = sc
                .clone()
                .for_fleet(n)
                .expect("fleet transform");
            let mut fleet =
                Cluster::from_scenario(&sc, system, None, n, policy)
                    .expect("cluster build");
            let out = fleet
                .run(&fleet_sc.runner(seed), sc.saturation_tok_s(system))
                .expect("cluster run");
            if n == 1 {
                base_goodput = out.report.fleet.goodput_tok_s;
            }
            let rep = out.report.with_baseline(base_goodput);
            let r = &rep.fleet;
            if policy == "jsq" {
                jsq_curve.push((n, r.goodput_tok_s));
            }
            let cfg = format!("policy={policy},replicas={n}");
            for (metric, value) in [
                ("goodput_tok_s", r.goodput_tok_s),
                ("throughput_tok_s", r.throughput_tok_s),
                ("slo_attainment", r.slo_attainment),
                ("ttft_p95_ms", r.ttft_ms.p95),
                ("util_skew", rep.util_skew),
            ] {
                recs.push(BenchRecord::new(cfg.as_str(), metric, value));
            }
            if let Some(e) = rep.scaling_efficiency {
                recs.push(BenchRecord::new(
                    cfg.as_str(),
                    "scaling_efficiency",
                    e,
                ));
            }
            t.row(vec![
                policy.into(),
                n.to_string(),
                format!("{}/{}", r.completed, r.offered),
                f2(r.slo_attainment * 100.0),
                f2(r.goodput_tok_s),
                f2(r.throughput_tok_s),
                f2(r.ttft_ms.p95),
                f2(rep.util_skew),
                rep.scaling_efficiency
                    .map(|e| f2(e * 100.0))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t.print();
    let g1 = jsq_curve
        .iter()
        .find(|(n, _)| *n == 1)
        .map(|(_, g)| *g)
        .unwrap_or(0.0);
    let g4 = jsq_curve
        .iter()
        .find(|(n, _)| *n == 4)
        .map(|(_, g)| *g)
        .unwrap_or(0.0);
    println!(
        "check: JSQ goodput 1 -> 4 replicas: {:.2} -> {:.2} tok/s \
         ({:.2}x; floor 2.5x)",
        g1,
        g4,
        if g1 > 0.0 { g4 / g1 } else { 0.0 }
    );
    assert!(
        g1 > 0.0 && g4 >= 2.5 * g1,
        "fleet goodput failed to scale: {g1} tok/s at 1 replica vs \
         {g4} tok/s at 4 (JSQ should spread chat-poisson load)"
    );
    println!(
        "expected shape: goodput grows near-linearly under jsq/kv \
         (balanced skew), round-robin trails under length skew, and \
         pd trades TTFT for decode-pool utilization via the modeled \
         KV handoff"
    );
    t.save(p3llm::benchkit::reports_dir(), "cluster_scaling").unwrap();
    let p = p3llm::benchkit::save_bench_json("cluster_scaling", seed, &recs)
        .expect("write BENCH_cluster_scaling.json");
    println!("saved {}", p.display());
}
