//! Offline stub of the `xla` PJRT bindings used by `p3llm::runtime`.
//!
//! The build environment has no registry access and no PJRT shared
//! library, so this crate provides the exact API surface the runtime
//! layer consumes.  Host-side [`Literal`] construction and inspection
//! are fully functional (they are plain byte buffers), while anything
//! that needs a PJRT client -- compilation, device buffers, execution
//! -- returns [`XlaError`] with a clear message.  On a machine with the
//! real bindings, point the workspace member `rust/vendor/xla` at them
//! (or use a `[patch]` section); no `p3llm` source changes are needed.
//! The `SimBackend` serving path never touches this crate's runtime
//! half, so the full engine lifecycle works against the stub.

use std::fmt;

/// Error type mirroring the real bindings' error enum well enough for
/// `{e:?}` formatting at the call sites.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn no_pjrt<T>() -> Result<T> {
    Err(XlaError(
        "PJRT runtime unavailable: p3llm was built against the offline \
         xla stub (rust/vendor/xla). Swap in the real bindings to run \
         AOT graphs; the sim backend works without them."
            .to_string(),
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(&self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Element types a [`Literal`] can be viewed as from host code.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn from_le_slice(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_slice(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_slice(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le_slice(b: &[u8]) -> Self {
        b[0]
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side tensor: shape + little-endian bytes.  Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product::<usize>().max(1);
        if elems * ty.byte_size() != data.len() {
            return Err(XlaError(format!(
                "literal shape {dims:?} x {ty:?} wants {} bytes, got {}",
                elems * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(XlaError(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(self.ty.byte_size())
            .map(T::from_le_slice)
            .collect())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            ty: self.ty,
            dims: self.dims.iter().map(|&d| d as i64).collect(),
        })
    }

    /// Real literals returned by tupled executables decompose into
    /// their leaves; stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        no_pjrt()
    }
}

#[derive(Debug, Clone)]
pub struct PjRtDevice;

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_pjrt()
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        no_pjrt()
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_pjrt()
    }

    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_pjrt()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// The stub cannot create a client: the serving engine's PJRT
    /// backend fails fast here with an actionable message.
    pub fn cpu() -> Result<PjRtClient> {
        no_pjrt()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        no_pjrt()
    }

    pub fn addressable_devices(&self) -> Vec<PjRtDevice> {
        vec![]
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        no_pjrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> =
            xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4],
        )
        .is_err());
    }

    #[test]
    fn runtime_surface_fails_fast() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
