//! LLM workload model: the operator trace of one decode step (or a
//! prefill) plus per-operand memory accounting (Fig. 3a / Fig. 14).
//!
//! A trace is a list of [`Op`]s; accelerator models (`accel/`) map each
//! op to NPU or PIM and cost it with the `sim` timing models.

use crate::config::llm::LlmConfig;

/// Which stored operand a matrix op streams (decides its precision
/// under a scheme and its Fig. 10 attn/linear energy class).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    Weight,
    KeyCache,
    ValueCache,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Linear,
    Attention,
    Other,
}

#[derive(Debug, Clone)]
pub enum Op {
    /// `count` independent GEMMs: [m, k] x stored [k, n].  `m` rows
    /// share the same stored matrix (the data-reuse opportunity the
    /// paper's Section III-B analysis is about).
    Gemm {
        name: &'static str,
        m: usize,
        k: usize,
        n: usize,
        count: usize,
        operand: Operand,
        class: OpClass,
    },
    /// Element-wise / reduction work on the NPU vector unit
    /// (RoPE, softmax, norms, dequant-rescale fusion epilogues).
    Vector { name: &'static str, elems: usize, class: OpClass },
}

impl Op {
    pub fn class(&self) -> OpClass {
        match self {
            Op::Gemm { class, .. } | Op::Vector { class, .. } => *class,
        }
    }

    /// total multiply-accumulates
    pub fn macs(&self) -> f64 {
        match self {
            Op::Gemm { m, k, n, count, .. } => {
                (*m as f64) * (*k as f64) * (*n as f64) * (*count as f64)
            }
            Op::Vector { .. } => 0.0,
        }
    }

    /// stored-operand elements streamed once (one pass over the matrix)
    pub fn stored_elems(&self) -> f64 {
        match self {
            Op::Gemm { k, n, count, .. } => (*k as f64) * (*n as f64) * (*count as f64),
            Op::Vector { .. } => 0.0,
        }
    }
}

/// One decode step for `bs` concurrent requests at context length `ctx`
/// (all requests at the same length -- the batch-sweep experiments use
/// uniform contexts like the paper).
pub fn decode_trace(m: &LlmConfig, bs: usize, ctx: usize) -> Vec<Op> {
    let l = m.layers;
    let g = m.gqa_group();
    let qkv_n = (m.n_heads + 2 * m.n_kv) * m.head_dim;
    let attn_dim = m.n_heads * m.head_dim;
    vec![
        Op::Gemm {
            name: "qkv_proj",
            m: bs,
            k: m.hidden,
            n: qkv_n,
            count: l,
            operand: Operand::Weight,
            class: OpClass::Linear,
        },
        Op::Vector {
            name: "rope",
            elems: bs * (m.n_heads + m.n_kv) * m.head_dim * l,
            class: OpClass::Other,
        },
        // Q.K^T: per (request, kv head), G query heads share the key
        // matrix [ctx, head_dim]
        Op::Gemm {
            name: "qk",
            m: g,
            k: m.head_dim,
            n: ctx,
            count: bs * m.n_kv * l,
            operand: Operand::KeyCache,
            class: OpClass::Attention,
        },
        Op::Vector {
            name: "softmax",
            elems: bs * m.n_heads * ctx * l,
            class: OpClass::Attention,
        },
        // P.V: same sharing structure over the value matrix [ctx, head_dim]
        Op::Gemm {
            name: "pv",
            m: g,
            k: ctx,
            n: m.head_dim,
            count: bs * m.n_kv * l,
            operand: Operand::ValueCache,
            class: OpClass::Attention,
        },
        Op::Gemm {
            name: "o_proj",
            m: bs,
            k: attn_dim,
            n: m.hidden,
            count: l,
            operand: Operand::Weight,
            class: OpClass::Linear,
        },
        Op::Gemm {
            name: "gate_up",
            m: bs,
            k: m.hidden,
            n: 2 * m.ffn,
            count: l,
            operand: Operand::Weight,
            class: OpClass::Linear,
        },
        Op::Vector {
            name: "silu_mul",
            elems: bs * m.ffn * l,
            class: OpClass::Other,
        },
        Op::Gemm {
            name: "down",
            m: bs,
            k: m.ffn,
            n: m.hidden,
            count: l,
            operand: Operand::Weight,
            class: OpClass::Linear,
        },
        Op::Vector {
            name: "norms",
            elems: bs * m.hidden * (2 * l + 1),
            class: OpClass::Other,
        },
        Op::Gemm {
            name: "lm_head",
            m: bs,
            k: m.hidden,
            n: m.vocab,
            count: 1,
            operand: Operand::Weight,
            class: OpClass::Linear,
        },
    ]
}

/// Prefill over `n_tokens` prompt tokens (GEMM-shaped, NPU territory).
pub fn prefill_trace(m: &LlmConfig, bs: usize, n_tokens: usize) -> Vec<Op> {
    let mut ops = decode_trace(m, bs * n_tokens, n_tokens);
    // attention in prefill is causal [T, T] per head, not [1, ctx]:
    for op in ops.iter_mut() {
        if let Op::Gemm { name, m: mm, n, count, .. } = op {
            if *name == "qk" {
                *mm = m.gqa_group() * n_tokens;
                *n = n_tokens;
                *count = bs * m.n_kv * m.layers;
            } else if *name == "pv" {
                *mm = m.gqa_group() * n_tokens;
                *count = bs * m.n_kv * m.layers;
            }
        }
    }
    ops
}

/// Per-operand memory footprint in bytes at the given element widths
/// (Fig. 3a uses fp16 = 16 bits everywhere; Fig. 14 plugs scheme bits).
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub weights: f64,
    pub kv: f64,
    pub activations: f64,
    pub scores: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.weights + self.kv + self.activations + self.scores
    }
}

pub fn memory_breakdown(
    m: &LlmConfig,
    bs: usize,
    ctx: usize,
    w_bits: f64,
    a_bits: f64,
    kv_bits: f64,
    p_bits: f64,
) -> MemoryBreakdown {
    let weights = m.n_params() as f64 * w_bits / 8.0;
    let kv = (bs * m.kv_elems(ctx)) as f64 * kv_bits / 8.0;
    // live activations: residual stream + the widest intermediate (ffn),
    // released after each module (Section III-A)
    let act = (bs * ctx * (m.hidden + 2 * m.ffn)) as f64 * a_bits / 8.0;
    // attention scores for one layer's worth (released immediately)
    let scores = (bs * m.n_heads * ctx) as f64 * p_bits / 8.0;
    MemoryBreakdown { weights, kv, activations: act, scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llm::{LLAMA2_7B, LLAMA31_8B};

    #[test]
    fn decode_macs_scale_with_batch() {
        let t1: f64 = decode_trace(&LLAMA2_7B, 1, 4096).iter().map(Op::macs).sum();
        let t4: f64 = decode_trace(&LLAMA2_7B, 4, 4096).iter().map(Op::macs).sum();
        assert!((t4 / t1 - 4.0).abs() < 0.01);
    }

    #[test]
    fn linear_macs_about_2x_params() {
        // one decode token: ~2 MACs per weight-parameter... actually 1
        // MAC per parameter of the matmul weights
        let macs: f64 = decode_trace(&LLAMA2_7B, 1, 1)
            .iter()
            .filter(|o| o.class() == OpClass::Linear)
            .map(Op::macs)
            .sum();
        let params = LLAMA2_7B.n_params() as f64;
        assert!((macs / params - 1.0).abs() < 0.1, "{}", macs / params);
    }

    #[test]
    fn gqa_reduces_kv_traffic_not_attention_macs() {
        let mha: f64 = decode_trace(&LLAMA2_7B, 1, 4096)
            .iter()
            .filter(|o| matches!(o, Op::Gemm { operand: Operand::KeyCache, .. }))
            .map(Op::stored_elems)
            .sum();
        let gqa: f64 = decode_trace(&LLAMA31_8B, 1, 4096)
            .iter()
            .filter(|o| matches!(o, Op::Gemm { operand: Operand::KeyCache, .. }))
            .map(Op::stored_elems)
            .sum();
        assert!(mha / gqa > 3.0); // 4x fewer kv heads
    }

    #[test]
    fn memory_kv_grows_with_batch_weights_constant() {
        let a = memory_breakdown(&LLAMA2_7B, 1, 4096, 16.0, 16.0, 16.0, 16.0);
        let b = memory_breakdown(&LLAMA2_7B, 8, 4096, 16.0, 16.0, 16.0, 16.0);
        assert_eq!(a.weights, b.weights);
        assert!((b.kv / a.kv - 8.0).abs() < 0.01);
        // Fig 3a: at bs=8 ctx=4K, Llama-2-7B KV rivals weights
        assert!(b.kv > 0.8 * b.weights);
    }

    #[test]
    fn prefill_is_compute_heavy() {
        let d: f64 = decode_trace(&LLAMA2_7B, 1, 512).iter().map(Op::macs).sum();
        let p: f64 = prefill_trace(&LLAMA2_7B, 1, 512).iter().map(Op::macs).sum();
        assert!(p > 100.0 * d);
    }
}
