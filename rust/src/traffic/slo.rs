//! SLO targets and the load report: what "fast enough" means and how
//! much of the offered load met it.
//!
//! Goodput (SLO-meeting work per second) is the paper-comparison
//! metric: a system that decodes fast but queues prefills past the
//! TTFT budget gets throughput credit and zero goodput, which is
//! exactly the distinction the Section I chatbot scenario draws.

use crate::coordinator::{Metrics, Percentiles, Request};
use crate::sched::SloClass;

/// Latency targets for one request class: time-to-first-token and
/// mean per-output-token budgets, both in engine-clock milliseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
}

impl SloSpec {
    /// Interactive chatbot: the 250 ms TTFT budget the paper adopts
    /// from DistServe, plus 50 ms/token (~20 tok/s reading speed).
    pub fn chatbot() -> Self {
        SloSpec { ttft_ms: 250.0, tpot_ms: 50.0 }
    }

    /// Latency-tolerant batch work (summarization, RAG synthesis).
    pub fn relaxed() -> Self {
        SloSpec { ttft_ms: 2000.0, tpot_ms: 100.0 }
    }

    /// Keystroke-adjacent completion: tight first-token budget.
    pub fn interactive_tight() -> Self {
        SloSpec { ttft_ms: 150.0, tpot_ms: 30.0 }
    }

    /// Does a finished request meet this SLO?  `tpot_ms` is `None` for
    /// single-token outputs, which only the TTFT target judges.
    pub fn meets(&self, ttft_ms: f64, tpot_ms: Option<f64>) -> bool {
        ttft_ms <= self.ttft_ms
            && tpot_ms.map_or(true, |t| t <= self.tpot_ms)
    }

    /// Widen (factor > 1) or tighten (< 1) both budgets -- how a
    /// scenario's base spec becomes a lower tier's looser target
    /// ([`SloClass::slo_factor`]).
    pub fn scaled(&self, factor: f64) -> Self {
        SloSpec {
            ttft_ms: self.ttft_ms * factor,
            tpot_ms: self.tpot_ms * factor,
        }
    }
}

/// Per-request timeline observed by the closed-loop runner.  All
/// timestamps are absolute engine-clock ms; `arrival_ms` is the
/// scheduled arrival, the origin every latency below is measured from
/// (so time spent queued before the engine could even accept the
/// request counts against the SLO, as it does for a real client).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReqRecord {
    pub arrival_ms: f64,
    pub submitted_ms: f64,
    pub prefill_start_ms: Option<f64>,
    pub first_token_ms: Option<f64>,
    pub finished_ms: Option<f64>,
    pub prompt_len: usize,
    pub tokens_generated: usize,
    /// prompt tokens served from the shared-prefix KV cache (0 = miss
    /// or cache disabled): their prefill compute was skipped
    pub cached_prefix_tokens: usize,
    /// SLO priority tier the request was submitted under
    pub class: SloClass,
    /// mid-decode evictions this request absorbed
    pub preemptions: usize,
    /// KV pages migrated to the slow tier across its swap preemptions
    pub pages_swapped: usize,
    /// KV pages dropped and re-prefilled across its recompute
    /// preemptions
    pub pages_recomputed: usize,
    /// cold-tier KV pages prefetched back to HBM ahead of this
    /// request's decode steps (tiered engines only)
    pub pages_prefetched: usize,
    /// cold-tier KV pages demand-migrated at step time, each stalling
    /// this request's decode (tiered engines only)
    pub pages_demand: usize,
}

impl ReqRecord {
    /// Snapshot one engine request against its scheduled arrival --
    /// the one place the timeline-extraction rule lives.  A wall-clock
    /// backend can accept a request *before* its scheduled arrival
    /// (`advance_to` is a no-op there); the effective arrival is then
    /// the submit instant, so latencies never go negative.
    pub fn from_request(req: &Request, scheduled_arrival_ms: f64) -> Self {
        ReqRecord {
            arrival_ms: scheduled_arrival_ms.min(req.submitted_ms),
            submitted_ms: req.submitted_ms,
            prefill_start_ms: req.prefill_start_ms,
            first_token_ms: req.first_token_ms,
            finished_ms: req.finished_ms,
            prompt_len: req.prompt.len(),
            tokens_generated: req.generated.len(),
            cached_prefix_tokens: req.cached_prefix_tokens,
            class: req.class,
            preemptions: req.preemptions,
            pages_swapped: req.pages_swapped,
            pages_recomputed: req.pages_recomputed,
            pages_prefetched: req.pages_prefetched,
            pages_demand: req.pages_demand,
        }
    }

    /// Client-observed time to first token (from arrival).
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_ms.map(|t| t - self.arrival_ms)
    }

    /// Time from arrival until prefill began (queueing + admission).
    pub fn queue_delay_ms(&self) -> Option<f64> {
        self.prefill_start_ms.map(|t| t - self.arrival_ms)
    }

    /// Mean per-token decode latency (excludes the prefill-emitted
    /// first token); `None` until finished or for 1-token outputs.
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_ms, self.finished_ms) {
            (Some(first), Some(fin)) if self.tokens_generated > 1 => {
                Some((fin - first) / (self.tokens_generated - 1) as f64)
            }
            _ => None,
        }
    }

    pub fn finished(&self) -> bool {
        self.finished_ms.is_some()
    }
}

/// End-of-run load-generation report: goodput and SLO attainment on
/// top of the engine's latency percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadReport {
    /// requests the arrival process offered
    pub offered: usize,
    pub completed: usize,
    /// completed requests meeting the [`SloSpec`]
    pub slo_met: usize,
    /// `slo_met / offered` (unfinished requests count as misses)
    pub slo_attainment: f64,
    /// first arrival -> last completion (ms)
    pub makespan_ms: f64,
    /// all generated tokens per second over the makespan
    pub throughput_tok_s: f64,
    /// SLO-meeting completions per second
    pub goodput_req_s: f64,
    /// tokens of SLO-meeting requests per second
    pub goodput_tok_s: f64,
    /// decode-only token rate while batching (observed saturation
    /// proxy: what the engine sustains when it is not idle/prefilling)
    pub busy_tok_s: f64,
    /// modeled peak decode throughput at the run's batch/context
    /// (from the `accel` cost model; `None` when not supplied)
    pub saturation_tok_s: Option<f64>,
    /// requests whose prefill hit the shared-prefix KV cache
    pub prefix_hits: usize,
    /// `prefix_hits / offered`
    pub prefix_hit_rate: f64,
    /// prompt tokens whose prefill compute the cache skipped
    pub prefill_tokens_saved: usize,
    /// mid-decode evictions across all requests (preemptive scheduling)
    pub preemptions: usize,
    /// KV pages migrated to the modeled slow tier by swap preemptions
    pub pages_swapped: usize,
    /// KV pages dropped and re-prefilled by recompute preemptions
    pub pages_recomputed: usize,
    /// cold-tier KV pages prefetched ahead of decode (tiered engines)
    pub pages_prefetched: usize,
    /// cold-tier KV pages demand-migrated at step time, each an
    /// engine-clock stall (tiered engines)
    pub pages_demand: usize,
    /// NPU busy ms across both interleaved sub-batch timelines (0
    /// under the serial schedule; zeroed in per-class sub-reports)
    pub npu_busy_ms: f64,
    /// PIM busy ms across both interleaved sub-batch timelines
    pub pim_busy_ms: f64,
    /// ms NPU and PIM ran concurrently (raw sum, fleet-mergeable)
    pub overlap_ms: f64,
    /// decode steps charged on the interleaved critical path
    pub interleaved_steps: u64,
    /// decode steps where the split lost and fused back to serial
    pub fused_steps: u64,
    /// ms saved vs the serial schedule across interleaved steps
    pub serial_saved_ms: f64,
    /// derived NPU‖PIM concurrency ratio in `[0, 1]`
    /// ([`Metrics::overlap_factor`])
    pub overlap_factor: f64,
    /// Per-tier breakdown, in [`SloClass::all`] order, present only
    /// when the run carried more than one tier.  Each sub-report is
    /// judged against the base SLO scaled by that tier's
    /// [`SloClass::slo_factor`]; engine-wide columns (`busy_tok_s`,
    /// `saturation_tok_s`) are zeroed/`None` in sub-reports, and
    /// their own `per_class` is empty.
    pub per_class: Vec<(SloClass, LoadReport)>,
    pub queue_delay_ms: Percentiles,
    pub ttft_ms: Percentiles,
    pub tpot_ms: Percentiles,
}

impl LoadReport {
    /// Aggregate per-request records against an SLO.  `metrics` is the
    /// engine's end-of-run snapshot (for the decode-busy rate);
    /// `saturation_tok_s` is the modeled peak to report utilization
    /// against, when the caller knows it.
    pub fn from_records(
        records: &[ReqRecord],
        slo: &SloSpec,
        metrics: &Metrics,
        saturation_tok_s: Option<f64>,
    ) -> Self {
        Self::from_records_inner(records, slo, metrics, saturation_tok_s, true)
    }

    fn from_records_inner(
        records: &[ReqRecord],
        slo: &SloSpec,
        metrics: &Metrics,
        saturation_tok_s: Option<f64>,
        with_classes: bool,
    ) -> Self {
        let offered = records.len();
        let completed = records.iter().filter(|r| r.finished()).count();
        let prefix_hits =
            records.iter().filter(|r| r.cached_prefix_tokens > 0).count();
        let prefill_tokens_saved: usize =
            records.iter().map(|r| r.cached_prefix_tokens).sum();
        let mut slo_met = 0usize;
        let mut met_tokens = 0usize;
        let mut total_tokens = 0usize;
        let mut ttfts = vec![];
        let mut tpots = vec![];
        let mut queues = vec![];
        for r in records {
            total_tokens += r.tokens_generated;
            if let Some(t) = r.ttft_ms() {
                ttfts.push(t);
            }
            if let Some(t) = r.tpot_ms() {
                tpots.push(t);
            }
            if let Some(t) = r.queue_delay_ms() {
                queues.push(t);
            }
            if r.finished() {
                let ttft = r.ttft_ms().unwrap_or(f64::INFINITY);
                if slo.meets(ttft, r.tpot_ms()) {
                    slo_met += 1;
                    met_tokens += r.tokens_generated;
                }
            }
        }
        let t0 = records
            .iter()
            .map(|r| r.arrival_ms)
            .fold(f64::INFINITY, f64::min);
        let t_end = records
            .iter()
            .filter_map(|r| r.finished_ms)
            .fold(f64::NEG_INFINITY, f64::max);
        let makespan_ms = if t_end.is_finite() && t0.is_finite() {
            (t_end - t0).max(0.0)
        } else {
            0.0
        };
        // a zero makespan (nothing finished, or everything at one
        // instant) reports zero rates rather than dividing through an
        // epsilon into absurd throughput
        let rate = |count: f64| {
            if makespan_ms > 0.0 {
                count / (makespan_ms / 1e3)
            } else {
                0.0
            }
        };
        // per-tier breakdown: only when the run actually mixed tiers,
        // so single-class flows (every pre-existing scenario) report
        // exactly as before.  Sub-reports recurse with
        // `with_classes = false` (bounded depth) and judge each tier
        // against its widened SLO.
        let mut per_class = vec![];
        if with_classes
            && records.iter().any(|r| r.class != records[0].class)
        {
            for class in SloClass::all() {
                let subset: Vec<ReqRecord> = records
                    .iter()
                    .filter(|r| r.class == class)
                    .copied()
                    .collect();
                if subset.is_empty() {
                    continue;
                }
                per_class.push((
                    class,
                    Self::from_records_inner(
                        &subset,
                        &slo.scaled(class.slo_factor()),
                        &Metrics::default(),
                        None,
                        false,
                    ),
                ));
            }
        }
        LoadReport {
            offered,
            completed,
            slo_met,
            slo_attainment: if offered > 0 {
                slo_met as f64 / offered as f64
            } else {
                0.0
            },
            makespan_ms,
            throughput_tok_s: rate(total_tokens as f64),
            goodput_req_s: rate(slo_met as f64),
            goodput_tok_s: rate(met_tokens as f64),
            busy_tok_s: metrics.tokens_per_sec(),
            saturation_tok_s,
            prefix_hits,
            prefix_hit_rate: if offered > 0 {
                prefix_hits as f64 / offered as f64
            } else {
                0.0
            },
            prefill_tokens_saved,
            preemptions: records.iter().map(|r| r.preemptions).sum(),
            pages_swapped: records.iter().map(|r| r.pages_swapped).sum(),
            pages_recomputed: records
                .iter()
                .map(|r| r.pages_recomputed)
                .sum(),
            pages_prefetched: records
                .iter()
                .map(|r| r.pages_prefetched)
                .sum(),
            pages_demand: records.iter().map(|r| r.pages_demand).sum(),
            npu_busy_ms: metrics.npu_busy_ms,
            pim_busy_ms: metrics.pim_busy_ms,
            overlap_ms: metrics.overlap_ms,
            interleaved_steps: metrics.interleaved_steps,
            fused_steps: metrics.fused_steps,
            serial_saved_ms: metrics.serial_saved_ms,
            overlap_factor: metrics.overlap_factor(),
            per_class,
            queue_delay_ms: Percentiles::from_samples(&queues),
            ttft_ms: Percentiles::from_samples(&ttfts),
            tpot_ms: Percentiles::from_samples(&tpots),
        }
    }

    /// `throughput / modeled saturation`, when the latter is known.
    pub fn utilization(&self) -> Option<f64> {
        self.saturation_tok_s
            .map(|s| self.throughput_tok_s / s.max(1e-9))
    }

    /// One tier's SLO attainment from the [`per_class`]
    /// (LoadReport::per_class) breakdown.  `None` when the run carried
    /// no such tier (or was single-tier and has no breakdown) -- the
    /// lookup the `monitor` gates use to compare end-of-run truth
    /// against the live burn-rate alerts.
    pub fn class_attainment(&self, class: SloClass) -> Option<f64> {
        self.per_class
            .iter()
            .find(|(c, _)| *c == class)
            .map(|(_, r)| r.slo_attainment)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(
        arrival: f64,
        first: f64,
        fin: f64,
        tokens: usize,
    ) -> ReqRecord {
        ReqRecord {
            arrival_ms: arrival,
            submitted_ms: arrival,
            prefill_start_ms: Some(arrival + 1.0),
            first_token_ms: Some(first),
            finished_ms: Some(fin),
            prompt_len: 16,
            tokens_generated: tokens,
            cached_prefix_tokens: 0,
            class: SloClass::Interactive,
            preemptions: 0,
            pages_swapped: 0,
            pages_recomputed: 0,
            pages_prefetched: 0,
            pages_demand: 0,
        }
    }

    #[test]
    fn prefix_hit_columns_aggregate_from_records() {
        let mut r1 = rec(0.0, 10.0, 100.0, 5);
        r1.cached_prefix_tokens = 32;
        let mut r2 = rec(0.0, 12.0, 110.0, 5);
        r2.cached_prefix_tokens = 16;
        let r3 = rec(0.0, 14.0, 120.0, 5); // miss
        let r4 = rec(0.0, 16.0, 130.0, 5); // miss
        let r = LoadReport::from_records(
            &[r1, r2, r3, r4],
            &SloSpec::relaxed(),
            &Metrics::default(),
            None,
        );
        assert_eq!(r.prefix_hits, 2);
        assert!((r.prefix_hit_rate - 0.5).abs() < 1e-12);
        assert_eq!(r.prefill_tokens_saved, 48);
        // no prefixes at all reports clean zeros
        let none = LoadReport::from_records(
            &[],
            &SloSpec::relaxed(),
            &Metrics::default(),
            None,
        );
        assert_eq!(none.prefix_hits, 0);
        assert_eq!(none.prefix_hit_rate, 0.0);
        assert_eq!(none.prefill_tokens_saved, 0);
    }

    #[test]
    fn slo_meets_logic() {
        let s = SloSpec::chatbot();
        assert!(s.meets(250.0, Some(50.0)));
        assert!(!s.meets(250.1, Some(10.0)));
        assert!(!s.meets(10.0, Some(50.1)));
        // single-token outputs: only TTFT judged
        assert!(s.meets(100.0, None));
    }

    #[test]
    fn report_splits_goodput_from_throughput() {
        let slo = SloSpec { ttft_ms: 100.0, tpot_ms: 10.0 };
        // r1 meets (ttft 50, tpot (561-61)/100 = 5); r2 misses on ttft
        let records = vec![
            rec(0.0, 50.0, 550.0, 101),
            rec(0.0, 200.0, 700.0, 101),
        ];
        let m = Metrics::default();
        let r = LoadReport::from_records(&records, &slo, &m, Some(1000.0));
        assert_eq!(r.offered, 2);
        assert_eq!(r.completed, 2);
        assert_eq!(r.slo_met, 1);
        assert!((r.slo_attainment - 0.5).abs() < 1e-12);
        assert!((r.makespan_ms - 700.0).abs() < 1e-9);
        // throughput counts both, goodput only the SLO-meeting one
        assert!((r.throughput_tok_s - 202.0 / 0.7).abs() < 1e-6);
        assert!((r.goodput_tok_s - 101.0 / 0.7).abs() < 1e-6);
        assert!((r.goodput_req_s - 1.0 / 0.7).abs() < 1e-6);
        assert_eq!(r.ttft_ms.count, 2);
        assert_eq!(r.queue_delay_ms.p50, 1.0);
        let u = r.utilization().unwrap();
        assert!(u > 0.0 && u < 1.0);
    }

    #[test]
    fn unfinished_requests_are_slo_misses() {
        let slo = SloSpec::relaxed();
        let mut unfinished = rec(0.0, 10.0, 0.0, 5);
        unfinished.finished_ms = None;
        let records = vec![rec(0.0, 10.0, 100.0, 5), unfinished];
        let r = LoadReport::from_records(
            &records,
            &slo,
            &Metrics::default(),
            None,
        );
        assert_eq!(r.completed, 1);
        assert_eq!(r.slo_met, 1);
        assert!((r.slo_attainment - 0.5).abs() < 1e-12);
        assert!(r.utilization().is_none());
    }

    #[test]
    fn zero_makespan_reports_zero_rates_not_infinity() {
        // tokens generated but nothing finished: makespan is 0 and
        // every rate must be 0, not total_tokens / epsilon
        let mut r1 = rec(0.0, 10.0, 0.0, 5);
        r1.finished_ms = None;
        let mut r2 = rec(3.0, 12.0, 0.0, 7);
        r2.finished_ms = None;
        let r = LoadReport::from_records(
            &[r1, r2],
            &SloSpec::chatbot(),
            &Metrics::default(),
            None,
        );
        assert_eq!(r.completed, 0);
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.throughput_tok_s, 0.0);
        assert_eq!(r.goodput_req_s, 0.0);
        assert_eq!(r.goodput_tok_s, 0.0);
    }

    #[test]
    fn per_class_breakdown_judges_each_tier_against_scaled_slo() {
        let slo = SloSpec { ttft_ms: 100.0, tpot_ms: 10.0 };
        // interactive: ttft 150 misses the base budget
        let mut int = rec(0.0, 150.0, 650.0, 101);
        int.class = SloClass::Interactive;
        // batch: same ttft 150 fits the 4x-widened budget (400 ms)
        let mut bat = rec(0.0, 150.0, 650.0, 101);
        bat.class = SloClass::Batch;
        bat.preemptions = 2;
        bat.pages_swapped = 14;
        let r = LoadReport::from_records(
            &[int, bat],
            &slo,
            &Metrics::default(),
            None,
        );
        // top-level judges everyone against the base SLO
        assert_eq!(r.slo_met, 0);
        assert_eq!(r.preemptions, 2);
        assert_eq!(r.pages_swapped, 14);
        assert_eq!(r.pages_recomputed, 0);
        // breakdown: one row per present tier, in all() order
        assert_eq!(r.per_class.len(), 2);
        let (c0, int_r) = &r.per_class[0];
        let (c1, bat_r) = &r.per_class[1];
        assert_eq!(*c0, SloClass::Interactive);
        assert_eq!(*c1, SloClass::Batch);
        assert_eq!(int_r.offered, 1);
        assert_eq!(int_r.slo_met, 0); // 150 > 100
        assert_eq!(bat_r.offered, 1);
        assert_eq!(bat_r.slo_met, 1); // 150 <= 400
        assert_eq!(bat_r.preemptions, 2);
        assert!(int_r.per_class.is_empty() && bat_r.per_class.is_empty());
        // single-tier runs keep the breakdown empty (legacy flows)
        let solo = LoadReport::from_records(
            &[rec(0.0, 10.0, 100.0, 5)],
            &slo,
            &Metrics::default(),
            None,
        );
        assert!(solo.per_class.is_empty());
        // scaled() arithmetic
        let wide = slo.scaled(SloClass::BestEffort.slo_factor());
        assert!((wide.ttft_ms - 1600.0).abs() < 1e-9);
        assert!((wide.tpot_ms - 160.0).abs() < 1e-9);
    }

    #[test]
    fn empty_records_are_well_defined() {
        let r = LoadReport::from_records(
            &[],
            &SloSpec::chatbot(),
            &Metrics::default(),
            None,
        );
        assert_eq!(r.offered, 0);
        assert_eq!(r.slo_attainment, 0.0);
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.ttft_ms.count, 0);
        assert!(r.throughput_tok_s == 0.0);
    }
}
