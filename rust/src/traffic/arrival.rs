//! Arrival processes: when requests reach the serving engine.
//!
//! Every process is deterministic in (parameters, seed) -- the same
//! `--seed` replays the exact same timeline, which is what makes
//! `loadtest` reports diffable across systems and schemes.  Times are
//! milliseconds on the engine clock, offsets from the run start.

use crate::error::{P3Error, Result};
use crate::testutil::Rng;

#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps with the
    /// given mean (the classic open-system chatbot model).
    Poisson { mean_interarrival_ms: f64 },
    /// Fixed inter-arrival gap (steady batch feeds, cron-style jobs).
    Constant { interarrival_ms: f64 },
    /// On/off bursty traffic: `burst_n` arrivals spaced `burst_gap_ms`
    /// apart, then an idle gap of `idle_ms`, repeating.  Stresses KV
    /// admission control and queue discipline.
    OnOff { burst_n: usize, burst_gap_ms: f64, idle_ms: f64 },
    /// Replay recorded arrival offsets (ms, sorted ascending), e.g.
    /// from [`parse_trace_tsv`].  Requests beyond the trace length
    /// repeat the trace shifted by its span.
    Trace { arrivals_ms: Vec<f64> },
}

impl ArrivalProcess {
    /// The first `n` absolute arrival offsets (ms, non-decreasing,
    /// first arrival at 0).  Deterministic in (self, seed); only
    /// `Poisson` consumes randomness.
    pub fn arrivals(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match self {
            ArrivalProcess::Poisson { mean_interarrival_ms } => {
                let mut rng = Rng::new(seed);
                let mut t = 0.0f64;
                for i in 0..n {
                    if i > 0 {
                        t += rng.exp(mean_interarrival_ms.max(1e-9));
                    }
                    out.push(t);
                }
            }
            ArrivalProcess::Constant { interarrival_ms } => {
                for i in 0..n {
                    out.push(i as f64 * interarrival_ms);
                }
            }
            ArrivalProcess::OnOff { burst_n, burst_gap_ms, idle_ms } => {
                let bn = (*burst_n).max(1);
                let mut t = 0.0f64;
                for i in 0..n {
                    if i > 0 {
                        t += if i % bn == 0 { *idle_ms } else { *burst_gap_ms };
                    }
                    out.push(t);
                }
            }
            ArrivalProcess::Trace { arrivals_ms } => {
                if arrivals_ms.is_empty() {
                    return vec![0.0; n];
                }
                let len = arrivals_ms.len();
                let span = arrivals_ms[len - 1] - arrivals_ms[0];
                // wrap period: trace span plus one mean gap, so the
                // replayed copies do not collide at the seam
                let period = span + (span / len as f64).max(1.0);
                for i in 0..n {
                    let lap = (i / len) as f64;
                    out.push(
                        arrivals_ms[i % len] - arrivals_ms[0] + lap * period,
                    );
                }
            }
        }
        out
    }

    /// Scale every time constant by `factor` (> 1 thins the load,
    /// < 1 intensifies it); the load-sweep knob of the SLO benches and
    /// the `loadtest` / `cluster` `--scale` flag.  A non-positive or
    /// non-finite factor would silently degenerate the process (zero
    /// or reversed gaps), so it is a typed error instead.
    pub fn scaled(&self, factor: f64) -> Result<ArrivalProcess> {
        if !factor.is_finite() || factor <= 0.0 {
            return Err(P3Error::InvalidFlag {
                flag: "scale".into(),
                value: format!("{factor}"),
            });
        }
        Ok(match self {
            ArrivalProcess::Poisson { mean_interarrival_ms } => {
                ArrivalProcess::Poisson {
                    mean_interarrival_ms: mean_interarrival_ms * factor,
                }
            }
            ArrivalProcess::Constant { interarrival_ms } => {
                ArrivalProcess::Constant {
                    interarrival_ms: interarrival_ms * factor,
                }
            }
            ArrivalProcess::OnOff { burst_n, burst_gap_ms, idle_ms } => {
                ArrivalProcess::OnOff {
                    burst_n: *burst_n,
                    burst_gap_ms: burst_gap_ms * factor,
                    idle_ms: idle_ms * factor,
                }
            }
            ArrivalProcess::Trace { arrivals_ms } => ArrivalProcess::Trace {
                arrivals_ms: arrivals_ms.iter().map(|t| t * factor).collect(),
            },
        })
    }
}

/// Parse a replay trace: one arrival offset (ms) per line, first
/// whitespace/tab-separated field; `#` comments and blank lines are
/// skipped.  Offsets are sorted; negative or non-finite values are
/// typed [`P3Error::Parse`] errors.
pub fn parse_trace_tsv(text: &str) -> Result<ArrivalProcess> {
    let mut arrivals = vec![];
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let field = line.split_whitespace().next().unwrap_or("");
        let v: f64 = field.parse().map_err(|_| {
            P3Error::Parse(format!(
                "trace line {}: malformed arrival {field:?}",
                lineno + 1
            ))
        })?;
        if !v.is_finite() || v < 0.0 {
            return Err(P3Error::Parse(format!(
                "trace line {}: arrival must be finite and >= 0, got {v}",
                lineno + 1
            )));
        }
        arrivals.push(v);
    }
    if arrivals.is_empty() {
        return Err(P3Error::Parse("trace has no arrivals".into()));
    }
    arrivals.sort_by(|a, b| a.total_cmp(b));
    Ok(ArrivalProcess::Trace { arrivals_ms: arrivals })
}

/// [`parse_trace_tsv`] over a file on disk.
pub fn load_trace_tsv(path: &str) -> Result<ArrivalProcess> {
    let text =
        std::fs::read_to_string(path).map_err(|e| P3Error::io(path, e))?;
    parse_trace_tsv(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monotone(xs: &[f64]) -> bool {
        xs.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn arrivals_start_at_zero_and_are_monotone() {
        let procs = [
            ArrivalProcess::Poisson { mean_interarrival_ms: 50.0 },
            ArrivalProcess::Constant { interarrival_ms: 10.0 },
            ArrivalProcess::OnOff {
                burst_n: 4,
                burst_gap_ms: 1.0,
                idle_ms: 100.0,
            },
            ArrivalProcess::Trace { arrivals_ms: vec![0.0, 5.0, 9.0] },
        ];
        for p in &procs {
            let a = p.arrivals(17, 3);
            assert_eq!(a.len(), 17);
            assert_eq!(a[0], 0.0, "{p:?}");
            assert!(monotone(&a), "{p:?}: {a:?}");
        }
    }

    #[test]
    fn poisson_is_seed_deterministic_with_the_right_mean() {
        let p = ArrivalProcess::Poisson { mean_interarrival_ms: 80.0 };
        let a = p.arrivals(400, 9);
        let b = p.arrivals(400, 9);
        assert_eq!(a, b);
        let c = p.arrivals(400, 10);
        assert_ne!(a, c);
        // empirical mean gap within 15% of the nominal one
        let mean = a[399] / 399.0;
        assert!((mean / 80.0 - 1.0).abs() < 0.15, "{mean}");
    }

    #[test]
    fn onoff_alternates_burst_and_idle() {
        let p = ArrivalProcess::OnOff {
            burst_n: 3,
            burst_gap_ms: 1.0,
            idle_ms: 50.0,
        };
        let a = p.arrivals(7, 0);
        assert_eq!(a, vec![0.0, 1.0, 2.0, 52.0, 53.0, 54.0, 104.0]);
    }

    #[test]
    fn trace_wraps_beyond_its_length() {
        let p = ArrivalProcess::Trace { arrivals_ms: vec![10.0, 20.0, 40.0] };
        let a = p.arrivals(6, 0);
        // rebased to 0; wrap period = span 30 + mean gap 10 = 40
        assert_eq!(a[..3], [0.0, 10.0, 30.0]);
        assert_eq!(a[3..], [40.0, 50.0, 70.0]);
    }

    #[test]
    fn parse_trace_skips_comments_sorts_and_type_errors() {
        let p = parse_trace_tsv("# t_ms\n40\n10.5\t extra col\n\n20\n").unwrap();
        assert_eq!(
            p,
            ArrivalProcess::Trace { arrivals_ms: vec![10.5, 20.0, 40.0] }
        );
        assert!(matches!(
            parse_trace_tsv("abc"),
            Err(P3Error::Parse(_))
        ));
        assert!(matches!(
            parse_trace_tsv("-4"),
            Err(P3Error::Parse(_))
        ));
        assert!(matches!(parse_trace_tsv("# only\n"), Err(P3Error::Parse(_))));
    }

    #[test]
    fn scaled_stretches_time() {
        let p = ArrivalProcess::Constant { interarrival_ms: 10.0 };
        assert_eq!(
            p.scaled(2.0).unwrap().arrivals(3, 0),
            vec![0.0, 20.0, 40.0]
        );
        let t = ArrivalProcess::Trace { arrivals_ms: vec![1.0, 3.0] };
        assert_eq!(
            t.scaled(3.0).unwrap(),
            ArrivalProcess::Trace { arrivals_ms: vec![3.0, 9.0] }
        );
    }

    #[test]
    fn scaled_rejects_degenerate_factors_typed() {
        let p = ArrivalProcess::Poisson { mean_interarrival_ms: 10.0 };
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            match p.scaled(bad) {
                Err(P3Error::InvalidFlag { flag, .. }) => {
                    assert_eq!(flag, "scale")
                }
                other => panic!("factor {bad}: expected InvalidFlag, got {other:?}"),
            }
        }
        assert!(p.scaled(0.5).is_ok());
    }
}
