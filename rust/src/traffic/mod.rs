//! L3.5 traffic: closed-loop load generation and scenario evaluation
//! over the serving engine.
//!
//! The paper's serving claims (Section I's chatbot TTFT SLO, the
//! per-system speedups) need realistic request streams, not hand-fed
//! batches.  This layer supplies them end to end:
//!
//! * [`ArrivalProcess`] -- seeded Poisson / constant-rate / on-off
//!   bursty arrivals, plus TSV trace replay ([`parse_trace_tsv`]).
//! * [`RequestMix`] -- named tenant classes (chat, summarization,
//!   code-completion, long-context RAG) drawing prompt/output lengths
//!   from clamped log-normals.  Prefix-bearing classes (`agent`,
//!   `rag-cached`, `tiny-prefix`) additionally draw a shared system
//!   prompt from a Zipf-popular [`PrefixPool`], so the engine's
//!   shared-prefix KV cache has something to hit.
//! * [`SloSpec`] / [`LoadReport`] -- TTFT + per-token targets, and the
//!   goodput / SLO-attainment / queueing-delay / saturation report,
//!   plus prefix-cache hit-rate and prefill-tokens-saved columns.
//! * [`LoadRunner`] -- schedules arrivals on the backend clock and
//!   drives the [`Engine`](crate::coordinator::Engine) closed-loop
//!   (submit on arrival, step, retire): the one serving timeline.
//! * [`Scenario`] -- the named registry behind `p3llm loadtest`
//!   (`chat-poisson`, `chat-burst`, `summarize-steady`,
//!   `code-complete`, `rag-long`, `agent-pool`, `rag-cached`,
//!   `smoke`, `smoke-prefix`).  Overload scenarios (`flash-crowd`,
//!   `starve-probe`, `smoke-overload`) additionally carry a
//!   [`TierMix`](crate::sched::TierMix) of SLO classes and a victim
//!   policy, and [`Scenario::with_load_factor`] pins the offered
//!   token rate to a multiple of the modeled saturation throughput
//!   for goodput-vs-load sweeps (`p3llm overload`,
//!   `benches/overload_degradation.rs`).
//!
//! ```
//! use p3llm::traffic;
//! # fn main() -> p3llm::Result<()> {
//! let sc = traffic::scenario_by_name("smoke-prefix").unwrap();
//! let mut eng = sc.engine("P3-LLM", None)?;
//! let out = sc.runner(7).run(&mut eng)?;
//! assert!(out.report.prefix_hit_rate > 0.0);
//! println!("SLO attainment {:.1}%  goodput {:.1} tok/s  hit {:.0}%",
//!          out.report.slo_attainment * 100.0,
//!          out.report.goodput_tok_s,
//!          out.report.prefix_hit_rate * 100.0);
//! # Ok(())
//! # }
//! ```
//!
//! Every run is bit-identical under a fixed `seed`: arrivals, lengths,
//! prompt tokens and shared-prefix assignments all derive from
//! `testutil::Rng` streams.

pub mod arrival;
pub mod mix;
pub mod runner;
pub mod scenario;
pub mod slo;

pub use arrival::{load_trace_tsv, parse_trace_tsv, ArrivalProcess};
pub use mix::{all_mixes, by_name as mix_by_name, PrefixPool, RequestMix};
pub use runner::{LoadRunner, LoadTarget, RunOutcome};
pub use scenario::{all_scenarios, by_name as scenario_by_name, Scenario};
pub use slo::{LoadReport, ReqRecord, SloSpec};
