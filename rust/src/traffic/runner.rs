//! The closed-loop load runner: schedules an arrival process on the
//! backend clock and drives the *real* serving engine -- submit on
//! arrival, step, retire -- so every latency number comes from the
//! same batcher / KV pool / backend path production requests take.
//!
//! This replaces the old `coordinator::scheduler` open-loop model,
//! which re-derived prefill/decode costs on the side and bypassed the
//! engine entirely; there is exactly one serving timeline now.

use crate::coordinator::{Engine, Metrics};
use crate::error::{P3Error, Result};
use crate::sched::{SloClass, TierMix};
use crate::testutil::Rng;

use super::arrival::ArrivalProcess;
use super::mix::RequestMix;
use super::slo::{LoadReport, ReqRecord, SloSpec};

/// Anything the closed-loop runner can drive: a single [`Engine`], or
/// a whole replica fleet behind a router
/// (`cluster::Cluster`).  The runner owns the arrival
/// schedule; the target owns the clock, admission and stepping.
pub trait LoadTarget {
    /// Clock the arrival schedule is interpreted on (ms).  For a fleet
    /// this is the causal frontier: the earliest clock among busy
    /// replicas (idle replicas can always fast-forward).
    fn now_ms(&self) -> f64;

    /// Nothing queued and nothing active anywhere.
    fn is_idle(&self) -> bool;

    /// Fast-forward idle capacity to absolute `ms` (jump over gaps
    /// between arrivals).  Wall-clock targets may ignore this; callers
    /// must tolerate `now_ms()` staying behind `ms`.
    fn advance_clock_to(&mut self, ms: f64);

    /// Longest admissible prompt (the runner clamps its samples).
    fn max_prompt(&self) -> usize;

    /// Vocabulary size for synthetic prompt tokens.
    fn vocab(&self) -> usize;

    /// Accept one request due at `due_ms` under SLO tier `class`;
    /// returns an opaque ticket the runner hands back to
    /// [`record`](Self::record).  A routed fleet uses `due_ms` to
    /// stamp the chosen replica's clock.
    fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        due_ms: f64,
        class: SloClass,
    ) -> Result<u64>;

    /// One unit of serving progress.
    fn step(&mut self) -> Result<()>;

    /// Per-request timeline after the run finished.
    fn record(&self, ticket: u64, scheduled_arrival_ms: f64) -> Result<ReqRecord>;

    /// End-of-run engine metrics (merged across replicas for a fleet).
    fn end_metrics(&self) -> Metrics;
}

impl LoadTarget for Engine {
    fn now_ms(&self) -> f64 {
        Engine::now_ms(self)
    }

    fn is_idle(&self) -> bool {
        Engine::is_idle(self)
    }

    fn advance_clock_to(&mut self, ms: f64) {
        Engine::advance_clock_to(self, ms);
    }

    fn max_prompt(&self) -> usize {
        Engine::max_prompt(self)
    }

    fn vocab(&self) -> usize {
        self.model().vocab
    }

    fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        _due_ms: f64,
        class: SloClass,
    ) -> Result<u64> {
        Engine::submit_class(self, prompt, max_new, class).map(|id| id.0)
    }

    fn step(&mut self) -> Result<()> {
        Engine::step(self).map(|_| ())
    }

    fn record(&self, ticket: u64, scheduled_arrival_ms: f64) -> Result<ReqRecord> {
        let req = self
            .request(crate::coordinator::RequestId(ticket))
            .ok_or(P3Error::UnknownRequest(ticket))?;
        Ok(ReqRecord::from_request(req, scheduled_arrival_ms))
    }

    fn end_metrics(&self) -> Metrics {
        self.metrics()
    }
}

/// A fully materialized load plan: per-request arrival offsets,
/// (prompt, output) shapes and shared-prefix assignments, all
/// deterministic in the construction seed.
#[derive(Debug, Clone)]
pub struct LoadRunner {
    /// arrival offsets (ms, non-decreasing) relative to run start
    pub arrivals_ms: Vec<f64>,
    /// per-request (prompt_tokens, max_new_tokens)
    pub shapes: Vec<(usize, usize)>,
    /// per-request shared-prefix rank from the mix's
    /// [`PrefixPool`](super::mix::PrefixPool) (None = unique prompt)
    pub prefix_ids: Vec<Option<usize>>,
    /// tokens per shared prefix (0 = the mix has no prefix pool)
    pub prefix_len: usize,
    /// per-request SLO tier (all [`SloClass::Interactive`] unless the
    /// plan was built [`with_tiers`](Self::with_tiers))
    pub classes: Vec<SloClass>,
    pub slo: SloSpec,
    seed: u64,
}

/// What a run produced: the aggregate [`LoadReport`] plus the raw
/// per-request records (submission order) for tests and TSV dumps.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub report: LoadReport,
    pub records: Vec<ReqRecord>,
}

impl LoadRunner {
    /// Materialize `n` requests from an arrival process and a request
    /// mix.  Arrival times, lengths and shared-prefix assignments draw
    /// from decoupled seed streams so changing the mix never perturbs
    /// the timeline.
    pub fn new(
        arrival: &ArrivalProcess,
        mix: &RequestMix,
        slo: SloSpec,
        n: usize,
        seed: u64,
    ) -> Self {
        let arrivals_ms = arrival.arrivals(n, seed);
        let mut rng = Rng::new(seed ^ 0x6d17_57a7_0123_beef);
        let shapes = (0..n).map(|_| mix.sample(&mut rng)).collect();
        let mut prng = Rng::new(seed ^ 0x5ca1_ab1e_0f00_0001);
        let (prefix_ids, prefix_len) = match &mix.prefixes {
            Some(pp) if pp.n > 0 && pp.len > 0 => {
                let ids = (0..n)
                    .map(|_| {
                        if prng.f64() < pp.p_none {
                            None
                        } else {
                            Some(pp.sample_id(&mut prng))
                        }
                    })
                    .collect();
                (ids, pp.len)
            }
            _ => (vec![None; n], 0),
        };
        LoadRunner {
            arrivals_ms,
            shapes,
            prefix_ids,
            prefix_len,
            classes: vec![SloClass::Interactive; n],
            slo,
            seed,
        }
    }

    /// Resample per-request SLO tiers from a [`TierMix`].  The class
    /// stream is decoupled from arrivals/shapes/prefixes (its own seed
    /// stream), so adding tiers to a scenario never perturbs the rest
    /// of the timeline.
    pub fn with_tiers(mut self, mix: TierMix) -> Self {
        let mut rng = Rng::new(self.seed ^ 0x7ea5_c1a5_5e50_0007);
        self.classes =
            (0..self.arrivals_ms.len()).map(|_| mix.sample(&mut rng)).collect();
        self
    }

    /// Explicit per-request tiers (trace-style tests); length must
    /// match the plan.
    pub fn with_classes(mut self, classes: Vec<SloClass>) -> Self {
        assert_eq!(classes.len(), self.arrivals_ms.len());
        self.classes = classes;
        self
    }

    /// A plan from explicit arrivals/shapes (trace-style tests).
    pub fn from_plan(
        arrivals_ms: Vec<f64>,
        shapes: Vec<(usize, usize)>,
        slo: SloSpec,
        seed: u64,
    ) -> Self {
        assert_eq!(arrivals_ms.len(), shapes.len());
        let n = arrivals_ms.len();
        LoadRunner {
            arrivals_ms,
            shapes,
            prefix_ids: vec![None; n],
            prefix_len: 0,
            classes: vec![SloClass::Interactive; n],
            slo,
            seed,
        }
    }

    fn submit_one<T: LoadTarget>(
        &self,
        target: &mut T,
        i: usize,
        due: f64,
    ) -> Result<u64> {
        let (plen, max_new) = self.shapes[i];
        // clamp to what this target's backend/ctx can admit
        let plen = plen.min(target.max_prompt()).max(1);
        let mut prng = Rng::new((self.seed ^ 0x9e37) ^ ((i as u64) << 17));
        let vocab = target.vocab().max(2);
        let prompt: Vec<i32> = match self.prefix_ids[i] {
            Some(pid) if self.prefix_len > 0 => {
                // shared system prompt: deterministic in (seed, rank)
                // so every request with this rank byte-matches -- the
                // content the engine's prefix cache hashes.  A sampled
                // length at or below the prefix length just sends a
                // truncated prefix (still page-shareable).
                let mut pfx = Rng::new(
                    (self.seed ^ 0x0bad_cafe_d00d_0000)
                        ^ (pid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                let shared = self.prefix_len.min(plen);
                let mut v: Vec<i32> = (0..shared)
                    .map(|_| pfx.usize(0, vocab) as i32)
                    .collect();
                v.extend(
                    (shared..plen).map(|_| prng.usize(0, vocab) as i32),
                );
                v
            }
            _ => (0..plen).map(|_| prng.usize(0, vocab) as i32).collect(),
        };
        target.submit(prompt, max_new.max(1), due, self.classes[i])
    }

    /// Drive a [`LoadTarget`] (one engine, or a routed fleet)
    /// closed-loop until every offered request retires.
    ///
    /// Requests are submitted when the target clock reaches their
    /// arrival; while the target is idle the clock fast-forwards to
    /// the next arrival.  Simulated backends jump; wall-clock backends
    /// cannot, so the idle engine accepts the next request early
    /// rather than spinning (its effective arrival in the report is
    /// then the submit instant -- latencies never go negative).
    pub fn run<T: LoadTarget>(&self, target: &mut T) -> Result<RunOutcome> {
        let n = self.arrivals_ms.len();
        let t0 = target.now_ms();
        let mut ids: Vec<Option<u64>> = vec![None; n];
        let mut next = 0usize;
        let mut guard = 0usize;
        loop {
            // admit everything due on the target clock
            while next < n
                && t0 + self.arrivals_ms[next] <= target.now_ms() + 1e-9
            {
                let due = t0 + self.arrivals_ms[next];
                ids[next] = Some(self.submit_one(target, next, due)?);
                next += 1;
            }
            if !target.is_idle() {
                target.step()?;
                guard += 1;
                if guard > 5_000_000 {
                    return Err(P3Error::Serve(
                        "load loop did not converge".into(),
                    ));
                }
                continue;
            }
            if next >= n {
                break;
            }
            let due = t0 + self.arrivals_ms[next];
            target.advance_clock_to(due);
            if target.now_ms() + 1e-9 < due {
                // the clock cannot fast-forward (wall-clock backend):
                // take the next request early rather than spinning
                ids[next] = Some(self.submit_one(target, next, due)?);
                next += 1;
            }
        }

        let mut records = Vec::with_capacity(n);
        for (i, id) in ids.iter().enumerate() {
            let id = (*id).ok_or_else(|| {
                P3Error::Serve(format!("request {i} was never submitted"))
            })?;
            records.push(target.record(id, t0 + self.arrivals_ms[i])?);
        }
        let report = LoadReport::from_records(
            &records,
            &self.slo,
            &target.end_metrics(),
            None,
        );
        Ok(RunOutcome { report, records })
    }

    /// [`run`](Self::run), attaching a modeled saturation throughput
    /// to the report (for utilization columns).
    pub fn run_with_saturation<T: LoadTarget>(
        &self,
        target: &mut T,
        saturation_tok_s: Option<f64>,
    ) -> Result<RunOutcome> {
        let mut out = self.run(target)?;
        out.report.saturation_tok_s = saturation_tok_s;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineBuilder;

    fn tiny_engine(max_batch: usize) -> Engine {
        EngineBuilder::sim()
            .model("tiny-1M")
            .max_batch(max_batch)
            .ctx_limit(128)
            .build()
            .unwrap()
    }

    #[test]
    fn closed_loop_serves_all_and_respects_arrivals() {
        let plan = LoadRunner::from_plan(
            vec![0.0, 0.0, 40.0, 1000.0],
            vec![(8, 4); 4],
            SloSpec::chatbot(),
            1,
        );
        let mut eng = tiny_engine(2);
        let out = plan.run(&mut eng).unwrap();
        assert_eq!(out.report.offered, 4);
        assert_eq!(out.report.completed, 4);
        for (r, &a) in out.records.iter().zip(&plan.arrivals_ms) {
            // never submitted before its arrival
            assert!(r.submitted_ms + 1e-9 >= a, "{r:?}");
            assert!(r.finished());
            assert!(r.ttft_ms().unwrap() > 0.0);
            assert!(r.queue_delay_ms().unwrap() >= 0.0);
        }
        // the last arrival is far out: the clock fast-forwarded to it
        assert!(out.records[3].submitted_ms >= 1000.0 - 1e-9);
        assert!((out.records[3].submitted_ms - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn runs_are_bit_identical_under_a_seed() {
        let mk = || {
            LoadRunner::new(
                &ArrivalProcess::Poisson { mean_interarrival_ms: 3.0 },
                &RequestMix::tiny(),
                SloSpec::chatbot(),
                12,
                7,
            )
        };
        let a = mk().run(&mut tiny_engine(4)).unwrap();
        let b = mk().run(&mut tiny_engine(4)).unwrap();
        assert_eq!(a.records, b.records);
        assert_eq!(a.report, b.report);
        // a different seed produces a different timeline
        let c = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 3.0 },
            &RequestMix::tiny(),
            SloSpec::chatbot(),
            12,
            8,
        )
        .run(&mut tiny_engine(4))
        .unwrap();
        assert_ne!(a.records, c.records);
    }

    #[test]
    fn prefix_bearing_plans_produce_cache_hits() {
        let plan = LoadRunner::new(
            &ArrivalProcess::Constant { interarrival_ms: 1.0 },
            &RequestMix::tiny_prefix(),
            SloSpec::chatbot(),
            12,
            7,
        );
        // the plan itself carries the shared-prefix assignments
        assert_eq!(plan.prefix_len, 32);
        assert!(plan.prefix_ids.iter().any(|p| p.is_some()));
        let out = plan.run(&mut tiny_engine(4)).unwrap();
        assert_eq!(out.report.completed, 12);
        assert!(out.report.prefix_hits > 0, "{:?}", out.report.prefix_hits);
        assert!(out.report.prefix_hit_rate > 0.0);
        assert!(out.report.prefill_tokens_saved >= 32);
        // the same plan with the cache disabled: zero hits, and the
        // skipped prefill compute shows up as strictly higher TTFT
        let mut cold = EngineBuilder::sim()
            .model("tiny-1M")
            .max_batch(4)
            .ctx_limit(128)
            .prefix_cache(false)
            .build()
            .unwrap();
        let coff = plan.run(&mut cold).unwrap();
        assert_eq!(coff.report.prefix_hits, 0);
        assert_eq!(coff.report.prefill_tokens_saved, 0);
        assert!(
            out.report.ttft_ms.mean < coff.report.ttft_ms.mean,
            "cached {} !< cold {}",
            out.report.ttft_ms.mean,
            coff.report.ttft_ms.mean
        );
    }

    #[test]
    fn tiered_plans_carry_classes_into_per_class_reports() {
        let mk = || {
            LoadRunner::new(
                &ArrivalProcess::Poisson { mean_interarrival_ms: 2.0 },
                &RequestMix::tiny(),
                SloSpec::chatbot(),
                16,
                11,
            )
            .with_tiers(TierMix::mixed())
        };
        let plan = mk();
        // the tier stream is decoupled: same arrivals/shapes as untiered
        let untiered = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 2.0 },
            &RequestMix::tiny(),
            SloSpec::chatbot(),
            16,
            11,
        );
        assert_eq!(plan.arrivals_ms, untiered.arrivals_ms);
        assert_eq!(plan.shapes, untiered.shapes);
        assert!(plan.classes.iter().any(|&c| c != SloClass::Interactive));
        assert_eq!(plan.classes, mk().classes); // deterministic
        let out = plan.run(&mut tiny_engine(4)).unwrap();
        assert_eq!(out.report.completed, 16);
        // records carry the submitted class, and the report splits it
        for (r, &c) in out.records.iter().zip(&plan.classes) {
            assert_eq!(r.class, c);
        }
        assert!(!out.report.per_class.is_empty());
        let total: usize =
            out.report.per_class.iter().map(|(_, r)| r.offered).sum();
        assert_eq!(total, 16);
    }

    #[test]
    fn saturating_load_raises_client_ttft() {
        let slo = SloSpec::chatbot();
        let mix = RequestMix::tiny();
        let heavy = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 0.05 },
            &mix,
            slo,
            24,
            3,
        );
        let calm = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 500.0 },
            &mix,
            slo,
            24,
            3,
        );
        let h = heavy.run(&mut tiny_engine(2)).unwrap().report;
        let c = calm.run(&mut tiny_engine(2)).unwrap().report;
        assert!(
            h.ttft_ms.mean > c.ttft_ms.mean,
            "{} vs {}",
            h.ttft_ms.mean,
            c.ttft_ms.mean
        );
        assert!(h.queue_delay_ms.p95 > c.queue_delay_ms.p95);
    }
}
