//! The closed-loop load runner: schedules an arrival process on the
//! backend clock and drives the *real* serving engine -- submit on
//! arrival, step, retire -- so every latency number comes from the
//! same batcher / KV pool / backend path production requests take.
//!
//! This replaces the old `coordinator::scheduler` open-loop model,
//! which re-derived prefill/decode costs on the side and bypassed the
//! engine entirely; there is exactly one serving timeline now.

use crate::coordinator::{Engine, RequestId};
use crate::error::{P3Error, Result};
use crate::testutil::Rng;

use super::arrival::ArrivalProcess;
use super::mix::RequestMix;
use super::slo::{LoadReport, ReqRecord, SloSpec};

/// A fully materialized load plan: per-request arrival offsets and
/// (prompt, output) shapes, deterministic in the construction seed.
#[derive(Debug, Clone)]
pub struct LoadRunner {
    /// arrival offsets (ms, non-decreasing) relative to run start
    pub arrivals_ms: Vec<f64>,
    /// per-request (prompt_tokens, max_new_tokens)
    pub shapes: Vec<(usize, usize)>,
    pub slo: SloSpec,
    seed: u64,
}

/// What a run produced: the aggregate [`LoadReport`] plus the raw
/// per-request records (submission order) for tests and TSV dumps.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub report: LoadReport,
    pub records: Vec<ReqRecord>,
}

impl LoadRunner {
    /// Materialize `n` requests from an arrival process and a request
    /// mix.  Arrival times and lengths draw from decoupled seed
    /// streams so changing the mix never perturbs the timeline.
    pub fn new(
        arrival: &ArrivalProcess,
        mix: &RequestMix,
        slo: SloSpec,
        n: usize,
        seed: u64,
    ) -> Self {
        let arrivals_ms = arrival.arrivals(n, seed);
        let mut rng = Rng::new(seed ^ 0x6d17_57a7_0123_beef);
        let shapes = (0..n).map(|_| mix.sample(&mut rng)).collect();
        LoadRunner { arrivals_ms, shapes, slo, seed }
    }

    /// A plan from explicit arrivals/shapes (trace-style tests).
    pub fn from_plan(
        arrivals_ms: Vec<f64>,
        shapes: Vec<(usize, usize)>,
        slo: SloSpec,
        seed: u64,
    ) -> Self {
        assert_eq!(arrivals_ms.len(), shapes.len());
        LoadRunner { arrivals_ms, shapes, slo, seed }
    }

    fn submit_one(&self, eng: &mut Engine, i: usize) -> Result<RequestId> {
        let (plen, max_new) = self.shapes[i];
        // clamp to what this engine's backend/ctx can admit
        let plen = plen.min(eng.max_prompt()).max(1);
        let mut prng = Rng::new((self.seed ^ 0x9e37) ^ ((i as u64) << 17));
        let vocab = eng.model().vocab.max(2);
        let prompt: Vec<i32> =
            (0..plen).map(|_| prng.usize(0, vocab) as i32).collect();
        eng.submit(prompt, max_new.max(1))
    }

    /// Drive `eng` closed-loop until every offered request retires.
    ///
    /// Requests are submitted when the engine clock reaches their
    /// arrival; while the engine is idle the clock fast-forwards to
    /// the next arrival.  Simulated backends jump; wall-clock backends
    /// cannot, so the idle engine accepts the next request early
    /// rather than spinning (its effective arrival in the report is
    /// then the submit instant -- latencies never go negative).
    pub fn run(&self, eng: &mut Engine) -> Result<RunOutcome> {
        let n = self.arrivals_ms.len();
        let t0 = eng.now_ms();
        let mut ids: Vec<Option<RequestId>> = vec![None; n];
        let mut next = 0usize;
        let mut guard = 0usize;
        loop {
            // admit everything due on the engine clock
            while next < n
                && t0 + self.arrivals_ms[next] <= eng.now_ms() + 1e-9
            {
                ids[next] = Some(self.submit_one(eng, next)?);
                next += 1;
            }
            if !eng.is_idle() {
                eng.step()?;
                guard += 1;
                if guard > 5_000_000 {
                    return Err(P3Error::Serve(
                        "load loop did not converge".into(),
                    ));
                }
                continue;
            }
            if next >= n {
                break;
            }
            let due = t0 + self.arrivals_ms[next];
            eng.advance_clock_to(due);
            if eng.now_ms() + 1e-9 < due {
                // the clock cannot fast-forward (wall-clock backend):
                // take the next request early rather than spinning
                ids[next] = Some(self.submit_one(eng, next)?);
                next += 1;
            }
        }

        let mut records = Vec::with_capacity(n);
        for (i, id) in ids.iter().enumerate() {
            let id = (*id).ok_or_else(|| {
                P3Error::Serve(format!("request {i} was never submitted"))
            })?;
            let req = eng
                .request(id)
                .ok_or(P3Error::UnknownRequest(id.0))?;
            records.push(ReqRecord {
                // a wall-clock backend can accept a request *before*
                // its scheduled arrival (advance_to is a no-op there);
                // the effective arrival is then the submit instant, so
                // latencies never go negative
                arrival_ms: (t0 + self.arrivals_ms[i])
                    .min(req.submitted_ms),
                submitted_ms: req.submitted_ms,
                prefill_start_ms: req.prefill_start_ms,
                first_token_ms: req.first_token_ms,
                finished_ms: req.finished_ms,
                prompt_len: req.prompt.len(),
                tokens_generated: req.generated.len(),
            });
        }
        let report = LoadReport::from_records(
            &records,
            &self.slo,
            &eng.metrics(),
            None,
        );
        Ok(RunOutcome { report, records })
    }

    /// [`run`](Self::run), attaching a modeled saturation throughput
    /// to the report (for utilization columns).
    pub fn run_with_saturation(
        &self,
        eng: &mut Engine,
        saturation_tok_s: Option<f64>,
    ) -> Result<RunOutcome> {
        let mut out = self.run(eng)?;
        out.report.saturation_tok_s = saturation_tok_s;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineBuilder;

    fn tiny_engine(max_batch: usize) -> Engine {
        EngineBuilder::sim()
            .model("tiny-1M")
            .max_batch(max_batch)
            .ctx_limit(128)
            .build()
            .unwrap()
    }

    #[test]
    fn closed_loop_serves_all_and_respects_arrivals() {
        let plan = LoadRunner::from_plan(
            vec![0.0, 0.0, 40.0, 1000.0],
            vec![(8, 4); 4],
            SloSpec::chatbot(),
            1,
        );
        let mut eng = tiny_engine(2);
        let out = plan.run(&mut eng).unwrap();
        assert_eq!(out.report.offered, 4);
        assert_eq!(out.report.completed, 4);
        for (r, &a) in out.records.iter().zip(&plan.arrivals_ms) {
            // never submitted before its arrival
            assert!(r.submitted_ms + 1e-9 >= a, "{r:?}");
            assert!(r.finished());
            assert!(r.ttft_ms().unwrap() > 0.0);
            assert!(r.queue_delay_ms().unwrap() >= 0.0);
        }
        // the last arrival is far out: the clock fast-forwarded to it
        assert!(out.records[3].submitted_ms >= 1000.0 - 1e-9);
        assert!((out.records[3].submitted_ms - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn runs_are_bit_identical_under_a_seed() {
        let mk = || {
            LoadRunner::new(
                &ArrivalProcess::Poisson { mean_interarrival_ms: 3.0 },
                &RequestMix::tiny(),
                SloSpec::chatbot(),
                12,
                7,
            )
        };
        let a = mk().run(&mut tiny_engine(4)).unwrap();
        let b = mk().run(&mut tiny_engine(4)).unwrap();
        assert_eq!(a.records, b.records);
        assert_eq!(a.report, b.report);
        // a different seed produces a different timeline
        let c = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 3.0 },
            &RequestMix::tiny(),
            SloSpec::chatbot(),
            12,
            8,
        )
        .run(&mut tiny_engine(4))
        .unwrap();
        assert_ne!(a.records, c.records);
    }

    #[test]
    fn saturating_load_raises_client_ttft() {
        let slo = SloSpec::chatbot();
        let mix = RequestMix::tiny();
        let heavy = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 0.05 },
            &mix,
            slo,
            24,
            3,
        );
        let calm = LoadRunner::new(
            &ArrivalProcess::Poisson { mean_interarrival_ms: 500.0 },
            &mix,
            slo,
            24,
            3,
        );
        let h = heavy.run(&mut tiny_engine(2)).unwrap().report;
        let c = calm.run(&mut tiny_engine(2)).unwrap().report;
        assert!(
            h.ttft_ms.mean > c.ttft_ms.mean,
            "{} vs {}",
            h.ttft_ms.mean,
            c.ttft_ms.mean
        );
        assert!(h.queue_delay_ms.p95 > c.queue_delay_ms.p95);
    }
}
