//! Request-mix models: how long prompts and outputs are.
//!
//! Serving behavior on NPU-PIM systems is dominated by length
//! heterogeneity (prefill is compute-bound NPU work, decode is
//! bandwidth-bound PIM work), so each named tenant class draws prompt
//! and output token counts from a clamped log-normal -- the shape
//! production traces consistently show.

use crate::testutil::Rng;

/// A pool of shared prompt prefixes (system prompts, cached RAG
/// contexts) with Zipf-distributed popularity: a few prefixes take
/// most of the traffic, the tail is cold -- the shape that makes
/// shared-prefix KV caching pay.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixPool {
    /// distinct shared prefixes in the pool
    pub n: usize,
    /// tokens per shared prefix (the cacheable span)
    pub len: usize,
    /// Zipf popularity exponent (weight of rank k is `1/(k+1)^zipf`;
    /// larger = more skewed toward the hottest prefix)
    pub zipf: f64,
    /// fraction of requests carrying no shared prefix at all
    pub p_none: f64,
}

impl PrefixPool {
    /// Draw a prefix rank by Zipf popularity (rank 0 hottest).
    pub fn sample_id(&self, rng: &mut Rng) -> usize {
        let n = self.n.max(1);
        let weights: Vec<f64> =
            (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(self.zipf)).collect();
        let total: f64 = weights.iter().sum();
        let mut u = rng.f64() * total;
        for (k, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return k;
            }
        }
        n - 1
    }
}

/// A named tenant class: log-normal prompt/output length model with
/// hard clamps so samples always fit the scenario's context budget,
/// plus an optional [`PrefixPool`] of shared prompt prefixes.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestMix {
    pub name: &'static str,
    /// ln-space location of the prompt length (ln of the median)
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_output: usize,
    pub max_output: usize,
    /// shared prompt prefixes this tenant class draws from (`None` =
    /// every prompt is unique)
    pub prefixes: Option<PrefixPool>,
}

/// ln of a median token count, as an f64 literal-friendly helper.
fn mu(median_tokens: usize) -> f64 {
    (median_tokens as f64).ln()
}

impl RequestMix {
    /// Interactive chat: short-to-medium prompts, medium answers.
    pub fn chat() -> Self {
        RequestMix {
            name: "chat",
            prompt_mu: mu(96),
            prompt_sigma: 0.7,
            output_mu: mu(64),
            output_sigma: 0.6,
            min_prompt: 8,
            max_prompt: 512,
            min_output: 4,
            max_output: 256,
            prefixes: None,
        }
    }

    /// Summarization: long documents in, short summaries out.
    pub fn summarization() -> Self {
        RequestMix {
            name: "summarization",
            prompt_mu: mu(512),
            prompt_sigma: 0.5,
            output_mu: mu(48),
            output_sigma: 0.5,
            min_prompt: 64,
            max_prompt: 1536,
            min_output: 8,
            max_output: 128,
            prefixes: None,
        }
    }

    /// Code completion: medium context, very short completions, high
    /// arrival rates (every keystroke pause can fire one).
    pub fn code_completion() -> Self {
        RequestMix {
            name: "code-completion",
            prompt_mu: mu(192),
            prompt_sigma: 0.8,
            output_mu: mu(24),
            output_sigma: 0.7,
            min_prompt: 16,
            max_prompt: 768,
            min_output: 2,
            max_output: 96,
            prefixes: None,
        }
    }

    /// Long-context RAG: retrieved passages dominate the prompt.
    pub fn rag_long() -> Self {
        RequestMix {
            name: "rag-long",
            prompt_mu: mu(1024),
            prompt_sigma: 0.4,
            output_mu: mu(96),
            output_sigma: 0.5,
            min_prompt: 256,
            max_prompt: 1792,
            min_output: 16,
            max_output: 256,
            prefixes: None,
        }
    }

    /// Miniature mix for the tiny-1M model (CI smoke gate: everything
    /// must fit a 128-token context and run in milliseconds).
    pub fn tiny() -> Self {
        RequestMix {
            name: "tiny",
            prompt_mu: mu(24),
            prompt_sigma: 0.5,
            output_mu: mu(12),
            output_sigma: 0.5,
            min_prompt: 4,
            max_prompt: 96,
            min_output: 2,
            max_output: 24,
            prefixes: None,
        }
    }

    /// Decode-heavy miniature mix for the tiny-1M model (CI smoke gate
    /// for NPU/PIM sub-batch interleaving: short prompts, long
    /// outputs, so the run's device time is dominated by batched
    /// decode steps whose NPU and PIM phases can overlap).
    pub fn tiny_decode() -> Self {
        RequestMix {
            name: "tiny-decode",
            prompt_mu: mu(12),
            prompt_sigma: 0.4,
            output_mu: mu(48),
            output_sigma: 0.3,
            min_prompt: 4,
            max_prompt: 24,
            min_output: 32,
            max_output: 64,
            prefixes: None,
        }
    }

    /// Agentic tool loop: every request re-sends one of a few long
    /// system prompts (tool schemas, instructions) ahead of a
    /// conversation-state suffix -- the canonical shared-prefix
    /// workload.
    pub fn agent() -> Self {
        RequestMix {
            name: "agent",
            prompt_mu: mu(320),
            prompt_sigma: 0.4,
            output_mu: mu(48),
            output_sigma: 0.6,
            min_prompt: 224,
            max_prompt: 768,
            min_output: 8,
            max_output: 192,
            prefixes: Some(PrefixPool {
                n: 4,
                len: 192,
                zipf: 1.0,
                p_none: 0.1,
            }),
        }
    }

    /// RAG over a popular document set: hot retrieved contexts repeat
    /// across many queries, so their prefill is cacheable.
    pub fn rag_cached() -> Self {
        RequestMix {
            name: "rag-cached",
            prompt_mu: mu(800),
            prompt_sigma: 0.3,
            output_mu: mu(64),
            output_sigma: 0.5,
            min_prompt: 576,
            max_prompt: 1408,
            min_output: 16,
            max_output: 128,
            prefixes: Some(PrefixPool {
                n: 8,
                len: 512,
                zipf: 1.2,
                p_none: 0.15,
            }),
        }
    }

    /// Prefix-bearing miniature mix for the tiny-1M model (CI smoke
    /// gate for the shared-prefix cache: two 32-token system prompts,
    /// everything fits a 128-token context).
    pub fn tiny_prefix() -> Self {
        RequestMix {
            name: "tiny-prefix",
            prompt_mu: mu(64),
            prompt_sigma: 0.3,
            output_mu: mu(8),
            output_sigma: 0.4,
            min_prompt: 48,
            max_prompt: 96,
            min_output: 2,
            max_output: 16,
            prefixes: Some(PrefixPool {
                n: 2,
                len: 32,
                zipf: 1.0,
                p_none: 0.1,
            }),
        }
    }

    /// Long-document analysis at 32k-class contexts (book chapters,
    /// contracts, log bundles): prompts run to tens of KV pages per
    /// request, so a working set of a few concurrent requests
    /// overflows an HBM hot tier and exercises the CXL cold pool.
    /// A small pool of shared instruction headers keeps the
    /// prefix cache in play.
    pub fn long_doc() -> Self {
        RequestMix {
            name: "long-doc",
            prompt_mu: mu(8192),
            prompt_sigma: 0.5,
            output_mu: mu(192),
            output_sigma: 0.5,
            min_prompt: 2048,
            max_prompt: 24576,
            min_output: 32,
            max_output: 768,
            prefixes: Some(PrefixPool {
                n: 3,
                len: 1024,
                zipf: 1.1,
                p_none: 0.2,
            }),
        }
    }

    /// Extreme long-context at 128k-class budgets (codebase dumps,
    /// multi-document synthesis): the per-request KV alone dwarfs any
    /// plausible hot tier, so decode throughput is set by how well the
    /// prefetcher hides cold-pool pulls.
    pub fn long_doc_xl() -> Self {
        RequestMix {
            name: "long-doc-xl",
            prompt_mu: mu(32768),
            prompt_sigma: 0.4,
            output_mu: mu(256),
            output_sigma: 0.5,
            min_prompt: 8192,
            max_prompt: 98304,
            min_output: 32,
            max_output: 1024,
            prefixes: Some(PrefixPool {
                n: 2,
                len: 4096,
                zipf: 1.0,
                p_none: 0.25,
            }),
        }
    }

    /// Miniature long-document mix for the tiny-1M model (CI smoke
    /// gate for the tiered KV hierarchy: prompts near the 160-token
    /// context ceiling so a fractional hot tier always overflows).
    pub fn long_doc_tiny() -> Self {
        RequestMix {
            name: "long-doc-tiny",
            prompt_mu: mu(112),
            prompt_sigma: 0.2,
            output_mu: mu(12),
            output_sigma: 0.4,
            min_prompt: 96,
            max_prompt: 128,
            min_output: 2,
            max_output: 24,
            prefixes: Some(PrefixPool {
                n: 2,
                len: 32,
                zipf: 1.0,
                p_none: 0.1,
            }),
        }
    }

    /// Draw one `(prompt_tokens, output_tokens)` pair.
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        let p = rng.lognormal(self.prompt_mu, self.prompt_sigma).round()
            as usize;
        let o = rng.lognormal(self.output_mu, self.output_sigma).round()
            as usize;
        (
            p.clamp(self.min_prompt, self.max_prompt),
            o.clamp(self.min_output, self.max_output),
        )
    }

    /// Upper bound on `prompt + output` any sample can reach (the
    /// context budget a scenario must provision).
    pub fn max_total_tokens(&self) -> usize {
        self.max_prompt + self.max_output
    }
}

/// Every named mix (`loadtest --list` shows these).
pub fn all_mixes() -> Vec<RequestMix> {
    vec![
        RequestMix::chat(),
        RequestMix::summarization(),
        RequestMix::code_completion(),
        RequestMix::rag_long(),
        RequestMix::agent(),
        RequestMix::rag_cached(),
        RequestMix::long_doc(),
        RequestMix::long_doc_xl(),
        RequestMix::tiny(),
        RequestMix::tiny_prefix(),
        RequestMix::tiny_decode(),
        RequestMix::long_doc_tiny(),
    ]
}

pub fn by_name(name: &str) -> Option<RequestMix> {
    all_mixes()
        .into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Runner;

    #[test]
    fn samples_respect_clamps_for_every_mix() {
        Runner::new(16).run(|r| {
            for m in all_mixes() {
                let (p, o) = m.sample(r);
                assert!(
                    (m.min_prompt..=m.max_prompt).contains(&p),
                    "{}: prompt {p}",
                    m.name
                );
                assert!(
                    (m.min_output..=m.max_output).contains(&o),
                    "{}: output {o}",
                    m.name
                );
            }
        });
    }

    #[test]
    fn mixes_are_seed_deterministic_and_heterogeneous() {
        let m = RequestMix::chat();
        let draw = |seed| {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| m.sample(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(1), draw(1));
        assert_ne!(draw(1), draw(2));
        // a log-normal mix is not constant-length
        let a = draw(1);
        assert!(a.iter().any(|&(p, _)| p != a[0].0));
    }

    #[test]
    fn median_roughly_matches_mu() {
        let m = RequestMix::summarization();
        let mut rng = Rng::new(7);
        let mut ps: Vec<usize> =
            (0..801).map(|_| m.sample(&mut rng).0).collect();
        ps.sort_unstable();
        let med = ps[400] as f64;
        assert!((med / 512.0 - 1.0).abs() < 0.25, "median {med}");
    }

    #[test]
    fn prefix_pools_are_zipf_skewed_and_fit_their_mix() {
        // every prefix-bearing mix leaves room for a unique suffix and
        // spans at least one full KV page
        for m in all_mixes() {
            if let Some(pp) = &m.prefixes {
                assert!(pp.len < m.min_prompt, "{}: prefix >= min prompt", m.name);
                assert!(pp.n >= 2, "{}", m.name);
                assert!(pp.len >= 16, "{}: prefix below one KV page", m.name);
                assert!((0.0..1.0).contains(&pp.p_none), "{}", m.name);
            }
        }
        // Zipf skew: rank 0 is drawn most often, every rank reachable
        let pp = RequestMix::rag_cached().prefixes.unwrap();
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; pp.n];
        for _ in 0..4000 {
            counts[pp.sample_id(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[pp.n - 1] * 2, "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        // deterministic under a seed
        let draw = |seed| {
            let mut r = Rng::new(seed);
            (0..64).map(|_| pp.sample_id(&mut r)).collect::<Vec<_>>()
        };
        assert_eq!(draw(9), draw(9));
    }

    #[test]
    fn registry_lookup() {
        assert_eq!(by_name("chat").unwrap().name, "chat");
        assert_eq!(by_name("RAG-LONG").unwrap().name, "rag-long");
        assert!(by_name("nope").is_none());
        // names are unique
        let names: std::collections::HashSet<_> =
            all_mixes().iter().map(|m| m.name).collect();
        assert_eq!(names.len(), all_mixes().len());
    }
}
