//! Request-mix models: how long prompts and outputs are.
//!
//! Serving behavior on NPU-PIM systems is dominated by length
//! heterogeneity (prefill is compute-bound NPU work, decode is
//! bandwidth-bound PIM work), so each named tenant class draws prompt
//! and output token counts from a clamped log-normal -- the shape
//! production traces consistently show.

use crate::testutil::Rng;

/// A named tenant class: log-normal prompt/output length model with
/// hard clamps so samples always fit the scenario's context budget.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestMix {
    pub name: &'static str,
    /// ln-space location of the prompt length (ln of the median)
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_output: usize,
    pub max_output: usize,
}

/// ln of a median token count, as an f64 literal-friendly helper.
fn mu(median_tokens: usize) -> f64 {
    (median_tokens as f64).ln()
}

impl RequestMix {
    /// Interactive chat: short-to-medium prompts, medium answers.
    pub fn chat() -> Self {
        RequestMix {
            name: "chat",
            prompt_mu: mu(96),
            prompt_sigma: 0.7,
            output_mu: mu(64),
            output_sigma: 0.6,
            min_prompt: 8,
            max_prompt: 512,
            min_output: 4,
            max_output: 256,
        }
    }

    /// Summarization: long documents in, short summaries out.
    pub fn summarization() -> Self {
        RequestMix {
            name: "summarization",
            prompt_mu: mu(512),
            prompt_sigma: 0.5,
            output_mu: mu(48),
            output_sigma: 0.5,
            min_prompt: 64,
            max_prompt: 1536,
            min_output: 8,
            max_output: 128,
        }
    }

    /// Code completion: medium context, very short completions, high
    /// arrival rates (every keystroke pause can fire one).
    pub fn code_completion() -> Self {
        RequestMix {
            name: "code-completion",
            prompt_mu: mu(192),
            prompt_sigma: 0.8,
            output_mu: mu(24),
            output_sigma: 0.7,
            min_prompt: 16,
            max_prompt: 768,
            min_output: 2,
            max_output: 96,
        }
    }

    /// Long-context RAG: retrieved passages dominate the prompt.
    pub fn rag_long() -> Self {
        RequestMix {
            name: "rag-long",
            prompt_mu: mu(1024),
            prompt_sigma: 0.4,
            output_mu: mu(96),
            output_sigma: 0.5,
            min_prompt: 256,
            max_prompt: 1792,
            min_output: 16,
            max_output: 256,
        }
    }

    /// Miniature mix for the tiny-1M model (CI smoke gate: everything
    /// must fit a 128-token context and run in milliseconds).
    pub fn tiny() -> Self {
        RequestMix {
            name: "tiny",
            prompt_mu: mu(24),
            prompt_sigma: 0.5,
            output_mu: mu(12),
            output_sigma: 0.5,
            min_prompt: 4,
            max_prompt: 96,
            min_output: 2,
            max_output: 24,
        }
    }

    /// Draw one `(prompt_tokens, output_tokens)` pair.
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        let p = rng.lognormal(self.prompt_mu, self.prompt_sigma).round()
            as usize;
        let o = rng.lognormal(self.output_mu, self.output_sigma).round()
            as usize;
        (
            p.clamp(self.min_prompt, self.max_prompt),
            o.clamp(self.min_output, self.max_output),
        )
    }

    /// Upper bound on `prompt + output` any sample can reach (the
    /// context budget a scenario must provision).
    pub fn max_total_tokens(&self) -> usize {
        self.max_prompt + self.max_output
    }
}

/// Every named mix (`loadtest --list` shows these).
pub fn all_mixes() -> Vec<RequestMix> {
    vec![
        RequestMix::chat(),
        RequestMix::summarization(),
        RequestMix::code_completion(),
        RequestMix::rag_long(),
        RequestMix::tiny(),
    ]
}

pub fn by_name(name: &str) -> Option<RequestMix> {
    all_mixes()
        .into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Runner;

    #[test]
    fn samples_respect_clamps_for_every_mix() {
        Runner::new(16).run(|r| {
            for m in all_mixes() {
                let (p, o) = m.sample(r);
                assert!(
                    (m.min_prompt..=m.max_prompt).contains(&p),
                    "{}: prompt {p}",
                    m.name
                );
                assert!(
                    (m.min_output..=m.max_output).contains(&o),
                    "{}: output {o}",
                    m.name
                );
            }
        });
    }

    #[test]
    fn mixes_are_seed_deterministic_and_heterogeneous() {
        let m = RequestMix::chat();
        let draw = |seed| {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| m.sample(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(1), draw(1));
        assert_ne!(draw(1), draw(2));
        // a log-normal mix is not constant-length
        let a = draw(1);
        assert!(a.iter().any(|&(p, _)| p != a[0].0));
    }

    #[test]
    fn median_roughly_matches_mu() {
        let m = RequestMix::summarization();
        let mut rng = Rng::new(7);
        let mut ps: Vec<usize> =
            (0..801).map(|_| m.sample(&mut rng).0).collect();
        ps.sort_unstable();
        let med = ps[400] as f64;
        assert!((med / 512.0 - 1.0).abs() < 0.25, "median {med}");
    }

    #[test]
    fn registry_lookup() {
        assert_eq!(by_name("chat").unwrap().name, "chat");
        assert_eq!(by_name("RAG-LONG").unwrap().name, "rag-long");
        assert!(by_name("nope").is_none());
        // names are unique
        let names: std::collections::HashSet<_> =
            all_mixes().iter().map(|m| m.name).collect();
        assert_eq!(names.len(), all_mixes().len());
    }
}
