//! Named serving scenarios: (arrival process, request mix, SLO,
//! engine shape) bundles the `loadtest` CLI sweeps by name.
//!
//! Each scenario is sized so a full seed-deterministic run finishes in
//! seconds on the sim backend while still exercising the regime it is
//! named after (queueing under Poisson load, KV admission under
//! bursts, long-context prefill pressure, ...).

use crate::accel;
use crate::config::llm;
use crate::coordinator::{Engine, EngineBuilder, KvLayout};
use crate::error::{P3Error, Result};

use super::arrival::ArrivalProcess;
use super::mix::RequestMix;
use super::runner::LoadRunner;
use super::slo::SloSpec;

#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub desc: &'static str,
    /// `config::llm` registry name
    pub model: &'static str,
    pub arrival: ArrivalProcess,
    pub mix: RequestMix,
    pub slo: SloSpec,
    pub n_requests: usize,
    pub max_batch: usize,
    pub ctx_limit: usize,
    /// full-context KV footprints the pool capacity is provisioned
    /// for (`kv_slots x KvLayout::bytes_per_request`).  Admission is
    /// page-granular, so short requests pack denser than this bound;
    /// a value *below* `max_batch` still makes bursts overcommit the
    /// pool and exercises admission control (bounce + FIFO requeue).
    pub kv_slots: usize,
    /// shared-prefix KV caching on the scenario's engines (default
    /// on; `loadtest --no-prefix-cache` and `benches/prefix_cache.rs`
    /// flip it for A/B runs)
    pub prefix_cache: bool,
}

impl Scenario {
    /// Materialize this scenario's load plan for a seed.
    pub fn runner(&self, seed: u64) -> LoadRunner {
        LoadRunner::new(
            &self.arrival,
            &self.mix,
            self.slo,
            self.n_requests,
            seed,
        )
    }

    /// Build a sim-backend engine shaped for this scenario on the
    /// named system, optionally overriding the quantization scheme.
    pub fn engine(
        &self,
        system: &str,
        scheme: Option<&str>,
    ) -> Result<Engine> {
        let model = llm::by_name(self.model)
            .ok_or_else(|| P3Error::UnknownModel(self.model.into()))?;
        let per_req = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: self.ctx_limit.min(model.max_ctx),
        }
        .bytes_per_request();
        let mut b = EngineBuilder::sim()
            .model(self.model)
            .system(system)
            .max_batch(self.max_batch)
            .ctx_limit(self.ctx_limit.min(model.max_ctx))
            .kv_capacity(per_req.saturating_mul(self.kv_slots.max(1)))
            .prefix_cache(self.prefix_cache);
        if let Some(s) = scheme {
            b = b.scheme(s);
        }
        b.build()
    }

    /// Scale the arrival process (`--scale`: > 1 thins the load, < 1
    /// intensifies it); degenerate factors are typed errors.
    pub fn with_scale(mut self, factor: f64) -> Result<Self> {
        self.arrival = self.arrival.scaled(factor)?;
        Ok(self)
    }

    /// Weak-scaling transform for an `n`-replica fleet: `n` times the
    /// request volume at `n` times the arrival rate, so per-replica
    /// offered load matches the single-engine scenario and fleet
    /// goodput can be read as a scaling curve.
    pub fn for_fleet(mut self, replicas: usize) -> Result<Self> {
        let r = replicas.max(1);
        self.n_requests *= r;
        self.arrival = self.arrival.scaled(1.0 / r as f64)?;
        Ok(self)
    }

    /// Modeled peak decode throughput (tok/s) of `system` at this
    /// scenario's batch/context -- the saturation roof `LoadReport`
    /// utilization is measured against.
    pub fn saturation_tok_s(&self, system: &str) -> Option<f64> {
        let a = accel::by_name(system)?;
        let m = llm::by_name(self.model)?;
        let ctx = self.ctx_limit.min(m.max_ctx);
        Some(a.decode_tokens_per_sec(&m, self.max_batch, ctx))
    }
}

/// The named scenario registry (`loadtest --scenario NAME | all`).
pub fn all_scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "chat-poisson",
            desc: "interactive chat, Poisson arrivals, 250 ms TTFT SLO",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 120.0 },
            mix: RequestMix::chat(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 1024,
            kv_slots: 10,
            prefix_cache: true,
        },
        Scenario {
            name: "chat-burst",
            desc: "chat under on/off bursts (KV admission pressure)",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::OnOff {
                burst_n: 8,
                burst_gap_ms: 2.0,
                idle_ms: 900.0,
            },
            mix: RequestMix::chat(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 1024,
            // fewer KV slots than batch lanes: each 8-request burst
            // overcommits the pool, exercising bounce + FIFO requeue
            kv_slots: 5,
            prefix_cache: true,
        },
        Scenario {
            name: "summarize-steady",
            desc: "document summarization at a constant feed rate",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Constant { interarrival_ms: 250.0 },
            mix: RequestMix::summarization(),
            slo: SloSpec::relaxed(),
            n_requests: 24,
            max_batch: 8,
            ctx_limit: 2048,
            kv_slots: 10,
            prefix_cache: true,
        },
        Scenario {
            name: "code-complete",
            desc: "high-rate code completion, tight first-token budget",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 60.0 },
            mix: RequestMix::code_completion(),
            slo: SloSpec::interactive_tight(),
            n_requests: 48,
            max_batch: 16,
            ctx_limit: 1024,
            kv_slots: 18,
            prefix_cache: true,
        },
        Scenario {
            name: "rag-long",
            desc: "long-context RAG: prefill-heavy retrieved prompts",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 400.0 },
            mix: RequestMix::rag_long(),
            slo: SloSpec::relaxed(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 2048,
            kv_slots: 6,
            prefix_cache: true,
        },
        Scenario {
            name: "agent-pool",
            desc: "agent loops re-sending Zipf-popular system prompts",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 100.0 },
            mix: RequestMix::agent(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 1024,
            kv_slots: 10,
            prefix_cache: true,
        },
        Scenario {
            name: "rag-cached",
            desc: "RAG over hot documents: cacheable retrieved contexts",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 300.0 },
            mix: RequestMix::rag_cached(),
            slo: SloSpec::relaxed(),
            n_requests: 16,
            max_batch: 4,
            ctx_limit: 2048,
            kv_slots: 6,
            prefix_cache: true,
        },
        Scenario {
            name: "smoke",
            desc: "CI gate: tiny model, Poisson load, milliseconds",
            model: "tiny-1M",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 5.0 },
            mix: RequestMix::tiny(),
            slo: SloSpec::chatbot(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 128,
            kv_slots: 6,
            prefix_cache: true,
        },
        Scenario {
            name: "smoke-prefix",
            desc: "CI gate: shared-prefix cache on the tiny model",
            model: "tiny-1M",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 5.0 },
            mix: RequestMix::tiny_prefix(),
            slo: SloSpec::chatbot(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 128,
            kv_slots: 6,
            prefix_cache: true,
        },
    ]
}

/// Case-insensitive scenario lookup.
pub fn by_name(name: &str) -> Option<Scenario> {
    all_scenarios()
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_the_advertised_matrix() {
        let all = all_scenarios();
        // >= 4 named non-smoke scenarios, unique names
        assert!(all.iter().filter(|s| s.name != "smoke").count() >= 4);
        let names: std::collections::HashSet<_> =
            all.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), all.len());
        assert_eq!(by_name("chat-poisson").unwrap().name, "chat-poisson");
        assert_eq!(by_name("SMOKE").unwrap().model, "tiny-1M");
        assert!(by_name("warp").is_none());
        // the bursty scenario must actually overcommit the KV pool:
        // fewer reservations than batch lanes, or admission control
        // (the thing it is named for) never triggers
        let burst = by_name("chat-burst").unwrap();
        assert!(burst.kv_slots < burst.max_batch);
    }

    #[test]
    fn scenarios_fit_their_context_budget_and_build() {
        for s in all_scenarios() {
            let m = llm::by_name(s.model).unwrap();
            let ctx = s.ctx_limit.min(m.max_ctx);
            // the mix's worst-case prompt must be admissible, and the
            // worst-case prompt + output must fit the context budget
            assert!(
                s.mix.max_prompt < ctx,
                "{}: prompt {} !< ctx {ctx}",
                s.name,
                s.mix.max_prompt
            );
            assert!(
                s.mix.max_total_tokens() <= ctx,
                "{}: prompt+output {} > ctx {ctx}",
                s.name,
                s.mix.max_total_tokens()
            );
            // engines build for every fig9 system
            for sys in ["NPU", "HBM-PIM", "Ecco", "P3-LLM"] {
                s.engine(sys, None).unwrap();
                assert!(s.saturation_tok_s(sys).unwrap() > 0.0);
            }
            assert!(s.engine("no-such-system", None).is_err());
        }
    }

    #[test]
    fn smoke_prefix_scenario_hits_the_cache() {
        let sc = by_name("smoke-prefix").unwrap();
        assert!(sc.mix.prefixes.is_some());
        let mut eng = sc.engine("P3-LLM", None).unwrap();
        assert!(eng.prefix_cache_enabled());
        let on = sc.runner(7).run(&mut eng).unwrap().report;
        assert_eq!(on.completed, sc.n_requests);
        assert!(on.prefix_hit_rate > 0.0, "{:?}", on.prefix_hits);
        assert!(on.prefill_tokens_saved > 0);
        // the same scenario with the cache disabled: zero hits and a
        // strictly higher mean TTFT (the CI smoke gate's assertion)
        let mut cold = sc.clone();
        cold.prefix_cache = false;
        let mut ceng = cold.engine("P3-LLM", None).unwrap();
        assert!(!ceng.prefix_cache_enabled());
        let off = cold.runner(7).run(&mut ceng).unwrap().report;
        assert_eq!(off.prefix_hits, 0);
        assert!(
            on.ttft_ms.mean < off.ttft_ms.mean,
            "cached {} !< cold {}",
            on.ttft_ms.mean,
            off.ttft_ms.mean
        );
    }

    #[test]
    fn smoke_scenario_runs_end_to_end() {
        let s = by_name("smoke").unwrap();
        let mut eng = s.engine("P3-LLM", None).unwrap();
        let out = s.runner(7).run(&mut eng).unwrap();
        assert_eq!(out.report.offered, s.n_requests);
        assert_eq!(out.report.completed, s.n_requests);
        assert!(out.report.goodput_tok_s > 0.0);
        assert!(out.report.slo_attainment > 0.0);
        // the decode-busy rate (observed saturation proxy) is live
        assert!(out.report.busy_tok_s > 0.0);
    }
}
