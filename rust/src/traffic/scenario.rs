//! Named serving scenarios: (arrival process, request mix, SLO,
//! engine shape) bundles the `loadtest` CLI sweeps by name.
//!
//! Each scenario is sized so a full seed-deterministic run finishes in
//! seconds on the sim backend while still exercising the regime it is
//! named after (queueing under Poisson load, KV admission under
//! bursts, long-context prefill pressure, ...).

use crate::accel;
use crate::config::llm;
use crate::coordinator::{Engine, EngineBuilder, KvLayout};
use crate::error::{P3Error, Result};
use crate::sched::TierMix;

use super::arrival::ArrivalProcess;
use super::mix::RequestMix;
use super::runner::LoadRunner;
use super::slo::SloSpec;

#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub desc: &'static str,
    /// `config::llm` registry name
    pub model: &'static str,
    pub arrival: ArrivalProcess,
    pub mix: RequestMix,
    pub slo: SloSpec,
    pub n_requests: usize,
    pub max_batch: usize,
    pub ctx_limit: usize,
    /// full-context KV footprints the pool capacity is provisioned
    /// for (`kv_slots x KvLayout::bytes_per_request`).  Admission is
    /// page-granular, so short requests pack denser than this bound;
    /// a value *below* `max_batch` still makes bursts overcommit the
    /// pool and exercises admission control (bounce + FIFO requeue).
    pub kv_slots: usize,
    /// shared-prefix KV caching on the scenario's engines (default
    /// on; `loadtest --no-prefix-cache` and `benches/prefix_cache.rs`
    /// flip it for A/B runs)
    pub prefix_cache: bool,
    /// SLO tier mix the runner samples per-request classes from
    /// (`None` = everything [`Interactive`](crate::sched::SloClass),
    /// the pre-tier behaviour)
    pub tiers: Option<TierMix>,
    /// victim policy for preemptive scheduling on this scenario's
    /// engines (`None` = FIFO admission, no preemption; see
    /// `sched::victim_by_name`)
    pub victim: Option<&'static str>,
    /// NPU/PIM sub-batch interleaving on this scenario's engines
    /// (`false` = the serial schedule; `p3llm interleave` and the
    /// A/B bench flip it)
    pub interleave: bool,
}

impl Scenario {
    /// Materialize this scenario's load plan for a seed (tier classes
    /// sampled from [`Scenario::tiers`] when set).
    pub fn runner(&self, seed: u64) -> LoadRunner {
        let plan = LoadRunner::new(
            &self.arrival,
            &self.mix,
            self.slo,
            self.n_requests,
            seed,
        );
        match self.tiers {
            Some(mix) => plan.with_tiers(mix),
            None => plan,
        }
    }

    /// Build a sim-backend engine shaped for this scenario on the
    /// named system, optionally overriding the quantization scheme.
    pub fn engine(
        &self,
        system: &str,
        scheme: Option<&str>,
    ) -> Result<Engine> {
        let model = llm::by_name(self.model)
            .ok_or_else(|| P3Error::UnknownModel(self.model.into()))?;
        let per_req = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: self.ctx_limit.min(model.max_ctx),
        }
        .bytes_per_request();
        let mut b = EngineBuilder::sim()
            .model(self.model)
            .system(system)
            .max_batch(self.max_batch)
            .ctx_limit(self.ctx_limit.min(model.max_ctx))
            .kv_capacity(per_req.saturating_mul(self.kv_slots.max(1)))
            .prefix_cache(self.prefix_cache)
            .interleave(self.interleave);
        if let Some(v) = self.victim {
            b = b.preempt(v);
        }
        if let Some(s) = scheme {
            b = b.scheme(s);
        }
        b.build()
    }

    /// Build this scenario's engine with the tiered KV hierarchy
    /// enabled: `hot_fraction` of the pool's pages live in PIM-attached
    /// HBM, the rest in the modeled CXL cold pool, and the
    /// ahead-of-decode prefetcher pulls `prefetch_depth` pages per
    /// request per step (`0` = pure demand paging).  This is the engine
    /// shape `p3llm memtier` sweeps.
    pub fn engine_tiered(
        &self,
        system: &str,
        scheme: Option<&str>,
        hot_fraction: f64,
        prefetch_depth: usize,
    ) -> Result<Engine> {
        let model = llm::by_name(self.model)
            .ok_or_else(|| P3Error::UnknownModel(self.model.into()))?;
        let per_req = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: self.ctx_limit.min(model.max_ctx),
        }
        .bytes_per_request();
        let mut b = EngineBuilder::sim()
            .model(self.model)
            .system(system)
            .max_batch(self.max_batch)
            .ctx_limit(self.ctx_limit.min(model.max_ctx))
            .kv_capacity(per_req.saturating_mul(self.kv_slots.max(1)))
            .prefix_cache(self.prefix_cache)
            .interleave(self.interleave)
            .hot_fraction(hot_fraction)
            .prefetch_depth(prefetch_depth);
        if let Some(v) = self.victim {
            b = b.preempt(v);
        }
        if let Some(s) = scheme {
            b = b.scheme(s);
        }
        b.build()
    }

    /// Scale the arrival process (`--scale`: > 1 thins the load, < 1
    /// intensifies it); degenerate factors are typed errors.
    pub fn with_scale(mut self, factor: f64) -> Result<Self> {
        self.arrival = self.arrival.scaled(factor)?;
        Ok(self)
    }

    /// Rescale the arrival process so the offered decode-token rate is
    /// `load` times the modeled saturation throughput of `system`
    /// ([`Scenario::saturation_tok_s`]) -- `load = 1.0` offers exactly
    /// saturation, `2.0` twice it.  The base rate is measured on the
    /// materialized plan for `seed` (post-clamp output lengths over
    /// the arrival span), so the normalization holds for the plan a
    /// caller then actually runs with the same seed.  This is what
    /// lets `p3llm overload` and the degradation bench talk about
    /// "2x saturation" without knowing absolute sim timings.
    pub fn with_load_factor(
        self,
        system: &str,
        load: f64,
        seed: u64,
    ) -> Result<Self> {
        if !load.is_finite() || load <= 0.0 {
            return Err(P3Error::InvalidFlag {
                flag: "load".into(),
                value: format!("{load}"),
            });
        }
        let sat = self.saturation_tok_s(system).ok_or_else(|| {
            P3Error::UnknownSystem(system.into())
        })?;
        let plan = self.runner(seed);
        let toks: usize = plan.shapes.iter().map(|&(_, o)| o).sum();
        let span_ms = plan
            .arrivals_ms
            .last()
            .copied()
            .unwrap_or(0.0)
            .max(1e-6);
        let base_tok_s = toks as f64 / (span_ms / 1e3);
        // with_scale(f) multiplies inter-arrival gaps by f, dividing
        // the offered rate by f: pick f so the new rate is load * sat
        self.with_scale(base_tok_s / (load * sat))
    }

    /// Weak-scaling transform for an `n`-replica fleet: `n` times the
    /// request volume at `n` times the arrival rate, so per-replica
    /// offered load matches the single-engine scenario and fleet
    /// goodput can be read as a scaling curve.
    pub fn for_fleet(mut self, replicas: usize) -> Result<Self> {
        let r = replicas.max(1);
        self.n_requests *= r;
        self.arrival = self.arrival.scaled(1.0 / r as f64)?;
        Ok(self)
    }

    /// Modeled peak decode throughput (tok/s) of `system` at this
    /// scenario's batch/context -- the saturation roof `LoadReport`
    /// utilization is measured against.
    pub fn saturation_tok_s(&self, system: &str) -> Option<f64> {
        let a = accel::by_name(system)?;
        let m = llm::by_name(self.model)?;
        let ctx = self.ctx_limit.min(m.max_ctx);
        Some(a.decode_tokens_per_sec(&m, self.max_batch, ctx))
    }
}

/// The named scenario registry (`loadtest --scenario NAME | all`).
pub fn all_scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "chat-poisson",
            desc: "interactive chat, Poisson arrivals, 250 ms TTFT SLO",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 120.0 },
            mix: RequestMix::chat(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 1024,
            kv_slots: 10,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "chat-burst",
            desc: "chat under on/off bursts (KV admission pressure)",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::OnOff {
                burst_n: 8,
                burst_gap_ms: 2.0,
                idle_ms: 900.0,
            },
            mix: RequestMix::chat(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 1024,
            // fewer KV slots than batch lanes: each 8-request burst
            // overcommits the pool, exercising bounce + FIFO requeue
            kv_slots: 5,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "summarize-steady",
            desc: "document summarization at a constant feed rate",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Constant { interarrival_ms: 250.0 },
            mix: RequestMix::summarization(),
            slo: SloSpec::relaxed(),
            n_requests: 24,
            max_batch: 8,
            ctx_limit: 2048,
            kv_slots: 10,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "code-complete",
            desc: "high-rate code completion, tight first-token budget",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 60.0 },
            mix: RequestMix::code_completion(),
            slo: SloSpec::interactive_tight(),
            n_requests: 48,
            max_batch: 16,
            ctx_limit: 1024,
            kv_slots: 18,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "rag-long",
            desc: "long-context RAG: prefill-heavy retrieved prompts",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 400.0 },
            mix: RequestMix::rag_long(),
            slo: SloSpec::relaxed(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 2048,
            kv_slots: 6,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "agent-pool",
            desc: "agent loops re-sending Zipf-popular system prompts",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 100.0 },
            mix: RequestMix::agent(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 1024,
            kv_slots: 10,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "rag-cached",
            desc: "RAG over hot documents: cacheable retrieved contexts",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 300.0 },
            mix: RequestMix::rag_cached(),
            slo: SloSpec::relaxed(),
            n_requests: 16,
            max_batch: 4,
            ctx_limit: 2048,
            kv_slots: 6,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "smoke",
            desc: "CI gate: tiny model, Poisson load, milliseconds",
            model: "tiny-1M",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 5.0 },
            mix: RequestMix::tiny(),
            slo: SloSpec::chatbot(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 128,
            kv_slots: 6,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "flash-crowd",
            desc: "mixed-tenant base + interactive flash crowd bursts \
                   (preemptive recompute evictions)",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::OnOff {
                burst_n: 6,
                burst_gap_ms: 40.0,
                idle_ms: 700.0,
            },
            mix: RequestMix::chat(),
            slo: SloSpec::chatbot(),
            n_requests: 36,
            max_batch: 8,
            ctx_limit: 1024,
            // fewer KV reservations than batch lanes: bursts exhaust
            // the pool while lanes are free, so a high-tier newcomer
            // must evict rather than bounce
            kv_slots: 5,
            prefix_cache: true,
            tiers: Some(TierMix::mixed()),
            victim: Some("recompute"),
            interleave: false,
        },
        Scenario {
            name: "starve-probe",
            desc: "80/20 interactive/best-effort: does the aging floor \
                   keep the 20% alive? (swap evictions)",
            model: "Llama-3.2-3B",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 70.0 },
            mix: RequestMix::chat(),
            slo: SloSpec::chatbot(),
            n_requests: 40,
            max_batch: 8,
            ctx_limit: 1024,
            kv_slots: 5,
            prefix_cache: true,
            tiers: Some(TierMix {
                interactive: 0.8,
                batch: 0.0,
                best_effort: 0.2,
            }),
            victim: Some("swap"),
            interleave: false,
        },
        Scenario {
            name: "smoke-overload",
            desc: "CI gate: tiny model past saturation, tiered + \
                   preemptive, milliseconds",
            model: "tiny-1M",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 2.0 },
            mix: RequestMix::tiny(),
            slo: SloSpec::chatbot(),
            n_requests: 48,
            max_batch: 8,
            ctx_limit: 128,
            // 2 full-context reservations = 16 pages; typical tiny
            // requests reserve ~3 pages, so ~5 fit -- KV binds while
            // ~3 batch lanes stay free (eviction, not bounce)
            kv_slots: 2,
            prefix_cache: true,
            tiers: Some(TierMix {
                interactive: 0.25,
                batch: 0.25,
                best_effort: 0.5,
            }),
            victim: Some("recompute"),
            interleave: false,
        },
        Scenario {
            name: "long-doc-32k",
            desc: "32k-context document analysis: per-request KV spans \
                   hundreds of pages (HBM/CXL tiering territory)",
            model: "Mistral-7B",
            arrival: ArrivalProcess::Poisson {
                mean_interarrival_ms: 2000.0,
            },
            mix: RequestMix::long_doc(),
            slo: SloSpec::relaxed(),
            n_requests: 8,
            max_batch: 4,
            ctx_limit: 32768,
            // two full-context reservations back ~4 concurrent long
            // docs: admission overcommits against the cold pool while
            // a fractional hot tier overflows every step
            kv_slots: 2,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "long-doc-128k",
            desc: "128k-context synthesis: KV per request dwarfs any \
                   hot tier, decode rides the prefetcher",
            model: "Llama-3.1-8B",
            arrival: ArrivalProcess::Poisson {
                mean_interarrival_ms: 8000.0,
            },
            mix: RequestMix::long_doc_xl(),
            slo: SloSpec::relaxed(),
            n_requests: 4,
            max_batch: 2,
            ctx_limit: 131072,
            kv_slots: 1,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "smoke-longdoc",
            desc: "CI gate: tiny model, near-ceiling prompts over a \
                   fractional HBM hot tier, milliseconds",
            model: "tiny-1M",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 5.0 },
            mix: RequestMix::long_doc_tiny(),
            slo: SloSpec::relaxed(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 160,
            kv_slots: 4,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
        Scenario {
            name: "smoke-interleave",
            desc: "CI gate: decode-heavy tiny batches for the NPU/PIM \
                   sub-batch interleaving A/B, milliseconds",
            model: "tiny-1M",
            // arrivals outpace the ~microsecond decode steps so the
            // backlog pins the batch at all 8 lanes for most of the
            // run (the acceptance regime: decode-heavy at batch >= 8)
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 0.005 },
            mix: RequestMix::tiny_decode(),
            slo: SloSpec::chatbot(),
            n_requests: 32,
            max_batch: 8,
            ctx_limit: 128,
            kv_slots: 10,
            prefix_cache: true,
            tiers: None,
            victim: None,
            // the registry default is the serial schedule; the
            // `interleave` CLI and bench flip this for the A/B
            interleave: false,
        },
        Scenario {
            name: "smoke-prefix",
            desc: "CI gate: shared-prefix cache on the tiny model",
            model: "tiny-1M",
            arrival: ArrivalProcess::Poisson { mean_interarrival_ms: 5.0 },
            mix: RequestMix::tiny_prefix(),
            slo: SloSpec::chatbot(),
            n_requests: 12,
            max_batch: 4,
            ctx_limit: 128,
            kv_slots: 6,
            prefix_cache: true,
            tiers: None,
            victim: None,
            interleave: false,
        },
    ]
}

/// Case-insensitive scenario lookup.
pub fn by_name(name: &str) -> Option<Scenario> {
    all_scenarios()
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_the_advertised_matrix() {
        let all = all_scenarios();
        // >= 4 named non-smoke scenarios, unique names
        assert!(all.iter().filter(|s| s.name != "smoke").count() >= 4);
        let names: std::collections::HashSet<_> =
            all.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), all.len());
        assert_eq!(by_name("chat-poisson").unwrap().name, "chat-poisson");
        assert_eq!(by_name("SMOKE").unwrap().model, "tiny-1M");
        assert!(by_name("warp").is_none());
        // the bursty scenario must actually overcommit the KV pool:
        // fewer reservations than batch lanes, or admission control
        // (the thing it is named for) never triggers
        let burst = by_name("chat-burst").unwrap();
        assert!(burst.kv_slots < burst.max_batch);
    }

    #[test]
    fn scenarios_fit_their_context_budget_and_build() {
        for s in all_scenarios() {
            let m = llm::by_name(s.model).unwrap();
            let ctx = s.ctx_limit.min(m.max_ctx);
            // the mix's worst-case prompt must be admissible, and the
            // worst-case prompt + output must fit the context budget
            assert!(
                s.mix.max_prompt < ctx,
                "{}: prompt {} !< ctx {ctx}",
                s.name,
                s.mix.max_prompt
            );
            assert!(
                s.mix.max_total_tokens() <= ctx,
                "{}: prompt+output {} > ctx {ctx}",
                s.name,
                s.mix.max_total_tokens()
            );
            // engines build for every fig9 system
            for sys in ["NPU", "HBM-PIM", "Ecco", "P3-LLM"] {
                s.engine(sys, None).unwrap();
                assert!(s.saturation_tok_s(sys).unwrap() > 0.0);
            }
            assert!(s.engine("no-such-system", None).is_err());
        }
    }

    #[test]
    fn smoke_prefix_scenario_hits_the_cache() {
        let sc = by_name("smoke-prefix").unwrap();
        assert!(sc.mix.prefixes.is_some());
        let mut eng = sc.engine("P3-LLM", None).unwrap();
        assert!(eng.prefix_cache_enabled());
        let on = sc.runner(7).run(&mut eng).unwrap().report;
        assert_eq!(on.completed, sc.n_requests);
        assert!(on.prefix_hit_rate > 0.0, "{:?}", on.prefix_hits);
        assert!(on.prefill_tokens_saved > 0);
        // the same scenario with the cache disabled: zero hits and a
        // strictly higher mean TTFT (the CI smoke gate's assertion)
        let mut cold = sc.clone();
        cold.prefix_cache = false;
        let mut ceng = cold.engine("P3-LLM", None).unwrap();
        assert!(!ceng.prefix_cache_enabled());
        let off = cold.runner(7).run(&mut ceng).unwrap().report;
        assert_eq!(off.prefix_hits, 0);
        assert!(
            on.ttft_ms.mean < off.ttft_ms.mean,
            "cached {} !< cold {}",
            on.ttft_ms.mean,
            off.ttft_ms.mean
        );
    }

    #[test]
    fn smoke_longdoc_overflows_the_hot_tier_and_loses_nothing() {
        let sc = by_name("smoke-longdoc").unwrap();
        // near-ceiling prompts: a 0.3 hot tier cannot hold even one
        // request's pages, so every step crosses the CXL link
        let mut eng = sc.engine_tiered("P3-LLM", None, 0.3, 4).unwrap();
        assert!(eng.tier_occupancy().is_some());
        let on = sc.runner(7).run(&mut eng).unwrap().report;
        assert_eq!(on.completed, sc.n_requests, "requests lost");
        assert!(on.pages_prefetched > 0, "prefetcher never fired");
        // the same scenario demand-paged on identical seeds: at least
        // as many stalls as the prefetching run, never a faster decode
        let mut deng = sc.engine_tiered("P3-LLM", None, 0.3, 0).unwrap();
        let off = sc.runner(7).run(&mut deng).unwrap().report;
        assert_eq!(off.completed, sc.n_requests);
        assert_eq!(off.pages_prefetched, 0);
        assert!(off.pages_demand > on.pages_demand);
        assert!(
            on.tpot_ms.mean < off.tpot_ms.mean,
            "prefetch {} !< demand {}",
            on.tpot_ms.mean,
            off.tpot_ms.mean
        );
        // the untiered engine path is untouched by the knobs
        assert!(sc.engine("P3-LLM", None).unwrap().tier_occupancy().is_none());
    }

    #[test]
    fn overload_scenarios_are_tiered_and_kv_bound() {
        for name in ["flash-crowd", "starve-probe", "smoke-overload"] {
            let s = by_name(name).unwrap();
            assert!(s.tiers.is_some(), "{name}: untiered");
            assert!(s.victim.is_some(), "{name}: no victim policy");
            // KV must bind before batch lanes do, or a high-tier
            // newcomer bounces instead of evicting
            assert!(s.kv_slots < s.max_batch, "{name}");
            let eng = s.engine("P3-LLM", None).unwrap();
            assert_eq!(eng.victim_policy(), Some(s.victim.unwrap()));
        }
        // load normalization: rescaled plans offer load*saturation
        let s = by_name("smoke-overload").unwrap();
        let sat = s.saturation_tok_s("P3-LLM").unwrap();
        for load in [0.5, 2.0] {
            let scaled = s
                .clone()
                .with_load_factor("P3-LLM", load, 7)
                .unwrap();
            let plan = scaled.runner(7);
            let toks: usize =
                plan.shapes.iter().map(|&(_, o)| o).sum();
            let rate =
                toks as f64 / (plan.arrivals_ms.last().unwrap() / 1e3);
            assert!(
                (rate / sat - load).abs() < 0.05 * load,
                "load {load}: offered {rate} vs sat {sat}"
            );
        }
        assert!(s
            .clone()
            .with_load_factor("P3-LLM", f64::NAN, 7)
            .is_err());
        assert!(s.with_load_factor("no-such-system", 1.0, 7).is_err());
    }

    #[test]
    fn smoke_overload_preempts_and_completes_past_saturation() {
        let s = by_name("smoke-overload")
            .unwrap()
            .with_load_factor("P3-LLM", 2.0, 7)
            .unwrap();
        let mut eng = s.engine("P3-LLM", None).unwrap();
        let out = s.runner(7).run(&mut eng).unwrap();
        // nothing lost: every preempted request resumed and finished
        assert_eq!(out.report.completed, out.report.offered);
        // past saturation with tiers, high-tier newcomers must have
        // evicted lower-tier decodes at least once
        assert!(out.report.preemptions > 0);
        assert_eq!(
            out.report.pages_swapped, 0,
            "recompute policy must not swap"
        );
        assert!(out.report.pages_recomputed > 0);
        // the report splits tiers
        assert!(!out.report.per_class.is_empty());
        let total: usize =
            out.report.per_class.iter().map(|(_, r)| r.offered).sum();
        assert_eq!(total, out.report.offered);
    }

    #[test]
    fn smoke_interleave_scenario_wins_the_ab() {
        let s = by_name("smoke-interleave").unwrap();
        // the registry default is the serial schedule
        assert!(!s.interleave);
        let mut ser = s.engine("P3-LLM", None).unwrap();
        assert!(!ser.interleave_enabled());
        let off = s.runner(7).run(&mut ser).unwrap().report;
        assert_eq!(off.completed, s.n_requests);
        assert_eq!(off.interleaved_steps, 0);
        assert_eq!(off.overlap_factor, 0.0);
        let mut on_sc = s.clone();
        on_sc.interleave = true;
        let mut ilv = on_sc.engine("P3-LLM", None).unwrap();
        assert!(ilv.interleave_enabled());
        let on = on_sc.runner(7).run(&mut ilv).unwrap().report;
        assert_eq!(on.completed, s.n_requests);
        // at batch 8 on the tiny model the split schedule wins: the
        // run must actually interleave, overlap both engines past the
        // CI threshold, and strictly beat the serial goodput
        assert!(on.interleaved_steps > 0);
        assert!(on.overlap_factor > 0.3, "{}", on.overlap_factor);
        assert!(on.serial_saved_ms > 0.0);
        assert!(
            on.makespan_ms < off.makespan_ms,
            "interleaved {} !< serial {}",
            on.makespan_ms,
            off.makespan_ms
        );
        assert!(
            on.goodput_tok_s > off.goodput_tok_s,
            "interleaved {} !> serial {}",
            on.goodput_tok_s,
            off.goodput_tok_s
        );
    }

    #[test]
    fn smoke_scenario_runs_end_to_end() {
        let s = by_name("smoke").unwrap();
        let mut eng = s.engine("P3-LLM", None).unwrap();
        let out = s.runner(7).run(&mut eng).unwrap();
        assert_eq!(out.report.offered, s.n_requests);
        assert_eq!(out.report.completed, s.n_requests);
        assert!(out.report.goodput_tok_s > 0.0);
        assert!(out.report.slo_attainment > 0.0);
        // the decode-busy rate (observed saturation proxy) is live
        assert!(out.report.busy_tok_s > 0.0);
    }
}
