//! `mem/`: the two-tier KV memory hierarchy -- HBM-hot / CXL-cold
//! paged offload with ahead-of-decode prefetch.
//!
//! P3-LLM's decode phase is KV-bandwidth-bound and a replica's
//! PIM-attached HBM caps the context it can serve.  This layer opens
//! the 32k-128k long-context scenarios by backing the paged
//! [`KvPool`](crate::coordinator::KvPool) with a CXL/DDR cold pool:
//!
//! * [`tier::TieredKv`] -- the per-page residency overlay (every page
//!   in exactly one [`Tier`]), LRU eviction to the hot-tier cap, and
//!   the ahead-of-decode prefetcher that pulls the next attention
//!   window back to HBM before the step that needs it, falling back
//!   to demand migration (an engine-clock stall) past its depth.
//! * [`transfer`] -- the single pricing model for every byte crossing
//!   a tier boundary: `max(HBM streaming pass, link latency + bytes /
//!   link bandwidth)`.  The `swap` victim policy's restore leg, CXL
//!   page migrations, and the cluster `pd` policy's pool-mediated
//!   prefill handoff all delegate here, so slow-tier cost lives in
//!   exactly one place.
//!
//! Link parameters come from [`crate::config::CxlLink`]; the engine
//! enables the hierarchy via `EngineBuilder::hot_fraction` /
//! `prefetch_depth` (sim backend), and migrations show up on the
//! telemetry `cxl` lane and in the `memtier` CLI sweep.

pub mod tier;
pub mod transfer;

pub use tier::{LaneOutcome, Tier, TieredKv};
pub use transfer::{
    kv_bytes, migration_ms, page_migration_ms, pool_handoff_ms,
    swap_restore_ms, transfer_ns,
};

/// Fraction of `elapsed_ms` the slow-tier link spent moving pages, in
/// `[0, 1]` -- the gauge the `obs` layer derives from the engine's
/// `cxl_busy_ms` counter (prefetch + demand migrations both occupy the
/// link; only demand stalls the clock).  Zero when nothing elapsed.
pub fn link_utilization(busy_ms: f64, elapsed_ms: f64) -> f64 {
    if elapsed_ms > 0.0 {
        (busy_ms / elapsed_ms).clamp(0.0, 1.0)
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn link_utilization_is_bounded() {
        assert_eq!(super::link_utilization(0.0, 100.0), 0.0);
        assert_eq!(super::link_utilization(25.0, 100.0), 0.25);
        // oversubscription clamps (overlapped prefetches can exceed
        // the wall window) and a zero window is not a division
        assert_eq!(super::link_utilization(250.0, 100.0), 1.0);
        assert_eq!(super::link_utilization(5.0, 0.0), 0.0);
    }
}
