//! The one slow-tier transfer model: every byte that leaves or enters
//! the PIM-attached HBM -- swap-victim restores, CXL page migrations,
//! pool-mediated prefill/decode handoffs -- is priced here and nowhere
//! else.
//!
//! A transfer races two resources and is limited by the slower one:
//! the HBM-side streaming pass (the banked `sim::dram` event model,
//! same pass a PIM GEMV pays to touch the bytes) and the link itself
//! (fixed access latency plus bytes over link bandwidth).  The `swap`
//! victim tier rides the external DRAM bus ([`HbmTiming::ext_bw_gbps`]
//! with no added latency); the cold KV tier rides a [`CxlLink`].
//!
//! [`HbmTiming::ext_bw_gbps`]: crate::config::accel::HbmTiming

use crate::config::accel::HbmTiming;
use crate::config::cxl::CxlLink;
use crate::config::llm::LlmConfig;
use crate::coordinator::PAGE_TOKENS;
use crate::sim::dram;

/// Packed KV bytes `tokens` tokens occupy: INT4 keys + INT4 values
/// across every layer (`2 * layers * tokens * kv_dim / 2`), the same
/// accounting the [`KvPool`](crate::coordinator::KvPool) bills pages
/// by.  Zero tokens price as one (a transfer always moves something).
pub fn kv_bytes(model: &LlmConfig, tokens: usize) -> f64 {
    (2 * model.layers * tokens.max(1) * (model.kv_dim() / 2)) as f64
}

/// Time in ns to move `bytes` between HBM and a slow tier over a link
/// with `link_bw_gbps` bandwidth and `link_latency_ns` fixed access
/// latency: `max(HBM streaming pass, link latency + bytes / bw)`.
pub fn transfer_ns(
    hbm: &HbmTiming,
    link_bw_gbps: f64,
    link_latency_ns: f64,
    bytes: f64,
) -> f64 {
    let stream_ns = dram::gemv_pass_ns(hbm, bytes);
    let link_ns = link_latency_ns + bytes / link_bw_gbps;
    stream_ns.max(link_ns)
}

/// Restore cost in ms for a swap victim's KV (`tokens` of context)
/// coming back over the external DRAM bus.  This is the admission-
/// blocking leg the `swap` victim policy charges (swap-out streams
/// out asynchronously behind the ongoing decode); `sched`'s
/// `swap_restore_ms` delegates here.
pub fn swap_restore_ms(
    hbm: &HbmTiming,
    model: &LlmConfig,
    tokens: usize,
) -> f64 {
    transfer_ns(hbm, hbm.ext_bw_gbps, 0.0, kv_bytes(model, tokens)) / 1e6
}

/// Migration cost in ms for `tokens` of KV crossing the CXL link
/// (either direction; the model is symmetric).
pub fn migration_ms(
    hbm: &HbmTiming,
    cxl: &CxlLink,
    model: &LlmConfig,
    tokens: usize,
) -> f64 {
    transfer_ns(hbm, cxl.bw_gbps, cxl.latency_ns, kv_bytes(model, tokens))
        / 1e6
}

/// Migration cost in ms for one KV page ([`PAGE_TOKENS`] tokens) over
/// the CXL link -- the unit price the tiered pool's prefetcher and
/// demand-miss path charge per page.
pub fn page_migration_ms(
    hbm: &HbmTiming,
    cxl: &CxlLink,
    model: &LlmConfig,
) -> f64 {
    migration_ms(hbm, cxl, model, PAGE_TOKENS)
}

/// Prefill/decode disaggregation handoff priced through the shared
/// cold pool instead of a replica-to-replica bus copy: the prefill
/// replica writes the prompt KV out to the CXL pool and the decode
/// replica reads it back -- two link passes.
pub fn pool_handoff_ms(
    hbm: &HbmTiming,
    cxl: &CxlLink,
    model: &LlmConfig,
    tokens: usize,
) -> f64 {
    2.0 * migration_ms(hbm, cxl, model, tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::npu;

    #[test]
    fn swap_pricing_matches_the_legacy_bus_formula_exactly() {
        // the unified model with (ext bus bw, zero latency) must
        // reproduce the formula `sched::swap_restore_ms` and the
        // cluster bus copy used before the unification, bit for bit
        let hbm = HbmTiming::default();
        for model in
            [crate::config::llm::TINY, crate::config::llm::MISTRAL_7B]
        {
            for tokens in [0, 1, 16, 333, 4096] {
                let bytes = (2
                    * model.layers
                    * tokens.max(1)
                    * (model.kv_dim() / 2)) as f64;
                let legacy = dram::gemv_pass_ns(&hbm, bytes)
                    .max(npu::transfer(&hbm, bytes).ns)
                    / 1e6;
                assert_eq!(
                    swap_restore_ms(&hbm, &model, tokens),
                    legacy,
                    "{} @ {tokens} tokens",
                    model.name
                );
            }
        }
    }

    #[test]
    fn transfer_pricing_is_positive_monotone_and_latency_floored() {
        let hbm = HbmTiming::default();
        let cxl = CxlLink::default();
        let model = crate::config::llm::TINY;
        let page = page_migration_ms(&hbm, &cxl, &model);
        assert!(page > 0.0);
        // even a 1-byte transfer pays the link access latency
        assert!(
            transfer_ns(&hbm, cxl.bw_gbps, cxl.latency_ns, 1.0)
                >= cxl.latency_ns
        );
        let mut last = 0.0;
        for tokens in [1, 64, 1024, 16384] {
            let ms = migration_ms(&hbm, &cxl, &model, tokens);
            assert!(ms > last, "{tokens}: {ms} !> {last}");
            last = ms;
        }
        // the CXL link is far slower than the external DRAM bus, so a
        // cold-tier migration strictly out-prices a swap restore of
        // the same span
        assert!(
            migration_ms(&hbm, &cxl, &model, 512)
                > swap_restore_ms(&hbm, &model, 512)
        );
        // a pool handoff is exactly two link passes
        let one = migration_ms(&hbm, &cxl, &model, 512);
        assert_eq!(pool_handoff_ms(&hbm, &cxl, &model, 512), 2.0 * one);
    }
}
