//! Per-page residency map of the two-tier KV hierarchy.
//!
//! [`TieredKv`] is a pure overlay on the paged
//! [`KvPool`](crate::coordinator::KvPool): the pool keeps owning page
//! storage, refcounts and free lists for the *combined* capacity
//! (admission overcommits HBM against the cold pool, so the typed
//! `KvExhausted` only fires when both tiers are full), while the
//! overlay tracks which of a request's pages are resident in
//! PIM-attached HBM (hot) and which have been evicted to the CXL/DDR
//! cold pool.  Residency is keyed `(request, page index)` -- page
//! indices are derived from committed token counts exactly as the
//! pool derives them (`ceil(tokens / PAGE_TOKENS)`), so the overlay
//! never reaches into the pool's private page tables.
//!
//! Life cycle per decode step, per lane ([`TieredKv::step_lane`]):
//! pages written this step (prefill output, the newest decode token's
//! page) are *born hot* -- the device writes them to HBM.  Cold pages
//! the attention pass needs are pulled back over the CXL link: the
//! ahead-of-decode prefetcher covers up to `prefetch_depth` of them
//! (it walked the page table during the previous step, so the
//! transfer overlapped compute and costs no engine time), the rest
//! are demand misses the engine charges as a clock stall.  After the
//! walk the hot set is trimmed back to `hot_cap_pages` by evicting
//! the least-recently-touched pages (deterministic tie-break on
//! `(request, page index)`); eviction is an asynchronous write-back
//! behind the ongoing decode, matching the `swap` victim policy's
//! swap-out convention, so it is counted but not charged.

use std::collections::BTreeMap;

/// Which tier a page is resident in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// PIM-attached HBM: attention reads execute in place.
    Hot,
    /// CXL/DDR cold pool: the page must migrate back before use.
    Cold,
}

#[derive(Debug, Clone, Copy)]
struct PageState {
    tier: Tier,
    /// last-touch stamp (monotone per overlay) driving LRU eviction
    tick: u64,
}

/// What one lane's pre-step page walk cost ([`TieredKv::step_lane`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneOutcome {
    /// pages written fresh to HBM this step (no transfer)
    pub born: usize,
    /// cold pages the prefetcher pulled back ahead of the step
    /// (overlapped -- no engine-clock charge)
    pub prefetched: usize,
    /// cold pages demand-migrated at step time (each charges one page
    /// migration as an engine-clock stall)
    pub demand: usize,
}

/// Residency map + LRU clock for the hot tier.  See the module docs.
#[derive(Debug, Clone)]
pub struct TieredKv {
    /// per-request page residency, indexed by page number
    lanes: BTreeMap<u64, Vec<PageState>>,
    hot_cap_pages: usize,
    prefetch_depth: usize,
    hot_count: usize,
    tick: u64,
    // lifetime counters (mirrored into serving metrics by the engine)
    prefetched: usize,
    demand: usize,
    evicted: usize,
}

impl TieredKv {
    /// `hot_cap_pages` is the HBM-resident page budget (at least one
    /// page -- a decode step must be able to land its output);
    /// `prefetch_depth` is how many cold pages per lane per step the
    /// ahead-of-decode prefetcher can hide (0 = pure demand paging).
    pub fn new(hot_cap_pages: usize, prefetch_depth: usize) -> Self {
        TieredKv {
            lanes: BTreeMap::new(),
            hot_cap_pages: hot_cap_pages.max(1),
            prefetch_depth,
            hot_count: 0,
            tick: 0,
            prefetched: 0,
            demand: 0,
            evicted: 0,
        }
    }

    pub fn hot_cap_pages(&self) -> usize {
        self.hot_cap_pages
    }

    pub fn prefetch_depth(&self) -> usize {
        self.prefetch_depth
    }

    /// Pages currently resident in HBM.
    pub fn hot_pages(&self) -> usize {
        self.hot_count
    }

    /// Pages currently parked in the cold pool.
    pub fn cold_pages(&self) -> usize {
        self.total_pages() - self.hot_count
    }

    /// Pages tracked across both tiers (== live pages of tracked
    /// lanes; every page is in exactly one tier).
    pub fn total_pages(&self) -> usize {
        self.lanes.values().map(|v| v.len()).sum()
    }

    /// Lifetime `(prefetched, demand, evicted)` page counts.
    pub fn counters(&self) -> (usize, usize, usize) {
        (self.prefetched, self.demand, self.evicted)
    }

    /// Walk one lane's page table just before its decode step:
    /// `npages` is the page count the step reads and grows
    /// (`ceil(tokens / PAGE_TOKENS)` committed so far).  New page
    /// indices are born hot; known-cold pages split into prefetched
    /// (up to the depth) and demand misses; every touched page gets a
    /// fresh LRU stamp; finally the hot set is trimmed to cap.
    pub fn step_lane(&mut self, rid: u64, npages: usize) -> LaneOutcome {
        let entry = self.lanes.entry(rid).or_default();
        let mut out = LaneOutcome::default();
        let known = entry.len().min(npages);
        for page in entry.iter_mut().take(known) {
            self.tick += 1;
            page.tick = self.tick;
            if page.tier == Tier::Cold {
                if out.prefetched < self.prefetch_depth {
                    out.prefetched += 1;
                } else {
                    out.demand += 1;
                }
                page.tier = Tier::Hot;
                self.hot_count += 1;
            }
        }
        while entry.len() < npages {
            self.tick += 1;
            entry.push(PageState { tier: Tier::Hot, tick: self.tick });
            self.hot_count += 1;
            out.born += 1;
        }
        self.prefetched += out.prefetched;
        self.demand += out.demand;
        self.evict_to_cap();
        out
    }

    /// Drop a lane's residency entries (request retired, preempted,
    /// or its prefill failed -- wherever the pool frees the
    /// sequence).  Unknown lanes are a no-op: requests that retire at
    /// prefill never enter a decode-step walk.
    pub fn free(&mut self, rid: u64) {
        if let Some(pages) = self.lanes.remove(&rid) {
            self.hot_count -=
                pages.iter().filter(|p| p.tier == Tier::Hot).count();
        }
    }

    /// Evict least-recently-touched hot pages to the cold tier until
    /// the hot set fits the cap.  One sorted pass; ties (impossible
    /// with the monotone tick, but cheap to guarantee) break on
    /// `(request, page index)` so eviction order is deterministic.
    fn evict_to_cap(&mut self) {
        if self.hot_count <= self.hot_cap_pages {
            return;
        }
        let mut hot: Vec<(u64, u64, usize)> = self
            .lanes
            .iter()
            .flat_map(|(&rid, pages)| {
                pages.iter().enumerate().filter_map(move |(i, p)| {
                    (p.tier == Tier::Hot).then_some((p.tick, rid, i))
                })
            })
            .collect();
        hot.sort_unstable();
        let excess = self.hot_count - self.hot_cap_pages;
        for &(_, rid, i) in hot.iter().take(excess) {
            self.lanes.get_mut(&rid).unwrap()[i].tier = Tier::Cold;
            self.hot_count -= 1;
            self.evicted += 1;
        }
    }

    /// Recompute the hot count from scratch and assert every
    /// bookkeeping quantity holds (test support): each page in
    /// exactly one tier, the incremental hot count exact, and the hot
    /// set within cap.
    #[cfg(test)]
    pub fn check_invariants(&self) {
        let hot = self
            .lanes
            .values()
            .flatten()
            .filter(|p| p.tier == Tier::Hot)
            .count();
        assert_eq!(hot, self.hot_count, "hot count drifted");
        assert!(
            self.hot_count <= self.hot_cap_pages,
            "hot set {} over cap {}",
            self.hot_count,
            self.hot_cap_pages
        );
        assert_eq!(
            self.hot_pages() + self.cold_pages(),
            self.total_pages(),
            "a page left both tiers"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Rng, Runner};

    #[test]
    fn pages_are_born_hot_then_age_to_cold_and_migrate_back() {
        let mut t = TieredKv::new(4, 1);
        // a 6-page lane: born hot, trimmed to the 4-page cap
        let o = t.step_lane(1, 6);
        assert_eq!(o, LaneOutcome { born: 6, prefetched: 0, demand: 0 });
        assert_eq!(t.hot_pages(), 4);
        assert_eq!(t.cold_pages(), 2);
        // LRU: the lowest-indexed (earliest-stamped) pages went cold
        // first, so the next walk pulls exactly those two back --
        // one hidden by the depth-1 prefetcher, one demand miss
        let o = t.step_lane(1, 6);
        assert_eq!(o, LaneOutcome { born: 0, prefetched: 1, demand: 1 });
        assert_eq!(t.hot_pages() + t.cold_pages(), 6);
        let (pre, dem, ev) = t.counters();
        assert_eq!((pre, dem), (1, 1));
        assert!(ev >= 2);
        t.check_invariants();
        t.free(1);
        assert_eq!(t.total_pages(), 0);
        assert_eq!(t.hot_pages(), 0);
        t.check_invariants();
    }

    #[test]
    fn depth_zero_is_pure_demand_paging() {
        let mut t = TieredKv::new(2, 0);
        t.step_lane(9, 5);
        let o = t.step_lane(9, 5);
        assert_eq!(o.prefetched, 0);
        assert_eq!(o.demand, 3);
        // a deep prefetcher hides the same walk entirely
        let mut p = TieredKv::new(2, 8);
        p.step_lane(9, 5);
        let o = p.step_lane(9, 5);
        assert_eq!(o.prefetched, 3);
        assert_eq!(o.demand, 0);
    }

    #[test]
    fn eviction_prefers_idle_lanes_over_the_stepping_lane() {
        let mut t = TieredKv::new(3, 0);
        t.step_lane(1, 3); // lane 1 fills the hot tier
        t.step_lane(2, 3); // lane 2 steps: lane 1's pages all go cold
        let again = t.step_lane(2, 3);
        assert_eq!(again.demand, 0, "the active lane stayed resident");
        let back = t.step_lane(1, 3);
        assert_eq!(back.demand, 3, "the idle lane's pages went cold");
        t.check_invariants();
    }

    /// Satellite: cross-tier residency conservation under randomized
    /// prefetch / evict / demand-miss / free churn.  After every
    /// operation each tracked page is in exactly one tier, the
    /// incremental hot count matches a from-scratch recount, the hot
    /// set respects the cap, and no lane loses pages (a lane's page
    /// count only grows until it is freed).  The companion engine-
    /// level churn test (preemption + prefix sharing on a live pool)
    /// lives in `coordinator::serve`.
    #[test]
    fn property_residency_conservation_under_churn() {
        Runner::new(48).run(|rng: &mut Rng| {
            let cap = rng.usize(1, 12);
            let depth = rng.usize(0, 5);
            let mut t = TieredKv::new(cap, depth);
            let mut expect: BTreeMap<u64, usize> = BTreeMap::new();
            let (mut pre, mut dem) = (0usize, 0usize);
            for _ in 0..rng.usize(20, 120) {
                let rid = rng.usize(1, 6) as u64;
                if rng.usize(0, 5) == 0 {
                    t.free(rid);
                    expect.remove(&rid);
                } else {
                    let have = expect.get(&rid).copied().unwrap_or(0);
                    let npages = if rng.bool() {
                        have.max(1) // re-walk at the current size
                    } else {
                        have + rng.usize(1, 8) // grow
                    };
                    let o = t.step_lane(rid, npages);
                    // growth is exactly the born count; nothing lost
                    assert_eq!(o.born, npages.max(have) - have);
                    // a walk migrates cold pages only, prefetch-first
                    assert!(o.prefetched <= depth);
                    if o.demand > 0 {
                        assert_eq!(o.prefetched, depth);
                    }
                    // known cold pages all migrated: what the walk
                    // didn't migrate or bear fresh was already hot,
                    // and the hot set is capped
                    assert!(o.prefetched + o.demand + o.born + cap >= npages);
                    expect.insert(rid, npages.max(have));
                    pre += o.prefetched;
                    dem += o.demand;
                }
                t.check_invariants();
                assert_eq!(
                    t.total_pages(),
                    expect.values().sum::<usize>(),
                    "a lane lost pages"
                );
                let (tp, td, _) = t.counters();
                assert_eq!((tp, td), (pre, dem));
            }
            for rid in expect.keys() {
                t.free(*rid);
            }
            // free() is also callable on already-freed / unknown rids
            t.free(999);
            assert_eq!(t.total_pages(), 0);
            assert_eq!(t.hot_pages(), 0);
            t.check_invariants();
        });
    }
}
