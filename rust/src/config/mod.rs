//! Configuration: LLM architectures, accelerator hardware parameters,
//! and quantization schemes.

pub mod accel;
pub mod llm;
pub mod scheme;

pub use accel::{HbmTiming, NpuConfig, PcuConfig, PimConfig, SystemConfig};
pub use llm::{LlmConfig, RopeStage};
pub use scheme::{OperandBits, QuantScheme};
