//! Configuration: LLM architectures, accelerator hardware parameters,
//! CXL cold-tier link timing, and quantization schemes.

pub mod accel;
pub mod cxl;
pub mod llm;
pub mod scheme;

pub use accel::{HbmTiming, NpuConfig, PcuConfig, PimConfig, SystemConfig};
pub use cxl::CxlLink;
pub use llm::{LlmConfig, RopeStage};
pub use scheme::{OperandBits, QuantScheme};
