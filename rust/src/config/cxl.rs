//! CXL/DDR cold-tier link parameters for the two-tier KV hierarchy
//! (see [`crate::mem`]).
//!
//! The hot tier is the PIM-attached HBM the paged [`KvPool`] models;
//! the cold tier sits behind a CXL.mem link with its own bandwidth and
//! access latency.  Defaults follow a single CXL 3.x x8 port in front
//! of a DDR5 expander: ~64 GB/s of usable link bandwidth and a few
//! hundred ns of added round-trip latency -- an order of magnitude
//! below the multi-TB/s in-package HBM, which is exactly the gap the
//! ahead-of-decode prefetcher exists to hide.
//!
//! [`KvPool`]: crate::coordinator::KvPool

/// CXL link model for the cold KV tier.  Bandwidth uses the same
/// GB/s == bytes/ns convention as [`HbmTiming::ext_bw_gbps`]
/// (`crate::config::accel::HbmTiming::ext_bw_gbps`), so
/// `latency_ns + bytes / bw_gbps` is a transfer time in ns.
///
/// [`HbmTiming::ext_bw_gbps`]: crate::config::accel::HbmTiming
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CxlLink {
    /// usable link bandwidth in GB/s (bytes per ns)
    pub bw_gbps: f64,
    /// fixed per-transfer access latency in ns (link traversal +
    /// expander-side DDR access), charged once per migration
    pub latency_ns: f64,
}

impl Default for CxlLink {
    fn default() -> Self {
        CxlLink { bw_gbps: 64.0, latency_ns: 600.0 }
    }
}

impl CxlLink {
    /// Link-side time to move `bytes` across the CXL port, in ns.
    /// The full migration price additionally races the HBM-side
    /// streaming pass -- see [`crate::mem::transfer_ns`].
    pub fn link_ns(&self, bytes: f64) -> f64 {
        self.latency_ns + bytes / self.bw_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_link_is_sane_and_latency_bound_for_small_transfers() {
        let link = CxlLink::default();
        assert!(link.bw_gbps > 0.0 && link.latency_ns > 0.0);
        // a 64-byte line is latency-dominated; a 1 MiB page stream is
        // bandwidth-dominated
        assert!(link.link_ns(64.0) < 2.0 * link.latency_ns);
        let big = link.link_ns((1 << 20) as f64);
        assert!(big > 10.0 * link.latency_ns, "{big}");
        // monotone in bytes
        assert!(link.link_ns(2048.0) > link.link_ns(1024.0));
    }
}
