//! Quantization schemes as the perf/memory model sees them: bits per
//! operand *including metadata overhead* (Table I / Section VI-B).

/// Effective stored bits for one operand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperandBits {
    pub weights: f64,
    pub activations: f64,
    pub kv: f64,
    pub scores: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct QuantScheme {
    pub name: &'static str,
    pub bits: OperandBits,
    /// can the PIM-side low-precision PCU run Q.K^T / P.V?
    pub attention_on_pim: bool,
    /// does the scheme require NPU-side decompression before compute
    /// (Ecco's codebook+Huffman path)?
    pub npu_decompress: bool,
}

impl QuantScheme {
    pub fn fp16() -> Self {
        QuantScheme {
            name: "FP16",
            bits: OperandBits { weights: 16.0, activations: 16.0, kv: 16.0, scores: 16.0 },
            attention_on_pim: true, // fp16 PCU computes it (slowly)
            npu_decompress: false,
        }
    }

    /// P3-LLM W4A8KV4P8: BitMoD weights 4 + group-128 metadata (16-bit
    /// scale + 2-bit select per 128) = 4.14; KV INT4-Asym per-head-128
    /// would be 4.16 -- the tiny model's head_dim is smaller but the
    /// *paper's* accounting uses 128, which we follow for the HW model.
    pub fn p3llm() -> Self {
        QuantScheme {
            name: "P3-LLM-W4A8KV4P8",
            bits: OperandBits { weights: 4.14, activations: 8.0, kv: 4.16, scores: 8.0 },
            attention_on_pim: true,
            npu_decompress: false,
        }
    }

    /// Ecco W4A8KV4 with k-means codebooks + Huffman (slightly better
    /// compression than P3, Fig. 14), NPU-side decompression.
    pub fn ecco() -> Self {
        QuantScheme {
            name: "Ecco-W4A8KV4",
            bits: OperandBits { weights: 4.05, activations: 8.0, kv: 4.05, scores: 16.0 },
            attention_on_pim: false,
            npu_decompress: true,
        }
    }

    /// Pimba: KV-only 8-bit microscaling (original design).
    pub fn pimba_orig() -> Self {
        QuantScheme {
            name: "Pimba-KV8",
            bits: OperandBits { weights: 16.0, activations: 16.0, kv: 8.25, scores: 16.0 },
            attention_on_pim: true,
            npu_decompress: false,
        }
    }

    /// Enhanced Pimba with 8-bit weight-activation quantization (Fig 12).
    pub fn pimba_enhanced() -> Self {
        QuantScheme {
            name: "Pimba-W8A8KV8",
            bits: OperandBits { weights: 8.25, activations: 8.0, kv: 8.25, scores: 16.0 },
            attention_on_pim: true,
            npu_decompress: false,
        }
    }

    /// SmoothQuant W8A8 running on the NPU (Fig. 13).
    pub fn smoothquant() -> Self {
        QuantScheme {
            name: "SmoothQuant-W8A8",
            bits: OperandBits { weights: 8.0, activations: 8.0, kv: 16.0, scores: 16.0 },
            attention_on_pim: false,
            npu_decompress: false,
        }
    }

    /// AWQ W4A16 (group 128) on the NPU (Fig. 13).
    pub fn awq() -> Self {
        QuantScheme {
            name: "AWQ-W4A16",
            bits: OperandBits { weights: 4.14, activations: 16.0, kv: 16.0, scores: 16.0 },
            attention_on_pim: false,
            npu_decompress: false,
        }
    }

    /// W4A8KV4 without 8-bit scores (Fig. 15 ablation step): P.V must
    /// run where scores live -- scores stay fp16 so P.V goes to NPU.
    pub fn p3_no_p8() -> Self {
        QuantScheme {
            name: "W4A8KV4-P16",
            bits: OperandBits { weights: 4.14, activations: 8.0, kv: 4.16, scores: 16.0 },
            attention_on_pim: false,
            npu_decompress: false,
        }
    }

    pub fn weight_bytes(&self, elems: usize) -> f64 {
        elems as f64 * self.bits.weights / 8.0
    }

    pub fn kv_bytes(&self, elems: usize) -> f64 {
        elems as f64 * self.bits.kv / 8.0
    }
}

/// Every named scheme (the `EngineBuilder` scheme registry).
pub fn all() -> Vec<QuantScheme> {
    vec![
        QuantScheme::fp16(),
        QuantScheme::p3llm(),
        QuantScheme::ecco(),
        QuantScheme::pimba_orig(),
        QuantScheme::pimba_enhanced(),
        QuantScheme::smoothquant(),
        QuantScheme::awq(),
        QuantScheme::p3_no_p8(),
    ]
}

/// Look a scheme up by its full name (case-insensitive) or a short
/// alias: `fp16`, `p3llm`/`p3`, `ecco`, `pimba`, `pimba-w8a8`,
/// `smoothquant`, `awq`, `w4a8kv4-p16`.
pub fn by_name(name: &str) -> Option<QuantScheme> {
    let n = name.to_ascii_lowercase();
    let alias = match n.as_str() {
        "p3" | "p3llm" | "p3-llm" => Some(QuantScheme::p3llm()),
        "pimba" => Some(QuantScheme::pimba_orig()),
        "pimba-w8a8" => Some(QuantScheme::pimba_enhanced()),
        "ecco" => Some(QuantScheme::ecco()),
        "smoothquant" => Some(QuantScheme::smoothquant()),
        "awq" => Some(QuantScheme::awq()),
        _ => None,
    };
    alias.or_else(|| {
        all().into_iter().find(|s| s.name.eq_ignore_ascii_case(&n))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert_eq!(by_name("fp16").unwrap().name, "FP16");
        assert_eq!(by_name("p3").unwrap().name, "P3-LLM-W4A8KV4P8");
        assert_eq!(by_name("P3-LLM-W4A8KV4P8").unwrap().name, "P3-LLM-W4A8KV4P8");
        assert_eq!(by_name("pimba-w8a8").unwrap().name, "Pimba-W8A8KV8");
        assert!(by_name("nope").is_none());
        // every registry entry resolves through its own full name
        for s in all() {
            assert_eq!(by_name(s.name).unwrap(), s);
        }
    }

    #[test]
    fn compression_ratios_match_fig14() {
        let fp = QuantScheme::fp16();
        let p3 = QuantScheme::p3llm();
        let ecco = QuantScheme::ecco();
        let r_p3 = fp.bits.weights / p3.bits.weights;
        let r_ecco = fp.bits.weights / ecco.bits.weights;
        // Fig 14: Ecco 3.8x, P3 3.7x -- Ecco slightly smaller
        assert!(r_ecco > r_p3);
        assert!((3.4..4.1).contains(&r_p3), "{r_p3}");
    }
}
