//! Accelerator hardware parameters (paper Section VI-A methodology).
//!
//! Baseline system: 4 NPU cores (128x128 systolic array + 128-way
//! vector unit + 16 MB scratchpad, 1 GHz) and 16 pseudo HBM channels.
//! PIM variants differ in PCU datapath width, operand precision,
//! command period (t_CCD_L vs t_CCD_S) and temporal weight reuse.

/// HBM2 timing in nanoseconds (JESD235); the PIM command cadence is the
/// column-to-column delay of the paper's Fig. 7.
#[derive(Debug, Clone)]
pub struct HbmTiming {
    pub t_ccd_l_ns: f64,
    pub t_ccd_s_ns: f64,
    pub t_rcd_ns: f64,
    pub t_rp_ns: f64,
    /// row buffer per bank (bytes)
    pub row_bytes: usize,
    /// one column access (bytes) = 256 bits
    pub col_bytes: usize,
    pub banks_per_channel: usize,
    pub channels: usize,
    /// off-chip (host-visible) bandwidth of the whole stack, GB/s
    pub ext_bw_gbps: f64,
}

impl Default for HbmTiming {
    fn default() -> Self {
        HbmTiming {
            t_ccd_l_ns: 4.0,
            t_ccd_s_ns: 2.0,
            t_rcd_ns: 14.0,
            t_rp_ns: 14.0,
            row_bytes: 1024,
            col_bytes: 32,
            banks_per_channel: 16,
            channels: 16,
            ext_bw_gbps: 512.0,
        }
    }
}

impl HbmTiming {
    /// Internal all-bank PIM bandwidth at command period `t_ccd` (GB/s):
    /// every channel streams one 32 B column per bank per command.
    pub fn pim_internal_bw_gbps(&self, t_ccd_ns: f64) -> f64 {
        let bytes = (self.channels * self.banks_per_channel * self.col_bytes)
            as f64;
        bytes / t_ccd_ns // B/ns == GB/s
    }
}

/// PIM compute unit configuration (one PCU is shared by 2 banks).
#[derive(Debug, Clone)]
pub struct PcuConfig {
    pub name: &'static str,
    /// multipliers fed per command (HBM-PIM: 16 FP16; P3: 64 4-bit)
    pub macs_per_command: usize,
    /// command period in ns (t_CCD_L = 4, t_CCD_S = 2)
    pub t_cmd_ns: f64,
    /// temporal weight reuse per column read (P3's TEP: 2)
    pub weight_reuse: usize,
    /// stored weight/KV operand width in bits on the PIM side
    pub weight_bits: f64,
    /// input operand width in bits (activations / scores)
    pub input_bits: f64,
    /// energy per MAC in pJ (Table VIII)
    pub mac_energy_pj: f64,
    /// relative PIM power increase from running at t_CCD_S (paper: +28%)
    pub power_factor: f64,
}

impl PcuConfig {
    /// HBM-PIM [49]: 16-way FP16 SIMD MAC per PCU, one command per
    /// t_CCD_L, no weight reuse.
    pub fn hbm_pim() -> Self {
        PcuConfig {
            name: "HBM-PIM-FP16",
            macs_per_command: 16,
            t_cmd_ns: 4.0,
            weight_reuse: 1,
            weight_bits: 16.0,
            input_bits: 16.0,
            mac_energy_pj: 0.69,
            power_factor: 1.0,
        }
    }

    /// P3-LLM PCU (Section V-A/V-D): 64 4-bit multipliers, t_CCD_S
    /// cadence, 2x temporal weight reuse.  Effective weight bits 4.16
    /// (INT4-Asym per-head metadata) / BitMoD ~4.25 with group-128
    /// scale+select.
    pub fn p3llm() -> Self {
        PcuConfig {
            name: "P3-PCU",
            macs_per_command: 64,
            t_cmd_ns: 2.0,
            weight_reuse: 2,
            weight_bits: 4.25,
            input_bits: 8.0,
            mac_energy_pj: 0.18,
            power_factor: 1.28,
        }
    }

    /// P3 PCU without the throughput enhancement (Fig. 15 ablation).
    pub fn p3llm_no_tep() -> Self {
        PcuConfig {
            name: "P3-PCU-noTEP",
            t_cmd_ns: 4.0,
            weight_reuse: 1,
            power_factor: 1.0,
            ..Self::p3llm()
        }
    }

    /// Pimba [44]: 8-bit microscaling PCU, t_CCD_L cadence.
    pub fn pimba() -> Self {
        PcuConfig {
            name: "Pimba-MX8",
            macs_per_command: 32,
            t_cmd_ns: 4.0,
            weight_reuse: 1,
            weight_bits: 8.25, // MX: shared 8-bit exponent per 32 elems
            input_bits: 8.0,
            mac_energy_pj: 0.40,
            power_factor: 1.0,
        }
    }

    /// System-wide MAC throughput (MAC/s).  Column reads are bound to
    /// t_CCD_L by the DRAM (internal bandwidth is identical for every
    /// PIM variant); each 32 B column feeds `macs_per_command`
    /// multipliers, and the throughput-enhanced PCU re-applies the
    /// column to `weight_reuse` inputs by computing at t_CCD_S.  So:
    /// HBM-PIM 16/32B x1 -> 0.5 MAC/B; P3 64/32B x2 -> 4 MAC/B = the
    /// paper's 8x roofline (Section III-B).
    pub fn system_macs_per_sec(&self, hbm: &HbmTiming) -> f64 {
        let bw = hbm.pim_internal_bw_gbps(hbm.t_ccd_l_ns) * 1e9; // B/s
        bw * self.macs_per_command as f64 / hbm.col_bytes as f64
            * self.weight_reuse as f64
    }
}

/// NPU configuration (Section VI-A: based on NeuPIMs [27]).
#[derive(Debug, Clone)]
pub struct NpuConfig {
    pub cores: usize,
    pub systolic: usize,
    pub vector_lanes: usize,
    pub scratchpad_mb: usize,
    pub freq_ghz: f64,
    /// energy per fp16 MAC in the logic process (pJ)
    pub mac_energy_pj: f64,
}

impl Default for NpuConfig {
    fn default() -> Self {
        NpuConfig {
            cores: 4,
            systolic: 128,
            vector_lanes: 128,
            scratchpad_mb: 16,
            freq_ghz: 1.0,
            mac_energy_pj: 0.31,
        }
    }
}

impl NpuConfig {
    pub fn peak_macs_per_sec(&self) -> f64 {
        (self.cores * self.systolic * self.systolic) as f64
            * self.freq_ghz
            * 1e9
    }

    pub fn vector_ops_per_sec(&self) -> f64 {
        (self.cores * self.vector_lanes) as f64 * self.freq_ghz * 1e9
    }
}

/// PIM subsystem = timing + PCU.
#[derive(Debug, Clone)]
pub struct PimConfig {
    pub hbm: HbmTiming,
    pub pcu: PcuConfig,
}

impl PimConfig {
    /// Column-read bandwidth: t_CCD_L cadence regardless of PCU clock
    /// (the TEP reuses columns, it cannot read them faster).
    pub fn internal_bw_gbps(&self) -> f64 {
        self.hbm.pim_internal_bw_gbps(self.hbm.t_ccd_l_ns)
    }
}

/// A complete system under evaluation.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub npu: NpuConfig,
    pub hbm: HbmTiming,
    pub pim: Option<PimConfig>,
}

impl SystemConfig {
    pub fn npu_only() -> Self {
        SystemConfig {
            npu: NpuConfig::default(),
            hbm: HbmTiming::default(),
            pim: None,
        }
    }

    pub fn with_pcu(pcu: PcuConfig) -> Self {
        let hbm = HbmTiming::default();
        SystemConfig {
            npu: NpuConfig::default(),
            hbm: hbm.clone(),
            pim: Some(PimConfig { hbm, pcu }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hbm_pim_bw_is_4x_external() {
        let h = HbmTiming::default();
        let ratio = h.pim_internal_bw_gbps(h.t_ccd_l_ns) / h.ext_bw_gbps;
        assert!((ratio - 4.0).abs() < 0.5, "{ratio}");
    }

    #[test]
    fn p3_throughput_8x_hbm_pim() {
        // Section III-B: 4x multipliers x 2x frequency = 8x roofline
        let h = HbmTiming::default();
        let base = PcuConfig::hbm_pim().system_macs_per_sec(&h);
        let p3 = PcuConfig::p3llm().system_macs_per_sec(&h);
        assert!((p3 / base - 8.0).abs() < 0.01, "{}", p3 / base);
    }

    #[test]
    fn npu_peak() {
        let npu = NpuConfig::default();
        assert!((npu.peak_macs_per_sec() - 65.536e12).abs() / 65.536e12
            < 1e-6);
    }
}
