//! LLM architecture configs (paper Section VI-A evaluates eight models;
//! the perf simulator only needs layer shapes, not weights).

/// Whether the key cache is quantized before or after RoPE
/// (Section IV-A: pre-RoPE for short-max-context models like Llama-1/2,
/// post-RoPE for long-context Llama-3 / Mistral).  The choice changes
/// the operator mapping: pre-RoPE forces Q.K^T onto the NPU (Fig. 6b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RopeStage {
    Pre,
    Post,
}

#[derive(Debug, Clone)]
pub struct LlmConfig {
    pub name: &'static str,
    pub hidden: usize,
    pub layers: usize,
    pub n_heads: usize,
    pub n_kv: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_ctx: usize,
    pub rope_stage: RopeStage,
}

impl LlmConfig {
    pub const fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv
    }

    /// kv channels per layer (keys or values)
    pub const fn kv_dim(&self) -> usize {
        self.n_kv * self.head_dim
    }

    /// total parameter count (embedding + decoder layers + lm head)
    pub fn n_params(&self) -> usize {
        let attn = self.hidden * self.n_heads * self.head_dim * 2
            + self.hidden * self.kv_dim() * 2;
        let mlp = 3 * self.hidden * self.ffn;
        self.layers * (attn + mlp + 2 * self.hidden)
            + 2 * self.vocab * self.hidden
            + self.hidden
    }

    /// KV-cache elements for one request at context length `ctx`.
    pub fn kv_elems(&self, ctx: usize) -> usize {
        2 * self.layers * self.kv_dim() * ctx
    }
}

pub const LLAMA2_7B: LlmConfig = LlmConfig {
    name: "Llama-2-7B",
    hidden: 4096,
    layers: 32,
    n_heads: 32,
    n_kv: 32,
    head_dim: 128,
    ffn: 11008,
    vocab: 32000,
    max_ctx: 4096,
    rope_stage: RopeStage::Pre,
};

pub const LLAMA2_13B: LlmConfig = LlmConfig {
    name: "Llama-2-13B",
    hidden: 5120,
    layers: 40,
    n_heads: 40,
    n_kv: 40,
    head_dim: 128,
    ffn: 13824,
    vocab: 32000,
    max_ctx: 4096,
    rope_stage: RopeStage::Pre,
};

pub const LLAMA1_7B: LlmConfig =
    LlmConfig { name: "Llama-1-7B", max_ctx: 2048, ..LLAMA2_7B };
pub const LLAMA1_13B: LlmConfig =
    LlmConfig { name: "Llama-1-13B", max_ctx: 2048, ..LLAMA2_13B };

pub const LLAMA31_8B: LlmConfig = LlmConfig {
    name: "Llama-3.1-8B",
    hidden: 4096,
    layers: 32,
    n_heads: 32,
    n_kv: 8,
    head_dim: 128,
    ffn: 14336,
    vocab: 128256,
    max_ctx: 131072,
    rope_stage: RopeStage::Post,
};

pub const LLAMA32_3B: LlmConfig = LlmConfig {
    name: "Llama-3.2-3B",
    hidden: 3072,
    layers: 28,
    n_heads: 24,
    n_kv: 8,
    head_dim: 128,
    ffn: 8192,
    vocab: 128256,
    max_ctx: 131072,
    rope_stage: RopeStage::Post,
};

pub const MISTRAL_7B: LlmConfig = LlmConfig {
    name: "Mistral-7B",
    hidden: 4096,
    layers: 32,
    n_heads: 32,
    n_kv: 8,
    head_dim: 128,
    ffn: 14336,
    vocab: 32768,
    max_ctx: 32768,
    rope_stage: RopeStage::Post,
};

/// The build-time-trained tiny model shipped in artifacts/ (serving
/// demo + accuracy experiments run real numerics through it).
pub const TINY: LlmConfig = LlmConfig {
    name: "tiny-1M",
    hidden: 128,
    layers: 4,
    n_heads: 8,
    n_kv: 2,
    head_dim: 16,
    ffn: 256,
    vocab: 256,
    max_ctx: 160,
    rope_stage: RopeStage::Post,
};

/// The five models the paper's accelerator evaluation uses (Fig. 9+).
pub fn eval_models() -> Vec<LlmConfig> {
    vec![
        LLAMA2_7B.clone(),
        LLAMA2_13B.clone(),
        LLAMA31_8B.clone(),
        LLAMA32_3B.clone(),
        MISTRAL_7B.clone(),
    ]
}

/// All eight models of Table IV.
pub fn all_models() -> Vec<LlmConfig> {
    vec![
        LLAMA1_7B.clone(),
        LLAMA1_13B.clone(),
        LLAMA2_7B.clone(),
        LLAMA2_13B.clone(),
        LLAMA31_8B.clone(),
        LLAMA32_3B.clone(),
        MISTRAL_7B.clone(),
    ]
}

pub fn by_name(name: &str) -> Option<LlmConfig> {
    let mut all = all_models();
    all.push(TINY.clone());
    all.into_iter().find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_roughly_match_names() {
        assert!((6.0e9..8.0e9).contains(&(LLAMA2_7B.n_params() as f64)));
        assert!((12.0e9..14.5e9).contains(&(LLAMA2_13B.n_params() as f64)));
        assert!((7.0e9..9.0e9).contains(&(LLAMA31_8B.n_params() as f64)));
        assert!((2.5e9..4.0e9).contains(&(LLAMA32_3B.n_params() as f64)));
        let tiny = TINY.n_params() as f64;
        assert!((0.5e6..2.0e6).contains(&tiny), "{tiny}");
    }

    #[test]
    fn gqa_groups() {
        assert_eq!(LLAMA2_7B.gqa_group(), 1);
        assert_eq!(LLAMA31_8B.gqa_group(), 4);
        assert_eq!(LLAMA32_3B.gqa_group(), 3);
        assert_eq!(MISTRAL_7B.gqa_group(), 4);
        assert_eq!(TINY.gqa_group(), 4);
    }

    #[test]
    fn kv_cache_size_llama2_dominates() {
        // Fig 3a: Llama-2-7B needs much more KV than GQA models
        let kv2 = LLAMA2_7B.kv_elems(4096);
        let kv3 = LLAMA31_8B.kv_elems(4096);
        assert!(kv2 > 3 * kv3);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("llama-2-7b").is_some());
        assert!(by_name("tiny-1M").is_some());
        assert!(by_name("nope").is_none());
    }
}
