//! P3-LLM: an integrated NPU-PIM accelerator for edge LLM inference
//! using hybrid numerical formats -- reproduction library.
//!
//! Layers (see DESIGN.md for the full map):
//! * `quant` -- bit-exact hybrid numerical formats (Section IV)
//! * `pcu` -- functional model of the low-precision PIM compute unit
//! * `config`/`workload`/`sim`/`accel`/`area` -- the cycle-level
//!   evaluation substrate behind every table and figure (Section VI)
//! * `coordinator` -- the serving system: request router, continuous
//!   batcher, quantized KV-cache pool, online NPU/PIM operator mapper,
//!   and the [`Engine`] driving a pluggable [`ExecBackend`]:
//!   `PjrtBackend` (real numerics over AOT-compiled graphs) or
//!   `SimBackend` (the `accel` cost model advancing simulated time,
//!   for batch-64 / long-context serving experiments with no
//!   artifacts)
//! * `traffic` -- closed-loop load generation over the engine: seeded
//!   arrival processes (Poisson / constant / bursty / trace replay),
//!   named request mixes (chat, summarization, code-completion,
//!   long-context RAG), [`SloSpec`] targets, and the [`LoadRunner`]
//!   producing [`LoadReport`]s (goodput, SLO attainment, queueing
//!   delay).  Scenario registry: `chat-poisson`, `chat-burst`,
//!   `summarize-steady`, `code-complete`, `rag-long`, `smoke` -- see
//!   `p3llm loadtest`.
//! * `cluster` -- multi-replica serving: a [`Cluster`] of N engine
//!   replicas on one lock-stepped virtual clock behind a pluggable
//!   [`RoutePolicy`] (round-robin, join-shortest-queue,
//!   least-KV-loaded, prefill/decode disaggregation with modeled KV
//!   handoff), reporting fleet goodput / utilization skew / scaling
//!   efficiency ([`ClusterReport`]) -- see `p3llm cluster`.
//! * `runtime` -- artifact registry, weight loaders, PJRT execution
//!   (python never runs at inference time)
//! * `report`/`testutil`/`cli`/`benchkit` -- harness utilities
//!
//! Public entry points: build an engine with [`EngineBuilder`], submit
//! prompts, poll/stream per request, and read [`Metrics`] (TTFT and
//! per-token latency percentiles) -- or drive whole request streams
//! with [`LoadRunner`] / `traffic::scenario_by_name`.  Every fallible
//! public API returns [`Result`]`<_, `[`P3Error`]`>`.

pub mod accel;
pub mod area;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod pcu;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod traffic;
pub mod workload;

pub use cluster::{Cluster, ClusterReport, RoutePolicy};
pub use coordinator::{
    BackendKind, Engine, EngineBuilder, ExecBackend, Metrics, Percentiles,
    RequestId, RequestStatus,
};
pub use error::{P3Error, Result};
pub use traffic::{LoadReport, LoadRunner, LoadTarget, Scenario, SloSpec};

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
