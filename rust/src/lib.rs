//! P3-LLM: an integrated NPU-PIM accelerator for edge LLM inference
//! using hybrid numerical formats -- reproduction library.
//!
//! Layers (see DESIGN.md for the full map, README.md for the CLI):
//! * `quant` -- bit-exact hybrid numerical formats (Section IV)
//! * `pcu` -- functional model of the low-precision PIM compute unit
//! * `config`/`workload`/`sim`/`accel`/`area` -- the cycle-level
//!   evaluation substrate behind every table and figure (Section VI)
//! * `coordinator` -- the serving system: request router, continuous
//!   batcher, page-granular quantized KV pool with shared-prefix
//!   caching (content-hashed, refcounted, copy-on-write pages; see
//!   [`coordinator::KvPool`]), online NPU/PIM operator mapper, and
//!   the [`Engine`] driving a pluggable [`ExecBackend`]:
//!   `PjrtBackend` (real numerics over AOT-compiled graphs) or
//!   `SimBackend` (the `accel` cost model advancing simulated time,
//!   for batch-64 / long-context serving experiments with no
//!   artifacts)
//! * `traffic` -- closed-loop load generation over the engine: seeded
//!   arrival processes (Poisson / constant / bursty / trace replay),
//!   named request mixes (chat, summarization, code-completion,
//!   long-context RAG, plus prefix-bearing `agent` and `rag-cached`
//!   with Zipf-popular system prompts), [`SloSpec`] targets, and the
//!   [`LoadRunner`] producing [`LoadReport`]s (goodput, SLO
//!   attainment, queueing delay, prefix-cache hit rate).  Scenario
//!   registry behind `p3llm loadtest`.
//! * `cluster` -- multi-replica serving: a [`Cluster`] of N engine
//!   replicas on one lock-stepped virtual clock behind a pluggable
//!   [`RoutePolicy`] (round-robin, join-shortest-queue,
//!   least-KV-loaded, prefix-affinity, prefill/decode disaggregation
//!   with modeled KV handoff), reporting fleet goodput / utilization
//!   skew / scaling efficiency ([`ClusterReport`]) -- see
//!   `p3llm cluster`.
//! * `mem` -- the two-tier KV hierarchy: hot pages in PIM-attached
//!   HBM, cold pages offloaded to a CXL/DDR pool
//!   ([`config::CxlLink`]), a per-page residency overlay with an
//!   ahead-of-decode prefetcher ([`mem::TieredKv`]), and the single
//!   slow-tier transfer pricing model every tier crossing (victim
//!   swap restores, page migrations, `pd` pool handoffs) delegates to
//!   -- see `p3llm memtier`.
//! * `sched` -- SLO-tiered preemptive scheduling: [`SloClass`]
//!   priority tiers carried from the traffic layer into per-class
//!   reports, and a pluggable [`VictimPolicy`] registry (recompute
//!   vs priced KV swap) the engine uses to protect interactive
//!   traffic under KV exhaustion -- see `p3llm overload`.
//! * `telemetry` -- zero-cost-when-disabled structured tracing across
//!   the whole stack: a [`Trace`] handle over a bounded ring
//!   [`telemetry::TraceSink`] records request lifecycle spans and
//!   per-operator NPU/PIM/bus device timelines on the engine clock,
//!   with Chrome-trace/Perfetto export, a utilization + NPU/PIM
//!   overlap summary, and a flight recorder for SLO-missing requests
//!   -- see `p3llm trace`.
//! * `obs` -- virtual-clock time-series observability: a typed
//!   metrics registry (counters / gauges / log2-bucket histograms)
//!   scraped at a fixed engine-clock interval into ring-buffered
//!   series, multi-window SLO burn-rate alerting per tier
//!   (pending -> firing -> resolved, recorded into the trace stream),
//!   Prometheus/JSON exports, and a fleet [`HealthReport`] -- the
//!   [`Obs`] handle is zero-cost when disabled, like [`Trace`].  See
//!   `p3llm monitor`.
//! * `runtime` -- artifact registry, weight loaders, PJRT execution
//!   (python never runs at inference time)
//! * `report`/`testutil`/`cli`/`benchkit` -- harness utilities
//!
//! Build an engine with [`EngineBuilder`], submit prompts, poll or
//! stream per request, and read [`Metrics`] (TTFT and per-token
//! latency percentiles) -- or drive whole request streams with
//! [`LoadRunner`] / `traffic::scenario_by_name`.  Every fallible
//! public API returns [`Result`]`<_, `[`P3Error`]`>`, and the sim
//! backend needs no artifacts:
//!
//! ```
//! use p3llm::{EngineBuilder, Result};
//!
//! fn main() -> Result<()> {
//!     let mut eng = EngineBuilder::sim()
//!         .model("tiny-1M")       // config::llm registry
//!         .scheme("p3llm")        // config::scheme registry
//!         .system("P3-LLM")       // accel registry (sim only)
//!         .max_batch(4)
//!         .ctx_limit(128)
//!         .build()?;
//!     let id = eng.submit(vec![1, 2, 3], 8)?;
//!     let metrics = eng.run_to_completion()?;
//!     assert_eq!(metrics.completed, 1);
//!     assert!(eng.poll(id)?.finished);
//!     println!("p95 TTFT {:.2} ms", metrics.ttft_ms.p95);
//!     Ok(())
//! }
//! ```

pub mod accel;
pub mod area;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod mem;
pub mod obs;
pub mod pcu;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod telemetry;
pub mod testutil;
pub mod traffic;
pub mod workload;

pub use cluster::{Cluster, ClusterReport, RoutePolicy};
pub use coordinator::{
    BackendKind, Engine, EngineBuilder, ExecBackend, Metrics, Percentiles,
    RequestId, RequestStatus,
};
pub use error::{P3Error, Result};
pub use obs::{HealthReport, Obs, ObsConfig};
pub use sched::{SloClass, TierMix, VictimPolicy};
pub use telemetry::{Trace, TraceEvent, TraceLane};
pub use traffic::{LoadReport, LoadRunner, LoadTarget, Scenario, SloSpec};

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
