//! P3-LLM: an integrated NPU-PIM accelerator for edge LLM inference
//! using hybrid numerical formats -- reproduction library.
//!
//! Layers (see DESIGN.md):
//! * `quant` -- bit-exact hybrid numerical formats (Section IV)
//! * `pcu` -- functional model of the low-precision PIM compute unit
//! * `config`/`workload`/`sim`/`accel`/`area` -- the cycle-level
//!   evaluation substrate behind every table and figure (Section VI)
//! * `coordinator`/`runtime` -- the serving system: request router,
//!   KV-cache manager, NPU/PIM operator mapper, PJRT execution of the
//!   AOT-compiled model graphs (python never runs at inference time)
//! * `report`/`testutil`/`cli` -- harness utilities

pub mod accel;
pub mod area;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod pcu;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod workload;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
