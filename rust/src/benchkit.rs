//! Bench harness support (criterion substitute -- the offline vendored
//! crate set has no criterion; see DESIGN.md).  Every `rust/benches/`
//! target is a `harness = false` binary that uses these helpers, prints
//! a paper-style table and saves TSV under `reports/`.

use std::time::Instant;

/// Where bench TSVs land.
pub fn reports_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("P3LLM_REPORTS").unwrap_or_else(|_| "reports".into()),
    )
}

pub fn artifacts_dir() -> String {
    std::env::var("P3LLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Measure `f` with warmup; criterion-lite.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        iters,
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        median_ns: samples[iters / 2],
        min_ns: samples[0],
    }
}

/// One `(config, metric, value)` sample of a bench sweep -- the unit
/// of the machine-readable `BENCH_<name>.json` sidecars the serving
/// benches write next to their TSVs.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Which point of the sweep, e.g. `"policy=jsq,replicas=4"`.
    pub config: String,
    /// Metric name, e.g. `"goodput_tok_s"`.
    pub metric: String,
    pub value: f64,
}

impl BenchRecord {
    pub fn new(
        config: impl Into<String>,
        metric: impl Into<String>,
        value: f64,
    ) -> Self {
        BenchRecord { config: config.into(), metric: metric.into(), value }
    }
}

/// Render bench records as a flat JSON array, one object per record,
/// schema `{"bench","config","metric","value","seed"}`.  Hand-rolled
/// (the offline crate set has no serde); the flat shape keeps every
/// bench's sidecar `jq`-able with the same query, no per-bench
/// nesting to know.  Non-finite values serialize as `null` -- JSON
/// has no `inf`.
pub fn bench_json(
    bench: &str,
    seed: u64,
    records: &[BenchRecord],
) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let value = if r.value.is_finite() {
            format!("{:.6}", r.value)
        } else {
            "null".into()
        };
        out.push_str(&format!(
            "{{\"bench\":\"{bench}\",\"config\":\"{}\",\
             \"metric\":\"{}\",\"value\":{value},\"seed\":{seed}}}{}\n",
            r.config,
            r.metric,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("]\n");
    out
}

/// Write [`bench_json`] to `BENCH_<bench>.json` under [`reports_dir`];
/// returns the path written.
pub fn save_bench_json(
    bench: &str,
    seed: u64,
    records: &[BenchRecord],
) -> std::io::Result<std::path::PathBuf> {
    let dir = reports_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, bench_json(bench, seed, records))?;
    Ok(path)
}

/// Which direction of drift a [`TrendBand`] tolerates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrendDir {
    /// Higher is better: fail when the value falls below
    /// `base * (1 - tol)`.  Upward drift never fails.
    Higher,
    /// Lower is better: fail when the value rises above
    /// `base * (1 + tol)`.  Downward drift never fails.
    Lower,
    /// Fail when the value leaves `base +- tol * |base|` either way.
    Either,
}

impl TrendDir {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "higher" => Some(TrendDir::Higher),
            "lower" => Some(TrendDir::Lower),
            "either" => Some(TrendDir::Either),
            _ => None,
        }
    }
}

/// One tolerance band from `benches/baselines.json`: pins a single
/// `(bench, config, metric)` record of a `BENCH_<bench>.json` sidecar
/// to a committed baseline value.  `value: null` turns the band into a
/// presence-only check -- the record must exist, but any number (or
/// null) passes; use it for metrics whose absolute level is machine-
/// or model-tuning-dependent while the emission itself is the
/// contract.
#[derive(Debug, Clone, PartialEq)]
pub struct TrendBand {
    pub bench: String,
    pub config: String,
    pub metric: String,
    /// committed baseline; `None` = presence-only
    pub value: Option<f64>,
    /// relative tolerance around `value` (absolute when `value` is 0)
    pub tol: f64,
    pub dir: TrendDir,
}

/// Outcome of [`check_trend`]: every band was evaluated; `failures`
/// holds one human-readable line per violated band.
#[derive(Debug, Clone, Default)]
pub struct TrendReport {
    /// one "<bench>/<config>/<metric>: ..." line per passing band
    pub passes: Vec<String>,
    pub failures: Vec<String>,
}

impl TrendReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Extract the raw value of `"key":` from one flat-JSON record line:
/// the quoted string body for string fields, the bare token up to the
/// next `,` or `}` otherwise.  Hand-rolled to match [`bench_json`]'s
/// own emitter (no serde in the offline crate set); not a general
/// JSON parser -- escaped quotes inside strings are out of scope.
fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    if let Some(body) = rest.strip_prefix('"') {
        body.find('"').map(|end| &body[..end])
    } else {
        let end = rest.find([',', '}']).unwrap_or(rest.len());
        Some(rest[..end].trim())
    }
}

/// Parse `benches/baselines.json` -- the same flat line-oriented shape
/// as [`bench_json`] plus `"tol"` and `"dir"` keys per record.  Lines
/// without a `"bench"` key (the array brackets) are skipped; any
/// malformed record line is a hard error, not a silent skip, so a
/// typo'd baseline cannot turn into vacuous coverage.
pub fn parse_trend_baselines(
    text: &str,
) -> std::result::Result<Vec<TrendBand>, String> {
    let mut bands = vec![];
    for (ln, line) in text.lines().enumerate() {
        if !line.contains("\"bench\"") {
            continue;
        }
        let get = |key: &str| {
            field(line, key).ok_or_else(|| {
                format!("baselines line {}: missing \"{key}\"", ln + 1)
            })
        };
        let raw_value = get("value")?;
        let value = if raw_value == "null" {
            None
        } else {
            Some(raw_value.parse::<f64>().map_err(|_| {
                format!(
                    "baselines line {}: bad value {raw_value:?}",
                    ln + 1
                )
            })?)
        };
        let tol = get("tol")?.parse::<f64>().map_err(|_| {
            format!("baselines line {}: bad tol", ln + 1)
        })?;
        if tol.is_nan() || tol < 0.0 {
            return Err(format!(
                "baselines line {}: tol must be >= 0",
                ln + 1
            ));
        }
        let dir = TrendDir::parse(get("dir")?).ok_or_else(|| {
            format!(
                "baselines line {}: dir must be higher|lower|either",
                ln + 1
            )
        })?;
        bands.push(TrendBand {
            bench: get("bench")?.to_string(),
            config: get("config")?.to_string(),
            metric: get("metric")?.to_string(),
            value,
            tol,
            dir,
        });
    }
    if bands.is_empty() {
        return Err("baselines.json defines no bands".into());
    }
    Ok(bands)
}

/// Evaluate one band against its sidecar text (`None` = the sidecar
/// file is missing).  Returns a pass line or a failure line.
pub fn check_band(
    band: &TrendBand,
    sidecar: Option<&str>,
) -> std::result::Result<String, String> {
    let who =
        format!("{}/{}/{}", band.bench, band.config, band.metric);
    let text = sidecar.ok_or_else(|| {
        format!("{who}: sidecar BENCH_{}.json missing", band.bench)
    })?;
    let rec = text
        .lines()
        .find(|l| {
            field(l, "config") == Some(band.config.as_str())
                && field(l, "metric") == Some(band.metric.as_str())
        })
        .ok_or_else(|| {
            format!("{who}: no such record in the sidecar")
        })?;
    let raw = field(rec, "value")
        .ok_or_else(|| format!("{who}: record has no value field"))?;
    let base = match band.value {
        // presence-only band: the record existing is the whole check
        None => return Ok(format!("{who}: present ({raw})")),
        Some(b) => b,
    };
    let cur = raw.parse::<f64>().map_err(|_| {
        format!("{who}: value is {raw}, baseline expects {base}")
    })?;
    let dev = if base == 0.0 { band.tol } else { band.tol * base.abs() };
    let verdict = match band.dir {
        TrendDir::Higher if cur < base - dev => Some("fell below"),
        TrendDir::Lower if cur > base + dev => Some("rose above"),
        TrendDir::Either if (cur - base).abs() > dev => {
            Some("drifted outside")
        }
        _ => None,
    };
    match verdict {
        Some(how) => Err(format!(
            "{who}: {cur} {how} baseline {base} (tol {})",
            band.tol
        )),
        None => Ok(format!("{who}: {cur} within {base} +- tol {}",
            band.tol)),
    }
}

/// Check every band of a committed baselines file against the
/// `BENCH_<bench>.json` sidecars under `reports`.  `Err` is reserved
/// for an unusable baselines file; individual band violations land in
/// [`TrendReport::failures`] so a run reports *all* regressions, not
/// just the first.
pub fn check_trend(
    baselines_json: &str,
    reports: &std::path::Path,
) -> std::result::Result<TrendReport, String> {
    let bands = parse_trend_baselines(baselines_json)?;
    let mut cache: Vec<(String, Option<String>)> = vec![];
    let mut rep = TrendReport::default();
    for band in &bands {
        if !cache.iter().any(|(b, _)| b == &band.bench) {
            let path =
                reports.join(format!("BENCH_{}.json", band.bench));
            cache.push((
                band.bench.clone(),
                std::fs::read_to_string(path).ok(),
            ));
        }
        let text = cache
            .iter()
            .find(|(b, _)| b == &band.bench)
            .and_then(|(_, t)| t.as_deref());
        match check_band(band, text) {
            Ok(line) => rep.passes.push(line),
            Err(line) => rep.failures.push(line),
        }
    }
    Ok(rep)
}

/// Quick-mode switch: `P3LLM_BENCH_FAST=1` trims block counts so the
/// full `cargo bench` suite stays in CI budget.
pub fn eval_blocks() -> usize {
    match std::env::var("P3LLM_BENCH_FAST").as_deref() {
        Ok("1") => 2,
        _ => 8,
    }
}

/// Guard for benches that need artifacts: print a skip note instead of
/// failing when `make artifacts` has not run.
pub fn require_artifacts() -> Option<String> {
    let dir = artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.tsv").exists() {
        Some(dir)
    } else {
        println!("SKIP: artifacts not found at {dir} (run `make artifacts`)");
        None
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_json_is_flat_and_null_safe() {
        use super::BenchRecord;
        let recs = vec![
            BenchRecord::new("n=1", "goodput_tok_s", 12.5),
            BenchRecord::new("n=2", "ttft_p99_ms", f64::INFINITY),
        ];
        let j = super::bench_json("demo", 7, &recs);
        assert!(j.starts_with("[\n") && j.ends_with("]\n"));
        assert!(j.contains(
            "{\"bench\":\"demo\",\"config\":\"n=1\",\
             \"metric\":\"goodput_tok_s\",\"value\":12.500000,\"seed\":7},"
        ));
        // infinities land as null, and only the last record skips the
        // trailing comma
        assert!(j.contains("\"value\":null,\"seed\":7}\n]"));
        assert_eq!(j.matches('{').count(), 2);
    }

    #[test]
    fn trend_bands_parse_and_judge() {
        use super::{check_band, parse_trend_baselines, TrendDir};
        let baselines = r#"[
{"bench":"demo","config":"n=1","metric":"goodput_tok_s","value":100.0,"tol":0.05,"dir":"higher"},
{"bench":"demo","config":"n=1","metric":"ttft_p99_ms","value":2.0,"tol":0.10,"dir":"lower"},
{"bench":"demo","config":"n=1","metric":"events","value":null,"tol":0,"dir":"either"}
]"#;
        let bands = parse_trend_baselines(baselines).unwrap();
        assert_eq!(bands.len(), 3);
        assert_eq!(bands[0].dir, TrendDir::Higher);
        assert_eq!(bands[2].value, None);

        let sidecar = super::bench_json(
            "demo",
            7,
            &[
                super::BenchRecord::new("n=1", "goodput_tok_s", 96.0),
                super::BenchRecord::new("n=1", "ttft_p99_ms", 2.19),
                super::BenchRecord::new("n=1", "events", f64::NAN),
            ],
        );
        // 96 >= 100*(1-0.05) and 2.19 <= 2*(1+0.10): inside the bands
        assert!(check_band(&bands[0], Some(&sidecar)).is_ok());
        assert!(check_band(&bands[1], Some(&sidecar)).is_ok());
        // presence-only band passes even on a null value
        assert!(check_band(&bands[2], Some(&sidecar)).is_ok());

        let regressed = super::bench_json(
            "demo",
            7,
            &[
                super::BenchRecord::new("n=1", "goodput_tok_s", 94.9),
                super::BenchRecord::new("n=1", "ttft_p99_ms", 2.21),
            ],
        );
        assert!(check_band(&bands[0], Some(&regressed))
            .unwrap_err()
            .contains("fell below"));
        assert!(check_band(&bands[1], Some(&regressed))
            .unwrap_err()
            .contains("rose above"));
        // events record vanished entirely -> presence band fails
        assert!(check_band(&bands[2], Some(&regressed))
            .unwrap_err()
            .contains("no such record"));
        // missing sidecar fails every band
        assert!(check_band(&bands[0], None)
            .unwrap_err()
            .contains("missing"));
    }

    #[test]
    fn trend_improvements_never_fail_directional_bands() {
        use super::{check_band, parse_trend_baselines};
        let bands = parse_trend_baselines(
            "[\n{\"bench\":\"d\",\"config\":\"c\",\"metric\":\"g\",\
             \"value\":10.0,\"tol\":0.0,\"dir\":\"higher\"},\n\
             {\"bench\":\"d\",\"config\":\"c\",\"metric\":\"t\",\
             \"value\":5.0,\"tol\":0.0,\"dir\":\"lower\"}\n]",
        )
        .unwrap();
        let sidecar = super::bench_json(
            "d",
            7,
            &[
                super::BenchRecord::new("c", "g", 1000.0),
                super::BenchRecord::new("c", "t", 0.001),
            ],
        );
        assert!(check_band(&bands[0], Some(&sidecar)).is_ok());
        assert!(check_band(&bands[1], Some(&sidecar)).is_ok());
    }

    #[test]
    fn trend_baselines_reject_garbage() {
        use super::parse_trend_baselines;
        assert!(parse_trend_baselines("[]").is_err());
        assert!(parse_trend_baselines(
            "{\"bench\":\"d\",\"config\":\"c\",\"metric\":\"m\",\
             \"value\":1.0,\"tol\":0.1,\"dir\":\"sideways\"}"
        )
        .unwrap_err()
        .contains("dir"));
        assert!(parse_trend_baselines(
            "{\"bench\":\"d\",\"config\":\"c\",\"metric\":\"m\",\
             \"value\":1.0,\"tol\":-0.1,\"dir\":\"higher\"}"
        )
        .unwrap_err()
        .contains("tol"));
        assert!(parse_trend_baselines(
            "{\"bench\":\"d\",\"config\":\"c\",\"value\":1.0,\
             \"tol\":0.1,\"dir\":\"higher\"}"
        )
        .unwrap_err()
        .contains("metric"));
    }

    #[test]
    fn timing_monotone() {
        let t = super::time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.min_ns <= t.mean_ns * 1.001);
        assert_eq!(t.iters, 5);
    }
}
