//! Bench harness support (criterion substitute -- the offline vendored
//! crate set has no criterion; see DESIGN.md).  Every `rust/benches/`
//! target is a `harness = false` binary that uses these helpers, prints
//! a paper-style table and saves TSV under `reports/`.

use std::time::Instant;

/// Where bench TSVs land.
pub fn reports_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("P3LLM_REPORTS").unwrap_or_else(|_| "reports".into()),
    )
}

pub fn artifacts_dir() -> String {
    std::env::var("P3LLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Measure `f` with warmup; criterion-lite.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        iters,
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        median_ns: samples[iters / 2],
        min_ns: samples[0],
    }
}

/// Quick-mode switch: `P3LLM_BENCH_FAST=1` trims block counts so the
/// full `cargo bench` suite stays in CI budget.
pub fn eval_blocks() -> usize {
    match std::env::var("P3LLM_BENCH_FAST").as_deref() {
        Ok("1") => 2,
        _ => 8,
    }
}

/// Guard for benches that need artifacts: print a skip note instead of
/// failing when `make artifacts` has not run.
pub fn require_artifacts() -> Option<String> {
    let dir = artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.tsv").exists() {
        Some(dir)
    } else {
        println!("SKIP: artifacts not found at {dir} (run `make artifacts`)");
        None
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_monotone() {
        let t = super::time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.min_ns <= t.mean_ns * 1.001);
        assert_eq!(t.iters, 5);
    }
}
