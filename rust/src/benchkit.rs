//! Bench harness support (criterion substitute -- the offline vendored
//! crate set has no criterion; see DESIGN.md).  Every `rust/benches/`
//! target is a `harness = false` binary that uses these helpers, prints
//! a paper-style table and saves TSV under `reports/`.

use std::time::Instant;

/// Where bench TSVs land.
pub fn reports_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("P3LLM_REPORTS").unwrap_or_else(|_| "reports".into()),
    )
}

pub fn artifacts_dir() -> String {
    std::env::var("P3LLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Measure `f` with warmup; criterion-lite.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        iters,
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        median_ns: samples[iters / 2],
        min_ns: samples[0],
    }
}

/// One `(config, metric, value)` sample of a bench sweep -- the unit
/// of the machine-readable `BENCH_<name>.json` sidecars the serving
/// benches write next to their TSVs.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Which point of the sweep, e.g. `"policy=jsq,replicas=4"`.
    pub config: String,
    /// Metric name, e.g. `"goodput_tok_s"`.
    pub metric: String,
    pub value: f64,
}

impl BenchRecord {
    pub fn new(
        config: impl Into<String>,
        metric: impl Into<String>,
        value: f64,
    ) -> Self {
        BenchRecord { config: config.into(), metric: metric.into(), value }
    }
}

/// Render bench records as a flat JSON array, one object per record,
/// schema `{"bench","config","metric","value","seed"}`.  Hand-rolled
/// (the offline crate set has no serde); the flat shape keeps every
/// bench's sidecar `jq`-able with the same query, no per-bench
/// nesting to know.  Non-finite values serialize as `null` -- JSON
/// has no `inf`.
pub fn bench_json(
    bench: &str,
    seed: u64,
    records: &[BenchRecord],
) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let value = if r.value.is_finite() {
            format!("{:.6}", r.value)
        } else {
            "null".into()
        };
        out.push_str(&format!(
            "{{\"bench\":\"{bench}\",\"config\":\"{}\",\
             \"metric\":\"{}\",\"value\":{value},\"seed\":{seed}}}{}\n",
            r.config,
            r.metric,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("]\n");
    out
}

/// Write [`bench_json`] to `BENCH_<bench>.json` under [`reports_dir`];
/// returns the path written.
pub fn save_bench_json(
    bench: &str,
    seed: u64,
    records: &[BenchRecord],
) -> std::io::Result<std::path::PathBuf> {
    let dir = reports_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, bench_json(bench, seed, records))?;
    Ok(path)
}

/// Quick-mode switch: `P3LLM_BENCH_FAST=1` trims block counts so the
/// full `cargo bench` suite stays in CI budget.
pub fn eval_blocks() -> usize {
    match std::env::var("P3LLM_BENCH_FAST").as_deref() {
        Ok("1") => 2,
        _ => 8,
    }
}

/// Guard for benches that need artifacts: print a skip note instead of
/// failing when `make artifacts` has not run.
pub fn require_artifacts() -> Option<String> {
    let dir = artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.tsv").exists() {
        Some(dir)
    } else {
        println!("SKIP: artifacts not found at {dir} (run `make artifacts`)");
        None
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_json_is_flat_and_null_safe() {
        use super::BenchRecord;
        let recs = vec![
            BenchRecord::new("n=1", "goodput_tok_s", 12.5),
            BenchRecord::new("n=2", "ttft_p99_ms", f64::INFINITY),
        ];
        let j = super::bench_json("demo", 7, &recs);
        assert!(j.starts_with("[\n") && j.ends_with("]\n"));
        assert!(j.contains(
            "{\"bench\":\"demo\",\"config\":\"n=1\",\
             \"metric\":\"goodput_tok_s\",\"value\":12.500000,\"seed\":7},"
        ));
        // infinities land as null, and only the last record skips the
        // trailing comma
        assert!(j.contains("\"value\":null,\"seed\":7}\n]"));
        assert_eq!(j.matches('{').count(), 2);
    }

    #[test]
    fn timing_monotone() {
        let t = super::time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.min_ns <= t.mean_ns * 1.001);
        assert_eq!(t.iters, 5);
    }
}
