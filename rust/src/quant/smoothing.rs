//! Dynamic input-aware key-cache smoothing (paper Eq. 2).
//!
//! Factors are per-channel absolute maxima over the prefill tokens,
//! computed once at prefill and reused for every decode step; the
//! serving path stores them in the KV-cache manager's smoothing store.

/// k: row-major [tokens, channels] -> per-channel |max| (>= eps).
pub fn smoothing_factors(k: &[f32], channels: usize) -> Vec<f32> {
    assert_eq!(k.len() % channels, 0);
    let mut f = vec![0.0f32; channels];
    for row in k.chunks_exact(channels) {
        for (fc, &v) in f.iter_mut().zip(row) {
            *fc = fc.max(v.abs());
        }
    }
    for fc in f.iter_mut() {
        *fc = fc.max(1e-6);
    }
    f
}

/// Merge newly observed tokens into existing factors (decode-time
/// growth is clamped: the paper reuses prefill factors unchanged, and
/// so do we -- this helper exists for the ablation that re-derives
/// factors online).
pub fn update_factors(f: &mut [f32], row: &[f32]) {
    for (fc, &v) in f.iter_mut().zip(row) {
        *fc = fc.max(v.abs());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::int::fake_quant_group_int4;

    #[test]
    fn factors_are_channel_maxima() {
        let k = vec![1.0f32, -4.0, 0.5, 2.0, 3.0, -0.25];
        let f = smoothing_factors(&k, 3);
        assert_eq!(f, vec![2.0, 4.0, 0.5]);
    }

    #[test]
    fn smoothing_reduces_outlier_channel_quant_error() {
        // 8 tokens x 16 channels, channel 5 is a 20x outlier
        let mut rng = crate::testutil::Rng::new(9);
        let t = 8;
        let c = 16;
        let mut k = vec![0.0f32; t * c];
        for (i, v) in k.iter_mut().enumerate() {
            *v = rng.range_f32(-1.0, 1.0)
                * if i % c == 5 { 20.0 } else { 1.0 };
        }
        let f = smoothing_factors(&k, c);
        let direct_err: f64 = {
            let mut q = k.clone();
            for row in q.chunks_exact_mut(c) {
                fake_quant_group_int4(row);
            }
            k.iter().zip(&q).map(|(a, b)| ((a - b) * (a - b)) as f64).sum()
        };
        let smooth_err: f64 = {
            let mut q = k.clone();
            for row in q.chunks_exact_mut(c) {
                for (v, fc) in row.iter_mut().zip(&f) {
                    *v /= fc;
                }
                fake_quant_group_int4(row);
                for (v, fc) in row.iter_mut().zip(&f) {
                    *v *= fc;
                }
            }
            k.iter().zip(&q).map(|(a, b)| ((a - b) * (a - b)) as f64).sum()
        };
        assert!(smooth_err < direct_err, "{smooth_err} vs {direct_err}");
    }
}
