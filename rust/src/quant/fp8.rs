//! FP8 grids: OCP E4M3 (activations) and the paper's unsigned S0E4M4
//! (attention scores, Section IV-B).
//!
//! Both are *value-grid* roundings of f32 (fake-quant): the serving
//! graphs consume f32 values that lie exactly on the 8-bit grid, the
//! same convention the python side uses.  `floor(log2|x|)` is computed
//! from the f32 bit pattern so the exponent is exact (no libm rounding
//! drift against the jnp reference -- boundary cases converge to the
//! same grid point either way, but bit-exactness is simpler to test).

/// Exact floor(log2(|x|)) for positive finite f32 (normals and
/// subnormals); returns a very small value for 0.
#[inline]
fn floor_log2(ax: f32) -> i32 {
    debug_assert!(ax >= 0.0);
    if ax < f32::MIN_POSITIVE {
        // python clamps |x| to 1e-38 before log2, which lands in the
        // subnormal range and then gets clipped by e_min anyway.
        return -127;
    }
    ((ax.to_bits() >> 23) & 0xff) as i32 - 127
}

#[inline]
fn round_fp(x: f32, n_mantissa: i32, e_min: i32, e_max: i32, max_val: f32) -> f32 {
    let ax = x.abs();
    let sign = if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        return 0.0 * x; // preserves signed zero like jnp.sign
    };
    let e = floor_log2(ax).clamp(e_min, e_max);
    let ulp = (e - n_mantissa) as f32;
    let ulp = ulp.exp2();
    let q = (ax / ulp).round_ties_even() * ulp;
    sign * q.min(max_val)
}

/// OCP FP8-E4M3: 4-bit exponent (bias 7), 3-bit mantissa, max 448.
#[inline]
pub fn fp8_e4m3(x: f32) -> f32 {
    round_fp(x, 3, -6, 8, 448.0)
}

/// Paper's unsigned FP8-S0E4M4 for attention scores: no sign bit,
/// 4-bit exponent (bias 15), 4-bit mantissa; covers [0, 1] with 1.0
/// exactly representable.
#[inline]
pub fn fp8_s0e4m4(x: f32) -> f32 {
    let x = x.clamp(0.0, 1.0);
    round_fp(x, 4, -14, 0, 1.0)
}

/// Unsigned INT8 with fixed 1/255 scale (the Table II INT8 row).
#[inline]
pub fn int8_unsigned(x: f32) -> f32 {
    ((x * 255.0).round_ties_even()).clamp(0.0, 255.0) / 255.0
}

/// Storage encoding of an S0E4M4 value (exponent/mantissa byte) -- used
/// by the PCU functional model; `decode` is its exact inverse on grid
/// values.
pub fn s0e4m4_encode(x: f32) -> u8 {
    let x = fp8_s0e4m4(x);
    if x == 0.0 {
        return 0;
    }
    let e = floor_log2(x).clamp(-14, 0);
    let m = (x / (e as f32).exp2() - 1.0) * 16.0;
    if x < (-14f32).exp2() {
        // subnormal: stored exponent 0, value = m/16 * 2^-14
        let m = x / (-14f32 - 4.0).exp2();
        return m as u8;
    }
    let stored_e = (e + 15) as u8;
    (stored_e << 4) | (m.round_ties_even() as u8 & 0xf)
}

pub fn s0e4m4_decode(b: u8) -> f32 {
    let stored_e = (b >> 4) as i32;
    let m = (b & 0xf) as f32;
    if stored_e == 0 {
        m * (-18f32).exp2()
    } else {
        (1.0 + m / 16.0) * ((stored_e - 15) as f32).exp2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_exact_values_roundtrip() {
        for v in [0.0f32, 0.5, 1.0, 1.5, -2.0, 448.0, 0.001953125] {
            assert_eq!(fp8_e4m3(v), v, "{v}");
        }
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(fp8_e4m3(1e6), 448.0);
        assert_eq!(fp8_e4m3(-1e6), -448.0);
    }

    #[test]
    fn e4m3_idempotent() {
        let mut x = -500.0f32;
        while x < 500.0 {
            let q = fp8_e4m3(x);
            assert_eq!(fp8_e4m3(q), q);
            x += 0.37;
        }
    }

    #[test]
    fn s0e4m4_covers_unit_interval() {
        assert_eq!(fp8_s0e4m4(0.0), 0.0);
        assert_eq!(fp8_s0e4m4(1.0), 1.0);
        assert_eq!(fp8_s0e4m4(2.0), 1.0);
        assert_eq!(fp8_s0e4m4(-0.5), 0.0);
        for i in 0..=1000 {
            let p = i as f32 / 1000.0;
            let q = fp8_s0e4m4(p);
            assert!((0.0..=1.0).contains(&q));
            if p >= 2f32.powi(-14) {
                assert!((q - p).abs() / p <= 2f32.powi(-5) + 1e-6);
            }
        }
    }

    #[test]
    fn s0e4m4_encode_decode_roundtrip() {
        for i in 0..=4096 {
            let p = i as f32 / 4096.0;
            let q = fp8_s0e4m4(p);
            assert_eq!(s0e4m4_decode(s0e4m4_encode(q)), q, "p={p}");
        }
    }

    #[test]
    fn int8u_grid() {
        assert_eq!(int8_unsigned(0.0), 0.0);
        assert_eq!(int8_unsigned(1.0), 1.0);
        assert!((int8_unsigned(0.5) - 0.5019608).abs() < 1e-6);
    }
}
