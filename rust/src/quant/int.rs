//! Asymmetric INT4 group quantization + nibble packing (KV-cache,
//! Section IV-A).  Matches `quant.quant_int_asym` in python bit-exactly
//! (same scale formula, round-half-even, same clip).
//!
//! A *group* is one attention head's worth of channels for one token
//! (per-head quantization, Section V-C): every group stores a 16-bit
//! scale and 4-bit zero-point in the paper; here scale/zero stay f32 in
//! metadata while the codes pack two-per-byte, giving the same 4.16
//! effective bits the paper reports for head_dim 128.

/// Quantization metadata + codes for one group.
#[derive(Debug, Clone, PartialEq)]
pub struct Int4Group {
    /// dequant: x = code * scale + zero
    pub scale: f32,
    pub zero: f32,
    /// one code per element, values 0..=15 (unpacked view)
    pub codes: Vec<u8>,
}

/// Quantize one group (e.g. one head x one token) to INT4-Asym.
pub fn quant_group_int4(x: &[f32]) -> Int4Group {
    let levels = 15.0f32;
    let mut xmin = f32::INFINITY;
    let mut xmax = f32::NEG_INFINITY;
    for &v in x {
        xmin = xmin.min(v);
        xmax = xmax.max(v);
    }
    let scale = ((xmax - xmin).max(1e-8)) / levels;
    let codes = x
        .iter()
        .map(|&v| ((v - xmin) / scale).round_ties_even().clamp(0.0, levels) as u8)
        .collect();
    Int4Group { scale, zero: xmin, codes }
}

/// Dequantize a group back to f32 (the PCU-side decode).
pub fn dequant_group_int4(g: &Int4Group, out: &mut [f32]) {
    debug_assert_eq!(g.codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(&g.codes) {
        *o = c as f32 * g.scale + g.zero;
    }
}

/// Fake-quant convenience: quantize + dequantize in place.
pub fn fake_quant_group_int4(x: &mut [f32]) {
    let g = quant_group_int4(x);
    dequant_group_int4(&g, x);
}

/// Pack 4-bit codes two per byte (low nibble = even index), the DRAM
/// storage layout the KV pool and Fig. 14 memory accounting use.
pub fn pack_nibbles(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = pair[0] & 0xf;
        let hi = if pair.len() == 2 { pair[1] & 0xf } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

pub fn unpack_nibbles(packed: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for &b in packed {
        out.push(b & 0xf);
        if out.len() < n {
            out.push(b >> 4);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

/// General INT-b asymmetric fake-quant of a group (b <= 8), used by the
/// Oaken mixed-precision path and tests.
pub fn fake_quant_group_int(x: &mut [f32], bits: u32) {
    let levels = ((1u32 << bits) - 1) as f32;
    let mut xmin = f32::INFINITY;
    let mut xmax = f32::NEG_INFINITY;
    for &v in x.iter() {
        xmin = xmin.min(v);
        xmax = xmax.max(v);
    }
    let scale = ((xmax - xmin).max(1e-8)) / levels;
    for v in x.iter_mut() {
        let q = ((*v - xmin) / scale).round_ties_even().clamp(0.0, levels);
        *v = q * scale + xmin;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin() * 3.0).collect();
        let g = quant_group_int4(&x);
        let mut y = vec![0.0; 16];
        dequant_group_int4(&g, &mut y);
        let bound = g.scale / 2.0 + 1e-6;
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= bound, "{a} {b}");
        }
    }

    #[test]
    fn extremes_exact() {
        let x = [-2.0f32, 0.1, 0.5, 7.0];
        let g = quant_group_int4(&x);
        let mut y = [0.0; 4];
        dequant_group_int4(&g, &mut y);
        assert!((y[0] - -2.0).abs() < 1e-5);
        assert!((y[3] - 7.0).abs() < 1e-3);
    }

    #[test]
    fn idempotent() {
        let mut x = [0.3f32, -1.2, 4.5, 0.0, 2.2, -0.7, 1.1, 3.3];
        fake_quant_group_int4(&mut x);
        let once = x;
        fake_quant_group_int4(&mut x);
        assert_eq!(once, x);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let codes: Vec<u8> = (0..31).map(|i| (i * 7) % 16).collect();
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), 16);
        assert_eq!(unpack_nibbles(&packed, 31), codes);
    }

    #[test]
    fn int8_group_finer_than_int4() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32).cos() * 2.0).collect();
        let (mut a, mut b) = (x.clone(), x.clone());
        fake_quant_group_int(&mut a, 4);
        fake_quant_group_int(&mut b, 8);
        let e4: f32 = x.iter().zip(&a).map(|(u, v)| (u - v).powi(2)).sum();
        let e8: f32 = x.iter().zip(&b).map(|(u, v)| (u - v).powi(2)).sum();
        assert!(e8 < e4);
    }
}
