//! BitMoD 4-bit weight format (paper Section IV-C).
//!
//! FP4 base grid {±0, ±0.5, ±1, ±1.5, ±2, ±3, ±4, ±6} with the
//! redundant negative zero remapped per group of 128 weights to one of
//! the special values {-8, -5, +5, +8}; the encoder searches all four
//! candidate tables and keeps the lowest squared error -- identical
//! search order and tie-breaking as `quant.quant_bitmod_encode`.

/// 15 shared base values; the 16th slot (code 15) is the special value.
pub const FP4_BASE: [f32; 15] = [
    0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -0.5, -1.0, -1.5, -2.0, -3.0,
    -4.0, -6.0,
];
pub const SPECIALS: [f32; 4] = [-8.0, -5.0, 5.0, 8.0];

/// The 4 candidate 16-entry dequant tables.
pub fn tables() -> [[f32; 16]; 4] {
    let mut t = [[0.0f32; 16]; 4];
    for (s, row) in t.iter_mut().enumerate() {
        row[..15].copy_from_slice(&FP4_BASE);
        row[15] = SPECIALS[s];
    }
    t
}

#[derive(Debug, Clone, PartialEq)]
pub struct BitmodGroup {
    pub scale: f32,
    /// index into SPECIALS (2 bits of metadata per group)
    pub special: u8,
    /// 4-bit codes, one per weight
    pub codes: Vec<u8>,
}

/// Encode one group of weights (group size 128 in the paper; any length
/// works).  Scale per candidate table is max|w| / max|table|.
pub fn bitmod_encode_group(w: &[f32]) -> BitmodGroup {
    let tabs = tables();
    let amax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let mut best: Option<(f32, BitmodGroup)> = None;
    for (s, tab) in tabs.iter().enumerate() {
        let tmax = tab.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = amax / tmax;
        let mut codes = Vec::with_capacity(w.len());
        let mut err = 0.0f32;
        for &v in w {
            let mut bi = 0usize;
            let mut bd = f32::INFINITY;
            for (i, &t) in tab.iter().enumerate() {
                let d = (v - t * scale).abs();
                if d < bd {
                    bd = d;
                    bi = i;
                }
            }
            codes.push(bi as u8);
            let dq = tab[bi] * scale;
            err += (dq - v) * (dq - v);
        }
        let cand = BitmodGroup { scale, special: s as u8, codes };
        match &best {
            Some((be, _)) if *be <= err => {}
            _ => best = Some((err, cand)),
        }
    }
    best.unwrap().1
}

/// Exact decoder (the PCU's 6-bit fixed-point dequant path models this
/// table lookup + scale in `pcu`).
pub fn bitmod_decode_group(g: &BitmodGroup, out: &mut [f32]) {
    let tab = tables()[g.special as usize];
    for (o, &c) in out.iter_mut().zip(&g.codes) {
        *o = tab[c as usize] * g.scale;
    }
}

/// Fake-quant a weight matrix laid out [k, n] with groups of `group`
/// along k for each output column n (the layout the GEMV kernel uses).
pub fn fake_quant_weights(w: &mut [f32], k: usize, n: usize, group: usize) {
    assert_eq!(w.len(), k * n);
    assert_eq!(k % group, 0);
    let mut col = vec![0.0f32; group];
    for j in 0..n {
        for g0 in (0..k).step_by(group) {
            for (i, c) in col.iter_mut().enumerate() {
                *c = w[(g0 + i) * n + j];
            }
            let enc = bitmod_encode_group(&col);
            bitmod_decode_group(&enc, &mut col);
            for (i, &c) in col.iter().enumerate() {
                w[(g0 + i) * n + j] = c;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn encode_decode_within_grid_error() {
        let mut rng = Rng::new(42);
        let w = rng.vec_f32(128, -0.5, 0.5);
        let g = bitmod_encode_group(&w);
        let mut y = vec![0.0; 128];
        bitmod_decode_group(&g, &mut y);
        // max grid gap is 2 (between 4 and 6) at scale
        let bound = g.scale * 1.01 + 1e-6;
        for (a, b) in w.iter().zip(&y) {
            assert!((a - b).abs() <= bound, "{a} {b} scale={}", g.scale);
        }
    }

    #[test]
    fn outlier_gets_special_slot() {
        let mut w = vec![0.1f32; 128];
        w[7] = -0.8;
        let g = bitmod_encode_group(&w);
        assert_eq!(g.codes[7], 15);
        assert!(g.special == 0 || g.special == 1); // -8 or -5
    }

    #[test]
    fn beats_int4_on_gaussianish_weights() {
        let mut rng = crate::testutil::Rng::new(7);
        let w: Vec<f32> = (0..512).map(|_| rng.normal() * 0.1).collect();
        let mut bm_err = 0.0f64;
        let mut i4_err = 0.0f64;
        for chunk in w.chunks(128) {
            let g = bitmod_encode_group(chunk);
            let mut y = vec![0.0; chunk.len()];
            bitmod_decode_group(&g, &mut y);
            bm_err += chunk
                .iter()
                .zip(&y)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>();
            let mut z = chunk.to_vec();
            crate::quant::int::fake_quant_group_int(&mut z, 4);
            i4_err += chunk
                .iter()
                .zip(&z)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>();
        }
        assert!(bm_err < i4_err, "{bm_err} vs {i4_err}");
    }

    #[test]
    fn fake_quant_weights_layout() {
        let mut rng = Rng::new(3);
        let (k, n) = (256, 8);
        let mut w = rng.vec_f32(k * n, -1.0, 1.0);
        let orig = w.clone();
        fake_quant_weights(&mut w, k, n, 128);
        assert_ne!(w, orig);
        // idempotent
        let once = w.clone();
        fake_quant_weights(&mut w, k, n, 128);
        assert_eq!(w, once);
    }
}
