//! Hybrid numerical formats (paper Section IV), bit-exact with the
//! python reference in `python/compile/quant.py`.
//!
//! The Rust implementations are the system of record on the serving
//! path: the KV-cache manager packs INT4-Asym nibbles, the weight
//! loader encodes BitMoD codes, and activations round through FP8 grids
//! before being fed to the PJRT executables.  `artifacts/golden_quant.tsv`
//! (produced by `python -m compile.aot`) pins both sides together;
//! `tests/golden.rs` asserts exact equality.

pub mod bitmod;
pub mod fp8;
pub mod int;
pub mod smoothing;

pub use bitmod::{bitmod_decode_group, bitmod_encode_group, BitmodGroup};
pub use fp8::{fp8_e4m3, fp8_s0e4m4, int8_unsigned};
pub use int::{
    dequant_group_int4, pack_nibbles, quant_group_int4, unpack_nibbles,
    Int4Group,
};
pub use smoothing::smoothing_factors;
