//! Analytical area / power model for PE and PCU designs
//! (paper Tables VII and VIII).
//!
//! Substitute for RTL synthesis + DeepScaleTool: component costs are
//! expressed in NAND2-equivalent gate counts from standard digital
//! building blocks (array multiplier ~ b1*b2 full adders, ripple/carry
//! compressors, flops for registers), then converted to um^2 with a
//! 28 nm gate density and scaled to the DRAM process with the paper's
//! 10x density derate [13].  The model is calibrated to reproduce the
//! *orderings and ratios* of Tables VII/VIII, which is what those
//! tables establish.

/// NAND2-equivalent gates.
#[derive(Debug, Clone, Copy, Default)]
pub struct Gates(pub f64);

/// um^2 per NAND2 gate in 28 nm logic (incl. routing overhead).
const UM2_PER_GATE_28NM: f64 = 0.6;
/// DRAM process density derate [13].
pub const DRAM_DENSITY_DERATE: f64 = 10.0;

/// full adder ~ 6 NAND2
const FA: f64 = 6.0;
/// flip-flop ~ 8 NAND2
const FF: f64 = 8.0;

/// b1 x b2 array multiplier.
pub fn multiplier(b1: usize, b2: usize) -> Gates {
    Gates((b1 * b2) as f64 * FA)
}

/// n-bit adder.
pub fn adder(bits: usize) -> Gates {
    Gates(bits as f64 * FA)
}

/// n-bit register.
pub fn register(bits: usize) -> Gates {
    Gates(bits as f64 * FF)
}

/// barrel shifter, n bits by up to s positions
pub fn shifter(bits: usize, stages: usize) -> Gates {
    Gates((bits * stages) as f64 * 2.5)
}

/// FP16 MAC with FP32 accumulate (HBM-PIM's PE): 11x11 mantissa
/// multiplier, exponent adder, alignment shifter, 32-bit add + renorm,
/// FP32 accumulator register.
pub fn fp16_mac() -> Gates {
    let mut g = 0.0;
    g += multiplier(11, 11).0;
    g += adder(6).0; // exponent add
    g += shifter(32, 5).0; // alignment
    g += adder(32).0 + shifter(32, 5).0; // add + normalize
    g += register(32).0;
    g += 150.0; // rounding / control
    Gates(g)
}

/// P3-LLM PE (Fig. 6a right): 4x {6-bit fixed multiplier + 4-bit
/// exponent shift}, 4:2 compressor tree, 32-bit accumulator; per-MAC
/// area is the PE divided by its 4 MACs/cycle.
pub fn p3_pe() -> Gates {
    let mut g = 0.0;
    g += 4.0 * multiplier(6, 6).0;
    g += 4.0 * shifter(16, 4).0; // exponent shift of products
    g += 2.0 * adder(24).0 + adder(28).0; // 4:2 compressor tree
    g += adder(32).0;
    g += register(32).0;
    g += 4.0 * 60.0; // BitMoD/INT4 decoders (LUT + mux)
    Gates(g)
}

/// MANT PE: two 8-bit-ish partial-sum paths + wide combining adder
/// (the paper's critique: "expensive adder to add the two partial sums").
pub fn mant_pe() -> Gates {
    let mut g = 0.0;
    g += 2.0 * multiplier(5, 9).0;
    g += adder(24).0 + shifter(24, 4).0; // combine partial sums
    g += adder(32).0 + register(32).0;
    g += 120.0;
    Gates(g)
}

/// BitMoD PE: bit-serial weight x FP16/FP32 activation datapath with an
/// FP32 accumulator (the expensive part).
pub fn bitmod_pe() -> Gates {
    let mut g = 0.0;
    g += 2.0 * multiplier(4, 12).0;
    g += shifter(32, 5).0 + adder(32).0; // fp32 align+add
    g += adder(8).0;
    g += 2.0 * register(32).0; // fp32 accumulator + staging
    g += 450.0; // fp32 normalize/round + datatype control
    Gates(g)
}

#[derive(Debug, Clone)]
pub struct PeReport {
    pub name: &'static str,
    pub macs_per_cycle: f64,
    pub area_um2_28nm: f64,
    /// energy per MAC (pJ), Table VIII rightmost column
    pub energy_pj_per_mac: f64,
}

/// Dynamic energy ~ switched capacitance ~ active gates; normalized so
/// the FP16 MAC lands at the paper's measured 0.69 pJ.
fn energy_from_gates(gates: f64, macs_per_cycle: f64) -> f64 {
    const PJ_PER_GATE: f64 = 0.69 / 1023.1 * 0.6; // calibrated vs fp16 row
    gates * PJ_PER_GATE / macs_per_cycle / 0.6
}

pub fn pe_table() -> Vec<PeReport> {
    let rows: [(&'static str, Gates, f64); 4] = [
        ("HBM-PIM", fp16_mac(), 1.0),
        ("MANT", mant_pe(), 2.0),
        ("BitMoD", bitmod_pe(), 2.0),
        ("P3-LLM", p3_pe(), 4.0),
    ];
    rows.iter()
        .map(|(name, g, macs)| PeReport {
            name,
            macs_per_cycle: *macs,
            area_um2_28nm: g.0 * UM2_PER_GATE_28NM,
            energy_pj_per_mac: energy_from_gates(g.0, *macs),
        })
        .collect()
}

/// Table VII: PCU compute/buffer area (mm^2, DRAM process at 20 nm
/// equivalent) and HBM area overhead.
#[derive(Debug, Clone)]
pub struct PcuAreaReport {
    pub name: &'static str,
    pub compute_mm2: f64,
    pub buffer_mm2: f64,
    pub hbm_overhead_pct: f64,
}

/// total HBM logic-area budget context: paper reports 16.4% for
/// HBM-PIM (7.7 compute + 6.2 buffer mm^2).
pub fn pcu_area_table() -> Vec<PcuAreaReport> {
    // per-die PCU count: 8 PCUs/channel x channels-per-die; calibrate
    // absolute mm^2 to the paper's HBM-PIM row, then derive P3 from the
    // gate-count ratio of its datapath at iso PCU count.
    let die_mm2 = 84.8; // HBM2 die
    let hbm_pim_compute = 7.7;
    let hbm_pim_buffer = 6.2;
    // datapath gates per PCU: 16 MAC lanes vs 16 PEs
    let g_base = 16.0 * fp16_mac().0;
    let g_p3 = 16.0 * p3_pe().0 + 16.0 * 8.0 * 2.5; // + wider input regs
    let p3_compute = hbm_pim_compute * g_p3 / g_base;
    vec![
        PcuAreaReport {
            name: "HBM-PIM",
            compute_mm2: hbm_pim_compute,
            buffer_mm2: hbm_pim_buffer,
            hbm_overhead_pct: (hbm_pim_compute + hbm_pim_buffer) / die_mm2 * 100.0,
        },
        PcuAreaReport {
            name: "P3-LLM",
            compute_mm2: p3_compute,
            buffer_mm2: hbm_pim_buffer,
            hbm_overhead_pct: (p3_compute + hbm_pim_buffer) / die_mm2 * 100.0,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_orderings() {
        let t = pe_table();
        let get = |n: &str| t.iter().find(|r| r.name == n).unwrap().clone();
        let fp16 = get("HBM-PIM");
        let mant = get("MANT");
        let bitmod = get("BitMoD");
        let p3 = get("P3-LLM");
        // paper: MANT 0.70x, BitMoD 1.26x, P3 1.08x of FP16 area
        assert!(mant.area_um2_28nm < fp16.area_um2_28nm);
        assert!(bitmod.area_um2_28nm > fp16.area_um2_28nm);
        let p3_ratio = p3.area_um2_28nm / fp16.area_um2_28nm;
        assert!((0.9..1.4).contains(&p3_ratio), "{p3_ratio}");
        // P3 energy/MAC far lowest (paper 0.26x)
        assert!(p3.energy_pj_per_mac < mant.energy_pj_per_mac);
        assert!(p3.energy_pj_per_mac < 0.45 * fp16.energy_pj_per_mac);
    }

    #[test]
    fn table7_overhead_under_25pct() {
        let t = pcu_area_table();
        for r in &t {
            assert!(r.hbm_overhead_pct < 25.0, "{}: {}", r.name, r.hbm_overhead_pct);
        }
        // P3 only slightly larger than HBM-PIM (paper: +1.1pp)
        let d = t[1].hbm_overhead_pct - t[0].hbm_overhead_pct;
        assert!((0.0..4.0).contains(&d), "{d}");
    }
}
