//! Fixed-interval virtual-clock scraping into ring-buffered time
//! series, plus the two deterministic exports (Prometheus text
//! format, JSON series dump).
//!
//! A scrape samples every registered metric's scalar
//! ([`Metric::scrape_value`]) at one engine-clock timestamp; the
//! per-metric rings keep the newest `cap` points (drop-oldest, same
//! policy as [`RingSink`](crate::telemetry::RingSink)).  Everything
//! iterates in [`MetricKey`] order, so two identical runs export
//! byte-identical text -- a `monitor --smoke` CI gate.

use std::collections::{BTreeMap, VecDeque};

use super::registry::{Metric, MetricKey, Registry};

/// One scraped sample on the engine clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub ts_ms: f64,
    pub value: f64,
}

/// Ring-buffered time series of one metric.
#[derive(Debug, Clone)]
pub struct Series {
    pub key: MetricKey,
    points: VecDeque<Point>,
    cap: usize,
    dropped: usize,
}

impl Series {
    fn new(key: MetricKey, cap: usize) -> Self {
        Series { key, points: VecDeque::new(), cap: cap.max(1), dropped: 0 }
    }

    fn push(&mut self, p: Point) {
        if self.points.len() == self.cap {
            self.points.pop_front();
            self.dropped += 1;
        }
        self.points.push_back(p);
    }

    /// Retained points, oldest first.
    pub fn points(&self) -> impl Iterator<Item = &Point> {
        self.points.iter()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Points discarded to stay within the ring bound.
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Last sample at or before `ts_ms` (the windowed-delta lookup the
    /// burn-rate engine runs on cumulative counter series).
    pub fn at_or_before(&self, ts_ms: f64) -> Option<Point> {
        self.points
            .iter()
            .rev()
            .find(|p| p.ts_ms <= ts_ms + 1e-9)
            .copied()
    }
}

/// The scraper: samples a [`Registry`] at a fixed virtual-clock
/// interval into per-metric [`Series`] rings.
#[derive(Debug)]
pub struct Scraper {
    interval_ms: f64,
    cap: usize,
    last_ms: Option<f64>,
    scrapes: u64,
    series: BTreeMap<MetricKey, Series>,
}

impl Scraper {
    /// Scrape every `interval_ms` of engine-clock time, keeping the
    /// newest `cap` points per series.
    pub fn new(interval_ms: f64, cap: usize) -> Self {
        Scraper {
            interval_ms: interval_ms.max(1e-6),
            cap: cap.max(1),
            last_ms: None,
            scrapes: 0,
            series: BTreeMap::new(),
        }
    }

    pub fn interval_ms(&self) -> f64 {
        self.interval_ms
    }

    /// Has a full interval elapsed since the last scrape?  (The first
    /// call is always due.)
    pub fn due(&self, now_ms: f64) -> bool {
        match self.last_ms {
            Some(last) => now_ms >= last + self.interval_ms - 1e-9,
            None => true,
        }
    }

    /// Sample every metric at `now_ms`.
    pub fn scrape(&mut self, now_ms: f64, registry: &Registry) {
        self.last_ms = Some(now_ms);
        self.scrapes += 1;
        for (key, m) in registry.iter() {
            let p = Point { ts_ms: now_ms, value: m.scrape_value() };
            self.series
                .entry(*key)
                .or_insert_with(|| Series::new(*key, self.cap))
                .push(p);
        }
    }

    /// Append a derived sample (e.g. a burn rate the alert engine just
    /// computed) outside the registry scrape.
    pub fn push_derived(&mut self, key: MetricKey, ts_ms: f64, value: f64) {
        self.series
            .entry(key)
            .or_insert_with(|| Series::new(key, self.cap))
            .push(Point { ts_ms, value });
    }

    pub fn scrapes(&self) -> u64 {
        self.scrapes
    }

    /// Engine-clock time of the most recent scrape (None before the
    /// first) -- where a post-run cool-down must resume from to keep
    /// series timestamps monotone.
    pub fn last_scrape_ms(&self) -> Option<f64> {
        self.last_ms
    }

    /// Total retained points across all series.
    pub fn total_points(&self) -> usize {
        self.series.values().map(|s| s.len()).sum()
    }

    /// All series in deterministic key order.
    pub fn series(&self) -> impl Iterator<Item = &Series> {
        self.series.values()
    }

    pub fn get(&self, key: &MetricKey) -> Option<&Series> {
        self.series.get(key)
    }

    /// Fleet-merged series for `(name, class)`: per-timestamp sum of
    /// every replica's samples (replicas scrape on the shared hub
    /// clock, so timestamps align by construction).
    pub fn fleet_points(
        &self,
        name: &'static str,
        class: Option<crate::sched::SloClass>,
    ) -> Vec<Point> {
        let mut acc: BTreeMap<u64, f64> = BTreeMap::new();
        for s in self.series.values() {
            if s.key.name != name || s.key.class != class {
                continue;
            }
            for p in s.points() {
                *acc.entry(p.ts_ms.to_bits()).or_insert(0.0) += p.value;
            }
        }
        acc.into_iter()
            .map(|(bits, value)| Point { ts_ms: f64::from_bits(bits), value })
            .collect()
    }
}

fn fmt_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "NaN".into()
    }
}

fn label_str(key: &MetricKey) -> String {
    match key.class {
        Some(c) => {
            format!("{{class=\"{}\",replica=\"{}\"}}", c.name(), key.replica)
        }
        None => format!("{{replica=\"{}\"}}", key.replica),
    }
}

/// Prometheus text-format dump of a registry's final values.  `# TYPE`
/// lines are emitted once per metric name; histograms expose `_count`,
/// `_sum` and `quantile` samples.  Deterministic: key order + fixed
/// float precision.
pub fn prometheus_text(registry: &Registry) -> String {
    let mut out = String::new();
    let mut last_name = "";
    for (key, m) in registry.iter() {
        if key.name != last_name {
            out.push_str(&format!(
                "# TYPE p3llm_{} {}\n",
                key.name,
                m.kind()
            ));
            last_name = key.name;
        }
        let labels = label_str(key);
        match m {
            Metric::Counter(v) | Metric::Gauge(v) => {
                out.push_str(&format!(
                    "p3llm_{}{labels} {}\n",
                    key.name,
                    fmt_f(*v)
                ));
            }
            Metric::Histogram(h) => {
                let base = match key.class {
                    Some(c) => format!(
                        "class=\"{}\",replica=\"{}\"",
                        c.name(),
                        key.replica
                    ),
                    None => format!("replica=\"{}\"", key.replica),
                };
                for (q, label) in
                    [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")]
                {
                    out.push_str(&format!(
                        "p3llm_{}{{{base},quantile=\"{label}\"}} {}\n",
                        key.name,
                        fmt_f(h.quantile(q))
                    ));
                }
                out.push_str(&format!(
                    "p3llm_{}_count{labels} {}\n",
                    key.name,
                    h.count()
                ));
                out.push_str(&format!(
                    "p3llm_{}_sum{labels} {}\n",
                    key.name,
                    fmt_f(h.sum())
                ));
            }
        }
    }
    out
}

/// JSON dump of every scraped series:
/// `{"series":[{"name","class","replica","points":[[ts_ms,value],..]},..]}`.
/// Hand-rolled like the other exporters (no serde in the crate).
pub fn series_json(scraper: &Scraper) -> String {
    let mut out = String::from("{\"series\":[\n");
    let mut first = true;
    for s in scraper.series() {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let class = match s.key.class {
            Some(c) => format!("\"{}\"", c.name()),
            None => "null".into(),
        };
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"class\":{class},\"replica\":{},\
             \"points\":[",
            s.key.name, s.key.replica
        ));
        for (i, p) in s.points().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "[{:.3},{}]",
                p.ts_ms,
                fmt_f(p.value)
            ));
        }
        out.push_str("]}");
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::SloClass;

    fn key(name: &'static str) -> MetricKey {
        MetricKey { name, class: None, replica: 0 }
    }

    #[test]
    fn scrape_cadence_and_ring_bound() {
        let mut reg = Registry::default();
        reg.counter_add(key("done"), 1.0);
        let mut sc = Scraper::new(10.0, 4);
        assert!(sc.due(0.0));
        sc.scrape(0.0, &reg);
        assert!(!sc.due(5.0));
        assert!(sc.due(10.0));
        for t in 1..10 {
            sc.scrape(t as f64 * 10.0, &reg);
        }
        let s = sc.get(&key("done")).unwrap();
        assert_eq!(s.len(), 4);
        assert_eq!(s.dropped(), 6);
        assert_eq!(sc.scrapes(), 10);
        // newest points survived
        assert_eq!(s.points().next().unwrap().ts_ms, 60.0);
        assert_eq!(s.at_or_before(75.0).unwrap().ts_ms, 70.0);
        assert!(s.at_or_before(10.0).is_none());
    }

    #[test]
    fn fleet_points_merge_replicas_by_timestamp() {
        let mut sc = Scraper::new(1.0, 16);
        for rep in 0..3u32 {
            let k = MetricKey { name: "q", class: None, replica: rep };
            sc.push_derived(k, 5.0, 1.0 + rep as f64);
            sc.push_derived(k, 6.0, 10.0);
        }
        let pts = sc.fleet_points("q", None);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0], Point { ts_ms: 5.0, value: 6.0 });
        assert_eq!(pts[1], Point { ts_ms: 6.0, value: 30.0 });
        assert!(sc.fleet_points("other", None).is_empty());
    }

    #[test]
    fn exports_are_deterministic_and_typed() {
        let build = || {
            let mut reg = Registry::default();
            reg.counter_add(key("slo_total"), 5.0);
            reg.counter_add(
                MetricKey {
                    name: "slo_total",
                    class: Some(SloClass::Batch),
                    replica: 1,
                },
                2.0,
            );
            reg.gauge_set(key("queue_depth"), 3.0);
            reg.observe(key("ttft_ms"), 4.0);
            reg.observe(key("ttft_ms"), 9.0);
            let mut sc = Scraper::new(1.0, 8);
            sc.scrape(1.0, &reg);
            sc.scrape(2.0, &reg);
            (prometheus_text(&reg), series_json(&sc))
        };
        let (p1, j1) = build();
        let (p2, j2) = build();
        assert_eq!(p1, p2);
        assert_eq!(j1, j2);
        assert!(p1.contains("# TYPE p3llm_slo_total counter"));
        assert!(p1.contains("# TYPE p3llm_queue_depth gauge"));
        assert!(p1.contains("# TYPE p3llm_ttft_ms histogram"));
        assert!(p1
            .contains("p3llm_slo_total{class=\"batch\",replica=\"1\"} "));
        assert!(p1.contains("quantile=\"0.95\""));
        assert!(p1.contains("p3llm_ttft_ms_count{replica=\"0\"} 2"));
        assert!(j1.contains("\"name\":\"queue_depth\""));
        assert!(j1.contains("\"points\":[[1.000,"));
    }
}
