//! `obs/`: virtual-clock observability -- a typed metrics registry
//! scraped into ring-buffered time series, SLO burn-rate alerting,
//! and fleet health snapshots.  Zero-cost when disabled, like
//! [`telemetry`](crate::telemetry).
//!
//! The terminal aggregates ([`LoadReport`](crate::LoadReport)) are
//! end-of-run scalars: a flash crowd that craters interactive SLOs
//! for 20 virtual seconds mid-run is invisible until the run ends.
//! This layer is the continuous sensor: the engine updates counters /
//! gauges / histograms as it serves ([`registry`]), a fixed
//! virtual-clock-interval scraper samples them into bounded series
//! ([`series`]), and multi-window burn-rate rules over the per-tier
//! miss counters drive a pending -> firing -> resolved alert state
//! machine ([`alert`]) whose transitions land in the trace stream and
//! whose summary is a fleet [`HealthReport`] ([`health`]) -- the
//! signal the ROADMAP's autoscaler item needs.
//!
//! The [`Obs`] handle mirrors [`Trace`](crate::telemetry::Trace):
//! cheap to clone, replica-tagged via [`Obs::for_replica`], and the
//! default [`Obs::off`] makes every emit a one-branch no-op so
//! uninstrumented runs stay byte-identical (`p3llm monitor --smoke`
//! proves it).
//!
//! ```
//! use p3llm::obs::{Obs, ObsConfig};
//! use p3llm::{EngineBuilder, SloSpec};
//! # fn main() -> p3llm::Result<()> {
//! let obs = Obs::new(ObsConfig::standard(SloSpec::chatbot()));
//! let mut eng = EngineBuilder::sim()
//!     .model("tiny-1M")
//!     .max_batch(2)
//!     .ctx_limit(128)
//!     .observe(obs.clone())
//!     .build()?;
//! eng.submit(vec![1, 2, 3], 4)?;
//! eng.run_to_completion()?;
//! let prom = obs.prometheus();
//! assert!(prom.contains("p3llm_slo_total"));
//! # Ok(())
//! # }
//! ```

pub mod alert;
pub mod health;
pub mod registry;
pub mod series;

use std::cell::RefCell;
use std::rc::Rc;

use crate::sched::SloClass;
use crate::telemetry::Trace;
use crate::traffic::SloSpec;

pub use alert::{AlertEvent, AlertKind, AlertRule, AlertState};
pub use health::{HealthReport, TierHealth};
pub use registry::{Histogram, Metric, MetricKey, Registry};
pub use series::{Point, Scraper, Series};

use alert::{windowed_burn, RuleEval};

/// Counter of requests judged against their tier SLO.
pub const SLO_TOTAL: &str = "slo_total";
/// Counter of requests that missed their tier SLO.
pub const SLO_MISS: &str = "slo_miss";
/// Derived series name the alert engine records fast-window burns
/// under (one series per tier).
pub const BURN_FAST: &str = "burn_fast";

/// Scraped metrics that additionally export as Perfetto counter
/// tracks when a trace handle is attached: registry name -> the
/// `obs:`-prefixed trace counter name (`telemetry::export` routes the
/// prefix onto a dedicated per-replica metrics track).
fn traced_name(name: &'static str) -> Option<&'static str> {
    Some(match name {
        "queue_depth" => "obs:queue_depth",
        "active_lanes" => "obs:active_lanes",
        "kv_used_bytes" => "obs:kv_used_bytes",
        "kv_cached_bytes" => "obs:kv_cached_bytes",
        "kv_hot_pages" => "obs:kv_hot_pages",
        "kv_cold_pages" => "obs:kv_cold_pages",
        "overlap_factor" => "obs:overlap_factor",
        _ => return None,
    })
}

/// Trace counter name for a tier's burn series.
fn burn_trace_name(class: SloClass) -> &'static str {
    match class {
        SloClass::Interactive => "obs:burn:interactive",
        SloClass::Batch => "obs:burn:batch",
        SloClass::BestEffort => "obs:burn:best-effort",
    }
}

/// Observability configuration: scrape cadence, series retention, the
/// base SLO the per-tier judges scale from, and the alert rules.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// engine-clock ms between scrapes
    pub scrape_interval_ms: f64,
    /// retained points per series (drop-oldest ring)
    pub ring: usize,
    /// base latency budgets; tier `c` is judged against
    /// `slo.scaled(c.slo_factor())`, same rule as
    /// [`LoadReport`](crate::LoadReport) breakdowns
    pub slo: SloSpec,
    pub rules: Vec<AlertRule>,
}

impl ObsConfig {
    /// One standard burn-rate rule per tier with the given windows.
    pub fn with_windows(
        slo: SloSpec,
        scrape_interval_ms: f64,
        fast_ms: f64,
        slow_ms: f64,
    ) -> Self {
        ObsConfig {
            scrape_interval_ms,
            ring: 1 << 14,
            slo,
            rules: SloClass::all()
                .into_iter()
                .map(|c| AlertRule::burn(c, fast_ms, slow_ms))
                .collect(),
        }
    }

    /// Default cadence: scrape every 50 virtual ms, 1 s fast window,
    /// 4 s slow window.
    pub fn standard(slo: SloSpec) -> Self {
        Self::with_windows(slo, 50.0, 1_000.0, 4_000.0)
    }
}

/// The shared hub behind every [`Obs`] clone.
struct Hub {
    cfg: ObsConfig,
    registry: Registry,
    scraper: Scraper,
    evals: Vec<RuleEval>,
    events: Vec<AlertEvent>,
    /// optional trace handle: scrapes mirror selected metrics as
    /// `obs:` counters and alert transitions as `alert:*` instants
    trace: Trace,
}

impl Hub {
    fn new(cfg: ObsConfig) -> Self {
        let scraper = Scraper::new(cfg.scrape_interval_ms, cfg.ring);
        let evals =
            cfg.rules.iter().map(|r| RuleEval::new(*r)).collect();
        Hub {
            cfg,
            registry: Registry::default(),
            scraper,
            evals,
            events: vec![],
            trace: Trace::off(),
        }
    }

    fn scrape(&mut self, now_ms: f64) {
        self.scraper.scrape(now_ms, &self.registry);
        if self.trace.enabled() {
            for (key, m) in self.registry.iter() {
                if let Some(tn) = traced_name(key.name) {
                    self.trace.for_replica(key.replica).counter(
                        tn,
                        now_ms,
                        m.scrape_value(),
                    );
                }
            }
        }
        // evaluate the burn-rate rules on the fleet-merged cumulative
        // miss counters this scrape just extended
        for i in 0..self.evals.len() {
            let rule = self.evals[i].rule;
            let total =
                self.scraper.fleet_points(SLO_TOTAL, Some(rule.class));
            let miss =
                self.scraper.fleet_points(SLO_MISS, Some(rule.class));
            let fast = windowed_burn(
                &total,
                &miss,
                now_ms,
                rule.fast_ms,
                rule.error_budget,
            );
            let slow = windowed_burn(
                &total,
                &miss,
                now_ms,
                rule.slow_ms,
                rule.error_budget,
            );
            self.scraper.push_derived(
                MetricKey {
                    name: BURN_FAST,
                    class: Some(rule.class),
                    replica: 0,
                },
                now_ms,
                fast,
            );
            self.trace.counter(burn_trace_name(rule.class), now_ms, fast);
            if let Some(ev) = self.evals[i].eval(now_ms, fast, slow) {
                self.trace.instant(
                    ev.kind.event_name(),
                    now_ms,
                    None,
                    Some(ev.class),
                    ev.burn,
                );
                self.events.push(ev);
            }
        }
    }

    fn health(
        &self,
        now_ms: f64,
        throughput_tok_s: Option<f64>,
        saturation_tok_s: Option<f64>,
    ) -> HealthReport {
        let mut tiers = vec![];
        let mut worst: Option<(SloClass, f64)> = None;
        for class in SloClass::all() {
            let total =
                self.registry.fleet_counter(SLO_TOTAL, Some(class));
            if total <= 0.0 {
                continue;
            }
            let missed =
                self.registry.fleet_counter(SLO_MISS, Some(class));
            let burn = self
                .scraper
                .get(&MetricKey {
                    name: BURN_FAST,
                    class: Some(class),
                    replica: 0,
                })
                .and_then(|s| s.at_or_before(now_ms))
                .map(|p| p.value)
                .unwrap_or(0.0);
            tiers.push(TierHealth {
                class,
                total,
                missed,
                attainment: 1.0 - missed / total,
                burn,
            });
            if worst.map_or(true, |(_, b)| burn > b) {
                worst = Some((class, burn));
            }
        }
        let shares: Vec<f64> = self
            .registry
            .iter()
            .filter(|(k, _)| k.name == "tokens_emitted")
            .map(|(_, m)| match m {
                Metric::Counter(v) => *v,
                _ => 0.0,
            })
            .collect();
        HealthReport {
            ts_ms: now_ms,
            tiers,
            worst_class: worst.map(|(c, _)| c),
            worst_burn: worst.map(|(_, b)| b).unwrap_or(0.0),
            saturation_headroom: match (throughput_tok_s, saturation_tok_s)
            {
                (Some(t), Some(s)) if s > 0.0 => Some(1.0 - t / s),
                _ => None,
            },
            replica_skew: health::skew(&shares),
            firing: self
                .evals
                .iter()
                .filter(|e| e.state() == AlertState::Firing)
                .count(),
            transitions: self.events.len(),
        }
    }
}

/// Cheap cloneable observability handle: a shared metrics hub plus
/// the replica tag stamped on every sample this clone emits.  The
/// default ([`Obs::off`]) is disabled -- every emit returns after one
/// branch, nothing is allocated, and instrumented code paths stay
/// byte-identical to uninstrumented ones.
#[derive(Clone, Default)]
pub struct Obs {
    hub: Option<Rc<RefCell<Hub>>>,
    replica: u32,
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Obs")
            .field("enabled", &self.enabled())
            .field("replica", &self.replica)
            .finish()
    }
}

impl Obs {
    /// Disabled handle (the default): emits are no-ops, exports are
    /// empty.
    pub fn off() -> Self {
        Obs::default()
    }

    /// Enabled handle over a fresh hub.
    pub fn new(cfg: ObsConfig) -> Self {
        Obs { hub: Some(Rc::new(RefCell::new(Hub::new(cfg)))), replica: 0 }
    }

    /// Is this handle recording?
    pub fn enabled(&self) -> bool {
        self.hub.is_some()
    }

    /// Replica tag this handle stamps on its samples.
    pub fn replica_id(&self) -> u32 {
        self.replica
    }

    /// Clone sharing the same hub but tagging samples with `replica`
    /// -- how a cluster's per-replica series merge by construction.
    pub fn for_replica(&self, replica: u32) -> Obs {
        Obs { hub: self.hub.clone(), replica }
    }

    /// Attach a trace handle: scrapes then mirror selected metrics as
    /// `obs:` counter events and alert transitions as `alert:*`
    /// instants into the trace stream (hub-wide; call once on the
    /// base handle).
    pub fn set_trace(&self, trace: Trace) {
        if let Some(hub) = &self.hub {
            hub.borrow_mut().trace = trace;
        }
    }

    fn key(
        &self,
        name: &'static str,
        class: Option<SloClass>,
    ) -> MetricKey {
        MetricKey { name, class, replica: self.replica }
    }

    /// Add to a monotonic counter.
    pub fn counter_add(
        &self,
        name: &'static str,
        class: Option<SloClass>,
        v: f64,
    ) {
        let Some(hub) = &self.hub else { return };
        hub.borrow_mut().registry.counter_add(self.key(name, class), v);
    }

    /// Set a gauge to its latest sample.
    pub fn gauge_set(
        &self,
        name: &'static str,
        class: Option<SloClass>,
        v: f64,
    ) {
        let Some(hub) = &self.hub else { return };
        hub.borrow_mut().registry.gauge_set(self.key(name, class), v);
    }

    /// Record one histogram observation.
    pub fn observe(
        &self,
        name: &'static str,
        class: Option<SloClass>,
        v: f64,
    ) {
        let Some(hub) = &self.hub else { return };
        hub.borrow_mut().registry.observe(self.key(name, class), v);
    }

    /// Judge one finished request against its tier's scaled SLO and
    /// record the miss counters + latency histograms the burn-rate
    /// rules watch.  `ttft_ms` / `tpot_ms` are engine-side latencies
    /// (measured from submission).
    pub fn request_finished(
        &self,
        class: SloClass,
        ttft_ms: f64,
        tpot_ms: Option<f64>,
    ) {
        let Some(hub) = &self.hub else { return };
        let mut hub = hub.borrow_mut();
        let spec = hub.cfg.slo.scaled(class.slo_factor());
        let met = spec.meets(ttft_ms, tpot_ms);
        let reg = &mut hub.registry;
        reg.counter_add(self.key(SLO_TOTAL, Some(class)), 1.0);
        if !met {
            reg.counter_add(self.key(SLO_MISS, Some(class)), 1.0);
        }
        reg.observe(self.key("ttft_ms", Some(class)), ttft_ms);
        if let Some(t) = tpot_ms {
            reg.observe(self.key("tpot_ms", Some(class)), t);
        }
    }

    /// Scrape + evaluate alerts if a full interval has elapsed on the
    /// engine clock (the engine calls this every step; the hub clock
    /// is shared, so a fleet scrapes once per interval, not once per
    /// replica).
    pub fn maybe_scrape(&self, now_ms: f64) {
        let Some(hub) = &self.hub else { return };
        let mut hub = hub.borrow_mut();
        if hub.scraper.due(now_ms) {
            hub.scrape(now_ms);
        }
    }

    /// Force one scrape + alert evaluation at `now_ms` (end-of-run
    /// flush).
    pub fn scrape_now(&self, now_ms: f64) {
        let Some(hub) = &self.hub else { return };
        hub.borrow_mut().scrape(now_ms);
    }

    /// Alert transitions recorded so far.
    pub fn events(&self) -> Vec<AlertEvent> {
        match &self.hub {
            Some(h) => h.borrow().events.clone(),
            None => vec![],
        }
    }

    /// Engine-clock time of the most recent scrape (None when disabled
    /// or before the first scrape).
    pub fn last_scrape_ms(&self) -> Option<f64> {
        self.hub
            .as_ref()
            .and_then(|h| h.borrow().scraper.last_scrape_ms())
    }

    /// Scrapes performed so far.
    pub fn scrapes(&self) -> u64 {
        match &self.hub {
            Some(h) => h.borrow().scraper.scrapes(),
            None => 0,
        }
    }

    /// Retained points across all series (0 when disabled).
    pub fn total_points(&self) -> usize {
        match &self.hub {
            Some(h) => h.borrow().scraper.total_points(),
            None => 0,
        }
    }

    /// Fleet-merged series for `(name, class)` (sums across replicas
    /// at each scrape timestamp).
    pub fn series_points(
        &self,
        name: &'static str,
        class: Option<SloClass>,
    ) -> Vec<Point> {
        match &self.hub {
            Some(h) => h.borrow().scraper.fleet_points(name, class),
            None => vec![],
        }
    }

    /// Prometheus text-format dump of the registry's current values
    /// (empty when disabled).
    pub fn prometheus(&self) -> String {
        match &self.hub {
            Some(h) => series::prometheus_text(&h.borrow().registry),
            None => String::new(),
        }
    }

    /// JSON dump of every scraped series (empty when disabled).
    pub fn series_json(&self) -> String {
        match &self.hub {
            Some(h) => series::series_json(&h.borrow().scraper),
            None => String::new(),
        }
    }

    /// Fleet health snapshot at `now_ms`.  Pass the run's observed
    /// throughput and modeled saturation for the headroom line when
    /// known.
    pub fn health(
        &self,
        now_ms: f64,
        throughput_tok_s: Option<f64>,
        saturation_tok_s: Option<f64>,
    ) -> HealthReport {
        match &self.hub {
            Some(h) => {
                h.borrow().health(now_ms, throughput_tok_s, saturation_tok_s)
            }
            None => HealthReport {
                ts_ms: now_ms,
                tiers: vec![],
                worst_class: None,
                worst_burn: 0.0,
                saturation_headroom: None,
                replica_skew: 0.0,
                firing: 0,
                transitions: 0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_obs_records_nothing() {
        let o = Obs::off();
        assert!(!o.enabled());
        o.counter_add("x", None, 1.0);
        o.gauge_set("y", None, 2.0);
        o.observe("z", None, 3.0);
        o.request_finished(SloClass::Interactive, 1.0, None);
        o.maybe_scrape(100.0);
        assert_eq!(o.total_points(), 0);
        assert_eq!(o.scrapes(), 0);
        assert!(o.prometheus().is_empty());
        assert!(o.series_json().is_empty());
        assert!(o.events().is_empty());
        let h = o.health(0.0, None, None);
        assert!(h.tiers.is_empty() && h.worst_class.is_none());
    }

    #[test]
    fn judges_requests_against_scaled_tier_budgets() {
        let slo = SloSpec { ttft_ms: 100.0, tpot_ms: 10.0 };
        let o = Obs::new(ObsConfig::standard(slo));
        // 150 ms TTFT: misses interactive (100), meets batch (400)
        o.request_finished(SloClass::Interactive, 150.0, None);
        o.request_finished(SloClass::Batch, 150.0, None);
        let prom = o.prometheus();
        assert!(prom.contains(
            "p3llm_slo_miss{class=\"interactive\",replica=\"0\"} 1.000000"
        ));
        assert!(prom.contains(
            "p3llm_slo_total{class=\"batch\",replica=\"0\"} 1.000000"
        ));
        assert!(!prom.contains("p3llm_slo_miss{class=\"batch\""));
        let h = o.health(0.0, Some(50.0), Some(100.0));
        assert_eq!(h.tiers.len(), 2);
        assert_eq!(h.tiers[0].class, SloClass::Interactive);
        assert_eq!(h.tiers[0].attainment, 0.0);
        assert_eq!(h.tiers[1].attainment, 1.0);
        assert_eq!(h.saturation_headroom, Some(0.5));
    }

    #[test]
    fn scrape_cadence_and_burn_alerts_end_to_end() {
        let slo = SloSpec { ttft_ms: 10.0, tpot_ms: f64::INFINITY };
        let cfg = ObsConfig::with_windows(slo, 10.0, 50.0, 100.0);
        let o = Obs::new(cfg);
        // healthy phase: all meet
        for t in 0..10 {
            o.request_finished(SloClass::Interactive, 1.0, None);
            o.maybe_scrape(t as f64 * 10.0);
        }
        assert!(o.events().is_empty());
        // outage: every request misses -> pending, then firing
        for t in 10..25 {
            o.request_finished(SloClass::Interactive, 99.0, None);
            o.request_finished(SloClass::Interactive, 99.0, None);
            o.maybe_scrape(t as f64 * 10.0);
        }
        let evs = o.events();
        assert!(
            evs.iter().any(|e| e.kind == AlertKind::Pending),
            "{evs:?}"
        );
        assert!(
            evs.iter().any(|e| e.kind == AlertKind::Firing),
            "{evs:?}"
        );
        let firing_ts = evs
            .iter()
            .find(|e| e.kind == AlertKind::Firing)
            .unwrap()
            .ts_ms;
        // recovery: meets again; burn decays to zero and the alert
        // resolves after the clear duration
        for t in 25..60 {
            o.request_finished(SloClass::Interactive, 1.0, None);
            o.maybe_scrape(t as f64 * 10.0);
        }
        let evs = o.events();
        let resolved = evs
            .iter()
            .find(|e| e.kind == AlertKind::Resolved)
            .expect("alert resolved after recovery");
        assert!(resolved.ts_ms > firing_ts);
        // the derived burn series exists and the health snapshot sees
        // a calm fleet again
        assert!(!o
            .series_points(BURN_FAST, Some(SloClass::Interactive))
            .is_empty());
        let h = o.health(600.0, None, None);
        assert_eq!(h.firing, 0);
        assert!(h.transitions >= 3);
    }

    #[test]
    fn replica_clones_share_one_hub() {
        let o = Obs::new(ObsConfig::standard(SloSpec::chatbot()));
        let r1 = o.for_replica(1);
        o.counter_add("tokens_emitted", None, 3.0);
        r1.counter_add("tokens_emitted", None, 9.0);
        o.scrape_now(5.0);
        let pts = o.series_points("tokens_emitted", None);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].value, 12.0);
        // skew sees the imbalance: max 9, mean 6 -> 0.5
        let h = o.health(5.0, None, None);
        assert!((h.replica_skew - 0.5).abs() < 1e-12);
    }
}
