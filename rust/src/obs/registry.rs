//! Typed metric primitives: monotonic counters, gauges, and log2-
//! bucket histograms with quantile estimation.
//!
//! The registry is a [`BTreeMap`] keyed by `(name, class, replica)`
//! ([`MetricKey`]), so iteration order -- and therefore every scrape,
//! export, and Prometheus dump built on it -- is deterministic by
//! construction.  Names are `&'static str` (same discipline as
//! [`TraceEvent::name`](crate::telemetry::TraceEvent)): the metric
//! namespace is closed at compile time, no per-emit allocation.

use std::collections::BTreeMap;

use crate::sched::SloClass;

/// Log2-bucket histogram: values land in geometric buckets
/// `(2^(i-1), 2^i]`, so any estimated quantile is within a factor of
/// two of the exact sample quantile (the bucket's bound ratio) --
/// `tests/obs.rs` property-checks this against the exact
/// [`Percentiles`](crate::Percentiles) on random samples.
///
/// 64 buckets cover `(2^-32, 2^32]` ms/bytes/counts; zero and
/// negative observations land in a dedicated underflow bucket, values
/// past the top saturate into the last bucket (`max` stays exact).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: [u64; Self::BUCKETS],
    /// observations `<= 0` (quantile representative: 0)
    zero: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; Self::BUCKETS],
            zero: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    const BUCKETS: usize = 64;
    /// bucket 0 covers `(2^(MIN_EXP-1), 2^MIN_EXP]`
    const MIN_EXP: i32 = -31;

    /// Bucket index for a positive value (None for `v <= 0`).
    fn bucket(v: f64) -> Option<usize> {
        if !(v > 0.0) {
            return None;
        }
        // smallest i with v <= 2^i
        let exp = v.log2().ceil() as i32;
        let i = (exp - Self::MIN_EXP).clamp(0, Self::BUCKETS as i32 - 1);
        Some(i as usize)
    }

    /// Upper bound of bucket `i` -- the quantile representative (so
    /// estimates never undershoot the exact sample quantile).
    fn bucket_bound(i: usize) -> f64 {
        (2.0f64).powi(i as i32 + Self::MIN_EXP)
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        match Self::bucket(v) {
            Some(i) => self.counts[i] += 1,
            None => self.zero += 1,
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0 {
            self.sum / self.count as f64
        } else {
            0.0
        }
    }

    /// Exact maximum observed (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count > 0 {
            self.max
        } else {
            0.0
        }
    }

    /// Estimated quantile `q` in `[0, 1]` by nearest rank over the
    /// buckets (the same `ceil(n * q)` rank rule
    /// [`Percentiles`](crate::Percentiles) uses), answering with the
    /// holding bucket's upper bound clamped to the exact max.  For a
    /// rank-`r` sample `s` this gives an estimate in `[s, 2s)`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((self.count as f64 * q).ceil() as u64).max(1);
        let mut cum = self.zero;
        if cum >= rank {
            return 0.0;
        }
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_bound(i).min(self.max);
            }
        }
        self.max
    }
}

/// One registered metric.  Counters are monotonic (negative deltas are
/// clamped); gauges hold the latest sample; histograms accumulate a
/// distribution.
#[derive(Debug, Clone, PartialEq)]
pub enum Metric {
    Counter(f64),
    Gauge(f64),
    Histogram(Histogram),
}

impl Metric {
    /// Prometheus type label.
    pub fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }

    /// The scalar a scrape samples: cumulative value for counters,
    /// current value for gauges, p95 estimate for histograms.
    pub fn scrape_value(&self) -> f64 {
        match self {
            Metric::Counter(v) | Metric::Gauge(v) => *v,
            Metric::Histogram(h) => h.quantile(0.95),
        }
    }
}

/// Registry key: `(name, class, replica)`.  The `Ord` derive (name
/// first, then tier, then replica) fixes iteration order everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    pub name: &'static str,
    /// SLO tier the sample is attributed to (None = engine-wide)
    pub class: Option<SloClass>,
    pub replica: u32,
}

/// The typed metrics registry: one [`Metric`] per [`MetricKey`],
/// created on first emit.  Type conflicts on a name are a programmer
/// error and panic in debug builds; release builds keep the first
/// registration (emits of the wrong type are dropped).
#[derive(Debug, Default)]
pub struct Registry {
    metrics: BTreeMap<MetricKey, Metric>,
}

impl Registry {
    /// Add `v` (clamped at 0) to a monotonic counter.
    pub fn counter_add(&mut self, key: MetricKey, v: f64) {
        let m = self
            .metrics
            .entry(key)
            .or_insert(Metric::Counter(0.0));
        match m {
            Metric::Counter(c) => *c += v.max(0.0),
            _ => debug_assert!(false, "{} is not a counter", key.name),
        }
    }

    /// Set a gauge to its latest sample.
    pub fn gauge_set(&mut self, key: MetricKey, v: f64) {
        let m = self.metrics.entry(key).or_insert(Metric::Gauge(0.0));
        match m {
            Metric::Gauge(g) => *g = v,
            _ => debug_assert!(false, "{} is not a gauge", key.name),
        }
    }

    /// Record one histogram observation.
    pub fn observe(&mut self, key: MetricKey, v: f64) {
        let m = self
            .metrics
            .entry(key)
            .or_insert_with(|| Metric::Histogram(Histogram::default()));
        match m {
            Metric::Histogram(h) => h.observe(v),
            _ => debug_assert!(false, "{} is not a histogram", key.name),
        }
    }

    /// Deterministic (sorted-key) iteration over every metric.
    pub fn iter(&self) -> impl Iterator<Item = (&MetricKey, &Metric)> {
        self.metrics.iter()
    }

    /// One metric's current state.
    pub fn get(&self, key: &MetricKey) -> Option<&Metric> {
        self.metrics.get(key)
    }

    /// Counter value summed across replicas for `(name, class)` --
    /// the fleet-merged scalar.
    pub fn fleet_counter(
        &self,
        name: &'static str,
        class: Option<SloClass>,
    ) -> f64 {
        self.metrics
            .iter()
            .filter(|(k, _)| k.name == name && k.class == class)
            .map(|(_, m)| match m {
                Metric::Counter(v) => *v,
                _ => 0.0,
            })
            .sum()
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(name: &'static str) -> MetricKey {
        MetricKey { name, class: None, replica: 0 }
    }

    #[test]
    fn counters_are_monotonic_and_gauges_latch_last() {
        let mut r = Registry::default();
        r.counter_add(key("done"), 2.0);
        r.counter_add(key("done"), 3.0);
        r.counter_add(key("done"), -5.0); // clamped
        assert_eq!(r.get(&key("done")), Some(&Metric::Counter(5.0)));
        r.gauge_set(key("depth"), 7.0);
        r.gauge_set(key("depth"), 4.0);
        assert_eq!(r.get(&key("depth")), Some(&Metric::Gauge(4.0)));
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn histogram_quantiles_bound_exact_ranks() {
        let mut h = Histogram::default();
        for v in 1..=1000 {
            h.observe(v as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
        assert_eq!(h.max(), 1000.0);
        // exact p50 is 500; the log2 estimate must sit in [500, 1000)
        let p50 = h.quantile(0.5);
        assert!((500.0..1000.0).contains(&p50), "{p50}");
        // p100 clamps to the exact max
        assert_eq!(h.quantile(1.0), 1000.0);
        // empty histogram answers zeros
        let e = Histogram::default();
        assert_eq!(e.quantile(0.5), 0.0);
        assert_eq!(e.max(), 0.0);
    }

    #[test]
    fn histogram_zero_and_saturation_buckets() {
        let mut h = Histogram::default();
        h.observe(0.0);
        h.observe(-3.0);
        h.observe(1e40); // saturates into the top bucket
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.5), 0.0); // rank 2 of 3 is a zero
        // the top-bucket estimate clamps to the exact max
        assert_eq!(h.quantile(1.0), 1e40);
        h.observe(f64::NAN); // ignored
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn iteration_is_sorted_by_key() {
        let mut r = Registry::default();
        r.counter_add(
            MetricKey { name: "b", class: None, replica: 1 },
            1.0,
        );
        r.counter_add(
            MetricKey { name: "a", class: None, replica: 0 },
            1.0,
        );
        r.counter_add(
            MetricKey {
                name: "a",
                class: Some(SloClass::Interactive),
                replica: 0,
            },
            1.0,
        );
        let names: Vec<(&str, Option<SloClass>, u32)> = r
            .iter()
            .map(|(k, _)| (k.name, k.class, k.replica))
            .collect();
        assert_eq!(
            names,
            vec![
                ("a", None, 0),
                ("a", Some(SloClass::Interactive), 0),
                ("b", None, 1),
            ]
        );
        // fleet merge sums across replicas
        r.counter_add(
            MetricKey { name: "b", class: None, replica: 3 },
            4.0,
        );
        assert_eq!(r.fleet_counter("b", None), 5.0);
    }
}
