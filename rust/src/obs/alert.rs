//! SLO burn-rate alerting on virtual time: multi-window rules over
//! per-tier miss counters, with a hysteresis state machine
//! (inactive -> pending -> firing -> resolved) that cannot flap on
//! boundary noise.
//!
//! Burn rate is the SRE error-budget formulation: over a trailing
//! window `W`, `burn = (Δmiss / Δtotal) / error_budget`, where the
//! error budget is `1 - attainment_target` for the tier.  Burn 1.0
//! means the tier is consuming its budget exactly at the sustainable
//! rate; the default rules fire at 2x.  A rule goes *pending* when the
//! fast window breaches (quick detection), *firing* only when the slow
//! window confirms (burst immunity), and *resolves* only after the
//! fast-window burn has stayed below a lower resolve threshold for a
//! clear duration (hysteresis: `resolve_burn < fire_burn`, so samples
//! oscillating around the fire threshold cannot toggle the state).

use crate::sched::SloClass;

use super::series::Point;

/// One multi-window burn-rate rule for one SLO tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlertRule {
    /// stable rule name (trace/event label)
    pub name: &'static str,
    /// tier whose miss counters this rule watches
    pub class: SloClass,
    /// fast detection window (engine-clock ms)
    pub fast_ms: f64,
    /// slow confirmation window (engine-clock ms)
    pub slow_ms: f64,
    /// error budget = `1 - attainment_target` for the tier
    pub error_budget: f64,
    /// burn threshold: pending on fast-window breach, firing when the
    /// slow window agrees
    pub fire_burn: f64,
    /// hysteresis floor -- the fast-window burn must stay *below* this
    /// (strictly lower than `fire_burn`) before resolution can start
    pub resolve_burn: f64,
    /// how long the burn must stay below `resolve_burn` to resolve
    pub clear_ms: f64,
}

impl AlertRule {
    /// The standard burn-rate rule for a tier: budget from
    /// [`SloClass::attainment_target`], fire at 2x burn, resolve below
    /// 1x sustained for one fast window.
    pub fn burn(class: SloClass, fast_ms: f64, slow_ms: f64) -> Self {
        AlertRule {
            name: "slo-burn",
            class,
            fast_ms: fast_ms.max(1e-6),
            slow_ms: slow_ms.max(fast_ms),
            error_budget: (1.0 - class.attainment_target()).max(1e-6),
            fire_burn: 2.0,
            resolve_burn: 1.0,
            clear_ms: fast_ms.max(1e-6),
        }
    }
}

/// Alert lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertState {
    Inactive,
    /// fast window breached; waiting for the slow window to confirm
    Pending,
    Firing,
}

/// A state transition the engine recorded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertKind {
    Pending,
    Firing,
    Resolved,
}

impl AlertKind {
    /// Stable trace instant name (`telemetry` event schema).
    pub fn event_name(self) -> &'static str {
        match self {
            AlertKind::Pending => "alert:pending",
            AlertKind::Firing => "alert:firing",
            AlertKind::Resolved => "alert:resolved",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AlertKind::Pending => "pending",
            AlertKind::Firing => "firing",
            AlertKind::Resolved => "resolved",
        }
    }
}

/// One typed alert transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlertEvent {
    pub rule: &'static str,
    pub class: SloClass,
    pub kind: AlertKind,
    /// engine-clock time of the evaluation that transitioned
    pub ts_ms: f64,
    /// fast-window burn at the transition
    pub burn: f64,
}

/// Windowed burn rate over cumulative (total, miss) counter series at
/// `now`: the miss fraction of requests finishing in `[now - window,
/// now]`, divided by the error budget.  Windows with no finished
/// requests burn nothing (no data is not an outage signal).
pub fn windowed_burn(
    total: &[Point],
    miss: &[Point],
    now_ms: f64,
    window_ms: f64,
    error_budget: f64,
) -> f64 {
    let at = |pts: &[Point], ts: f64| -> f64 {
        pts.iter()
            .rev()
            .find(|p| p.ts_ms <= ts + 1e-9)
            .map(|p| p.value)
            .unwrap_or(0.0)
    };
    let t0 = now_ms - window_ms;
    let d_total = at(total, now_ms) - at(total, t0);
    if d_total <= 0.0 {
        return 0.0;
    }
    let d_miss = (at(miss, now_ms) - at(miss, t0)).max(0.0);
    (d_miss / d_total) / error_budget.max(1e-9)
}

/// Per-rule evaluator: the state machine plus its hysteresis clock.
#[derive(Debug, Clone)]
pub struct RuleEval {
    pub rule: AlertRule,
    state: AlertState,
    /// when the fast-window burn last dropped below `resolve_burn`
    /// (None = currently at or above it)
    below_since_ms: Option<f64>,
}

impl RuleEval {
    pub fn new(rule: AlertRule) -> Self {
        RuleEval { rule, state: AlertState::Inactive, below_since_ms: None }
    }

    pub fn state(&self) -> AlertState {
        self.state
    }

    /// Evaluate one scrape tick.  At most one transition per tick
    /// (pending and firing are distinct ticks, so the timeline always
    /// shows the pending phase).  Returns the transition, if any.
    pub fn eval(
        &mut self,
        now_ms: f64,
        burn_fast: f64,
        burn_slow: f64,
    ) -> Option<AlertEvent> {
        // hysteresis clock: track how long the fast burn has stayed
        // below the resolve floor
        if burn_fast < self.rule.resolve_burn {
            self.below_since_ms.get_or_insert(now_ms);
        } else {
            self.below_since_ms = None;
        }
        let cleared = self
            .below_since_ms
            .is_some_and(|t| now_ms - t + 1e-9 >= self.rule.clear_ms);
        let kind = match self.state {
            AlertState::Inactive => {
                if burn_fast >= self.rule.fire_burn {
                    self.state = AlertState::Pending;
                    Some(AlertKind::Pending)
                } else {
                    None
                }
            }
            AlertState::Pending => {
                if burn_fast >= self.rule.fire_burn
                    && burn_slow >= self.rule.fire_burn
                {
                    self.state = AlertState::Firing;
                    Some(AlertKind::Firing)
                } else if cleared {
                    // a pending that fizzled goes back quietly -- only
                    // a firing alert resolves audibly
                    self.state = AlertState::Inactive;
                    None
                } else {
                    None
                }
            }
            AlertState::Firing => {
                if cleared {
                    self.state = AlertState::Inactive;
                    self.below_since_ms = None;
                    Some(AlertKind::Resolved)
                } else {
                    None
                }
            }
        };
        kind.map(|k| AlertEvent {
            rule: self.rule.name,
            class: self.rule.class,
            kind: k,
            ts_ms: now_ms,
            burn: burn_fast,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(v: &[(f64, f64)]) -> Vec<Point> {
        v.iter().map(|&(ts_ms, value)| Point { ts_ms, value }).collect()
    }

    #[test]
    fn windowed_burn_is_a_delta_ratio() {
        let total = pts(&[(0.0, 10.0), (50.0, 20.0), (100.0, 40.0)]);
        let miss = pts(&[(0.0, 0.0), (50.0, 1.0), (100.0, 11.0)]);
        // window [50, 100]: 20 finished, 10 missed, budget 0.05
        let b = windowed_burn(&total, &miss, 100.0, 50.0, 0.05);
        assert!((b - (10.0 / 20.0) / 0.05).abs() < 1e-9);
        // empty window burns nothing
        assert_eq!(windowed_burn(&total, &miss, 200.0, 10.0, 0.05), 0.0);
        assert_eq!(windowed_burn(&[], &[], 100.0, 50.0, 0.05), 0.0);
    }

    fn rule() -> AlertRule {
        AlertRule {
            name: "slo-burn",
            class: SloClass::Interactive,
            fast_ms: 100.0,
            slow_ms: 400.0,
            error_budget: 0.05,
            fire_burn: 2.0,
            resolve_burn: 1.0,
            clear_ms: 100.0,
        }
    }

    #[test]
    fn pending_then_firing_then_resolved() {
        let mut e = RuleEval::new(rule());
        assert_eq!(e.eval(0.0, 0.0, 0.0), None);
        // fast breach -> pending
        let p = e.eval(10.0, 5.0, 1.0).unwrap();
        assert_eq!(p.kind, AlertKind::Pending);
        assert_eq!(e.state(), AlertState::Pending);
        // slow confirms -> firing (a distinct tick)
        let f = e.eval(20.0, 5.0, 3.0).unwrap();
        assert_eq!(f.kind, AlertKind::Firing);
        // still burning: no transition
        assert_eq!(e.eval(30.0, 4.0, 3.0), None);
        // burn drops below the resolve floor but must *stay* there
        assert_eq!(e.eval(40.0, 0.5, 3.0), None);
        assert_eq!(e.eval(90.0, 0.5, 2.0), None);
        let r = e.eval(140.0, 0.2, 1.0).unwrap();
        assert_eq!(r.kind, AlertKind::Resolved);
        assert_eq!(e.state(), AlertState::Inactive);
    }

    #[test]
    fn boundary_noise_does_not_flap() {
        let mut e = RuleEval::new(rule());
        e.eval(0.0, 5.0, 5.0);
        e.eval(10.0, 5.0, 5.0);
        assert_eq!(e.state(), AlertState::Firing);
        // oscillate just around the fire threshold: always above the
        // resolve floor, so the alert must stay firing with zero
        // transitions
        let mut t = 20.0;
        for i in 0..50 {
            let burn = if i % 2 == 0 { 1.9 } else { 2.1 };
            assert_eq!(e.eval(t, burn, burn), None, "tick {i}");
            assert_eq!(e.state(), AlertState::Firing);
            t += 10.0;
        }
        // a dip below resolve_burn shorter than clear_ms doesn't
        // resolve either
        assert_eq!(e.eval(t, 0.5, 1.0), None);
        assert_eq!(e.eval(t + 50.0, 1.5, 1.0), None);
        assert_eq!(e.state(), AlertState::Firing);
        // only a sustained clear resolves -- exactly one transition
        assert_eq!(e.eval(t + 100.0, 0.5, 0.5), None);
        let r = e.eval(t + 210.0, 0.5, 0.5).unwrap();
        assert_eq!(r.kind, AlertKind::Resolved);
    }

    #[test]
    fn pending_fizzle_is_silent() {
        let mut e = RuleEval::new(rule());
        let p = e.eval(0.0, 3.0, 0.5).unwrap();
        assert_eq!(p.kind, AlertKind::Pending);
        // burn collapses before the slow window confirms: back to
        // inactive with no resolved event (it never fired)
        assert_eq!(e.eval(10.0, 0.1, 0.5), None);
        assert_eq!(e.eval(120.0, 0.1, 0.5), None);
        assert_eq!(e.state(), AlertState::Inactive);
        // and it can go pending again later
        assert!(e.eval(200.0, 3.0, 0.5).is_some());
    }
}
