//! Fleet health snapshot: worst-tier burn rate, saturation headroom,
//! and per-replica skew, distilled from the metrics hub.
//!
//! This is the sensor the ROADMAP's planned autoscaler acts on:
//! `worst_burn > 1` means some tier is spending its error budget
//! faster than sustainable (add capacity), `headroom` says how much
//! modeled throughput is left before the fleet saturates, and
//! `replica_skew` says whether the router is the problem instead.

use crate::sched::SloClass;

/// One tier's burn/attainment line in a [`HealthReport`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierHealth {
    pub class: SloClass,
    /// requests judged (fleet-wide, cumulative)
    pub total: f64,
    /// SLO misses (fleet-wide, cumulative)
    pub missed: f64,
    /// cumulative attainment `1 - missed / total` (1.0 when idle)
    pub attainment: f64,
    /// fast-window burn at the last evaluation
    pub burn: f64,
}

/// Fleet health snapshot at one evaluation instant.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthReport {
    /// engine-clock time of the snapshot
    pub ts_ms: f64,
    /// per-tier lines, highest priority first (present tiers only)
    pub tiers: Vec<TierHealth>,
    /// tier with the highest current burn (None when no tier has
    /// judged a request yet)
    pub worst_class: Option<SloClass>,
    /// that tier's fast-window burn rate
    pub worst_burn: f64,
    /// `1 - throughput / modeled saturation` (None when the modeled
    /// peak is unknown); negative means past saturation
    pub saturation_headroom: Option<f64>,
    /// per-replica decode-token imbalance: `max / mean - 1` over
    /// per-replica token counters (0.0 for a single replica or a
    /// perfectly balanced fleet)
    pub replica_skew: f64,
    /// alerts currently in the firing state
    pub firing: usize,
    /// pending -> firing -> resolved transitions recorded so far
    pub transitions: usize,
}

impl HealthReport {
    /// Render as stable one-line-per-fact text (CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "health @ {:.1} ms: {} firing, {} transitions\n",
            self.ts_ms, self.firing, self.transitions
        ));
        for t in &self.tiers {
            out.push_str(&format!(
                "  {:<12} attainment {:.3} ({} / {} met), burn {:.2}\n",
                t.class.name(),
                t.attainment,
                (t.total - t.missed) as u64,
                t.total as u64,
                t.burn
            ));
        }
        match self.worst_class {
            Some(c) => out.push_str(&format!(
                "  worst tier: {} (burn {:.2})\n",
                c.name(),
                self.worst_burn
            )),
            None => out.push_str("  worst tier: none (no traffic judged)\n"),
        }
        if let Some(h) = self.saturation_headroom {
            out.push_str(&format!(
                "  saturation headroom: {:.1}%\n",
                h * 100.0
            ));
        }
        out.push_str(&format!(
            "  replica skew: {:.3}\n",
            self.replica_skew
        ));
        out
    }
}

/// `max / mean - 1` over per-replica load shares (0 when `<= 1`
/// replica reported or all shares are zero).
pub fn skew(shares: &[f64]) -> f64 {
    if shares.len() < 2 {
        return 0.0;
    }
    let sum: f64 = shares.iter().sum();
    if sum <= 0.0 {
        return 0.0;
    }
    let mean = sum / shares.len() as f64;
    let max = shares.iter().fold(0.0f64, |a, &b| a.max(b));
    (max / mean - 1.0).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_is_max_over_mean() {
        assert_eq!(skew(&[]), 0.0);
        assert_eq!(skew(&[5.0]), 0.0);
        assert_eq!(skew(&[2.0, 2.0, 2.0]), 0.0);
        // max 6, mean 3 -> skew 1.0
        assert!((skew(&[6.0, 3.0, 0.0]) - 1.0).abs() < 1e-12);
        assert_eq!(skew(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn render_is_stable_text() {
        let r = HealthReport {
            ts_ms: 123.0,
            tiers: vec![TierHealth {
                class: SloClass::Interactive,
                total: 10.0,
                missed: 2.0,
                attainment: 0.8,
                burn: 4.0,
            }],
            worst_class: Some(SloClass::Interactive),
            worst_burn: 4.0,
            saturation_headroom: Some(0.25),
            replica_skew: 0.0,
            firing: 1,
            transitions: 2,
        };
        let s = r.render();
        assert!(s.contains("health @ 123.0 ms: 1 firing"));
        assert!(s.contains("interactive   attainment 0.800 (8 / 10 met)"));
        assert!(s.contains("worst tier: interactive (burn 4.00)"));
        assert!(s.contains("saturation headroom: 25.0%"));
        assert_eq!(s, r.render());
    }
}
