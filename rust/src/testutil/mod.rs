//! Minimal property-testing framework (proptest substitute -- the
//! offline vendored crate set has no proptest, see DESIGN.md).
//!
//! Seeded xoshiro-style generator + a `prop` runner that reports the
//! failing case number/seed so failures reproduce deterministically.

pub mod prop;

pub use prop::{Rng, Runner};
