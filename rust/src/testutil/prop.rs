//! Seeded RNG + property runner.

/// splitmix64-seeded xorshift64* -- deterministic, fast, good enough
/// for test-case generation.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut z = seed.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        Rng(z ^ (z >> 31) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545f4914f6cdd1d)
    }

    /// uniform in [0, 1)
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// uniform in [0, 1) with 53-bit resolution
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// uniform in [lo, hi)
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// roughly standard normal (sum of 12 uniforms)
    pub fn normal(&mut self) -> f32 {
        (0..12).map(|_| self.f32()).sum::<f32>() - 6.0
    }

    /// roughly standard normal in f64 (sum of 12 uniforms)
    pub fn normal_f64(&mut self) -> f64 {
        (0..12).map(|_| self.f64()).sum::<f64>() - 6.0
    }

    /// exponential with the given mean (> 0): inter-arrival gaps of a
    /// Poisson process
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// log-normal with ln-space location `mu` and scale `sigma`
    /// (median = e^mu): request prompt/output length mixes
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal_f64()).exp()
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range_f32(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }
}

/// Runs a property `cases` times with derived seeds; panics with the
/// case index + seed on first failure.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Runner {
    fn default() -> Self {
        Runner { cases: 64, seed: 0x5eed_2026 }
    }
}

impl Runner {
    pub fn new(cases: usize) -> Self {
        Runner { cases, ..Default::default() }
    }

    pub fn run<F: FnMut(&mut Rng)>(&self, mut f: F) {
        for i in 0..self.cases {
            let seed = self.seed.wrapping_add(i as u64 * 0x9e3779b9);
            let mut rng = Rng::new(seed);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || f(&mut rng),
            ));
            if let Err(e) = r {
                eprintln!("property failed at case {i} (seed {seed:#x})");
                std::panic::resume_unwind(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        Runner::new(32).run(|r| {
            let v = r.range_f32(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let u = r.usize(5, 9);
            assert!((5..9).contains(&u));
        });
    }

    #[test]
    fn exp_and_lognormal_have_the_right_shape() {
        let mut r = Rng::new(11);
        let n = 20000;
        let mean = (0..n).map(|_| r.exp(40.0)).sum::<f64>() / n as f64;
        assert!((mean / 40.0 - 1.0).abs() < 0.05, "{mean}");
        // all draws positive and finite
        for _ in 0..1000 {
            let e = r.exp(2.0);
            assert!(e.is_finite() && e >= 0.0, "{e}");
            let l = r.lognormal(3.0, 0.5);
            assert!(l.is_finite() && l > 0.0, "{l}");
        }
        // log-normal median ~ e^mu
        let mut xs: Vec<f64> =
            (0..4001).map(|_| r.lognormal(3.0, 0.8)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let med = xs[2000];
        assert!((med / 3.0f64.exp() - 1.0).abs() < 0.15, "{med}");
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }
}
