//! Structured tracing across the serving stack: request-span events,
//! NPU/PIM/bus device timelines, Perfetto export, and a flight
//! recorder -- zero-cost when disabled.
//!
//! The stack's terminal aggregates ([`Metrics`](crate::Metrics),
//! [`LoadReport`](crate::LoadReport)) say *how much* time went where;
//! this layer says *where it went*: every request's journey (enqueue
//! -> admit/bounce -> prefill tiles -> decode steps -> preempt/restore
//! -> retire) and every device lane's occupancy (NPU, PIM, DRAM bus)
//! as timestamped events on the engine clock (simulated ms for the sim
//! backend, wall ms for PJRT).
//!
//! The [`Trace`] handle is the whole integration surface: a cheap
//! cloneable reference to a shared [`TraceSink`] plus a replica tag.
//! A disabled handle ([`Trace::off`], the default everywhere) makes
//! every emit a no-op branch, so untraced runs stay bit-identical --
//! `ci.sh` proves this by diffing `loadtest --smoke` output.  Enable
//! with [`Trace::ring`] and thread the handle through
//! [`EngineBuilder::telemetry`](crate::EngineBuilder::telemetry) (or
//! [`Engine::set_trace`](crate::Engine::set_trace)); a cluster gives
//! each replica a [`Trace::for_replica`] clone of one shared sink, so
//! fleet events merge by construction.
//!
//! Exporters live in the submodules: [`export`] (Chrome trace-event
//! JSON, loadable in Perfetto), [`summary`] (busy%, idle gaps, and the
//! NPU/PIM overlap factor ROADMAP item 1 is gated on), and [`flight`]
//! (last-N-events dump for requests that miss their SLO or die in an
//! error path).  `p3llm trace` drives all three from the CLI.
//!
//! ```
//! use p3llm::telemetry::Trace;
//! use p3llm::EngineBuilder;
//! # fn main() -> p3llm::Result<()> {
//! let trace = Trace::ring(4096);
//! let mut eng = EngineBuilder::sim()
//!     .model("tiny-1M")
//!     .max_batch(2)
//!     .ctx_limit(128)
//!     .telemetry(trace.clone())
//!     .build()?;
//! eng.submit(vec![1, 2, 3], 4)?;
//! eng.run_to_completion()?;
//! let events = trace.snapshot();
//! assert!(events.iter().any(|e| e.name == "retire"));
//! # Ok(())
//! # }
//! ```

pub mod export;
pub mod flight;
pub mod summary;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::sched::SloClass;

/// Which timeline an event lives on.  `Host` carries the request
/// lifecycle and engine-level spans; the other three are the device
/// occupancy tracks the sim backend emits per operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TraceLane {
    /// engine/request lifecycle (enqueue, admit, prefill, retire, ...)
    Host,
    /// NPU compute occupancy (prefill tiles, NPU-mapped decode ops)
    Npu,
    /// PIM compute occupancy (PIM-mapped decode ops)
    Pim,
    /// DRAM/external-bus transfers (PIM result return, KV install,
    /// swap restore)
    Bus,
    /// CXL link occupancy of the tiered KV hierarchy (ahead-of-decode
    /// prefetches and demand page migrations between HBM and the cold
    /// pool)
    Cxl,
}

impl TraceLane {
    /// Stable lower-case lane name (track labels, JSON categories).
    pub fn name(self) -> &'static str {
        match self {
            TraceLane::Host => "host",
            TraceLane::Npu => "npu",
            TraceLane::Pim => "pim",
            TraceLane::Bus => "bus",
            TraceLane::Cxl => "cxl",
        }
    }

    /// Stable small index (Chrome trace `tid` for device tracks).
    pub fn index(self) -> u32 {
        match self {
            TraceLane::Host => 0,
            TraceLane::Npu => 1,
            TraceLane::Pim => 2,
            TraceLane::Bus => 3,
            TraceLane::Cxl => 4,
        }
    }
}

/// Event shape: a duration span, a point-in-time marker, or a sampled
/// counter value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// `[ts_ms, ts_ms + dur_ms]` occupancy on a lane
    Span,
    /// point event (`dur_ms` is 0)
    Instant,
    /// sampled value (`value` holds the sample; `dur_ms` is 0)
    Counter,
}

/// One structured trace event.  `seq` is the sink-assigned emission
/// order -- the deterministic tiebreak for equal timestamps and the
/// key exporters sort by.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// emission order within the sink (assigned by [`TraceSink::record`])
    pub seq: u64,
    /// start time on the engine clock (ms)
    pub ts_ms: f64,
    /// span duration (0 for instants and counters)
    pub dur_ms: f64,
    pub kind: EventKind,
    pub lane: TraceLane,
    /// stable event name (see the DESIGN.md event-schema table)
    pub name: &'static str,
    /// request the event belongs to (None for device/engine events).
    /// Request ids are per-replica counters: the cross-replica key is
    /// `(replica, rid)`.
    pub rid: Option<u64>,
    /// SLO tier of the request (when known)
    pub class: Option<SloClass>,
    /// replica tag ([`Trace::for_replica`]; 0 for a single engine)
    pub replica: u32,
    /// event payload: tokens for prefill/hit events, pages for
    /// preemptions, batch size for decode steps, the sample for
    /// counters, bytes for transfers
    pub value: f64,
}

/// Destination for trace events.  Implementations must assign `seq`
/// in [`record`](TraceSink::record) and may bound retention (dropping
/// *oldest* first) -- the bundled [`RingSink`] does both.
pub trait TraceSink {
    /// Append one event, stamping its `seq`.
    fn record(&mut self, ev: TraceEvent);
    /// Retained events, oldest first.
    fn snapshot(&self) -> Vec<TraceEvent>;
    /// Events discarded so far to stay within the retention bound.
    fn dropped(&self) -> usize;
}

/// Bounded ring-buffer sink: keeps the newest `cap` events, counts
/// what it dropped.  The drop-oldest policy is what makes the flight
/// recorder work on long runs -- the tail of every request's history
/// survives.
#[derive(Debug)]
pub struct RingSink {
    cap: usize,
    buf: VecDeque<TraceEvent>,
    dropped: usize,
    next_seq: u64,
}

impl RingSink {
    /// `cap` >= 1 retained events (0 is clamped to 1).
    pub fn new(cap: usize) -> Self {
        RingSink {
            cap: cap.max(1),
            buf: VecDeque::new(),
            dropped: 0,
            next_seq: 0,
        }
    }

    /// Retention bound this ring was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

impl TraceSink for RingSink {
    fn record(&mut self, mut ev: TraceEvent) {
        ev.seq = self.next_seq;
        self.next_seq += 1;
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    fn snapshot(&self) -> Vec<TraceEvent> {
        self.buf.iter().copied().collect()
    }

    fn dropped(&self) -> usize {
        self.dropped
    }
}

/// Cheap cloneable tracing handle: a shared [`TraceSink`] plus the
/// replica tag stamped on every event this clone emits.  The default
/// ([`Trace::off`]) is disabled -- every emit returns after one branch
/// and no event is ever constructed, which is the zero-overhead path
/// the whole stack ships with.
#[derive(Clone, Default)]
pub struct Trace {
    sink: Option<Rc<RefCell<Box<dyn TraceSink>>>>,
    replica: u32,
}

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trace")
            .field("enabled", &self.enabled())
            .field("replica", &self.replica)
            .finish()
    }
}

impl Trace {
    /// Disabled handle (the default): emits are no-ops, snapshots are
    /// empty.
    pub fn off() -> Self {
        Trace::default()
    }

    /// Enabled handle over a fresh [`RingSink`] retaining `cap` events.
    pub fn ring(cap: usize) -> Self {
        Trace::with_sink(Box::new(RingSink::new(cap)))
    }

    /// Enabled handle over a caller-provided sink.
    pub fn with_sink(sink: Box<dyn TraceSink>) -> Self {
        Trace { sink: Some(Rc::new(RefCell::new(sink))), replica: 0 }
    }

    /// Is this handle recording?
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Replica tag this handle stamps on its events.
    pub fn replica_id(&self) -> u32 {
        self.replica
    }

    /// Clone sharing the same sink but tagging events with `replica`
    /// -- how a cluster merges per-replica streams into one timeline.
    pub fn for_replica(&self, replica: u32) -> Trace {
        Trace { sink: self.sink.clone(), replica }
    }

    fn record(&self, kind: EventKind, lane: TraceLane, name: &'static str,
        ts_ms: f64, dur_ms: f64, rid: Option<u64>, class: Option<SloClass>,
        value: f64)
    {
        let Some(sink) = &self.sink else { return };
        sink.borrow_mut().record(TraceEvent {
            seq: 0,
            ts_ms,
            dur_ms,
            kind,
            lane,
            name,
            rid,
            class,
            replica: self.replica,
            value,
        });
    }

    /// Emit a `[t0_ms, t1_ms]` occupancy span on `lane`.
    #[allow(clippy::too_many_arguments)]
    pub fn span(&self, lane: TraceLane, name: &'static str, t0_ms: f64,
        t1_ms: f64, rid: Option<u64>, class: Option<SloClass>, value: f64)
    {
        self.record(
            EventKind::Span,
            lane,
            name,
            t0_ms,
            (t1_ms - t0_ms).max(0.0),
            rid,
            class,
            value,
        );
    }

    /// Emit a lifecycle point event (always on the [`TraceLane::Host`]
    /// lane).
    pub fn instant(&self, name: &'static str, ts_ms: f64, rid: Option<u64>,
        class: Option<SloClass>, value: f64)
    {
        self.record(
            EventKind::Instant,
            TraceLane::Host,
            name,
            ts_ms,
            0.0,
            rid,
            class,
            value,
        );
    }

    /// Emit a sampled counter value (host lane, no request).
    pub fn counter(&self, name: &'static str, ts_ms: f64, value: f64) {
        self.record(
            EventKind::Counter,
            TraceLane::Host,
            name,
            ts_ms,
            0.0,
            None,
            None,
            value,
        );
    }

    /// Snapshot of the sink's retained events (empty when disabled).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        match &self.sink {
            Some(s) => s.borrow().snapshot(),
            None => vec![],
        }
    }

    /// Events the sink discarded to stay bounded (0 when disabled).
    pub fn dropped(&self) -> usize {
        match &self.sink {
            Some(s) => s.borrow().dropped(),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let t = Trace::off();
        assert!(!t.enabled());
        t.instant("enqueue", 1.0, Some(1), None, 0.0);
        t.span(TraceLane::Npu, "prefill", 0.0, 2.0, None, None, 0.0);
        t.counter("kv_used_bytes", 3.0, 42.0);
        assert!(t.snapshot().is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_sink_keeps_newest_and_counts_drops() {
        let t = Trace::ring(8);
        for i in 0..100 {
            t.instant("tick", i as f64, None, None, i as f64);
        }
        let evs = t.snapshot();
        assert_eq!(evs.len(), 8);
        assert_eq!(t.dropped(), 92);
        // newest survive, in emission order, with monotone seq
        assert_eq!(evs[0].value, 92.0);
        assert_eq!(evs[7].value, 99.0);
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn replica_clones_share_one_sink() {
        let t = Trace::ring(64);
        let r1 = t.for_replica(1);
        t.instant("enqueue", 0.0, Some(1), None, 0.0);
        r1.instant("enqueue", 0.0, Some(1), None, 0.0);
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].replica, 0);
        assert_eq!(evs[1].replica, 1);
        assert_eq!(r1.snapshot().len(), 2);
    }

    #[test]
    fn span_clamps_negative_durations() {
        let t = Trace::ring(4);
        t.span(TraceLane::Bus, "xfer", 5.0, 4.0, None, None, 0.0);
        assert_eq!(t.snapshot()[0].dur_ms, 0.0);
    }
}
