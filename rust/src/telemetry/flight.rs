//! Flight recorder: reconstruct the last N events of one request's
//! history from a (possibly ring-truncated) trace -- the post-mortem
//! view for requests that missed their SLO or died in an error path.
//!
//! The [`RingSink`](super::RingSink) drops *oldest* events first, so
//! the tail every dump needs is exactly what a bounded sink retains on
//! long runs.

use super::TraceEvent;

/// Last `last_n` events of request `(replica, rid)`, in emission
/// order.  Device-lane events carry no request id and are not
/// attributed; the request's own host-lane history is what dumps.
pub fn flight_dump(
    events: &[TraceEvent],
    replica: u32,
    rid: u64,
    last_n: usize,
) -> Vec<TraceEvent> {
    let mut mine: Vec<TraceEvent> = events
        .iter()
        .filter(|e| e.replica == replica && e.rid == Some(rid))
        .copied()
        .collect();
    mine.sort_by_key(|e| e.seq);
    let skip = mine.len().saturating_sub(last_n);
    mine.split_off(skip)
}

/// Requests that hit an error terminal (`"error"` event) -- always
/// flight-dump candidates, independent of SLO judging.
pub fn error_requests(events: &[TraceEvent]) -> Vec<(u32, u64)> {
    let mut out: Vec<(u32, u64)> = events
        .iter()
        .filter(|e| e.name == "error")
        .filter_map(|e| e.rid.map(|r| (e.replica, r)))
        .collect();
    out.sort();
    out.dedup();
    out
}

/// Requests whose event-derived TTFT (`enqueue` to `first_token`)
/// exceeds `base_ttft_ms` scaled by their tier's
/// [`slo_factor`](crate::sched::SloClass::slo_factor) -- the SLO-miss
/// detector behind `trace --flight-on-miss`.  Returns sorted
/// `(replica, rid, ttft_ms)` triples; requests whose `enqueue` was
/// ring-dropped are skipped (no start time, no verdict).
pub fn ttft_misses(
    events: &[TraceEvent],
    base_ttft_ms: f64,
) -> Vec<(u32, u64, f64)> {
    let mut out = vec![];
    for e in events.iter().filter(|e| e.name == "first_token") {
        let Some(rid) = e.rid else { continue };
        let Some(enq) = events.iter().find(|q| {
            q.name == "enqueue" && q.replica == e.replica && q.rid == e.rid
        }) else {
            continue;
        };
        let ttft = e.ts_ms - enq.ts_ms;
        let budget =
            base_ttft_ms * e.class.map(|c| c.slo_factor()).unwrap_or(1.0);
        if ttft > budget {
            out.push((e.replica, rid, ttft));
        }
    }
    out.sort_by(|a, b| {
        (a.0, a.1).cmp(&(b.0, b.1)).then(a.2.total_cmp(&b.2))
    });
    out.dedup_by_key(|m| (m.0, m.1));
    out
}

/// Render one dump as indented human-readable lines (what the `trace`
/// subcommand prints under `--flight-on-miss`).
pub fn render(events: &[TraceEvent]) -> String {
    events
        .iter()
        .map(|e| {
            let span = if e.dur_ms > 0.0 {
                format!(" +{:.3}ms", e.dur_ms)
            } else {
                String::new()
            };
            let class = e
                .class
                .map(|c| format!(" class={}", c.name()))
                .unwrap_or_default();
            format!(
                "  {:>12.3} ms  {:<16}{span}{class} value={:.1}",
                e.ts_ms, e.name, e.value
            )
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Trace, TraceLane};

    #[test]
    fn dump_keeps_the_tail_in_order() {
        let t = Trace::ring(64);
        t.instant("enqueue", 0.0, Some(7), None, 1.0);
        for i in 0..5 {
            t.instant("token", 1.0 + i as f64, Some(7), None, i as f64);
        }
        t.instant("retire", 9.0, Some(7), None, 5.0);
        t.instant("enqueue", 0.5, Some(8), None, 1.0);
        let d = flight_dump(&t.snapshot(), 0, 7, 3);
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].name, "token");
        assert_eq!(d[2].name, "retire");
        assert!(d.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(flight_dump(&t.snapshot(), 1, 7, 3).is_empty());
    }

    #[test]
    fn error_terminals_are_found() {
        let t = Trace::ring(16);
        t.instant("enqueue", 0.0, Some(3), None, 0.0);
        t.instant("error", 1.0, Some(3), None, 0.0);
        t.for_replica(2).instant("error", 1.0, Some(4), None, 0.0);
        assert_eq!(error_requests(&t.snapshot()), vec![(0, 3), (2, 4)]);
    }

    #[test]
    fn ttft_misses_scale_budgets_by_tier() {
        use crate::sched::SloClass;
        let t = Trace::ring(32);
        // interactive: ttft 5 vs budget 2 -> miss
        t.instant("enqueue", 0.0, Some(1), Some(SloClass::Interactive), 0.0);
        t.instant(
            "first_token",
            5.0,
            Some(1),
            Some(SloClass::Interactive),
            0.0,
        );
        // batch: ttft 5 vs budget 2*4 -> within budget
        t.instant("enqueue", 0.0, Some(2), Some(SloClass::Batch), 0.0);
        t.instant("first_token", 5.0, Some(2), Some(SloClass::Batch), 0.0);
        let misses = ttft_misses(&t.snapshot(), 2.0);
        assert_eq!(misses.len(), 1);
        assert_eq!((misses[0].0, misses[0].1), (0, 1));
        assert!((misses[0].2 - 5.0).abs() < 1e-9);
        // a zero budget flags everyone (the smoke gate's injected miss)
        assert_eq!(ttft_misses(&t.snapshot(), 0.0).len(), 2);
    }

    #[test]
    fn cxl_migrations_attribute_to_their_request() {
        let t = Trace::ring(32);
        t.instant("enqueue", 0.0, Some(5), None, 1.0);
        t.span(TraceLane::Cxl, "prefetch", 1.0, 2.0, Some(5), None, 3.0);
        t.span(
            TraceLane::Cxl,
            "demand_migrate",
            2.0,
            2.5,
            Some(5),
            None,
            1.0,
        );
        // another request's prefetch must not leak into rid 5's dump
        t.span(TraceLane::Cxl, "prefetch", 1.5, 2.5, Some(6), None, 2.0);
        let d = flight_dump(&t.snapshot(), 0, 5, 8);
        let names: Vec<&str> = d.iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["enqueue", "prefetch", "demand_migrate"]);
        let s = render(&d);
        assert!(s.contains("demand_migrate"));
        assert!(s.contains("prefetch"));
    }

    #[test]
    fn render_mentions_names_and_spans() {
        let t = Trace::ring(8);
        t.span(TraceLane::Host, "prefill", 1.0, 3.0, Some(1), None, 4.0);
        let s = render(&t.snapshot());
        assert!(s.contains("prefill"));
        assert!(s.contains("+2.000ms"));
    }
}
