//! Flight recorder: reconstruct the last N events of one request's
//! history from a (possibly ring-truncated) trace -- the post-mortem
//! view for requests that missed their SLO or died in an error path.
//!
//! The [`RingSink`](super::RingSink) drops *oldest* events first, so
//! the tail every dump needs is exactly what a bounded sink retains on
//! long runs.

use super::TraceEvent;

/// Last `last_n` events of request `(replica, rid)`, in emission
/// order.  Device-lane events carry no request id and are not
/// attributed; the request's own host-lane history is what dumps.
pub fn flight_dump(
    events: &[TraceEvent],
    replica: u32,
    rid: u64,
    last_n: usize,
) -> Vec<TraceEvent> {
    let mut mine: Vec<TraceEvent> = events
        .iter()
        .filter(|e| e.replica == replica && e.rid == Some(rid))
        .copied()
        .collect();
    mine.sort_by_key(|e| e.seq);
    let skip = mine.len().saturating_sub(last_n);
    mine.split_off(skip)
}

/// Requests that hit an error terminal (`"error"` event) -- always
/// flight-dump candidates, independent of SLO judging.
pub fn error_requests(events: &[TraceEvent]) -> Vec<(u32, u64)> {
    let mut out: Vec<(u32, u64)> = events
        .iter()
        .filter(|e| e.name == "error")
        .filter_map(|e| e.rid.map(|r| (e.replica, r)))
        .collect();
    out.sort();
    out.dedup();
    out
}

/// Requests whose event-derived TTFT (`enqueue` to `first_token`)
/// exceeds `base_ttft_ms` scaled by their tier's
/// [`slo_factor`](crate::sched::SloClass::slo_factor) -- the SLO-miss
/// detector behind `trace --flight-on-miss`.  Returns sorted
/// `(replica, rid, ttft_ms)` triples; requests whose `enqueue` was
/// ring-dropped are skipped (no start time, no verdict).
pub fn ttft_misses(
    events: &[TraceEvent],
    base_ttft_ms: f64,
) -> Vec<(u32, u64, f64)> {
    let mut out = vec![];
    for e in events.iter().filter(|e| e.name == "first_token") {
        let Some(rid) = e.rid else { continue };
        let Some(enq) = events.iter().find(|q| {
            q.name == "enqueue" && q.replica == e.replica && q.rid == e.rid
        }) else {
            continue;
        };
        let ttft = e.ts_ms - enq.ts_ms;
        let budget =
            base_ttft_ms * e.class.map(|c| c.slo_factor()).unwrap_or(1.0);
        if ttft > budget {
            out.push((e.replica, rid, ttft));
        }
    }
    out.sort_by(|a, b| {
        (a.0, a.1).cmp(&(b.0, b.1)).then(a.2.total_cmp(&b.2))
    });
    out.dedup_by_key(|m| (m.0, m.1));
    out
}

/// `alert:firing` transitions the [`crate::obs`] burn-rate engine
/// recorded into the trace stream, as sorted `(replica, class, ts_ms,
/// burn)` tuples -- each one a flight-dump trigger: an alert firing is
/// exactly the moment the recent history is worth keeping.
pub fn alert_firings(
    events: &[TraceEvent],
) -> Vec<(u32, Option<crate::sched::SloClass>, f64, f64)> {
    let mut out: Vec<_> = events
        .iter()
        .filter(|e| e.name == "alert:firing")
        .map(|e| (e.replica, e.class, e.ts_ms, e.value))
        .collect();
    out.sort_by(|a, b| {
        a.2.total_cmp(&b.2).then((a.0, a.1.map(|c| c.rank())).cmp(&(
            b.0,
            b.1.map(|c| c.rank()),
        )))
    });
    out
}

/// The fleet-wide context around an alert transition: the last
/// `last_n` request-lifecycle events at or before `ts_ms`, in emission
/// order -- what was in flight when the alert fired.  Scraped metric
/// counters (`obs:` names) are excluded; they are the *cause* of the
/// alert and already plotted as counter tracks, while the dump answers
/// "which requests were doing what".
pub fn alert_context_dump(
    events: &[TraceEvent],
    ts_ms: f64,
    last_n: usize,
) -> Vec<TraceEvent> {
    let mut ctx: Vec<TraceEvent> = events
        .iter()
        .filter(|e| {
            e.ts_ms <= ts_ms + 1e-9
                && e.rid.is_some()
                && !e.name.starts_with("obs:")
                && !e.name.starts_with("alert:")
        })
        .copied()
        .collect();
    ctx.sort_by_key(|e| e.seq);
    let skip = ctx.len().saturating_sub(last_n);
    ctx.split_off(skip)
}

/// Render one dump as indented human-readable lines (what the `trace`
/// subcommand prints under `--flight-on-miss`).
pub fn render(events: &[TraceEvent]) -> String {
    events
        .iter()
        .map(|e| {
            let span = if e.dur_ms > 0.0 {
                format!(" +{:.3}ms", e.dur_ms)
            } else {
                String::new()
            };
            let class = e
                .class
                .map(|c| format!(" class={}", c.name()))
                .unwrap_or_default();
            format!(
                "  {:>12.3} ms  {:<16}{span}{class} value={:.1}",
                e.ts_ms, e.name, e.value
            )
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Trace, TraceLane};

    #[test]
    fn dump_keeps_the_tail_in_order() {
        let t = Trace::ring(64);
        t.instant("enqueue", 0.0, Some(7), None, 1.0);
        for i in 0..5 {
            t.instant("token", 1.0 + i as f64, Some(7), None, i as f64);
        }
        t.instant("retire", 9.0, Some(7), None, 5.0);
        t.instant("enqueue", 0.5, Some(8), None, 1.0);
        let d = flight_dump(&t.snapshot(), 0, 7, 3);
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].name, "token");
        assert_eq!(d[2].name, "retire");
        assert!(d.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(flight_dump(&t.snapshot(), 1, 7, 3).is_empty());
    }

    #[test]
    fn error_terminals_are_found() {
        let t = Trace::ring(16);
        t.instant("enqueue", 0.0, Some(3), None, 0.0);
        t.instant("error", 1.0, Some(3), None, 0.0);
        t.for_replica(2).instant("error", 1.0, Some(4), None, 0.0);
        assert_eq!(error_requests(&t.snapshot()), vec![(0, 3), (2, 4)]);
    }

    #[test]
    fn ttft_misses_scale_budgets_by_tier() {
        use crate::sched::SloClass;
        let t = Trace::ring(32);
        // interactive: ttft 5 vs budget 2 -> miss
        t.instant("enqueue", 0.0, Some(1), Some(SloClass::Interactive), 0.0);
        t.instant(
            "first_token",
            5.0,
            Some(1),
            Some(SloClass::Interactive),
            0.0,
        );
        // batch: ttft 5 vs budget 2*4 -> within budget
        t.instant("enqueue", 0.0, Some(2), Some(SloClass::Batch), 0.0);
        t.instant("first_token", 5.0, Some(2), Some(SloClass::Batch), 0.0);
        let misses = ttft_misses(&t.snapshot(), 2.0);
        assert_eq!(misses.len(), 1);
        assert_eq!((misses[0].0, misses[0].1), (0, 1));
        assert!((misses[0].2 - 5.0).abs() < 1e-9);
        // a zero budget flags everyone (the smoke gate's injected miss)
        assert_eq!(ttft_misses(&t.snapshot(), 0.0).len(), 2);
    }

    #[test]
    fn cxl_migrations_attribute_to_their_request() {
        let t = Trace::ring(32);
        t.instant("enqueue", 0.0, Some(5), None, 1.0);
        t.span(TraceLane::Cxl, "prefetch", 1.0, 2.0, Some(5), None, 3.0);
        t.span(
            TraceLane::Cxl,
            "demand_migrate",
            2.0,
            2.5,
            Some(5),
            None,
            1.0,
        );
        // another request's prefetch must not leak into rid 5's dump
        t.span(TraceLane::Cxl, "prefetch", 1.5, 2.5, Some(6), None, 2.0);
        let d = flight_dump(&t.snapshot(), 0, 5, 8);
        let names: Vec<&str> = d.iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["enqueue", "prefetch", "demand_migrate"]);
        let s = render(&d);
        assert!(s.contains("demand_migrate"));
        assert!(s.contains("prefetch"));
    }

    #[test]
    fn alert_firings_and_context_dump() {
        use crate::sched::SloClass;
        let t = Trace::ring(64);
        // the in-flight history an alert should capture
        t.instant("enqueue", 0.0, Some(1), Some(SloClass::Interactive), 1.0);
        t.instant("admit", 1.0, Some(1), Some(SloClass::Interactive), 1.0);
        t.instant("enqueue", 2.0, Some(2), Some(SloClass::Batch), 1.0);
        // scraped counters and the alert instants themselves are noise
        t.counter("obs:queue_depth", 3.0, 7.0);
        t.instant(
            "alert:pending",
            3.0,
            None,
            Some(SloClass::Interactive),
            2.5,
        );
        t.instant(
            "alert:firing",
            4.0,
            None,
            Some(SloClass::Interactive),
            3.5,
        );
        // after the firing instant: must not appear in its context
        t.instant("retire", 5.0, Some(1), Some(SloClass::Interactive), 9.0);
        t.instant("alert:resolved", 8.0, None, Some(SloClass::Interactive), 0.5);
        let evs = t.snapshot();
        let firings = alert_firings(&evs);
        assert_eq!(firings.len(), 1);
        let (rep, class, ts, burn) = firings[0];
        assert_eq!(rep, 0);
        assert_eq!(class, Some(SloClass::Interactive));
        assert!((ts - 4.0).abs() < 1e-9);
        assert!((burn - 3.5).abs() < 1e-9);
        let ctx = alert_context_dump(&evs, ts, 8);
        let names: Vec<&str> = ctx.iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["enqueue", "admit", "enqueue"]);
        // bounded tail: only the newest N survive
        let ctx2 = alert_context_dump(&evs, ts, 2);
        assert_eq!(ctx2.len(), 2);
        assert_eq!(ctx2[0].name, "admit");
    }

    #[test]
    fn render_mentions_names_and_spans() {
        let t = Trace::ring(8);
        t.span(TraceLane::Host, "prefill", 1.0, 3.0, Some(1), None, 4.0);
        let s = render(&t.snapshot());
        assert!(s.contains("prefill"));
        assert!(s.contains("+2.000ms"));
    }
}
