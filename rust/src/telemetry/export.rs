//! Chrome trace-event JSON export (loadable in Perfetto / about:tracing).
//!
//! Track layout: one *process* per replica, with one *thread* per
//! device lane (`host` / `npu` / `pim` / `bus`), one `metrics` thread
//! for scraped [`crate::obs`] counter tracks (counter events whose
//! name carries the `obs:` prefix render as Perfetto counter plots on
//! their own row), plus one thread per sampled request (its host-lane
//! lifecycle events move onto that track, so a request's journey reads
//! as a single row).  Timestamps convert from engine-clock ms to the
//! trace format's microseconds.
//!
//! The output is deterministic: events sort by `(ts, seq)`, floats
//! print with fixed precision, and track metadata is emitted in sorted
//! order -- two same-seed runs export byte-identical JSON (a CI gate).

use std::collections::BTreeSet;

use super::{EventKind, TraceEvent, TraceLane};

/// Thread id of the per-replica `metrics` track `obs:`-prefixed
/// counter events land on (device lanes use 0..4, sampled requests
/// 16+).
pub const METRICS_TID: u32 = 8;

/// Does this event belong on the scraped-metrics counter track?
fn is_obs_counter(e: &TraceEvent) -> bool {
    matches!(e.kind, EventKind::Counter) && e.name.starts_with("obs:")
}

/// First `k` distinct requests by appearance (emission order) -- the
/// default sampling the `trace` subcommand uses for per-request
/// tracks.  Keys are `(replica, rid)`: request ids are per-replica
/// counters, so the pair is the only cross-replica-unique identity.
pub fn sample_requests(events: &[TraceEvent], k: usize) -> Vec<(u32, u64)> {
    let mut seen = BTreeSet::new();
    let mut out = vec![];
    let mut by_seq: Vec<&TraceEvent> = events.iter().collect();
    by_seq.sort_by_key(|e| e.seq);
    for e in by_seq {
        if let Some(rid) = e.rid {
            if out.len() < k && seen.insert((e.replica, rid)) {
                out.push((e.replica, rid));
            }
        }
    }
    out
}

fn push_args(out: &mut String, e: &TraceEvent) {
    out.push_str("\"args\":{");
    let mut first = true;
    let mut field = |out: &mut String, s: String| {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&s);
    };
    if let Some(rid) = e.rid {
        field(out, format!("\"rid\":{rid}"));
    }
    if let Some(c) = e.class {
        field(out, format!("\"class\":\"{}\"", c.name()));
    }
    field(out, format!("\"value\":{:.3}", e.value));
    out.push('}');
}

/// Render `events` as Chrome trace-event JSON.  `sampled` request keys
/// (see [`sample_requests`]) get their own per-request track; every
/// other event lands on its replica x lane track.
pub fn chrome_trace_json(
    events: &[TraceEvent],
    sampled: &[(u32, u64)],
) -> String {
    let req_tid = |replica: u32, rid: u64| -> Option<u32> {
        sampled
            .iter()
            .position(|&(rep, r)| rep == replica && r == rid)
            .map(|i| 16 + i as u32)
    };
    let mut sorted: Vec<&TraceEvent> = events.iter().collect();
    sorted.sort_by(|a, b| {
        a.ts_ms.total_cmp(&b.ts_ms).then(a.seq.cmp(&b.seq))
    });
    // track metadata in deterministic order
    let mut replicas = BTreeSet::new();
    let mut lanes = BTreeSet::new();
    let mut obs_replicas = BTreeSet::new();
    for e in events {
        replicas.insert(e.replica);
        if is_obs_counter(e) {
            obs_replicas.insert(e.replica);
        } else {
            lanes.insert((e.replica, e.lane));
        }
    }
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |out: &mut String, line: String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&line);
    };
    for &rep in &replicas {
        push(
            &mut out,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{rep},\
                 \"tid\":0,\"args\":{{\"name\":\"replica {rep}\"}}}}"
            ),
        );
    }
    for &(rep, lane) in &lanes {
        push(
            &mut out,
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{rep},\
                 \"tid\":{},\"args\":{{\"name\":\"{}\"}}}}",
                lane.index(),
                lane.name()
            ),
        );
    }
    for &rep in &obs_replicas {
        push(
            &mut out,
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{rep},\
                 \"tid\":{METRICS_TID},\
                 \"args\":{{\"name\":\"metrics\"}}}}"
            ),
        );
    }
    for (i, &(rep, rid)) in sampled.iter().enumerate() {
        push(
            &mut out,
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{rep},\
                 \"tid\":{},\"args\":{{\"name\":\"req {rid}\"}}}}",
                16 + i
            ),
        );
    }
    for e in sorted {
        let tid = if is_obs_counter(e) {
            METRICS_TID
        } else {
            match (e.rid, e.lane) {
                (Some(rid), TraceLane::Host) => {
                    req_tid(e.replica, rid).unwrap_or(e.lane.index())
                }
                _ => e.lane.index(),
            }
        };
        let ts_us = e.ts_ms * 1e3;
        let mut line = format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"pid\":{},\"tid\":{tid},\
             \"ts\":{ts_us:.3},",
            e.name,
            e.lane.name(),
            e.replica
        );
        match e.kind {
            EventKind::Span => {
                line.push_str(&format!(
                    "\"ph\":\"X\",\"dur\":{:.3},",
                    e.dur_ms * 1e3
                ));
            }
            EventKind::Instant => {
                line.push_str("\"ph\":\"i\",\"s\":\"t\",");
            }
            EventKind::Counter => {
                line.push_str("\"ph\":\"C\",");
            }
        }
        push_args(&mut line, e);
        line.push('}');
        push(&mut out, line);
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Trace;

    fn demo_events() -> Vec<TraceEvent> {
        let t = Trace::ring(64);
        let r1 = t.for_replica(1);
        t.instant("enqueue", 0.0, Some(1), None, 3.0);
        t.span(TraceLane::Npu, "prefill", 0.0, 2.0, None, None, 3.0);
        t.span(TraceLane::Pim, "qk", 2.0, 2.5, None, None, 8.0);
        t.span(TraceLane::Cxl, "prefetch", 2.0, 3.0, Some(1), None, 2.0);
        r1.instant("retire", 4.0, Some(1), None, 2.0);
        t.counter("kv_used_bytes", 4.0, 1024.0);
        t.snapshot()
    }

    #[test]
    fn sampling_is_first_seen_and_replica_aware() {
        let evs = demo_events();
        let s = sample_requests(&evs, 4);
        assert_eq!(s, vec![(0, 1), (1, 1)]);
        assert_eq!(sample_requests(&evs, 1), vec![(0, 1)]);
    }

    #[test]
    fn export_emits_tracks_and_phases() {
        let evs = demo_events();
        let json = chrome_trace_json(&evs, &sample_requests(&evs, 2));
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("replica 0"));
        assert!(json.contains("replica 1"));
        assert!(json.contains("\"name\":\"npu\""));
        assert!(json.contains("\"name\":\"pim\""));
        // the tiered-KV migration lane exports as its own track: a
        // thread_name record plus the span tagged with its category
        assert!(json.contains("\"name\":\"cxl\""));
        assert!(json.contains("\"cat\":\"cxl\""));
        assert!(json
            .contains(&format!("\"tid\":{}", TraceLane::Cxl.index())));
        assert!(json.contains("req 1"));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"ph\":\"C\""));
        // sampled request events moved off the shared host track
        assert!(json.contains("\"tid\":16"));
    }

    #[test]
    fn obs_counters_land_on_the_metrics_track() {
        let t = Trace::ring(64);
        let r1 = t.for_replica(1);
        t.counter("obs:queue_depth", 1.0, 3.0);
        t.counter("obs:queue_depth", 2.0, 5.0);
        r1.counter("obs:burn:interactive", 2.0, 1.5);
        // a plain engine counter stays on its lane track
        t.counter("kv_used_bytes", 2.0, 64.0);
        let evs = t.snapshot();
        let json = chrome_trace_json(&evs, &[]);
        // one metrics thread per replica that scraped
        assert!(json.contains(
            "\"pid\":0,\"tid\":8,\"args\":{\"name\":\"metrics\"}"
        ));
        assert!(json.contains(
            "\"pid\":1,\"tid\":8,\"args\":{\"name\":\"metrics\"}"
        ));
        // obs counters moved to tid 8; the plain counter kept tid 0
        assert!(json.contains(
            "\"name\":\"obs:queue_depth\",\"cat\":\"host\",\"pid\":0,\
             \"tid\":8"
        ));
        assert!(json.contains(
            "\"name\":\"kv_used_bytes\",\"cat\":\"host\",\"pid\":0,\
             \"tid\":0"
        ));
        assert!(json.contains("\"name\":\"obs:burn:interactive\""));
    }

    #[test]
    fn export_is_deterministic() {
        let a = {
            let e = demo_events();
            chrome_trace_json(&e, &sample_requests(&e, 2))
        };
        let b = {
            let e = demo_events();
            chrome_trace_json(&e, &sample_requests(&e, 2))
        };
        assert_eq!(a, b);
    }
}
