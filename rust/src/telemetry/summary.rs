//! Derived utilization view of a trace: per replica x device lane busy
//! time, idle gaps, and the NPU/PIM overlap factor -- the metric the
//! sub-batch interleaving work is gated on.  The serial schedule
//! (`interleave=off`) lays operators end to end, so the factor reports
//! ~0 there; the interleaved sim backend runs sub-batch A's NPU phase
//! under B's PIM phase and the factor of a traced run must clear the
//! CI gate's 0.3 floor (see `interleave --smoke` and
//! `tests/interleave.rs`).

use crate::report::{f2, Table};

use super::{EventKind, TraceEvent, TraceLane};

/// Busy/idle statistics of one replica x lane track.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneStat {
    pub replica: u32,
    pub lane: TraceLane,
    /// union of the lane's span intervals (double-counts nothing)
    pub busy_ms: f64,
    /// busy_ms / the trace's wall window
    pub busy_frac: f64,
    pub spans: usize,
    /// gaps between consecutive busy intervals on this lane
    pub idle_gaps: usize,
    pub max_gap_ms: f64,
}

/// Per-replica NPU/PIM concurrency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlapStat {
    pub replica: u32,
    /// time both the NPU and PIM lanes were busy simultaneously
    pub overlap_ms: f64,
    /// overlap_ms / min(npu busy, pim busy): 0 = fully serialized,
    /// 1 = the less-busy engine is always covered by the other
    pub factor: f64,
}

/// Whole-trace utilization summary ([`utilization`]).
#[derive(Debug, Clone, PartialEq)]
pub struct UtilSummary {
    /// wall window covered by the trace (first ts to last span end)
    pub wall_ms: f64,
    pub lanes: Vec<LaneStat>,
    pub overlap: Vec<OverlapStat>,
}

/// Merge sorted-or-not intervals into a disjoint ascending union.
fn merged(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut out: Vec<(f64, f64)> = vec![];
    for (s, e) in iv {
        match out.last_mut() {
            Some(last) if s <= last.1 + 1e-9 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

fn span_ms(iv: &[(f64, f64)]) -> f64 {
    iv.iter().map(|(s, e)| e - s).sum()
}

/// Intersection length of two disjoint ascending interval unions.
fn overlap_ms(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let (mut i, mut j, mut total) = (0, 0, 0.0);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            total += hi - lo;
        }
        if a[i].1 < b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    total
}

/// Compute the utilization summary of an event stream.  Only `Span`
/// events contribute occupancy; instants and counters shape nothing
/// here.  Deterministic for a deterministic trace.
pub fn utilization(events: &[TraceEvent]) -> UtilSummary {
    let spans: Vec<&TraceEvent> =
        events.iter().filter(|e| e.kind == EventKind::Span).collect();
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    for e in events {
        t_min = t_min.min(e.ts_ms);
        t_max = t_max.max(e.ts_ms + e.dur_ms);
    }
    let wall_ms = if t_max > t_min { t_max - t_min } else { 0.0 };
    let mut keys: Vec<(u32, TraceLane)> =
        spans.iter().map(|e| (e.replica, e.lane)).collect();
    keys.sort();
    keys.dedup();
    let mut lanes = vec![];
    let mut replicas: Vec<u32> = keys.iter().map(|k| k.0).collect();
    replicas.dedup();
    let lane_union = |replica: u32, lane: TraceLane| {
        merged(
            spans
                .iter()
                .filter(|e| e.replica == replica && e.lane == lane)
                .map(|e| (e.ts_ms, e.ts_ms + e.dur_ms))
                .collect(),
        )
    };
    for &(replica, lane) in &keys {
        let union = lane_union(replica, lane);
        let busy = span_ms(&union);
        let mut idle_gaps = 0;
        let mut max_gap = 0.0f64;
        for w in union.windows(2) {
            let gap = w[1].0 - w[0].1;
            if gap > 1e-9 {
                idle_gaps += 1;
                max_gap = max_gap.max(gap);
            }
        }
        lanes.push(LaneStat {
            replica,
            lane,
            busy_ms: busy,
            busy_frac: if wall_ms > 0.0 { busy / wall_ms } else { 0.0 },
            spans: spans
                .iter()
                .filter(|e| e.replica == replica && e.lane == lane)
                .count(),
            idle_gaps,
            max_gap_ms: max_gap,
        });
    }
    let overlap = replicas
        .iter()
        .map(|&replica| {
            let npu = lane_union(replica, TraceLane::Npu);
            let pim = lane_union(replica, TraceLane::Pim);
            let o = overlap_ms(&npu, &pim);
            let floor = span_ms(&npu).min(span_ms(&pim));
            OverlapStat {
                replica,
                overlap_ms: o,
                factor: if floor > 0.0 { o / floor } else { 0.0 },
            }
        })
        .collect();
    UtilSummary { wall_ms, lanes, overlap }
}

impl UtilSummary {
    /// Busy time of one replica's lane (0 when the lane never ran).
    pub fn busy_ms(&self, replica: u32, lane: TraceLane) -> f64 {
        self.lanes
            .iter()
            .find(|l| l.replica == replica && l.lane == lane)
            .map(|l| l.busy_ms)
            .unwrap_or(0.0)
    }

    /// Render the per-lane rows as a printable [`Table`].
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!("device utilization over {:.1} ms", self.wall_ms),
            &[
                "replica", "lane", "busy ms", "busy %", "spans",
                "idle gaps", "max gap ms",
            ],
        );
        for l in &self.lanes {
            t.row(vec![
                l.replica.to_string(),
                l.lane.name().into(),
                f2(l.busy_ms),
                f2(l.busy_frac * 100.0),
                l.spans.to_string(),
                l.idle_gaps.to_string(),
                f2(l.max_gap_ms),
            ]);
        }
        t
    }

    /// One-line overlap report per replica (the `trace` subcommand
    /// prints this; `trace --smoke` greps for "overlap factor").
    pub fn overlap_lines(&self) -> String {
        self.overlap
            .iter()
            .map(|o| {
                format!(
                    "replica {}: NPU||PIM overlap factor {:.3} \
                     ({:.2} ms concurrent)",
                    o.replica, o.factor, o.overlap_ms
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Trace;

    #[test]
    fn busy_gaps_and_overlap() {
        let t = Trace::ring(64);
        // npu: [0,2] + [5,6]; pim: [1,3]; overlap [1,2]
        t.span(TraceLane::Npu, "a", 0.0, 2.0, None, None, 0.0);
        t.span(TraceLane::Npu, "b", 5.0, 6.0, None, None, 0.0);
        t.span(TraceLane::Pim, "c", 1.0, 3.0, None, None, 0.0);
        let u = utilization(&t.snapshot());
        assert!((u.wall_ms - 6.0).abs() < 1e-9);
        assert!((u.busy_ms(0, TraceLane::Npu) - 3.0).abs() < 1e-9);
        assert!((u.busy_ms(0, TraceLane::Pim) - 2.0).abs() < 1e-9);
        let npu = u
            .lanes
            .iter()
            .find(|l| l.lane == TraceLane::Npu)
            .unwrap();
        assert_eq!(npu.idle_gaps, 1);
        assert!((npu.max_gap_ms - 3.0).abs() < 1e-9);
        let o = &u.overlap[0];
        assert!((o.overlap_ms - 1.0).abs() < 1e-9);
        assert!((o.factor - 0.5).abs() < 1e-9);
        assert!(u.overlap_lines().contains("overlap factor"));
    }

    #[test]
    fn overlap_ms_on_synthetic_interval_sets() {
        // disjoint unions never intersect
        assert_eq!(overlap_ms(&[(0.0, 1.0)], &[(2.0, 3.0)]), 0.0);
        // nested: [1,2] sits entirely inside [0,4]
        assert!(
            (overlap_ms(&[(0.0, 4.0)], &[(1.0, 2.0)]) - 1.0).abs()
                < 1e-12
        );
        // partial: [0,2]+[5,7] against [1,6] intersects 1+1
        assert!(
            (overlap_ms(&[(0.0, 2.0), (5.0, 7.0)], &[(1.0, 6.0)])
                - 2.0)
                .abs()
                < 1e-12
        );
        // zero-length intervals contribute nothing from either side
        assert_eq!(overlap_ms(&[(1.0, 1.0)], &[(0.0, 2.0)]), 0.0);
        assert_eq!(overlap_ms(&[(0.0, 2.0)], &[(1.0, 1.0)]), 0.0);
    }

    #[test]
    fn nested_and_zero_length_spans_shape_the_overlap_stat() {
        let t = Trace::ring(16);
        // pim [1,2] nested inside npu [0,4]; a zero-length pim tick
        // at t=3 adds a span but no busy time
        t.span(TraceLane::Npu, "outer", 0.0, 4.0, None, None, 0.0);
        t.span(TraceLane::Pim, "inner", 1.0, 2.0, None, None, 0.0);
        t.span(TraceLane::Pim, "tick", 3.0, 3.0, None, None, 0.0);
        let u = utilization(&t.snapshot());
        assert!((u.busy_ms(0, TraceLane::Pim) - 1.0).abs() < 1e-9);
        let pim = u
            .lanes
            .iter()
            .find(|l| l.lane == TraceLane::Pim)
            .unwrap();
        assert_eq!(pim.spans, 2);
        let o = &u.overlap[0];
        assert!((o.overlap_ms - 1.0).abs() < 1e-9);
        // the nested lane is covered for its whole busy time, so the
        // factor saturates at 1 (overlap / min busy)
        assert!((o.factor - 1.0).abs() < 1e-9);
    }

    #[test]
    fn serialized_lanes_have_zero_overlap() {
        let t = Trace::ring(16);
        t.span(TraceLane::Npu, "a", 0.0, 1.0, None, None, 0.0);
        t.span(TraceLane::Pim, "b", 1.0, 2.0, None, None, 0.0);
        let u = utilization(&t.snapshot());
        assert_eq!(u.overlap[0].overlap_ms, 0.0);
        assert_eq!(u.overlap[0].factor, 0.0);
    }

    #[test]
    fn cxl_lane_rolls_up_like_any_device_track() {
        let t = Trace::ring(32);
        t.span(TraceLane::Npu, "decode", 0.0, 4.0, None, None, 0.0);
        // two prefetch bursts and one demand stall on the cxl lane
        t.span(TraceLane::Cxl, "prefetch", 0.0, 1.0, Some(1), None, 2.0);
        t.span(TraceLane::Cxl, "prefetch", 0.5, 1.5, Some(2), None, 2.0);
        t.span(
            TraceLane::Cxl,
            "demand_migrate",
            3.0,
            3.5,
            Some(1),
            None,
            1.0,
        );
        let u = utilization(&t.snapshot());
        // overlapping prefetches union to [0,1.5]; the stall adds 0.5
        assert!((u.busy_ms(0, TraceLane::Cxl) - 2.0).abs() < 1e-9);
        let cxl = u
            .lanes
            .iter()
            .find(|l| l.lane == TraceLane::Cxl)
            .unwrap();
        assert_eq!(cxl.spans, 3);
        assert_eq!(cxl.idle_gaps, 1);
        let rendered = u.table().render();
        assert!(rendered.contains("cxl"));
    }

    #[test]
    fn empty_trace_is_all_zero() {
        let u = utilization(&[]);
        assert_eq!(u.wall_ms, 0.0);
        assert!(u.lanes.is_empty());
        assert!(u.overlap.is_empty());
    }
}
