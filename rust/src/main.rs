//! p3llm -- leader binary: serve / eval / simulate / loadtest /
//! cluster / report.
//!
//! `serve` runs the unified engine on either execution backend
//! (`--backend pjrt` for real numerics from AOT artifacts, `--backend
//! sim` for the NPU-PIM cost model: any model, any batch, no
//! artifacts); `simulate` reuses the same engine under each modeled
//! system; `loadtest` sweeps named traffic scenarios across systems
//! through the closed-loop `traffic::LoadRunner`; `cluster` routes
//! the same scenarios across N engine replicas (`cluster::Cluster`)
//! and reports fleet goodput and scaling.  Python is never on the
//! request path.

use p3llm::accel::Accel;
use p3llm::benchkit::BenchRecord;
use p3llm::cli::Args;
use p3llm::cluster::{
    all_policy_names, policy_by_name, policy_desc, Cluster, ClusterOutcome,
};
use p3llm::config::llm;
use p3llm::coordinator::{Engine, EngineBuilder, KvLayout, Metrics};
use p3llm::error::{P3Error, Result};
use p3llm::obs::{AlertEvent, AlertKind, Obs, ObsConfig, Point, BURN_FAST};
use p3llm::report::{f2, f3, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};
use p3llm::sched::{victim_by_name, SloClass, TierMix};
use p3llm::telemetry::{export, flight, summary, Trace, TraceLane};
use p3llm::traffic::{
    self, ArrivalProcess, LoadReport, LoadRunner, RequestMix, Scenario,
    SloSpec,
};

const USAGE: &str = "\
p3llm <command> [options]

commands:
  serve      run the serving engine end-to-end
             --backend {pjrt,sim}   execution substrate (default pjrt)
             --requests N --max-new N --batch N --no-prefix-cache
             pjrt: --fp16 --device-weights  (tiny model, needs artifacts)
             sim:  --model NAME --system NAME --scheme NAME
                   --prompt-len N --ctx N --kv-cap BYTES
  eval       perplexity of a configured quantization variant
             --config NAME --corpus {wiki,c4} --blocks N  (see evalcfg.tsv)
  list-eval  list configured accuracy variants
  simulate   decode latency on the modeled NPU-PIM systems, plus a
             closed-loop serving view of the chosen system
             --model NAME --batch N --ctx N --system NAME --seed N
             --requests N --max-new N --interarrival MS
             --interleave   overlap NPU||PIM sub-batches in the
                      closed-loop view (see `interleave`)
  loadtest   sweep traffic scenarios x systems through the closed-loop
             load runner; reports goodput / SLO attainment (sim only,
             no artifacts, bit-identical under a fixed --seed)
             --scenario NAME[,NAME..]|all   (default all; see --list)
             --system NAME[,NAME..]|all     (default NPU,HBM-PIM,Ecco,P3-LLM)
             --scheme NAME --seed N (default 7)
             --requests N --model NAME --batch N --ctx N --mix NAME
             --scale F      stretch (>1) / intensify (<1) arrival gaps
             --trace FILE   replay arrival offsets (ms) from a TSV
             --no-prefix-cache   disable shared-prefix KV caching (A/B)
             --tiers I/B/E   SLO-class shares (interactive/batch/
                      best-effort, e.g. 50/30/20) sampled per request
             --victim NAME   preemptive scheduling victim policy
                      (recompute | swap); omits = FIFO, no preemption
             --list   show scenarios + mixes     --save  write TSV
             --smoke  CI gate: tiny scenarios incl. the prefix cache;
                      fails on zero goodput, zero hit rate, or a cache
                      that does not lower mean TTFT
  cluster    multi-replica serving: route a scenario's arrivals across
             N engine replicas (sim backend, weak-scaled load) and
             report fleet goodput / utilization skew / scaling
             efficiency vs 1 replica
             --replicas N[,N..] (default 1,2,4)
             --policy NAME[,NAME..]|all     (default jsq; see --list;
                      pa = prefix-affinity for replica-local caches)
             --scenario NAME[,NAME..]|all   (default chat-poisson)
             --system NAME --scheme NAME --seed N --requests N
             --scale F --save --no-prefix-cache
             --tiers I/B/E --victim NAME    (as in loadtest: tiered
                      arrivals + preemptive replicas)
             --list   show routing policies
             --smoke  CI gate: 2 replicas, tiny model, JSQ; fails on
                      zero fleet goodput
  overload   tiered overload degradation: pin offered load to a
             multiple of the modeled saturation throughput and sweep
             it past 1.0 with SLO classes + preemptive scheduling;
             reports per-tier goodput / attainment / TTFT curves
             against a FIFO baseline
             --scenario NAME (default flash-crowd; --smoke uses
                      smoke-overload)   --system NAME --scheme NAME
             --seed N --requests N
             --victim NAME[,NAME..]  (default recompute)
             --load F[,F..]   offered/saturation factors (default 1,2)
             --tiers I/B/E    override the scenario's tier mix
             --save   write overload.tsv + BENCH_overload.json
             --smoke  CI gate: bit-identical two-run diff; at 2x
                      saturation the preemptive engines lose zero
                      requests, preempt at least once, and hold
                      interactive attainment >= 0.9 against a
                      calibrated TTFT budget the FIFO baseline
                      strictly misses
  trace      request-span tracing + NPU/PIM/bus device timelines: run
             one scenario traced (sim backend), export a Chrome
             trace-event JSON (open in Perfetto or about:tracing),
             print per-lane utilization + the NPU||PIM overlap factor,
             and flight-dump requests that miss their TTFT budget
             --scenario NAME (default chat-poisson; --smoke uses
                      smoke-overload at 2x saturation: preemptions,
                      bounces, and restores all land in the trace)
             --system NAME --scheme NAME --seed N --requests N
             --replicas N --policy NAME    trace a routed fleet (one
                      track group per replica, shared sink)
             --tiers I/B/E --victim NAME   (as in loadtest)
             --out FILE            trace path (default reports/trace.json)
             --sample-requests K   per-request tracks (default 4)
             --ring N              event retention bound (default 262144)
             --flight-on-miss      dump last events of SLO-missing or
                      errored requests
             --flight-last N       flight-recorder depth (default 16)
             --save   also write the utilization table TSV
             --smoke  CI gate: bit-identical two-run export, nonzero
                      NPU+PIM+bus busy time, a complete enqueue->retire
                      span chain, flight recorder fires on an injected
                      zero TTFT budget, and a telemetry-off run is
                      report-identical with 0 events recorded
  memtier    tiered KV hierarchy (HBM hot tier / CXL cold pool) sweep:
             hot-tier fraction x prefetch depth x scenario through the
             closed-loop runner; reports TTFT/TPOT curves next to
             prefetched vs demand-migrated page counts
             --scenario NAME[,NAME..]   (default smoke-longdoc; the
                      long-doc-32k / long-doc-128k scenarios are the
                      full-size long-context sweeps)
             --hot F[,F..]     hot-tier fractions of the KV pool's
                      pages (default 0.25,0.5,1.0; 1.0 = no cold tier)
             --depth N[,N..]   ahead-of-decode prefetch depths in
                      pages/request/step (default 0,4,8; 0 = pure
                      demand paging, every cold page stalls decode)
             --system NAME --scheme NAME --seed N --requests N
             --save   write memtier.tsv + BENCH_memtier.json
             --smoke  CI gate: bit-identical double run; the long-doc
                      scenario overflows the hot tier yet loses zero
                      requests with a nonzero prefetch hit rate;
                      prefetch-on strictly beats demand paging on mean
                      TPOT, incl. a 32k-context Mistral-7B proof
  interleave A/B the NPU||PIM sub-batch interleaving against the
             serial schedule on the same seeds: split each step's
             active lanes into two sub-batches so A's NPU phase
             overlaps B's PIM phase (steps that would lose fuse back
             to the serial charge); reports goodput / makespan /
             overlap factor per mode
             --scenario NAME[,NAME..]   (default smoke-interleave)
             --system NAME --scheme NAME --seed N --requests N
             --tiers I/B/E --victim NAME   (as in loadtest)
             --save   write interleave.tsv + BENCH_interleave.json
             --smoke  CI gate: in-process double-run determinism per
                      mode; serial mode charges zero interleaving;
                      at batch 8 the decode-heavy scenario overlaps
                      > 0.3 of the less-busy engine and beats serial
                      goodput strictly
  monitor    virtual-clock observability: run scenarios with the obs
             layer scraping typed metrics (queue depth, KV occupancy,
             per-tier SLO counters, burn rates) into time series on a
             fixed engine-clock cadence; prints a time-bucketed series
             table, the burn-rate alert timeline (pending -> firing ->
             resolved), and a fleet health snapshot, flight-dumps the
             in-flight context of the first firing alert, and exports
             the registry as Prometheus text + the series as JSON
             --scenario NAME[,NAME..]|all  (default flash-crowd)
             --system NAME --scheme NAME --seed N --requests N
             --load F         pin offered load to F x saturation
             --tiers I/B/E --victim NAME   (as in loadtest)
             --replicas N --policy NAME    monitor a routed fleet
                      (per-replica series merge at the shared hub)
             --scrape-ms F    scrape cadence, engine-clock ms (50)
             --fast-ms F --slow-ms F   burn-rate windows (1000/4000)
             --flight-last N  alert flight-dump depth (default 16)
             --out FILE       Prometheus text (reports/metrics.prom)
             --json-out FILE  series JSON (reports/metrics_series.json)
             --save   write the bucketed series table TSV
             --smoke  CI gate on a calibrated flash crowd: the
                      interactive burn-rate alert fires strictly before
                      the end-of-run report shows the attainment dip
                      and resolves after the crowd subsides; a
                      metrics-off run is report-identical with zero
                      series points; exports are byte-deterministic
  trend      compare the BENCH_*.json sidecars under reports/ against
             the committed tolerance bands in benches/baselines.json;
             prints one line per band and fails on any regression
             --baselines FILE   (default rust/benches/baselines.json)
  version

common: --artifacts DIR (default: artifacts)";

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("list-eval") => cmd_list_eval(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("loadtest") => cmd_loadtest(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("overload") => cmd_overload(&args),
        Some("trace") => cmd_trace(&args),
        Some("memtier") => cmd_memtier(&args),
        Some("interleave") => cmd_interleave(&args),
        Some("monitor") => cmd_monitor(&args),
        Some("trend") => cmd_trend(&args),
        Some("version") => {
            println!("p3llm {}", p3llm::version());
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn print_metrics(m: &Metrics) {
    println!(
        "completed={} steps={} tokens={} decode_tok/s={:.1} wall={:.1}ms \
         (backend={}, {} clock)",
        m.completed,
        m.decode_steps,
        m.tokens_out,
        m.tokens_per_sec(),
        m.wall_ms,
        m.backend,
        if m.backend == "sim" { "simulated" } else { "wall" },
    );
    println!(
        "TTFT ms:      mean={:.2} p50={:.2} p95={:.2} p99={:.2} max={:.2}",
        m.ttft_ms.mean, m.ttft_ms.p50, m.ttft_ms.p95, m.ttft_ms.p99, m.ttft_ms.max
    );
    println!(
        "per-token ms: mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
        m.per_token_ms.mean,
        m.per_token_ms.p50,
        m.per_token_ms.p95,
        m.per_token_ms.p99,
        m.per_token_ms.max
    );
}

/// Drive a built engine through a batch of requests to completion.
fn drive(engine: &mut Engine, n_requests: usize, max_new: usize, prompt_len: usize) -> Result<Metrics> {
    let prompts = [
        "in 980 , aldora",
        "the kettle works",
        "to fix your router , first",
        "celund is the capital of",
    ];
    for i in 0..n_requests {
        let toks: Vec<i32> = if prompt_len > 0 {
            // synthetic prompt of the requested length (sim workloads)
            let mut rng = p3llm::testutil::Rng::new(0xd21f ^ i as u64);
            (0..prompt_len).map(|_| rng.usize(0, 251) as i32).collect()
        } else {
            prompts[i % prompts.len()].bytes().map(|b| b as i32).collect()
        };
        engine.submit(toks, max_new)?;
    }
    engine.run_to_completion()
}

fn print_load_report(r: &LoadReport) {
    println!(
        "offered={} completed={} SLO-met={} attainment={:.1}% \
         makespan={:.1}ms",
        r.offered,
        r.completed,
        r.slo_met,
        r.slo_attainment * 100.0,
        r.makespan_ms
    );
    let util = match r.utilization() {
        Some(u) => format!("   utilization={:.1}%", u * 100.0),
        None => String::new(),
    };
    println!(
        "goodput: {:.2} req/s, {:.1} tok/s   throughput: {:.1} tok/s   \
         decode-busy: {:.1} tok/s{util}",
        r.goodput_req_s, r.goodput_tok_s, r.throughput_tok_s, r.busy_tok_s
    );
    println!(
        "TTFT ms:  mean={:.2} p50={:.2} p95={:.2} p99={:.2}",
        r.ttft_ms.mean, r.ttft_ms.p50, r.ttft_ms.p95, r.ttft_ms.p99
    );
    println!(
        "queue ms: mean={:.2} p95={:.2}   TPOT ms: mean={:.3} p95={:.3}",
        r.queue_delay_ms.mean,
        r.queue_delay_ms.p95,
        r.tpot_ms.mean,
        r.tpot_ms.p95
    );
    if r.prefix_hits > 0 {
        println!(
            "prefix cache: {} hits ({:.1}%), {} prefill tokens saved",
            r.prefix_hits,
            r.prefix_hit_rate * 100.0,
            r.prefill_tokens_saved
        );
    }
    if r.preemptions > 0 {
        println!(
            "preemptions: {} ({} pages swapped, {} recomputed)",
            r.preemptions, r.pages_swapped, r.pages_recomputed
        );
    }
    if r.pages_prefetched + r.pages_demand > 0 {
        let hit = r.pages_prefetched as f64
            / (r.pages_prefetched + r.pages_demand) as f64;
        println!(
            "cxl tier: {} pages prefetched, {} demand-migrated \
             (prefetch hit rate {:.1}%)",
            r.pages_prefetched,
            r.pages_demand,
            hit * 100.0
        );
    }
    if r.interleaved_steps + r.fused_steps > 0 {
        println!(
            "interleave: {} steps overlapped, {} fused back to serial, \
             overlap factor {:.2}, {:.3}ms saved vs serial",
            r.interleaved_steps,
            r.fused_steps,
            r.overlap_factor,
            r.serial_saved_ms
        );
    }
}

/// Headers for the per-SLO-class breakdown tables (`loadtest`,
/// `cluster`, `overload`): one row per tier present in a run.
const TIER_HEADERS: [&str; 13] = [
    "scenario",
    "config",
    "tier",
    "done",
    "SLO %",
    "goodput req/s",
    "TTFT p50",
    "TTFT p99",
    "TPOT p50",
    "TPOT p99",
    "preempt",
    "swapped",
    "recomputed",
];

/// Append one row per SLO class of `r` (no-op for single-tier runs,
/// whose `per_class` is empty).  Each tier is judged against the base
/// SLO scaled by its `slo_factor`.
fn tier_rows(t: &mut Table, scenario: &str, config: &str, r: &LoadReport) {
    for (class, cr) in &r.per_class {
        t.row(vec![
            scenario.into(),
            config.into(),
            class.name().into(),
            format!("{}/{}", cr.completed, cr.offered),
            f2(cr.slo_attainment * 100.0),
            f2(cr.goodput_req_s),
            f2(cr.ttft_ms.p50),
            f2(cr.ttft_ms.p99),
            f3(cr.tpot_ms.p50),
            f3(cr.tpot_ms.p99),
            cr.preemptions.to_string(),
            cr.pages_swapped.to_string(),
            cr.pages_recomputed.to_string(),
        ]);
    }
}

/// Save a subcommand's primary table -- plus its per-tier companion
/// when one has rows -- under `p3llm::benchkit::reports_dir()`,
/// printing each written path.  The one save block `loadtest`,
/// `cluster`, `overload`, and `trace` share.
fn save_tables(t: &Table, tiers: Option<&Table>, name: &str) -> Result<()> {
    let dir = p3llm::benchkit::reports_dir();
    t.save(&dir, name).map_err(|e| P3Error::io(&dir, e))?;
    println!("saved {}", dir.join(format!("{name}.tsv")).display());
    if let Some(tt) = tiers {
        if !tt.rows.is_empty() {
            let tname = format!("{name}_tiers");
            tt.save(&dir, &tname).map_err(|e| P3Error::io(&dir, e))?;
            println!("saved {}", dir.join(format!("{tname}.tsv")).display());
        }
    }
    Ok(())
}

/// Apply the shared `--tiers I/B/E` and `--victim NAME` overrides.
/// Both parse strictly into typed [`P3Error::InvalidFlag`] errors.
fn apply_tier_flags(args: &Args, scenarios: &mut [Scenario]) -> Result<()> {
    if let Some(spec) = args.get("tiers") {
        let mix = TierMix::parse(spec)?;
        for s in scenarios.iter_mut() {
            s.tiers = Some(mix);
        }
    }
    if let Some(v) = args.get("victim") {
        let policy =
            victim_by_name(v).ok_or_else(|| P3Error::InvalidFlag {
                flag: "victim".into(),
                value: v.into(),
            })?;
        for s in scenarios.iter_mut() {
            s.victim = Some(policy.name());
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend = args.get_or("backend", "pjrt").to_ascii_lowercase();
    let n_requests = args.get_usize("requests", 8)?;
    let max_new = args.get_usize("max-new", 48)?;
    let mut b = EngineBuilder::backend(&backend)?;
    match backend.as_str() {
        "pjrt" => {
            b = b
                .artifacts_dir(&artifacts_dir(args))
                .max_batch(args.get_usize("batch", 8)?)
                .scheme(if args.has("fp16") { "fp16" } else { "p3llm" })
                .device_weights(args.has("device-weights"));
        }
        _ => {
            b = b
                .model(args.get_or("model", "tiny-1M"))
                .system(args.get_or("system", "P3-LLM"))
                .max_batch(args.get_usize("batch", 8)?)
                .kv_capacity(args.get_usize("kv-cap", 64 << 20)?);
            if let Some(s) = args.get("scheme") {
                b = b.scheme(s);
            }
            if args.get("ctx").is_some() {
                b = b.ctx_limit(args.get_usize("ctx", 1024)?);
            }
        }
    }
    if args.has("no-prefix-cache") {
        b = b.prefix_cache(false);
    }
    let mut engine = b.build()?;
    let prompt_len = match backend.as_str() {
        "pjrt" => 0,
        _ => args.get_usize("prompt-len", 16)?,
    };
    println!(
        "serving {n_requests} requests on {} via {} backend",
        engine.model().name,
        engine.backend_name()
    );
    let metrics = drive(&mut engine, n_requests, max_new, prompt_len)?;
    print_metrics(&metrics);
    if let Some(m) = engine.mapping_summary() {
        println!(
            "operator mapping (last step): {} NPU ops, {} PIM ops, {} PIM commands",
            m.npu_ops, m.pim_ops, m.pim_commands
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::new(&dir)?;
    let ev = Evaluator::new(&rt)?;
    let cfgs = eval_configs(&rt.artifacts.dir)?;
    let name = args.get_or("config", "fp16");
    let cfg = cfgs.iter().find(|c| c.name == name).ok_or_else(|| {
        P3Error::Eval(format!("unknown config {name}; try list-eval"))
    })?;
    let corpus = args.get_or("corpus", "wiki");
    let blocks = args.get_usize("blocks", 8)?;
    // --set kv_bits=2,a_bits=8 style scalar overrides
    let overrides: Vec<(String, f32)> = args
        .get_or("set", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            Some((k.to_string(), v.parse().ok()?))
        })
        .collect();
    let refs: Vec<(&str, f32)> =
        overrides.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let r = ev.evaluate(cfg, corpus, blocks, &refs)?;
    println!(
        "{name} on {corpus}: ppl {:.4}  acc {:.2}%   ({})",
        r.ppl,
        r.accuracy * 100.0,
        cfg.note
    );
    Ok(())
}

fn cmd_list_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let cfgs = eval_configs(std::path::Path::new(&dir))?;
    let mut t = Table::new("eval configs", &["name", "graph", "weights", "note"]);
    for c in cfgs {
        t.row(vec![c.name, c.graph, c.weights, c.note]);
    }
    t.print();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "Llama-3.1-8B");
    let model = llm::by_name(model_name)
        .ok_or_else(|| P3Error::UnknownModel(model_name.into()))?;
    let bs = args.get_usize("batch", 1)?;
    let ctx = args.get_usize("ctx", 4096)?;
    let mut t = Table::new(
        format!("{} decode step, bs={bs}, ctx={ctx}", model.name),
        &["system", "attn ms", "linear ms", "total ms", "tok/s", "energy mJ"],
    );
    for a in [
        Accel::npu_fp16(),
        Accel::hbm_pim(),
        Accel::ecco(),
        Accel::pimba_enhanced(),
        Accel::p3llm(),
    ] {
        let c = a.decode_step(&model, bs, ctx);
        t.row(vec![
            a.name.into(),
            f2(c.attn.ns / 1e6),
            f2(c.linear.ns / 1e6),
            f2(c.total_ns() / 1e6),
            f2(bs as f64 / (c.total_ns() * 1e-9)),
            f2(c.total_pj() / 1e9),
        ]);
    }
    t.print();

    // the per-step table above is open-loop; the view below closes
    // the loop through the one serving timeline implementation
    // (traffic::LoadRunner driving the same engine as `serve`)
    let system = args.get_or("system", "P3-LLM");
    let seed = args.get_u64("seed", 7)?;
    // --max-new pins the output length the chat mix would otherwise draw
    let mut mix = RequestMix::chat();
    if args.get("max-new").is_some() {
        let n = args.get_usize("max-new", 32)?.max(1);
        mix.min_output = n;
        mix.max_output = n;
    }
    let sc = Scenario {
        name: "simulate",
        desc: "closed-loop serving view of the simulate subcommand",
        model: model.name,
        arrival: ArrivalProcess::Poisson {
            mean_interarrival_ms: args.get_f64("interarrival", 150.0)?,
        },
        mix,
        slo: SloSpec::chatbot(),
        n_requests: args.get_usize("requests", 4 * bs.max(1))?,
        max_batch: bs.max(1),
        ctx_limit: ctx.min(model.max_ctx).max(64),
        kv_slots: bs.max(1) + 2,
        prefix_cache: !args.has("no-prefix-cache"),
        tiers: None,
        victim: None,
        interleave: args.has("interleave"),
    };
    let mut engine = sc.engine(system, None)?;
    println!(
        "closed-loop serving view ({} on {system}, chat mix, Poisson \
         arrivals, seed {seed}):",
        engine.model().name
    );
    let out = sc
        .runner(seed)
        .run_with_saturation(&mut engine, sc.saturation_tok_s(system))?;
    print_load_report(&out.report);
    if let Some(m) = engine.mapping_summary() {
        println!(
            "operator mapping (last step): {} NPU ops, {} PIM ops, {} PIM commands",
            m.npu_ops, m.pim_ops, m.pim_commands
        );
    }
    Ok(())
}

/// Resolve a `--scenario` selection (`NAME[,NAME..]` or `all`, which
/// excludes the CI smoke scenario) and apply the shared `--requests`
/// override -- common to `loadtest` and `cluster`.
fn select_scenarios(args: &Args, default_sel: &str) -> Result<Vec<Scenario>> {
    let sel = args.get_or("scenario", default_sel);
    let mut scenarios: Vec<Scenario> = if sel.eq_ignore_ascii_case("all") {
        traffic::all_scenarios()
            .into_iter()
            .filter(|s| !s.name.starts_with("smoke"))
            .collect()
    } else {
        let mut v = vec![];
        for name in args.get_list("scenario", default_sel) {
            v.push(traffic::scenario_by_name(&name).ok_or_else(|| {
                P3Error::InvalidConfig(format!(
                    "unknown scenario {name:?} (see `p3llm loadtest --list`)"
                ))
            })?);
        }
        v
    };
    if args.get("requests").is_some() {
        let n = args.get_usize("requests", 1)?.max(1);
        for s in &mut scenarios {
            s.n_requests = n;
        }
    }
    if args.has("no-prefix-cache") {
        for s in &mut scenarios {
            s.prefix_cache = false;
        }
    }
    Ok(scenarios)
}

/// Resolve `--scenario` / `--system` selections and per-flag scenario
/// overrides, then sweep scenario x system through the closed-loop
/// runner and print/save the comparison table.
fn cmd_loadtest(args: &Args) -> Result<()> {
    if args.has("list") {
        let mut t = Table::new(
            "traffic scenarios",
            &["name", "model", "requests", "batch", "ctx", "mix", "description"],
        );
        for s in traffic::all_scenarios() {
            t.row(vec![
                s.name.into(),
                s.model.into(),
                s.n_requests.to_string(),
                s.max_batch.to_string(),
                s.ctx_limit.to_string(),
                s.mix.name.into(),
                s.desc.into(),
            ]);
        }
        t.print();
        let mut m = Table::new(
            "request mixes (--mix)",
            &["name", "prompt range", "output range"],
        );
        for mx in traffic::all_mixes() {
            m.row(vec![
                mx.name.into(),
                format!("{}..={}", mx.min_prompt, mx.max_prompt),
                format!("{}..={}", mx.min_output, mx.max_output),
            ]);
        }
        m.print();
        return Ok(());
    }
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 7)?;
    let mut scenarios =
        select_scenarios(args, if smoke { "smoke,smoke-prefix" } else { "all" })?;
    if let Some(m) = args.get("model") {
        let model =
            llm::by_name(m).ok_or_else(|| P3Error::UnknownModel(m.into()))?;
        for s in &mut scenarios {
            s.model = model.name;
        }
    }
    if args.get("batch").is_some() {
        let b = args.get_usize("batch", 1)?.max(1);
        for s in &mut scenarios {
            s.max_batch = b;
        }
    }
    if args.get("ctx").is_some() {
        let c = args.get_usize("ctx", 1024)?.max(64);
        for s in &mut scenarios {
            s.ctx_limit = c;
        }
    }
    if let Some(name) = args.get("mix") {
        let mix = traffic::mix_by_name(name).ok_or_else(|| {
            P3Error::InvalidConfig(format!(
                "unknown request mix {name:?} (see `p3llm loadtest --list`)"
            ))
        })?;
        for s in &mut scenarios {
            s.mix = mix.clone();
        }
    }
    if let Some(path) = args.get("trace") {
        let tr = traffic::load_trace_tsv(path)?;
        for s in &mut scenarios {
            s.arrival = tr.clone();
        }
    }
    // --scale stretches/intensifies every arrival gap; degenerate
    // factors surface as the typed InvalidFlag from ArrivalProcess
    let scale = args.get_f64("scale", 1.0)?;
    for s in &mut scenarios {
        s.arrival = s.arrival.scaled(scale)?;
    }
    apply_tier_flags(args, &mut scenarios)?;
    let default_systems =
        if smoke { "NPU,P3-LLM" } else { "NPU,HBM-PIM,Ecco,P3-LLM" };
    let sys_sel = args.get_or("system", default_systems);
    let systems: Vec<String> = if sys_sel.eq_ignore_ascii_case("all") {
        p3llm::accel::all_systems()
            .iter()
            .map(|a| a.name.to_string())
            .collect()
    } else {
        args.get_list("system", default_systems)
    };
    let scheme = args.get("scheme");

    let mut t = Table::new(
        format!("loadtest: scenario x system, seed {seed}"),
        &[
            "scenario",
            "system",
            "scheme",
            "done",
            "SLO %",
            "goodput req/s",
            "goodput tok/s",
            "tok/s",
            "p95 TTFT ms",
            "p95 queue ms",
            "util %",
            "hit %",
            "saved tok",
        ],
    );
    let mut tiers_t = Table::new(
        "per-tier breakdown (SLO budget x tier slo_factor)",
        &TIER_HEADERS,
    );
    let mut bench_records: Vec<BenchRecord> = vec![];
    for sc in &scenarios {
        for sys in &systems {
            let mut engine = sc.engine(sys, scheme)?;
            let out = sc
                .runner(seed)
                .run_with_saturation(&mut engine, sc.saturation_tok_s(sys))?;
            let r = &out.report;
            if smoke && (r.goodput_tok_s <= 0.0 || r.completed < r.offered) {
                return Err(P3Error::Serve(format!(
                    "smoke gate: {} on {sys}: goodput {:.2} tok/s, \
                     {}/{} completed",
                    sc.name, r.goodput_tok_s, r.completed, r.offered
                )));
            }
            // prefix-bearing smoke scenarios also gate the cache: a
            // nonzero hit rate, and a strictly lower mean TTFT than
            // the identical run with the cache disabled
            if smoke && sc.mix.prefixes.is_some() && sc.prefix_cache {
                if r.prefix_hits == 0 {
                    return Err(P3Error::Serve(format!(
                        "smoke gate: {} on {sys}: prefix-bearing \
                         scenario reported zero cache hits",
                        sc.name
                    )));
                }
                let mut cold = sc.clone();
                cold.prefix_cache = false;
                let mut cold_engine = cold.engine(sys, scheme)?;
                let off = cold.runner(seed).run(&mut cold_engine)?.report;
                if r.ttft_ms.mean >= off.ttft_ms.mean {
                    return Err(P3Error::Serve(format!(
                        "smoke gate: {} on {sys}: prefix cache did not \
                         lower mean TTFT ({:.3} ms cached vs {:.3} ms \
                         cold)",
                        sc.name, r.ttft_ms.mean, off.ttft_ms.mean
                    )));
                }
            }
            let scheme_name = match scheme {
                Some(s) => s.to_string(),
                None => p3llm::accel::by_name(sys)
                    .map(|a| a.scheme.name.to_string())
                    .unwrap_or_else(|| "-".into()),
            };
            t.row(vec![
                sc.name.into(),
                sys.clone(),
                scheme_name,
                format!("{}/{}", r.completed, r.offered),
                f2(r.slo_attainment * 100.0),
                f2(r.goodput_req_s),
                f2(r.goodput_tok_s),
                f2(r.throughput_tok_s),
                f2(r.ttft_ms.p95),
                f2(r.queue_delay_ms.p95),
                r.utilization()
                    .map(|u| f2(u * 100.0))
                    .unwrap_or_else(|| "-".into()),
                f2(r.prefix_hit_rate * 100.0),
                r.prefill_tokens_saved.to_string(),
            ]);
            tier_rows(&mut tiers_t, sc.name, sys, r);
            if smoke {
                let cfg = format!("scenario={},system={sys}", sc.name);
                bench_records.push(BenchRecord::new(
                    cfg.clone(),
                    "goodput_tok_s",
                    r.goodput_tok_s,
                ));
                bench_records.push(BenchRecord::new(
                    cfg,
                    "ttft_mean_ms",
                    r.ttft_ms.mean,
                ));
            }
        }
    }
    t.print();
    if !tiers_t.rows.is_empty() {
        tiers_t.print();
    }
    if smoke {
        let path = p3llm::benchkit::save_bench_json(
            "loadtest_smoke",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
    }
    if args.has("save") {
        save_tables(&t, Some(&tiers_t), "loadtest")?;
    }
    Ok(())
}

/// Sweep replica-count x routing-policy x scenario through the
/// multi-replica cluster.  Load is weak-scaled (`Scenario::for_fleet`:
/// n x requests at n x the arrival rate) so the goodput column reads
/// as a scaling curve; every (scenario, policy) pair also runs a
/// 1-replica baseline to anchor the scaling-efficiency column.
fn cmd_cluster(args: &Args) -> Result<()> {
    if args.has("list") {
        let mut t =
            Table::new("routing policies (--policy)", &["name", "description"]);
        for p in all_policy_names() {
            t.row(vec![p.into(), policy_desc(p).into()]);
        }
        t.print();
        return Ok(());
    }
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 7)?;
    let system = args.get_or("system", "P3-LLM");
    let scheme = args.get("scheme");
    let scale = args.get_f64("scale", 1.0)?;

    let mut scenarios: Vec<Scenario> =
        select_scenarios(args, if smoke { "smoke" } else { "chat-poisson" })?
            .into_iter()
            .map(|s| s.with_scale(scale))
            .collect::<Result<_>>()?;
    apply_tier_flags(args, &mut scenarios)?;

    let mut replica_counts = vec![];
    for tok in args.get_list("replicas", if smoke { "2" } else { "1,2,4" }) {
        // malformed or zero counts are typed errors, not silent clamps
        let n = tok.parse().ok().filter(|&n: &usize| n >= 1).ok_or(
            P3Error::InvalidFlag {
                flag: "replicas".into(),
                value: tok.clone(),
            },
        )?;
        replica_counts.push(n);
    }
    if replica_counts.is_empty() {
        replica_counts.push(1);
    }

    let policies: Vec<String> =
        if args.get_or("policy", "jsq").eq_ignore_ascii_case("all") {
            all_policy_names().iter().map(|s| s.to_string()).collect()
        } else {
            args.get_list("policy", "jsq")
        };
    for p in &policies {
        if policy_by_name(p).is_none() {
            return Err(P3Error::InvalidConfig(format!(
                "unknown routing policy {p:?} (see `p3llm cluster --list`)"
            )));
        }
    }

    let mut t = Table::new(
        format!("cluster: scenario x policy x replicas on {system}, seed {seed}"),
        &[
            "scenario",
            "policy",
            "replicas",
            "done",
            "SLO %",
            "goodput req/s",
            "goodput tok/s",
            "tok/s",
            "p95 TTFT ms",
            "hit %",
            "overlap",
            "skew",
            "scale-eff %",
        ],
    );
    let mut tiers_t = Table::new(
        "per-tier fleet breakdown (SLO budget x tier slo_factor)",
        &TIER_HEADERS,
    );
    let mut bench_records: Vec<BenchRecord> = vec![];
    for sc in &scenarios {
        let sat = sc.saturation_tok_s(system);
        for pol in &policies {
            let run_n = |n: usize| -> Result<ClusterOutcome> {
                let fleet_sc = sc.clone().for_fleet(n)?;
                let mut cl =
                    Cluster::from_scenario(sc, system, scheme, n, pol)?;
                cl.run(&fleet_sc.runner(seed), sat)
            };
            // a 1-replica run anchors the scaling-efficiency column
            let base = run_n(1)?;
            let base_goodput = base.report.fleet.goodput_tok_s;
            for &n in &replica_counts {
                let out = if n == 1 { base.clone() } else { run_n(n)? };
                let rep = out.report.with_baseline(base_goodput);
                let r = &rep.fleet;
                if smoke && (r.goodput_tok_s <= 0.0 || r.completed < r.offered)
                {
                    return Err(P3Error::Serve(format!(
                        "cluster smoke gate: {} x{n} via {pol}: goodput \
                         {:.2} tok/s, {}/{} completed",
                        sc.name, r.goodput_tok_s, r.completed, r.offered
                    )));
                }
                t.row(vec![
                    sc.name.into(),
                    pol.clone(),
                    n.to_string(),
                    format!("{}/{}", r.completed, r.offered),
                    f2(r.slo_attainment * 100.0),
                    f2(r.goodput_req_s),
                    f2(r.goodput_tok_s),
                    f2(r.throughput_tok_s),
                    f2(r.ttft_ms.p95),
                    f2(r.prefix_hit_rate * 100.0),
                    f2(r.overlap_factor),
                    f2(rep.util_skew),
                    rep.scaling_efficiency
                        .map(|e| f2(e * 100.0))
                        .unwrap_or_else(|| "-".into()),
                ]);
                tier_rows(
                    &mut tiers_t,
                    sc.name,
                    &format!("{pol} x{n}"),
                    &rep.fleet,
                );
                if smoke {
                    bench_records.push(BenchRecord::new(
                        format!(
                            "scenario={},policy={pol},replicas={n}",
                            sc.name
                        ),
                        "goodput_tok_s",
                        r.goodput_tok_s,
                    ));
                }
            }
        }
    }
    t.print();
    if !tiers_t.rows.is_empty() {
        tiers_t.print();
    }
    if smoke {
        let path = p3llm::benchkit::save_bench_json(
            "cluster_smoke",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
    }
    if args.has("save") {
        save_tables(&t, Some(&tiers_t), "cluster")?;
    }
    Ok(())
}

/// One curve point of the overload sweep as a hand-rolled JSON object
/// (`BENCH_overload.json` carries no serde dependency).
fn curve_json(victim: &str, load: f64, r: &LoadReport) -> String {
    let mut tiers = String::new();
    for (i, (class, cr)) in r.per_class.iter().enumerate() {
        if i > 0 {
            tiers.push(',');
        }
        tiers.push_str(&format!(
            "{{\"tier\":\"{}\",\"goodput_req_s\":{:.6},\
             \"attainment\":{:.6},\"ttft_p99_ms\":{:.6}}}",
            class.name(),
            cr.goodput_req_s,
            cr.slo_attainment,
            cr.ttft_ms.p99
        ));
    }
    format!(
        "{{\"victim\":\"{victim}\",\"load\":{load},\"offered\":{},\
         \"completed\":{},\"preemptions\":{},\"pages_swapped\":{},\
         \"pages_recomputed\":{},\"goodput_tok_s\":{:.6},\
         \"attainment\":{:.6},\"tiers\":[{tiers}]}}",
        r.offered,
        r.completed,
        r.preemptions,
        r.pages_swapped,
        r.pages_recomputed,
        r.goodput_tok_s,
        r.slo_attainment
    )
}

/// The interactive-tier sub-report of a tiered run, if present.
fn interactive_report(r: &LoadReport) -> Option<&LoadReport> {
    r.per_class
        .iter()
        .find(|(c, _)| *c == SloClass::Interactive)
        .map(|(_, cr)| cr)
}

/// Sweep offered load past the modeled saturation point with SLO
/// classes and preemptive scheduling.  Load factors are
/// offered/saturation ratios (`Scenario::with_load_factor`), so "2x"
/// means the same thing on every system; each victim policy is swept
/// next to a FIFO baseline (same tiers, no preemption).
fn cmd_overload(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 7)?;
    let system = args.get_or("system", "P3-LLM").to_string();
    let scheme = args.get("scheme");
    let default_sc = if smoke { "smoke-overload" } else { "flash-crowd" };
    let name = args.get_or("scenario", default_sc);
    let mut sc = traffic::scenario_by_name(name).ok_or_else(|| {
        P3Error::InvalidConfig(format!(
            "unknown scenario {name:?} (see `p3llm loadtest --list`)"
        ))
    })?;
    if args.get("requests").is_some() {
        sc.n_requests = args.get_usize("requests", 1)?.max(1);
    }
    if let Some(spec) = args.get("tiers") {
        sc.tiers = Some(TierMix::parse(spec)?);
    }
    if sc.tiers.is_none() {
        // overload degradation is only meaningful with mixed tiers
        sc.tiers = Some(TierMix::mixed());
    }
    let mut victims: Vec<&'static str> = vec![];
    for v in args.get_list("victim", "recompute") {
        let p = victim_by_name(&v).ok_or_else(|| P3Error::InvalidFlag {
            flag: "victim".into(),
            value: v.clone(),
        })?;
        if !victims.contains(&p.name()) {
            victims.push(p.name());
        }
    }
    if victims.is_empty() {
        victims.push("recompute");
    }
    let mut loads: Vec<f64> = vec![];
    for tok in args.get_list("load", if smoke { "2" } else { "1,2" }) {
        let f = tok
            .parse::<f64>()
            .ok()
            .filter(|f| f.is_finite() && *f > 0.0)
            .ok_or_else(|| P3Error::InvalidFlag {
                flag: "load".into(),
                value: tok.clone(),
            })?;
        loads.push(f);
    }
    if loads.is_empty() {
        loads = if smoke { vec![2.0] } else { vec![1.0, 2.0] };
    }

    // one point: pin offered load to `load` x saturation, set the
    // victim policy (None = FIFO baseline), optionally re-judge the
    // records against an override SLO (the smoke gate's calibrated
    // budget)
    let run_one = |victim: Option<&'static str>,
                   load: f64,
                   slo: Option<SloSpec>|
     -> Result<LoadReport> {
        let mut s = sc.clone().with_load_factor(&system, load, seed)?;
        s.victim = victim;
        let mut engine = s.engine(&system, scheme)?;
        let mut plan = s.runner(seed);
        if let Some(slo) = slo {
            plan.slo = slo;
        }
        let out = plan
            .run_with_saturation(&mut engine, s.saturation_tok_s(&system))?;
        Ok(out.report)
    };

    let mut t = Table::new(
        format!(
            "overload: {} on {system}, seed {seed} \
             (load = offered/saturation)",
            sc.name
        ),
        &[
            "victim",
            "load",
            "done",
            "SLO %",
            "goodput tok/s",
            "p99 TTFT ms",
            "preempt",
            "swapped",
            "recomputed",
        ],
    );
    let mut tiers_t = Table::new(
        "per-tier breakdown (SLO budget x tier slo_factor)",
        &TIER_HEADERS,
    );
    let mut curves = String::new();
    let mut bench_records: Vec<BenchRecord> = vec![];
    for &load in &loads {
        for victim in victims.iter().map(|v| Some(*v)).chain([None]) {
            let label = victim.unwrap_or("fifo");
            let r = run_one(victim, load, None)?;
            if smoke && r.completed < r.offered {
                return Err(P3Error::Serve(format!(
                    "overload smoke gate: {label} at {load}x lost \
                     requests ({}/{} completed)",
                    r.completed, r.offered
                )));
            }
            t.row(vec![
                label.into(),
                format!("{load}x"),
                format!("{}/{}", r.completed, r.offered),
                f2(r.slo_attainment * 100.0),
                f2(r.goodput_tok_s),
                f2(r.ttft_ms.p99),
                r.preemptions.to_string(),
                r.pages_swapped.to_string(),
                r.pages_recomputed.to_string(),
            ]);
            tier_rows(&mut tiers_t, sc.name, &format!("{label}@{load}x"), &r);
            if smoke {
                let cfg = format!("victim={label},load={load}");
                bench_records.push(BenchRecord::new(
                    cfg.clone(),
                    "goodput_tok_s",
                    r.goodput_tok_s,
                ));
                bench_records.push(BenchRecord::new(
                    cfg,
                    "attainment",
                    r.slo_attainment,
                ));
            }
            if !curves.is_empty() {
                curves.push(',');
            }
            curves.push_str(&curve_json(label, load, &r));
        }
    }
    t.print();
    if !tiers_t.rows.is_empty() {
        tiers_t.print();
    }

    if smoke {
        // (a) determinism: an identical in-process re-sweep must agree
        // bit-for-bit (ci.sh additionally diffs two full process runs)
        let mut curves2 = String::new();
        for &load in &loads {
            for victim in victims.iter().map(|v| Some(*v)).chain([None]) {
                let r = run_one(victim, load, None)?;
                if !curves2.is_empty() {
                    curves2.push(',');
                }
                curves2.push_str(&curve_json(victim.unwrap_or("fifo"), load, &r));
            }
        }
        if curves2 != curves {
            return Err(P3Error::Serve(
                "overload smoke gate: two identical sweeps disagreed \
                 (nondeterminism)"
                    .into(),
            ));
        }
        // (b) the absolute SLO budget is meaningless for the tiny CI
        // model, so calibrate one: interactive p95 TTFT at 0.1x
        // saturation under FIFO, with 8x headroom
        let calib = run_one(None, 0.1, None)?;
        let t_base = interactive_report(&calib)
            .map(|c| c.ttft_ms.p95)
            .unwrap_or(calib.ttft_ms.p95);
        if !(t_base > 0.0) {
            return Err(P3Error::Serve(
                "overload smoke gate: calibration run produced no \
                 interactive TTFT"
                    .into(),
            ));
        }
        let budget =
            SloSpec { ttft_ms: 8.0 * t_base, tpot_ms: f64::INFINITY };
        // (c) at the heaviest load (2x saturation by default) every
        // preemptive engine must lose nothing, preempt at least once,
        // and hold interactive attainment >= 0.9 under the calibrated
        // budget; the FIFO baseline must lose nothing either but
        // strictly miss every preemptive engine's attainment
        let heavy = loads.iter().cloned().fold(f64::MIN, f64::max);
        let att_of = |r: &LoadReport, label: &str| -> Result<f64> {
            if r.completed < r.offered {
                return Err(P3Error::Serve(format!(
                    "overload smoke gate: {label} at {heavy}x lost \
                     requests ({}/{} completed)",
                    r.completed, r.offered
                )));
            }
            interactive_report(r).map(|c| c.slo_attainment).ok_or_else(
                || {
                    P3Error::Serve(format!(
                        "overload smoke gate: {label} run carried no \
                         interactive tier"
                    ))
                },
            )
        };
        let fifo = run_one(None, heavy, Some(budget))?;
        let fifo_att = att_of(&fifo, "fifo")?;
        for &v in &victims {
            let r = run_one(Some(v), heavy, Some(budget))?;
            let att = att_of(&r, v)?;
            if r.preemptions == 0 {
                return Err(P3Error::Serve(format!(
                    "overload smoke gate: {v} at {heavy}x never \
                     preempted"
                )));
            }
            if att < 0.9 || att <= fifo_att {
                return Err(P3Error::Serve(format!(
                    "overload smoke gate: {v} at {heavy}x interactive \
                     attainment {:.3} (need >= 0.9 and > FIFO's {:.3})",
                    att, fifo_att
                )));
            }
            println!(
                "smoke gate: {v} at {heavy}x: interactive attainment \
                 {:.3} vs FIFO {:.3} (budget {:.3} ms), {} preemptions",
                att, fifo_att, budget.ttft_ms, r.preemptions
            );
        }
        let path = p3llm::benchkit::save_bench_json(
            "overload_smoke",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
    }

    if args.has("save") {
        save_tables(&t, Some(&tiers_t), "overload")?;
        let dir = p3llm::benchkit::reports_dir();
        let json = format!(
            "{{\"bench\":\"overload\",\"scenario\":\"{}\",\
             \"system\":\"{system}\",\"seed\":{seed},\
             \"curves\":[{curves}]}}\n",
            sc.name
        );
        let path = dir.join("BENCH_overload.json");
        std::fs::write(&path, json).map_err(|e| P3Error::io(&path, e))?;
        println!("saved {}", path.display());
    }
    Ok(())
}

/// Run one scenario with telemetry on: export a Chrome trace-event
/// JSON (open in Perfetto or about:tracing), print the per-lane
/// utilization table and NPU/PIM overlap factor, and flight-dump
/// requests that missed their TTFT budget or died in an error path.
/// `--smoke` turns the run into the deterministic CI gate `ci.sh`
/// wires in.
fn cmd_trace(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 7)?;
    let system = args.get_or("system", "P3-LLM").to_string();
    let scheme = args.get("scheme");
    let default_sc = if smoke { "smoke-overload" } else { "chat-poisson" };
    let name = args.get_or("scenario", default_sc);
    let mut sc = traffic::scenario_by_name(name).ok_or_else(|| {
        P3Error::InvalidConfig(format!(
            "unknown scenario {name:?} (see `p3llm loadtest --list`)"
        ))
    })?;
    if args.get("requests").is_some() {
        sc.n_requests = args.get_usize("requests", 1)?.max(1);
    }
    apply_tier_flags(args, std::slice::from_mut(&mut sc))?;
    if smoke {
        // overload at 2x saturation with the swap victim: preemptions,
        // restores, bounces, and bus traffic all show up in the trace
        sc = sc.with_load_factor(&system, 2.0, seed)?;
        if sc.tiers.is_none() {
            sc.tiers = Some(TierMix::mixed());
        }
        if sc.victim.is_none() {
            sc.victim = Some("swap");
        }
    }
    let replicas = args.get_usize("replicas", 1)?.max(1);
    let policy = args.get_or("policy", "jsq").to_string();
    if policy_by_name(&policy).is_none() {
        return Err(P3Error::InvalidConfig(format!(
            "unknown routing policy {policy:?} (see `p3llm cluster --list`)"
        )));
    }
    let ring = args.get_usize("ring", 1 << 18)?.max(1);
    let sample_k = args.get_usize("sample-requests", 4)?;
    let flight_last = args.get_usize("flight-last", 16)?.max(1);
    let flight_on_miss = args.has("flight-on-miss") || smoke;
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => p3llm::benchkit::reports_dir().join("trace.json"),
    };

    let run = |trace: &Trace| -> Result<LoadReport> {
        if replicas > 1 {
            let fleet_sc = sc.clone().for_fleet(replicas)?;
            let mut cl = Cluster::from_scenario_traced(
                &sc, &system, scheme, replicas, &policy, trace,
            )?;
            let out = cl
                .run(&fleet_sc.runner(seed), sc.saturation_tok_s(&system))?;
            Ok(out.report.fleet)
        } else {
            let mut engine = sc.engine(&system, scheme)?;
            engine.set_trace(trace.clone());
            let plan = sc.runner(seed);
            let out = plan.run_with_saturation(
                &mut engine,
                sc.saturation_tok_s(&system),
            )?;
            Ok(out.report)
        }
    };

    let trace = Trace::ring(ring);
    let report = run(&trace)?;
    let events = trace.snapshot();
    let sampled = export::sample_requests(&events, sample_k);
    let json = export::chrome_trace_json(&events, &sampled);

    print_load_report(&report);
    let util = summary::utilization(&events);
    util.table().print();
    if !util.overlap.is_empty() {
        println!("{}", util.overlap_lines());
    }
    println!(
        "trace: {} events recorded ({} dropped), {} request tracks sampled",
        events.len(),
        trace.dropped(),
        sampled.len()
    );

    if let Some(dir) = out_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| P3Error::io(dir, e))?;
        }
    }
    std::fs::write(&out_path, &json)
        .map_err(|e| P3Error::io(&out_path, e))?;
    println!("saved {}", out_path.display());
    if args.has("save") {
        save_tables(&util.table(), None, "trace_util")?;
    }

    if flight_on_miss {
        // judge TTFT against the scenario's own budget (scaled per
        // tier); the smoke gate injects an impossible zero budget so
        // the recorder provably fires
        let base_ttft = if smoke { 0.0 } else { sc.slo.ttft_ms };
        let mut dumps: Vec<(u32, u64, Option<f64>)> =
            flight::ttft_misses(&events, base_ttft)
                .into_iter()
                .map(|(rep, rid, ttft)| (rep, rid, Some(ttft)))
                .collect();
        for (rep, rid) in flight::error_requests(&events) {
            if !dumps.iter().any(|d| d.0 == rep && d.1 == rid) {
                dumps.push((rep, rid, None));
            }
        }
        if dumps.is_empty() {
            println!("flight recorder: no SLO misses, nothing to dump");
        }
        for (i, (rep, rid, ttft)) in dumps.iter().enumerate() {
            if i >= 3 {
                println!(
                    "flight recorder: ... {} more missing requests \
                     (not dumped)",
                    dumps.len() - i
                );
                break;
            }
            let why = match ttft {
                Some(t) => format!("TTFT {t:.3} ms over budget"),
                None => "error terminal".into(),
            };
            println!(
                "flight recorder: replica {rep} request {rid} ({why}), \
                 last {flight_last} events:"
            );
            println!(
                "{}",
                flight::render(&flight::flight_dump(
                    &events,
                    *rep,
                    *rid,
                    flight_last
                ))
            );
        }
    }

    if smoke {
        // (a) a second identical in-process run must export
        // byte-identical JSON (ci.sh additionally diffs two processes)
        let trace2 = Trace::ring(ring);
        let report2 = run(&trace2)?;
        let events2 = trace2.snapshot();
        let json2 = export::chrome_trace_json(
            &events2,
            &export::sample_requests(&events2, sample_k),
        );
        if json2 != json || report2 != report {
            return Err(P3Error::Serve(
                "trace smoke gate: two identical runs disagreed \
                 (nondeterminism)"
                    .into(),
            ));
        }
        if trace.dropped() > 0 {
            return Err(P3Error::Serve(format!(
                "trace smoke gate: ring dropped {} events (raise --ring)",
                trace.dropped()
            )));
        }
        // (b) the device timelines must actually light up
        for lane in [TraceLane::Npu, TraceLane::Pim, TraceLane::Bus] {
            let busy: f64 = (0..replicas as u32)
                .map(|r| util.busy_ms(r, lane))
                .sum();
            if !(busy > 0.0) {
                return Err(P3Error::Serve(format!(
                    "trace smoke gate: {} lane shows zero busy time",
                    lane.name()
                )));
            }
        }
        // (c) at least one complete enqueue -> retire span chain
        let complete =
            events.iter().filter(|e| e.name == "retire").any(|e| {
                events.iter().any(|q| {
                    q.name == "enqueue"
                        && q.replica == e.replica
                        && q.rid == e.rid
                })
            });
        if !complete {
            return Err(P3Error::Serve(
                "trace smoke gate: no complete enqueue->retire span chain"
                    .into(),
            ));
        }
        // (d) the flight recorder fired on the injected zero budget
        if flight::ttft_misses(&events, 0.0).is_empty() {
            return Err(P3Error::Serve(
                "trace smoke gate: flight recorder found no TTFT misses \
                 under a zero budget"
                    .into(),
            ));
        }
        // (e) zero-overhead proof: the same run with telemetry off
        // must produce an identical report and record nothing
        let off = Trace::off();
        let plain = run(&off)?;
        if plain != report {
            return Err(P3Error::Serve(
                "trace smoke gate: disabled telemetry perturbed the run"
                    .into(),
            ));
        }
        let mut bench_records: Vec<BenchRecord> = vec![BenchRecord::new(
            format!("scenario={}", sc.name),
            "events",
            events.len() as f64,
        )];
        for l in &util.lanes {
            bench_records.push(BenchRecord::new(
                format!("replica={},lane={}", l.replica, l.lane.name()),
                "busy_ms",
                l.busy_ms,
            ));
        }
        let path = p3llm::benchkit::save_bench_json(
            "trace_smoke",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
        println!(
            "smoke gate: deterministic export, all device lanes busy, \
             complete request chains, flight recorder fired; telemetry \
             off: report identical, {} events recorded",
            off.snapshot().len()
        );
    }
    Ok(())
}

/// Print every burn-rate alert transition the run recorded, in order.
fn print_alert_timeline(events: &[AlertEvent]) {
    if events.is_empty() {
        println!("alerts: none (no burn-rate rule transitioned)");
        return;
    }
    println!("alerts: {} transitions", events.len());
    for e in events {
        println!(
            "  {:>10.1} ms  {:<12} {:<9} burn={:.2} rule={}",
            e.ts_ms,
            e.class.name(),
            e.kind.name(),
            e.burn,
            e.rule
        );
    }
}

/// Time-bucketed view of the scraped series over `[0, end_ms]`: mean
/// per bucket for the headline gauges plus each tier's fast-window
/// burn rate.  Empty buckets (idle gaps between arrivals) print "-".
fn series_table(obs: &Obs, end_ms: f64, buckets: usize) -> Table {
    let mut t = Table::new(
        format!("scraped series ({} scrapes, mean per bucket)", obs.scrapes()),
        &["t ms", "queue", "lanes", "kv MB", "burn I", "burn B", "burn E"],
    );
    let cols: Vec<(Vec<Point>, f64)> = vec![
        (obs.series_points("queue_depth", None), 1.0),
        (obs.series_points("active_lanes", None), 1.0),
        (obs.series_points("kv_used_bytes", None), 1e-6),
        (obs.series_points(BURN_FAST, Some(SloClass::Interactive)), 1.0),
        (obs.series_points(BURN_FAST, Some(SloClass::Batch)), 1.0),
        (obs.series_points(BURN_FAST, Some(SloClass::BestEffort)), 1.0),
    ];
    let buckets = buckets.max(1);
    let w = (end_ms / buckets as f64).max(1e-9);
    for b in 0..buckets {
        let lo = b as f64 * w;
        let hi = lo + w;
        let last = b + 1 == buckets;
        let mut row = vec![format!("{:.0}-{:.0}", lo, hi)];
        for (pts, scale) in &cols {
            let vals: Vec<f64> = pts
                .iter()
                .filter(|p| p.ts_ms >= lo && (p.ts_ms < hi || last))
                .map(|p| p.value * scale)
                .collect();
            row.push(if vals.is_empty() {
                "-".into()
            } else {
                f2(vals.iter().sum::<f64>() / vals.len() as f64)
            });
        }
        t.row(row);
    }
    t
}

/// Flight-dump the in-flight context of the first firing alert the
/// trace recorded: which requests were doing what when the burn rate
/// crossed the firing threshold.
fn print_alert_flight(trace: &Trace, flight_last: usize) {
    let events = trace.snapshot();
    let firings = flight::alert_firings(&events);
    let Some(&(rep, class, ts, burn)) = firings.first() else {
        return;
    };
    let tier = class.map(|c| format!(" {}", c.name())).unwrap_or_default();
    println!(
        "flight recorder: first firing alert (replica {rep}{tier} at \
         {ts:.1} ms, burn {burn:.2}), last {flight_last} in-flight \
         events:"
    );
    println!(
        "{}",
        flight::render(&flight::alert_context_dump(&events, ts, flight_last))
    );
}

/// Keep the scrape clock ticking through the quiet tail after the last
/// retire so trailing burn windows can observe the recovery and firing
/// alerts can resolve (the engine only scrapes while it steps).
/// Returns the last scrape timestamp.
fn cool_down(obs: &Obs, from_ms: f64, step_ms: f64, horizon_ms: f64) -> f64 {
    // resume from wherever the scrape clock actually stopped (the
    // makespan is relative to the first arrival, which can lag the
    // engine clock) so series timestamps stay monotone
    let from = obs.last_scrape_ms().unwrap_or(from_ms).max(from_ms);
    let step = step_ms.max(1e-3);
    let mut t_end = from;
    let mut k = 1u64;
    while (k as f64) * step <= horizon_ms + 1e-9 {
        t_end = from + k as f64 * step;
        obs.scrape_now(t_end);
        k += 1;
    }
    t_end
}

/// Continuous observability over the closed-loop runner: scrape the
/// obs layer on a fixed virtual-clock cadence while scenarios run,
/// then print the time-bucketed series, the alert timeline, and the
/// fleet health snapshot, and export Prometheus text + JSON series.
/// `--smoke` is the CI gate ci.sh wires in.
fn cmd_monitor(args: &Args) -> Result<()> {
    if args.has("smoke") {
        return monitor_smoke(args);
    }
    let seed = args.get_u64("seed", 7)?;
    let system = args.get_or("system", "P3-LLM").to_string();
    let scheme = args.get("scheme");
    let mut scenarios = select_scenarios(args, "flash-crowd")?;
    apply_tier_flags(args, &mut scenarios)?;
    let replicas = args.get_usize("replicas", 1)?.max(1);
    let policy = args.get_or("policy", "jsq").to_string();
    if policy_by_name(&policy).is_none() {
        return Err(P3Error::InvalidConfig(format!(
            "unknown routing policy {policy:?} (see `p3llm cluster --list`)"
        )));
    }
    let scrape = args.get_f64("scrape-ms", 50.0)?.max(1e-3);
    let fast = args.get_f64("fast-ms", 1_000.0)?.max(1e-3);
    let slow = args.get_f64("slow-ms", 4_000.0)?.max(1e-3);
    let flight_last = args.get_usize("flight-last", 16)?.max(1);

    for (i, sc) in scenarios.iter_mut().enumerate() {
        if sc.tiers.is_none() {
            // burn-rate rules are per tier; an untiered run would only
            // ever exercise the interactive rule
            sc.tiers = Some(TierMix::mixed());
        }
        if let Some(tok) = args.get("load") {
            let f = tok
                .parse::<f64>()
                .ok()
                .filter(|f| f.is_finite() && *f > 0.0)
                .ok_or_else(|| P3Error::InvalidFlag {
                    flag: "load".into(),
                    value: tok.into(),
                })?;
            *sc = sc.clone().with_load_factor(&system, f, seed)?;
        }
        let obs =
            Obs::new(ObsConfig::with_windows(sc.slo, scrape, fast, slow));
        let trace = Trace::ring(1 << 18);
        obs.set_trace(trace.clone());
        let report = if replicas > 1 {
            let fleet_sc = sc.clone().for_fleet(replicas)?;
            let mut cl = Cluster::from_scenario_observed(
                sc, &system, scheme, replicas, &policy, &trace, &obs,
            )?;
            cl.run(&fleet_sc.runner(seed), sc.saturation_tok_s(&system))?
                .report
                .fleet
        } else {
            let mut engine = sc.engine(&system, scheme)?;
            engine.set_trace(trace.clone());
            engine.set_obs(obs.clone());
            sc.runner(seed)
                .run_with_saturation(
                    &mut engine,
                    sc.saturation_tok_s(&system),
                )?
                .report
        };
        let t_end =
            cool_down(&obs, report.makespan_ms, scrape, slow + 2.0 * fast);

        if i > 0 {
            println!();
        }
        println!(
            "monitor: {} on {system}, seed {seed}, {replicas} replica(s), \
             scrape every {scrape} ms",
            sc.name
        );
        print_load_report(&report);
        let mut tiers_t = Table::new(
            "per-tier breakdown (SLO budget x tier slo_factor)",
            &TIER_HEADERS,
        );
        tier_rows(&mut tiers_t, sc.name, "monitor", &report);
        if !tiers_t.rows.is_empty() {
            tiers_t.print();
        }
        let st = series_table(&obs, t_end, 8);
        st.print();
        print_alert_timeline(&obs.events());
        let h = obs.health(
            t_end,
            Some(report.throughput_tok_s),
            report.saturation_tok_s,
        );
        println!("{}", h.render());
        print_alert_flight(&trace, flight_last);

        let dir = p3llm::benchkit::reports_dir();
        let prom_path = match args.get("out") {
            Some(p) => std::path::PathBuf::from(p),
            None => dir.join(format!("metrics_{}.prom", sc.name)),
        };
        let json_path = match args.get("json-out") {
            Some(p) => std::path::PathBuf::from(p),
            None => dir.join(format!("metrics_{}_series.json", sc.name)),
        };
        for (path, body) in
            [(&prom_path, obs.prometheus()), (&json_path, obs.series_json())]
        {
            if let Some(d) = path.parent() {
                if !d.as_os_str().is_empty() {
                    std::fs::create_dir_all(d)
                        .map_err(|e| P3Error::io(d, e))?;
                }
            }
            std::fs::write(path, body).map_err(|e| P3Error::io(path, e))?;
            println!("saved {}", path.display());
        }
        if args.has("save") {
            save_tables(&st, Some(&tiers_t), "monitor")?;
        }
    }
    Ok(())
}

/// The `monitor --smoke` CI gate: a calibrated flash crowd on the tiny
/// sim model proving (a) the interactive burn-rate alert fires
/// strictly before the end-of-run report can show the attainment dip
/// and resolves after the crowd subsides, (b) a metrics-off run is
/// report-identical with zero series points, and (c) the Prometheus +
/// JSON exports are byte-deterministic across runs.
fn monitor_smoke(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 7)?;
    let flight_last = args.get_usize("flight-last", 16)?.max(1);
    let build = |obs: &Obs, trace: &Trace| -> Result<Engine> {
        let mut e = EngineBuilder::sim()
            .model("tiny-1M")
            .max_batch(2)
            .ctx_limit(128)
            .preempt("recompute")
            .build()?;
        e.set_trace(trace.clone());
        e.set_obs(obs.clone());
        Ok(e)
    };

    // the absolute SLO budget is meaningless for the tiny CI model, so
    // calibrate one: p95 TTFT under a deliberately calm probe, with 6x
    // headroom (same idiom as the overload gate)
    let probe = LoadRunner::from_plan(
        (0..8).map(|i| i as f64 * 200.0).collect(),
        vec![(16, 8); 8],
        SloSpec::chatbot(),
        seed,
    );
    let mut eng = build(&Obs::off(), &Trace::off())?;
    let t_base = probe.run(&mut eng)?.report.ttft_ms.p95;
    if !(t_base > 0.0) {
        return Err(P3Error::Serve(
            "monitor smoke gate: calibration run produced no TTFT".into(),
        ));
    }
    let budget = SloSpec { ttft_ms: 6.0 * t_base, tpot_ms: f64::INFINITY };

    // calm lead-in -> flash crowd -> calm recovery, all timed in units
    // of the calibrated TTFT so the shape survives cost-model changes
    let mk_plan = || -> LoadRunner {
        let mut arrivals = vec![];
        let mut shapes = vec![];
        let mut classes = vec![];
        for i in 0..12 {
            arrivals.push(i as f64 * 8.0 * t_base);
            shapes.push((16, 8));
            classes.push(SloClass::Interactive);
        }
        let burst_t = 96.0 * t_base;
        for i in 0..32 {
            arrivals.push(burst_t);
            shapes.push((16, 8));
            classes.push(match i % 4 {
                0 | 1 => SloClass::Interactive,
                2 => SloClass::Batch,
                _ => SloClass::BestEffort,
            });
        }
        for i in 0..16 {
            arrivals.push(220.0 * t_base + i as f64 * 12.0 * t_base);
            shapes.push((16, 8));
            classes.push(SloClass::Interactive);
        }
        LoadRunner::from_plan(arrivals, shapes, budget, seed)
            .with_classes(classes)
    };
    let scrape = 2.0 * t_base;
    let fast = 24.0 * t_base;
    let slow = 60.0 * t_base;
    let run_obs = || -> Result<(LoadReport, Obs, Trace, f64)> {
        let obs =
            Obs::new(ObsConfig::with_windows(budget, scrape, fast, slow));
        let trace = Trace::ring(1 << 18);
        obs.set_trace(trace.clone());
        let mut eng = build(&obs, &trace)?;
        let report = mk_plan().run(&mut eng)?.report;
        let t_end =
            cool_down(&obs, report.makespan_ms, scrape, slow + 2.0 * fast);
        Ok((report, obs, trace, t_end))
    };

    let (report, obs, trace, t_end) = run_obs()?;
    print_load_report(&report);
    series_table(&obs, t_end, 8).print();
    let events = obs.events();
    print_alert_timeline(&events);
    let h = obs.health(
        t_end,
        Some(report.throughput_tok_s),
        report.saturation_tok_s,
    );
    println!("{}", h.render());
    print_alert_flight(&trace, flight_last);

    // (a) alert leads the terminal report: firing strictly before the
    // makespan, resolution strictly after firing, and the end-of-run
    // attainment does show the dip the alert called early
    let firing = events
        .iter()
        .find(|e| {
            e.class == SloClass::Interactive && e.kind == AlertKind::Firing
        })
        .ok_or_else(|| {
            P3Error::Serve(
                "monitor smoke gate: interactive burn-rate alert never \
                 fired during the flash crowd"
                    .into(),
            )
        })?;
    let resolved = events
        .iter()
        .find(|e| {
            e.class == SloClass::Interactive
                && e.kind == AlertKind::Resolved
                && e.ts_ms > firing.ts_ms
        })
        .ok_or_else(|| {
            P3Error::Serve(
                "monitor smoke gate: firing alert never resolved after \
                 the crowd subsided"
                    .into(),
            )
        })?;
    let lead = report.makespan_ms - firing.ts_ms;
    if !(lead > 0.0) {
        return Err(P3Error::Serve(format!(
            "monitor smoke gate: alert fired at {:.1} ms, not before the \
             end of the run ({:.1} ms)",
            firing.ts_ms, report.makespan_ms
        )));
    }
    let att = report
        .class_attainment(SloClass::Interactive)
        .unwrap_or(report.slo_attainment);
    if !(att < 1.0) {
        return Err(P3Error::Serve(
            "monitor smoke gate: flash crowd left no attainment dip to \
             alert on"
                .into(),
        ));
    }
    if flight::alert_firings(&trace.snapshot()).is_empty() {
        return Err(P3Error::Serve(
            "monitor smoke gate: firing alert never reached the trace \
             stream"
                .into(),
        ));
    }

    // (b) zero-cost when disabled: the identical plan with metrics and
    // telemetry off must produce a byte-identical LoadReport
    let mut plain_eng = build(&Obs::off(), &Trace::off())?;
    let plain = mk_plan().run(&mut plain_eng)?.report;
    if plain != report {
        return Err(P3Error::Serve(
            "monitor smoke gate: disabled metrics perturbed the run"
                .into(),
        ));
    }

    // (c) deterministic exports: a second instrumented run must agree
    // byte-for-byte (ci.sh additionally diffs two full process runs)
    let (report2, obs2, _trace2, _) = run_obs()?;
    if report2 != report
        || obs2.prometheus() != obs.prometheus()
        || obs2.series_json() != obs.series_json()
    {
        return Err(P3Error::Serve(
            "monitor smoke gate: two identical runs disagreed \
             (nondeterminism)"
                .into(),
        ));
    }

    let bench_records = vec![
        BenchRecord::new("scenario=flash-smoke", "alert_lead_ms", lead),
        BenchRecord::new(
            "scenario=flash-smoke",
            "firing_ts_ms",
            firing.ts_ms,
        ),
        BenchRecord::new(
            "scenario=flash-smoke",
            "resolved_ts_ms",
            resolved.ts_ms,
        ),
        BenchRecord::new(
            "scenario=flash-smoke",
            "interactive_attainment",
            att,
        ),
        BenchRecord::new(
            "scenario=flash-smoke",
            "series_points",
            obs.total_points() as f64,
        ),
        BenchRecord::new(
            "scenario=flash-smoke",
            "alert_transitions",
            events.len() as f64,
        ),
    ];
    let path =
        p3llm::benchkit::save_bench_json("monitor", seed, &bench_records)
            .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
    println!("saved {}", path.display());
    println!(
        "smoke gate: interactive burn-rate alert fired at {:.1} ms, \
         {:.1} ms before the end-of-run report (makespan {:.1} ms, \
         attainment {:.3}); resolved at {:.1} ms after the crowd \
         subsided",
        firing.ts_ms, lead, report.makespan_ms, att, resolved.ts_ms
    );
    println!(
        "smoke gate: metrics off: report identical, 0 series points; \
         instrumented exports byte-identical across runs ({} scrapes, \
         {} series points)",
        obs.scrapes(),
        obs.total_points()
    );
    Ok(())
}

/// Sweep the tiered KV hierarchy: hot-tier fraction x ahead-of-decode
/// prefetch depth x scenario through the closed-loop runner.  Every
/// engine keeps its hot pages in PIM-attached HBM and overflows to the
/// modeled CXL cold pool; `--depth 0` is pure demand paging (each cold
/// page stalls the decode clock for one link transfer), larger depths
/// overlap the next attention window's pulls with decode.  `--smoke`
/// is the CI gate ci.sh wires in.
fn cmd_memtier(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 7)?;
    let system = args.get_or("system", "P3-LLM").to_string();
    let scheme = args.get("scheme");
    let mut scenarios = vec![];
    for name in args.get_list("scenario", "smoke-longdoc") {
        scenarios.push(traffic::scenario_by_name(&name).ok_or_else(|| {
            P3Error::InvalidConfig(format!(
                "unknown scenario {name:?} (see `p3llm loadtest --list`)"
            ))
        })?);
    }
    if args.get("requests").is_some() {
        let n = args.get_usize("requests", 1)?.max(1);
        for s in &mut scenarios {
            s.n_requests = n;
        }
    }
    let mut hots: Vec<f64> = vec![];
    for tok in args.get_list("hot", "0.25,0.5,1.0") {
        let f = tok
            .parse::<f64>()
            .ok()
            .filter(|f| f.is_finite() && *f > 0.0 && *f <= 1.0)
            .ok_or_else(|| P3Error::InvalidFlag {
                flag: "hot".into(),
                value: tok.clone(),
            })?;
        hots.push(f);
    }
    let mut depths: Vec<usize> = vec![];
    for tok in args.get_list("depth", "0,4,8") {
        let d = tok.parse::<usize>().ok().ok_or_else(|| {
            P3Error::InvalidFlag { flag: "depth".into(), value: tok.clone() }
        })?;
        depths.push(d);
    }

    let mut t = Table::new(
        format!(
            "memtier: hot-tier fraction x prefetch depth on {system}, \
             seed {seed}",
            ),
        &[
            "scenario",
            "hot",
            "depth",
            "done",
            "goodput tok/s",
            "p95 TTFT ms",
            "mean TPOT ms",
            "p95 TPOT ms",
            "prefetched",
            "demand",
        ],
    );
    let mut bench_records: Vec<BenchRecord> = vec![];
    for sc in &scenarios {
        for &hot in &hots {
            for &depth in &depths {
                let mut engine =
                    sc.engine_tiered(&system, scheme, hot, depth)?;
                let out = sc.runner(seed).run_with_saturation(
                    &mut engine,
                    sc.saturation_tok_s(&system),
                )?;
                let r = &out.report;
                if smoke && r.completed < r.offered {
                    return Err(P3Error::Serve(format!(
                        "memtier smoke gate: {} hot={hot} depth={depth} \
                         lost requests ({}/{} completed)",
                        sc.name, r.completed, r.offered
                    )));
                }
                t.row(vec![
                    sc.name.into(),
                    format!("{hot}"),
                    depth.to_string(),
                    format!("{}/{}", r.completed, r.offered),
                    f2(r.goodput_tok_s),
                    f2(r.ttft_ms.p95),
                    f3(r.tpot_ms.mean),
                    f3(r.tpot_ms.p95),
                    r.pages_prefetched.to_string(),
                    r.pages_demand.to_string(),
                ]);
                let cfg = format!(
                    "scenario={},hot={hot},depth={depth}",
                    sc.name
                );
                bench_records.push(BenchRecord::new(
                    cfg.clone(),
                    "tpot_mean_ms",
                    r.tpot_ms.mean,
                ));
                bench_records.push(BenchRecord::new(
                    cfg.clone(),
                    "pages_prefetched",
                    r.pages_prefetched as f64,
                ));
                bench_records.push(BenchRecord::new(
                    cfg,
                    "pages_demand",
                    r.pages_demand as f64,
                ));
            }
        }
    }
    t.print();

    if smoke {
        // (a) determinism: an identical in-process tiered re-run must
        // agree bit-for-bit (ci.sh additionally diffs two processes)
        let sc = traffic::scenario_by_name("smoke-longdoc").ok_or_else(
            || P3Error::InvalidConfig("smoke-longdoc missing".into()),
        )?;
        let run_tiered = |hot: f64, depth: usize| -> Result<LoadReport> {
            let mut engine = sc.engine_tiered(&system, scheme, hot, depth)?;
            let out = sc.runner(seed).run_with_saturation(
                &mut engine,
                sc.saturation_tok_s(&system),
            )?;
            Ok(out.report)
        };
        let pf = run_tiered(0.3, 4)?;
        if run_tiered(0.3, 4)? != pf {
            return Err(P3Error::Serve(
                "memtier smoke gate: two identical tiered runs \
                 disagreed (nondeterminism)"
                    .into(),
            ));
        }
        // (b) the long-doc scenario overflows the hot tier yet loses
        // nothing, and the prefetcher actually fires
        if pf.completed < pf.offered {
            return Err(P3Error::Serve(format!(
                "memtier smoke gate: smoke-longdoc lost requests \
                 ({}/{} completed)",
                pf.completed, pf.offered
            )));
        }
        if pf.pages_prefetched == 0 {
            return Err(P3Error::Serve(
                "memtier smoke gate: prefetcher never fired on an \
                 overflowing hot tier"
                    .into(),
            ));
        }
        // (c) prefetch-on strictly beats pure demand paging on mean
        // decode TPOT under identical seeds
        let dm = run_tiered(0.3, 0)?;
        if dm.completed < dm.offered || dm.pages_prefetched != 0 {
            return Err(P3Error::Serve(
                "memtier smoke gate: demand-paging baseline is broken"
                    .into(),
            ));
        }
        if !(pf.tpot_ms.mean < dm.tpot_ms.mean) {
            return Err(P3Error::Serve(format!(
                "memtier smoke gate: prefetch mean TPOT {:.4} ms !< \
                 demand-paging {:.4} ms",
                pf.tpot_ms.mean, dm.tpot_ms.mean
            )));
        }
        let hit = pf.pages_prefetched as f64
            / (pf.pages_prefetched + pf.pages_demand) as f64;
        println!(
            "smoke gate: smoke-longdoc hot=0.3: {}/{} completed, \
             prefetch hit rate {:.1}%, prefetch mean TPOT {:.4} ms < \
             demand-paging {:.4} ms",
            pf.completed,
            pf.offered,
            hit * 100.0,
            pf.tpot_ms.mean,
            dm.tpot_ms.mean
        );
        // (d) the 32k-context proof: two ~8k-token Mistral-7B long
        // docs on one replica whose hot tier holds only a quarter of
        // the pool -- the working set cannot fit HBM alone, yet both
        // complete, and the ahead-of-decode prefetcher strictly beats
        // demand migration on the same seeds
        let model = llm::by_name("Mistral-7B")
            .ok_or_else(|| P3Error::UnknownModel("Mistral-7B".into()))?;
        let per_req = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: 32768,
        }
        .bytes_per_request();
        let run_32k = |depth: usize| -> Result<Metrics> {
            let mut eng = EngineBuilder::sim()
                .model("Mistral-7B")
                .system(&system)
                .max_batch(2)
                .ctx_limit(32768)
                .kv_capacity(per_req)
                .hot_fraction(0.25)
                .prefetch_depth(depth)
                .build()?;
            let mut rng = p3llm::testutil::Rng::new(0x32c0 ^ seed);
            for _ in 0..2 {
                let toks: Vec<i32> = (0..8192)
                    .map(|_| rng.usize(0, 32000) as i32)
                    .collect();
                eng.submit(toks, 24)?;
            }
            eng.run_to_completion()
        };
        let dm32 = run_32k(0)?;
        let pf32 = run_32k(8)?;
        if dm32.completed != 2 || pf32.completed != 2 {
            return Err(P3Error::Serve(format!(
                "memtier smoke gate: 32k long-doc lost requests \
                 (demand {}/2, prefetch {}/2)",
                dm32.completed, pf32.completed
            )));
        }
        if pf32.pages_prefetched == 0
            || dm32.pages_prefetched != 0
            || !(pf32.per_token_ms.mean < dm32.per_token_ms.mean)
        {
            return Err(P3Error::Serve(format!(
                "memtier smoke gate: 32k proof failed (prefetch TPOT \
                 {:.4} ms vs demand {:.4} ms, {} pages prefetched)",
                pf32.per_token_ms.mean,
                dm32.per_token_ms.mean,
                pf32.pages_prefetched
            )));
        }
        println!(
            "smoke gate: 32k long-doc on Mistral-7B (hot tier 0.25): \
             2/2 completed; prefetch mean TPOT {:.4} ms < demand \
             {:.4} ms ({} pages prefetched)",
            pf32.per_token_ms.mean,
            dm32.per_token_ms.mean,
            pf32.pages_prefetched
        );
        bench_records.push(BenchRecord::new(
            "model=Mistral-7B,ctx=32768,hot=0.25,depth=0",
            "tpot_mean_ms",
            dm32.per_token_ms.mean,
        ));
        bench_records.push(BenchRecord::new(
            "model=Mistral-7B,ctx=32768,hot=0.25,depth=8",
            "tpot_mean_ms",
            pf32.per_token_ms.mean,
        ));
        let path = p3llm::benchkit::save_bench_json(
            "memtier_smoke",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
    }

    if args.has("save") {
        save_tables(&t, None, "memtier")?;
        let path = p3llm::benchkit::save_bench_json(
            "memtier",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
    }
    Ok(())
}

/// A/B the NPU||PIM sub-batch interleaving against the serial
/// schedule: the same scenario, seed for seed, once with
/// `interleave=off` (bit-identical to the pre-interleave engine) and
/// once with the two device timelines overlapped.  `--smoke` is the
/// CI gate ci.sh wires in: in-process double-run determinism per
/// mode, a serial run that charges zero interleaving, and -- on the
/// decode-heavy smoke scenario at batch 8 -- an overlap factor above
/// 0.3 with goodput strictly above serial.
fn cmd_interleave(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 7)?;
    let system = args.get_or("system", "P3-LLM").to_string();
    let scheme = args.get("scheme");
    let mut scenarios = vec![];
    for name in args.get_list("scenario", "smoke-interleave") {
        scenarios.push(traffic::scenario_by_name(&name).ok_or_else(|| {
            P3Error::InvalidConfig(format!(
                "unknown scenario {name:?} (see `p3llm loadtest --list`)"
            ))
        })?);
    }
    if args.get("requests").is_some() {
        let n = args.get_usize("requests", 1)?.max(1);
        for s in &mut scenarios {
            s.n_requests = n;
        }
    }
    apply_tier_flags(args, &mut scenarios)?;

    let run_mode = |sc: &Scenario, on: bool| -> Result<LoadReport> {
        let mut sc = sc.clone();
        sc.interleave = on;
        let mut engine = sc.engine(&system, scheme)?;
        let out = sc
            .runner(seed)
            .run_with_saturation(&mut engine, sc.saturation_tok_s(&system))?;
        Ok(out.report)
    };

    let mut t = Table::new(
        format!(
            "interleave: serial vs NPU||PIM sub-batch overlap on \
             {system}, seed {seed}"
        ),
        &[
            "scenario",
            "mode",
            "done",
            "goodput tok/s",
            "makespan ms",
            "mean TPOT ms",
            "overlap",
            "steps ilv/fused",
            "saved ms",
        ],
    );
    let mut bench_records: Vec<BenchRecord> = vec![];
    let mut gate: Option<(Scenario, LoadReport, LoadReport)> = None;
    for sc in &scenarios {
        let serial = run_mode(sc, false)?;
        let ilv = run_mode(sc, true)?;
        for (mode, r) in [("serial", &serial), ("interleaved", &ilv)] {
            t.row(vec![
                sc.name.into(),
                mode.into(),
                format!("{}/{}", r.completed, r.offered),
                f2(r.goodput_tok_s),
                f3(r.makespan_ms),
                f3(r.tpot_ms.mean),
                f2(r.overlap_factor),
                format!("{}/{}", r.interleaved_steps, r.fused_steps),
                f3(r.serial_saved_ms),
            ]);
            let cfg =
                format!("scenario={},mode={mode},batch={}", sc.name, sc.max_batch);
            bench_records.push(BenchRecord::new(
                cfg.clone(),
                "goodput_tok_s",
                r.goodput_tok_s,
            ));
            bench_records.push(BenchRecord::new(
                cfg.clone(),
                "overlap_factor",
                r.overlap_factor,
            ));
            bench_records.push(BenchRecord::new(
                cfg,
                "serial_saved_ms",
                r.serial_saved_ms,
            ));
        }
        bench_records.push(BenchRecord::new(
            format!("scenario={},batch={}", sc.name, sc.max_batch),
            "goodput_speedup",
            if serial.goodput_tok_s > 0.0 {
                ilv.goodput_tok_s / serial.goodput_tok_s
            } else {
                0.0
            },
        ));
        if gate.is_none() {
            gate = Some((sc.clone(), serial, ilv));
        }
    }
    t.print();

    if smoke {
        let (sc, serial, ilv) = gate.expect("at least one scenario ran");
        // (a) determinism: identical in-process re-runs of both modes
        // must agree bit-for-bit (ci.sh additionally diffs processes)
        if run_mode(&sc, false)? != serial || run_mode(&sc, true)? != ilv {
            return Err(P3Error::Serve(
                "interleave smoke gate: two identical runs disagreed \
                 (nondeterminism)"
                    .into(),
            ));
        }
        // (b) the serial schedule charges zero interleaving: no
        // overlapped or fused steps, no concurrent busy time
        if serial.interleaved_steps != 0
            || serial.fused_steps != 0
            || serial.overlap_ms != 0.0
            || serial.overlap_factor != 0.0
            || serial.serial_saved_ms != 0.0
        {
            return Err(P3Error::Serve(format!(
                "interleave smoke gate: serial mode charged \
                 interleaving ({} steps, {:.3} ms overlap)",
                serial.interleaved_steps, serial.overlap_ms
            )));
        }
        // (c) neither mode loses requests
        if serial.completed < serial.offered || ilv.completed < ilv.offered
        {
            return Err(P3Error::Serve(format!(
                "interleave smoke gate: lost requests (serial {}/{}, \
                 interleaved {}/{})",
                serial.completed, serial.offered, ilv.completed,
                ilv.offered
            )));
        }
        // (d) the win: at batch >= 8 the decode-heavy scenario must
        // overlap more than 0.3 of the less-busy engine and convert
        // that into strictly higher goodput than the serial schedule
        if ilv.interleaved_steps == 0 {
            return Err(P3Error::Serve(
                "interleave smoke gate: no step ever interleaved"
                    .into(),
            ));
        }
        if ilv.overlap_factor <= 0.3 {
            return Err(P3Error::Serve(format!(
                "interleave smoke gate: overlap factor {:.3} <= 0.3",
                ilv.overlap_factor
            )));
        }
        if ilv.goodput_tok_s <= serial.goodput_tok_s
            || ilv.makespan_ms >= serial.makespan_ms
            || ilv.serial_saved_ms <= 0.0
        {
            return Err(P3Error::Serve(format!(
                "interleave smoke gate: no win over serial (goodput \
                 {:.2} vs {:.2} tok/s, makespan {:.3} vs {:.3} ms)",
                ilv.goodput_tok_s,
                serial.goodput_tok_s,
                ilv.makespan_ms,
                serial.makespan_ms
            )));
        }
        println!(
            "smoke gate: {} batch={}: overlap factor {:.3} > 0.3; \
             interleaved goodput {:.2} tok/s > serial {:.2} tok/s \
             ({} steps overlapped, {} fused, {:.3} ms saved)",
            sc.name,
            sc.max_batch,
            ilv.overlap_factor,
            ilv.goodput_tok_s,
            serial.goodput_tok_s,
            ilv.interleaved_steps,
            ilv.fused_steps,
            ilv.serial_saved_ms
        );
        let path = p3llm::benchkit::save_bench_json(
            "interleave",
            seed,
            &bench_records,
        )
        .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
        println!("saved {}", path.display());
    }

    if args.has("save") {
        save_tables(&t, None, "interleave")?;
        if !smoke {
            let path = p3llm::benchkit::save_bench_json(
                "interleave",
                seed,
                &bench_records,
            )
            .map_err(|e| P3Error::io(p3llm::benchkit::reports_dir(), e))?;
            println!("saved {}", path.display());
        }
    }
    Ok(())
}

/// Check the committed bench baselines (`rust/benches/baselines.json`)
/// against the `BENCH_*.json` sidecars the smoke gates just wrote.
/// Every band is evaluated -- a run reports all regressions, not just
/// the first -- and any violation is a hard error, so ci.sh can gate
/// on the exit code alone.
fn cmd_trend(args: &Args) -> Result<()> {
    let path = args
        .get_or("baselines", "rust/benches/baselines.json")
        .to_string();
    let text = std::fs::read_to_string(&path)
        .map_err(|e| P3Error::io(&path, e))?;
    let reports = p3llm::benchkit::reports_dir();
    let rep = p3llm::benchkit::check_trend(&text, &reports)
        .map_err(P3Error::InvalidConfig)?;
    for line in &rep.passes {
        println!("trend OK: {line}");
    }
    for line in &rep.failures {
        println!("trend FAIL: {line}");
    }
    if !rep.ok() {
        return Err(P3Error::Serve(format!(
            "trend: {} of {} bands regressed against {path}",
            rep.failures.len(),
            rep.failures.len() + rep.passes.len()
        )));
    }
    println!("trend: {} bands within tolerance of {path}", rep.passes.len());
    Ok(())
}
