//! p3llm -- leader binary: serve / eval / simulate / report.
//!
//! `serve` runs the unified engine on either execution backend
//! (`--backend pjrt` for real numerics from AOT artifacts, `--backend
//! sim` for the NPU-PIM cost model: any model, any batch, no
//! artifacts); `simulate` reuses the same engine under each modeled
//! system.  Python is never on the request path.

use p3llm::accel::Accel;
use p3llm::cli::Args;
use p3llm::config::llm;
use p3llm::coordinator::{Engine, EngineBuilder, Metrics};
use p3llm::error::{P3Error, Result};
use p3llm::report::{f2, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

const USAGE: &str = "\
p3llm <command> [options]

commands:
  serve      run the serving engine end-to-end
             --backend {pjrt,sim}   execution substrate (default pjrt)
             --requests N --max-new N --batch N
             pjrt: --fp16 --device-weights  (tiny model, needs artifacts)
             sim:  --model NAME --system NAME --scheme NAME
                   --prompt-len N --ctx N --kv-cap BYTES
  eval       perplexity of a configured quantization variant
             --config NAME --corpus {wiki,c4} --blocks N  (see evalcfg.tsv)
  list-eval  list configured accuracy variants
  simulate   decode latency on the modeled NPU-PIM systems, plus a
             full serving-loop run of the chosen system
             --model NAME --batch N --ctx N --system NAME
  version

common: --artifacts DIR (default: artifacts)";

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("list-eval") => cmd_list_eval(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("version") => {
            println!("p3llm {}", p3llm::version());
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn print_metrics(m: &Metrics) {
    println!(
        "completed={} steps={} tokens={} decode_tok/s={:.1} wall={:.1}ms \
         (backend={}, {} clock)",
        m.completed,
        m.decode_steps,
        m.tokens_out,
        m.tokens_per_sec(),
        m.wall_ms,
        m.backend,
        if m.backend == "sim" { "simulated" } else { "wall" },
    );
    println!(
        "TTFT ms:      mean={:.2} p50={:.2} p95={:.2} p99={:.2} max={:.2}",
        m.ttft_ms.mean, m.ttft_ms.p50, m.ttft_ms.p95, m.ttft_ms.p99, m.ttft_ms.max
    );
    println!(
        "per-token ms: mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
        m.per_token_ms.mean,
        m.per_token_ms.p50,
        m.per_token_ms.p95,
        m.per_token_ms.p99,
        m.per_token_ms.max
    );
}

/// Drive a built engine through a batch of requests to completion.
fn drive(engine: &mut Engine, n_requests: usize, max_new: usize, prompt_len: usize) -> Result<Metrics> {
    let prompts = [
        "in 980 , aldora",
        "the kettle works",
        "to fix your router , first",
        "celund is the capital of",
    ];
    for i in 0..n_requests {
        let toks: Vec<i32> = if prompt_len > 0 {
            // synthetic prompt of the requested length (sim workloads)
            (0..prompt_len).map(|t| ((i * 31 + t * 7) % 251) as i32).collect()
        } else {
            prompts[i % prompts.len()].bytes().map(|b| b as i32).collect()
        };
        engine.submit(toks, max_new)?;
    }
    engine.run_to_completion()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend = args.get_or("backend", "pjrt").to_ascii_lowercase();
    let n_requests = args.get_usize("requests", 8)?;
    let max_new = args.get_usize("max-new", 48)?;
    let mut b = EngineBuilder::backend(&backend)?;
    match backend.as_str() {
        "pjrt" => {
            b = b
                .artifacts_dir(&artifacts_dir(args))
                .max_batch(args.get_usize("batch", 8)?)
                .scheme(if args.has("fp16") { "fp16" } else { "p3llm" })
                .device_weights(args.has("device-weights"));
        }
        _ => {
            b = b
                .model(args.get_or("model", "tiny-1M"))
                .system(args.get_or("system", "P3-LLM"))
                .max_batch(args.get_usize("batch", 8)?)
                .kv_capacity(args.get_usize("kv-cap", 64 << 20)?);
            if let Some(s) = args.get("scheme") {
                b = b.scheme(s);
            }
            if args.get("ctx").is_some() {
                b = b.ctx_limit(args.get_usize("ctx", 1024)?);
            }
        }
    }
    let mut engine = b.build()?;
    let prompt_len = match backend.as_str() {
        "pjrt" => 0,
        _ => args.get_usize("prompt-len", 16)?,
    };
    println!(
        "serving {n_requests} requests on {} via {} backend",
        engine.model().name,
        engine.backend_name()
    );
    let metrics = drive(&mut engine, n_requests, max_new, prompt_len)?;
    print_metrics(&metrics);
    if let Some(m) = engine.mapping_summary() {
        println!(
            "operator mapping (last step): {} NPU ops, {} PIM ops, {} PIM commands",
            m.npu_ops, m.pim_ops, m.pim_commands
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::new(&dir)?;
    let ev = Evaluator::new(&rt)?;
    let cfgs = eval_configs(&rt.artifacts.dir)?;
    let name = args.get_or("config", "fp16");
    let cfg = cfgs.iter().find(|c| c.name == name).ok_or_else(|| {
        P3Error::Eval(format!("unknown config {name}; try list-eval"))
    })?;
    let corpus = args.get_or("corpus", "wiki");
    let blocks = args.get_usize("blocks", 8)?;
    // --set kv_bits=2,a_bits=8 style scalar overrides
    let overrides: Vec<(String, f32)> = args
        .get_or("set", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            Some((k.to_string(), v.parse().ok()?))
        })
        .collect();
    let refs: Vec<(&str, f32)> =
        overrides.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let r = ev.evaluate(cfg, corpus, blocks, &refs)?;
    println!(
        "{name} on {corpus}: ppl {:.4}  acc {:.2}%   ({})",
        r.ppl,
        r.accuracy * 100.0,
        cfg.note
    );
    Ok(())
}

fn cmd_list_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let cfgs = eval_configs(std::path::Path::new(&dir))?;
    let mut t = Table::new("eval configs", &["name", "graph", "weights", "note"]);
    for c in cfgs {
        t.row(vec![c.name, c.graph, c.weights, c.note]);
    }
    t.print();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "Llama-3.1-8B");
    let model = llm::by_name(model_name)
        .ok_or_else(|| P3Error::UnknownModel(model_name.into()))?;
    let bs = args.get_usize("batch", 1)?;
    let ctx = args.get_usize("ctx", 4096)?;
    let mut t = Table::new(
        format!("{} decode step, bs={bs}, ctx={ctx}", model.name),
        &["system", "attn ms", "linear ms", "total ms", "tok/s", "energy mJ"],
    );
    for a in [
        Accel::npu_fp16(),
        Accel::hbm_pim(),
        Accel::ecco(),
        Accel::pimba_enhanced(),
        Accel::p3llm(),
    ] {
        let c = a.decode_step(&model, bs, ctx);
        t.row(vec![
            a.name.into(),
            f2(c.attn.ns / 1e6),
            f2(c.linear.ns / 1e6),
            f2(c.total_ns() / 1e6),
            f2(bs as f64 / (c.total_ns() * 1e-9)),
            f2(c.total_pj() / 1e9),
        ]);
    }
    t.print();

    // the per-step table above is open-loop; this closes the loop by
    // running the *same serving engine* as `serve` on the sim backend
    let system = args.get_or("system", "P3-LLM");
    let n_requests = args.get_usize("requests", 4 * bs.max(1))?;
    let max_new = args.get_usize("max-new", 32)?;
    let ctx_limit = ctx.min(model.max_ctx).max(64);
    // worst-case packed reservation for the chosen batch
    let per_req = p3llm::coordinator::KvLayout {
        layers: model.layers,
        kv_dim: model.kv_dim(),
        head_dim: model.head_dim,
        max_ctx: ctx_limit,
    }
    .bytes_per_request();
    let mut engine = EngineBuilder::sim()
        .model(model_name)
        .system(system)
        .max_batch(bs.max(1))
        .ctx_limit(ctx_limit)
        .kv_capacity(per_req * (bs.max(1) + 1))
        .build()?;
    println!(
        "serving-loop view ({} on {}, continuous batching):",
        engine.model().name,
        system
    );
    let metrics = drive(&mut engine, n_requests, max_new, 16)?;
    print_metrics(&metrics);
    if let Some(m) = engine.mapping_summary() {
        println!(
            "operator mapping (last step): {} NPU ops, {} PIM ops, {} PIM commands",
            m.npu_ops, m.pim_ops, m.pim_commands
        );
    }
    Ok(())
}
