//! p3llm -- leader binary: serve / eval / simulate / report.
//!
//! Everything runs from AOT artifacts (see `make artifacts`); python is
//! never on the request path.

use anyhow::{anyhow, Result};

use p3llm::accel::Accel;
use p3llm::cli::Args;
use p3llm::config::llm;
use p3llm::coordinator::{Engine, EngineConfig};
use p3llm::report::{f2, Table};
use p3llm::runtime::{eval::eval_configs, Evaluator, Runtime};

const USAGE: &str = "\
p3llm <command> [options]

commands:
  serve      run the edge serving demo on the tiny shipped model
             --requests N --max-new N --batch {1,2,4,8} --fp16 --device-weights
  eval       perplexity of a configured quantization variant
             --config NAME --corpus {wiki,c4} --blocks N  (see evalcfg.tsv)
  list-eval  list configured accuracy variants
  simulate   decode latency on the modeled NPU-PIM systems
             --model NAME --batch N --ctx N
  version

common: --artifacts DIR (default: artifacts)";

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("list-eval") => cmd_list_eval(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("version") => {
            println!("p3llm {}", p3llm::version());
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = EngineConfig {
        quantized: !args.has("fp16"),
        max_batch: args.get_usize("batch", 8),
        device_weights: args.has("device-weights"),
        ..Default::default()
    };
    let n_requests = args.get_usize("requests", 8);
    let max_new = args.get_usize("max-new", 48);
    let mut engine = Engine::new(&artifacts_dir(args), cfg)?;
    println!(
        "serving {n_requests} requests on {} (quantized={})",
        engine.model.name, engine.cfg.quantized
    );
    let prompts = [
        "in 980 , aldora",
        "the kettle works",
        "to fix your router , first",
        "celund is the capital of",
    ];
    for i in 0..n_requests {
        let p = prompts[i % prompts.len()];
        let toks: Vec<i32> = p.bytes().map(|b| b as i32).collect();
        engine.submit(toks, max_new);
    }
    let stats = engine.run_to_completion()?;
    println!(
        "completed={} steps={} tokens={} decode_tok/s={:.1} mean_ttft={:.1}ms wall={:.0}ms",
        stats.completed,
        stats.decode_steps,
        stats.tokens_out,
        stats.tokens_per_sec(),
        stats.mean_ttft_ms(),
        stats.wall_ms
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::new(&dir)?;
    let ev = Evaluator::new(&rt)?;
    let cfgs = eval_configs(&rt.artifacts.dir)?;
    let name = args.get_or("config", "fp16");
    let cfg = cfgs
        .iter()
        .find(|c| c.name == name)
        .ok_or_else(|| anyhow!("unknown config {name}; try list-eval"))?;
    let corpus = args.get_or("corpus", "wiki");
    let blocks = args.get_usize("blocks", 8);
    // --set kv_bits=2,a_bits=8 style scalar overrides
    let overrides: Vec<(String, f32)> = args
        .get_or("set", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            Some((k.to_string(), v.parse().ok()?))
        })
        .collect();
    let refs: Vec<(&str, f32)> =
        overrides.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let r = ev.evaluate(cfg, corpus, blocks, &refs)?;
    println!(
        "{name} on {corpus}: ppl {:.4}  acc {:.2}%   ({})",
        r.ppl,
        r.accuracy * 100.0,
        cfg.note
    );
    Ok(())
}

fn cmd_list_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let cfgs = eval_configs(std::path::Path::new(&dir))?;
    let mut t = Table::new("eval configs", &["name", "graph", "weights", "note"]);
    for c in cfgs {
        t.row(vec![c.name, c.graph, c.weights, c.note]);
    }
    t.print();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = llm::by_name(args.get_or("model", "Llama-3.1-8B"))
        .ok_or_else(|| anyhow!("unknown model"))?;
    let bs = args.get_usize("batch", 1);
    let ctx = args.get_usize("ctx", 4096);
    let mut t = Table::new(
        format!("{} decode step, bs={bs}, ctx={ctx}", model.name),
        &["system", "attn ms", "linear ms", "total ms", "tok/s", "energy mJ"],
    );
    for a in [
        Accel::npu_fp16(),
        Accel::hbm_pim(),
        Accel::ecco(),
        Accel::pimba_enhanced(),
        Accel::p3llm(),
    ] {
        let c = a.decode_step(&model, bs, ctx);
        t.row(vec![
            a.name.into(),
            f2(c.attn.ns / 1e6),
            f2(c.linear.ns / 1e6),
            f2(c.total_ns() / 1e6),
            f2(bs as f64 / (c.total_ns() * 1e-9)),
            f2(c.total_pj() / 1e9),
        ]);
    }
    t.print();
    Ok(())
}
