//! Report rendering: paper-style tables printed to stdout and saved as
//! TSV under `reports/` so EXPERIMENTS.md can cite exact files.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "{}", self.title);
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write TSV to `reports/<name>.tsv` (dir created on demand).
    pub fn save(&self, dir: impl AsRef<Path>, name: &str) -> std::io::Result<()> {
        let dir = dir.as_ref();
        fs::create_dir_all(dir)?;
        let mut s = String::new();
        let _ = writeln!(s, "# {}", self.title);
        let _ = writeln!(s, "{}", self.headers.join("\t"));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join("\t"));
        }
        fs::write(dir.join(format!("{name}.tsv")), s)
    }
}

/// Format helpers used across bench harnesses.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn fx(x: f64) -> String {
    format!("{x:.2}x")
}

pub fn si(x: f64) -> String {
    if x >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_save() {
        let mut t = Table::new("demo", &["a", "bee"]);
        t.row(vec!["1".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("demo") && s.contains("bee"));
        let dir = std::env::temp_dir().join("p3llm_report_test");
        t.save(&dir, "demo").unwrap();
        let got = std::fs::read_to_string(dir.join("demo.tsv")).unwrap();
        assert!(got.contains("1\t2.50"));
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(2.5e12), "2.50T");
        assert_eq!(si(999.0), "999.00");
    }
}
