//! Crate-level error type: every public API returns
//! `Result<_, P3Error>` instead of leaking an `anyhow`-style opaque
//! error.  Variants are typed where callers can act on them (prompt
//! rejection, KV admission control, config validation); free-text
//! variants carry the layer they came from so a message like
//! `artifacts: graph decode_q_b4 not in manifest` is attributable.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum P3Error {
    /// Filesystem problem while loading artifacts/weights/corpora.
    Io { path: String, msg: String },
    /// Artifact registry problem (manifest, graph or data lookup).
    Artifacts(String),
    /// PJRT / XLA layer failure (compile, transfer, execute).
    Xla(String),
    /// Prompt longer than the backend can absorb in one prefill.
    PromptTooLong { len: usize, max: usize },
    /// A request with no prompt tokens cannot be decoded.
    EmptyPrompt,
    /// Page-granular KV admission signal: the pool cannot cover a
    /// request's worst-case page need even after reclaiming every
    /// unreferenced cached prefix page.
    KvExhausted { needed_pages: usize, free_pages: usize },
    /// A request was allocated a KV entry twice.
    DuplicateKvEntry(u64),
    /// Builder/engine configuration rejected at `build()` time.
    InvalidConfig(String),
    /// Quantization scheme name not in `config::scheme` registry.
    UnknownScheme(String),
    /// Accelerator system name not in the `accel` registry.
    UnknownSystem(String),
    /// Model name not in `config::llm`.
    UnknownModel(String),
    /// Request id not known to the engine.
    UnknownRequest(u64),
    /// A `--flag value` pair that did not parse as the expected type.
    InvalidFlag { flag: String, value: String },
    /// Malformed number/field in a TSV or binary artifact.
    Parse(String),
    /// Serving-loop invariant violation.
    Serve(String),
    /// Evaluation-driver failure (corpus, aux blob, eval config).
    Eval(String),
}

impl P3Error {
    /// Attach a path to an I/O-ish failure.
    pub fn io(path: impl fmt::Debug, err: impl fmt::Display) -> Self {
        P3Error::Io { path: format!("{path:?}"), msg: err.to_string() }
    }

    /// Wrap an `xla` layer error (`{e:?}` like the old call sites).
    pub fn xla(err: impl fmt::Debug) -> Self {
        P3Error::Xla(format!("{err:?}"))
    }
}

impl fmt::Display for P3Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            P3Error::Io { path, msg } => write!(f, "io: {path}: {msg}"),
            P3Error::Artifacts(m) => write!(f, "artifacts: {m}"),
            P3Error::Xla(m) => write!(f, "xla: {m}"),
            P3Error::PromptTooLong { len, max } => write!(
                f,
                "prompt too long: {len} tokens exceeds the backend's \
                 single-prefill limit of {max}"
            ),
            P3Error::EmptyPrompt => write!(f, "prompt has no tokens"),
            P3Error::KvExhausted { needed_pages, free_pages } => write!(
                f,
                "KV pool exhausted: need {needed_pages} pages, \
                 {free_pages} reclaimable"
            ),
            P3Error::DuplicateKvEntry(id) => {
                write!(f, "request {id} already has a KV entry")
            }
            P3Error::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            P3Error::UnknownScheme(n) => write!(
                f,
                "unknown quantization scheme {n:?} (see config::scheme::all)"
            ),
            P3Error::UnknownSystem(n) => write!(
                f,
                "unknown accelerator system {n:?} (see accel::all_systems)"
            ),
            P3Error::UnknownModel(n) => write!(f, "unknown model {n:?}"),
            P3Error::UnknownRequest(id) => write!(f, "unknown request {id}"),
            P3Error::InvalidFlag { flag, value } => {
                write!(f, "flag --{flag}: malformed value {value:?}")
            }
            P3Error::Parse(m) => write!(f, "parse: {m}"),
            P3Error::Serve(m) => write!(f, "serve: {m}"),
            P3Error::Eval(m) => write!(f, "eval: {m}"),
        }
    }
}

impl std::error::Error for P3Error {}

impl From<std::num::ParseIntError> for P3Error {
    fn from(e: std::num::ParseIntError) -> Self {
        P3Error::Parse(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for P3Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        P3Error::Parse(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = P3Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_actionable() {
        let e = P3Error::PromptTooLong { len: 100, max: 64 };
        let s = e.to_string();
        assert!(s.contains("100") && s.contains("64"), "{s}");
        let e = P3Error::InvalidFlag { flag: "batch".into(), value: "x".into() };
        assert!(e.to_string().contains("--batch"));
    }

    #[test]
    fn parse_errors_convert() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert!(matches!(parse("zz"), Err(P3Error::Parse(_))));
        assert_eq!(parse("7").unwrap(), 7);
    }
}
