//! Perplexity / accuracy evaluation driver: streams a token corpus
//! through an AOT-lowered teacher-forced eval graph and aggregates NLL.
//! This is how every accuracy table (II-VI, Fig. 3b, Fig. 8) is
//! regenerated from Rust -- python never runs.

use std::path::Path;

use super::artifacts::{lit_f32, lit_i32, vec_f32, Runtime};
use crate::error::{P3Error, Result};
use super::weights::{load_tokens, AuxBlob, EvalCfg, Weights};

pub const EVAL_B: usize = 8;
pub const EVAL_T: usize = 128;

/// Token blocks of shape [EVAL_B, EVAL_T+1].
pub fn blocks(tokens: &[i32], max_blocks: usize) -> Vec<Vec<i32>> {
    let span = EVAL_T + 1;
    let per_block = EVAL_B * span;
    tokens
        .chunks_exact(per_block)
        .take(max_blocks)
        .map(|c| c.to_vec())
        .collect()
}

pub struct Evaluator<'a> {
    pub rt: &'a Runtime,
    pub weights_layout: std::path::PathBuf,
    pub aux_layout: std::path::PathBuf,
}

impl<'a> Evaluator<'a> {
    pub fn new(rt: &'a Runtime) -> Result<Self> {
        Ok(Evaluator {
            rt,
            weights_layout: rt.artifacts.dir.join("weights_fp.tsv"),
            aux_layout: rt.artifacts.dir.join("aux_layout.tsv"),
        })
    }

    fn weights_tsv(&self) -> Result<std::path::PathBuf> {
        // the layout TSV is written once as weights.tsv by train.py; if
        // absent, fall back to deriving from any weights_*.tsv present
        let p = self.rt.artifacts.dir.join("weights.tsv");
        if p.exists() {
            return Ok(p);
        }
        Err(P3Error::Artifacts("weights.tsv missing from artifacts".into()))
    }

    pub fn load_weights(&self, variant: &str) -> Result<Weights> {
        let bin = self.rt.artifacts.data_path(&format!("weights_{variant}"))?;
        Weights::load(bin, &self.weights_tsv()?)
    }

    pub fn load_aux(&self, variant: &str) -> Result<AuxBlob> {
        let bin = self.rt.artifacts.dir.join(format!("aux_{variant}.bin"));
        AuxBlob::load(&bin, &self.aux_layout)
    }

    pub fn load_corpus(&self, corpus: &str, split: &str) -> Result<Vec<i32>> {
        let path = self.rt.artifacts.data_path(&format!(
            "tokens_{corpus}_{split}"
        ))?;
        load_tokens(path)
    }

    /// Perplexity of one configured variant on an eval corpus.
    pub fn perplexity(
        &self,
        cfg: &EvalCfg,
        corpus: &str,
        max_blocks: usize,
        extra_scalars: &[(&str, f32)],
    ) -> Result<f64> {
        Ok(self.evaluate(cfg, corpus, max_blocks, extra_scalars)?.ppl)
    }

    /// Full evaluation (perplexity + held-out top-1 accuracy).
    pub fn evaluate(
        &self,
        cfg: &EvalCfg,
        corpus: &str,
        max_blocks: usize,
        extra_scalars: &[(&str, f32)],
    ) -> Result<EvalResult> {
        let weights = self.load_weights(&cfg.weights)?;
        let mut aux = self.load_aux(&cfg.aux)?;
        for (k, v) in &cfg.scalars {
            aux.set_scalar(k, *v)?;
        }
        for (k, v) in extra_scalars {
            aux.set_scalar(k, *v)?;
        }
        self.evaluate_raw(&cfg.graph, &weights, &aux, corpus, max_blocks)
    }

    pub fn perplexity_raw(
        &self,
        graph: &str,
        weights: &Weights,
        aux: &AuxBlob,
        corpus: &str,
        max_blocks: usize,
    ) -> Result<f64> {
        Ok(self.evaluate_raw(graph, weights, aux, corpus, max_blocks)?.ppl)
    }

    /// Evaluation with explicit weights + aux (sweep entry point).
    pub fn evaluate_raw(
        &self,
        graph: &str,
        weights: &Weights,
        aux: &AuxBlob,
        corpus: &str,
        max_blocks: usize,
    ) -> Result<EvalResult> {
        let exe = self.rt.load(graph)?;
        let tokens = self.load_corpus(corpus, "eval")?;
        let blks = blocks(&tokens, max_blocks);
        if blks.is_empty() {
            return Err(P3Error::Eval(format!("corpus {corpus} too small")));
        }

        // graph signature: [params sorted...] block [aux...]
        // §Perf: weights + aux go to device buffers once; only the
        // token block is uploaded per iteration (run_b fast path).
        // NOTE: host literals must outlive their device buffers --
        // PJRT's BufferFromHostLiteral may reference host memory
        // asynchronously (dropping the literal early segfaults).
        let mut keep_lits: Vec<xla::Literal> = Vec::new();
        let mut fixed_bufs: Vec<xla::PjRtBuffer> = Vec::new();
        for t in &weights.tensors {
            let lit = lit_f32(&t.dims, &t.f32_data)?;
            fixed_bufs.push(self.rt.to_device(&lit)?);
            keep_lits.push(lit);
        }
        let mut aux_bufs = Vec::new();
        for (_, dims, off, cnt) in &aux.layout {
            let lit = lit_f32(dims, &aux.data[*off..*off + *cnt])?;
            aux_bufs.push(self.rt.to_device(&lit)?);
            keep_lits.push(lit);
        }

        let mut total_nll = 0.0f64;
        let mut total_cnt = 0.0f64;
        let mut total_correct = 0.0f64;
        for blk in &blks {
            let blk_lit = lit_i32(&[EVAL_B, EVAL_T + 1], blk)?;
            let blk_buf = self.rt.to_device(&blk_lit)?;
            let mut args: Vec<&xla::PjRtBuffer> = fixed_bufs.iter().collect();
            args.push(&blk_buf);
            args.extend(aux_bufs.iter());
            let out = exe.run_b(&args)?;
            total_nll += vec_f32(&out[0])?[0] as f64;
            total_cnt += vec_f32(&out[1])?[0] as f64;
            total_correct += vec_f32(&out[2])?[0] as f64;
        }
        drop(keep_lits);
        Ok(EvalResult {
            ppl: (total_nll / total_cnt).exp(),
            accuracy: total_correct / total_cnt,
            tokens: total_cnt as usize,
        })
    }
}

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub ppl: f64,
    /// held-out next-token top-1 accuracy (Table V substitute)
    pub accuracy: f64,
    pub tokens: usize,
}

/// xla::Literal has no Clone; round-trip through raw bytes.
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape().map_err(P3Error::xla)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            lit_f32(&dims, &l.to_vec::<f32>().map_err(P3Error::xla)?)
        }
        xla::ElementType::S32 => super::artifacts::lit_i32(
            &dims,
            &l.to_vec::<i32>().map_err(P3Error::xla)?,
        ),
        xla::ElementType::U8 => super::artifacts::lit_u8(
            &dims,
            &l.to_vec::<u8>().map_err(P3Error::xla)?,
        ),
        t => Err(P3Error::Xla(format!("clone_literal: unsupported {t:?}"))),
    }
}

/// Load all eval configurations.
pub fn eval_configs(dir: &Path) -> Result<Vec<EvalCfg>> {
    super::weights::load_evalcfg(&dir.join("evalcfg.tsv"))
}
