//! PJRT runtime: artifact registry, weight/aux loaders, executable
//! cache, and the accuracy-evaluation driver.  Python runs only at
//! build time (`make artifacts`); everything here is pure Rust over
//! the PJRT C API.

pub mod artifacts;
pub mod eval;
pub mod weights;

pub use artifacts::{Artifacts, Executable, Runtime};
pub use eval::Evaluator;
pub use weights::{AuxBlob, EvalCfg, Weights};
