//! Artifact registry + PJRT execution (the `xla` crate wrapping the
//! PJRT C API; the offline build links the vendored stub in
//! `rust/vendor/xla` -- see DESIGN.md).
//!
//! `manifest.tsv` (written by `python -m compile.aot`) lists every HLO
//! graph with its input signature; graphs are compiled once per process
//! and cached.  Interchange is HLO *text*: jax >= 0.5 emits protos with
//! 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{P3Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u8" => DType::U8,
            _ => return Err(P3Error::Parse(format!("unknown dtype {s}"))),
        })
    }

    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
}

impl GraphSpec {
    pub fn arg_index(&self, name: &str) -> Option<usize> {
        self.args.iter().position(|a| a.name == name)
    }
}

/// Parsed manifest.tsv.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub graphs: HashMap<String, GraphSpec>,
    pub data: HashMap<String, PathBuf>,
}

impl Artifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            P3Error::Io {
                path: format!("{manifest:?}"),
                msg: format!("{e} (run `make artifacts`)"),
            }
        })?;
        let mut graphs = HashMap::new();
        let mut data = HashMap::new();
        for line in text.lines().skip(1) {
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() < 4 {
                continue;
            }
            let (kind, name, file, info) = (cols[0], cols[1], cols[2], cols[3]);
            match kind {
                "graph" => {
                    let args = info
                        .split(';')
                        .filter(|s| !s.is_empty())
                        .map(|spec| {
                            let p: Vec<&str> = spec.split(':').collect();
                            if p.len() != 3 {
                                return Err(P3Error::Parse(format!(
                                    "bad arg spec {spec}"
                                )));
                            }
                            let dims = if p[1].is_empty() {
                                vec![]
                            } else {
                                p[1].split('x')
                                    .map(|d| d.parse::<usize>())
                                    .collect::<std::result::Result<_, _>>()?
                            };
                            Ok(ArgSpec {
                                name: p[0].to_string(),
                                dims,
                                dtype: DType::parse(p[2])?,
                            })
                        })
                        .collect::<Result<Vec<_>>>()?;
                    graphs.insert(
                        name.to_string(),
                        GraphSpec {
                            name: name.to_string(),
                            file: dir.join(file),
                            args,
                        },
                    );
                }
                "data" => {
                    data.insert(name.to_string(), dir.join(file));
                }
                _ => {}
            }
        }
        Ok(Artifacts { dir, graphs, data })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .ok_or_else(|| P3Error::Artifacts(format!("graph {name} not in manifest")))
    }

    pub fn data_path(&self, name: &str) -> Result<&PathBuf> {
        self.data
            .get(name)
            .ok_or_else(|| P3Error::Artifacts(format!("data {name} not in manifest")))
    }
}

/// Literal construction helpers.
pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )
    .map_err(P3Error::xla)
}

pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )
    .map_err(P3Error::xla)
}

pub fn lit_u8(dims: &[usize], data: &[u8]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U8,
        dims,
        data,
    )
    .map_err(P3Error::xla)
}

/// A compiled graph.
pub struct Executable {
    pub spec: GraphSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple
    /// (aot.py lowers everything with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.args.len() {
            return Err(P3Error::Artifacts(format!(
                "{}: expected {} args, got {}",
                self.spec.name,
                self.spec.args.len(),
                args.len()
            )));
        }
        let out =
            self.exe.execute::<xla::Literal>(args).map_err(P3Error::xla)?;
        let lit = out[0][0].to_literal_sync().map_err(P3Error::xla)?;
        lit.to_tuple().map_err(P3Error::xla)
    }

    /// Execute with device buffers (persistent-weights fast path).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute_b(args).map_err(P3Error::xla)?;
        let lit = out[0][0].to_literal_sync().map_err(P3Error::xla)?;
        lit.to_tuple().map_err(P3Error::xla)
    }
}

/// PJRT CPU runtime with an executable cache.
pub struct Runtime {
    pub artifacts: Artifacts,
    pub client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let artifacts = Artifacts::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(P3Error::xla)?;
        Ok(Runtime { artifacts, client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.artifacts.graph(name)?.clone();
        let proto =
            xla::HloModuleProto::from_text_file(spec.file.to_str().unwrap())
                .map_err(|e| P3Error::Xla(format!("loading {name}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(P3Error::xla)?;
        let arc = Arc::new(Executable { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Upload a literal to the first addressable device (persistent
    /// buffer for execute_b).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let devices = self.client.addressable_devices();
        self.client
            .buffer_from_host_literal(Some(&devices[0]), lit)
            .map_err(P3Error::xla)
    }
}

/// Read a scalar f32 out of a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>().map_err(P3Error::xla)?[0])
}

pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(P3Error::xla)
}
