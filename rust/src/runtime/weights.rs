//! Weight / aux / token data loaders for the artifact files emitted by
//! `python -m compile.aot` (flat little-endian binaries + TSV layouts).

use std::collections::HashMap;
use std::path::Path;

use super::artifacts::DType;
use crate::error::{P3Error, Result};

/// One named tensor backed by a slice of the flat weight file.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub f32_data: Vec<f32>,
    pub u8_data: Vec<u8>,
    pub dtype: DType,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().map_err(P3Error::from))
        .collect()
}

fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).map_err(|e| P3Error::io(path, e))?;
    if bytes.len() % 4 != 0 {
        return Err(P3Error::Parse(format!(
            "{path:?} not a multiple of 4 bytes"
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Model weights: sorted-name order matching the graph input order
/// (weights_*.tsv layout is shared by every weights_*.bin variant).
#[derive(Debug, Clone)]
pub struct Weights {
    /// in sorted-name (graph input) order
    pub tensors: Vec<Tensor>,
    pub by_name: HashMap<String, usize>,
}

impl Weights {
    /// `layout_tsv` is artifacts/weights.tsv (name/shape/offset/count);
    /// the same layout applies to every weight variant file.
    pub fn load(bin: &Path, layout_tsv: &Path) -> Result<Self> {
        let flat = read_f32_file(bin)?;
        let layout = std::fs::read_to_string(layout_tsv)
            .map_err(|e| P3Error::io(layout_tsv, e))?;
        let mut tensors = vec![];
        let mut by_name = HashMap::new();
        for line in layout.lines().skip(1) {
            let c: Vec<&str> = line.split('\t').collect();
            if c.len() != 4 {
                continue;
            }
            let dims = parse_shape(c[1])?;
            let off: usize = c[2].parse()?;
            let cnt: usize = c[3].parse()?;
            if off + cnt > flat.len() {
                return Err(P3Error::Parse(format!(
                    "{}: out of range in {bin:?}",
                    c[0]
                )));
            }
            by_name.insert(c[0].to_string(), tensors.len());
            tensors.push(Tensor {
                name: c[0].to_string(),
                dims,
                f32_data: flat[off..off + cnt].to_vec(),
                u8_data: vec![],
                dtype: DType::F32,
            });
        }
        if tensors.is_empty() {
            return Err(P3Error::Parse(format!(
                "empty layout {layout_tsv:?}"
            )));
        }
        Ok(Weights { tensors, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.by_name.get(name).map(|&i| &self.tensors[i])
    }
}

/// Packed BitMoD weights (codes/scales/specials) for the kernel decode
/// graphs; layout in weights_packed.tsv with per-tensor dtypes.
pub fn load_packed(bin: &Path, layout_tsv: &Path) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(bin).map_err(|e| P3Error::io(bin, e))?;
    let layout = std::fs::read_to_string(layout_tsv)
        .map_err(|e| P3Error::io(layout_tsv, e))?;
    let mut out = vec![];
    for line in layout.lines().skip(1) {
        let c: Vec<&str> = line.split('\t').collect();
        if c.len() != 5 {
            continue;
        }
        let dims = parse_shape(c[1])?;
        let dtype = DType::parse(c[2])?;
        let off: usize = c[3].parse()?;
        let nbytes: usize = c[4].parse()?;
        let chunk = &bytes[off..off + nbytes];
        let (f32_data, u8_data) = match dtype {
            DType::F32 => (
                chunk
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
                vec![],
            ),
            DType::U8 => (vec![], chunk.to_vec()),
            DType::I32 => {
                return Err(P3Error::Parse(
                    "unexpected i32 packed tensor".into(),
                ))
            }
        };
        out.push(Tensor {
            name: c[0].to_string(),
            dims,
            f32_data,
            u8_data,
            dtype,
        });
    }
    Ok(out)
}

/// Aux blob: flat f32 in aux_layout.tsv order, with named scalar/vector
/// views + override support for the experiment sweeps.
#[derive(Debug, Clone)]
pub struct AuxBlob {
    pub layout: Vec<(String, Vec<usize>, usize, usize)>, // name,dims,off,cnt
    pub data: Vec<f32>,
}

impl AuxBlob {
    pub fn load(bin: &Path, layout_tsv: &Path) -> Result<Self> {
        let data = read_f32_file(bin)?;
        let text = std::fs::read_to_string(layout_tsv)
            .map_err(|e| P3Error::io(layout_tsv, e))?;
        let mut layout = vec![];
        for line in text.lines().skip(1) {
            let c: Vec<&str> = line.split('\t').collect();
            if c.len() != 4 {
                continue;
            }
            layout.push((
                c[0].to_string(),
                parse_shape(c[1])?,
                c[2].parse()?,
                c[3].parse()?,
            ));
        }
        let total: usize = layout.iter().map(|l| l.3).sum();
        if total != data.len() {
            return Err(P3Error::Parse(format!(
                "aux blob size {} != layout {}",
                data.len(),
                total
            )));
        }
        Ok(AuxBlob { layout, data })
    }

    /// Override a scalar aux field (e.g. kv_bits=4 for a sweep point).
    pub fn set_scalar(&mut self, name: &str, value: f32) -> Result<()> {
        for (n, _, off, cnt) in &self.layout {
            if n == name {
                if *cnt != 1 {
                    return Err(P3Error::Eval(format!(
                        "{name} is not a scalar"
                    )));
                }
                self.data[*off] = value;
                return Ok(());
            }
        }
        Err(P3Error::Eval(format!("aux field {name} not found")))
    }

    pub fn view(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.layout.iter().find(|(n, ..)| n == name).map(
            |(_, dims, off, cnt)| (dims.as_slice(), &self.data[*off..off + cnt]),
        )
    }
}

/// Byte-level token stream (tokens_*.bin).
pub fn load_tokens(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).map_err(|e| P3Error::io(path, e))?;
    Ok(bytes.into_iter().map(|b| b as i32).collect())
}

/// evalcfg.tsv rows: experiment-variant registry.
#[derive(Debug, Clone)]
pub struct EvalCfg {
    pub name: String,
    pub graph: String,
    pub weights: String,
    pub aux: String,
    /// "k=v,k=v" scalar overrides
    pub scalars: Vec<(String, f32)>,
    pub note: String,
}

pub fn load_evalcfg(path: &Path) -> Result<Vec<EvalCfg>> {
    let text =
        std::fs::read_to_string(path).map_err(|e| P3Error::io(path, e))?;
    let mut out = vec![];
    for line in text.lines().skip(1) {
        let c: Vec<&str> = line.split('\t').collect();
        if c.len() != 6 {
            continue;
        }
        let scalars = c[4]
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|kv| {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| P3Error::Parse(format!("{kv}")))?;
                Ok((k.to_string(), v.parse::<f32>()?))
            })
            .collect::<Result<Vec<_>>>()?;
        out.push(EvalCfg {
            name: c[0].into(),
            graph: c[1].into(),
            weights: c[2].into(),
            aux: c[3].into(),
            scalars,
            note: c[5].into(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shapes() {
        assert_eq!(parse_shape("4x2x3").unwrap(), vec![4, 2, 3]);
        assert_eq!(parse_shape("").unwrap(), Vec::<usize>::new());
    }
}
