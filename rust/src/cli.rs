//! Tiny hand-rolled CLI argument parser (the vendored offline crate set
//! has no clap; see DESIGN.md environment substitutions).
//!
//! Supports `binary <subcommand> --flag value --switch positional`.
//! Typed getters report malformed values as
//! [`P3Error::InvalidFlag`](crate::error::P3Error::InvalidFlag) instead
//! of silently falling back to the default.

use std::collections::HashMap;

use crate::error::{P3Error, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(mut argv: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut rest: Vec<String> = argv.by_ref().collect();
        rest.reverse();
        while let Some(a) = rest.pop() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if rest
                    .last()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = rest.pop().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, k: &str, default: &'a str) -> &'a str {
        self.get(k).unwrap_or(default)
    }

    /// Integer flag: absent -> default; present-but-malformed -> error.
    pub fn get_usize(&self, k: &str, default: usize) -> Result<usize> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| P3Error::InvalidFlag {
                flag: k.to_string(),
                value: v.to_string(),
            }),
        }
    }

    /// u64 flag (seeds): absent -> default; malformed -> error.
    pub fn get_u64(&self, k: &str, default: u64) -> Result<u64> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| P3Error::InvalidFlag {
                flag: k.to_string(),
                value: v.to_string(),
            }),
        }
    }

    /// Float flag: absent -> default; present-but-malformed -> error.
    pub fn get_f64(&self, k: &str, default: f64) -> Result<f64> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| P3Error::InvalidFlag {
                flag: k.to_string(),
                value: v.to_string(),
            }),
        }
    }

    /// Comma-separated list flag (`--system NPU,P3-LLM`): absent falls
    /// back to `default`; items are whitespace-trimmed and empty
    /// segments dropped; spelling is otherwise kept (registries do
    /// their own case-insensitive lookup).
    pub fn get_list(&self, k: &str, default: &str) -> Vec<String> {
        self.get_or(k, default)
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }

    pub fn has(&self, k: &str) -> bool {
        self.switches.iter().any(|s| s == k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = parse("serve --model tiny-1M --batch=4 req1 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("tiny-1M"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 4);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["req1"]);
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.get_or("corpus", "wiki"), "wiki");
        assert_eq!(a.get_f64("kv_bits", 4.0).unwrap(), 4.0);
        assert_eq!(a.get_u64("seed", 7).unwrap(), 7);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn u64_seeds_parse_and_reject() {
        let a = parse("loadtest --seed 18446744073709551615");
        assert_eq!(a.get_u64("seed", 0).unwrap(), u64::MAX);
        let b = parse("loadtest --seed -1");
        assert!(matches!(
            b.get_u64("seed", 0),
            Err(P3Error::InvalidFlag { .. })
        ));
    }

    #[test]
    fn list_flags_split_on_commas() {
        let a = parse("cluster --policy rr,jsq, --replicas 2");
        assert_eq!(a.get_list("policy", "jsq"), vec!["rr", "jsq"]);
        // absent falls back to the default spelling
        assert_eq!(a.get_list("scenario", "chat-poisson"), vec!["chat-poisson"]);
        assert_eq!(
            parse("x --sys a,b,c").get_list("sys", ""),
            vec!["a", "b", "c"]
        );
        // all-empty selections collapse to nothing
        assert!(parse("x --sys ,").get_list("sys", "z").is_empty());
        // items are trimmed, so spaced spellings match unspaced ones
        let mut spaced = Args::default();
        spaced.flags.insert("policy".into(), " rr , jsq ".into());
        assert_eq!(spaced.get_list("policy", ""), vec!["rr", "jsq"]);
    }

    #[test]
    fn malformed_values_are_errors_not_defaults() {
        let a = parse("serve --batch eight --rate 1.5x");
        match a.get_usize("batch", 8) {
            Err(P3Error::InvalidFlag { flag, value }) => {
                assert_eq!(flag, "batch");
                assert_eq!(value, "eight");
            }
            other => panic!("expected InvalidFlag, got {other:?}"),
        }
        assert!(matches!(
            a.get_f64("rate", 1.0),
            Err(P3Error::InvalidFlag { .. })
        ));
        // well-formed values still parse; absent flags still default
        assert_eq!(a.get_usize("absent", 3).unwrap(), 3);
        assert_eq!(parse("x --batch 2").get_usize("batch", 8).unwrap(), 2);
    }
}
