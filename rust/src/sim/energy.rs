//! Energy constants (pJ), calibrated so the relative numbers of the
//! paper's Fig. 10 / Table VIII hold:
//!
//! * internal DRAM access (cell array + column decoder): the dominant
//!   PIM energy term the paper says does not change under TEP,
//! * external HBM transfer (array + PHY + interface) ~2.8x internal,
//! * PCU MAC energies come from Table VIII via `PcuConfig`.

/// DRAM array read energy per byte, inside the die (no PHY): 2.5 pJ/bit.
pub const DRAM_INTERNAL_PJ_PER_BYTE: f64 = 20.0;

/// Full off-chip HBM access per byte: ~7 pJ/bit.
pub const DRAM_EXT_PJ_PER_BYTE: f64 = 56.0;

/// One bank-row activation.
pub const ROW_ACT_PJ: f64 = 1000.0;

/// On-chip SRAM (scratchpad) access per byte.
pub const SRAM_PJ_PER_BYTE: f64 = 1.5;

/// NPU vector-unit op.
pub const VECTOR_OP_PJ: f64 = 0.8;

/// Ecco-style codebook + Huffman decode, per decompressed byte.
pub const DECOMPRESS_PJ_PER_BYTE: f64 = 6.0;

#[cfg(test)]
mod tests {
    #[test]
    fn external_costs_more_than_internal() {
        assert!(super::DRAM_EXT_PJ_PER_BYTE > 2.0 * super::DRAM_INTERNAL_PJ_PER_BYTE);
    }
}
