//! PIM timing model: Newton-style command streaming (Fig. 7).
//!
//! A GEMV pass streams the stored matrix once through all banks: one
//! 256-bit column per bank per `t_cmd`; every column feeds the PCU's
//! multipliers, so compute and command rate coincide by construction
//! (the PCU datapath is sized to the column width -- 16 FP16 ops for
//! HBM-PIM, 64 4-bit ops for P3-LLM).
//!
//! A GEMM with `m` input rows needs `ceil(m / weight_reuse)` passes:
//! HBM-PIM re-reads the matrix per input row (no reuse -> its Fig. 9/10
//! batch-scaling pathology); the P3 throughput-enhanced PCU reuses each
//! column for two inputs within a `t_CCD_L` window (Section V-D).

use crate::config::accel::PimConfig;
use crate::sim::{energy, Cost};

/// Fraction of row-activation latency hidden by bank-group interleaving
/// (commands to other bank groups proceed while one group activates).
const ACT_OVERLAP: f64 = 0.75;

#[derive(Debug, Clone, Copy)]
pub struct PimGemm {
    /// input rows sharing the stored matrix
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// independent instances (e.g. batch x kv-heads)
    pub count: usize,
    /// stored operand bits per element (weights/KV under the scheme)
    pub stored_bits: f64,
}

impl PimConfig {
    /// Time + energy for a batched GEMM on the PIM subsystem.  All
    /// instances are spread across channels/banks (weights and KV are
    /// interleaved across the full stack, as in HBM-PIM's all-bank mode).
    pub fn gemm(&self, g: PimGemm) -> Cost {
        let pcu = &self.pcu;
        let passes = g.m.div_ceil(pcu.weight_reuse) as f64;
        let stored_bytes =
            (g.k * g.n * g.count) as f64 * g.stored_bits / 8.0;
        let read_bytes = stored_bytes * passes;

        // command-rate bound: bytes / internal (t_CCD_L) bandwidth;
        // the compute roof can in principle bind instead, so take max
        let bw = self.internal_bw_gbps(); // GB/s == B/ns
        let macs = (g.m * g.k * g.n * g.count) as f64;
        let compute_ns = macs / pcu.system_macs_per_sec(&self.hbm) * 1e9;
        let stream_ns = (read_bytes / bw).max(compute_ns);

        // row activation overhead: each bank re-activates when its
        // streaming crosses a row boundary
        let banks = (self.hbm.channels * self.hbm.banks_per_channel) as f64;
        let rows_per_bank = (read_bytes / banks / self.hbm.row_bytes as f64).ceil();
        let act_ns = rows_per_bank
            * (self.hbm.t_rcd_ns + self.hbm.t_rp_ns)
            * (1.0 - ACT_OVERLAP);

        // input broadcast from NPU over the external bus
        let in_bytes = (g.m * g.k * g.count) as f64 * pcu.input_bits / 8.0;
        let bcast_ns = in_bytes / self.hbm.ext_bw_gbps;

        let pj = read_bytes * energy::DRAM_INTERNAL_PJ_PER_BYTE
            + macs * pcu.mac_energy_pj * pcu.power_factor
            + rows_per_bank * banks * energy::ROW_ACT_PJ
            + in_bytes * energy::DRAM_EXT_PJ_PER_BYTE;

        Cost { ns: stream_ns + act_ns + bcast_ns, pj }
    }

    /// Number of PIM commands a pass issues (Fig. 7 trace length).
    pub fn commands_per_pass(&self, k: usize, n: usize, stored_bits: f64) -> usize {
        let bytes = (k * n) as f64 * stored_bits / 8.0;
        let per_cmd = (self.hbm.channels
            * self.hbm.banks_per_channel
            * self.hbm.col_bytes) as f64;
        (bytes / per_cmd).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::accel::{HbmTiming, PcuConfig};

    fn pim(pcu: PcuConfig) -> PimConfig {
        PimConfig { hbm: HbmTiming::default(), pcu }
    }

    #[test]
    fn p3_gemv_faster_by_bit_ratio_at_batch1() {
        // single-input GEMV is column-read bound: the gain over
        // HBM-PIM is the stored-bit ratio (16 / 4.25 ~ 3.8x); the full
        // 8x roofline shows up once TEP reuse kicks in at batch 2
        let g16 = PimGemm { m: 1, k: 4096, n: 4096, count: 32, stored_bits: 16.0 };
        let g4 = PimGemm { stored_bits: 4.25, ..g16 };
        let base = pim(PcuConfig::hbm_pim()).gemm(g16).ns;
        let fast = pim(PcuConfig::p3llm()).gemm(g4).ns;
        let ratio = base / fast;
        assert!((3.0..4.5).contains(&ratio), "{ratio}");
        // batch 2: TEP doubles effective throughput -> ~7.5x
        let b2_16 = PimGemm { m: 2, ..g16 };
        let b2_4 = PimGemm { m: 2, ..g4 };
        let r2 = pim(PcuConfig::hbm_pim()).gemm(b2_16).ns
            / pim(PcuConfig::p3llm()).gemm(b2_4).ns;
        assert!((6.0..9.0).contains(&r2), "{r2}");
    }

    #[test]
    fn tep_reuse_helps_batch2_not_batch1() {
        let p3 = pim(PcuConfig::p3llm());
        let no_tep = pim(PcuConfig::p3llm_no_tep());
        let b1 = PimGemm { m: 1, k: 4096, n: 4096, count: 32, stored_bits: 4.25 };
        let b2 = PimGemm { m: 2, ..b1 };
        // batch 1: both stream the matrix once -> same time
        let (a, b) = (p3.gemm(b1).ns, no_tep.gemm(b1).ns);
        assert!((a - b).abs() / b < 0.05, "{a} vs {b}");
        // batch 2: TEP reads once, noTEP reads twice -> ~2x gap
        let ratio = no_tep.gemm(b2).ns / p3.gemm(b2).ns;
        assert!((1.7..2.3).contains(&ratio), "{ratio}");
    }

    #[test]
    fn hbm_pim_rereads_weights_per_batch_row() {
        let p = pim(PcuConfig::hbm_pim());
        let b1 = PimGemm { m: 1, k: 1024, n: 1024, count: 1, stored_bits: 16.0 };
        let b4 = PimGemm { m: 4, ..b1 };
        let r = p.gemm(b4).ns / p.gemm(b1).ns;
        assert!((3.5..4.5).contains(&r), "{r}");
    }

    #[test]
    fn energy_scales_with_power_factor() {
        let g = PimGemm { m: 2, k: 1024, n: 1024, count: 1, stored_bits: 4.25 };
        let e_tep = pim(PcuConfig::p3llm()).gemm(g).pj;
        let e_no = pim(PcuConfig::p3llm_no_tep()).gemm(g).pj;
        // TEP reads the matrix once instead of twice: net energy WIN
        // despite the 1.28x PCU power factor (paper: 1.56x better)
        assert!(e_tep < e_no, "{e_tep} vs {e_no}");
    }
}
