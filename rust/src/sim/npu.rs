//! NPU timing model: 4 cores x 128x128 systolic array + vector units,
//! fed from HBM at the external bandwidth (512 GB/s).  GEMMs are
//! double-buffered through the 16 MB scratchpad, so time is the max of
//! the compute and memory rooflines plus a small fill/drain overhead.

use crate::config::accel::{HbmTiming, NpuConfig};
use crate::sim::{energy, Cost};

#[derive(Debug, Clone, Copy)]
pub struct NpuGemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub count: usize,
    /// stored operand bits (weights read from DRAM)
    pub stored_bits: f64,
    /// activation bits
    pub act_bits: f64,
    /// extra per-byte decompression cost factor (Ecco's codebook +
    /// Huffman decode path; 1.0 = none)
    pub decompress_factor: f64,
}

impl Default for NpuGemm {
    fn default() -> Self {
        NpuGemm {
            m: 1,
            k: 1,
            n: 1,
            count: 1,
            stored_bits: 16.0,
            act_bits: 16.0,
            decompress_factor: 1.0,
        }
    }
}

/// Systolic array fill/drain overhead per GEMM instance tile wave.
const TILE_OVERHEAD_NS: f64 = 0.3;

pub fn gemm(npu: &NpuConfig, hbm: &HbmTiming, g: NpuGemm) -> Cost {
    let macs = (g.m * g.k * g.n * g.count) as f64;
    // low-precision operands double MAC issue rate on 8-bit paths
    // (the NPU supports INT8/FP8 at 2x rate like modern tensor cores)
    let speed = if g.stored_bits <= 8.0 && g.act_bits <= 8.0 { 2.0 } else { 1.0 };
    let compute_ns = macs / (npu.peak_macs_per_sec() * speed) * 1e9;

    let stored_bytes = (g.k * g.n * g.count) as f64 * g.stored_bits / 8.0;
    let act_bytes = (g.m * g.k * g.count) as f64 * g.act_bits / 8.0
        + (g.m * g.n * g.count) as f64 * 2.0;
    let mem_ns =
        (stored_bytes * g.decompress_factor + act_bytes) / hbm.ext_bw_gbps;

    let ns = compute_ns.max(mem_ns) + TILE_OVERHEAD_NS;
    let pj = macs * npu.mac_energy_pj
        + (stored_bytes + act_bytes)
            * (energy::DRAM_EXT_PJ_PER_BYTE + energy::SRAM_PJ_PER_BYTE)
        + stored_bytes * (g.decompress_factor - 1.0)
            * energy::DECOMPRESS_PJ_PER_BYTE;
    Cost { ns, pj }
}

/// Vector-unit op (softmax, RoPE, norms, requant epilogues).
pub fn vector(npu: &NpuConfig, elems: usize) -> Cost {
    // ~4 vector ops per element (exp + sum + div etc. amortized)
    let ops = elems as f64 * 4.0;
    Cost {
        ns: ops / npu.vector_ops_per_sec() * 1e9,
        pj: ops * energy::VECTOR_OP_PJ,
    }
}

/// Move bytes across the NPU<->PIM boundary (external bus).
pub fn transfer(hbm: &HbmTiming, bytes: f64) -> Cost {
    Cost {
        ns: bytes / hbm.ext_bw_gbps,
        pj: bytes * energy::DRAM_EXT_PJ_PER_BYTE,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_gemv_is_memory_bound() {
        let npu = NpuConfig::default();
        let hbm = HbmTiming::default();
        let g = NpuGemm { m: 1, k: 4096, n: 4096, ..Default::default() };
        let c = gemm(&npu, &hbm, g);
        // memory roofline: 32 MB fp16 weights / 512 GB/s = 65.5 us
        assert!((c.ns - 65536.0).abs() / 65536.0 < 0.1, "{}", c.ns);
    }

    #[test]
    fn large_batch_becomes_compute_bound() {
        let npu = NpuConfig::default();
        let hbm = HbmTiming::default();
        let b1 = gemm(&npu, &hbm, NpuGemm { m: 1, k: 4096, n: 4096, ..Default::default() });
        let b256 = gemm(&npu, &hbm,
            NpuGemm { m: 256, k: 4096, n: 4096, ..Default::default() });
        // 256x work in much less than 256x time (reuse)
        assert!(b256.ns < 4.0 * b1.ns);
    }

    #[test]
    fn quantized_weights_cut_memory_time() {
        let npu = NpuConfig::default();
        let hbm = HbmTiming::default();
        let fp = gemm(&npu, &hbm, NpuGemm { m: 1, k: 4096, n: 4096, ..Default::default() });
        let q = gemm(&npu, &hbm,
            NpuGemm { m: 1, k: 4096, n: 4096, stored_bits: 4.14, act_bits: 8.0,
                      ..Default::default() });
        let r = fp.ns / q.ns;
        assert!((3.0..4.5).contains(&r), "{r}");
    }
}
