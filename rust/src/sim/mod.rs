//! Cycle-level performance and energy models (paper Section VI-A:
//! "we develop a cycle-level simulator to model the P3-LLM system with
//! 4 NPU cores and 16 pseudo HBM channels", PIM methodology following
//! Newton [23]).
//!
//! Time is modeled in nanoseconds at DRAM-command granularity for the
//! PIM side and systolic/bandwidth rooflines for the NPU side; energy
//! in picojoules from per-access constants (`energy`).

pub mod energy;
pub mod npu;
pub mod dram;
pub mod pim;
pub mod roofline;

/// Cost of running one operator somewhere.
#[derive(Debug, Clone, Copy, Default)]
pub struct Cost {
    pub ns: f64,
    pub pj: f64,
}

impl Cost {
    pub fn add(&mut self, o: Cost) {
        self.ns += o.ns;
        self.pj += o.pj;
    }
}
