//! Event-level DRAM bank-state model (Newton-style, paper Section VI-A).
//!
//! The analytical model in `sim::pim` costs a GEMV pass in closed form;
//! this module replays the same pass command-by-command against
//! per-bank state machines (row open/close, tRCD/tRP/tRAS, tCCD_L
//! between column reads of one bank group) and reports the exact cycle
//! count.  `tests` assert the two models agree within a few percent --
//! the closed form is what the accelerator sweeps use, the event model
//! is the ground truth for the Fig. 7 trace.

use crate::config::accel::HbmTiming;

#[derive(Debug, Clone, Copy, PartialEq)]
enum BankState {
    Idle,
    /// row open since (ns), row id
    Active(f64, usize),
}

#[derive(Debug, Clone)]
pub struct Bank {
    state: BankState,
    /// earliest time the next column command may issue
    ready_at: f64,
}

impl Default for Bank {
    fn default() -> Self {
        Bank { state: BankState::Idle, ready_at: 0.0 }
    }
}

/// One PIM channel: banks stream a weight matrix in lockstep (all-bank
/// mode), one 32 B column per command per bank.
#[derive(Debug)]
pub struct Channel {
    pub hbm: HbmTiming,
    banks: Vec<Bank>,
    pub now_ns: f64,
    pub stats: ChannelStats,
}

#[derive(Debug, Default, Clone)]
pub struct ChannelStats {
    pub col_reads: usize,
    pub activations: usize,
    pub precharges: usize,
}

impl Channel {
    pub fn new(hbm: HbmTiming) -> Self {
        let banks = vec![Bank::default(); hbm.banks_per_channel];
        Channel { hbm, banks, now_ns: 0.0, stats: Default::default() }
    }

    /// Issue one all-bank column read of row `row` at byte offset
    /// `col`; advances time by the constrained command period.
    pub fn all_bank_read(&mut self, row: usize) {
        let t_ccd = self.hbm.t_ccd_l_ns;
        let mut issue_at = self.now_ns;
        // activate any bank whose open row differs
        let mut any_activation = false;
        for b in self.banks.iter_mut() {
            match b.state {
                BankState::Active(_, r) if r == row => {}
                BankState::Active(since, _) => {
                    // precharge + activate; honor tRAS since activation
                    let pre_at = (since + 33.0).max(self.now_ns); // tRAS~33
                    let ready = pre_at + self.hbm.t_rp_ns + self.hbm.t_rcd_ns;
                    b.state = BankState::Active(ready, row);
                    b.ready_at = ready;
                    self.stats.precharges += 1;
                    self.stats.activations += 1;
                    any_activation = true;
                }
                BankState::Idle => {
                    let ready = self.now_ns + self.hbm.t_rcd_ns;
                    b.state = BankState::Active(ready, row);
                    b.ready_at = ready;
                    self.stats.activations += 1;
                    any_activation = true;
                }
            }
            issue_at = issue_at.max(b.ready_at);
        }
        let _ = any_activation;
        self.now_ns = issue_at + t_ccd;
        self.stats.col_reads += 1;
        for b in self.banks.iter_mut() {
            b.ready_at = self.now_ns;
        }
    }

    /// Stream `bytes_per_bank` of a matrix through every bank; returns
    /// elapsed ns.  Rows are `row_bytes` long, columns `col_bytes`.
    pub fn stream_matrix(&mut self, bytes_per_bank: usize) -> f64 {
        let start = self.now_ns;
        let cols_per_row = self.hbm.row_bytes / self.hbm.col_bytes;
        let total_cols = bytes_per_bank.div_ceil(self.hbm.col_bytes);
        for c in 0..total_cols {
            let row = c / cols_per_row;
            self.all_bank_read(row);
        }
        self.now_ns - start
    }
}

/// Event-model GEMV pass time across the whole PIM stack (all channels
/// stream in parallel -> one channel's time is the stack's time).
pub fn gemv_pass_ns(hbm: &HbmTiming, stored_bytes: f64) -> f64 {
    let per_bank = stored_bytes
        / (hbm.channels * hbm.banks_per_channel) as f64;
    let mut ch = Channel::new(hbm.clone());
    ch.stream_matrix(per_bank.ceil() as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::accel::{PcuConfig, PimConfig};
    use crate::sim::pim::PimGemm;

    #[test]
    fn single_row_streams_at_tccd() {
        let hbm = HbmTiming::default();
        let mut ch = Channel::new(hbm.clone());
        // one row per bank: 32 cols -> tRCD + 32 * tCCD_L
        let t = ch.stream_matrix(hbm.row_bytes);
        let want = hbm.t_rcd_ns + 32.0 * hbm.t_ccd_l_ns;
        assert!((t - want).abs() < 1e-6, "{t} vs {want}");
        assert_eq!(ch.stats.activations, hbm.banks_per_channel);
        assert_eq!(ch.stats.col_reads, 32);
    }

    #[test]
    fn row_switch_costs_precharge_activate() {
        let hbm = HbmTiming::default();
        let mut ch = Channel::new(hbm.clone());
        let t2 = ch.stream_matrix(2 * hbm.row_bytes);
        let mut ch1 = Channel::new(hbm.clone());
        let t1 = ch1.stream_matrix(hbm.row_bytes);
        // second row adds stream time + (tRP + tRCD) switch penalty
        let penalty = t2 - 2.0 * t1 + hbm.t_rcd_ns;
        assert!(penalty > 0.0, "penalty {penalty}");
        assert_eq!(ch.stats.precharges, hbm.banks_per_channel);
    }

    #[test]
    fn event_model_close_to_analytical() {
        // the closed-form pim.gemm stream time must agree with the
        // event model within ~15% for a realistic weight matrix
        let hbm = HbmTiming::default();
        let pim = PimConfig { hbm: hbm.clone(), pcu: PcuConfig::hbm_pim() };
        let g = PimGemm { m: 1, k: 4096, n: 4096, count: 8, stored_bits: 16.0 };
        let analytical = pim.gemm(g).ns;
        let stored = (g.k * g.n * g.count) as f64 * 2.0;
        let event = gemv_pass_ns(&hbm, stored);
        let rel = (analytical - event).abs() / event;
        assert!(rel < 0.15, "analytical {analytical} vs event {event}");
    }

    #[test]
    fn event_model_monotone_in_size() {
        let hbm = HbmTiming::default();
        let a = gemv_pass_ns(&hbm, 1e6);
        let b = gemv_pass_ns(&hbm, 2e6);
        assert!(b > 1.8 * a, "{a} {b}");
    }
}
