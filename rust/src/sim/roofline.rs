//! Roofline analysis (paper Fig. 4): attainable MAC throughput vs
//! arithmetic intensity for NPU, HBM-PIM and P3-LLM, with the paper's
//! operator markers (MHA, GQA at group G, linear at batch BS).

use crate::config::accel::{HbmTiming, NpuConfig, PcuConfig};

#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    /// peak MAC/s
    pub peak: f64,
    /// bytes/s the compute units can be fed at
    pub bw: f64,
}

impl Platform {
    /// attainable MAC/s at arithmetic intensity `ai` (MACs per byte of
    /// stored-operand traffic)
    pub fn attainable(&self, ai: f64) -> f64 {
        (self.bw * ai).min(self.peak)
    }

    /// intensity where the roof flattens
    pub fn knee(&self) -> f64 {
        self.peak / self.bw
    }
}

pub fn npu_platform(npu: &NpuConfig, hbm: &HbmTiming) -> Platform {
    Platform {
        name: "NPU".into(),
        peak: npu.peak_macs_per_sec(),
        bw: hbm.ext_bw_gbps * 1e9,
    }
}

pub fn pim_platform(pcu: &PcuConfig, hbm: &HbmTiming) -> Platform {
    Platform {
        name: pcu.name.into(),
        peak: pcu.system_macs_per_sec(hbm),
        bw: hbm.pim_internal_bw_gbps(hbm.t_ccd_l_ns) * 1e9,
    }
}

/// Arithmetic intensity of a decode operator: MACs per stored byte.
/// A GEMV over an fp16 matrix has intensity 0.5 MAC/B; GQA with group G
/// (or a batch-BS linear) raises it to G (BS) rows per matrix pass.
pub fn op_intensity(rows_sharing: usize, stored_bits: f64) -> f64 {
    rows_sharing as f64 / (stored_bits / 8.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_hbm_pim_advantage_dies_at_g4() {
        let hbm = HbmTiming::default();
        let npu = npu_platform(&NpuConfig::default(), &hbm);
        let pim = pim_platform(&PcuConfig::hbm_pim(), &hbm);
        // MHA (G=1, fp16): PIM wins big
        let ai = op_intensity(1, 16.0);
        assert!(pim.attainable(ai) > 3.0 * npu.attainable(ai));
        // PIM roof flattens at its knee: G=4 fp16 already saturates it
        let ai4 = op_intensity(4, 16.0);
        assert!(pim.attainable(ai4) <= pim.peak * 1.001);
        // NPU is still memory-bound even at BS=16
        let ai16 = op_intensity(16, 16.0);
        assert!(npu.attainable(ai16) < npu.peak);
    }

    #[test]
    fn p3_roofline_8x_hbm_pim() {
        let hbm = HbmTiming::default();
        let base = pim_platform(&PcuConfig::hbm_pim(), &hbm);
        let p3 = pim_platform(&PcuConfig::p3llm(), &hbm);
        assert!((p3.peak / base.peak - 8.0).abs() < 0.01);
    }
}
