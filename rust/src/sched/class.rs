//! SLO classes (priority tiers) and per-request tier mixes.
//!
//! Past saturation a serving fleet cannot meet every tenant's SLO at
//! once; the scheduler needs to know *whose* budget to protect.  An
//! [`SloClass`] is that signal: attached at the traffic layer, carried
//! through `Request` / `ReqRecord`, read by the coordinator's
//! admission ordering and victim selection, and reported per tier in
//! `LoadReport` breakdowns.

use crate::error::{P3Error, Result};
use crate::testutil::Rng;

/// Request priority tier.  The variant order *is* the priority order
/// (`rank`): `Interactive` outranks `Batch` outranks `BestEffort`, so
/// the derived `Ord` sorts highest priority first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SloClass {
    /// Human-facing latency (chat, completion): the tier whose SLO the
    /// preemptive scheduler protects under overload.
    Interactive,
    /// Deadline-tolerant throughput work (summarization, evals).
    Batch,
    /// Scavenger traffic: absorbs the loss when capacity runs out,
    /// shielded from outright starvation only by the aging floor.
    BestEffort,
}

impl SloClass {
    /// Priority rank: 0 is highest.  Admission orders ascending by
    /// rank; victims are picked descending (lowest tier first).
    pub fn rank(&self) -> u8 {
        match self {
            SloClass::Interactive => 0,
            SloClass::Batch => 1,
            SloClass::BestEffort => 2,
        }
    }

    /// Registry name (`--tiers` breakdown labels).
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
            SloClass::BestEffort => "best-effort",
        }
    }

    /// How much a tier's latency budget widens relative to the
    /// scenario's base [`SloSpec`](crate::traffic::SloSpec): the
    /// interactive tier is judged against the base budget, lower tiers
    /// against proportionally looser ones (a best-effort request is
    /// not "missing" a chatbot TTFT it never bought).
    pub fn slo_factor(&self) -> f64 {
        match self {
            SloClass::Interactive => 1.0,
            SloClass::Batch => 4.0,
            SloClass::BestEffort => 16.0,
        }
    }

    /// Target SLO attainment for the tier -- the fraction of requests
    /// that must meet their (scaled) latency budget.  The complement
    /// is the tier's *error budget*, which the `obs` burn-rate rules
    /// spend: interactive tenants buy five nines of patience less than
    /// batch ones tolerate.
    pub fn attainment_target(&self) -> f64 {
        match self {
            SloClass::Interactive => 0.95,
            SloClass::Batch => 0.90,
            SloClass::BestEffort => 0.75,
        }
    }

    /// Every tier, highest priority first.
    pub fn all() -> [SloClass; 3] {
        [SloClass::Interactive, SloClass::Batch, SloClass::BestEffort]
    }

    /// Case-insensitive lookup (accepts short spellings).
    pub fn by_name(name: &str) -> Option<SloClass> {
        match name.to_ascii_lowercase().as_str() {
            "interactive" | "int" | "i" => Some(SloClass::Interactive),
            "batch" | "b" => Some(SloClass::Batch),
            "best-effort" | "besteffort" | "be" | "e" => {
                Some(SloClass::BestEffort)
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for SloClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Relative tier weights a traffic scenario draws per-request classes
/// from (`--tiers I/B/E`, e.g. `50/30/20`).  Weights need not sum to
/// one; they are normalized at sampling time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierMix {
    pub interactive: f64,
    pub batch: f64,
    pub best_effort: f64,
}

impl TierMix {
    /// The mixed-tenant default the overload scenarios use: half
    /// interactive over a batch + best-effort base.
    pub fn mixed() -> Self {
        TierMix { interactive: 0.5, batch: 0.3, best_effort: 0.2 }
    }

    /// Strict typed parse of an `I/B/E` weight spec ("50/30/20"):
    /// exactly three `/`-separated weights, each finite and `>= 0`,
    /// summing to something positive.  Anything else is a typed
    /// [`P3Error::InvalidFlag`] on `--tiers`.
    pub fn parse(spec: &str) -> Result<TierMix> {
        let bad = || P3Error::InvalidFlag {
            flag: "tiers".into(),
            value: spec.into(),
        };
        let parts: Vec<f64> = spec
            .split('/')
            .map(|p| p.trim().parse::<f64>().map_err(|_| bad()))
            .collect::<Result<_>>()?;
        if parts.len() != 3
            || parts.iter().any(|w| !w.is_finite() || *w < 0.0)
            || parts.iter().sum::<f64>() <= 0.0
        {
            return Err(bad());
        }
        Ok(TierMix {
            interactive: parts[0],
            batch: parts[1],
            best_effort: parts[2],
        })
    }

    /// Normalized share of one tier.
    pub fn share(&self, class: SloClass) -> f64 {
        let total = self.interactive + self.batch + self.best_effort;
        let w = match class {
            SloClass::Interactive => self.interactive,
            SloClass::Batch => self.batch,
            SloClass::BestEffort => self.best_effort,
        };
        if total > 0.0 {
            w / total
        } else {
            0.0
        }
    }

    /// Draw one class by weight (deterministic in the rng stream).
    pub fn sample(&self, rng: &mut Rng) -> SloClass {
        let total = self.interactive + self.batch + self.best_effort;
        let mut u = rng.f64() * total;
        for c in SloClass::all() {
            let w = match c {
                SloClass::Interactive => self.interactive,
                SloClass::Batch => self.batch,
                SloClass::BestEffort => self.best_effort,
            };
            u -= w;
            if u <= 0.0 {
                return c;
            }
        }
        SloClass::BestEffort
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_order_priority_and_names_round_trip() {
        assert!(SloClass::Interactive < SloClass::Batch);
        assert!(SloClass::Batch < SloClass::BestEffort);
        for c in SloClass::all() {
            assert_eq!(SloClass::by_name(c.name()), Some(c));
            assert_eq!(c.rank() as usize, SloClass::all().iter().position(|x| *x == c).unwrap());
        }
        assert_eq!(SloClass::by_name("BE"), Some(SloClass::BestEffort));
        assert!(SloClass::by_name("platinum").is_none());
        // widening is monotone in rank: lower tiers get looser budgets
        assert!(SloClass::Interactive.slo_factor() == 1.0);
        assert!(SloClass::Batch.slo_factor() > 1.0);
        assert!(SloClass::BestEffort.slo_factor() > SloClass::Batch.slo_factor());
    }

    #[test]
    fn tier_mix_parse_is_strict_and_typed() {
        let m = TierMix::parse("50/30/20").unwrap();
        assert!((m.share(SloClass::Interactive) - 0.5).abs() < 1e-12);
        assert!((m.share(SloClass::BestEffort) - 0.2).abs() < 1e-12);
        // weights normalize, so scaled specs are equivalent shares
        let m2 = TierMix::parse("5/3/2").unwrap();
        for c in SloClass::all() {
            assert!((m.share(c) - m2.share(c)).abs() < 1e-12);
        }
        for bad in ["", "1/2", "1/2/3/4", "a/1/1", "1/-2/1", "0/0/0", "nan/1/1", "inf/1/1"] {
            match TierMix::parse(bad) {
                Err(P3Error::InvalidFlag { flag, value }) => {
                    assert_eq!(flag, "tiers");
                    assert_eq!(value, bad);
                }
                other => panic!("{bad:?}: expected InvalidFlag, got {other:?}"),
            }
        }
    }

    #[test]
    fn sampling_tracks_shares_and_is_deterministic() {
        let m = TierMix::mixed();
        let draw = |seed| {
            let mut r = Rng::new(seed);
            (0..2000).map(|_| m.sample(&mut r)).collect::<Vec<_>>()
        };
        assert_eq!(draw(3), draw(3));
        let xs = draw(3);
        let frac = |c| {
            xs.iter().filter(|&&x| x == c).count() as f64 / xs.len() as f64
        };
        assert!((frac(SloClass::Interactive) - 0.5).abs() < 0.05);
        assert!((frac(SloClass::Batch) - 0.3).abs() < 0.05);
        assert!((frac(SloClass::BestEffort) - 0.2).abs() < 0.05);
        // degenerate single-tier mix draws only that tier
        let solo = TierMix { interactive: 0.0, batch: 0.0, best_effort: 1.0 };
        let mut r = Rng::new(1);
        assert!((0..64).all(|_| solo.sample(&mut r) == SloClass::BestEffort));
    }
}
