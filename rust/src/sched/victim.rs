//! Victim selection for preemptive KV admission.
//!
//! When a higher tier hits `KvExhausted` pressure, the coordinator
//! frees pages by evicting a low-priority in-flight decode.  *Which*
//! victim, and *what happens to its KV*, is the policy:
//!
//! * **recompute** -- drop the victim's pages and requeue it for
//!   re-prefill.  Free is instant; the cost is repaying the prefill,
//!   which is cheap when the shared-prefix cache still holds the
//!   victim's registered prompt pages.
//! * **swap** -- migrate the victim's pages to a modeled slow tier and
//!   restore them on resume, priced by the unified slow-tier transfer
//!   model in [`crate::mem::transfer`] (the same model that prices CXL
//!   page migrations and cluster KV handoffs).

use crate::config::accel::HbmTiming;
use crate::config::llm::LlmConfig;
use crate::sched::SloClass;
use std::cmp::Reverse;

/// What a policy does with the victim's KV pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimMode {
    /// Drop pages; the victim re-prefills its full context on resume.
    Recompute,
    /// Migrate pages to the slow tier; resume pays a modeled restore
    /// transfer instead of recompute.
    Swap,
}

impl VictimMode {
    /// Stable telemetry event name of a preemption under this mode --
    /// what pairs a `preempt:*` instant with its later `recompute` /
    /// `restore` span in the trace (see the DESIGN.md event schema).
    pub fn event_name(self) -> &'static str {
        match self {
            VictimMode::Recompute => "preempt:recompute",
            VictimMode::Swap => "preempt:swap",
        }
    }
}

/// One preemptible in-flight decode, as the selector sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VictimCandidate {
    pub rid: u64,
    pub class: SloClass,
    /// Effective priority rank (aging may have promoted the request
    /// above its nominal class).
    pub rank: u8,
    /// Tokens generated so far (progress that recompute repays).
    pub generated: usize,
    /// KV pages the victim currently occupies (what eviction frees).
    pub kv_pages: usize,
}

/// Pluggable victim selection strategy.
pub trait VictimPolicy {
    fn name(&self) -> &'static str;
    fn mode(&self) -> VictimMode;
    /// Pick the index of the candidate to evict, or `None` to refuse.
    /// Candidates are pre-filtered to ranks strictly below the
    /// newcomer's, so any choice is priority-correct; the policy only
    /// decides *which* low-tier request pays.
    fn select(&self, candidates: &[VictimCandidate]) -> Option<usize>;
}

/// Evict the lowest tier with the least progress: re-prefilling a
/// request that has barely decoded repays almost nothing beyond its
/// (often prefix-cached) prompt.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecomputeVictim;

impl VictimPolicy for RecomputeVictim {
    fn name(&self) -> &'static str {
        "recompute"
    }

    fn mode(&self) -> VictimMode {
        VictimMode::Recompute
    }

    fn select(&self, candidates: &[VictimCandidate]) -> Option<usize> {
        (0..candidates.len()).max_by_key(|&i| {
            let c = &candidates[i];
            (c.rank, Reverse(c.generated), c.rid)
        })
    }
}

/// Evict the lowest tier with the largest KV footprint: each swap
/// costs one modeled transfer regardless of how much it frees, so
/// taking the biggest resident maximizes pages freed per eviction.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapVictim;

impl VictimPolicy for SwapVictim {
    fn name(&self) -> &'static str {
        "swap"
    }

    fn mode(&self) -> VictimMode {
        VictimMode::Swap
    }

    fn select(&self, candidates: &[VictimCandidate]) -> Option<usize> {
        (0..candidates.len()).max_by_key(|&i| {
            let c = &candidates[i];
            (c.rank, c.kv_pages, c.rid)
        })
    }
}

/// Modeled one-way swap transfer time for `tokens` of packed KV: the
/// cache streams through the stack's DRAM (event-level `sim::dram`
/// read pass) and crosses the external bus to the slow tier; the
/// stages pipeline, so the slower one prices the hop.  Delegates to
/// the unified slow-tier transfer model
/// ([`crate::mem::swap_restore_ms`]) so every tier crossing in the
/// stack is priced in one place.
pub fn swap_restore_ms(
    hbm: &HbmTiming,
    model: &LlmConfig,
    tokens: usize,
) -> f64 {
    crate::mem::swap_restore_ms(hbm, model, tokens)
}

/// Registry names, canonical order (`--victim` accepts these).
pub fn all_victim_names() -> Vec<&'static str> {
    vec!["recompute", "swap"]
}

/// One-line description for `--list`-style output.
pub fn victim_desc(name: &str) -> &'static str {
    match name {
        "recompute" => "drop victim pages, re-prefill on resume (cheap with warm prefix cache)",
        "swap" => "migrate pages to a modeled slow tier, priced restore on resume",
        _ => "",
    }
}

/// Case-insensitive lookup (accepts short spellings).
pub fn victim_by_name(name: &str) -> Option<Box<dyn VictimPolicy>> {
    match name.to_ascii_lowercase().as_str() {
        "recompute" | "redo" | "rc" => Some(Box::new(RecomputeVictim)),
        "swap" | "sw" => Some(Box::new(SwapVictim)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::accel::HbmTiming;
    use crate::config::llm;

    fn cand(
        rid: u64,
        class: SloClass,
        generated: usize,
        kv_pages: usize,
    ) -> VictimCandidate {
        VictimCandidate { rid, class, rank: class.rank(), generated, kv_pages }
    }

    #[test]
    fn registry_round_trips_and_rejects_unknown() {
        for name in all_victim_names() {
            let p = victim_by_name(name).expect(name);
            assert_eq!(p.name(), name);
            assert!(!victim_desc(name).is_empty());
        }
        assert_eq!(victim_by_name("SWAP").unwrap().mode(), VictimMode::Swap);
        assert_eq!(
            victim_by_name("redo").unwrap().mode(),
            VictimMode::Recompute
        );
        assert!(victim_by_name("lru").is_none());
    }

    #[test]
    fn recompute_picks_lowest_tier_least_progress() {
        let p = RecomputeVictim;
        let cands = vec![
            cand(1, SloClass::Batch, 2, 8),
            cand(2, SloClass::BestEffort, 9, 2),
            cand(3, SloClass::BestEffort, 3, 6),
        ];
        // lowest tier wins over less progress at a higher tier, and
        // within the tier the least-progressed request pays
        assert_eq!(p.select(&cands), Some(2));
        assert_eq!(p.select(&[]), None);
        // deterministic tie-break on rid
        let tied = vec![
            cand(7, SloClass::Batch, 5, 1),
            cand(4, SloClass::Batch, 5, 9),
        ];
        assert_eq!(p.select(&tied), Some(0));
    }

    #[test]
    fn swap_picks_lowest_tier_biggest_footprint() {
        let p = SwapVictim;
        let cands = vec![
            cand(1, SloClass::BestEffort, 0, 3),
            cand(2, SloClass::BestEffort, 12, 7),
            cand(3, SloClass::Batch, 0, 20),
        ];
        assert_eq!(p.select(&cands), Some(1));
        // aging promotion flows through rank, not class: a promoted
        // best-effort request stops being the preferred victim
        let aged = vec![
            VictimCandidate {
                rank: 0,
                ..cand(1, SloClass::BestEffort, 0, 30)
            },
            cand(2, SloClass::Batch, 0, 1),
        ];
        assert_eq!(p.select(&aged), Some(1));
    }

    #[test]
    fn swap_pricing_scales_with_tokens_and_model() {
        let hbm = HbmTiming::default();
        let tiny = llm::TINY.clone();
        let big = llm::LLAMA2_7B.clone();
        let t64 = swap_restore_ms(&hbm, &tiny, 64);
        let t512 = swap_restore_ms(&hbm, &tiny, 512);
        assert!(t64 > 0.0 && t64.is_finite());
        assert!(t512 > t64, "{t512} vs {t64}");
        // zero tokens still prices a minimal transfer (never free)
        assert!(swap_restore_ms(&hbm, &tiny, 0) > 0.0);
        // a 7B KV footprint costs far more than the tiny model's
        assert!(
            swap_restore_ms(&hbm, &big, 64) > 10.0 * t64,
            "model scaling"
        );
    }
}
