//! SLO-tiered preemptive scheduling: priority classes, tier mixes,
//! and KV victim policies.
//!
//! Past saturation a FIFO batcher degrades every tenant at once; this
//! layer gives the coordinator the two levers graceful degradation
//! needs:
//!
//! * [`SloClass`] / [`TierMix`] -- per-request priority tiers attached
//!   at the traffic layer and carried through `Request` / `ReqRecord`
//!   into per-class `LoadReport` breakdowns.
//! * [`VictimPolicy`] -- under `KvExhausted` pressure from a higher
//!   tier, the engine evicts a low-priority in-flight decode.
//!   [`RecomputeVictim`] drops its pages and requeues it for
//!   re-prefill (cheap when the shared-prefix cache is warm);
//!   [`SwapVictim`] migrates them to a modeled slow tier priced by
//!   [`swap_restore_ms`] and restores on resume.
//!
//! An aging floor keeps preemption from starving the bottom tier: a
//! request queued past the engine's aging window is promoted to
//! top effective rank, which makes it both first in line and
//! unpreemptable.
//!
//! ```
//! use p3llm::sched::{SloClass, TierMix, victim_by_name};
//!
//! let mix = TierMix::parse("50/30/20").unwrap();
//! assert!(mix.share(SloClass::Interactive) > mix.share(SloClass::BestEffort));
//! let policy = victim_by_name("swap").unwrap();
//! assert_eq!(policy.name(), "swap");
//! ```

mod class;
mod victim;

pub use class::{SloClass, TierMix};
pub use victim::{
    all_victim_names, swap_restore_ms, victim_by_name, victim_desc,
    RecomputeVictim, SwapVictim, VictimCandidate, VictimMode, VictimPolicy,
};
