//! Online NPU/PIM operator mapper (paper Fig. 6b + Section V-B).
//!
//! Shares the cost model with `accel::Accel`: for every operator of the
//! decode trace it picks the cheaper engine, honoring the scheme's
//! eligibility rules (pre-RoPE keys pin Q.K^T to the NPU; fp16 scores
//! pin P.V to the NPU).  The serving engine queries it per step; the
//! `pim_trace` example prints the resulting assignment + the Fig. 7
//! command timing.

use crate::accel::Accel;
use crate::config::llm::LlmConfig;
use crate::sim::pim::PimGemm;
use crate::workload::{decode_trace, Op, Operand};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Npu,
    Pim,
}

#[derive(Debug, Clone)]
pub struct Assignment {
    pub op: &'static str,
    pub engine: Engine,
    pub ns: f64,
    /// PIM command count (0 for NPU ops)
    pub commands: usize,
}

/// Aggregate view of one decode step's operator mapping, surfaced by
/// the sim serving backend through `Engine::mapping_summary`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MapSummary {
    pub npu_ops: usize,
    pub pim_ops: usize,
    pub pim_commands: usize,
    /// summed per-op latency (serialized upper bound, ns)
    pub total_ns: f64,
}

/// Per-engine serialized occupancy of one step's assignments:
/// `(npu_ms, pim_ms)`.  The interleaved sim backend prices each
/// sub-batch's critical path from these two sums.
pub fn engine_ms(assignments: &[Assignment]) -> (f64, f64) {
    let (mut npu, mut pim) = (0.0, 0.0);
    for a in assignments {
        match a.engine {
            Engine::Npu => npu += a.ns,
            Engine::Pim => pim += a.ns,
        }
    }
    (npu / 1e6, pim / 1e6)
}

pub fn summarize(assignments: &[Assignment]) -> MapSummary {
    let mut s = MapSummary::default();
    for a in assignments {
        match a.engine {
            Engine::Npu => s.npu_ops += 1,
            Engine::Pim => {
                s.pim_ops += 1;
                s.pim_commands += a.commands;
            }
        }
        s.total_ns += a.ns;
    }
    s
}

/// Map one decode step's operators.
pub fn map_decode_step(
    accel: &Accel,
    model: &LlmConfig,
    bs: usize,
    ctx: usize,
) -> Vec<Assignment> {
    let mut out = vec![];
    for op in decode_trace(model, bs, ctx) {
        match &op {
            Op::Vector { name, elems, .. } => {
                let c = crate::sim::npu::vector(&accel.system.npu, *elems);
                out.push(Assignment {
                    op: name,
                    engine: Engine::Npu,
                    ns: c.ns,
                    commands: 0,
                });
            }
            Op::Gemm { name, m, k, n, count, operand, .. } => {
                let npu_c = accel.npu_cost_pub(&op);
                let pim = accel
                    .system
                    .pim
                    .as_ref()
                    .filter(|_| accel.pim_eligible_pub(model, name, *operand));
                match pim {
                    Some(p) => {
                        let pim_c = accel.pim_cost_pub(p, &op);
                        if pim_c.ns <= npu_c.ns {
                            let stored = match operand {
                                Operand::Weight => accel.scheme.bits.weights,
                                _ => accel.scheme.bits.kv,
                            };
                            let passes = m.div_ceil(p.pcu.weight_reuse);
                            let cmds = p.commands_per_pass(*k, *n, stored)
                                * passes
                                * count;
                            out.push(Assignment {
                                op: name,
                                engine: Engine::Pim,
                                ns: pim_c.ns,
                                commands: cmds,
                            });
                        } else {
                            out.push(Assignment {
                                op: name,
                                engine: Engine::Npu,
                                ns: npu_c.ns,
                                commands: 0,
                            });
                        }
                    }
                    None => out.push(Assignment {
                        op: name,
                        engine: Engine::Npu,
                        ns: npu_c.ns,
                        commands: 0,
                    }),
                }
            }
        }
    }
    out
}

/// Fig. 7-style command timing of one PIM GEMV pass: returns the start
/// time (ns) of each of the first `max_cmds` commands for the baseline
/// (t_CCD_L) and TEP (t_CCD_S compute on each column twice) PCU.
pub fn command_timing(
    pim: &crate::config::accel::PimConfig,
    g: PimGemm,
    max_cmds: usize,
) -> Vec<(usize, f64, &'static str)> {
    let mut out = vec![];
    let reuse = pim.pcu.weight_reuse;
    let n_cols = pim.commands_per_pass(g.k, g.n, g.stored_bits).min(max_cmds);
    for c in 0..n_cols {
        let t_col = c as f64 * pim.hbm.t_ccd_l_ns;
        out.push((c, t_col, "col_read"));
        for r in 0..reuse {
            out.push((c, t_col + r as f64 * pim.pcu.t_cmd_ns, "mac"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llm::{LLAMA2_7B, LLAMA31_8B};

    #[test]
    fn p3_offloads_everything_at_bs1_gqa() {
        let a = Accel::p3llm();
        let asg = map_decode_step(&a, &LLAMA31_8B, 1, 4096);
        for x in &asg {
            if ["qkv_proj", "qk", "pv", "o_proj", "gate_up", "down"]
                .contains(&x.op)
            {
                assert_eq!(x.engine, Engine::Pim, "{}", x.op);
                assert!(x.commands > 0);
            }
            if ["rope", "softmax", "norms", "silu_mul"].contains(&x.op) {
                assert_eq!(x.engine, Engine::Npu, "{}", x.op);
            }
        }
    }

    #[test]
    fn summary_counts_match_assignments() {
        let a = Accel::p3llm();
        let asg = map_decode_step(&a, &LLAMA31_8B, 1, 4096);
        let s = summarize(&asg);
        assert_eq!(s.npu_ops + s.pim_ops, asg.len());
        assert!(s.pim_ops > 0 && s.pim_commands > 0);
        assert!(s.total_ns > 0.0);
    }

    #[test]
    fn engine_ms_partitions_the_serial_sum() {
        let a = Accel::p3llm();
        let asg = map_decode_step(&a, &LLAMA31_8B, 1, 4096);
        let (npu_ms, pim_ms) = engine_ms(&asg);
        let s = summarize(&asg);
        assert!(npu_ms > 0.0 && pim_ms > 0.0);
        assert!(
            ((npu_ms + pim_ms) - s.total_ns / 1e6).abs() < 1e-9,
            "engine split must sum to the serialized total"
        );
    }

    #[test]
    fn prerope_model_runs_qk_on_npu() {
        let a = Accel::p3llm();
        let asg = map_decode_step(&a, &LLAMA2_7B, 1, 4096);
        let qk = asg.iter().find(|x| x.op == "qk").unwrap();
        assert_eq!(qk.engine, Engine::Npu);
    }

    #[test]
    fn large_batch_moves_linears_to_npu() {
        // Fig. 16: at batch >= 8 the PIM becomes compute-bound on
        // linear layers and P3 offloads them to the NPU
        let a = Accel::p3llm();
        let asg = map_decode_step(&a, &LLAMA31_8B, 64, 4096);
        let lin = asg.iter().find(|x| x.op == "gate_up").unwrap();
        assert_eq!(lin.engine, Engine::Npu);
        // but attention stays on PIM (GQA G=4 has little reuse)
        let qk = asg.iter().find(|x| x.op == "qk").unwrap();
        assert_eq!(qk.engine, Engine::Pim);
    }

    #[test]
    fn command_timing_tep_two_macs_per_column() {
        let pim = crate::config::accel::PimConfig {
            hbm: Default::default(),
            pcu: crate::config::accel::PcuConfig::p3llm(),
        };
        let g = PimGemm { m: 2, k: 128, n: 128, count: 1, stored_bits: 4.25 };
        let t = command_timing(&pim, g, 4);
        let macs: Vec<_> = t.iter().filter(|(_, _, k)| *k == "mac").collect();
        let cols: Vec<_> =
            t.iter().filter(|(_, _, k)| *k == "col_read").collect();
        assert_eq!(macs.len(), 2 * cols.len());
    }
}
