//! Request lifecycle for the edge serving loop.

use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    Queued,
    Prefilling,
    Decoding,
    Finished,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// byte-level prompt tokens
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub state: State,
    pub generated: Vec<i32>,
    /// absolute position of the next KV slot (= tokens so far)
    pub pos: usize,
    pub submitted: Instant,
    pub first_token: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id: RequestId(id),
            prompt,
            max_new_tokens,
            state: State::Queued,
            generated: vec![],
            pos: 0,
            submitted: Instant::now(),
            first_token: None,
            finished: None,
        }
    }

    pub fn last_token(&self) -> i32 {
        *self
            .generated
            .last()
            .or_else(|| self.prompt.last())
            .expect("request with empty prompt")
    }

    pub fn done(&self, max_ctx: usize) -> bool {
        self.generated.len() >= self.max_new_tokens
            || self.pos >= max_ctx
    }

    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token
            .map(|t| t.duration_since(self.submitted).as_secs_f64() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_helpers() {
        let mut r = Request::new(1, vec![5, 6, 7], 4);
        assert_eq!(r.last_token(), 7);
        assert!(!r.done(100));
        r.generated.extend([1, 2, 3, 4]);
        assert_eq!(r.last_token(), 4);
        assert!(r.done(100));
        let mut r2 = Request::new(2, vec![1], 100);
        r2.pos = 50;
        assert!(r2.done(50));
    }
}
