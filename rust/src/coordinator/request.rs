//! Request lifecycle for the serving loop.
//!
//! Timestamps are engine-clock milliseconds supplied by the active
//! [`ExecBackend`](super::backend::ExecBackend): wall time for the PJRT
//! backend, simulated NPU-PIM time for the sim backend.  That keeps
//! TTFT / per-token metrics meaningful on both substrates.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    Queued,
    Prefilling,
    Decoding,
    Finished,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Snapshot returned by [`Engine::poll`](super::serve::Engine::poll).
#[derive(Debug, Clone)]
pub struct RequestStatus {
    pub id: RequestId,
    pub state: State,
    /// tokens generated so far (including any already streamed out)
    pub tokens_generated: usize,
    pub ttft_ms: Option<f64>,
    /// set once the request retired from the batch
    pub finished: bool,
}

#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// byte-level prompt tokens
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub state: State,
    pub generated: Vec<i32>,
    /// absolute position of the next KV slot (= tokens so far)
    pub pos: usize,
    /// engine-clock timestamps (ms)
    pub submitted_ms: f64,
    /// when prefill began (queueing delay = this - submitted)
    pub prefill_start_ms: Option<f64>,
    pub first_token_ms: Option<f64>,
    pub finished_ms: Option<f64>,
    /// streaming cursor: tokens before this index were already drained
    /// by `Engine::take_tokens`
    pub streamed: usize,
    /// Some(ms): the prompt's KV was computed elsewhere and migrates in
    /// (prefill/decode disaggregation) -- install it at this modeled
    /// transfer charge instead of running prefill compute
    pub prefill_charge_ms: Option<f64>,
    /// prompt tokens served from the shared-prefix cache at prefill
    /// (0 = miss, or the cache was disabled): their prefill compute
    /// was skipped and their KV pages are shared
    pub cached_prefix_tokens: usize,
    /// SLO priority tier (attached at submit; drives preemptive
    /// admission ordering and victim selection)
    pub class: crate::sched::SloClass,
    /// times this request was evicted mid-decode by a higher tier
    pub preemptions: usize,
    /// KV pages migrated to the slow tier across all swap preemptions
    pub pages_swapped: usize,
    /// KV pages dropped and re-prefilled across recompute preemptions
    pub pages_recomputed: usize,
    /// cold-tier KV pages the ahead-of-decode prefetcher pulled back
    /// to HBM for this request (tiered engines only)
    pub pages_prefetched: usize,
    /// cold-tier KV pages demand-migrated at step time, each stalling
    /// this request's decode (tiered engines only)
    pub pages_demand: usize,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize, now_ms: f64) -> Self {
        Request {
            id: RequestId(id),
            prompt,
            max_new_tokens,
            state: State::Queued,
            generated: vec![],
            pos: 0,
            submitted_ms: now_ms,
            prefill_start_ms: None,
            first_token_ms: None,
            finished_ms: None,
            streamed: 0,
            prefill_charge_ms: None,
            cached_prefix_tokens: 0,
            class: crate::sched::SloClass::Interactive,
            preemptions: 0,
            pages_swapped: 0,
            pages_recomputed: 0,
            pages_prefetched: 0,
            pages_demand: 0,
        }
    }

    pub fn last_token(&self) -> i32 {
        *self
            .generated
            .last()
            .or_else(|| self.prompt.last())
            .expect("request with empty prompt")
    }

    pub fn done(&self, max_ctx: usize) -> bool {
        self.generated.len() >= self.max_new_tokens
            || self.pos >= max_ctx
    }

    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_ms.map(|t| t - self.submitted_ms)
    }

    /// Mean per-token decode latency (excludes the prefill-emitted
    /// first token); `None` until finished or for 1-token requests.
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_ms, self.finished_ms) {
            (Some(first), Some(fin)) if self.generated.len() > 1 => {
                Some((fin - first) / (self.generated.len() - 1) as f64)
            }
            _ => None,
        }
    }

    pub fn status(&self) -> RequestStatus {
        RequestStatus {
            id: self.id,
            state: self.state,
            tokens_generated: self.generated.len(),
            ttft_ms: self.ttft_ms(),
            finished: self.state == State::Finished,
        }
    }

    /// Drain tokens generated since the last drain (streaming).
    pub fn take_new_tokens(&mut self) -> Vec<i32> {
        let out = self.generated[self.streamed..].to_vec();
        self.streamed = self.generated.len();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_helpers() {
        let mut r = Request::new(1, vec![5, 6, 7], 4, 0.0);
        assert_eq!(r.last_token(), 7);
        assert!(!r.done(100));
        r.generated.extend([1, 2, 3, 4]);
        assert_eq!(r.last_token(), 4);
        assert!(r.done(100));
        let mut r2 = Request::new(2, vec![1], 100, 0.0);
        r2.pos = 50;
        assert!(r2.done(50));
    }

    #[test]
    fn timing_on_engine_clock() {
        let mut r = Request::new(1, vec![9], 8, 10.0);
        assert_eq!(r.ttft_ms(), None);
        r.first_token_ms = Some(35.0);
        assert_eq!(r.ttft_ms(), Some(25.0));
        r.generated.extend([1, 2, 3, 4, 5]);
        r.finished_ms = Some(135.0);
        // 100 ms over 4 decode-emitted tokens
        assert_eq!(r.tpot_ms(), Some(25.0));
    }

    #[test]
    fn streaming_cursor_drains_incrementally() {
        let mut r = Request::new(1, vec![9], 8, 0.0);
        r.generated.extend([10, 11]);
        assert_eq!(r.take_new_tokens(), vec![10, 11]);
        assert!(r.take_new_tokens().is_empty());
        r.generated.push(12);
        assert_eq!(r.take_new_tokens(), vec![12]);
        assert_eq!(r.status().tokens_generated, 3);
    }
}
