//! PJRT execution backend: the real-numerics substrate, extracted from
//! the original monolithic serving engine.  Runs the AOT-compiled
//! prefill/decode graphs of the tiny shipped model on the PJRT CPU
//! client; the engine-side lifecycle (batcher, KV pool, metrics) lives
//! in [`super::serve::Engine`] and is shared with the sim backend.

use std::time::Instant;

use super::backend::{covering_or_err, DecodeOut, ExecBackend, Lane, PrefillOut};
use super::batcher::COMPILED_BATCHES;
use super::kvcache::KvPool;
use crate::config::llm::{LlmConfig, TINY};
use crate::error::{P3Error, Result};
use crate::runtime::artifacts::{lit_f32, lit_i32, vec_f32, Runtime};
use crate::runtime::weights::Weights;

/// Prefill graph sequence length: prompts longer than this are rejected
/// at `submit` (the AOT prefill graph has a fixed [1, 64] signature).
pub const PREFILL_T: usize = 64;

pub struct PjrtBackend {
    rt: Runtime,
    model: LlmConfig,
    quantized: bool,
    device_weights: bool,
    pub weights: Weights,
    weight_lits: Vec<xla::Literal>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    t0: Instant,
}

impl PjrtBackend {
    pub fn new(
        artifacts_dir: &str,
        quantized: bool,
        device_weights: bool,
    ) -> Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        let model = TINY.clone();
        let variant = if quantized { "bitmod" } else { "fp" };
        let weights = Weights::load(
            rt.artifacts.data_path(&format!("weights_{variant}"))?,
            &rt.artifacts.dir.join("weights.tsv"),
        )?;
        let mut weight_lits = vec![];
        for t in &weights.tensors {
            weight_lits.push(lit_f32(&t.dims, &t.f32_data)?);
        }
        let mut weight_bufs = vec![];
        if device_weights {
            // §Perf: persistent device-resident weight buffers cut the
            // decode step ~2.8x vs re-uploading literals every call
            for l in &weight_lits {
                weight_bufs.push(rt.to_device(l)?);
            }
        }
        Ok(PjrtBackend {
            rt,
            model,
            quantized,
            device_weights,
            weights,
            weight_lits,
            weight_bufs,
            t0: Instant::now(),
        })
    }

    pub fn quantized(&self) -> bool {
        self.quantized
    }

    fn clone_weight_args(&self) -> Result<Vec<xla::Literal>> {
        self.weight_lits
            .iter()
            .map(crate::runtime::eval::clone_literal)
            .collect()
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model(&self) -> &LlmConfig {
        &self.model
    }

    fn max_prefill(&self) -> usize {
        PREFILL_T
    }

    /// Suffix tile of a prompt whose first `prefix_len` tokens were
    /// adopted from the shared-prefix KV cache.  The AOT prefill graph
    /// has a fixed single-tile `[1, 64]` signature and cannot attend
    /// into cached KV, so the suffix prefills as its own tile: its
    /// tile-internal attention and positions restart at 0 -- a
    /// documented approximation of the true prefix-conditioned
    /// prefill.  The *decode* steps that follow read the full
    /// dequantized cache (adopted prefix pages + suffix KV) at true
    /// positions, so generation attends over the real prefix from the
    /// first decoded token on.  Because of this approximation the
    /// prefix cache is **opt-in** on this backend
    /// (`EngineBuilder::prefix_cache(true)`); the default keeps exact
    /// numerics.
    fn prefill_continue(
        &mut self,
        chunk: &[i32],
        prefix_len: usize,
    ) -> Result<PrefillOut> {
        let _ = prefix_len;
        self.prefill(chunk)
    }

    fn now_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    /// Run the prefill graph, returning the first token plus the
    /// prompt KV (compact `[layer][token][kv_dim]`) and smoothing
    /// factors for the pool.
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let graph = if self.quantized { "prefill_q" } else { "prefill_fp" };
        let exe = self.rt.load(graph)?;
        let kvd = self.model.kv_dim();
        let layers = self.model.layers;
        let true_len = prompt.len().min(PREFILL_T);
        let mut toks = vec![0i32; PREFILL_T];
        toks[..true_len].copy_from_slice(&prompt[..true_len]);

        let out = if self.device_weights {
            let dyn_lits = [
                lit_i32(&[1, PREFILL_T], &toks)?,
                lit_i32(&[], &[true_len as i32])?,
            ];
            let dyn_bufs: Vec<xla::PjRtBuffer> = dyn_lits
                .iter()
                .map(|l| self.rt.to_device(l))
                .collect::<Result<_>>()?;
            let mut refs: Vec<&xla::PjRtBuffer> =
                self.weight_bufs.iter().collect();
            refs.extend(dyn_bufs.iter());
            exe.run_b(&refs)?
        } else {
            let mut args = self.clone_weight_args()?;
            args.push(lit_i32(&[1, PREFILL_T], &toks)?);
            args.push(lit_i32(&[], &[true_len as i32])?);
            exe.run(&args)?
        };
        let logits = vec_f32(&out[0])?;
        let kc = vec_f32(&out[1])?; // [L,1,T,kvd]
        let vc = vec_f32(&out[2])?;
        let sf = vec_f32(&out[3])?; // [L,kvd]

        let smooth: Vec<Vec<f32>> = (0..layers)
            .map(|l| {
                if self.quantized {
                    sf[l * kvd..(l + 1) * kvd].to_vec()
                } else {
                    vec![1.0; kvd]
                }
            })
            .collect();
        // compact [L, T=PREFILL_T, kvd] -> [L, true_len, kvd]
        let mut k = vec![0.0f32; layers * true_len * kvd];
        let mut v = vec![0.0f32; layers * true_len * kvd];
        for l in 0..layers {
            for t in 0..true_len {
                let src = (l * PREFILL_T + t) * kvd;
                let dst = (l * true_len + t) * kvd;
                k[dst..dst + kvd].copy_from_slice(&kc[src..src + kvd]);
                v[dst..dst + kvd].copy_from_slice(&vc[src..src + kvd]);
            }
        }
        Ok(PrefillOut {
            first_token: argmax(&logits),
            smooth,
            k,
            v,
            true_len,
        })
    }

    /// One decode step: pad the lanes to the smallest compiled batch,
    /// materialize the dequantized KV views, run the graph, compact the
    /// outputs back to the live lanes.
    fn decode_step(&mut self, lanes: &[Lane], pool: &KvPool) -> Result<DecodeOut> {
        let b = covering_or_err(&COMPILED_BATCHES, lanes.len())?;
        let model = self.model.clone();
        let (l, ctx, kvd) = (model.layers, model.max_ctx, model.kv_dim());
        let graph = if self.quantized {
            format!("decode_q_b{b}")
        } else {
            format!("decode_fp_b{b}")
        };
        let exe = self.rt.load(&graph)?;

        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut kc = vec![0.0f32; l * b * ctx * kvd];
        let mut vc = vec![0.0f32; l * b * ctx * kvd];
        let mut sfb = vec![1.0f32; l * b * kvd];
        let mut kscratch = vec![0.0f32; ctx * kvd];
        let mut vscratch = vec![0.0f32; ctx * kvd];
        for (lane, li) in lanes.iter().enumerate() {
            tokens[lane] = li.last_token;
            pos[lane] = li.pos as i32;
            let smooth = pool
                .seq_smooth(li.rid)
                .ok_or_else(|| P3Error::Serve(format!("no KV for {}", li.rid)))?;
            for layer in 0..l {
                pool.dequant_layer(li.rid, layer, &mut kscratch, &mut vscratch)?;
                let off = (layer * b + lane) * ctx * kvd;
                kc[off..off + ctx * kvd].copy_from_slice(&kscratch);
                vc[off..off + ctx * kvd].copy_from_slice(&vscratch);
                let soff = (layer * b + lane) * kvd;
                sfb[soff..soff + kvd].copy_from_slice(&smooth[layer]);
            }
        }

        let out = if self.device_weights {
            let dyn_lits = [
                lit_i32(&[b], &tokens)?,
                lit_i32(&[b], &pos)?,
                lit_f32(&[l, b, ctx, kvd], &kc)?,
                lit_f32(&[l, b, ctx, kvd], &vc)?,
                lit_f32(&[l, b, kvd], &sfb)?,
            ];
            let dyn_bufs: Vec<xla::PjRtBuffer> = dyn_lits
                .iter()
                .map(|lit| self.rt.to_device(lit))
                .collect::<Result<_>>()?;
            let mut refs: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
            refs.extend(dyn_bufs.iter());
            exe.run_b(&refs)?
        } else {
            let mut args = self.clone_weight_args()?;
            args.push(lit_i32(&[b], &tokens)?);
            args.push(lit_i32(&[b], &pos)?);
            args.push(lit_f32(&[l, b, ctx, kvd], &kc)?);
            args.push(lit_f32(&[l, b, ctx, kvd], &vc)?);
            args.push(lit_f32(&[l, b, kvd], &sfb)?);
            exe.run(&args)?
        };
        let logits = vec_f32(&out[0])?; // [b, vocab]
        let gk = vec_f32(&out[1])?; // [l, b, kvd] (padded batch)
        let gv = vec_f32(&out[2])?;

        // compact padded-batch outputs to the live lanes
        let n = lanes.len();
        let mut next = Vec::with_capacity(n);
        let mut new_k = vec![0.0f32; l * n * kvd];
        let mut new_v = vec![0.0f32; l * n * kvd];
        for lane in 0..n {
            next.push(argmax(
                &logits[lane * model.vocab..(lane + 1) * model.vocab],
            ));
            for layer in 0..l {
                let src = (layer * b + lane) * kvd;
                let dst = (layer * n + lane) * kvd;
                new_k[dst..dst + kvd].copy_from_slice(&gk[src..src + kvd]);
                new_v[dst..dst + kvd].copy_from_slice(&gv[src..src + kvd]);
            }
        }
        Ok(DecodeOut { tokens: next, new_k, new_v })
    }
}

pub fn argmax(xs: &[f32]) -> i32 {
    let mut bi = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi as i32
}

#[cfg(test)]
mod tests {
    #[test]
    fn argmax_basic() {
        assert_eq!(super::argmax(&[0.1, -2.0, 5.0, 3.0]), 2);
    }
}
