//! KV-cache manager: quantized (INT4-Asym per-head) page-pooled
//! storage, shared-prefix caching, and the smoothing-factor store
//! (paper Sections IV-A, V-C).
//!
//! The pool is the system of record for KV state.  Storage is
//! **page-granular**: fixed-size pages of [`PAGE_TOKENS`] token slots
//! (all layers, both cache sides) come from a free list, sequences are
//! page tables, and admission reserves a request's *actual* worst case
//! (`prompt + max_new`, context-capped) instead of the old
//! whole-request full-context reservation -- which is what lets batch
//! depth scale with the quantized footprint rather than `max_ctx`.
//!
//! New K/V vectors are packed to 4-bit nibbles with per-(token, head)
//! scale/zero metadata, exactly matching the fake-quant grid the AOT
//! decode graphs emit (so pack -> unpack round-trips bit-exactly);
//! dequantized f32 views are materialized per decode step as the
//! graph's cache inputs -- the CPU-side analogue of the PCU's in-bank
//! decode.  Keys are stored *smoothed* (divided by the per-channel
//! prefill factors); the factors are multiplied back when building the
//! f32 view, numerically identical to the paper's query-side fusion.
//!
//! **Shared-prefix caching** rides on the pages: every full prompt
//! page is registered under a chained content hash
//! (`h_i = H(h_{i-1}, tokens[i*P..(i+1)*P])`, vLLM-style), pages are
//! refcounted, and a later prompt that starts with a cached chain
//! adopts those pages instead of re-prefilling them.  Shared pages are
//! copy-on-write: any writer appending into a page with other
//! referents gets a private copy first.  Cached pages whose refcount
//! is only the cache itself are reclaimable -- allocation evicts the
//! least-recently-used ones under pressure, so the cache can never
//! wedge admission.

use std::collections::HashMap;

use crate::error::{P3Error, Result};
use crate::quant::int::{pack_nibbles, quant_group_int4};

/// Token slots per KV page (all layers, K and V sides).  The page is
/// the unit of allocation, refcounting, sharing and eviction.
pub const PAGE_TOKENS: usize = 16;

#[derive(Debug, Clone)]
pub struct KvLayout {
    pub layers: usize,
    pub kv_dim: usize,
    pub head_dim: usize,
    pub max_ctx: usize,
}

impl KvLayout {
    pub fn heads(&self) -> usize {
        self.kv_dim / self.head_dim
    }

    /// packed bytes per token per layer per cache side
    fn token_bytes(&self) -> usize {
        self.kv_dim / 2
    }

    /// Packed bytes one page holds when full ([`PAGE_TOKENS`] tokens
    /// across all layers, K and V).
    pub fn page_bytes(&self) -> usize {
        2 * self.layers * PAGE_TOKENS * self.token_bytes()
    }

    /// Pages a full-context request can touch at most.
    pub fn pages_per_request(&self) -> usize {
        self.max_ctx.div_ceil(PAGE_TOKENS).max(1)
    }

    /// Worst-case packed bytes a full-context request can occupy --
    /// a *sizing helper* for choosing a `kv_capacity`, **not** an
    /// admission unit: since the pool went page-granular, admission
    /// accounts `ceil((prompt + max_new) / PAGE_TOKENS)` pages per
    /// request (see [`KvPool::can_admit`]), so short requests pack far
    /// denser than this bound suggests.
    pub fn bytes_per_request(&self) -> usize {
        self.pages_per_request() * self.page_bytes()
    }
}

/// splitmix64 finalizer: the one deterministic mixer the coordinator
/// uses (content hashing here, synthetic KV in the sim backend).
pub(crate) fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

const CHAIN_SEED: u64 = 0x5EED_CAFE_F00D_0001;

/// Chained page hash: `H(prev, window)` over one page worth of tokens.
fn chain_hash(prev: u64, window: &[i32]) -> u64 {
    let mut h = mix64(prev ^ 0x9E37_79B9_7F4A_7C15);
    for &t in window {
        h = mix64(h ^ (t as u32 as u64));
    }
    h
}

/// Content hash of a prompt's first KV page (`None` when the prompt is
/// shorter than one page).  This is the prefix-affinity routing key:
/// requests sharing a system prompt share this value, so a router can
/// keep their caches replica-local (`cluster` policy `pa`).
pub fn prefix_page_hash(tokens: &[i32]) -> Option<u64> {
    if tokens.len() < PAGE_TOKENS {
        None
    } else {
        Some(chain_hash(CHAIN_SEED, &tokens[..PAGE_TOKENS]))
    }
}

/// One fixed-size KV page: up to [`PAGE_TOKENS`] token slots across
/// all layers and both cache sides, plus refcount/cache bookkeeping.
#[derive(Debug)]
struct Page {
    /// `[layer]` -> packed nibbles (keys, smoothed domain)
    k_codes: Vec<Vec<u8>>,
    v_codes: Vec<Vec<u8>>,
    /// `[layer]` -> per-(token, head) (scale, zero)
    k_meta: Vec<Vec<(f32, f32)>>,
    v_meta: Vec<Vec<(f32, f32)>>,
    /// committed token slots
    len: usize,
    /// live sequences referencing this page
    refs: usize,
    /// chain hash this page is registered under in the prefix cache
    cached: Option<u64>,
}

impl Page {
    fn new(layers: usize) -> Page {
        Page {
            k_codes: vec![Vec::new(); layers],
            v_codes: vec![Vec::new(); layers],
            k_meta: vec![Vec::new(); layers],
            v_meta: vec![Vec::new(); layers],
            len: 0,
            refs: 0,
            cached: None,
        }
    }

    /// Private copy for copy-on-write (content only; fresh bookkeeping).
    fn fork(&self) -> Page {
        Page {
            k_codes: self.k_codes.clone(),
            v_codes: self.v_codes.clone(),
            k_meta: self.k_meta.clone(),
            v_meta: self.v_meta.clone(),
            len: self.len,
            refs: 1,
            cached: None,
        }
    }

    fn reset(&mut self) {
        for c in self.k_codes.iter_mut().chain(self.v_codes.iter_mut()) {
            c.clear();
        }
        for m in self.k_meta.iter_mut().chain(self.v_meta.iter_mut()) {
            m.clear();
        }
        self.len = 0;
        self.refs = 0;
        self.cached = None;
    }

    fn packed_bytes(&self) -> usize {
        self.k_codes.iter().map(|c| c.len()).sum::<usize>()
            + self.v_codes.iter().map(|c| c.len()).sum::<usize>()
    }
}

/// One live request's view of the pool: a page table plus the
/// per-layer per-channel key smoothing factors its tokens were packed
/// under.
#[derive(Debug)]
struct Seq {
    /// page ids in token order; the first `shared` are adopted from
    /// the prefix cache (refcounts shared with other sequences)
    pages: Vec<usize>,
    /// committed tokens
    len: usize,
    smooth: Vec<Vec<f32>>,
    /// worst-case pages this sequence may still allocate privately
    /// (admission reserved them; lazy allocation draws them down)
    reserved: usize,
    /// leading pages adopted shared from the prefix cache
    shared: usize,
}

/// A successful prefix-cache lookup: the cached pages covering the
/// first `tokens` prompt tokens, plus the smoothing factors they were
/// packed under (the adopting sequence must reuse them, or the shared
/// keys would dequantize in the wrong domain).
///
/// The hit **owns one reference on each matched page** (taken by
/// [`KvPool::lookup_prefix`], so no intervening allocation can evict
/// and recycle them).  Resolve it exactly once: pass it to
/// [`KvPool::alloc_seq`] (which consumes the references, even on
/// error) or return it via [`KvPool::release_hit`].  Dropping a hit
/// without resolving it leaks the pins and the pages can never be
/// reclaimed.
#[derive(Debug)]
pub struct PrefixHit {
    pages: Vec<usize>,
    pub tokens: usize,
    pub smooth: Vec<Vec<f32>>,
}

#[derive(Debug)]
struct CacheSlot {
    page: usize,
    last_use: u64,
    /// generation of this chain's root registration: a stale child
    /// whose root was evicted and re-registered fails the generation
    /// check and is never followed
    root_gen: u64,
    /// smoothing factors, stored on root (depth-0) slots only
    smooth: Option<Vec<Vec<f32>>>,
}

#[derive(Debug, Default)]
struct PrefixCache {
    slots: HashMap<u64, CacheSlot>,
    clock: u64,
    generation: u64,
}

impl PrefixCache {
    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn next_gen(&mut self) -> u64 {
        self.generation += 1;
        self.generation
    }
}

/// Page-granular KV pool: slab of pages + free list, per-request page
/// tables, reservation-based admission, and the shared-prefix cache.
pub struct KvPool {
    pub layout: KvLayout,
    pub capacity_bytes: usize,
    total_pages: usize,
    /// page slab, grown lazily up to `total_pages`
    pages: Vec<Page>,
    free: Vec<usize>,
    seqs: HashMap<u64, Seq>,
    cache: PrefixCache,
}

impl KvPool {
    pub fn new(layout: KvLayout, capacity_bytes: usize) -> Self {
        let total_pages = capacity_bytes / layout.page_bytes().max(1);
        KvPool {
            layout,
            capacity_bytes,
            total_pages,
            pages: Vec::new(),
            free: Vec::new(),
            seqs: HashMap::new(),
            cache: PrefixCache::default(),
        }
    }

    /// Worst-case packed bytes for a full-context request -- a sizing
    /// helper only; see [`KvLayout::bytes_per_request`].
    pub fn bytes_per_request(&self) -> usize {
        self.layout.bytes_per_request()
    }

    pub fn page_bytes(&self) -> usize {
        self.layout.page_bytes()
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Worst-case pages a request of `total_max_tokens` can touch.
    fn need_pages(&self, total_max_tokens: usize) -> usize {
        total_max_tokens
            .clamp(1, self.layout.max_ctx)
            .div_ceil(PAGE_TOKENS)
    }

    /// Pages already promised to live sequences but not yet allocated
    /// (each sequence reserved its worst case at admission and draws
    /// pages lazily as tokens commit).
    fn outstanding_pages(&self) -> usize {
        self.seqs
            .values()
            .map(|s| s.reserved.saturating_sub(s.pages.len() - s.shared))
            .sum()
    }

    /// Cached pages no live sequence references: reclaimable by LRU
    /// eviction when allocation runs dry.
    ///
    /// O(cache slots) scan, paid once per admission check -- fine at
    /// this repo's pool sizes (thousands of pages).  If admission ever
    /// shows up in profiles, replace with a counter maintained on the
    /// refs 0 <-> 1 and cached set/clear transitions.
    fn evictable_pages(&self) -> usize {
        self.cache
            .slots
            .values()
            .filter(|s| self.pages[s.page].refs == 0)
            .count()
    }

    /// Pages obtainable right now: never-created slab headroom, the
    /// free list, and evictable cached pages.
    pub fn available_pages(&self) -> usize {
        (self.total_pages - self.pages.len())
            + self.free.len()
            + self.evictable_pages()
    }

    /// Would a request that can grow to `total_max_tokens` (prompt +
    /// max_new, context-capped) fit?  Admission is **page-granular**:
    /// the request's worst case is `ceil(total_max / PAGE_TOKENS)`
    /// pages -- not the old full-context whole-request reservation --
    /// checked against what is obtainable (free + reclaimable cached
    /// pages) minus what earlier admissions still have outstanding.
    /// Conservative on purpose: a prefix hit at prefill time only
    /// lowers the real need.
    pub fn can_admit(&self, total_max_tokens: usize) -> bool {
        self.outstanding_pages() + self.need_pages(total_max_tokens)
            <= self.available_pages()
    }

    fn alloc_page(&mut self) -> Result<usize> {
        if let Some(p) = self.free.pop() {
            return Ok(p);
        }
        if self.pages.len() < self.total_pages {
            self.pages.push(Page::new(self.layout.layers));
            return Ok(self.pages.len() - 1);
        }
        if let Some(p) = self.evict_one() {
            self.pages[p].reset();
            return Ok(p);
        }
        Err(P3Error::KvExhausted { needed_pages: 1, free_pages: 0 })
    }

    /// Evict the least-recently-used cache entry whose page no live
    /// sequence references; returns the reclaimed page id.
    fn evict_one(&mut self) -> Option<usize> {
        let victim = self
            .cache
            .slots
            .iter()
            .filter(|(_, s)| self.pages[s.page].refs == 0)
            .min_by_key(|(_, s)| s.last_use)
            .map(|(h, s)| (*h, s.page));
        let (h, pid) = victim?;
        self.cache.slots.remove(&h);
        self.pages[pid].cached = None;
        Some(pid)
    }

    /// Longest cached page chain this prompt starts with, capped so at
    /// least one suffix token remains to prefill (the logits of the
    /// last prompt token must still be computed).  Touches the chain's
    /// LRU clocks and **pins** the matched pages (one reference each),
    /// so they cannot be evicted before the caller resolves the hit --
    /// see [`PrefixHit`] for the resolution contract.
    pub fn lookup_prefix(&mut self, prompt: &[i32]) -> Option<PrefixHit> {
        if prompt.len() < 2 {
            return None;
        }
        let cap = (prompt.len() - 1) / PAGE_TOKENS;
        let mut h = CHAIN_SEED;
        let mut pages = Vec::new();
        let mut smooth: Option<Vec<Vec<f32>>> = None;
        let mut chain_gen = 0u64;
        for i in 0..cap {
            h = chain_hash(h, &prompt[i * PAGE_TOKENS..(i + 1) * PAGE_TOKENS]);
            let tick = self.cache.tick();
            let Some(slot) = self.cache.slots.get_mut(&h) else {
                break;
            };
            if i == 0 {
                let Some(s) = slot.smooth.clone() else {
                    break;
                };
                smooth = Some(s);
                chain_gen = slot.root_gen;
            } else if slot.root_gen != chain_gen {
                // stale child of an evicted-then-rebuilt root
                break;
            }
            slot.last_use = tick;
            pages.push(slot.page);
        }
        let smooth = smooth?;
        if pages.is_empty() {
            return None;
        }
        for &p in &pages {
            self.pages[p].refs += 1;
        }
        let tokens = pages.len() * PAGE_TOKENS;
        Some(PrefixHit { pages, tokens, smooth })
    }

    /// Return an unadopted [`PrefixHit`]'s page references (the pages
    /// fall back to cache-idle, reclaimable state).
    pub fn release_hit(&mut self, hit: PrefixHit) {
        for pid in hit.pages {
            let page = &mut self.pages[pid];
            debug_assert!(page.refs > 0);
            page.refs -= 1;
            if page.refs == 0 && page.cached.is_none() {
                page.reset();
                self.free.push(pid);
            }
        }
    }

    /// Create the page table for request `id`.  `total_max_tokens` is
    /// the request's worst case (prompt + max_new, context-capped):
    /// its page need is reserved here and drawn down lazily as tokens
    /// commit, so a mid-decode allocation can never fail for an
    /// admitted request.  A [`PrefixHit`] adopts the cached pages
    /// shared -- the hit's pins become the sequence's references --
    /// and the sequence starts `hit.tokens` long.  The hit is consumed
    /// on every path: on error its pins are released.
    pub fn alloc_seq(
        &mut self,
        id: u64,
        smooth: Vec<Vec<f32>>,
        total_max_tokens: usize,
        hit: Option<PrefixHit>,
    ) -> Result<()> {
        if self.seqs.contains_key(&id) {
            if let Some(h) = hit {
                self.release_hit(h);
            }
            return Err(P3Error::DuplicateKvEntry(id));
        }
        if smooth.len() != self.layout.layers {
            if let Some(h) = hit {
                self.release_hit(h);
            }
            return Err(P3Error::Serve(
                "smoothing factors: wrong layer count".into(),
            ));
        }
        let need = self.need_pages(total_max_tokens);
        let (pages, len) = match hit {
            Some(h) => (h.pages, h.tokens),
            None => (Vec::new(), 0),
        };
        let shared = pages.len();
        // reserve against *full* shared pages only: a partial shared
        // tail page (possible through pool-level sharing; the engine's
        // cache hits are always page-aligned) will be copy-on-written
        // by the first append, so its replacement page must be funded
        // by this reservation or a CoW could exhaust the pool
        // mid-decode for an admitted request
        let reserved = need.saturating_sub(len / PAGE_TOKENS);
        // same bound the engine pre-checks with can_admit: the hit's
        // pinned pages already left availability at lookup, so only
        // the private remainder needs reserving here
        if self.outstanding_pages() + reserved > self.available_pages() {
            self.release_hit(PrefixHit {
                pages,
                tokens: len,
                smooth: Vec::new(),
            });
            return Err(P3Error::KvExhausted {
                needed_pages: reserved,
                free_pages: self
                    .available_pages()
                    .saturating_sub(self.outstanding_pages()),
            });
        }
        // the hit's pins become this sequence's page references
        self.seqs.insert(id, Seq { pages, len, smooth, reserved, shared });
        Ok(())
    }

    /// Register every *full prompt page* of sequence `id` in the
    /// prefix cache under its chained content hash, so later prompts
    /// sharing the prefix can adopt the pages.  Idempotent for pages
    /// already registered (including ones this sequence itself
    /// adopted); the partial tail page (prompt length not a page
    /// multiple) is never registered.
    pub fn register_prefix(&mut self, id: u64, prompt: &[i32]) {
        let p = PAGE_TOKENS;
        let full = prompt.len() / p;
        if full == 0 {
            return;
        }
        let (page_ids, seq_shared) = match self.seqs.get(&id) {
            Some(s) if s.len >= full * p && s.pages.len() >= full => {
                (s.pages[..full].to_vec(), s.shared)
            }
            _ => return,
        };
        let mut h = CHAIN_SEED;
        let mut chain_gen = 0u64;
        for i in 0..full {
            h = chain_hash(h, &prompt[i * p..(i + 1) * p]);
            let tick = self.cache.tick();
            if let Some(slot) = self.cache.slots.get_mut(&h) {
                if i == 0 {
                    chain_gen = slot.root_gen;
                    slot.last_use = tick;
                    // a registrant that shares first-page content with
                    // an existing chain but did not adopt it packed its
                    // deeper pages under its own smoothing factors:
                    // keep the chain as-is rather than mixing domains
                    if seq_shared == 0 && full > 1 {
                        return;
                    }
                } else if slot.root_gen == chain_gen {
                    slot.last_use = tick;
                } else {
                    // stale child of a rebuilt root: repoint it at our
                    // page (same content chain, current factor domain)
                    let old = slot.page;
                    slot.page = page_ids[i];
                    slot.root_gen = chain_gen;
                    slot.last_use = tick;
                    slot.smooth = None;
                    self.pages[page_ids[i]].cached = Some(h);
                    let op = &mut self.pages[old];
                    op.cached = None;
                    if op.refs == 0 {
                        op.reset();
                        self.free.push(old);
                    }
                }
            } else {
                if i == 0 {
                    chain_gen = self.cache.next_gen();
                }
                // the smoothing factors are cloned only when a fresh
                // root is created -- the steady state (chain already
                // cached) never copies them
                let smooth = if i == 0 {
                    Some(self.seqs[&id].smooth.clone())
                } else {
                    None
                };
                self.cache.slots.insert(
                    h,
                    CacheSlot {
                        page: page_ids[i],
                        last_use: tick,
                        root_gen: chain_gen,
                        smooth,
                    },
                );
                self.pages[page_ids[i]].cached = Some(h);
            }
        }
    }

    /// Append one token's K and V for `layer` to sequence `id`.  `k`
    /// must be in the *unsmoothed* domain; it is divided by the
    /// sequence's smoothing factors before quantization.  Allocates a
    /// fresh page at page boundaries and copy-on-writes a shared page
    /// before the first append into it.
    ///
    /// Each call re-resolves the sequence (one or two hash lookups);
    /// the quantize-and-pack work per call dwarfs that, but a
    /// per-lane handle API is the next step if the append path ever
    /// dominates a profile.
    pub fn push_token(
        &mut self,
        id: u64,
        layer: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        enum Target {
            NewPage,
            Cow(usize),
            InPlace,
        }
        let (page_idx, target) = {
            let seq =
                self.seqs.get(&id).ok_or(P3Error::UnknownRequest(id))?;
            let page_idx = seq.len / PAGE_TOKENS;
            if page_idx == seq.pages.len() {
                (page_idx, Target::NewPage)
            } else {
                let pid = seq.pages[page_idx];
                if self.pages[pid].refs > 1 {
                    (page_idx, Target::Cow(pid))
                } else {
                    (page_idx, Target::InPlace)
                }
            }
        };
        match target {
            Target::NewPage => {
                let pid = self.alloc_page()?;
                self.pages[pid].refs = 1;
                self.seqs.get_mut(&id).unwrap().pages.push(pid);
            }
            Target::Cow(old) => {
                let pid = self.alloc_page()?;
                let copy = self.pages[old].fork();
                self.pages[pid] = copy;
                self.pages[old].refs -= 1;
                let seq = self.seqs.get_mut(&id).unwrap();
                seq.pages[page_idx] = pid;
                seq.shared = seq.shared.min(page_idx);
            }
            Target::InPlace => {}
        }
        let dh = self.layout.head_dim;
        debug_assert_eq!(k.len(), self.layout.kv_dim);
        let seq = self.seqs.get_mut(&id).unwrap();
        let pid = seq.pages[page_idx];
        let sf = &seq.smooth[layer];
        let page = &mut self.pages[pid];
        let ks: Vec<f32> = k.iter().zip(sf).map(|(x, f)| x / f).collect();
        for head in ks.chunks_exact(dh) {
            let g = quant_group_int4(head);
            page.k_meta[layer].push((g.scale, g.zero));
            page.k_codes[layer].extend(pack_nibbles(&g.codes));
        }
        for head in v.chunks_exact(dh) {
            let g = quant_group_int4(head);
            page.v_meta[layer].push((g.scale, g.zero));
            page.v_codes[layer].extend(pack_nibbles(&g.codes));
        }
        Ok(())
    }

    /// Mark one token complete across all layers.
    pub fn commit_token(&mut self, id: u64) -> Result<()> {
        let tb = self.layout.token_bytes();
        let seq = self.seqs.get_mut(&id).ok_or(P3Error::UnknownRequest(id))?;
        let page_idx = seq.len / PAGE_TOKENS;
        let pid = *seq.pages.get(page_idx).ok_or_else(|| {
            P3Error::Serve(format!("commit without pushed KV for request {id}"))
        })?;
        seq.len += 1;
        let local = (seq.len - 1) % PAGE_TOKENS + 1;
        let page = &mut self.pages[pid];
        page.len = page.len.max(local);
        debug_assert!(page.k_codes.iter().all(|c| c.len() == page.len * tb));
        debug_assert!(page.v_codes.iter().all(|c| c.len() == page.len * tb));
        Ok(())
    }

    /// Committed tokens of sequence `id`.
    pub fn seq_len(&self, id: u64) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// Tokens of `id` served from adopted shared-prefix pages.
    pub fn seq_shared_tokens(&self, id: u64) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.shared * PAGE_TOKENS)
    }

    /// Per-layer per-channel key smoothing factors of sequence `id`.
    pub fn seq_smooth(&self, id: u64) -> Option<&Vec<Vec<f32>>> {
        self.seqs.get(&id).map(|s| &s.smooth)
    }

    /// Dequantize layer `layer` of sequence `id` into `k_out`/`v_out`,
    /// each sized `max_ctx * kv_dim` (row-major over tokens); tokens
    /// beyond the sequence length are zero.  Keys get the smoothing
    /// factors multiplied back.
    ///
    /// Allocation-free hot path (paragraph Perf): nibbles are decoded
    /// in place two at a time -- this runs once per (request, layer)
    /// per decode step, the L3 equivalent of the PCU's in-bank decode.
    pub fn dequant_layer(
        &self,
        id: u64,
        layer: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let seq = self.seqs.get(&id).ok_or(P3Error::UnknownRequest(id))?;
        let dh = self.layout.head_dim;
        let kvd = self.layout.kv_dim;
        let heads = self.layout.heads();
        k_out[seq.len * kvd..].fill(0.0);
        v_out[seq.len * kvd..].fill(0.0);
        let sf = &seq.smooth[layer];
        for (pi, &pid) in seq.pages.iter().enumerate() {
            let base = pi * PAGE_TOKENS;
            if base >= seq.len {
                break;
            }
            let toks = (seq.len - base).min(PAGE_TOKENS);
            let page = &self.pages[pid];
            let (kc, vc) = (&page.k_codes[layer], &page.v_codes[layer]);
            let (km, vm) = (&page.k_meta[layer], &page.v_meta[layer]);
            for t in 0..toks {
                for h in 0..heads {
                    let gi = t * heads + h;
                    let code_off = gi * dh / 2;
                    let (ks, kz) = km[gi];
                    let (vs, vz) = vm[gi];
                    let row = (base + t) * kvd;
                    let kdst = &mut k_out[row + h * dh..row + (h + 1) * dh];
                    let vdst = &mut v_out[row + h * dh..row + (h + 1) * dh];
                    let sfh = &sf[h * dh..(h + 1) * dh];
                    for j in 0..dh / 2 {
                        let kb = kc[code_off + j];
                        let vb = vc[code_off + j];
                        kdst[2 * j] =
                            ((kb & 0xf) as f32 * ks + kz) * sfh[2 * j];
                        kdst[2 * j + 1] =
                            ((kb >> 4) as f32 * ks + kz) * sfh[2 * j + 1];
                        vdst[2 * j] = (vb & 0xf) as f32 * vs + vz;
                        vdst[2 * j + 1] = (vb >> 4) as f32 * vs + vz;
                    }
                }
            }
        }
        Ok(())
    }

    /// Effective bits/element of sequence `id` incl. the 16-bit scale
    /// + 4-bit zero per-group metadata (paper: 4.16 bits at head_dim
    /// 128; larger for the tiny model's head_dim 16).
    pub fn effective_bits(&self, id: u64) -> f64 {
        let Some(seq) = self.seqs.get(&id) else {
            return 0.0;
        };
        let l = &self.layout;
        let elems = (2 * seq.len * l.layers * l.kv_dim).max(1) as f64;
        let code_bits = (2 * seq.len * l.layers * l.token_bytes()) as f64 * 8.0;
        let meta_bits = (2 * seq.len * l.layers * l.heads()) as f64 * 20.0;
        (code_bits + meta_bits) / elems
    }

    /// Packed bytes held by pages live sequences reference (shared
    /// pages counted once).  Cache-idle pages are *excluded* -- they
    /// are reclaimable, reported by [`cached_bytes`](Self::cached_bytes).
    pub fn used_bytes(&self) -> usize {
        self.pages
            .iter()
            .filter(|p| p.refs > 0)
            .map(Page::packed_bytes)
            .sum()
    }

    /// Packed bytes held by cache-only pages (reclaimable on demand).
    pub fn cached_bytes(&self) -> usize {
        self.pages
            .iter()
            .filter(|p| p.refs == 0 && p.cached.is_some())
            .map(Page::packed_bytes)
            .sum()
    }

    /// Registered prefix-cache entries (== cached pages).
    pub fn cached_pages(&self) -> usize {
        self.cache.slots.len()
    }

    /// `(used_bytes, cached_bytes, live_seqs)` in one call -- the
    /// telemetry layer samples this at every step boundary into the
    /// `kv_used_bytes` / `kv_cached_bytes` counter tracks.
    pub fn occupancy(&self) -> (usize, usize, usize) {
        (self.used_bytes(), self.cached_bytes(), self.seqs.len())
    }

    /// Release sequence `id`: its private pages return to the free
    /// list; shared pages drop one reference, and cached pages outlive
    /// the sequence for future prefix hits (reclaimed by LRU eviction
    /// under pressure).
    pub fn free(&mut self, id: u64) -> bool {
        let Some(seq) = self.seqs.remove(&id) else {
            return false;
        };
        for pid in seq.pages {
            let page = &mut self.pages[pid];
            debug_assert!(page.refs > 0);
            page.refs -= 1;
            if page.refs == 0 && page.cached.is_none() {
                page.reset();
                self.free.push(pid);
            }
        }
        true
    }

    /// Live sequences.
    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Recompute every bookkeeping quantity from scratch and assert it
    /// matches the incremental state (test support).
    #[cfg(test)]
    fn check_invariants(&self) {
        let mut on_free = vec![false; self.pages.len()];
        for &f in &self.free {
            assert!(!on_free[f], "page {f} double-freed");
            on_free[f] = true;
        }
        let mut refs = vec![0usize; self.pages.len()];
        for s in self.seqs.values() {
            assert!(s.shared <= s.pages.len());
            for &p in &s.pages {
                refs[p] += 1;
            }
        }
        for (i, page) in self.pages.iter().enumerate() {
            assert_eq!(page.refs, refs[i], "page {i} refcount drifted");
            if on_free[i] {
                assert_eq!(page.refs, 0, "page {i} free while referenced");
                assert!(page.cached.is_none(), "page {i} free while cached");
            } else {
                assert!(
                    page.refs > 0 || page.cached.is_some(),
                    "page {i} leaked (unreachable but not free)"
                );
            }
        }
        for (h, slot) in &self.cache.slots {
            assert_eq!(
                self.pages[slot.page].cached,
                Some(*h),
                "cache slot and page disagree"
            );
        }
        let cached_n =
            self.pages.iter().filter(|p| p.cached.is_some()).count();
        assert_eq!(cached_n, self.cache.slots.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Rng, Runner};

    fn layout() -> KvLayout {
        KvLayout { layers: 2, kv_dim: 32, head_dim: 16, max_ctx: 64 }
    }

    fn pool_of(pages: usize) -> KvPool {
        let lay = layout();
        let cap = pages * lay.page_bytes();
        KvPool::new(lay, cap)
    }

    fn ones_smooth(l: &KvLayout) -> Vec<Vec<f32>> {
        vec![vec![1.0; l.kv_dim]; l.layers]
    }

    /// Push `n` constant-valued tokens into `id` across both layers.
    fn push_n(pool: &mut KvPool, id: u64, n: usize, kval: f32, vval: f32) {
        let k = vec![kval; 32];
        let v = vec![vval; 32];
        for _ in 0..n {
            for l in 0..2 {
                pool.push_token(id, l, &k, &v).unwrap();
            }
            pool.commit_token(id).unwrap();
        }
    }

    #[test]
    fn pack_unpack_roundtrips_grid_values() {
        // values already on the INT4 grid must round-trip exactly
        Runner::new(16).run(|r: &mut Rng| {
            let lay = layout();
            let mut pool = pool_of(8);
            pool.alloc_seq(1, ones_smooth(&lay), 8, None).unwrap();
            let mut k: Vec<f32> = r.vec_f32(32, -2.0, 2.0);
            let mut v: Vec<f32> = r.vec_f32(32, -1.0, 3.0);
            for h in 0..2 {
                crate::quant::int::fake_quant_group_int4(
                    &mut k[h * 16..(h + 1) * 16],
                );
                crate::quant::int::fake_quant_group_int4(
                    &mut v[h * 16..(h + 1) * 16],
                );
            }
            for layer in 0..2 {
                pool.push_token(1, layer, &k, &v).unwrap();
            }
            pool.commit_token(1).unwrap();
            let mut ko = vec![0.0; 64 * 32];
            let mut vo = vec![0.0; 64 * 32];
            pool.dequant_layer(1, 0, &mut ko, &mut vo).unwrap();
            for i in 0..32 {
                assert!((ko[i] - k[i]).abs() < 1e-5, "{} vs {}", ko[i], k[i]);
                assert!((vo[i] - v[i]).abs() < 1e-5);
            }
            // beyond len stays zero
            assert!(ko[32..].iter().all(|&x| x == 0.0));
        });
    }

    #[test]
    fn smoothing_factors_applied_on_keys() {
        let lay = layout();
        let mut pool = pool_of(8);
        let smooth = vec![vec![2.0; 32], vec![4.0; 32]];
        pool.alloc_seq(1, smooth, 8, None).unwrap();
        let k = vec![1.0f32; 32];
        let v = vec![0.5f32; 32];
        pool.push_token(1, 0, &k, &v).unwrap();
        pool.push_token(1, 1, &k, &v).unwrap();
        pool.commit_token(1).unwrap();
        let mut ko = vec![0.0; 64 * 32];
        let mut vo = vec![0.0; 64 * 32];
        pool.dequant_layer(1, 1, &mut ko, &mut vo).unwrap();
        // k/4 quantized (constant group -> ~exact) then *4
        assert!((ko[0] - 1.0).abs() < 1e-4, "{}", ko[0]);
        assert!((vo[0] - 0.5).abs() < 1e-4);
    }

    #[test]
    fn paged_admission_is_request_sized_and_typed() {
        let lay = layout();
        let mut pool = pool_of(2);
        // admission is by actual request footprint, not full context:
        // two 1-page requests fit a pool a single full-context request
        // (4 pages at max_ctx 64) would not
        assert!(pool.can_admit(16));
        assert!(pool.can_admit(32));
        assert!(!pool.can_admit(33)); // 3 pages > capacity
        pool.alloc_seq(1, ones_smooth(&lay), 16, None).unwrap();
        assert!(pool.can_admit(16));
        pool.alloc_seq(2, ones_smooth(&lay), 16, None).unwrap();
        assert!(!pool.can_admit(1));
        // exhaustion surfaces as the typed page-level error ...
        match pool.alloc_seq(3, ones_smooth(&lay), 16, None) {
            Err(P3Error::KvExhausted { needed_pages, free_pages }) => {
                assert_eq!(needed_pages, 1);
                assert_eq!(free_pages, 0);
            }
            other => panic!("expected KvExhausted, got {other:?}"),
        }
        // ... and double-alloc as the duplicate-entry error
        assert!(matches!(
            pool.alloc_seq(2, ones_smooth(&lay), 16, None),
            Err(P3Error::DuplicateKvEntry(2))
        ));
        assert!(pool.free(1));
        assert!(!pool.free(1));
        pool.alloc_seq(3, ones_smooth(&lay), 16, None).unwrap();
        assert_eq!(pool.len(), 2);
        pool.check_invariants();
    }

    #[test]
    fn reservation_covers_lazy_allocation_exactly() {
        let lay = layout();
        let mut pool = pool_of(4);
        // 33 tokens -> 3 pages reserved up front, allocated lazily
        pool.alloc_seq(1, ones_smooth(&lay), 33, None).unwrap();
        assert!(pool.can_admit(16)); // 1 page still free
        assert!(!pool.can_admit(17)); // 2 pages would overcommit
        push_n(&mut pool, 1, 33, 1.0, 1.0);
        assert_eq!(pool.seq_len(1), Some(33));
        // drawing reserved pages down does not change admission
        assert!(pool.can_admit(16));
        assert!(!pool.can_admit(17));
        pool.check_invariants();
        assert!(pool.free(1));
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(pool.available_pages(), pool.total_pages());
    }

    #[test]
    fn prefix_roundtrip_shares_pages_and_skips_reprefill() {
        let lay = layout();
        let mut pool = pool_of(8);
        let prompt: Vec<i32> = (0..33).map(|i| i as i32).collect();
        pool.alloc_seq(1, ones_smooth(&lay), 40, None).unwrap();
        push_n(&mut pool, 1, 33, 1.0, 0.5);
        pool.register_prefix(1, &prompt);
        assert_eq!(pool.cached_pages(), 2); // 2 full pages; tail not cached
        assert!(pool.free(1));
        // the cached pages outlive the sequence ...
        assert_eq!(pool.used_bytes(), 0);
        assert!(pool.cached_bytes() > 0);
        pool.check_invariants();
        // ... and a later identical prompt adopts them
        let hit = pool.lookup_prefix(&prompt).expect("prefix hit");
        assert_eq!(hit.tokens, 32);
        let smooth = hit.smooth.clone();
        pool.alloc_seq(2, smooth, 40, Some(hit)).unwrap();
        assert_eq!(pool.seq_len(2), Some(32));
        assert_eq!(pool.seq_shared_tokens(2), Some(32));
        // prefill only the 1-token suffix
        push_n(&mut pool, 2, 1, 1.0, 0.5);
        assert_eq!(pool.seq_len(2), Some(33));
        let mut ko = vec![0.0; 64 * 32];
        let mut vo = vec![0.0; 64 * 32];
        pool.dequant_layer(2, 0, &mut ko, &mut vo).unwrap();
        // shared prefix and private suffix both dequantize
        assert!((ko[0] - 1.0).abs() < 1e-4);
        assert!((ko[32 * 32] - 1.0).abs() < 1e-4);
        assert!(ko[33 * 32..].iter().all(|&x| x == 0.0));
        pool.check_invariants();
        assert!(pool.free(2));
        pool.check_invariants();
    }

    #[test]
    fn prefix_boundary_edge_cases() {
        let lay = layout();
        let mut pool = pool_of(16);
        let prompt: Vec<i32> = (0..40).map(|i| i as i32).collect();
        pool.alloc_seq(1, ones_smooth(&lay), 48, None).unwrap();
        push_n(&mut pool, 1, 40, 1.0, 1.0);
        pool.register_prefix(1, &prompt);
        // zero-length shared content: a disjoint prompt misses
        let other: Vec<i32> = (0..40).map(|i| 1000 + i as i32).collect();
        assert!(pool.lookup_prefix(&other).is_none());
        // shorter than one page: no cached span even on matching content
        assert!(pool.lookup_prefix(&prompt[..12]).is_none());
        assert!(pool.lookup_prefix(&prompt[..16]).is_none());
        // one page + 1 token: a 1-page hit
        let hit = pool.lookup_prefix(&prompt[..17]).unwrap();
        assert_eq!(hit.tokens, 16);
        pool.release_hit(hit);
        // exact-page-multiple prompt: the hit is capped one page short
        // so at least one suffix token remains to prefill
        let hit = pool.lookup_prefix(&prompt[..32]).unwrap();
        assert_eq!(hit.tokens, 16);
        pool.release_hit(hit);
        // spanning both registered pages
        let hit = pool.lookup_prefix(&prompt).unwrap();
        assert_eq!(hit.tokens, 32);
        pool.release_hit(hit);
        // the partial tail (tokens 32..40) was never registered
        assert_eq!(pool.cached_pages(), 2);
        pool.check_invariants();
    }

    #[test]
    fn pinned_hits_block_eviction_of_their_pages() {
        let lay = layout();
        let mut pool = pool_of(2);
        let prompt: Vec<i32> = (0..17).map(|i| i as i32).collect();
        pool.alloc_seq(1, ones_smooth(&lay), 17, None).unwrap();
        push_n(&mut pool, 1, 17, 1.0, 1.0);
        pool.register_prefix(1, &prompt);
        assert!(pool.free(1));
        // unpinned, the cached page is reclaimable ...
        assert_eq!(pool.available_pages(), 2);
        // ... but a pinned hit takes it out of the reclaimable set, so
        // a competing 2-page admission is refused instead of evicting
        // the page out from under the hit
        let hit = pool.lookup_prefix(&prompt).expect("hit");
        assert_eq!(pool.available_pages(), 1);
        assert!(!pool.can_admit(32));
        assert!(matches!(
            pool.alloc_seq(2, ones_smooth(&lay), 32, None),
            Err(P3Error::KvExhausted { .. })
        ));
        // the adopter still lands, with the cached content intact
        let smooth = hit.smooth.clone();
        pool.alloc_seq(3, smooth, 17, Some(hit)).unwrap();
        push_n(&mut pool, 3, 1, 1.0, 1.0);
        let mut ko = vec![0.0; 64 * 32];
        let mut vo = vec![0.0; 64 * 32];
        pool.dequant_layer(3, 0, &mut ko, &mut vo).unwrap();
        assert!((ko[0] - 1.0).abs() < 1e-4);
        assert!(pool.free(3));
        pool.check_invariants();
    }

    #[test]
    fn copy_on_write_protects_shared_partial_pages() {
        let lay = layout();
        let mut pool = pool_of(8);
        pool.alloc_seq(1, ones_smooth(&lay), 32, None).unwrap();
        push_n(&mut pool, 1, 8, 1.0, 0.5);
        // hand-share seq 1's partial first page into seq 2: the pool
        // state a partial-tail prefix share would create.  A real
        // lookup pins its pages; simulate that pin here since the hit
        // is hand-built.
        let shared_page = pool.seqs[&1].pages[0];
        pool.pages[shared_page].refs += 1;
        let hit = PrefixHit {
            pages: vec![shared_page],
            tokens: 8,
            smooth: ones_smooth(&lay),
        };
        pool.alloc_seq(2, ones_smooth(&lay), 32, Some(hit)).unwrap();
        assert_eq!(pool.pages[shared_page].refs, 2);
        // seq 2 appends: must copy, not clobber seq 1's tail
        push_n(&mut pool, 2, 1, -1.0, 2.0);
        assert_eq!(pool.pages[shared_page].refs, 1);
        assert_ne!(pool.seqs[&2].pages[0], shared_page);
        let mut ko = vec![0.0; 64 * 32];
        let mut vo = vec![0.0; 64 * 32];
        // seq 1 still dequantizes its original values
        pool.dequant_layer(1, 0, &mut ko, &mut vo).unwrap();
        assert!((ko[0] - 1.0).abs() < 1e-4);
        assert!(ko[8 * 32..].iter().all(|&x| x == 0.0));
        // seq 2 sees the shared prefix plus its own append
        pool.dequant_layer(2, 0, &mut ko, &mut vo).unwrap();
        assert!((ko[0] - 1.0).abs() < 1e-4);
        assert!((ko[8 * 32] + 1.0).abs() < 1e-4);
        // seq 1 keeps appending without disturbing seq 2
        push_n(&mut pool, 1, 1, 1.0, 0.5);
        pool.dequant_layer(2, 0, &mut ko, &mut vo).unwrap();
        assert!(ko[9 * 32..].iter().all(|&x| x == 0.0));
        pool.check_invariants();
        assert!(pool.free(1));
        assert!(pool.free(2));
        pool.check_invariants();
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn lru_eviction_reclaims_unreferenced_cached_pages() {
        let lay = layout();
        let mut pool = pool_of(4);
        let mk = |tag: i32| -> Vec<i32> {
            (0..16).map(|i| tag * 100 + i).collect()
        };
        // three one-page prompts cached in turn (ticks ascending)
        for (id, tag) in [(1u64, 1i32), (2, 2), (3, 3)] {
            let prompt = mk(tag);
            pool.alloc_seq(id, ones_smooth(&lay), 17, None).unwrap();
            push_n(&mut pool, id, 16, 1.0, 1.0);
            pool.register_prefix(id, &prompt);
            assert!(pool.free(id));
        }
        assert_eq!(pool.cached_pages(), 3);
        pool.check_invariants();
        // a fourth distinct prompt needs 2 pages; only 1 fresh slab
        // page remains, so the LRU cached page (tag 1) is evicted
        assert!(pool.can_admit(17));
        pool.alloc_seq(4, ones_smooth(&lay), 17, None).unwrap();
        push_n(&mut pool, 4, 17, 1.0, 1.0);
        pool.check_invariants();
        let probe = |tag: i32| -> Vec<i32> {
            let mut p = mk(tag);
            p.push(999);
            p
        };
        assert!(pool.lookup_prefix(&probe(1)).is_none(), "LRU not evicted");
        let h2 = pool.lookup_prefix(&probe(2)).expect("tag 2 still cached");
        pool.release_hit(h2);
        let h3 = pool.lookup_prefix(&probe(3)).expect("tag 3 still cached");
        pool.release_hit(h3);
        assert!(pool.free(4));
        pool.check_invariants();
    }

    #[test]
    fn page_conservation_under_bursty_random_ops() {
        // property: across random admit / append / retire bursts with
        // prefix sharing, no page is leaked or double-freed, refcounts
        // recompute exactly, and draining everything reclaims the pool
        Runner::new(24).run(|r: &mut Rng| {
            let lay = layout();
            let mut pool = pool_of(6);
            // (id, tokens_remaining) of live sequences
            let mut live: Vec<(u64, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..60 {
                let op = r.usize(0, 3);
                if op == 0 || live.is_empty() {
                    let plen = r.usize(4, 40);
                    // half the prompts share content -> prefix hits
                    let prompt: Vec<i32> = if r.bool() {
                        (0..plen).map(|i| i as i32).collect()
                    } else {
                        (0..plen).map(|_| r.usize(0, 50) as i32).collect()
                    };
                    let extra = r.usize(1, 8);
                    let total = plen + extra;
                    if pool.can_admit(total) {
                        next_id += 1;
                        let hit = pool.lookup_prefix(&prompt);
                        let cached =
                            hit.as_ref().map(|h| h.tokens).unwrap_or(0);
                        let smooth = hit
                            .as_ref()
                            .map(|h| h.smooth.clone())
                            .unwrap_or_else(|| ones_smooth(&lay));
                        pool.alloc_seq(next_id, smooth, total, hit).unwrap();
                        push_n(&mut pool, next_id, plen - cached, 0.5, 0.5);
                        pool.register_prefix(next_id, &prompt);
                        live.push((next_id, extra));
                    }
                } else if op == 1 {
                    let idx = r.usize(0, live.len());
                    let (id, _) = live.swap_remove(idx);
                    assert!(pool.free(id));
                    assert!(!pool.free(id));
                } else {
                    // decode-append within the admitted budget
                    let idx = r.usize(0, live.len());
                    let (id, left) = live[idx];
                    if left > 0 {
                        push_n(&mut pool, id, 1, 0.25, 0.25);
                        live[idx].1 = left - 1;
                    }
                }
                pool.check_invariants();
                // the admission invariant: outstanding promises are
                // always coverable by obtainable pages
                assert!(
                    pool.outstanding_pages() <= pool.available_pages(),
                    "reservations overcommitted"
                );
                assert_eq!(pool.len(), live.len());
            }
            for (id, _) in live.drain(..) {
                assert!(pool.free(id));
            }
            pool.check_invariants();
            assert_eq!(pool.used_bytes(), 0);
            // everything left is reclaimable cache
            assert_eq!(pool.available_pages(), pool.total_pages());
        });
    }

    #[test]
    fn page_conservation_under_preempt_resume_churn() {
        // property: the sched layer's eviction paths -- preempt (free
        // the victim's pages), park, resume (re-install the full
        // context via re-prefill: the pool-level shape of both the
        // recompute and swap restore modes) -- interleaved with
        // shared-prefix adoption never leak a page, never double-free,
        // and keep refcounts / pins / free list exactly recomputable
        Runner::new(24).run(|r: &mut Rng| {
            let lay = layout();
            let mut pool = pool_of(8);
            // live/parked: (id, context length, admitted budget)
            let mut live: Vec<(u64, usize, usize)> = vec![];
            let mut parked: Vec<(u64, usize, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..120 {
                match r.usize(0, 5) {
                    // fresh admission with prefix lookup + publication
                    // (page-aligned prompts over a 3-letter alphabet
                    // keep shared-prefix collisions frequent)
                    0 => {
                        let tag = r.usize(0, 3) as i32;
                        let plen = r.usize(1, 3) * PAGE_TOKENS;
                        let prompt = vec![tag; plen];
                        let total = (plen + r.usize(1, 8)).min(lay.max_ctx);
                        if pool.can_admit(total) {
                            next_id += 1;
                            let hit = pool.lookup_prefix(&prompt);
                            let cached =
                                hit.as_ref().map_or(0, |h| h.tokens);
                            let smooth = hit
                                .as_ref()
                                .map(|h| h.smooth.clone())
                                .unwrap_or_else(|| ones_smooth(&lay));
                            pool.alloc_seq(next_id, smooth, total, hit)
                                .unwrap();
                            push_n(&mut pool, next_id, plen - cached, 0.5, 0.5);
                            pool.register_prefix(next_id, &prompt);
                            live.push((next_id, plen, total));
                        }
                    }
                    // decode-append within the admitted budget
                    1 => {
                        if !live.is_empty() {
                            let idx = r.usize(0, live.len());
                            if live[idx].1 < live[idx].2 {
                                push_n(&mut pool, live[idx].0, 1, 0.25, 0.25);
                                live[idx].1 += 1;
                            }
                        }
                    }
                    // preempt: the victim's pages release immediately
                    // (shared ones stay cached for other adopters)
                    2 => {
                        if !live.is_empty() {
                            let idx = r.usize(0, live.len());
                            let v = live.swap_remove(idx);
                            assert!(pool.free(v.0));
                            parked.push(v);
                        }
                    }
                    // resume: re-admit and re-install the parked
                    // context (the engine's resume prefill skips
                    // prefix lookup/registration); under pressure the
                    // request stays parked and retries later
                    3 => {
                        if !parked.is_empty() {
                            let idx = r.usize(0, parked.len());
                            let (id, ctx, total) = parked.swap_remove(idx);
                            if pool.can_admit(total) {
                                pool.alloc_seq(
                                    id,
                                    ones_smooth(&lay),
                                    total,
                                    None,
                                )
                                .unwrap();
                                push_n(&mut pool, id, ctx, 0.5, 0.5);
                                live.push((id, ctx, total));
                            } else {
                                parked.push((id, ctx, total));
                            }
                        }
                    }
                    // retire for good
                    _ => {
                        if !live.is_empty() {
                            let idx = r.usize(0, live.len());
                            let (id, ..) = live.swap_remove(idx);
                            assert!(pool.free(id));
                            assert!(!pool.free(id), "double-free accepted");
                        }
                    }
                }
                pool.check_invariants();
                assert_eq!(pool.len(), live.len());
                assert!(
                    pool.outstanding_pages() <= pool.available_pages(),
                    "reservations overcommitted"
                );
            }
            for (id, ..) in live.drain(..) {
                assert!(pool.free(id));
            }
            pool.check_invariants();
            assert!(pool.is_empty());
            assert_eq!(pool.used_bytes(), 0);
            // everything left is reclaimable cache
            assert_eq!(pool.available_pages(), pool.total_pages());
        });
    }

    #[test]
    fn effective_bits_reasonable() {
        let lay =
            KvLayout { layers: 1, kv_dim: 128, head_dim: 128, max_ctx: 16 };
        let mut pool = KvPool::new(lay.clone(), lay.bytes_per_request());
        pool.alloc_seq(1, vec![vec![1.0; 128]], 4, None).unwrap();
        let k: Vec<f32> = (0..128).map(|i| (i as f32).sin()).collect();
        pool.push_token(1, 0, &k, &k).unwrap();
        pool.commit_token(1).unwrap();
        let bits = pool.effective_bits(1);
        // paper: 4.16 effective bits at head_dim 128
        assert!((4.1..4.3).contains(&bits), "{bits}");
    }

    #[test]
    fn sizing_helper_matches_page_math() {
        let lay = layout();
        assert_eq!(
            lay.bytes_per_request(),
            lay.pages_per_request() * lay.page_bytes()
        );
        // for a page-aligned context the helper equals the exact
        // packed size: 2 sides x layers x ctx x kv_dim/2
        assert_eq!(lay.bytes_per_request(), 2 * 2 * 64 * 16);
        assert_eq!(prefix_page_hash(&[1; 15]), None);
        assert!(prefix_page_hash(&[1; 16]).is_some());
        // the affinity key depends only on the first page
        let a: Vec<i32> = (0..40).collect();
        let b: Vec<i32> = (0..16).chain(100..124).collect();
        assert_eq!(prefix_page_hash(&a), prefix_page_hash(&b));
    }
}
