//! KV-cache manager: quantized (INT4-Asym per-head) block-pooled
//! storage + the smoothing-factor store (paper Sections IV-A, V-C).
//!
//! The pool is the system of record for KV state: new K/V vectors are
//! packed to 4-bit nibbles with per-(token, head) scale/zero metadata,
//! exactly matching the fake-quant grid the AOT decode graphs emit (so
//! pack -> unpack round-trips bit-exactly); dequantized f32 views are
//! materialized per decode step as the graph's cache inputs -- the
//! CPU-side analogue of the PCU's in-bank decode.
//!
//! Keys are stored *smoothed* (divided by the per-channel prefill
//! factors); the factors are multiplied back when building the f32
//! view, numerically identical to the paper's query-side fusion.

use crate::error::{P3Error, Result};
use crate::quant::int::{pack_nibbles, quant_group_int4};

#[derive(Debug, Clone)]
pub struct KvLayout {
    pub layers: usize,
    pub kv_dim: usize,
    pub head_dim: usize,
    pub max_ctx: usize,
}

impl KvLayout {
    pub fn heads(&self) -> usize {
        self.kv_dim / self.head_dim
    }

    /// packed bytes per token per layer per cache side
    fn token_bytes(&self) -> usize {
        self.kv_dim / 2
    }

    /// Worst-case packed bytes one full-context request reserves (the
    /// unit of the pool's admission accounting -- callers sizing a
    /// `kv_capacity` should use this rather than re-deriving it).
    pub fn bytes_per_request(&self) -> usize {
        2 * self.layers * self.max_ctx * self.token_bytes()
    }
}

/// Quantized storage for one request: codes + per-group metadata for
/// both K and V across all layers.
#[derive(Debug)]
pub struct KvEntry {
    layout: KvLayout,
    /// [layer][token] -> packed nibbles (kv_dim/2 bytes)  (keys, smoothed)
    k_codes: Vec<Vec<u8>>,
    v_codes: Vec<Vec<u8>>,
    /// [layer][token*heads] -> (scale, zero)
    k_meta: Vec<Vec<(f32, f32)>>,
    v_meta: Vec<Vec<(f32, f32)>>,
    /// per-layer per-channel smoothing factors (from prefill)
    pub smooth: Vec<Vec<f32>>,
    pub len: usize,
}

impl KvEntry {
    fn new(layout: KvLayout, smooth: Vec<Vec<f32>>) -> Self {
        let l = layout.layers;
        KvEntry {
            layout,
            k_codes: vec![vec![]; l],
            v_codes: vec![vec![]; l],
            k_meta: vec![vec![]; l],
            v_meta: vec![vec![]; l],
            smooth,
            len: 0,
        }
    }

    /// Append one token's K and V for layer `layer`.  `k` must already
    /// be in the *unsmoothed* domain; it is divided by the smoothing
    /// factors before quantization.
    pub fn push_token(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let dh = self.layout.head_dim;
        debug_assert_eq!(k.len(), self.layout.kv_dim);
        let sf = &self.smooth[layer];
        let ks: Vec<f32> =
            k.iter().zip(sf).map(|(x, f)| x / f).collect();
        for head in ks.chunks_exact(dh) {
            let g = quant_group_int4(head);
            self.k_meta[layer].push((g.scale, g.zero));
            self.k_codes[layer].extend(pack_nibbles(&g.codes));
        }
        for head in v.chunks_exact(dh) {
            let g = quant_group_int4(head);
            self.v_meta[layer].push((g.scale, g.zero));
            self.v_codes[layer].extend(pack_nibbles(&g.codes));
        }
    }

    /// Mark one token complete across all layers.
    pub fn commit_token(&mut self) {
        self.len += 1;
        debug_assert!(self
            .k_codes
            .iter()
            .all(|c| c.len() == self.len * self.layout.token_bytes()));
    }

    /// Dequantize layer `layer` into `k_out`/`v_out`, each sized
    /// [max_ctx * kv_dim] (row-major over tokens); tokens beyond `len`
    /// are zero.  Keys get the smoothing factors multiplied back.
    ///
    /// Allocation-free hot path (§Perf): nibbles are decoded in-place
    /// two at a time -- this runs once per (request, layer) per decode
    /// step, the L3 equivalent of the PCU's in-bank decode.
    pub fn dequant_layer(&self, layer: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        let dh = self.layout.head_dim;
        let kvd = self.layout.kv_dim;
        let heads = self.layout.heads();
        k_out[self.len * kvd..].fill(0.0);
        v_out[self.len * kvd..].fill(0.0);
        let sf = &self.smooth[layer];
        let (kc, vc) = (&self.k_codes[layer], &self.v_codes[layer]);
        let (km, vm) = (&self.k_meta[layer], &self.v_meta[layer]);
        for t in 0..self.len {
            for h in 0..heads {
                let gi = t * heads + h;
                let code_off = gi * dh / 2;
                let (ks, kz) = km[gi];
                let (vs, vz) = vm[gi];
                let kdst = &mut k_out[t * kvd + h * dh..t * kvd + (h + 1) * dh];
                let vdst = &mut v_out[t * kvd + h * dh..t * kvd + (h + 1) * dh];
                let sfh = &sf[h * dh..(h + 1) * dh];
                for j in 0..dh / 2 {
                    let kb = kc[code_off + j];
                    let vb = vc[code_off + j];
                    kdst[2 * j] =
                        ((kb & 0xf) as f32 * ks + kz) * sfh[2 * j];
                    kdst[2 * j + 1] =
                        ((kb >> 4) as f32 * ks + kz) * sfh[2 * j + 1];
                    vdst[2 * j] = (vb & 0xf) as f32 * vs + vz;
                    vdst[2 * j + 1] = (vb >> 4) as f32 * vs + vz;
                }
            }
        }
    }

    /// Packed bytes held (codes only; metadata accounted separately).
    pub fn packed_bytes(&self) -> usize {
        self.k_codes.iter().map(|c| c.len()).sum::<usize>()
            + self.v_codes.iter().map(|c| c.len()).sum::<usize>()
    }

    /// Effective bits/element incl. scale+zero metadata (paper: 4.16
    /// bits at head_dim 128; larger for the tiny model's head_dim 16).
    pub fn effective_bits(&self) -> f64 {
        let elems = (2 * self.len * self.layout.layers * self.layout.kv_dim)
            .max(1) as f64;
        let meta_bits = (self.k_meta.iter().map(|m| m.len()).sum::<usize>()
            + self.v_meta.iter().map(|m| m.len()).sum::<usize>())
            as f64
            * 20.0; // 16-bit scale + 4-bit zero, as in the paper
        (self.packed_bytes() as f64 * 8.0 + meta_bits) / elems
    }
}

/// Fixed-capacity pool of per-request entries.
pub struct KvPool {
    pub layout: KvLayout,
    pub capacity_bytes: usize,
    entries: std::collections::HashMap<u64, KvEntry>,
}

impl KvPool {
    pub fn new(layout: KvLayout, capacity_bytes: usize) -> Self {
        KvPool { layout, capacity_bytes, entries: Default::default() }
    }

    /// Worst-case packed bytes for a full-context request.
    pub fn bytes_per_request(&self) -> usize {
        self.layout.bytes_per_request()
    }

    pub fn used_bytes(&self) -> usize {
        self.entries.values().map(|e| e.packed_bytes()).sum()
    }

    pub fn reserved_bytes(&self) -> usize {
        self.entries.len() * self.bytes_per_request()
    }

    /// Would an additional full-context request fit under the
    /// worst-case reservation accounting?  The engine's admission
    /// control asks this before prefilling a queued request.
    pub fn can_admit(&self) -> bool {
        self.reserved_bytes() + self.bytes_per_request() <= self.capacity_bytes
    }

    pub fn alloc(&mut self, id: u64, smooth: Vec<Vec<f32>>) -> Result<&mut KvEntry> {
        if self.entries.contains_key(&id) {
            return Err(P3Error::DuplicateKvEntry(id));
        }
        if !self.can_admit() {
            return Err(P3Error::KvCapacity {
                needed: self.reserved_bytes() + self.bytes_per_request(),
                capacity: self.capacity_bytes,
            });
        }
        if smooth.len() != self.layout.layers {
            return Err(P3Error::Serve(
                "smoothing factors: wrong layer count".into(),
            ));
        }
        Ok(self
            .entries
            .entry(id)
            .or_insert_with(|| KvEntry::new(self.layout.clone(), smooth)))
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut KvEntry> {
        self.entries.get_mut(&id)
    }

    pub fn get(&self, id: u64) -> Option<&KvEntry> {
        self.entries.get(&id)
    }

    pub fn free(&mut self, id: u64) -> bool {
        self.entries.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Rng, Runner};

    fn layout() -> KvLayout {
        KvLayout { layers: 2, kv_dim: 32, head_dim: 16, max_ctx: 8 }
    }

    fn ones_smooth(l: &KvLayout) -> Vec<Vec<f32>> {
        vec![vec![1.0; l.kv_dim]; l.layers]
    }

    #[test]
    fn pack_unpack_roundtrips_grid_values() {
        // values already on the INT4 grid must round-trip exactly
        Runner::new(16).run(|r: &mut Rng| {
            let lay = layout();
            let mut e = KvEntry::new(lay.clone(), ones_smooth(&lay));
            let mut k: Vec<f32> = r.vec_f32(32, -2.0, 2.0);
            let mut v: Vec<f32> = r.vec_f32(32, -1.0, 3.0);
            for h in 0..2 {
                crate::quant::int::fake_quant_group_int4(
                    &mut k[h * 16..(h + 1) * 16],
                );
                crate::quant::int::fake_quant_group_int4(
                    &mut v[h * 16..(h + 1) * 16],
                );
            }
            for layer in 0..2 {
                e.push_token(layer, &k, &v);
            }
            e.commit_token();
            let mut ko = vec![0.0; 8 * 32];
            let mut vo = vec![0.0; 8 * 32];
            e.dequant_layer(0, &mut ko, &mut vo);
            for i in 0..32 {
                assert!((ko[i] - k[i]).abs() < 1e-5, "{} vs {}", ko[i], k[i]);
                assert!((vo[i] - v[i]).abs() < 1e-5);
            }
            // beyond len stays zero
            assert!(ko[32..].iter().all(|&x| x == 0.0));
        });
    }

    #[test]
    fn smoothing_factors_applied_on_keys() {
        let lay = layout();
        let smooth = vec![vec![2.0; 32], vec![4.0; 32]];
        let mut e = KvEntry::new(lay, smooth);
        let k = vec![1.0f32; 32];
        let v = vec![0.5f32; 32];
        e.push_token(0, &k, &v);
        e.push_token(1, &k, &v);
        e.commit_token();
        let mut ko = vec![0.0; 8 * 32];
        let mut vo = vec![0.0; 8 * 32];
        e.dequant_layer(1, &mut ko, &mut vo);
        // k/4 quantized (constant group -> ~exact) then *4
        assert!((ko[0] - 1.0).abs() < 1e-4, "{}", ko[0]);
        assert!((vo[0] - 0.5).abs() < 1e-4);
    }

    #[test]
    fn pool_capacity_enforced() {
        let lay = layout();
        let per = 2 * 2 * 8 * 16; // layers*2sides*ctx*token_bytes
        let mut pool = KvPool::new(lay.clone(), 2 * per);
        assert!(pool.can_admit());
        pool.alloc(1, ones_smooth(&lay)).unwrap();
        pool.alloc(2, ones_smooth(&lay)).unwrap();
        assert!(!pool.can_admit());
        // exhaustion surfaces as the typed capacity error ...
        match pool.alloc(3, ones_smooth(&lay)) {
            Err(P3Error::KvCapacity { needed, capacity }) => {
                assert_eq!(capacity, 2 * per);
                assert!(needed > capacity);
            }
            other => panic!("expected KvCapacity, got {other:?}"),
        }
        // ... and double-alloc as the duplicate-entry error
        assert!(matches!(
            pool.alloc(2, ones_smooth(&lay)),
            Err(P3Error::DuplicateKvEntry(2))
        ));
        assert!(pool.free(1));
        pool.alloc(3, ones_smooth(&lay)).unwrap();
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn pool_invariants_under_random_ops() {
        // property: reserved bytes never exceed capacity; double-alloc
        // and double-free are rejected; used <= reserved
        Runner::new(32).run(|r: &mut Rng| {
            let lay = layout();
            let per = KvPool::new(lay.clone(), usize::MAX).bytes_per_request();
            let mut pool = KvPool::new(lay.clone(), 5 * per);
            let mut live: Vec<u64> = vec![];
            for i in 0..40u64 {
                if r.bool() || live.is_empty() {
                    match pool.alloc(i, ones_smooth(&lay)) {
                        Ok(_) => live.push(i),
                        Err(_) => assert!(live.len() >= 5),
                    }
                } else {
                    let idx = r.usize(0, live.len());
                    let id = live.swap_remove(idx);
                    assert!(pool.free(id));
                    assert!(!pool.free(id));
                }
                assert!(pool.reserved_bytes() <= pool.capacity_bytes);
                assert!(pool.used_bytes() <= pool.reserved_bytes());
                assert_eq!(pool.len(), live.len());
            }
        });
    }

    #[test]
    fn effective_bits_reasonable() {
        let lay = KvLayout { layers: 1, kv_dim: 128, head_dim: 128, max_ctx: 4 };
        let mut e = KvEntry::new(lay, vec![vec![1.0; 128]]);
        let k: Vec<f32> = (0..128).map(|i| (i as f32).sin()).collect();
        e.push_token(0, &k, &k);
        e.commit_token();
        let bits = e.effective_bits();
        // paper: 4.16 effective bits at head_dim 128
        assert!((4.1..4.3).contains(&bits), "{bits}");
    }
}
