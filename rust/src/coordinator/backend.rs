//! The execution-backend abstraction the serving engine drives.
//!
//! The paper's co-design claim is that one mapping/serving policy spans
//! both the measured system and the modeled hardware; [`ExecBackend`]
//! is that seam.  The engine owns the request lifecycle (router,
//! continuous batcher, quantized KV pool); a backend owns only the
//! numerics of one prefill or one batched decode step plus the clock
//! those steps advance:
//!
//! * [`PjrtBackend`](super::pjrt::PjrtBackend) -- real numerics through
//!   the AOT-compiled PJRT graphs of the tiny shipped model (wall
//!   clock).
//! * [`SimBackend`](super::simbackend::SimBackend) -- the `accel`
//!   NPU-PIM cost model advancing simulated time, with synthetic
//!   tokens/KV exercising the identical pool/batcher path.  This is
//!   what makes batch-64 / long-context serving-loop experiments
//!   possible without PJRT artifacts.

use super::kvcache::KvPool;
use crate::config::llm::LlmConfig;
use crate::coordinator::mapper::MapSummary;
use crate::error::{P3Error, Result};

/// [`covering_batch`](super::batcher::covering_batch) that turns "no
/// compiled size covers the active set" into a typed serve error.
pub fn covering_or_err(sizes: &[usize], n: usize) -> Result<usize> {
    super::batcher::covering_batch(sizes, n).ok_or_else(|| {
        P3Error::Serve(format!("no compiled batch covers {n} active lanes"))
    })
}

/// Which execution substrate an [`EngineBuilder`](super::serve::EngineBuilder)
/// should construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT PJRT graphs (real numerics, tiny model, wall clock).
    Pjrt,
    /// `accel` cost model (simulated time, any model/scheme/batch).
    Sim,
}

impl BackendKind {
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "pjrt" => Some(BackendKind::Pjrt),
            "sim" | "model" | "simulate" => Some(BackendKind::Sim),
            _ => None,
        }
    }
}

/// One active request's view for a decode step.
#[derive(Debug, Clone, Copy)]
pub struct Lane {
    pub rid: u64,
    /// token pending processing this step
    pub last_token: i32,
    /// absolute KV slot the pending token occupies
    pub pos: usize,
}

/// Result of prefilling one prompt.
pub struct PrefillOut {
    /// first generated token (greedy over the prefill logits)
    pub first_token: i32,
    /// per-layer per-channel key smoothing factors for the KV entry
    pub smooth: Vec<Vec<f32>>,
    /// prompt-token K rows, layout `[layer][token][kv_dim]` with
    /// `token < true_len` (compact, stride = true_len)
    pub k: Vec<f32>,
    /// prompt-token V rows, same layout as `k`
    pub v: Vec<f32>,
    pub true_len: usize,
}

/// Cumulative NPU‖PIM sub-batch interleaving counters a backend has
/// accrued over its lifetime (see
/// [`ExecBackend::decode_step_interleaved`]).  All `_ms` fields are
/// raw busy/overlap sums so fleet reports can merge replicas by
/// addition; [`overlap_factor`](Self::overlap_factor) derives the
/// bounded ratio.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InterleaveStats {
    /// summed NPU occupancy across both sub-batch timelines (ms)
    pub npu_busy_ms: f64,
    /// summed PIM occupancy across both sub-batch timelines (ms)
    pub pim_busy_ms: f64,
    /// wall time both engines were busy simultaneously (ms)
    pub overlap_ms: f64,
    /// decode steps charged on the two-timeline critical path
    pub interleaved_steps: u64,
    /// decode steps where the serial schedule was cheaper and the
    /// backend fused the sub-batches back into one serial step
    pub fused_steps: u64,
    /// serial-schedule cost minus the charged critical path, summed
    /// over interleaved steps (ms saved vs `interleave=off`)
    pub serial_saved_ms: f64,
}

impl InterleaveStats {
    /// Concurrency ratio in `[0, 1]`: overlap time over the smaller
    /// engine's total busy time (1.0 = the scarcer engine was never
    /// the only one running).
    pub fn overlap_factor(&self) -> f64 {
        let floor = self.npu_busy_ms.min(self.pim_busy_ms);
        if floor > 0.0 {
            self.overlap_ms / floor
        } else {
            0.0
        }
    }
}

/// Result of one batched decode step over `lanes`.
pub struct DecodeOut {
    /// next token per lane (greedy)
    pub tokens: Vec<i32>,
    /// K rows of the tokens just processed, `[layer][lane][kv_dim]`
    pub new_k: Vec<f32>,
    /// V rows, same layout as `new_k`
    pub new_v: Vec<f32>,
}

/// An execution substrate for the serving engine: prefill + batched
/// decode-step over request lanes, plus the engine clock.
pub trait ExecBackend {
    /// Short name for logs/metrics ("pjrt", "sim").
    fn name(&self) -> &'static str;

    fn model(&self) -> &LlmConfig;

    /// Longest prompt (tokens) a single prefill call can absorb -- one
    /// prefill *tile*.  Backends that cannot chunk reject longer
    /// prompts at `submit` with
    /// [`P3Error::PromptTooLong`](crate::error::P3Error::PromptTooLong);
    /// backends reporting [`chunked_prefill`](Self::chunked_prefill)
    /// have the engine absorb longer prompts in `ceil(len /
    /// max_prefill())` successive tiles.
    fn max_prefill(&self) -> usize;

    /// Can the engine split a long prompt across several prefill
    /// tiles?  The sim backend models NPU tiled prefill and says yes;
    /// the PJRT backend's AOT graph is a single fixed tile and keeps
    /// the typed rejection.
    fn chunked_prefill(&self) -> bool {
        false
    }

    /// One prefill tile with `prefix_len` tokens of this prompt
    /// already installed -- earlier chunks of a chunked prefill, or a
    /// shared-prefix cache hit whose pages the engine adopted (then
    /// the first tile already starts at `prefix_len > 0` and the
    /// cached span's compute is skipped entirely).  Cost-model
    /// backends charge the *incremental* cost of extending the prefix
    /// -- the later tiles attend against everything before them, so
    /// the telescoping sum over tiles reproduces the full-prompt cost
    /// -- while the default ignores the prefix (single-tile backends
    /// run the chunk as-is).
    fn prefill_continue(
        &mut self,
        chunk: &[i32],
        prefix_len: usize,
    ) -> Result<PrefillOut> {
        let _ = prefix_len;
        self.prefill(chunk)
    }

    /// Install prefill state for a prompt whose KV was computed
    /// elsewhere (prefill/decode disaggregation: a decode replica
    /// receives a migrated KV cache), charging `charge_ms` of clock --
    /// the modeled transfer time -- instead of prefill compute.
    /// Backends that cannot absorb foreign KV fall back to a real
    /// prefill.
    fn install_prefill(
        &mut self,
        prompt: &[i32],
        charge_ms: f64,
    ) -> Result<PrefillOut> {
        let _ = charge_ms;
        self.prefill(prompt)
    }

    /// Run prefill over one prompt.  Advances the backend clock.
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut>;

    /// One decode step over the active lanes, reading cached KV from
    /// `pool`.  Advances the backend clock.
    fn decode_step(&mut self, lanes: &[Lane], pool: &KvPool) -> Result<DecodeOut>;

    /// One decode step over two interleaved sub-batches, so sub-batch
    /// A's NPU phase overlaps sub-batch B's PIM phase and vice versa.
    /// `stall_a_ms` / `stall_b_ms` are per-sub-batch demand-miss stalls
    /// (tiered KV) delaying only that timeline; `serial_stall_ms` is
    /// the single serialized stall the fallback serial schedule would
    /// charge.  Backends without two device timelines keep this
    /// default: concatenate the lanes, charge the serialized stall,
    /// and run the ordinary serial step -- bit-identical to
    /// `interleave=off`.  Implementations must return tokens/KV rows
    /// in `lanes_a ++ lanes_b` order.
    fn decode_step_interleaved(
        &mut self,
        lanes_a: &[Lane],
        lanes_b: &[Lane],
        stall_a_ms: f64,
        stall_b_ms: f64,
        serial_stall_ms: f64,
        pool: &KvPool,
    ) -> Result<DecodeOut> {
        let _ = (stall_a_ms, stall_b_ms);
        if serial_stall_ms > 0.0 {
            let cursor = self.now_ms() + serial_stall_ms;
            self.advance_to(cursor);
        }
        let mut lanes = Vec::with_capacity(lanes_a.len() + lanes_b.len());
        lanes.extend_from_slice(lanes_a);
        lanes.extend_from_slice(lanes_b);
        self.decode_step(&lanes, pool)
    }

    /// Cumulative interleaving counters (zero for backends that only
    /// ever run the serial schedule).
    fn interleave_stats(&self) -> InterleaveStats {
        InterleaveStats::default()
    }

    /// Engine clock in milliseconds: wall time since backend creation
    /// for PJRT, accumulated simulated time for sim.
    fn now_ms(&self) -> f64;

    /// Fast-forward the clock to absolute `ms` (closed-loop load
    /// generation jumps over idle gaps between arrivals).  Simulated
    /// clocks advance; wall clocks cannot and default to a no-op --
    /// callers must tolerate `now_ms()` staying behind `ms`.
    fn advance_to(&mut self, _ms: f64) {}

    /// NPU/PIM operator-mapping summary of the most recent decode step
    /// (cost-model backends only).
    fn mapping_summary(&self) -> Option<MapSummary> {
        None
    }

    /// Adopt a telemetry handle for device-occupancy spans (NPU / PIM
    /// / bus tracks).  Backends without per-operator visibility (PJRT:
    /// opaque AOT graphs) keep the no-op default -- the engine still
    /// records the request lifecycle on its own clock.
    fn set_trace(&mut self, _trace: crate::telemetry::Trace) {}
}
