//! Simulated execution backend: drives the *same* engine lifecycle
//! (batcher, quantized KV pool, request states, metrics) as the PJRT
//! backend, but the numerics are synthetic and time advances from the
//! `accel`/`sim` NPU-PIM cost model instead of the wall clock.
//!
//! This is the serving-loop view of the paper's evaluation substrate:
//! TTFT and per-token latency percentiles under continuous batching at
//! batch 64+ and multi-thousand-token contexts on any configured model
//! x scheme x system -- none of which the PJRT-on-CPU tiny-model path
//! can reach, and none of which needs AOT artifacts.
//!
//! Tokens and KV rows are generated deterministically (splitmix-style
//! hash of request id / position), so sim runs are exactly reproducible
//! and still exercise the real INT4 pack/dequant pool path.

use super::backend::{DecodeOut, ExecBackend, Lane, PrefillOut};
use super::kvcache::mix64 as mix;
use super::mapper::{
    map_decode_step, summarize, Assignment, Engine as MapEngine, MapSummary,
};
use super::pjrt::PREFILL_T;
use crate::accel::Accel;
use crate::config::llm::LlmConfig;
use crate::coordinator::kvcache::KvPool;
use crate::error::Result;
use crate::sim::npu;
use crate::telemetry::{Trace, TraceLane};

/// value in [-1, 1) from a hash
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
}

pub struct SimBackend {
    accel: Accel,
    model: LlmConfig,
    /// pool/prefill context cap (<= model.max_ctx); also the longest
    /// prompt one modeled prefill absorbs
    ctx_limit: usize,
    clock_ms: f64,
    last_map: Option<MapSummary>,
    /// per-op assignments behind `last_map` (device-lane telemetry
    /// replays them every step; shape-invariant like the summary)
    last_asg: Vec<Assignment>,
    /// (bs, ctx) the cached mapping summary was computed for
    map_key: (usize, usize),
    /// device-occupancy telemetry (default off = zero overhead)
    trace: Trace,
}

impl SimBackend {
    pub fn new(accel: Accel, model: LlmConfig, ctx_limit: usize) -> Self {
        let ctx_limit = ctx_limit.min(model.max_ctx).max(1);
        SimBackend {
            accel,
            model,
            ctx_limit,
            clock_ms: 0.0,
            last_map: None,
            last_asg: vec![],
            map_key: (0, 0),
            trace: Trace::off(),
        }
    }

    /// Lay the step's per-op assignments onto the NPU/PIM device lanes
    /// and price the PIM partial-sum return on the bus lane.  The ops
    /// tile `[t0, t1]` serially (the engine executes them in trace
    /// order today -- the overlap factor reads ~0 until the ROADMAP's
    /// sub-batch interleaving lands), normalized so the lane timeline
    /// matches the clock charge exactly.
    fn trace_decode_lanes(&self, t0: f64, t1: f64, bs: usize) {
        let serial_ns: f64 = self.last_asg.iter().map(|a| a.ns).sum();
        if serial_ns <= 0.0 || t1 <= t0 {
            return;
        }
        let scale = (t1 - t0) / (serial_ns / 1e6);
        let mut cur = t0;
        let mut pim_used = false;
        for a in &self.last_asg {
            let lane = match a.engine {
                MapEngine::Npu => TraceLane::Npu,
                MapEngine::Pim => {
                    pim_used = true;
                    TraceLane::Pim
                }
            };
            let d = a.ns / 1e6 * scale;
            self.trace
                .span(lane, a.op, cur, cur + d, None, None, a.commands as f64);
            cur += d;
        }
        if pim_used {
            // PIM results (fp16 activations, one row per lane) return
            // to the NPU over the external bus each step
            let bytes = (bs * self.model.hidden * 2) as f64;
            let bus_ms =
                npu::transfer(&self.accel.system.hbm, bytes).ns / 1e6;
            let b0 = (t1 - bus_ms).max(t0);
            self.trace.span(
                TraceLane::Bus,
                "pim_return",
                b0,
                t1,
                None,
                None,
                bytes,
            );
        }
    }

    pub fn accel(&self) -> &Accel {
        &self.accel
    }

    pub fn ctx_limit(&self) -> usize {
        self.ctx_limit
    }

    fn synth_row(&self, seed: u64, out: &mut [f32]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = unit(mix(seed ^ (i as u64).wrapping_mul(0xA24BAED4963EE407)));
        }
    }

    fn synth_token(&self, seed: u64) -> i32 {
        (mix(seed) % self.model.vocab as u64) as i32
    }

    /// Deterministic prefill outputs (tokens, smoothing, KV rows) for a
    /// prompt -- shared by the modeled prefill and by
    /// `install_prefill`, which charges transfer time instead of
    /// compute but must produce the identical KV state.
    fn synth_prefill(&self, prompt: &[i32]) -> PrefillOut {
        let true_len = prompt.len().min(self.ctx_limit);
        let kvd = self.model.kv_dim();
        let layers = self.model.layers;
        let pseed = prompt
            .iter()
            .fold(0x5EED_u64, |h, &t| mix(h ^ t as u64));
        // mild deterministic per-channel variation stands in for the
        // dynamic smoothing factors the real prefill graph emits
        let smooth: Vec<Vec<f32>> = (0..layers)
            .map(|l| {
                (0..kvd)
                    .map(|c| {
                        1.0 + 0.5
                            * unit(mix(pseed ^ ((l * kvd + c) as u64)))
                                .abs()
                    })
                    .collect()
            })
            .collect();
        let mut k = vec![0.0f32; layers * true_len * kvd];
        let mut v = vec![0.0f32; layers * true_len * kvd];
        for l in 0..layers {
            for t in 0..true_len {
                let off = (l * true_len + t) * kvd;
                let seed = mix(pseed ^ ((l as u64) << 32) ^ t as u64);
                self.synth_row(seed, &mut k[off..off + kvd]);
                self.synth_row(seed ^ 0xDEAD, &mut v[off..off + kvd]);
            }
        }
        PrefillOut {
            first_token: self.synth_token(pseed ^ 0xF1257),
            smooth,
            k,
            v,
            true_len,
        }
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn model(&self) -> &LlmConfig {
        &self.model
    }

    fn max_prefill(&self) -> usize {
        // one modeled prefill tile; the engine absorbs longer prompts
        // in successive tiles (chunked_prefill below)
        PREFILL_T.min(self.ctx_limit)
    }

    fn chunked_prefill(&self) -> bool {
        true
    }

    fn now_ms(&self) -> f64 {
        self.clock_ms
    }

    fn advance_to(&mut self, ms: f64) {
        self.clock_ms = self.clock_ms.max(ms);
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let out = self.synth_prefill(prompt);
        // prefill is NPU territory (compute-bound GEMM, Section II)
        let t0 = self.clock_ms;
        self.clock_ms += self.accel.prefill_ms(&self.model, out.true_len);
        self.trace.span(
            TraceLane::Npu,
            "prefill",
            t0,
            self.clock_ms,
            None,
            None,
            out.true_len as f64,
        );
        Ok(out)
    }

    fn prefill_continue(
        &mut self,
        chunk: &[i32],
        prefix_len: usize,
    ) -> Result<PrefillOut> {
        let out = self.synth_prefill(chunk);
        // incremental causal-attention cost of extending `prefix_len`
        // installed tokens by this tile: prefill_ms(prefix + tile) -
        // prefill_ms(prefix), so the telescoping sum over a prompt's
        // tiles charges exactly prefill_ms(total) -- tile-local
        // costing would silently drop the quadratic attention term
        let end = (prefix_len + out.true_len).min(self.model.max_ctx);
        let base = if prefix_len == 0 {
            0.0
        } else {
            self.accel.prefill_ms(&self.model, prefix_len)
        };
        let inc = self.accel.prefill_ms(&self.model, end) - base;
        let t0 = self.clock_ms;
        self.clock_ms += inc.max(0.0);
        self.trace.span(
            TraceLane::Npu,
            "prefill_tile",
            t0,
            self.clock_ms,
            None,
            None,
            out.true_len as f64,
        );
        Ok(out)
    }

    fn install_prefill(
        &mut self,
        prompt: &[i32],
        charge_ms: f64,
    ) -> Result<PrefillOut> {
        // KV arrives over the fabric, not from compute: deterministic
        // synthetic state (seeded by the whole prompt, so it differs
        // bitwise from a *tiled* local prefill of the same prompt --
        // the sim decode path never reads KV contents, only its
        // occupancy), transfer-priced clock advance
        let out = self.synth_prefill(prompt);
        let t0 = self.clock_ms;
        self.clock_ms += charge_ms.max(0.0);
        self.trace.span(
            TraceLane::Bus,
            "kv_install",
            t0,
            self.clock_ms,
            None,
            None,
            out.true_len as f64,
        );
        Ok(out)
    }

    fn decode_step(&mut self, lanes: &[Lane], _pool: &KvPool) -> Result<DecodeOut> {
        let bs = lanes.len();
        // the modeled step prices the deepest lane's context (uniform-
        // context costing, like the paper's batch sweeps)
        let ctx = lanes
            .iter()
            .map(|l| l.pos + 1)
            .max()
            .unwrap_or(1)
            .min(self.ctx_limit);
        let step = self.accel.decode_step(&self.model, bs, ctx);
        let t0 = self.clock_ms;
        self.clock_ms += step.total_ns() / 1e6;
        if self.map_key != (bs, ctx) {
            // refresh the operator-mapping summary when the step shape
            // changes (it is invariant otherwise)
            let asg = map_decode_step(&self.accel, &self.model, bs, ctx);
            self.last_map = Some(summarize(&asg));
            self.last_asg = asg;
            self.map_key = (bs, ctx);
        }
        if self.trace.enabled() {
            self.trace_decode_lanes(t0, self.clock_ms, bs);
        }
        let kvd = self.model.kv_dim();
        let layers = self.model.layers;
        let mut tokens = Vec::with_capacity(bs);
        let mut new_k = vec![0.0f32; layers * bs * kvd];
        let mut new_v = vec![0.0f32; layers * bs * kvd];
        for (lane, li) in lanes.iter().enumerate() {
            let seed = mix(li.rid ^ ((li.pos as u64) << 20));
            tokens.push(self.synth_token(seed));
            for layer in 0..layers {
                let off = (layer * bs + lane) * kvd;
                let ls = mix(seed ^ ((layer as u64) << 48));
                self.synth_row(ls, &mut new_k[off..off + kvd]);
                self.synth_row(ls ^ 0xBEEF, &mut new_v[off..off + kvd]);
            }
        }
        Ok(DecodeOut { tokens, new_k, new_v })
    }

    fn mapping_summary(&self) -> Option<MapSummary> {
        self.last_map
    }

    fn set_trace(&mut self, trace: Trace) {
        self.trace = trace;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llm::TINY;

    #[test]
    fn clock_advances_and_is_deterministic() {
        let mk = || SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let mut a = mk();
        let mut b = mk();
        let pa = a.prefill(&[1, 2, 3]).unwrap();
        let pb = b.prefill(&[1, 2, 3]).unwrap();
        assert!(a.now_ms() > 0.0);
        assert_eq!(a.now_ms(), b.now_ms());
        assert_eq!(pa.first_token, pb.first_token);
        assert_eq!(pa.k, pb.k);
        assert!(pa.first_token >= 0 && (pa.first_token as usize) < TINY.vocab);
        assert_eq!(pa.true_len, 3);
        assert_eq!(pa.smooth.len(), TINY.layers);
        assert!(pa.smooth[0].iter().all(|&f| (1.0..=1.5).contains(&f)));
    }

    #[test]
    fn bigger_batch_costs_more_time() {
        let mut s = SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let pool = KvPool::new(
            crate::coordinator::kvcache::KvLayout {
                layers: TINY.layers,
                kv_dim: TINY.kv_dim(),
                head_dim: TINY.head_dim,
                max_ctx: 128,
            },
            usize::MAX,
        );
        let lane = |rid| Lane { rid, last_token: 1, pos: 4 };
        let t0 = s.now_ms();
        s.decode_step(&[lane(1)], &pool).unwrap();
        let d1 = s.now_ms() - t0;
        let t1 = s.now_ms();
        s.decode_step(&(0..32).map(lane).collect::<Vec<_>>(), &pool)
            .unwrap();
        let d32 = s.now_ms() - t1;
        assert!(d32 > d1, "{d32} vs {d1}");
        let m = s.mapping_summary().unwrap();
        assert!(m.npu_ops > 0);
        assert!(m.pim_ops + m.npu_ops >= 8);
    }
}
