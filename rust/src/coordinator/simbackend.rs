//! Simulated execution backend: drives the *same* engine lifecycle
//! (batcher, quantized KV pool, request states, metrics) as the PJRT
//! backend, but the numerics are synthetic and time advances from the
//! `accel`/`sim` NPU-PIM cost model instead of the wall clock.
//!
//! This is the serving-loop view of the paper's evaluation substrate:
//! TTFT and per-token latency percentiles under continuous batching at
//! batch 64+ and multi-thousand-token contexts on any configured model
//! x scheme x system -- none of which the PJRT-on-CPU tiny-model path
//! can reach, and none of which needs AOT artifacts.
//!
//! Tokens and KV rows are generated deterministically (splitmix-style
//! hash of request id / position), so sim runs are exactly reproducible
//! and still exercise the real INT4 pack/dequant pool path.

use super::backend::{
    DecodeOut, ExecBackend, InterleaveStats, Lane, PrefillOut,
};
use super::kvcache::mix64 as mix;
use super::mapper::{
    engine_ms, map_decode_step, summarize, Assignment, Engine as MapEngine,
    MapSummary,
};
use super::pjrt::PREFILL_T;
use crate::accel::Accel;
use crate::config::llm::LlmConfig;
use crate::coordinator::kvcache::KvPool;
use crate::error::Result;
use crate::sim::npu;
use crate::telemetry::{Trace, TraceLane};

/// value in [-1, 1) from a hash
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
}

pub struct SimBackend {
    accel: Accel,
    model: LlmConfig,
    /// pool/prefill context cap (<= model.max_ctx); also the longest
    /// prompt one modeled prefill absorbs
    ctx_limit: usize,
    clock_ms: f64,
    last_map: Option<MapSummary>,
    /// per-op assignments behind `last_map` (device-lane telemetry
    /// replays them every step; shape-invariant like the summary)
    last_asg: Vec<Assignment>,
    /// (bs, ctx) the cached mapping summary was computed for
    map_key: (usize, usize),
    /// device-occupancy telemetry (default off = zero overhead)
    trace: Trace,
    /// cumulative NPU/PIM sub-batch interleaving counters
    ilv: InterleaveStats,
}

impl SimBackend {
    pub fn new(accel: Accel, model: LlmConfig, ctx_limit: usize) -> Self {
        let ctx_limit = ctx_limit.min(model.max_ctx).max(1);
        SimBackend {
            accel,
            model,
            ctx_limit,
            clock_ms: 0.0,
            last_map: None,
            last_asg: vec![],
            map_key: (0, 0),
            trace: Trace::off(),
            ilv: InterleaveStats::default(),
        }
    }

    /// Lay the step's per-op assignments onto the NPU/PIM device lanes
    /// and price the PIM partial-sum return on the bus lane.  The ops
    /// tile `[t0, t1]` serially (this is the *serial* schedule --
    /// interleaved steps trace their two concurrent phases through
    /// `trace_phase` instead), normalized so the lane timeline matches
    /// the clock charge exactly.
    fn trace_decode_lanes(&self, t0: f64, t1: f64, bs: usize) {
        let serial_ns: f64 = self.last_asg.iter().map(|a| a.ns).sum();
        if serial_ns <= 0.0 || t1 <= t0 {
            return;
        }
        let scale = (t1 - t0) / (serial_ns / 1e6);
        let mut cur = t0;
        let mut pim_used = false;
        for a in &self.last_asg {
            let lane = match a.engine {
                MapEngine::Npu => TraceLane::Npu,
                MapEngine::Pim => {
                    pim_used = true;
                    TraceLane::Pim
                }
            };
            let d = a.ns / 1e6 * scale;
            self.trace
                .span(lane, a.op, cur, cur + d, None, None, a.commands as f64);
            cur += d;
        }
        if pim_used {
            // PIM results (fp16 activations, one row per lane) return
            // to the NPU over the external bus each step
            let bytes = (bs * self.model.hidden * 2) as f64;
            let bus_ms =
                npu::transfer(&self.accel.system.hbm, bytes).ns / 1e6;
            let b0 = (t1 - bus_ms).max(t0);
            self.trace.span(
                TraceLane::Bus,
                "pim_return",
                b0,
                t1,
                None,
                None,
                bytes,
            );
        }
    }

    /// Lay one sub-batch's assignments for a single engine serially
    /// from `t0` onto that engine's device lane (interleaved steps
    /// trace each phase at its real critical-path position instead of
    /// replaying the whole serial schedule).
    fn trace_phase(&self, asg: &[Assignment], engine: MapEngine, t0: f64) {
        let lane = match engine {
            MapEngine::Npu => TraceLane::Npu,
            MapEngine::Pim => TraceLane::Pim,
        };
        let mut cur = t0;
        for a in asg.iter().filter(|a| a.engine == engine) {
            let d = a.ns / 1e6;
            self.trace
                .span(lane, a.op, cur, cur + d, None, None, a.commands as f64);
            cur += d;
        }
    }

    /// Per-engine serialized cost of one sub-batch's decode step:
    /// `(npu_ms, pim_ms, assignments)`.
    fn sub_batch_cost(
        &self,
        lanes: &[Lane],
    ) -> (f64, f64, Vec<Assignment>) {
        let ctx = lanes
            .iter()
            .map(|l| l.pos + 1)
            .max()
            .unwrap_or(1)
            .min(self.ctx_limit);
        let asg =
            map_decode_step(&self.accel, &self.model, lanes.len(), ctx);
        let (npu, pim) = engine_ms(&asg);
        (npu, pim, asg)
    }

    /// Deterministic tokens + KV rows for a decode step over `lanes`.
    /// Depends only on each lane's `(rid, pos)` and its index within
    /// `lanes`, so any sub-batch grouping that preserves lane order
    /// produces identical per-request rows.
    fn synth_decode(&self, lanes: &[Lane]) -> DecodeOut {
        let bs = lanes.len();
        let kvd = self.model.kv_dim();
        let layers = self.model.layers;
        let mut tokens = Vec::with_capacity(bs);
        let mut new_k = vec![0.0f32; layers * bs * kvd];
        let mut new_v = vec![0.0f32; layers * bs * kvd];
        for (lane, li) in lanes.iter().enumerate() {
            let seed = mix(li.rid ^ ((li.pos as u64) << 20));
            tokens.push(self.synth_token(seed));
            for layer in 0..layers {
                let off = (layer * bs + lane) * kvd;
                let ls = mix(seed ^ ((layer as u64) << 48));
                self.synth_row(ls, &mut new_k[off..off + kvd]);
                self.synth_row(ls ^ 0xBEEF, &mut new_v[off..off + kvd]);
            }
        }
        DecodeOut { tokens, new_k, new_v }
    }

    /// Interleaved-mode fallback: the split schedule would not beat
    /// the serial one, so charge the serialized stall and run the
    /// ordinary serial step over `lanes_a ++ lanes_b` -- per-step
    /// timing is then bit-identical to `interleave=off`.
    fn fused_step(
        &mut self,
        lanes_a: &[Lane],
        lanes_b: &[Lane],
        serial_stall_ms: f64,
        pool: &KvPool,
    ) -> Result<DecodeOut> {
        if serial_stall_ms > 0.0 {
            let cursor = self.clock_ms + serial_stall_ms;
            self.advance_to(cursor);
        }
        let mut lanes = Vec::with_capacity(lanes_a.len() + lanes_b.len());
        lanes.extend_from_slice(lanes_a);
        lanes.extend_from_slice(lanes_b);
        let out = self.decode_step(&lanes, pool)?;
        let (npu, pim) = engine_ms(&self.last_asg);
        self.ilv.npu_busy_ms += npu;
        self.ilv.pim_busy_ms += pim;
        self.ilv.fused_steps += 1;
        Ok(out)
    }

    pub fn accel(&self) -> &Accel {
        &self.accel
    }

    pub fn ctx_limit(&self) -> usize {
        self.ctx_limit
    }

    fn synth_row(&self, seed: u64, out: &mut [f32]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = unit(mix(seed ^ (i as u64).wrapping_mul(0xA24BAED4963EE407)));
        }
    }

    fn synth_token(&self, seed: u64) -> i32 {
        (mix(seed) % self.model.vocab as u64) as i32
    }

    /// Deterministic prefill outputs (tokens, smoothing, KV rows) for a
    /// prompt -- shared by the modeled prefill and by
    /// `install_prefill`, which charges transfer time instead of
    /// compute but must produce the identical KV state.
    fn synth_prefill(&self, prompt: &[i32]) -> PrefillOut {
        let true_len = prompt.len().min(self.ctx_limit);
        let kvd = self.model.kv_dim();
        let layers = self.model.layers;
        let pseed = prompt
            .iter()
            .fold(0x5EED_u64, |h, &t| mix(h ^ t as u64));
        // mild deterministic per-channel variation stands in for the
        // dynamic smoothing factors the real prefill graph emits
        let smooth: Vec<Vec<f32>> = (0..layers)
            .map(|l| {
                (0..kvd)
                    .map(|c| {
                        1.0 + 0.5
                            * unit(mix(pseed ^ ((l * kvd + c) as u64)))
                                .abs()
                    })
                    .collect()
            })
            .collect();
        let mut k = vec![0.0f32; layers * true_len * kvd];
        let mut v = vec![0.0f32; layers * true_len * kvd];
        for l in 0..layers {
            for t in 0..true_len {
                let off = (l * true_len + t) * kvd;
                let seed = mix(pseed ^ ((l as u64) << 32) ^ t as u64);
                self.synth_row(seed, &mut k[off..off + kvd]);
                self.synth_row(seed ^ 0xDEAD, &mut v[off..off + kvd]);
            }
        }
        PrefillOut {
            first_token: self.synth_token(pseed ^ 0xF1257),
            smooth,
            k,
            v,
            true_len,
        }
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn model(&self) -> &LlmConfig {
        &self.model
    }

    fn max_prefill(&self) -> usize {
        // one modeled prefill tile; the engine absorbs longer prompts
        // in successive tiles (chunked_prefill below)
        PREFILL_T.min(self.ctx_limit)
    }

    fn chunked_prefill(&self) -> bool {
        true
    }

    fn now_ms(&self) -> f64 {
        self.clock_ms
    }

    fn advance_to(&mut self, ms: f64) {
        self.clock_ms = self.clock_ms.max(ms);
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let out = self.synth_prefill(prompt);
        // prefill is NPU territory (compute-bound GEMM, Section II)
        let t0 = self.clock_ms;
        self.clock_ms += self.accel.prefill_ms(&self.model, out.true_len);
        self.trace.span(
            TraceLane::Npu,
            "prefill",
            t0,
            self.clock_ms,
            None,
            None,
            out.true_len as f64,
        );
        Ok(out)
    }

    fn prefill_continue(
        &mut self,
        chunk: &[i32],
        prefix_len: usize,
    ) -> Result<PrefillOut> {
        let out = self.synth_prefill(chunk);
        // incremental causal-attention cost of extending `prefix_len`
        // installed tokens by this tile: prefill_ms(prefix + tile) -
        // prefill_ms(prefix), so the telescoping sum over a prompt's
        // tiles charges exactly prefill_ms(total) -- tile-local
        // costing would silently drop the quadratic attention term
        let end = (prefix_len + out.true_len).min(self.model.max_ctx);
        let base = if prefix_len == 0 {
            0.0
        } else {
            self.accel.prefill_ms(&self.model, prefix_len)
        };
        let inc = self.accel.prefill_ms(&self.model, end) - base;
        let t0 = self.clock_ms;
        self.clock_ms += inc.max(0.0);
        self.trace.span(
            TraceLane::Npu,
            "prefill_tile",
            t0,
            self.clock_ms,
            None,
            None,
            out.true_len as f64,
        );
        Ok(out)
    }

    fn install_prefill(
        &mut self,
        prompt: &[i32],
        charge_ms: f64,
    ) -> Result<PrefillOut> {
        // KV arrives over the fabric, not from compute: deterministic
        // synthetic state (seeded by the whole prompt, so it differs
        // bitwise from a *tiled* local prefill of the same prompt --
        // the sim decode path never reads KV contents, only its
        // occupancy), transfer-priced clock advance
        let out = self.synth_prefill(prompt);
        let t0 = self.clock_ms;
        self.clock_ms += charge_ms.max(0.0);
        self.trace.span(
            TraceLane::Bus,
            "kv_install",
            t0,
            self.clock_ms,
            None,
            None,
            out.true_len as f64,
        );
        Ok(out)
    }

    fn decode_step(&mut self, lanes: &[Lane], _pool: &KvPool) -> Result<DecodeOut> {
        let bs = lanes.len();
        // the modeled step prices the deepest lane's context (uniform-
        // context costing, like the paper's batch sweeps)
        let ctx = lanes
            .iter()
            .map(|l| l.pos + 1)
            .max()
            .unwrap_or(1)
            .min(self.ctx_limit);
        let step = self.accel.decode_step(&self.model, bs, ctx);
        let t0 = self.clock_ms;
        self.clock_ms += step.total_ns() / 1e6;
        if self.map_key != (bs, ctx) {
            // refresh the operator-mapping summary when the step shape
            // changes (it is invariant otherwise)
            let asg = map_decode_step(&self.accel, &self.model, bs, ctx);
            self.last_map = Some(summarize(&asg));
            self.last_asg = asg;
            self.map_key = (bs, ctx);
        }
        if self.trace.enabled() {
            self.trace_decode_lanes(t0, self.clock_ms, bs);
        }
        Ok(self.synth_decode(lanes))
    }

    fn decode_step_interleaved(
        &mut self,
        lanes_a: &[Lane],
        lanes_b: &[Lane],
        stall_a_ms: f64,
        stall_b_ms: f64,
        serial_stall_ms: f64,
        pool: &KvPool,
    ) -> Result<DecodeOut> {
        if lanes_a.is_empty() || lanes_b.is_empty() {
            // one sub-batch: nothing to overlap, charge the serial
            // schedule (same as `interleave=off`)
            return self.fused_step(
                lanes_a,
                lanes_b,
                serial_stall_ms,
                pool,
            );
        }
        let bs = lanes_a.len() + lanes_b.len();
        let ctx = lanes_a
            .iter()
            .chain(lanes_b.iter())
            .map(|l| l.pos + 1)
            .max()
            .unwrap_or(1)
            .min(self.ctx_limit);
        // what the serial schedule would charge for the fused batch
        let serial_ms =
            self.accel.decode_step(&self.model, bs, ctx).total_ns() / 1e6;
        let t0 = self.clock_ms;
        let serial_end = t0 + serial_stall_ms + serial_ms;
        // two-phase critical path: phase 1 runs A on the NPU while B
        // streams on the PIM, phase 2 swaps engines.  Demand-miss
        // stalls delay only the owning sub-batch's timeline.
        let (npu_a, pim_a, asg_a) = self.sub_batch_cost(lanes_a);
        let (npu_b, pim_b, asg_b) = self.sub_batch_cost(lanes_b);
        let a_start = t0 + stall_a_ms.max(0.0);
        let b_start = t0 + stall_b_ms.max(0.0);
        let p2 = (a_start + npu_a).max(b_start + pim_b);
        let end = (p2 + pim_a).max(p2 + npu_b);
        if end >= serial_end {
            // splitting loses (PIM weight-streaming passes conserve
            // across the split at small per-sub-batch m): fuse back to
            // the serial schedule so interleaving never regresses
            return self.fused_step(
                lanes_a,
                lanes_b,
                serial_stall_ms,
                pool,
            );
        }
        self.clock_ms = end;
        if self.map_key != (bs, ctx) {
            let asg = map_decode_step(&self.accel, &self.model, bs, ctx);
            self.last_map = Some(summarize(&asg));
            self.last_asg = asg;
            self.map_key = (bs, ctx);
        }
        // overlap: phase-1 window intersection + the fully concurrent
        // phase-2 pair (both start at the phase barrier `p2`)
        let o1 = ((a_start + npu_a).min(b_start + pim_b)
            - a_start.max(b_start))
        .max(0.0);
        let o2 = pim_a.min(npu_b);
        self.ilv.npu_busy_ms += npu_a + npu_b;
        self.ilv.pim_busy_ms += pim_a + pim_b;
        self.ilv.overlap_ms += o1 + o2;
        self.ilv.interleaved_steps += 1;
        self.ilv.serial_saved_ms += serial_end - end;
        if self.trace.enabled() {
            // phase 1: A-NPU || B-PIM; phase 2: A-PIM || B-NPU
            self.trace_phase(&asg_a, MapEngine::Npu, a_start);
            self.trace_phase(&asg_b, MapEngine::Pim, b_start);
            self.trace_phase(&asg_a, MapEngine::Pim, p2);
            self.trace_phase(&asg_b, MapEngine::Npu, p2);
            let pim_used = asg_a
                .iter()
                .chain(asg_b.iter())
                .any(|a| a.engine == MapEngine::Pim);
            if pim_used {
                let bytes = (bs * self.model.hidden * 2) as f64;
                let bus_ms =
                    npu::transfer(&self.accel.system.hbm, bytes).ns / 1e6;
                let b0 = (end - bus_ms).max(t0);
                self.trace.span(
                    TraceLane::Bus,
                    "pim_return",
                    b0,
                    end,
                    None,
                    None,
                    bytes,
                );
            }
        }
        let mut lanes = Vec::with_capacity(bs);
        lanes.extend_from_slice(lanes_a);
        lanes.extend_from_slice(lanes_b);
        Ok(self.synth_decode(&lanes))
    }

    fn mapping_summary(&self) -> Option<MapSummary> {
        self.last_map
    }

    fn interleave_stats(&self) -> InterleaveStats {
        self.ilv
    }

    fn set_trace(&mut self, trace: Trace) {
        self.trace = trace;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llm::TINY;

    #[test]
    fn clock_advances_and_is_deterministic() {
        let mk = || SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let mut a = mk();
        let mut b = mk();
        let pa = a.prefill(&[1, 2, 3]).unwrap();
        let pb = b.prefill(&[1, 2, 3]).unwrap();
        assert!(a.now_ms() > 0.0);
        assert_eq!(a.now_ms(), b.now_ms());
        assert_eq!(pa.first_token, pb.first_token);
        assert_eq!(pa.k, pb.k);
        assert!(pa.first_token >= 0 && (pa.first_token as usize) < TINY.vocab);
        assert_eq!(pa.true_len, 3);
        assert_eq!(pa.smooth.len(), TINY.layers);
        assert!(pa.smooth[0].iter().all(|&f| (1.0..=1.5).contains(&f)));
    }

    #[test]
    fn bigger_batch_costs_more_time() {
        let mut s = SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let pool = KvPool::new(
            crate::coordinator::kvcache::KvLayout {
                layers: TINY.layers,
                kv_dim: TINY.kv_dim(),
                head_dim: TINY.head_dim,
                max_ctx: 128,
            },
            usize::MAX,
        );
        let lane = |rid| Lane { rid, last_token: 1, pos: 4 };
        let t0 = s.now_ms();
        s.decode_step(&[lane(1)], &pool).unwrap();
        let d1 = s.now_ms() - t0;
        let t1 = s.now_ms();
        s.decode_step(&(0..32).map(lane).collect::<Vec<_>>(), &pool)
            .unwrap();
        let d32 = s.now_ms() - t1;
        assert!(d32 > d1, "{d32} vs {d1}");
        let m = s.mapping_summary().unwrap();
        assert!(m.npu_ops > 0);
        assert!(m.pim_ops + m.npu_ops >= 8);
    }

    /// even-index lanes -> A, odd-index -> B (the engine's split rule)
    fn parity_split(lanes: &[Lane]) -> (Vec<Lane>, Vec<Lane>) {
        let (mut a, mut b) = (vec![], vec![]);
        for (i, l) in lanes.iter().enumerate() {
            if i % 2 == 0 {
                a.push(*l);
            } else {
                b.push(*l);
            }
        }
        (a, b)
    }

    fn tiny_pool() -> KvPool {
        KvPool::new(
            crate::coordinator::kvcache::KvLayout {
                layers: TINY.layers,
                kv_dim: TINY.kv_dim(),
                head_dim: TINY.head_dim,
                max_ctx: 128,
            },
            usize::MAX,
        )
    }

    #[test]
    fn interleaved_step_beats_serial_and_conserves_outputs() {
        let mk = || SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let pool = tiny_pool();
        let lanes: Vec<Lane> = (0..8)
            .map(|i| Lane { rid: i, last_token: 1, pos: 100 })
            .collect();
        let (a, b) = parity_split(&lanes);
        let mut combined = a.clone();
        combined.extend_from_slice(&b);
        let mut ser = mk();
        let so = ser.decode_step(&combined, &pool).unwrap();
        let serial_ms = ser.now_ms();
        let mut ilv = mk();
        let io = ilv
            .decode_step_interleaved(&a, &b, 0.0, 0.0, 0.0, &pool)
            .unwrap();
        assert!(
            ilv.now_ms() < serial_ms,
            "interleaved {} !< serial {}",
            ilv.now_ms(),
            serial_ms
        );
        assert_eq!(so.tokens, io.tokens);
        assert_eq!(so.new_k, io.new_k);
        assert_eq!(so.new_v, io.new_v);
        let st = ilv.interleave_stats();
        assert_eq!(st.interleaved_steps, 1);
        assert_eq!(st.fused_steps, 0);
        assert!(st.overlap_factor() > 0.3, "{}", st.overlap_factor());
        assert!(st.serial_saved_ms > 0.0);
        // serial path accrues no interleave counters
        assert_eq!(ser.interleave_stats(), InterleaveStats::default());
    }

    #[test]
    fn losing_split_fuses_back_to_the_serial_charge() {
        // bs=2 on the tiny model: the PIM weight-stream conserves
        // across the split, so the fused fallback must charge exactly
        // the serial schedule
        let mk = || SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let pool = tiny_pool();
        let a = [Lane { rid: 1, last_token: 1, pos: 100 }];
        let b = [Lane { rid: 2, last_token: 1, pos: 100 }];
        let mut ser = mk();
        ser.decode_step(
            &[a[0], b[0]],
            &pool,
        )
        .unwrap();
        let mut ilv = mk();
        ilv.decode_step_interleaved(&a, &b, 0.0, 0.0, 0.0, &pool)
            .unwrap();
        assert_eq!(ilv.now_ms(), ser.now_ms());
        let st = ilv.interleave_stats();
        assert_eq!(st.interleaved_steps, 0);
        assert_eq!(st.fused_steps, 1);
        assert_eq!(st.overlap_ms, 0.0);
    }

    #[test]
    fn per_sub_batch_stalls_delay_only_their_timeline() {
        let mk = || SimBackend::new(Accel::p3llm(), TINY.clone(), 128);
        let pool = tiny_pool();
        let lanes: Vec<Lane> = (0..8)
            .map(|i| Lane { rid: i, last_token: 1, pos: 100 })
            .collect();
        let (a, b) = parity_split(&lanes);
        let mut no_stall = mk();
        no_stall
            .decode_step_interleaved(&a, &b, 0.0, 0.0, 0.0, &pool)
            .unwrap();
        let base = no_stall.now_ms();
        // stall only sub-batch B by less than A's NPU phase: B's PIM
        // start shifts but the critical path can absorb part of it, so
        // the end moves by at most the stall
        let stall = base * 0.25;
        let mut stalled = mk();
        stalled
            .decode_step_interleaved(&a, &b, 0.0, stall, stall, &pool)
            .unwrap();
        assert!(stalled.now_ms() > base);
        assert!(stalled.now_ms() <= base + stall + 1e-12);
    }
}
