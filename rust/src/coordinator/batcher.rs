//! Continuous batcher: admits queued requests into the active decode
//! set at step boundaries and picks the AOT graph batch size.
//!
//! The decode graphs are compiled for batch sizes {1, 2, 4, 8}; the
//! batcher selects the smallest compiled size that covers the active
//! set and pads the rest (padding lanes attend to a zeroed slot-0 and
//! their outputs are discarded).

use super::request::RequestId;

pub const COMPILED_BATCHES: [usize; 4] = [1, 2, 4, 8];

#[derive(Debug, Clone)]
pub struct Batcher {
    pub max_batch: usize,
    queue: std::collections::VecDeque<RequestId>,
    active: Vec<RequestId>,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        assert!(COMPILED_BATCHES.contains(&max_batch));
        Batcher { max_batch, queue: Default::default(), active: vec![] }
    }

    pub fn enqueue(&mut self, id: RequestId) {
        self.queue.push_back(id);
    }

    /// Admit as many queued requests as fit; returns the newly admitted
    /// ids (they need prefill before the next decode step).
    pub fn admit(&mut self) -> Vec<RequestId> {
        let mut newly = vec![];
        while self.active.len() < self.max_batch {
            match self.queue.pop_front() {
                Some(id) => {
                    self.active.push(id);
                    newly.push(id);
                }
                None => break,
            }
        }
        newly
    }

    pub fn retire(&mut self, id: RequestId) {
        self.active.retain(|&r| r != id);
    }

    pub fn active(&self) -> &[RequestId] {
        &self.active
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Smallest compiled batch covering the active set.
    pub fn graph_batch(&self) -> Option<usize> {
        let n = self.active.len();
        if n == 0 {
            return None;
        }
        COMPILED_BATCHES.iter().copied().find(|&b| b >= n)
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Rng, Runner};

    fn id(n: u64) -> RequestId {
        RequestId(n)
    }

    #[test]
    fn admits_up_to_max() {
        let mut b = Batcher::new(4);
        for i in 0..6 {
            b.enqueue(id(i));
        }
        let newly = b.admit();
        assert_eq!(newly.len(), 4);
        assert_eq!(b.queued(), 2);
        assert_eq!(b.graph_batch(), Some(4));
        b.retire(id(0));
        assert_eq!(b.graph_batch(), Some(4)); // 3 active -> graph 4
        b.retire(id(1));
        b.retire(id(2));
        assert_eq!(b.graph_batch(), Some(1));
        let newly = b.admit();
        assert_eq!(newly.len(), 2);
        assert_eq!(b.graph_batch(), Some(4)); // 3 active again
    }

    #[test]
    fn graph_batch_covers_active() {
        Runner::new(64).run(|r: &mut Rng| {
            let max = *r.pick(&COMPILED_BATCHES);
            let mut b = Batcher::new(max);
            let n = r.usize(0, 20);
            for i in 0..n as u64 {
                b.enqueue(id(i));
            }
            b.admit();
            // invariants: active <= max; graph batch covers active;
            // admitted + queued conserve the submitted count
            assert!(b.active().len() <= max);
            assert_eq!(b.active().len() + b.queued(), n);
            if let Some(g) = b.graph_batch() {
                assert!(g >= b.active().len());
                assert!(COMPILED_BATCHES.contains(&g));
            } else {
                assert!(b.active().is_empty());
            }
        });
    }

    #[test]
    fn continuous_admission_after_retire() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.enqueue(id(i));
        }
        b.admit();
        assert_eq!(b.active(), &[id(0), id(1)]);
        b.retire(id(0));
        b.admit();
        assert_eq!(b.active(), &[id(1), id(2)]);
    }
}
