//! Continuous batcher: admits queued requests into the active decode
//! set at step boundaries.
//!
//! The batcher is execution-substrate agnostic: the *backend* decides
//! how many lanes actually run (the PJRT backend pads the active set up
//! to the smallest AOT-compiled batch via [`covering_batch`]; the sim
//! backend runs the active set exactly).  Capacity-rejected requests go
//! back to the *front* of the queue via [`Batcher::requeue_front`] so
//! admission order is preserved.

use super::request::RequestId;

/// Batch sizes the AOT decode graphs are compiled for (PJRT backend).
pub const COMPILED_BATCHES: [usize; 4] = [1, 2, 4, 8];

/// Smallest size in `sizes` covering `n` active lanes (None when the
/// active set is empty or nothing covers it).
pub fn covering_batch(sizes: &[usize], n: usize) -> Option<usize> {
    if n == 0 {
        return None;
    }
    sizes.iter().copied().filter(|&b| b >= n).min()
}

#[derive(Debug, Clone)]
pub struct Batcher {
    pub max_batch: usize,
    queue: std::collections::VecDeque<RequestId>,
    active: Vec<RequestId>,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        Batcher { max_batch, queue: Default::default(), active: vec![] }
    }

    pub fn enqueue(&mut self, id: RequestId) {
        self.queue.push_back(id);
    }

    /// Admit as many queued requests as fit; returns the newly admitted
    /// ids (they need prefill before the next decode step).
    pub fn admit(&mut self) -> Vec<RequestId> {
        let mut newly = vec![];
        while self.active.len() < self.max_batch {
            match self.queue.pop_front() {
                Some(id) => {
                    self.active.push(id);
                    newly.push(id);
                }
                None => break,
            }
        }
        newly
    }

    /// Admit by priority key instead of FIFO: repeatedly takes the
    /// queued request minimizing `key` until the batch is full.  The
    /// scan is stable (first-queued wins a tie), so a key of unit type
    /// degenerates to plain FIFO [`Batcher::admit`] and a key of
    /// `(rank, submit_time)` is FIFO within each priority tier.
    pub fn admit_by<K: Ord>(
        &mut self,
        mut key: impl FnMut(RequestId) -> K,
    ) -> Vec<RequestId> {
        let mut newly = vec![];
        while self.active.len() < self.max_batch && !self.queue.is_empty() {
            let qi = (0..self.queue.len())
                .min_by_key(|&i| key(self.queue[i]))
                .expect("non-empty queue");
            let id = self.queue.remove(qi).expect("index in bounds");
            self.active.push(id);
            newly.push(id);
        }
        newly
    }

    pub fn retire(&mut self, id: RequestId) {
        self.active.retain(|&r| r != id);
    }

    /// Bounce an admitted-but-unservable request (e.g. KV pool full)
    /// back to the head of the queue: it stays first in line and is
    /// re-admitted as soon as a lane's KV reservation frees.
    pub fn requeue_front(&mut self, id: RequestId) {
        self.active.retain(|&r| r != id);
        self.queue.push_front(id);
    }

    pub fn active(&self) -> &[RequestId] {
        &self.active
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// `(queued, active)` depths in one call -- the telemetry layer
    /// samples this at every step boundary into the `queue_depth` /
    /// `active_lanes` counter tracks.
    pub fn depths(&self) -> (usize, usize) {
        (self.queue.len(), self.active.len())
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Rng, Runner};

    fn id(n: u64) -> RequestId {
        RequestId(n)
    }

    #[test]
    fn admits_up_to_max() {
        let mut b = Batcher::new(4);
        for i in 0..6 {
            b.enqueue(id(i));
        }
        let newly = b.admit();
        assert_eq!(newly.len(), 4);
        assert_eq!(b.queued(), 2);
        assert_eq!(covering_batch(&COMPILED_BATCHES, b.active().len()), Some(4));
        b.retire(id(0));
        // 3 active -> graph 4
        assert_eq!(covering_batch(&COMPILED_BATCHES, b.active().len()), Some(4));
        b.retire(id(1));
        b.retire(id(2));
        assert_eq!(covering_batch(&COMPILED_BATCHES, b.active().len()), Some(1));
        let newly = b.admit();
        assert_eq!(newly.len(), 2);
        assert_eq!(covering_batch(&COMPILED_BATCHES, b.active().len()), Some(4));
    }

    #[test]
    fn covering_batch_covers_active() {
        Runner::new(64).run(|r: &mut Rng| {
            let max = *r.pick(&COMPILED_BATCHES);
            let mut b = Batcher::new(max);
            let n = r.usize(0, 20);
            for i in 0..n as u64 {
                b.enqueue(id(i));
            }
            b.admit();
            // invariants: active <= max; graph batch covers active;
            // admitted + queued conserve the submitted count
            assert!(b.active().len() <= max);
            assert_eq!(b.active().len() + b.queued(), n);
            if let Some(g) = covering_batch(&COMPILED_BATCHES, b.active().len()) {
                assert!(g >= b.active().len());
                assert!(COMPILED_BATCHES.contains(&g));
            } else {
                assert!(b.active().is_empty());
            }
        });
    }

    #[test]
    fn arbitrary_max_batch_for_sim() {
        // the sim backend runs lanes exactly: no compiled-size rounding
        let mut b = Batcher::new(64);
        for i in 0..70 {
            b.enqueue(id(i));
        }
        assert_eq!(b.admit().len(), 64);
        assert_eq!(b.queued(), 6);
        assert_eq!(covering_batch(&[], b.active().len()), None);
    }

    #[test]
    fn continuous_admission_after_retire() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.enqueue(id(i));
        }
        b.admit();
        assert_eq!(b.active(), &[id(0), id(1)]);
        b.retire(id(0));
        b.admit();
        assert_eq!(b.active(), &[id(1), id(2)]);
    }

    #[test]
    fn requeue_front_preserves_order() {
        let mut b = Batcher::new(3);
        for i in 0..5 {
            b.enqueue(id(i));
        }
        b.admit();
        assert_eq!(b.active(), &[id(0), id(1), id(2)]);
        // request 2 bounced (e.g. KV pool full): it must come back
        // BEFORE the untouched 3 and 4
        b.requeue_front(id(2));
        assert_eq!(b.active(), &[id(0), id(1)]);
        let newly = b.admit();
        assert_eq!(newly, vec![id(2)]);
        b.retire(id(0));
        b.retire(id(1));
        assert_eq!(b.admit(), vec![id(3), id(4)]);
    }

    #[test]
    fn admit_by_orders_by_key_and_is_fifo_on_ties() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.enqueue(id(i));
        }
        // rank: 1 and 3 are high priority (key 0), rest low (key 1)
        let rank = |r: RequestId| u8::from(r.0 != 1 && r.0 != 3);
        assert_eq!(b.admit_by(rank), vec![id(1), id(3)]);
        b.retire(id(1));
        b.retire(id(3));
        // remaining all tie on key -> plain FIFO order
        assert_eq!(b.admit_by(rank), vec![id(0), id(2)]);
        assert_eq!(b.queued(), 1);
        // unit key == admit(): pure FIFO
        b.retire(id(0));
        assert_eq!(b.admit_by(|_| ()), vec![id(4)]);
    }

    #[test]
    fn retiring_last_active_lane_goes_idle() {
        let mut b = Batcher::new(2);
        b.enqueue(id(7));
        b.admit();
        assert!(!b.idle());
        b.retire(id(7));
        assert!(b.idle());
        // retiring an unknown id is a no-op
        b.retire(id(99));
        assert!(b.idle());
    }
}
