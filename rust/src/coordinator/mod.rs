//! L3 coordinator (the paper's system layer, Fig. 6): request router +
//! continuous batcher, page-granular quantized KV-cache manager with
//! shared-prefix caching and smoothing-factor store, online NPU/PIM
//! operator mapper, and the serving engine.
//!
//! The engine drives an [`ExecBackend`]; two substrates implement it:
//! [`PjrtBackend`] (real numerics over the AOT-compiled PJRT graphs)
//! and [`SimBackend`] (the `accel` cost model advancing simulated
//! time).  See DESIGN.md for the full layer map.

pub mod backend;
pub mod batcher;
pub mod kvcache;
pub mod mapper;
pub mod pjrt;
pub mod request;
pub mod serve;
pub mod simbackend;

pub use backend::{
    BackendKind, DecodeOut, ExecBackend, InterleaveStats, Lane, PrefillOut,
};
pub use batcher::{covering_batch, Batcher, COMPILED_BATCHES};
pub use kvcache::{
    prefix_page_hash, KvLayout, KvPool, PrefixHit, PAGE_TOKENS,
};
pub use mapper::{
    engine_ms, map_decode_step, Assignment, Engine as MapEngine, MapSummary,
};
pub use pjrt::{PjrtBackend, PREFILL_T};
pub use request::{Request, RequestId, RequestStatus, State};
pub use serve::{Engine, EngineBuilder, Metrics, Percentiles};
pub use simbackend::SimBackend;
