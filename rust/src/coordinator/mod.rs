//! L3 coordinator (the paper's system layer, Fig. 6): request router +
//! continuous batcher, quantized KV-cache manager with smoothing-factor
//! store, online NPU/PIM operator mapper, and the serving engine that
//! drives the AOT-compiled PJRT graphs.

pub mod batcher;
pub mod kvcache;
pub mod mapper;
pub mod request;
pub mod scheduler;
pub mod serve;

pub use batcher::Batcher;
pub use kvcache::{KvEntry, KvLayout, KvPool};
pub use mapper::{map_decode_step, Assignment, Engine as MapEngine};
pub use request::{Request, RequestId, State};
pub use serve::{Engine, EngineConfig, Stats};
